"""The committed golden CSV must match the reference generator.

``rust/tests/golden/faults_case_study.csv`` pins the byte-exact output
of ``pgft faults`` on the paper's case study (see
``rust/tests/faults_golden.rs``).  The file is produced by
``python/tools/gen_faults_golden.py`` — an independent Python port of
the routing/faults/metrics pipeline — so this test closes the loop:
generator output == committed bytes, and the paper-pinned figures hold.
"""

import os
import sys

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
TOOLS = os.path.normpath(os.path.join(HERE, "..", "tools"))
GOLDEN = os.path.normpath(
    os.path.join(HERE, "..", "..", "rust", "tests", "golden", "faults_case_study.csv")
)
sys.path.insert(0, TOOLS)

import gen_faults_golden as gen  # noqa: E402


@pytest.fixture(scope="module")
def csv_text():
    # golden_csv() runs the generator's internal paper-pinned asserts
    # (Algorithm 1 gNIDs, §III.B/§IV C_topo, valley-freedom, fault
    # eligibility, bundle concentration) as a side effect.
    return gen.golden_csv()


def test_generator_is_deterministic(csv_text):
    assert gen.golden_csv() == csv_text


def test_committed_golden_matches_generator(csv_text):
    assert os.path.exists(GOLDEN), (
        "rust/tests/golden/faults_case_study.csv is missing — run "
        "python3 python/tools/gen_faults_golden.py and commit the result"
    )
    with open(GOLDEN, encoding="utf-8", newline="") as f:
        committed = f.read()
    assert committed == csv_text, (
        "committed golden differs from the reference generator; regenerate "
        "with python3 python/tools/gen_faults_golden.py (and re-run the "
        "Rust side: cargo test --test faults_golden)"
    )


def test_schema_and_pinned_rows(csv_text):
    lines = csv_text.splitlines()
    assert lines[0] == ",".join(gen.COLUMNS)
    rows = [line.split(",") for line in lines[1:]]
    assert len(rows) == 2 * 3, "2 algorithms x 3 fault scenarios"
    assert all(len(r) == len(gen.COLUMNS) for r in rows)
    assert rows[0][:8] == [
        "case-study", "io:last:1", "dmodk", "c2io-sym", "none", "1", "56", "4",
    ]
    assert rows[3][:8] == [
        "case-study", "io:last:1", "gdmodk", "c2io-sym", "none", "1", "56", "1",
    ]
    for r in rows:
        fault, dead, routable = r[4], r[14], r[16]
        if fault == "none":
            assert (dead, r[15], routable) == ("0", "0", "1")
        elif fault == "links:2":
            assert dead == "2"
        elif fault == "stage:3:4":
            assert dead == "4"
        # No simulate/netsim/workload requested: the optional-axis
        # columns stay empty.
        assert r[17:] == [""] * 13


def test_rng_matches_rust_reference_semantics():
    # Determinism + spread of the xoshiro256** port (mirrors
    # util::rng tests; exact Rust-vs-Python cross-values are pinned by
    # the golden bytes themselves via fault sampling).
    a = gen.Xoshiro256(42)
    b = gen.Xoshiro256(42)
    seq = [a.next_u64() for _ in range(100)]
    assert seq == [b.next_u64() for _ in range(100)]
    assert all(0 <= x <= gen.MASK for x in seq)
    c = gen.Xoshiro256(43)
    assert sum(x == y for x, y in zip(seq, (c.next_u64() for _ in range(100)))) < 3
    rng = gen.Xoshiro256(11)
    for _ in range(50):
        s = rng.sample_indices(20, 10)
        assert len(set(s)) == 10 and all(i < 20 for i in s)
