"""AOT path: lowering produces parseable HLO text with the right
parameter signature, and a small solve lowered the same way still
computes correct numbers when executed through jax itself."""

import numpy as np
import pytest

# Without jax the module fails at *collection* time (an error, not a
# skip) — guard the import so jax-less environments collect cleanly.
pytest.importorskip("jax", reason="AOT lowering needs jax")

import jax
import jax.numpy as jnp

from compile.aot import lower_portload, to_hlo_text
from compile.model import fairrate_solve


def test_portload_hlo_text_shape():
    text = lower_portload(8, 8)
    assert "HloModule" in text
    assert "f32[8,8]" in text, "incidence parameter shape"
    assert "f32[8]" in text, "vector parameter shape"
    # return_tuple=True → root is a tuple.
    assert "(f32[8]" in text


def test_fairrate_lowered_module_is_single_while():
    # The fori_loop must lower to one while op — a single execute per
    # solve, no python in the loop.
    def fn(a, cap, valid):
        return fairrate_solve(a, cap, valid, iters=8)

    spec_a = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    spec_v = jax.ShapeDtypeStruct((8,), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec_a, spec_v, spec_v))
    assert text.count("while(") >= 1 or " while " in text
    assert "HloModule" in text


def test_lowered_solver_numbers_via_jax_executable():
    # Compile the lowered function with jax and check a known case; this
    # validates the exact computation the rust runtime will execute.
    def fn(a, cap, valid):
        rates, frozen = fairrate_solve(a, cap, valid, iters=8)
        return rates, frozen

    jfn = jax.jit(fn)
    a = np.array([[1, 1], [1, 0], [0, 1]], np.float32)
    a = np.pad(a, ((0, 5), (0, 6)))
    cap = np.pad(np.array([1.0, 2.0], np.float32), (0, 6), constant_values=1.0)
    valid = np.pad(np.ones(3, np.float32), (0, 5))
    rates, frozen = jfn(a, cap, valid)
    np.testing.assert_allclose(np.asarray(rates)[:3], [0.5, 0.5, 1.5], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(rates)[3:], 0.0)
    assert np.all(np.asarray(frozen)[:3] == 1.0)
