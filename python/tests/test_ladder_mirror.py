"""The parameterized ladder mirror must agree with the golden mirror.

``python/tools/pgft_ladder.py`` generalizes the hard-coded case-study
port in ``gen_faults_golden.py`` to any ``PGFT(h; m; w; p)`` and swaps
the dense per-destination reachability tables for lazy memoized ones.
On the case study — where both exist — the two must agree on every
observable: topology ids, pristine routes, fault expansion, and every
degraded route.  The sampled-pair generator and the chunk-and-splice
repair (the Python half of the Rust ``retrace_incremental_par``
invariant) are pinned here too.
"""

import os
import sys

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
TOOLS = os.path.normpath(os.path.join(HERE, "..", "tools"))
sys.path.insert(0, TOOLS)

import gen_faults_golden as gold  # noqa: E402
import pgft_ladder as lad  # noqa: E402


@pytest.fixture(scope="module")
def case_pair():
    return gold.Topo(), lad.Topo(lad.named_spec("case-study"))


def all_pairs(n):
    return [(s, d) for s in range(n) for d in range(n) if s != d]


def test_topology_ids_match_the_golden_mirror(case_pair):
    g, l = case_pair
    assert l.num_nodes == g.num_nodes == 64
    assert l.num_switches == g.num_switches == 14
    assert l.num_links == g.num_links == 96
    assert l.num_ports == g.num_ports == 192
    assert l.sw_level == g.sw_level
    assert l.sw_up == g.sw_up
    assert l.sw_down == g.sw_down
    assert l.node_up == g.node_up
    assert l.link_stage == g.link_stage
    assert l.port_link == g.port_link
    assert l.port_index == g.port_index
    # Int-encoded peers carry the same graph as the golden tuples.
    for p in range(l.num_ports):
        kind, idx = g.port_peer[p]
        assert l.port_peer[p] == (idx if kind == "n" else l.num_nodes + idx)


def test_pristine_routes_match_and_are_minimal(case_pair):
    g, l = case_pair
    types = gold.build_types(g)
    gnid = gold.build_gnid(types)
    for key_gnid in (None, gnid):
        rg = gold.XmodkRouter(g, key_gnid)
        rl = lad.XmodkRouter(l, key_gnid)
        for (s, d) in all_pairs(64):
            route = lad.trace_route(l, rl, s, d)
            assert route == gold.trace_route(g, rg, s, d), (s, d)
            # The arena pre-sizing invariant behind FlowSet::trace:
            # pristine Xmodk routes are exactly minimal_hops long.
            assert len(route) == l.spec.minimal_hops(s, d), (s, d)


def test_fault_expansion_matches_links_k(case_pair):
    g, l = case_pair
    for k, seed in [(2, 1), (5, 7), (0, 1)]:
        assert lad.generate_link_faults(l, k, seed) == gold.generate_faults(
            g, f"links:{k}", seed
        )


def test_lazy_degraded_router_matches_the_dense_one(case_pair):
    g, l = case_pair
    types = gold.build_types(g)
    gnid = gold.build_gnid(types)
    survivable = 0
    for seed in range(1, 9):
        dead = set(lad.generate_link_faults(l, 3, seed))
        try:
            dense = gold.DegradedRouter(g, dead, gold.XmodkRouter(g, gnid))
        except RuntimeError:
            continue  # partitioned: nothing to compare
        survivable += 1
        lazy = lad.LazyDegradedRouter(l, dead, lad.XmodkRouter(l, gnid))
        for (s, d) in all_pairs(64):
            assert lad.trace_route(l, lazy, s, d) == gold.trace_route(
                g, dense, s, d
            ), (seed, s, d)
        if survivable >= 2:
            break
    assert survivable >= 2, "the seed range never produced survivable scenarios"


def test_sample_pairs_is_deterministic_and_self_free():
    a = lad.sample_pairs(512, 3, 42)
    assert a == lad.sample_pairs(512, 3, 42)
    assert a != lad.sample_pairs(512, 3, 43)
    assert len(a) == 512 * 3
    for i, (s, d) in enumerate(a):
        assert s == i // 3
        assert s != d
        assert 0 <= d < 512


def test_chunked_repair_splices_byte_identical_to_serial(case_pair):
    # The Python half of the parallel-retrace invariant: partition the
    # dirty flows into chunks, re-trace each independently, splice in
    # flow order — identical to the serial repair for any chunking.
    _, l = case_pair
    base = lad.XmodkRouter(l)
    flows = lad.sample_pairs(64, 4, 1)
    pristine = [lad.trace_route(l, base, s, d) for (s, d) in flows]
    dead = set(lad.generate_link_faults(l, 6, 3))
    dirty = lad.dirty_flows(pristine, l, dead)
    assert dirty, "premise: the scenario must dirty some flows"
    degraded = lad.LazyDegradedRouter(l, dead, base)
    serial = list(pristine)
    for f in dirty:
        serial[f] = lad.trace_route(l, degraded, *flows[f])
    for workers in (1, 2, 4, 8):
        chunk = max((len(dirty) + 4 * workers - 1) // (4 * workers), 1)
        spliced = list(pristine)
        for lo in range(0, len(dirty), chunk):
            worker = lad.LazyDegradedRouter(l, dead, base)  # private memo
            for f in dirty[lo : lo + chunk]:
                spliced[f] = lad.trace_route(l, worker, *flows[f])
        assert spliced == serial, workers


def test_ladder_specs_have_the_advertised_scale():
    # Mirrors families::tests::ladder_specs_have_the_advertised_scale.
    expected = {
        "xl-16k": 16_384,
        "xl-64k": 65_536,
        "xl-256k": 262_144,
        "xl-1m": 1_048_576,
    }
    for name, nodes in expected.items():
        assert lad.named_spec(name).num_nodes == nodes
    for name, topology, dsts, faults in lad.LADDER:
        assert topology in expected
        assert dsts >= 1
        assert faults >= 0
    assert lad.arena_bytes(2, 6) == 8 * 2 + 4 * 2 + 4 * 3 + 4 * 6


def test_implicit_topo_agrees_with_tables_everywhere(case_pair):
    # The Python half of the tentpole's byte-identity pin: the
    # closed-form ImplicitTopo (mirror of topology::view) must agree
    # with the materialized Topo on every observable — ids, port graph,
    # ancestry, down-port arithmetic, and whole routes.
    _, t = case_pair
    i = lad.ImplicitTopo(t.spec)
    assert (i.num_nodes, i.num_switches, i.num_links, i.num_ports) == (
        t.num_nodes, t.num_switches, t.num_links, t.num_ports
    )
    for p in range(t.num_ports):
        assert i.port_peer[p] == t.port_peer[p], p
        assert i.port_link[p] == t.port_link[p], p
        assert i.port_up[p] == t.port_up[p], p
        assert i.port_index[p] == t.port_index[p], p
    assert [i.link_stage[x] for x in range(t.num_links)] == list(t.link_stage)
    for s in range(t.num_switches):
        assert i.sw_level[s] == t.sw_level[s]
        assert i.sw_up[s] == t.sw_up[s], s
    for n in range(t.num_nodes):
        assert i.node_up[n] == t.node_up[n], n
    for sw in range(t.num_switches):
        for dst in range(0, t.num_nodes, 7):
            assert i.is_ancestor(sw, dst) == t.is_ancestor(sw, dst), (sw, dst)
    assert list(i.eligible_links()) == list(t.eligible_links())
    rt, ri = lad.XmodkRouter(t), lad.XmodkRouter(i)
    for (s, d) in all_pairs(t.num_nodes):
        assert lad.trace_route(i, ri, s, d) == lad.trace_route(t, rt, s, d), (s, d)


def test_budgeted_lazy_router_is_route_identical_and_evicts(case_pair):
    # Memory-bounded repair: a tiny reach budget must change *nothing*
    # about the routes — only force arena flushes (evictions > 0) —
    # while the default budget never evicts at this scale.
    _, t = case_pair
    base = lad.XmodkRouter(t)
    dead = set(lad.generate_link_faults(t, 4, 7))
    flows = all_pairs(t.num_nodes)
    roomy = lad.LazyDegradedRouter(t, dead, base, lad.DEFAULT_REACH_BUDGET)
    tight = lad.LazyDegradedRouter(t, dead, base, 2048)
    want = [lad.trace_route(t, roomy, s, d) for (s, d) in flows]
    got = [lad.trace_route(t, tight, s, d) for (s, d) in flows]
    assert got == want
    assert roomy.stats["evictions"] == 0
    assert tight.stats["evictions"] > 0
    for r in (roomy, tight):
        assert r.stats["computed"] > 0
        assert r.stats["hits"] > 0
        assert 0 < r.stats["resident_bytes"] <= r.stats["peak_bytes"]
    # The flush check runs on descend-map builds; the per-switch memo
    # charges between them may overshoot by a few entries, never more.
    assert tight.stats["peak_bytes"] <= 2048 + tight._entry_bytes + 8 * lad.MEMO_ENTRY_BYTES


def test_congestion_kernel_mirrors_agree_with_brute_force(case_pair):
    # Blocked (1 word/port) and striped (4 words/port) kernels must
    # both reproduce the set-based distinct-source/destination counts.
    _, t = case_pair
    base = lad.XmodkRouter(t)
    flows = lad.sample_pairs(t.num_nodes, 5, 9)
    routes = [lad.trace_route(t, base, s, d) for (s, d) in flows]
    src = [set() for _ in range(t.num_ports)]
    dst = [set() for _ in range(t.num_ports)]
    for f, r in enumerate(routes):
        for p in r:
            src[p].add(flows[f][0])
            dst[p].add(flows[f][1])
    brute = ([len(x) for x in src], [len(x) for x in dst])
    assert lad.port_loads_blocked(flows, routes, t.num_ports) == brute
    assert lad.port_loads_striped(flows, routes, t.num_ports) == brute
    want_c = max(min(s, d) for s, d in zip(*brute))
    assert lad.c_topo(*brute) == want_c > 0
