"""L2 correctness: the fixed-iteration JAX waterfilling solver vs the
exact python progressive-filling reference, plus hand-checked cases."""

import numpy as np
import pytest

# compile.model imports jax at module scope; guard it so jax-less
# environments skip these tests instead of failing collection.
pytest.importorskip("jax", reason="fixed-iteration solver needs jax")

from compile.kernels.ref import ref_fairrate_exact
from compile.model import fairrate_solve


def _solve(a, cap, valid=None, iters=None):
    a = np.asarray(a, np.float32)
    cap = np.asarray(cap, np.float32)
    if valid is None:
        valid = (a.sum(axis=1) > 0).astype(np.float32)
    rates, frozen = fairrate_solve(a, cap, np.asarray(valid, np.float32), iters=iters)
    return np.asarray(rates), np.asarray(frozen)


def test_single_bottleneck_shares_equally():
    # 4 flows through one unit port → 0.25 each.
    a = np.ones((4, 1), np.float32)
    rates, frozen = _solve(a, [1.0])
    np.testing.assert_allclose(rates, [0.25] * 4, rtol=1e-6)
    assert np.all(frozen == 1.0)


def test_two_tier_waterfilling():
    # Flow 0 uses ports {0,1}; flow 1 uses {0}; flow 2 uses {1}.
    # cap = [1, 2]. Port 0: share 0.5 → freeze flows 0,1 at 0.5.
    # Port 1 residual 2-0.5 = 1.5 for flow 2 → 1.5.
    a = np.array([[1, 1], [1, 0], [0, 1]], np.float32)
    rates, _ = _solve(a, [1.0, 2.0])
    np.testing.assert_allclose(rates, [0.5, 0.5, 1.5], rtol=1e-5)


def test_invalid_flows_get_zero():
    a = np.array([[1, 0], [1, 0], [0, 1]], np.float32)
    rates, _ = _solve(a, [1.0, 1.0], valid=[1, 0, 1])
    np.testing.assert_allclose(rates, [1.0, 0.0, 1.0], rtol=1e-5)


def test_padding_rows_and_ports_are_inert():
    # Same system embedded in a padded (8, 8) problem.
    a = np.zeros((8, 8), np.float32)
    a[0, 0] = a[0, 1] = 1
    a[1, 0] = 1
    a[2, 1] = 1
    cap = np.ones(8, np.float32)
    cap[1] = 2.0
    valid = np.zeros(8, np.float32)
    valid[:3] = 1
    rates, _ = _solve(a, cap, valid=valid)
    np.testing.assert_allclose(rates[:3], [0.5, 0.5, 1.5], rtol=1e-5)
    np.testing.assert_allclose(rates[3:], 0.0)


@pytest.mark.parametrize("seed", range(10))
def test_matches_exact_reference_random(seed):
    rng = np.random.default_rng(seed)
    f = int(rng.integers(4, 40))
    p = int(rng.integers(2, 24))
    a = (rng.random((f, p)) < 0.35).astype(np.float32)
    a[a.sum(axis=1) == 0, rng.integers(0, p)] = 1  # every flow crosses ≥1 port
    cap = rng.uniform(0.5, 4.0, p).astype(np.float32)
    rates, frozen = _solve(a, cap)
    expect = ref_fairrate_exact(a, cap)
    assert np.all(frozen == 1.0), "all valid flows must freeze"
    np.testing.assert_allclose(rates, expect, rtol=2e-4, atol=2e-4)


def test_max_min_properties_random():
    # No port over capacity; every flow bottlenecked somewhere.
    rng = np.random.default_rng(123)
    a = (rng.random((30, 12)) < 0.3).astype(np.float32)
    a[a.sum(axis=1) == 0, 0] = 1
    cap = rng.uniform(1.0, 3.0, 12).astype(np.float32)
    rates, _ = _solve(a, cap)
    load = a.T @ rates
    assert np.all(load <= cap * (1 + 1e-4)), f"over capacity: {load} vs {cap}"
    # Bottleneck property: each flow crosses a port that is (nearly) full.
    full = load >= cap * (1 - 1e-3)
    for fidx in range(30):
        ports = a[fidx] > 0
        assert full[ports].any(), f"flow {fidx} has slack everywhere"


def test_iters_parameter_suffices():
    # With iters == P the solve always converges (each step freezes ≥1 port).
    a = np.eye(6, dtype=np.float32)
    rates, frozen = _solve(a, np.arange(1, 7, dtype=np.float32), iters=6)
    np.testing.assert_allclose(rates, np.arange(1, 7), rtol=1e-6)
    assert np.all(frozen == 1.0)
