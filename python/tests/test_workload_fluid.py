"""The workload fluid-makespan figures pinned by the Rust suite must
reproduce under the independent Python mirror.

``python/tools/check_workload_fluid.py`` re-implements the fluid phase
simulation of ``rust/src/workload/compile.rs`` over the routing ports in
``gen_faults_golden.py`` and asserts the acceptance figures of
``rust/tests/workload_model.rs``: gdmodk beats dmodk by > 2x on the
built-in ``mix`` (measured ~2.91x), and single-phase checkpoint
makespans are exactly 28672.0 (dmodk) / 7168.0 (gdmodk).
"""

import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.normpath(os.path.join(HERE, "..", "tools")))

import check_workload_fluid as fluid  # noqa: E402


def test_fluid_mirror_reproduces_rust_pins():
    results = fluid.check()  # raises on any divergence
    assert results["mix"]["ratio"] > 2.0
    assert results["mix"]["phases"] == 63
    assert results["single-c2io-sym-1024/dmodk"] == 28672.0
    assert results["single-c2io-sym-1024/gdmodk"] == 7168.0


def test_fluid_mirror_is_deterministic():
    assert fluid.check() == fluid.check()
