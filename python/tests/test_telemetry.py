"""The telemetry cross-checker must accept self-consistent documents
and reject corrupted ones.

``python/tools/check_telemetry.py`` validates ``pgft netsim
--telemetry`` output against the Python pipeline (injection replay,
flit conservation, per-port route bounds).  CI feeds it real Rust
output; this test pins the checker's own behavior with synthetic
documents built from the same replay, so a silent checker regression
cannot slip through either side.
"""

import copy
import json
import os
import sys
import types

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
TOOLS = os.path.normpath(os.path.join(HERE, "..", "tools"))
sys.path.insert(0, TOOLS)

import check_telemetry as ct  # noqa: E402

CFG = types.SimpleNamespace(
    warmup=100, measure=400, drain=100, seed=1, packet_flits=4, vcs=2, vc_capacity=8
)
RATES = [0.1, 0.3]


def synthetic_run(algo):
    """A run dict the checker must accept: injection from the replay,
    every flit delivered, forwarded exactly the delivered lower bound."""
    flows, routes = ct.build_pipeline(algo)
    nf = len(flows)
    pf = CFG.packet_flits
    injected = [ct.replay_injected_packets(f, RATES, CFG) for f in range(nf)]
    delivered = [n * pf for n in injected]
    forwarded = [0] * ct._TOPO.num_ports
    for f, ports in enumerate(routes):
        for p in ports:
            forwarded[p] += delivered[f]
    total = sum(injected)
    horizon = CFG.warmup + CFG.measure + CFG.drain
    return {
        "label": {"algo": algo, "pattern": "c2io-sym", "rates": ",".join(str(r) for r in RATES)},
        "counters": {
            "netsim.cycles": len(RATES) * horizon,
            "netsim.packets.injected": total,
            "netsim.flits.injected": total * pf,
            "netsim.flits.created": total * pf,
            "netsim.flits.delivered": total * pf,
            "netsim.flits.accepted": total * pf,
            "netsim.flits.in_flight_end": 0,
            "netsim.flits.buffered_end": 0,
            "netsim.flits.backlogged_end": 0,
        },
        "maxima": {},
        "vectors": {
            "netsim.flow.injected_packets": {"kind": "sum", "values": injected},
            "netsim.flow.delivered_flits": {"kind": "sum", "values": delivered},
            "netsim.port.forwarded_flits": {"kind": "sum", "values": forwarded},
            "netsim.port.credit_stalls": {"kind": "sum", "values": [0] * ct._TOPO.num_ports},
            "netsim.vc.occupancy_hwm": {
                "kind": "max",
                "values": [1] * (ct._TOPO.num_ports * CFG.vcs),
            },
        },
        "histograms": {"netsim.queue_depth": {"count": 3, "buckets": [[1, 2], [2, 1]]}},
        "spans": {},
    }


@pytest.fixture(scope="module")
def doc():
    return {
        "schema": "pgft-telemetry/1",
        "command": "netsim",
        "host_cpus": 4,
        "runs": [synthetic_run("dmodk"), synthetic_run("gdmodk")],
        "journal": [],
    }


def test_injection_replay_is_deterministic_and_rate_monotone():
    a = [ct.replay_injected_packets(f, RATES, CFG) for f in range(8)]
    assert a == [ct.replay_injected_packets(f, RATES, CFG) for f in range(8)]
    assert sum(a) > 0, "0.1+0.3 over 600 cycles must inject packets"
    lo = sum(ct.replay_injected_packets(f, [0.1], CFG) for f in range(8))
    hi = sum(ct.replay_injected_packets(f, [0.8], CFG) for f in range(8))
    assert lo < hi, "higher offered load must inject more packets"


def test_draw_gap_mirrors_rust_semantics():
    rng = ct.Xoshiro256(7)
    gaps = [ct.draw_gap(rng, 0.125) for _ in range(20000)]
    assert all(g >= 1 for g in gaps)
    mean = sum(gaps) / len(gaps)
    assert abs(mean - 8.0) < 0.4, mean  # geometric mean gap 1/p
    assert ct.draw_gap(ct.Xoshiro256(3), 1.0) == 1


def test_checker_accepts_a_consistent_document(doc):
    checked, skipped = ct.check_document(doc, CFG)
    assert checked == 2 and skipped == 0


def test_checker_skips_unsupported_runs(doc):
    d = copy.deepcopy(doc)
    d["runs"].append(
        {"label": {"algo": "random", "pattern": "shift:1", "rates": "0.1"}}
    )
    checked, skipped = ct.check_document(d, CFG)
    assert checked == 2 and skipped == 1


def test_checker_rejects_corrupted_injection_counter(doc):
    d = copy.deepcopy(doc)
    d["runs"][0]["counters"]["netsim.packets.injected"] += 1
    with pytest.raises(ct.CheckError, match="packets.injected"):
        ct.check_document(d, CFG)


def test_checker_rejects_broken_conservation(doc):
    d = copy.deepcopy(doc)
    d["runs"][1]["counters"]["netsim.flits.delivered"] -= 1
    with pytest.raises(ct.CheckError, match="conservation"):
        ct.check_document(d, CFG)


def test_checker_rejects_out_of_bounds_port_counter(doc):
    d = copy.deepcopy(doc)
    values = d["runs"][0]["vectors"]["netsim.port.forwarded_flits"]["values"]
    hot = max(range(len(values)), key=lambda p: values[p])
    values[hot] -= 1  # below the delivered-flit lower bound
    with pytest.raises(ct.CheckError, match="outside"):
        ct.check_document(d, CFG)


def test_checker_rejects_wrong_schema_and_nulls(doc, tmp_path):
    d = copy.deepcopy(doc)
    d["schema"] = "pgft-telemetry/0"
    with pytest.raises(ct.CheckError, match="schema"):
        ct.check_document(d, CFG)
    # End-to-end via main(): a null anywhere fails the document.
    bad = copy.deepcopy(doc)
    bad["runs"][0]["counters"]["netsim.cycles"] = None
    p = tmp_path / "t.json"
    p.write_text(json.dumps(bad))
    assert ct.main([str(p)]) == 1
    good = tmp_path / "g.json"
    good.write_text(json.dumps(doc))
    assert ct.main([str(good)]) == 0
