"""L1 correctness: the Pallas kernel vs the pure-jnp oracle, swept over
shapes, block sizes, dtypes and value distributions (hypothesis-style
parametrized sweep — the hypothesis package is not available offline, so
the sweep is explicit and seeded)."""

import numpy as np
import pytest

# compile.kernels imports jax at module scope; without it collection
# errors out rather than skipping — guard before the transitive import.
pytest.importorskip("jax", reason="Pallas kernel needs jax")

from compile.kernels.fairrate import port_accumulate
from compile.kernels.ref import ref_port_accumulate


def _case(rng, f, p, density=0.3, binary=True):
    a = (rng.random((f, p)) < density).astype(np.float32)
    if not binary:
        a = a * rng.random((f, p)).astype(np.float32)
    r = rng.random(f).astype(np.float32)
    u = (rng.random(f) < 0.5).astype(np.float32)
    return a, r, u


@pytest.mark.parametrize("f,p", [(8, 8), (16, 64), (64, 16), (256, 256), (512, 128), (1024, 1024)])
def test_kernel_matches_ref_shapes(f, p):
    rng = np.random.default_rng(f * 1000 + p)
    a, r, u = _case(rng, f, p)
    load, cnt = port_accumulate(a, r, u, block_f=min(256, f), block_p=min(256, p))
    rload, rcnt = ref_port_accumulate(a, r, u)
    np.testing.assert_allclose(np.asarray(load), np.asarray(rload), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(rcnt), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bf,bp", [(8, 8), (8, 32), (32, 8), (64, 64)])
def test_kernel_block_shapes(bf, bp):
    rng = np.random.default_rng(bf * 100 + bp)
    a, r, u = _case(rng, 64, 64)
    load, cnt = port_accumulate(a, r, u, block_f=bf, block_p=bp)
    rload, rcnt = ref_port_accumulate(a, r, u)
    np.testing.assert_allclose(np.asarray(load), np.asarray(rload), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(rcnt), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seed", range(8))
def test_kernel_random_sweep(seed):
    rng = np.random.default_rng(seed)
    f = int(rng.choice([8, 16, 32, 64, 128]))
    p = int(rng.choice([8, 16, 32, 64, 128]))
    a, r, u = _case(rng, f, p, density=float(rng.uniform(0.05, 0.9)), binary=bool(seed % 2))
    load, cnt = port_accumulate(a, r, u, block_f=min(32, f), block_p=min(32, p))
    rload, rcnt = ref_port_accumulate(a, r, u)
    np.testing.assert_allclose(np.asarray(load), np.asarray(rload), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(rcnt), rtol=1e-4, atol=1e-4)


def test_kernel_zero_inputs():
    a = np.zeros((16, 16), np.float32)
    r = np.zeros(16, np.float32)
    u = np.zeros(16, np.float32)
    load, cnt = port_accumulate(a, r, u, block_f=16, block_p=16)
    assert np.all(np.asarray(load) == 0)
    assert np.all(np.asarray(cnt) == 0)


def test_kernel_rejects_indivisible_blocks():
    a = np.zeros((10, 16), np.float32)
    with pytest.raises(ValueError):
        port_accumulate(a, np.zeros(10, np.float32), np.zeros(10, np.float32),
                        block_f=4, block_p=16)
