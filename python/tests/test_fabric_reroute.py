"""Pin the fabric coordinator's incremental-reroute mirror.

``tools/check_fabric_reroute.py`` replays the pinned cascade scenario
(``cascade:4`` @ seed 2 on the case-study topology) through the Python
routing mirror and recomputes the per-event forwarding-table diffs,
moved-route counts, and post-cascade C_p. The same constants are pinned
on the Rust side in ``rust/tests/fabric_service.rs`` — if either side
drifts, one of the two implementations changed behaviour.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_fabric_reroute as fab  # noqa: E402


def test_pinned_cascade():
    results = fab.check()  # raises on any internal divergence
    assert results["scenario"] == "cascade:4@seed2"
    assert results["events"] == [85, 64, 88, 90]

    dmodk = results["dmodk"]
    assert dmodk["partitioned_stages"] == []
    assert dmodk["diff_entries"] == [16, 80, 14, 14]
    assert dmodk["routes_changed"] == [256, 448, 192, 192]
    assert dmodk["final_c_topo_c2io"] == 4
    assert dmodk["final_c_topo_all_pairs"] == 16

    gdmodk = results["gdmodk"]
    assert gdmodk["partitioned_stages"] == []
    assert gdmodk["diff_entries"] == [16, 86, 13, 14]
    assert gdmodk["routes_changed"] == [256, 496, 168, 184]
    assert gdmodk["final_c_topo_c2io"] == 2
    assert gdmodk["final_c_topo_all_pairs"] == 16


def test_deterministic():
    assert fab.check() == fab.check()
