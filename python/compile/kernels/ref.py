"""Pure-jnp / pure-python oracles for the Pallas kernel and the fair-rate
solver. These are the correctness ground truth the pytest suite compares
against; nothing here is ever lowered into the shipped artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["ref_port_accumulate", "ref_fairrate_exact"]


def ref_port_accumulate(a, rates, active):
    """Reference for the L1 kernel: the fused dual contraction.

    load[p] = sum_f a[f, p] * rates[f]
    cnt[p]  = sum_f a[f, p] * active[f]
    """
    a = jnp.asarray(a)
    load = jnp.einsum("fp,f->p", a, jnp.asarray(rates))
    cnt = jnp.einsum("fp,f->p", a, jnp.asarray(active))
    return load, cnt


def ref_fairrate_exact(a, cap, valid=None):
    """Exact max-min fair rates by progressive filling (pure numpy).

    a     : (F, P) 0/1 incidence matrix (flow f uses port p).
    cap   : (P,) port capacities.
    valid : (F,) optional 0/1 mask; invalid flows get rate 0.

    Returns (F,) rates. Classic water-filling: repeatedly find the
    bottleneck port (smallest residual fair share), freeze its flows at
    that share, repeat until every flow is frozen.
    """
    a = np.asarray(a, dtype=np.float64)
    cap = np.asarray(cap, dtype=np.float64)
    nflows, nports = a.shape
    rates = np.zeros(nflows)
    if valid is None:
        valid = (a.sum(axis=1) > 0).astype(np.float64)
    else:
        valid = np.asarray(valid, dtype=np.float64)
    frozen = valid < 0.5  # invalid flows are frozen at rate 0

    for _ in range(nports + 1):
        active = ~frozen
        if not active.any():
            break
        cnt = a[active].sum(axis=0)  # active flows per port
        used = (a[frozen] * rates[frozen, None]).sum(axis=0) if frozen.any() else np.zeros(nports)
        with np.errstate(divide="ignore", invalid="ignore"):
            share = np.where(cnt > 0, (cap - used) / np.maximum(cnt, 1e-30), np.inf)
        share = np.maximum(share, 0.0)
        theta = share.min()
        if not np.isfinite(theta):
            # Remaining active flows traverse no port (shouldn't happen for
            # valid flows); they keep rate 0.
            break
        bottleneck = share <= theta * (1 + 1e-12) + 1e-15
        hit = active & (a[:, bottleneck].sum(axis=1) > 0)
        if not hit.any():
            break
        rates[hit] = theta
        frozen |= hit
    return rates
