"""Layer-1 Pallas kernel: the waterfilling step's fused dual contraction.

Given the flow×port incidence matrix ``A`` (F, P), current flow rates
``r`` (F,) and the active-flow mask ``u`` (F,), compute in one pass

    load[p] = Σ_f A[f, p] · r[f]      (capacity already committed at p)
    cnt[p]  = Σ_f A[f, p] · u[f]      (active flows crossing p)

This is the hot inner product of the max-min fair-rate solver (the
simulation study the paper lists as future work): both outputs share one
traversal of ``A``, which is the whole point of fusing them.

TPU mapping (DESIGN.md §Hardware-Adaptation): ``A`` is tiled into
(BLOCK_F × BLOCK_P) VMEM blocks via BlockSpec; the two vectors ride along
as (BLOCK_F,) slices; the (BLOCK_P,) accumulators stay resident in VMEM
across the F-sweep (output index map ignores the F grid axis). The MXU
sees the contraction as a (1×BF)·(BF×BP) matmul pair. ``interpret=True``
everywhere: the CPU PJRT client cannot execute Mosaic custom-calls, and
the artifacts must run inside the rust coordinator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["port_accumulate", "BLOCK_F", "BLOCK_P"]

# Block sizes chosen for TPU VMEM (see DESIGN.md §Perf): a 256×256 f32
# tile is 256 KiB; A-tile + vectors + accumulators fit well under the
# ~16 MiB VMEM budget with room for double buffering.
BLOCK_F = 256
BLOCK_P = 256


def _kernel(a_ref, r_ref, u_ref, load_ref, cnt_ref):
    """One (BLOCK_F, BLOCK_P) tile: accumulate both contractions."""
    f_step = pl.program_id(1)

    @pl.when(f_step == 0)
    def _init():
        load_ref[...] = jnp.zeros_like(load_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    a = a_ref[...]
    # (BF,) · (BF, BP) → (BP,); two vector-matrix products over one A tile.
    load_ref[...] += jnp.dot(r_ref[...], a, preferred_element_type=jnp.float32)
    cnt_ref[...] += jnp.dot(u_ref[...], a, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_f", "block_p"))
def port_accumulate(a, r, u, *, block_f: int = BLOCK_F, block_p: int = BLOCK_P):
    """Fused dual contraction via Pallas. Shapes must tile evenly; the
    AOT wrapper pads to the artifact shape before calling.
    """
    nf, np_ = a.shape
    bf = min(block_f, nf)
    bp = min(block_p, np_)
    if nf % bf or np_ % bp:
        raise ValueError(f"shape ({nf},{np_}) not divisible by blocks ({bf},{bp})")
    grid = (np_ // bp, nf // bf)  # P-major, F innermost → accumulators revolve
    load, cnt = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bf, bp), lambda p, f: (f, p)),
            pl.BlockSpec((bf,), lambda p, f: (f,)),
            pl.BlockSpec((bf,), lambda p, f: (f,)),
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda p, f: (p,)),
            pl.BlockSpec((bp,), lambda p, f: (p,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=True,
    )(a.astype(jnp.float32), r.astype(jnp.float32), u.astype(jnp.float32))
    return load, cnt
