"""Layer-2 JAX model: the flow-level max-min fair-rate solver.

The paper's evaluation is a static congestion metric; its conclusions
call for "a corresponding study of the new algorithms based on
simulation … to provide results in terms of performance". This module is
that study's compute core: given the routed incidence matrix of a
communication pattern, compute per-flow max-min fair rates (progressive
filling / waterfilling), from which the rust coordinator derives
aggregate throughput and completion time per routing algorithm.

The solver is a fixed-trip-count ``fori_loop`` of waterfilling steps so
the whole computation lowers to a single HLO module (one PJRT execute
per solve — python is never on the request path). Each step's dual
contraction is the L1 Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.fairrate import port_accumulate

__all__ = ["fairrate_solve", "port_load"]

_BIG = jnp.float32(3.0e38)


def _step(carry, a, cap):
    """One waterfilling iteration.

    carry = (rates (F,), frozen (F,) 0/1). Finds the bottleneck fair
    share theta over ports with active flows, freezes every active flow
    crossing a bottleneck port at rate theta.
    """
    rates, frozen = carry
    active = 1.0 - frozen
    load, cnt = port_accumulate(a, rates * frozen, active)
    # Residual fair share per port; +inf where no active flow crosses.
    share = jnp.where(cnt > 0.5, jnp.maximum(cap - load, 0.0) / jnp.maximum(cnt, 1.0), _BIG)
    theta = jnp.min(share)
    done = theta >= _BIG  # all ports drained → no-op step
    bottleneck = (share <= theta * 1.0000001 + 1e-12).astype(jnp.float32)
    # Flows crossing any bottleneck port: (F,P)·(P,) > 0.
    hit = (jnp.dot(a, bottleneck) > 0.5).astype(jnp.float32) * active
    hit = jnp.where(done, jnp.zeros_like(hit), hit)
    rates = rates + hit * theta * (1.0 - done)
    frozen = jnp.minimum(frozen + hit, 1.0)
    return rates, frozen


def fairrate_solve(a, cap, valid, iters: int | None = None):
    """Max-min fair rates for every valid flow.

    a     : (F, P) f32 0/1 incidence matrix (padding rows all-zero).
    cap   : (P,) f32 port capacities (padding ports: any positive value).
    valid : (F,) f32 0/1 — which rows are real flows.
    iters : static trip count; default P (each step freezes ≥1 port).

    Returns (rates (F,), iterations-used-equivalent frozen mask (F,)).
    """
    f, p = a.shape
    n_it = iters if iters is not None else p
    rates0 = jnp.zeros((f,), jnp.float32)
    frozen0 = 1.0 - valid.astype(jnp.float32)

    def body(_, carry):
        return _step(carry, a, cap)

    rates, frozen = jax.lax.fori_loop(0, n_it, body, (rates0, frozen0))
    return rates, frozen


def port_load(a, rates, active):
    """Standalone dual contraction (exported as its own artifact): the
    coordinator also uses it to compute port loads / active-flow counts
    for routed patterns without running a full solve."""
    return port_accumulate(a, rates, active)
