"""AOT lowering: JAX/Pallas → HLO text artifacts for the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: the image's
xla_extension 0.5.1 rejects jax≥0.5's 64-bit-instruction-id protos, while
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (shapes are static; the rust side pads):
    fairrate_f{F}_p{P}.hlo.txt   — full max-min solve, one execute/solve
    portload_f{F}_p{P}.hlo.txt   — the fused dual contraction alone
    manifest.txt                 — "name kind F P iters" per line

Usage: ``python -m compile.aot --out ../artifacts`` (from python/).
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import fairrate_solve, port_load

# (F, P, iters) variants to compile. The case study needs (224 flows,
# 192 ports) → 256/256; the medium-512 sweep needs more ports.
SHAPES = [
    (256, 256, 64),
    (1024, 1024, 128),
    (2048, 2048, 256),
]


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fairrate(f: int, p: int, iters: int) -> str:
    spec_a = jax.ShapeDtypeStruct((f, p), jnp.float32)
    spec_cap = jax.ShapeDtypeStruct((p,), jnp.float32)
    spec_valid = jax.ShapeDtypeStruct((f,), jnp.float32)

    def fn(a, cap, valid):
        rates, frozen = fairrate_solve(a, cap, valid, iters=iters)
        return rates, frozen

    return to_hlo_text(jax.jit(fn).lower(spec_a, spec_cap, spec_valid))


def lower_portload(f: int, p: int) -> str:
    spec_a = jax.ShapeDtypeStruct((f, p), jnp.float32)
    spec_v = jax.ShapeDtypeStruct((f,), jnp.float32)

    def fn(a, rates, active):
        return port_load(a, rates, active)

    return to_hlo_text(jax.jit(fn).lower(spec_a, spec_v, spec_v))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact name filter (substring match)"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for f, p, iters in SHAPES:
        jobs = [
            (f"fairrate_f{f}_p{p}", "fairrate", lambda: lower_fairrate(f, p, iters), iters),
            (f"portload_f{f}_p{p}", "portload", lambda: lower_portload(f, p), 0),
        ]
        for name, kind, lower, it in jobs:
            if args.only and not any(s in name for s in args.only.split(",")):
                continue
            text = lower()
            path = os.path.join(args.out, f"{name}.hlo.txt")
            with open(path, "w") as fh:
                fh.write(text)
            manifest.append(f"{name} {kind} {f} {p} {it}")
            print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.out, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
