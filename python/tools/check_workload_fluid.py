"""Independent Python mirror of the workload fluid-makespan evaluator.

Mirrors ``rust/src/workload/compile.rs::evaluate_makespan`` (the fluid
phase simulation: between global phase boundaries every active flow
progresses at its exact max-min fair rate; a phase ends when the
earliest job finishes its segment) on top of the routing/topology ports
in ``gen_faults_golden.py``, and re-derives the figures the Rust test
suite pins:

 * on the built-in ``mix`` workload (GPGPU ring-allreduce train job +
   compute->IO c2io-sym checkpoint job, placement
   ``io:last:1,gpgpu:first:2``) gdmodk's makespan beats dmodk's by
   better than 2x (measured ~2.91x) — the acceptance criterion of
   ``rust/tests/workload_model.rs``;
 * a single-phase workload degenerates to ``bytes / min_rate`` exactly,
   and on the paper placement the dmodk/gdmodk checkpoint makespans are
   exactly 28672.0 / 7168.0 for 1024 bytes (the hard float pins in the
   same test).

Run directly (``python3 python/tools/check_workload_fluid.py``) or via
``python/tests/test_workload_fluid.py``.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import gen_faults_golden as gen  # noqa: E402

RANK_ORDER = ("compute", "io", "service", "gpgpu")  # NodeType::rank order


def build_types_gpgpu(topo):
    """Placement io:last:1,gpgpu:first:2 on the case study."""
    types = ["compute"] * topo.num_nodes
    for leaf in topo.level_switches(1):
        nids = sorted(
            {topo.port_peer[p][1] for p in topo.sw_down[leaf] if topo.port_peer[p][0] == "n"}
        )
        types[nids[-1]] = "io"
        for n in nids[:2]:
            types[n] = "gpgpu"
    return types


def build_gnid(types):
    """TypeReindex::new — canonical rank order, NID order within type."""
    gnid = [0] * len(types)
    nxt = 0
    for ty in RANK_ORDER:
        for nid, t in enumerate(types):
            if t == ty:
                gnid[nid] = nxt
                nxt += 1
    assert nxt == len(types)
    return gnid


def fair_rates(port_lists):
    """Water-filling max-min rates, mirror of sim::fairrate (caps = 1)."""
    nf = len(port_lists)
    ports = sorted({p for pl in port_lists for p in pl})
    col = {p: i for i, p in enumerate(ports)}
    cols = [[col[p] for p in pl] for pl in port_lists]
    np_ = len(ports)
    rates = [0.0] * nf
    frozen = [len(c) == 0 for c in cols]
    for _ in range(np_ + 1):
        load = [0.0] * np_
        cnt = [0] * np_
        for f in range(nf):
            for c in cols[f]:
                if frozen[f]:
                    load[c] += rates[f]
                else:
                    cnt[c] += 1
        theta = float("inf")
        for p in range(np_):
            if cnt[p] > 0:
                share = max(1.0 - load[p], 0.0) / cnt[p]
                theta = min(theta, share)
        if theta == float("inf"):
            break
        progressed = False
        for f in range(nf):
            if frozen[f]:
                continue
            hit = any(
                cnt[c] > 0
                and (max(1.0 - load[c], 0.0) / cnt[c]) <= theta * (1 + 1e-12) + 1e-15
                for c in cols[f]
            )
            if hit:
                rates[f] = theta
                frozen[f] = True
                progressed = True
        if not progressed:
            break
    assert all(frozen), "solver must converge"
    return rates


def c2io_flows(topo, types):
    """c2io-sym restricted to compute sources (mirrors Pattern::C2ioSym)."""
    flows = []
    for leaf in topo.level_switches(1):
        nids = sorted(
            {topo.port_peer[p][1] for p in topo.sw_down[leaf] if topo.port_peer[p][0] == "n"}
        )
        srcs = [n for n in nids if types[n] == "compute"]
        if not srcs:
            continue
        top = list(topo.sw_top[leaf])
        top[-1] = gen.M[gen.H - 1] - 1 - top[-1]
        mirror = topo.switch_at(1, tuple(top), topo.sw_bottom[leaf])
        mnids = sorted(
            {topo.port_peer[p][1] for p in topo.sw_down[mirror] if topo.port_peer[p][0] == "n"}
        )
        dsts = [n for n in mnids if types[n] == "io"]
        if not dsts:
            continue
        for i, s in enumerate(srcs):
            flows.append((s, dsts[i % len(dsts)]))
    return flows


def ring_segments(group, payload):
    """Ring allreduce: 2(n-1) shift-by-one steps of payload/n chunks."""
    n = len(group)
    shift = [(group[i], group[(i + 1) % n]) for i in range(n)]
    return [("flows", shift, payload / n)] * (2 * (n - 1))


def mix_jobs(topo, types):
    """The built-in `mix` (WorkloadSpec::mix volumes: ckpt 4096, ar 2048)."""
    gpgpu = [n for n, t in enumerate(types) if t == "gpgpu"]
    ckpt = [("idle", 32.0), ("flows", c2io_flows(topo, types), 4096.0)]
    train = (
        ring_segments(gpgpu, 2048)
        + [("idle", 64.0)]
        + ring_segments(gpgpu, 2048)
    )
    return [ckpt, train]


def evaluate(topo, router, jobs):
    """The fluid phase loop (mirror of compile.rs::evaluate_makespan)."""
    seg_idx = [0] * len(jobs)

    def enter(j, k):
        if k >= len(jobs[j]):
            return ("done",)
        seg = jobs[j][k]
        if seg[0] == "idle":
            return ("idle", seg[1])
        return ("flows", [seg[2]] * len(seg[1]))

    states = [enter(j, 0) for j in range(len(jobs))]
    t = 0.0
    phases = 0
    job_times = [0.0] * len(jobs)
    total_segments = sum(len(j) for j in jobs)
    for _ in range(total_segments + 1):
        pairs, owners = [], []
        any_active = False
        for j, st in enumerate(states):
            if st[0] == "flows":
                any_active = True
                for i, (s, d) in enumerate(jobs[j][seg_idx[j]][1]):
                    pairs.append((s, d))
                    owners.append((j, i))
            elif st[0] == "idle":
                any_active = True
        if not any_active:
            return t, phases, job_times
        rates = (
            fair_rates([gen.trace_route(topo, router, s, d) for (s, d) in pairs])
            if pairs
            else []
        )
        completions = [None] * len(jobs)
        for g, (j, i) in enumerate(owners):
            assert rates[g] > 1e-15
            need = states[j][1][i] / rates[g]
            if completions[j] is None or need > completions[j]:
                completions[j] = need
        for j, st in enumerate(states):
            if st[0] == "idle":
                completions[j] = st[1]
        dt = min(c for c in completions if c is not None)
        for g, (j, i) in enumerate(owners):
            states[j][1][i] = max(states[j][1][i] - rates[g] * dt, 0.0)
        for j in range(len(jobs)):
            if states[j][0] == "idle":
                states[j] = ("idle", states[j][1] - dt)
            if completions[j] is not None and completions[j] <= dt:
                seg_idx[j] += 1
                states[j] = enter(j, seg_idx[j])
                if states[j][0] == "done":
                    job_times[j] = t + dt
        phases += 1
        t += dt
    raise AssertionError("fluid loop failed to retire a segment per phase")


def check():
    """Re-derive and assert every figure the Rust suite pins."""
    topo = gen.Topo()
    results = {}

    # --- the acceptance mix (io:last:1,gpgpu:first:2) ---
    types = build_types_gpgpu(topo)
    gnid = build_gnid(types)
    assert sum(1 for t in types if t == "gpgpu") == 16
    jobs = mix_jobs(topo, types)
    dmodk = gen.XmodkRouter(topo, None)
    gdmodk = gen.XmodkRouter(topo, gnid)
    md, pd, _ = evaluate(topo, dmodk, jobs)
    mg, pg, _ = evaluate(topo, gdmodk, jobs)
    assert pd == pg == 63, (pd, pg)
    assert mg * 2.0 < md, f"gdmodk {mg} must beat dmodk {md} by > 2x"
    results["mix"] = {"dmodk": md, "gdmodk": mg, "ratio": md / mg, "phases": pd}

    # --- single-phase identity on the paper placement (io:last:1) ---
    ptypes = gen.build_types(topo)
    pgnid = gen.build_gnid(ptypes)
    flows = gen.c2io_sym_flows(topo, ptypes)
    single = [[("flows", flows, 1024.0)]]
    for name, router, want in (
        ("dmodk", gen.XmodkRouter(topo, None), 28672.0),
        ("gdmodk", gen.XmodkRouter(topo, pgnid), 7168.0),
    ):
        rates = fair_rates([gen.trace_route(topo, router, s, d) for (s, d) in flows])
        ms, ph, _ = evaluate(topo, router, single)
        assert ph == 1
        assert ms == 1024.0 / min(rates), (name, ms)
        assert ms == want, f"{name}: makespan {ms} != pinned {want}"
        results[f"single-c2io-sym-1024/{name}"] = ms
    return results


def main():
    results = check()
    for key, val in results.items():
        print(f"{key}: {val}")
    print("OK — all workload fluid figures reproduce the Rust pins")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
