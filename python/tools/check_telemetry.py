#!/usr/bin/env python3
"""Cross-check a ``pgft netsim --telemetry`` document against the
golden-pinned Python pipeline.

The Rust engine exports per-flow injection counters, per-flow delivered
flits and per-port forwarded-flit counters in its ``pgft-telemetry/1``
document.  This script rebuilds the same case-study fabric, routes and
seeded injection streams from the independent Python port behind
``rust/tests/golden/faults_case_study.csv`` (``gen_faults_golden.py``)
and verifies, per run:

* ``netsim.flow.injected_packets`` matches an exact replay of the
  closed-form geometric-gap Bernoulli injection (same xoshiro256**
  per-flow streams, same ``1 + floor(ln(1-u)/ln1p(-p))`` draw);
* the flit-conservation identity holds in the exported counters:
  injected == delivered + in-flight + buffered + backlogged;
* every per-port forwarded-flit counter is bracketed by the routes:
  the flits of flows crossing a port that were *delivered* must all
  have been forwarded there, and a port can never forward more than
  the flits those flows *injected*;
* shapes and caps: one slot per port, ``ports x vcs`` occupancy marks
  never above the VC capacity, and the document carries no ``null``.

Only the case-study ``c2io-sym`` runs of the deterministic ``dmodk`` /
``gdmodk`` algorithms are checkable (the Python port mirrors exactly
those); other runs are reported as skipped, not failed.  The engine
parameters must match the ``pgft netsim`` invocation — pass the same
``--warmup/--measure/--drain/--seed/--packet-flits`` values.

Usage::

    pgft netsim --topo case-study --algo dmodk,gdmodk --pattern c2io-sym \
        --rates 0.1,0.3 --warmup 100 --measure 400 --drain 100 \
        --telemetry netsim-telemetry.json --format csv --out /dev/null
    python3 python/tools/check_telemetry.py netsim-telemetry.json \
        --warmup 100 --measure 400 --drain 100

The behavioral contract is pinned by ``python/tests/test_telemetry.py``.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, HERE)

from gen_faults_golden import (  # noqa: E402
    MASK,
    Topo,
    XmodkRouter,
    Xoshiro256,
    build_gnid,
    build_types,
    c2io_sym_flows,
    trace_route,
)

# util::rng seeds one xoshiro stream per flow at seed + (f+1) * golden gamma.
GOLDEN_GAMMA = 0x9E3779B97F4A7C15


class CheckError(AssertionError):
    """A telemetry cross-check failure (message carries the detail)."""


def ensure(cond: bool, msg: str) -> None:
    if not cond:
        raise CheckError(msg)


def next_f64(rng: Xoshiro256) -> float:
    """Mirror of ``util::rng::Xoshiro256::next_f64`` — exact: the
    53-bit mantissa scale is a power of two, so no rounding happens."""
    return (rng.next_u64() >> 11) * (1.0 / (1 << 53))


def draw_gap(rng: Xoshiro256, p: float) -> int:
    """Mirror of ``netsim::inject::draw_gap`` (closed-form geometric)."""
    if p >= 1.0:
        return 1
    u = next_f64(rng)
    g = math.floor(math.log(1.0 - u) / math.log1p(-p))
    if not math.isfinite(g) or g >= 2**64:
        return MASK
    return 1 + int(g)


def replay_injected_packets(flow_index: int, rates: list, cfg) -> int:
    """Packets the engine injects for one flow across a rate grid.

    Mirrors ``Engine::run_detailed``: the first arrival is seeded at
    ``gap`` after the window start (0), every firing inside the horizon
    injects one packet (Bernoulli burst = 1) and redraws the gap; an
    arrival past ``warmup + measure + drain`` never fires.
    """
    end = cfg.warmup + cfg.measure + cfg.drain
    total = 0
    for rate in rates:
        p = rate / float(cfg.packet_flits)
        rng = Xoshiro256((cfg.seed + (flow_index + 1) * GOLDEN_GAMMA) & MASK)
        t = 0
        while True:
            t = min(t + draw_gap(rng, p), MASK)
            if t > end:
                break
            total += 1
    return total


def build_pipeline(algo: str):
    """Case-study topo + c2io-sym routes for one algorithm (cached)."""
    if algo not in _PIPELINES:
        topo = _TOPO
        types = build_types(topo)
        gnid = build_gnid(types)
        router = XmodkRouter(topo, gnid if algo == "gdmodk" else None)
        flows = c2io_sym_flows(topo, types)
        routes = [trace_route(topo, router, s, d) for (s, d) in flows]
        _PIPELINES[algo] = (flows, routes)
    return _PIPELINES[algo]


_TOPO = Topo()
_PIPELINES: dict = {}


def check_run(run: dict, cfg) -> None:
    """Cross-check one labelled telemetry run. Raises CheckError."""
    label = run.get("label", {})
    algo = label.get("algo", "?")
    rates = [float(x) for x in label.get("rates", "").split(",") if x]
    ensure(rates, f"run {label}: no rates in the label")
    flows, routes = build_pipeline(algo)
    nf = len(flows)
    counters = run["counters"]
    vectors = run["vectors"]
    pf = cfg.packet_flits

    # 1. The injection replay: exact, per flow, summed over the grid.
    expected = [replay_injected_packets(f, rates, cfg) for f in range(nf)]
    got = vectors["netsim.flow.injected_packets"]["values"]
    ensure(len(got) == nf, f"{algo}: {len(got)} flow slots, expected {nf}")
    for f in range(nf):
        ensure(
            got[f] == expected[f],
            f"{algo} flow {f} {flows[f]}: injected {got[f]} != replay {expected[f]}",
        )
    ensure(
        counters["netsim.packets.injected"] == sum(expected),
        f"{algo}: packets.injected {counters['netsim.packets.injected']} "
        f"!= replay total {sum(expected)}",
    )
    ensure(
        counters["netsim.flits.injected"] == sum(expected) * pf,
        f"{algo}: flits.injected must be packets x {pf}",
    )
    horizon = cfg.warmup + cfg.measure + cfg.drain
    ensure(
        counters["netsim.cycles"] == len(rates) * horizon,
        f"{algo}: cycles {counters['netsim.cycles']} != "
        f"{len(rates)} runs x {horizon}",
    )

    # 2. Flit conservation, from the exported counters alone.
    injected = counters["netsim.flits.injected"]
    accounted = (
        counters["netsim.flits.delivered"]
        + counters["netsim.flits.in_flight_end"]
        + counters["netsim.flits.buffered_end"]
        + counters["netsim.flits.backlogged_end"]
    )
    ensure(
        injected == accounted,
        f"{algo}: conservation broken: injected {injected} != accounted {accounted}",
    )
    ensure(
        counters["netsim.flits.created"]
        == injected - counters["netsim.flits.backlogged_end"],
        f"{algo}: created flits must be injected minus end-of-run backlog",
    )
    ensure(
        counters["netsim.flits.accepted"] <= counters["netsim.flits.delivered"],
        f"{algo}: accepted (measured-window) flits exceed delivered",
    )

    # 3. Per-port forwarded-flit counters, bracketed by the routes.
    forwarded = vectors["netsim.port.forwarded_flits"]["values"]
    delivered = vectors["netsim.flow.delivered_flits"]["values"]
    ensure(
        len(forwarded) == _TOPO.num_ports,
        f"{algo}: {len(forwarded)} port slots, expected {_TOPO.num_ports}",
    )
    ensure(len(delivered) == nf, f"{algo}: {len(delivered)} delivered-flit slots")
    lower = [0] * _TOPO.num_ports
    upper = [0] * _TOPO.num_ports
    for f, ports in enumerate(routes):
        for p in ports:
            lower[p] += delivered[f]
            upper[p] += expected[f] * pf
    for p in range(_TOPO.num_ports):
        ensure(
            lower[p] <= forwarded[p] <= upper[p],
            f"{algo} port {p}: forwarded {forwarded[p]} outside "
            f"[{lower[p]}, {upper[p]}] from the route membership",
        )

    # 4. Shapes and caps of the remaining per-entity families.
    hwm = vectors["netsim.vc.occupancy_hwm"]["values"]
    ensure(
        len(hwm) == _TOPO.num_ports * cfg.vcs,
        f"{algo}: {len(hwm)} VC slots, expected ports x vcs",
    )
    ensure(
        all(v <= cfg.vc_capacity for v in hwm),
        f"{algo}: a VC occupancy mark exceeds the capacity {cfg.vc_capacity}",
    )
    ensure(
        vectors["netsim.vc.occupancy_hwm"]["kind"] == "max",
        f"{algo}: occupancy high-water marks must merge as max",
    )
    stalls = vectors["netsim.port.credit_stalls"]["values"]
    ensure(len(stalls) == _TOPO.num_ports, f"{algo}: credit-stall slots")
    qd = run["histograms"]["netsim.queue_depth"]
    ensure(
        qd["count"] == sum(c for _, c in qd["buckets"]),
        f"{algo}: queue-depth histogram count != bucket sum",
    )


def check_document(doc: dict, cfg) -> tuple:
    """Check a whole telemetry document; returns (checked, skipped)."""
    ensure(doc.get("schema") == "pgft-telemetry/1", "wrong or missing schema tag")
    ensure(doc.get("command") == "netsim", "document is not a netsim emission")
    ensure(doc.get("host_cpus", 0) >= 1, "host_cpus provenance missing")
    checked, skipped = 0, 0
    for run in doc.get("runs", []):
        label = run.get("label", {})
        if label.get("pattern") != "c2io-sym" or label.get("algo") not in (
            "dmodk",
            "gdmodk",
        ):
            skipped += 1
            continue
        check_run(run, cfg)
        checked += 1
    ensure(checked > 0, "no checkable (case-study c2io-sym dmodk/gdmodk) runs")
    return checked, skipped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("telemetry", help="pgft-telemetry/1 JSON from pgft netsim")
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--measure", type=int, default=400)
    ap.add_argument("--drain", type=int, default=100)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--packet-flits", dest="packet_flits", type=int, default=4)
    ap.add_argument("--vcs", type=int, default=2)
    ap.add_argument("--vc-capacity", dest="vc_capacity", type=int, default=8)
    cfg = ap.parse_args(argv)
    with open(cfg.telemetry, encoding="utf-8") as f:
        text = f.read()
    try:
        ensure("null" not in text, "telemetry documents must not carry null")
        checked, skipped = check_document(json.loads(text), cfg)
    except CheckError as e:
        sys.stderr.write(f"FAIL {cfg.telemetry}: {e}\n")
        return 1
    sys.stderr.write(
        f"OK {cfg.telemetry}: {checked} run(s) cross-checked against the "
        f"Python pipeline ({skipped} skipped)\n"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
