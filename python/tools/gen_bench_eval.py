#!/usr/bin/env python3
"""Seed `rust/BENCH_eval.json` from the Python port of the pipeline.

The eval-layer perf record (`BENCH_eval.json`) is normally written by
`cargo bench --bench bench_eval`, which overwrites the committed file
with rust numbers and is what CI uploads as the perf-trajectory
artifact. The container that authored the eval layer has no rust
toolchain, so this tool produces the *initial* committed record by
measuring the same three figures on the exact Python port of the
tracing pipeline (`gen_faults_golden.py`, pinned byte-identical to the
rust implementation by the faults golden):

 * traces/s — all-pairs route tracing on the case study;
 * incremental-vs-full re-trace on a single-link fault cell (the
   structural claim the record must witness: re-tracing only the flows
   that cross the dead link beats re-tracing everything, and produces
   identical routes);
 * netsim events/s — requires the rust engine; ``null`` in this record.

Usage: python3 python/tools/gen_bench_eval.py [out.json]
"""

from __future__ import annotations

import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import gen_faults_golden as g  # noqa: E402


def best_of(reps: int, fn):
    """Smallest wall-clock of `reps` runs (and the last result)."""
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def main() -> int:
    topo = g.Topo()
    n = topo.num_nodes
    flows = [(s, d) for s in range(n) for d in range(n)]
    base = g.XmodkRouter(topo)

    pristine, trace_s = best_of(
        3, lambda: [g.trace_route(topo, base, s, d) for (s, d) in flows]
    )
    traces_per_sec = len(flows) / trace_s

    # One dead eligible (stage >= 2) link, expanded like the rust model.
    dead = set(g.generate_faults(topo, "links:1", 1))
    assert len(dead) == 1
    degraded = g.DegradedRouter(topo, dead, g.XmodkRouter(topo))

    full, full_s = best_of(
        3, lambda: [g.trace_route(topo, degraded, s, d) for (s, d) in flows]
    )

    def incremental():
        out = []
        moved = 0
        for route, (s, d) in zip(pristine, flows):
            if any(topo.port_link[p] in dead for p in route):
                out.append(g.trace_route(topo, degraded, s, d))
                moved += 1
            else:
                out.append(route)
        return out, moved

    (incr, dirty), incr_s = best_of(3, incremental)
    assert incr == full, "incremental re-trace must be byte-identical to full"
    assert dirty > 0, "the dead link must touch at least one all-pairs flow"
    speedup = full_s / incr_s

    out_path = sys.argv[1] if len(sys.argv) > 1 else str(
        pathlib.Path(__file__).resolve().parents[2] / "rust" / "BENCH_eval.json"
    )
    body = (
        "{\n"
        '  "schema": "pgft-bench-eval/1",\n'
        '  "source": "python-port",\n'
        '  "note": "seeded by python/tools/gen_bench_eval.py; '
        "cargo bench --bench bench_eval overwrites this with rust numbers "
        '(netsim events/s needs the rust engine)",\n'
        '  "traces_per_sec": {"case-study": %.1f, "medium-512": null},\n'
        '  "retrace": {"topology": "case-study", "dead_links": 1, "flows": %d, '
        '"dirty_flows": %d, "full_ms": %.4f, "incremental_ms": %.4f, '
        '"speedup": %.4f},\n'
        '  "netsim_events_per_sec": null\n'
        "}\n"
    ) % (traces_per_sec, len(flows), dirty, full_s * 1e3, incr_s * 1e3, speedup)
    pathlib.Path(out_path).write_text(body)
    print(body)
    print(f"wrote {out_path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
