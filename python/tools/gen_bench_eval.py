#!/usr/bin/env python3
"""Seed `rust/BENCH_eval.json` (schema pgft-bench-eval/2) from the
Python port of the pipeline.

The eval-layer perf record is normally written by `cargo bench --bench
bench_eval`, which walks the full size ladder in rust and overwrites
the committed file. The container that authored the eval layer has no
rust toolchain, so this tool produces the committed record by walking
the *same* ladder on the parameterized Python mirror
(`pgft_ladder.py`, cross-checked against the golden-pinned
`gen_faults_golden.py` by `python/tests/test_ladder_mirror.py`):

 * per rung — trace throughput (flows/s, trace_ms) and arena bytes per
   flow on the rung's flow set (all-pairs for the paper fabrics,
   sampled pairs for 16k/64k/256k);
 * per faulted rung — full re-trace vs serial incremental (dirty flows
   only) vs chunk-and-splice parallel repair at 2/4/8 workers, with
   the byte-identity invariant asserted at every width;
 * `host_cpus` — the parallelism actually available while measuring.
   On a single-CPU host the parallel entries honestly hover around
   1.0x (they measure fork overhead, not the splice design); the
   speedup>1.5x acceptance in `tests/eval_agreement.rs` applies to
   records produced with >= 4 CPUs, which a `cargo bench` run on any
   normal machine regenerates;
 * `netsim` — the flit-level engine is rust-only, so a python-port
   record says `skipped` instead of carrying null.

The emitted JSON is byte-compatible with the rust emitter in
`benches/bench_eval.rs` (same keys, same ordering, same float widths)
so the pin test parses both identically.

Usage: python3 python/tools/gen_bench_eval.py [out.json]
"""

from __future__ import annotations

import multiprocessing as mp
import os
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import pgft_ladder as lad  # noqa: E402

PARALLEL_WORKERS = [2, 4, 8]


def best_of(reps: int, fn):
    """Smallest wall-clock of `reps` runs (and the last result)."""
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def all_pairs(n: int) -> list:
    return [(s, d) for s in range(n) for d in range(n) if s != d]


# Worker state is inherited through fork (COW) — only the slice bounds
# cross the pipe. Each worker builds its own LazyDegradedRouter so the
# memo tables are private, exactly like the per-worker sub-arenas in
# FlowSet::retrace_incremental_par.
_G: dict = {}


def _repair_slice(bounds):
    lo, hi = bounds
    topo, dead, base, flows, dirty = (
        _G[k] for k in ("topo", "dead", "base", "flows", "dirty")
    )
    worker = lad.LazyDegradedRouter(topo, dead, base)
    return [lad.trace_route(topo, worker, *flows[dirty[i]]) for i in range(lo, hi)]


def parallel_repair(workers: int):
    """Chunk the dirty flows, repair each chunk in its own process,
    splice in flow order. The timed region includes pool creation, the
    same way the rust bench pays its thread spawns."""
    dirty = _G["dirty"]
    chunk = max((len(dirty) + 4 * workers - 1) // (4 * workers), 1)
    bounds = [(lo, min(lo + chunk, len(dirty))) for lo in range(0, len(dirty), chunk)]
    with mp.get_context("fork").Pool(workers) as pool:
        parts = pool.map(_repair_slice, bounds)
    out = list(_G["pristine"])
    it = iter([r for part in parts for r in part])
    for f in dirty:
        out[f] = next(it)
    return out


def measure_rung(rung, topo, flows, dead, skip_reason, reps):
    base = lad.XmodkRouter(topo)

    pristine, trace_s = best_of(
        reps, lambda: [lad.trace_route(topo, base, s, d) for (s, d) in flows]
    )
    hops = sum(len(r) for r in pristine)
    bytes_per_flow = lad.arena_bytes(len(flows), hops) / max(len(flows), 1)
    rec = {
        "rung": rung,
        "endpoints": topo.num_nodes,
        "flows": len(flows),
        "trace_ms": trace_s * 1e3,
        "flows_per_sec": len(flows) / trace_s,
        "bytes_per_flow": bytes_per_flow,
    }

    if dead is None:
        rec["retrace"] = skip_reason
        return rec

    dirty = lad.dirty_flows(pristine, topo, dead)
    print(f"  {rung}: {len(dirty)} of {len(flows)} flows cross a dead link")
    full, full_s = best_of(
        reps,
        lambda: [
            lad.trace_route(topo, lad.LazyDegradedRouter(topo, dead, base), s, d)
            for (s, d) in flows
        ],
    )
    # ^ one shared lazy router per pass would be fair too; a fresh one
    # per flow would not. Rebuild per *pass* so reps stay cold.

    def serial():
        worker = lad.LazyDegradedRouter(topo, dead, base)
        out = list(pristine)
        for f in dirty:
            out[f] = lad.trace_route(topo, worker, *flows[f])
        return out

    serial_routes, serial_s = best_of(reps, serial)
    assert serial_routes == full, f"{rung}: incremental must equal a full re-trace"

    _G.update(topo=topo, dead=dead, base=base, flows=flows, dirty=dirty,
              pristine=pristine)
    parallel = []
    for workers in PARALLEL_WORKERS:
        par, par_s = best_of(reps, lambda: parallel_repair(workers))
        assert par == serial_routes, f"{rung}: {workers}-way repair must equal serial"
        parallel.append((workers, par_s * 1e3))
    _G.clear()

    rec["retrace"] = {
        "dead_links": len(dead),
        "dirty_flows": len(dirty),
        "full_ms": full_s * 1e3,
        "serial_ms": serial_s * 1e3,
        "parallel": parallel,
    }
    return rec


def emit(records, host_cpus: int) -> str:
    out = ["{"]
    out.append('  "schema": "pgft-bench-eval/2",')
    out.append('  "source": "python-port",')
    out.append(f'  "host_cpus": {host_cpus},')
    out.append(
        '  "netsim": {"skipped": "flit-level engine is rust-only; '
        'cargo bench --bench bench_eval measures events/s"},'
    )
    out.append('  "ladder": [')
    for i, r in enumerate(records):
        out.append("    {")
        out.append(f'      "rung": "{r["rung"]}",')
        out.append(f'      "endpoints": {r["endpoints"]},')
        out.append(f'      "flows": {r["flows"]},')
        out.append(f'      "trace_ms": {r["trace_ms"]:.4f},')
        out.append(f'      "flows_per_sec": {r["flows_per_sec"]:.1f},')
        out.append(f'      "bytes_per_flow": {r["bytes_per_flow"]:.2f},')
        rt = r["retrace"]
        if isinstance(rt, str):
            out.append(f'      "retrace": {{"skipped": "{rt}"}}')
        else:
            out.append('      "retrace": {')
            out.append(f'        "dead_links": {rt["dead_links"]},')
            out.append(f'        "dirty_flows": {rt["dirty_flows"]},')
            out.append(f'        "full_ms": {rt["full_ms"]:.4f},')
            out.append(f'        "serial_ms": {rt["serial_ms"]:.4f},')
            speedup = rt["full_ms"] / max(rt["serial_ms"], 1e-9)
            out.append(f'        "speedup_incremental": {speedup:.4f},')
            out.append('        "parallel": [')
            for j, (workers, ms) in enumerate(rt["parallel"]):
                comma = "," if j + 1 < len(rt["parallel"]) else ""
                sp = rt["serial_ms"] / max(ms, 1e-9)
                out.append(
                    f'          {{"threads": {workers}, "ms": {ms:.4f}, '
                    f'"speedup": {sp:.4f}}}{comma}'
                )
            out.append("        ]")
            out.append("      }")
        out.append("    }" + ("," if i + 1 < len(records) else ""))
    out.append("  ]")
    out.append("}")
    return "\n".join(out) + "\n"


def main() -> int:
    records = []

    # Paper fabrics: all-pairs flows, first stage-2 link dead (the same
    # scenario benches/bench_eval.rs uses).
    for name in ("case-study", "medium-512"):
        topo = lad.Topo(lad.named_spec(name))
        flows = all_pairs(topo.num_nodes)
        dead = {next(l for l in range(topo.num_links) if topo.link_stage[l] == 2)}
        print(f"== {name}: {topo.num_nodes} endpoints, {len(flows)} flows ==")
        records.append(measure_rung(name, topo, flows, dead, "", reps=3))

    # Ladder rungs: sampled pairs, links:K preset scenarios, seed 1.
    for name, topology, dsts, fault_links in lad.LADDER:
        topo = lad.Topo(lad.named_spec(topology))
        flows = lad.sample_pairs(topo.num_nodes, dsts, 1)
        dead = (
            set(lad.generate_link_faults(topo, fault_links, 1))
            if fault_links > 0
            else None
        )
        print(f"== {name}: {topo.num_nodes} endpoints, {len(flows)} flows ==")
        records.append(
            measure_rung(
                name,
                topo,
                flows,
                dead,
                "fault-aware router reachability tables exceed the memory "
                "budget at 256k endpoints (DESIGN.md §10)",
                reps=2,
            )
        )

    try:
        host_cpus = len(os.sched_getaffinity(0))
    except AttributeError:
        host_cpus = os.cpu_count() or 1
    body = emit(records, host_cpus)
    out_path = sys.argv[1] if len(sys.argv) > 1 else str(
        pathlib.Path(__file__).resolve().parents[2] / "rust" / "BENCH_eval.json"
    )
    pathlib.Path(out_path).write_text(body)
    print(body)
    print(f"wrote {out_path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
