#!/usr/bin/env python3
"""Seed `rust/BENCH_eval.json` (schema pgft-bench-eval/3) from the
Python port of the pipeline.

The eval-layer perf record is normally written by `cargo bench --bench
bench_eval`, which walks the full size ladder in rust and overwrites
the committed file. The container that authored the eval layer has no
rust toolchain, so this tool produces the committed record by walking
the *same* ladder on the parameterized Python mirror
(`pgft_ladder.py`, cross-checked against the golden-pinned
`gen_faults_golden.py` by `python/tests/test_ladder_mirror.py`):

 * per rung — trace throughput (flows/s, trace_ms), arena bytes per
   flow, and the process peak RSS after the rung (`ru_maxrss`, the
   Python stand-in for the rust emitter's `VmHWM`; both are monotone
   high-water marks, so each rung's figure bounds everything measured
   up to it);
 * per faulted rung — full re-trace vs serial incremental (dirty flows
   only) vs chunk-and-splice parallel repair at 2/4/8 workers, with
   the byte-identity invariant asserted at every width. Rungs at and
   above 16k endpoints repair through the *budgeted* lazy reachability
   (`DEFAULT_REACH_BUDGET`, the accounting mirror of
   `faults::router::LazyReach`) and record the reach-arena peak they
   paid (`reach_peak_mb`) — which is what closed the 256k retrace skip
   of schema v2 and lets the 1m rung run `links:K` at all;
 * the `1m` rung traces through `ImplicitTopo` (the mirror of
   `topology::view::ImplicitTopology` — no port tables), `mode:
   "implicit"`; the 16k rung traces through *both* and asserts the
   routes are identical, mirroring the rust bench's identity pin;
 * `kernel` — the striped congestion kernel against the single-word
   blocked baseline on the 16k store, structurally mirrored from
   `metrics::BitmapAccum` (same blocking, stamps and popcount merges;
   the ratio reflects Python dispatch, not SIMD — `source` records the
   provenance, and a `cargo bench` run regenerates rust numbers);
 * `host_cpus` — the parallelism actually available while measuring.
   On a single-CPU host the parallel entries honestly hover around
   1.0x (they measure fork overhead, not the splice design); the
   speedup>1.5x acceptance in `tests/eval_agreement.rs` applies to
   records produced with >= 4 CPUs;
 * `netsim` — the flit-level engine is rust-only, so a python-port
   record says `skipped` instead of carrying null.

The emitted JSON is byte-compatible with the rust emitter in
`benches/bench_eval.rs` (same keys, same ordering, same float widths)
so the pin test parses both identically.

Usage: python3 python/tools/gen_bench_eval.py [out.json]
"""

from __future__ import annotations

import multiprocessing as mp
import os
import pathlib
import resource
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import pgft_ladder as lad  # noqa: E402

PARALLEL_WORKERS = [2, 4, 8]

# Mirror of the sweep runner's (and rust bench's) lazy-reach policy.
LAZY_REACH_MIN_NODES = 16_384


def best_of(reps: int, fn):
    """Smallest wall-clock of `reps` runs (and the last result)."""
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def all_pairs(n: int) -> list:
    return [(s, d) for s in range(n) for d in range(n) if s != d]


def peak_rss_mb() -> float:
    """`ru_maxrss` is KiB on Linux — the same monotone high-water story
    as the rust emitter's `VmHWM`."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


# Worker state is inherited through fork (COW) — only the slice bounds
# cross the pipe. Each worker builds its own LazyDegradedRouter so the
# memo tables are private, exactly like the per-worker sub-arenas in
# FlowSet::retrace_incremental_par.
_G: dict = {}


def _repair_slice(bounds):
    lo, hi = bounds
    topo, dead, base, flows, dirty, budget = (
        _G[k] for k in ("topo", "dead", "base", "flows", "dirty", "budget")
    )
    worker = lad.LazyDegradedRouter(topo, dead, base, budget)
    return [lad.trace_route(topo, worker, *flows[dirty[i]]) for i in range(lo, hi)]


def parallel_repair(workers: int):
    """Chunk the dirty flows, repair each chunk in its own process,
    splice in flow order. The timed region includes pool creation, the
    same way the rust bench pays its thread spawns."""
    dirty = _G["dirty"]
    chunk = max((len(dirty) + 4 * workers - 1) // (4 * workers), 1)
    bounds = [(lo, min(lo + chunk, len(dirty))) for lo in range(0, len(dirty), chunk)]
    with mp.get_context("fork").Pool(workers) as pool:
        parts = pool.map(_repair_slice, bounds)
    out = list(_G["pristine"])
    it = iter([r for part in parts for r in part])
    for f in dirty:
        out[f] = next(it)
    return out


def measure_rung(rung, mode, topo, flows, dead, reps):
    base = lad.XmodkRouter(topo)
    budget = (
        lad.DEFAULT_REACH_BUDGET if topo.num_nodes >= LAZY_REACH_MIN_NODES else 0
    )

    pristine, trace_s = best_of(
        reps, lambda: [lad.trace_route(topo, base, s, d) for (s, d) in flows]
    )
    hops = sum(len(r) for r in pristine)
    bytes_per_flow = lad.arena_bytes(len(flows), hops) / max(len(flows), 1)
    rec = {
        "rung": rung,
        "mode": mode,
        "endpoints": topo.num_nodes,
        "flows": len(flows),
        "trace_ms": trace_s * 1e3,
        "flows_per_sec": len(flows) / trace_s,
        "bytes_per_flow": bytes_per_flow,
    }

    if dead is None:
        rec["retrace"] = "no fault scenario configured for this rung"
        rec["peak_rss_mb"] = peak_rss_mb()
        return rec

    dirty = lad.dirty_flows(pristine, topo, dead)
    print(f"  {rung}: {len(dirty)} of {len(flows)} flows cross a dead link")
    full, full_s = best_of(
        reps,
        lambda: [
            lad.trace_route(topo, r, s, d)
            for r in (lad.LazyDegradedRouter(topo, dead, base, budget),)
            for (s, d) in flows
        ],
    )
    # ^ one shared lazy router per pass (a fresh one per flow would not
    # be fair). Rebuild per *pass* so reps stay cold.

    serial_router_cell = []

    def serial():
        worker = lad.LazyDegradedRouter(topo, dead, base, budget)
        serial_router_cell.append(worker)
        out = list(pristine)
        for f in dirty:
            out[f] = lad.trace_route(topo, worker, *flows[f])
        return out

    serial_routes, serial_s = best_of(reps, serial)
    assert serial_routes == full, f"{rung}: incremental must equal a full re-trace"
    reach_peak_mb = serial_router_cell[-1].stats["peak_bytes"] / (1 << 20)

    _G.update(topo=topo, dead=dead, base=base, flows=flows, dirty=dirty,
              pristine=pristine, budget=budget)
    parallel = []
    for workers in PARALLEL_WORKERS:
        par, par_s = best_of(reps, lambda: parallel_repair(workers))
        assert par == serial_routes, f"{rung}: {workers}-way repair must equal serial"
        parallel.append((workers, par_s * 1e3))
    _G.clear()

    rec["retrace"] = {
        "dead_links": len(dead),
        "dirty_flows": len(dirty),
        "full_ms": full_s * 1e3,
        "serial_ms": serial_s * 1e3,
        "reach_peak_mb": reach_peak_mb,
        "parallel": parallel,
    }
    rec["peak_rss_mb"] = peak_rss_mb()
    return rec


def measure_kernel():
    """The striped-vs-blocked duel on the 16k store (mirror of the rust
    bench's kernel leg; reports must agree exactly)."""
    topo = lad.Topo(lad.named_spec("xl-16k"))
    base = lad.XmodkRouter(topo)
    flows = lad.sample_pairs(topo.num_nodes, 4, 1)
    routes = [lad.trace_route(topo, base, s, d) for (s, d) in flows]
    striped, striped_s = best_of(
        2, lambda: lad.port_loads_striped(flows, routes, topo.num_ports)
    )
    blocked, blocked_s = best_of(
        2, lambda: lad.port_loads_blocked(flows, routes, topo.num_ports)
    )
    assert striped == blocked, "striped kernel must reproduce the blocked kernel"
    return {
        "rung": "16k",
        "flows": len(flows),
        "blocked_flows_per_sec": len(flows) / blocked_s,
        "striped_flows_per_sec": len(flows) / striped_s,
        "speedup": blocked_s / max(striped_s, 1e-9),
    }


def emit(kernel, records, host_cpus: int) -> str:
    out = ["{"]
    out.append('  "schema": "pgft-bench-eval/3",')
    out.append('  "source": "python-port",')
    out.append(f'  "host_cpus": {host_cpus},')
    out.append(
        '  "netsim": {"skipped": "flit-level engine is rust-only; '
        'cargo bench --bench bench_eval measures events/s"},'
    )
    out.append(
        f'  "kernel": {{"rung": "{kernel["rung"]}", "flows": {kernel["flows"]}, '
        f'"blocked_flows_per_sec": {kernel["blocked_flows_per_sec"]:.1f}, '
        f'"striped_flows_per_sec": {kernel["striped_flows_per_sec"]:.1f}, '
        f'"speedup": {kernel["speedup"]:.4f}}},'
    )
    out.append('  "ladder": [')
    for i, r in enumerate(records):
        out.append("    {")
        out.append(f'      "rung": "{r["rung"]}",')
        out.append(f'      "mode": "{r["mode"]}",')
        out.append(f'      "endpoints": {r["endpoints"]},')
        out.append(f'      "flows": {r["flows"]},')
        out.append(f'      "trace_ms": {r["trace_ms"]:.4f},')
        out.append(f'      "flows_per_sec": {r["flows_per_sec"]:.1f},')
        out.append(f'      "bytes_per_flow": {r["bytes_per_flow"]:.2f},')
        out.append(f'      "peak_rss_mb": {r["peak_rss_mb"]:.1f},')
        rt = r["retrace"]
        if isinstance(rt, str):
            out.append(f'      "retrace": {{"skipped": "{rt}"}}')
        else:
            out.append('      "retrace": {')
            out.append(f'        "dead_links": {rt["dead_links"]},')
            out.append(f'        "dirty_flows": {rt["dirty_flows"]},')
            out.append(f'        "full_ms": {rt["full_ms"]:.4f},')
            out.append(f'        "serial_ms": {rt["serial_ms"]:.4f},')
            out.append(f'        "reach_peak_mb": {rt["reach_peak_mb"]:.2f},')
            speedup = rt["full_ms"] / max(rt["serial_ms"], 1e-9)
            out.append(f'        "speedup_incremental": {speedup:.4f},')
            out.append('        "parallel": [')
            for j, (workers, ms) in enumerate(rt["parallel"]):
                comma = "," if j + 1 < len(rt["parallel"]) else ""
                sp = rt["serial_ms"] / max(ms, 1e-9)
                out.append(
                    f'          {{"threads": {workers}, "ms": {ms:.4f}, '
                    f'"speedup": {sp:.4f}}}{comma}'
                )
            out.append("        ]")
            out.append("      }")
        out.append("    }" + ("," if i + 1 < len(records) else ""))
    out.append("  ]")
    out.append("}")
    return "\n".join(out) + "\n"


def main() -> int:
    records = []

    # Paper fabrics: all-pairs flows, first stage-2 link dead (the same
    # scenario benches/bench_eval.rs uses).
    for name in ("case-study", "medium-512"):
        topo = lad.Topo(lad.named_spec(name))
        flows = all_pairs(topo.num_nodes)
        dead = {next(l for l in range(topo.num_links) if topo.link_stage[l] == 2)}
        print(f"== {name}: {topo.num_nodes} endpoints, {len(flows)} flows ==")
        records.append(measure_rung(name, "tables", topo, flows, dead, reps=3))

    # Ladder rungs: sampled pairs, links:K preset scenarios, seed 1.
    # 16k/64k/256k run on materialized tables, 1m through the implicit
    # view; all repair under the lazy reach budget.
    for name, topology, dsts, fault_links in lad.LADDER:
        spec = lad.named_spec(topology)
        if name == "1m":
            topo, mode = lad.ImplicitTopo(spec), "implicit"
        else:
            topo, mode = lad.Topo(spec), "tables"
        flows = lad.sample_pairs(topo.num_nodes, dsts, 1)
        if name == "16k":
            # Mirror of the rust bench's identity pin: the implicit
            # view must trace byte-identical to the tables.
            implicit = lad.ImplicitTopo(spec)
            base_t, base_i = lad.XmodkRouter(topo), lad.XmodkRouter(implicit)
            for (s, d) in flows[:4096]:
                assert lad.trace_route(topo, base_t, s, d) == lad.trace_route(
                    implicit, base_i, s, d
                ), (s, d)
            print("  16k: implicit view traced identical to tables (4096 flows)")
        dead = (
            set(lad.generate_link_faults(topo, fault_links, 1))
            if fault_links > 0
            else None
        )
        print(f"== {name}: {topo.num_nodes} endpoints, {len(flows)} flows ==")
        reps = 2 if topo.num_nodes <= 65_536 else 1
        records.append(measure_rung(name, mode, topo, flows, dead, reps=reps))

    print("== congestion kernel: striped vs blocked (16k store) ==")
    kernel = measure_kernel()

    try:
        host_cpus = len(os.sched_getaffinity(0))
    except AttributeError:
        host_cpus = os.cpu_count() or 1
    body = emit(kernel, records, host_cpus)
    out_path = sys.argv[1] if len(sys.argv) > 1 else str(
        pathlib.Path(__file__).resolve().parents[2] / "rust" / "BENCH_eval.json"
    )
    pathlib.Path(out_path).write_text(body)
    print(body)
    print(f"wrote {out_path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
