#!/usr/bin/env python3
"""Independent mirror of the fabric coordinator's incremental reroute
pipeline for one pinned cascade scenario.

``rust/src/coordinator/`` repairs its route store and forwarding tables
after every fault event and reports two cost figures per event: the
number of forwarding-table entries that changed (``last_diff_entries``,
what a fabric manager would push to switches) and the number of
all-pairs routes that moved (``routes_changed``).  The builder
containers have no Rust toolchain, so this script recomputes both
figures — plus the post-cascade congestion ``C_p`` over the paper's
C2IO pattern — from the Python routing mirror in
``gen_faults_golden.py`` and pins them (see
``python/tests/test_fabric_reroute.py``; the Rust side pins the same
constants in ``rust/tests/fabric_service.rs``).

The pinned scenario is ``cascade:4`` at seed 2 on the case-study
topology — the smallest seed whose four cumulative stages all leave the
fabric connected (seed 1 partitions two leaves at stage 3).  Cascade
generation shares the ``links:K`` branch of ``FaultModel::generate``
(same sample + shuffle), so the mirror calls
``generate_faults(topo, "links:4", 2)`` and replays the four deaths as
cumulative stages, exactly as the coordinator drains them.
"""

from __future__ import annotations

import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, HERE)

import gen_faults_golden as g  # noqa: E402

SCENARIO_MODEL = "links:4"  # cascade:4 generates identically (same branch)
SCENARIO_SEED = 2
ALGOS = ("dmodk", "gdmodk")
UNROUTED = None


def all_pairs(n: int) -> list:
    """Mirror of ``routing::verify::all_pairs`` (src-major, no diagonal)."""
    return [(s, d) for s in range(n) for d in range(n) if s != d]


def reaches(router, sw: int, dst: int) -> bool:
    """``Router::reaches`` — pristine routers always reach; the degraded
    mirror exposes its ``good`` field (elements nodes-first)."""
    if isinstance(router, g.DegradedRouter):
        return router.good[dst][router.topo.num_nodes + sw]
    return True


def build_switch_tables(topo: g.Topo, router) -> list:
    """Mirror of ``ForwardingTables::build`` (switch_out only; the diff
    figure the coordinator reports counts only switch entries)."""
    out = []
    for sw in range(topo.num_switches):
        row = []
        for dst in range(topo.num_nodes):
            if not reaches(router, sw, dst):
                row.append(UNROUTED)
            elif router.descend_at(sw, dst):
                j = router.down_link(sw, 0, dst)
                row.append(topo.down_port_toward(sw, dst, j))
            else:
                row.append(router.up_port(sw, 0, dst))
        out.append(row)
    return out


def diff_entries(a: list, b: list) -> int:
    """Mirror of ``ForwardingTables::diff_entries``."""
    return sum(
        1 for ra, rb in zip(a, b) for x, y in zip(ra, rb) if x != y
    )


def check() -> dict:
    topo = g.Topo()
    types = g.build_types(topo)
    gnid = g.build_gnid(types)
    c2io = g.c2io_sym_flows(topo, types)
    pairs = all_pairs(topo.num_nodes)
    assert len(pairs) == 64 * 63

    events = g.generate_faults(topo, SCENARIO_MODEL, SCENARIO_SEED)
    assert len(events) == 4 and len(set(events)) == 4
    for link in events:
        assert topo.link_stage[link] >= 2, "only switch links are eligible"

    results: dict = {
        "scenario": f"cascade:4@seed{SCENARIO_SEED}",
        "events": list(events),
    }
    for algo in ALGOS:
        base = g.XmodkRouter(topo, gnid if algo == "gdmodk" else None)
        tables = build_switch_tables(topo, base)
        store = [g.trace_route(topo, base, s, d) for (s, d) in pairs]
        diffs, moved, partitioned = [], [], []
        dead: set = set()
        for step, link in enumerate(events, start=1):
            dead.add(link)
            try:
                degraded = g.DegradedRouter(topo, set(dead), base)
            except RuntimeError:
                # Partitioned fabric: the coordinator keeps serving the
                # previous tables, so nothing changes at this stage.
                partitioned.append(step)
                continue
            new_tables = build_switch_tables(topo, degraded)
            new_store = [g.trace_route(topo, degraded, s, d) for (s, d) in pairs]
            # No repaired route may use a dead link, and every route a
            # dead link touched must have moved (the dirty-flow set is
            # exactly the changed set — the incremental-repair invariant).
            for old, new in zip(store, new_store):
                crosses = any(topo.port_link[p] in dead for p in old)
                assert crosses == (old != new), "dirty flows = changed flows"
                assert all(topo.port_link[p] not in dead for p in new)
            diffs.append(diff_entries(tables, new_tables))
            moved.append(sum(1 for a, b in zip(store, new_store) if a != b))
            tables, store = new_tables, new_store
        final = g.Report(topo, list(zip(pairs, store)))
        c2io_rep = g.Report(
            topo, [((s, d), store[s * 63 + d - (1 if d > s else 0)]) for (s, d) in c2io]
        )
        results[algo] = {
            "diff_entries": diffs,
            "routes_changed": moved,
            "partitioned_stages": partitioned,
            "final_c_topo_all_pairs": final.c_topo(),
            "final_c_topo_c2io": c2io_rep.c_topo(),
        }
    return results


def main() -> int:
    import json

    results = check()
    json.dump(results, sys.stdout, indent=2)
    sys.stdout.write("\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
