#!/usr/bin/env python3
"""Reference generator for ``rust/tests/golden/faults_case_study.csv``.

This is a line-by-line port of the exact pipeline behind::

    pgft faults --topo case-study --algo dmodk,gdmodk --pattern c2io-sym \
                --faults none,links:2,stage:3:4 --seeds 1 --serial --format csv

kept in Python so the golden file can be (re)generated and audited
without a Rust toolchain, and so CI has an independent implementation to
diff against.  Every stage mirrors its Rust counterpart exactly:

* ``util::rng``            -> SplitMix64 / xoshiro256** / Lemire bounded
* ``topology::build``      -> identical switch/port/link id assignment
* ``routing::xmodk``       -> Dmodk / Gdmodk closed forms + Algorithm 1
* ``faults::scenario``     -> seeded links:K / stage:L:K expansion
* ``faults::view/router``  -> reachability fields + degraded rerouting
* ``metrics``              -> the C_p = min(src, dst) congestion report
* ``sweep::result``        -> the 26-column CSV row encoding

Run ``python3 python/tools/gen_faults_golden.py`` to regenerate the
golden file; the script asserts every paper-pinned figure on the way
(see ``python/tests/test_faults_golden.py`` for the pytest wrapper).
"""

from __future__ import annotations

import os
import sys

MASK = (1 << 64) - 1

# ---------------------------------------------------------------------------
# util::rng — SplitMix64 + xoshiro256** + Lemire bounded sampling
# ---------------------------------------------------------------------------


class SplitMix64:
    def __init__(self, seed: int) -> None:
        self.state = seed & MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return z ^ (z >> 31)


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & MASK


class Xoshiro256:
    def __init__(self, seed: int) -> None:
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_below(self, bound: int) -> int:
        assert bound > 0
        x = self.next_u64()
        m = x * bound
        low = m & MASK
        if low < bound:
            threshold = ((-bound) & MASK) % bound
            while low < threshold:
                x = self.next_u64()
                m = x * bound
                low = m & MASK
        return m >> 64

    def index(self, bound: int) -> int:
        return self.next_below(bound)

    def shuffle(self, xs: list) -> None:
        for i in range(len(xs) - 1, 0, -1):
            j = self.index(i + 1)
            xs[i], xs[j] = xs[j], xs[i]

    def sample_indices(self, n: int, k: int) -> list:
        assert k <= n
        chosen: list = []
        for j in range(n - k, n):
            t = self.index(j + 1)
            if t in chosen:
                chosen.append(j)
            else:
                chosen.append(t)
        return chosen


# ---------------------------------------------------------------------------
# topology — the paper's case study PGFT(3; 8,4,2; 1,2,1; 1,1,4)
# ---------------------------------------------------------------------------

H = 3
M = [8, 4, 2]
W = [1, 2, 1]
P = [1, 1, 4]


def w_prefix(l: int) -> int:
    out = 1
    for x in W[:l]:
        out *= x
    return out


class Topo:
    """Mirror of ``topology::build::build_pgft`` (same id assignment)."""

    def __init__(self) -> None:
        self.num_nodes = 1
        for m in M:
            self.num_nodes *= m
        # switches: level-major; each has level, top, bottom, up/down port slots
        self.sw_level: list = []
        self.sw_top: list = []
        self.sw_bottom: list = []
        self.sw_up: list = []
        self.sw_down: list = []
        self.level_start = []
        for l in range(1, H + 1):
            self.level_start.append(len(self.sw_level))
            above = 1
            for m in M[l:]:
                above *= m
            below = 1
            for w in W[:l]:
                below *= w
            for within in range(above * below):
                x = within
                bottom = []
                for j in range(l):
                    bottom.append(x % W[j])
                    x //= W[j]
                top = []
                for j in range(H - l):
                    top.append(x % M[l + j])
                    x //= M[l + j]
                assert x == 0
                self.sw_level.append(l)
                self.sw_top.append(top)
                self.sw_bottom.append(bottom)
                self.sw_up.append([None] * self.up_ports_at(l))
                self.sw_down.append([None] * self.down_ports_at(l))
        self.level_start.append(len(self.sw_level))
        self.num_switches = len(self.sw_level)

        self.node_digits = []
        self.node_up = []
        for nid in range(self.num_nodes):
            d = []
            x = nid
            for l in range(H):
                d.append(x % M[l])
                x //= M[l]
            self.node_digits.append(d)
            self.node_up.append([None] * self.up_ports_at(0))

        # ports: owner, peer, up, link, index;  links: up_port, down_port, stage
        self.port_owner: list = []
        self.port_peer: list = []
        self.port_up: list = []
        self.port_link: list = []
        self.port_index: list = []
        self.link_up: list = []
        self.link_down: list = []
        self.link_stage: list = []

        # stage 1: nodes to leaves
        for nid in range(self.num_nodes):
            digits = self.node_digits[nid]
            child_idx = digits[0]
            for c in range(W[0]):
                leaf = self.switch_at(1, digits[1:], [c])
                for j in range(P[0]):
                    up_idx = c + W[0] * j
                    down_idx = child_idx * P[0] + j
                    self._add_link(("n", nid), up_idx, ("s", leaf), down_idx, 1)

        # stages 2..h
        for l in range(1, H):
            for sid in range(self.level_start[l - 1], self.level_start[l]):
                top = self.sw_top[sid]
                bottom = self.sw_bottom[sid]
                child_idx = top[0]
                for c in range(W[l]):
                    parent = self.switch_at(l + 1, top[1:], bottom + [c])
                    for j in range(P[l]):
                        up_idx = c + W[l] * j
                        down_idx = child_idx * P[l] + j
                        self._add_link(("s", sid), up_idx, ("s", parent), down_idx, l + 1)

        assert all(p is not None for ups in self.sw_up for p in ups)
        assert all(p is not None for dns in self.sw_down for p in dns)
        assert all(p is not None for ups in self.node_up for p in ups)
        self.num_ports = len(self.port_owner)
        self.num_links = len(self.link_up)

    @staticmethod
    def up_ports_at(l: int) -> int:
        return 0 if l >= H else W[l] * P[l]

    @staticmethod
    def down_ports_at(l: int) -> int:
        return M[l - 1] * P[l - 1]

    def switch_at(self, level: int, top: list, bottom: list) -> int:
        bot = 0
        for j in range(level - 1, -1, -1):
            bot = bot * W[j] + bottom[j]
        topv = 0
        for j in range(H - level - 1, -1, -1):
            topv = topv * M[level + j] + top[j]
        within = topv * w_prefix(level) + bot
        return self.level_start[level - 1] + within

    def _add_link(self, lower, up_idx, upper, down_idx, stage) -> None:
        link_id = len(self.link_up)
        up_port = len(self.port_owner)
        down_port = up_port + 1
        self.port_owner += [lower, upper]
        self.port_peer += [upper, lower]
        self.port_up += [True, False]
        self.port_link += [link_id, link_id]
        self.port_index += [up_idx, down_idx]
        self.link_up.append(up_port)
        self.link_down.append(down_port)
        self.link_stage.append(stage)
        kind, idx = lower
        if kind == "n":
            self.node_up[idx][up_idx] = up_port
        else:
            self.sw_up[idx][up_idx] = up_port
        ukind, uidx = upper
        assert ukind == "s"
        self.sw_down[uidx][down_idx] = down_port

    def is_ancestor(self, sw: int, nid: int) -> bool:
        level = self.sw_level[sw]
        d = self.node_digits[nid]
        return all(d[level + j] == t for j, t in enumerate(self.sw_top[sw]))

    def ancestors_at(self, l: int, nid: int) -> list:
        digits = self.node_digits[nid]
        top = digits[l:]
        wl = w_prefix(l)
        out = []
        bottom = [0] * l
        for _ in range(wl):
            out.append(self.switch_at(l, top, bottom))
            for j in range(l):
                bottom[j] += 1
                if bottom[j] < W[j]:
                    break
                bottom[j] = 0
        out.sort()
        return out

    def child_index_toward(self, sw: int, nid: int) -> int:
        return self.node_digits[nid][self.sw_level[sw] - 1]

    def down_port_toward(self, sw: int, nid: int, j: int) -> int:
        p_l = P[self.sw_level[sw] - 1]
        c = self.child_index_toward(sw, nid)
        return self.sw_down[sw][c * p_l + j]

    def port_level(self, p: int) -> int:
        kind, idx = self.port_owner[p]
        return 0 if kind == "n" else self.sw_level[idx]

    def level_switches(self, l: int):
        return range(self.level_start[l - 1], self.level_start[l])


# ---------------------------------------------------------------------------
# nodes — placement io:last:1 + Algorithm 1 re-index
# ---------------------------------------------------------------------------


def build_types(topo: Topo) -> list:
    """io:last:1 — the highest NID of each leaf is IO, the rest compute."""
    types = ["compute"] * topo.num_nodes
    for leaf in topo.level_switches(1):
        nids = sorted(
            {topo.port_peer[p][1] for p in topo.sw_down[leaf] if topo.port_peer[p][0] == "n"}
        )
        types[nids[-1]] = "io"
    return types


def build_gnid(types: list) -> list:
    """TypeReindex::new — compute first, then io, NID order within type."""
    gnid = [0] * len(types)
    nxt = 0
    for ty in ("compute", "io"):
        for nid, t in enumerate(types):
            if t == ty:
                gnid[nid] = nxt
                nxt += 1
    assert nxt == len(types)
    return gnid


# ---------------------------------------------------------------------------
# patterns — c2io-sym (bijective symmetric-leaf reading)
# ---------------------------------------------------------------------------


def c2io_sym_flows(topo: Topo, types: list) -> list:
    flows = []
    for leaf in topo.level_switches(1):
        nids = sorted(
            {topo.port_peer[p][1] for p in topo.sw_down[leaf] if topo.port_peer[p][0] == "n"}
        )
        srcs = [n for n in nids if types[n] == "compute"]
        if not srcs:
            continue
        # mirrored leaf: top-level digit flipped
        top = list(topo.sw_top[leaf])
        top[-1] = M[H - 1] - 1 - top[-1]
        mirror = topo.switch_at(1, top, topo.sw_bottom[leaf])
        mnids = sorted(
            {topo.port_peer[p][1] for p in topo.sw_down[mirror] if topo.port_peer[p][0] == "n"}
        )
        dsts = [n for n in mnids if types[n] == "io"]
        if not dsts:
            continue
        for i, s in enumerate(srcs):
            flows.append((s, dsts[i % len(dsts)]))
    return flows


# ---------------------------------------------------------------------------
# routing — Xmodk closed forms and the trace loop
# ---------------------------------------------------------------------------


def up_index(level: int, key: int) -> int:
    k = W[level] * P[level]
    return (key // w_prefix(level)) % k


def down_index(level: int, key: int) -> int:
    return (key // w_prefix(level)) % P[level - 1]


class XmodkRouter:
    """Dmodk (key = dst) or Gdmodk (key = gnid[dst])."""

    def __init__(self, topo: Topo, gnid=None) -> None:
        self.topo = topo
        self.gnid = gnid

    def key(self, src: int, dst: int) -> int:
        return self.gnid[dst] if self.gnid is not None else dst

    def inject_port(self, src: int, dst: int) -> int:
        return self.topo.node_up[src][up_index(0, self.key(src, dst))]

    def up_port(self, sw: int, src: int, dst: int) -> int:
        level = self.topo.sw_level[sw]
        return self.topo.sw_up[sw][up_index(level, self.key(src, dst))]

    def down_link(self, sw: int, src: int, dst: int) -> int:
        level = self.topo.sw_level[sw]
        return down_index(level, self.key(src, dst))

    def descend_at(self, sw: int, dst: int) -> bool:
        return self.topo.is_ancestor(sw, dst)


def trace_route(topo: Topo, router, src: int, dst: int) -> list:
    """Mirror of ``routing::trace::trace_route_into``."""
    if src == dst:
        return []
    ports = [router.inject_port(src, dst)]
    cur = topo.port_peer[ports[0]]
    while True:
        kind, idx = cur
        if kind == "n":
            assert idx == dst, f"route ended at node {idx}, wanted {dst}"
            return ports
        sw = idx
        if router.descend_at(sw, dst):
            j = router.down_link(sw, src, dst)
            out = topo.down_port_toward(sw, dst, j)
        else:
            out = router.up_port(sw, src, dst)
        ports.append(out)
        cur = topo.port_peer[out]
        assert len(ports) <= 2 * H + 1, "route too long: loop?"


# ---------------------------------------------------------------------------
# faults — scenario expansion (links:K, stage:L:K) and degraded rerouting
# ---------------------------------------------------------------------------

SEED_XOR = 0xFA0175CE4A5105


def generate_faults(topo: Topo, model: str, seed: int) -> list:
    """Mirror of ``FaultModel::generate`` for the golden's three specs."""
    rng = Xoshiro256(seed ^ SEED_XOR)
    eligible = [l for l in range(topo.num_links) if topo.link_stage[l] >= 2]
    if model == "none":
        return []
    if model.startswith("links:"):
        count = int(model.split(":")[1])
        k = min(count, len(eligible))
        idx = rng.sample_indices(max(len(eligible), 1), k)
        rng.shuffle(idx)
        return [eligible[i] for i in idx]
    if model.startswith("stage:"):
        _, stage_s, count_s = model.split(":")
        stage, count = int(stage_s), int(count_s)
        stage_links = [l for l in range(topo.num_links) if topo.link_stage[l] == stage]
        if not stage_links:
            return []
        bundle = max(Topo.up_ports_at(stage - 1), 1)
        bundles = max(len(stage_links) // bundle, 1)
        start = rng.next_below(bundles) * bundle
        k = min(count, len(stage_links))
        return [stage_links[(start + i) % len(stage_links)] for i in range(k)]
    raise ValueError(f"unsupported fault model {model!r}")


class DegradedRouter:
    """Mirror of ``faults::router::DegradedRouter`` over a base router."""

    def __init__(self, topo: Topo, dead: set, base) -> None:
        self.topo = topo
        self.dead = dead
        self.base = base
        n, ns = topo.num_nodes, topo.num_switches
        self.descend = [[False] * ns for _ in range(n)]
        self.good = [[False] * (n + ns) for _ in range(n)]
        for dst in range(n):
            desc, good = self._reach(dst)
            for src in range(n):
                if not good[src]:
                    raise RuntimeError(f"fabric partitioned: {src} -> {dst}")
            self.descend[dst] = desc
            self.good[dst] = good

    def _alive(self, port: int) -> bool:
        return self.topo.port_link[port] not in self.dead

    def _reach(self, dst: int):
        """Mirror of ``DegradedTopology::reach``."""
        topo = self.topo
        n, ns = topo.num_nodes, topo.num_switches
        descend = [False] * ns
        good = [False] * (n + ns)
        good[dst] = True
        for l in range(1, H + 1):
            for sw in topo.ancestors_at(l, dst):
                p_l = P[l - 1]
                ok = False
                for j in range(p_l):
                    port = topo.down_port_toward(sw, dst, j)
                    if not self._alive(port):
                        continue
                    kind, idx = topo.port_peer[port]
                    if kind == "n":
                        if idx == dst:
                            ok = True
                            break
                    elif descend[idx]:
                        ok = True
                        break
                descend[sw] = ok
        for l in range(H, 0, -1):
            for sw in topo.level_switches(l):
                g = descend[sw]
                if not g:
                    for p in topo.sw_up[sw]:
                        if self._alive(p):
                            kind, idx = topo.port_peer[p]
                            if kind == "s" and good[n + idx]:
                                g = True
                                break
                good[n + sw] = g
        for nid in range(n):
            if nid == dst:
                continue
            g = False
            for p in topo.node_up[nid]:
                if self._alive(p):
                    kind, idx = topo.port_peer[p]
                    if kind == "s" and good[n + idx]:
                        g = True
                        break
            good[nid] = g
        return descend, good

    def _up_viable(self, port: int, dst: int) -> bool:
        if not self._alive(port):
            return False
        kind, idx = self.topo.port_peer[port]
        return kind == "s" and self.good[dst][self.topo.num_nodes + idx]

    def _pick_up(self, ports: list, preferred: int, dst: int) -> int:
        start = self.topo.port_index[preferred]
        assert ports[start] == preferred
        for i in range(len(ports)):
            port = ports[(start + i) % len(ports)]
            if self._up_viable(port, dst):
                return port
        raise RuntimeError("no viable up-port (connectivity was validated)")

    def inject_port(self, src: int, dst: int) -> int:
        preferred = self.base.inject_port(src, dst)
        return self._pick_up(self.topo.node_up[src], preferred, dst)

    def up_port(self, sw: int, src: int, dst: int) -> int:
        preferred = self.base.up_port(sw, src, dst)
        return self._pick_up(self.topo.sw_up[sw], preferred, dst)

    def down_link(self, sw: int, src: int, dst: int) -> int:
        level = self.topo.sw_level[sw]
        p_l = P[level - 1]
        preferred = self.base.down_link(sw, src, dst) % p_l
        for i in range(p_l):
            j = (preferred + i) % p_l
            if self._alive(self.topo.down_port_toward(sw, dst, j)):
                return j
        raise RuntimeError("descend_at guaranteed an alive parallel link")

    def descend_at(self, sw: int, dst: int) -> bool:
        return self.descend[dst][sw]


# ---------------------------------------------------------------------------
# metrics — the C_p = min(src, dst) congestion report + AlgoSummary fields
# ---------------------------------------------------------------------------


class Report:
    def __init__(self, topo: Topo, routes: list) -> None:
        self.topo = topo
        np_ = topo.num_ports
        self.routes_n = [0] * np_
        self.srcs = [set() for _ in range(np_)]
        self.dsts = [set() for _ in range(np_)]
        for (src, dst), ports in routes:
            for p in ports:
                self.routes_n[p] += 1
                self.srcs[p].add(src)
                self.dsts[p].add(dst)

    def c(self, p: int) -> int:
        return min(len(self.srcs[p]), len(self.dsts[p]))

    def c_topo(self) -> int:
        return max(self.c(p) for p in range(self.topo.num_ports))

    def hot_ports(self) -> list:
        return [p for p in range(self.topo.num_ports) if self.c(p) > 1]

    def c_max_at(self, level: int, up: bool) -> int:
        vals = [
            self.c(p)
            for p in range(self.topo.num_ports)
            if self.topo.port_level(p) == level and self.topo.port_up[p] == up
        ]
        return max(vals) if vals else 0

    def used_at(self, level: int, up: bool) -> int:
        return sum(
            1
            for p in range(self.topo.num_ports)
            if self.topo.port_level(p) == level
            and self.topo.port_up[p] == up
            and self.routes_n[p] > 0
        )


def summary_cells(topo: Topo, rep: Report) -> dict:
    hot = rep.hot_ports()
    hot_per_level = [0] * (H + 1)
    for p in hot:
        hot_per_level[topo.port_level(p)] += 1
    total_top = sum(
        1
        for p in range(topo.num_ports)
        if topo.port_level(p) == H and not topo.port_up[p]
    )
    return {
        "c_topo": rep.c_topo(),
        "hot_total": len(hot),
        "hot_per_level": hot_per_level,
        "c_max_up": [rep.c_max_at(l, True) for l in range(H + 1)],
        "c_max_down": [rep.c_max_at(l, False) for l in range(H + 1)],
        "used_top": rep.used_at(H, False),
        "total_top": total_top,
    }


# ---------------------------------------------------------------------------
# the golden grid itself
# ---------------------------------------------------------------------------

COLUMNS = [
    "topology", "placement", "algo", "pattern", "fault", "seed", "flows", "C_topo",
    "hot_ports", "hot_per_level", "cmax_up", "cmax_down", "used_top", "total_top",
    "dead_links", "routes_changed", "routable", "agg_thru", "min_rate", "completion",
    "retention", "ns_offered", "ns_accepted", "ns_mean_lat", "ns_p99_lat", "ns_saturated",
    "workload", "wl_phases", "wl_makespan", "wl_job_times",
]

# Optional-axis columns (simulate / netsim / workload) that stay empty in
# this grid: everything after `routable`.
EMPTY_TAIL = [""] * (len(COLUMNS) - 17)


def join_nums(xs: list) -> str:
    return "|".join(str(x) for x in xs)


def golden_rows() -> list:
    topo = Topo()
    assert topo.num_nodes == 64 and topo.num_switches == 14
    assert topo.num_links == 96 and topo.num_ports == 192

    types = build_types(topo)
    assert [n for n, t in enumerate(types) if t == "io"] == [7, 15, 23, 31, 39, 47, 55, 63]
    gnid = build_gnid(types)
    assert gnid[7] == 56 and gnid[47] == 61 and gnid[63] == 63
    assert gnid[0] == 0 and gnid[8] == 7 and gnid[62] == 55

    flows = c2io_sym_flows(topo, types)
    assert len(flows) == 56
    assert all((s, 47) in flows for s in range(8, 15)), "paper: NIDs 8..14 -> 47"
    assert all((s, 15) in flows for s in range(40, 47))

    seed = 1
    rows = []
    for algo in ("dmodk", "gdmodk"):
        base = XmodkRouter(topo, gnid if algo == "gdmodk" else None)
        pristine = [((s, d), trace_route(topo, base, s, d)) for (s, d) in flows]
        # Sanity of the degraded port: zero faults is byte-identical to
        # the base router (the property rust/tests/fault_rerouting.rs
        # pins on the Rust side).
        empty = DegradedRouter(topo, set(), base)
        assert [trace_route(topo, empty, s, d) for (s, d) in flows] == [
            p for (_sd, p) in pristine
        ], "zero-fault DegradedRouter must not move a single port"
        for ports_pair in pristine:
            (_s, _d), ports = ports_pair
            assert len(ports) == 6, "all C2IO flows cross the top: 6 hops"
            dirs = [topo.port_up[p] for p in ports]
            first_down = dirs.index(False) if False in dirs else len(dirs)
            assert all(not u for u in dirs[first_down:]), "valley-free"

        for fault in ("none", "links:2", "stage:3:4"):
            events = generate_faults(topo, fault, seed)
            dead = set(events)
            dead_links = len(dead)
            if fault == "none":
                routed = pristine
                routes_changed = 0
            else:
                for l in dead:
                    assert topo.link_stage[l] >= 2, "only switch links are eligible"
                try:
                    degraded = DegradedRouter(topo, dead, base)
                except RuntimeError:
                    # Partitioned fabric: an unroutable row (mirrors the
                    # sweep runner), not a grid error.
                    rows.append([
                        "case-study", "io:last:1", algo, "c2io-sym", fault, str(seed),
                        str(len(flows)), "0", "0", join_nums([0] * (H + 1)),
                        join_nums([0] * (H + 1)), join_nums([0] * (H + 1)), "0", "16",
                        str(dead_links), str(len(flows)), "0",
                    ] + EMPTY_TAIL)
                    continue
                routed = [((s, d), trace_route(topo, degraded, s, d)) for (s, d) in flows]
                for (_sd, ports) in routed:
                    for p in ports:
                        assert topo.port_link[p] not in dead, "dead link used"
                routes_changed = sum(
                    1 for (a, b) in zip(pristine, routed) if a[1] != b[1]
                )
            rep = Report(topo, routed)
            cells = summary_cells(topo, rep)

            if fault == "none":
                if algo == "dmodk":
                    assert cells["c_topo"] == 4, "paper §III.B"
                    assert cells["hot_per_level"][H] == 2, "two hot top-level ports"
                    assert cells["used_top"] == 2, "Dmodk concentrates on 2 top ports"
                else:
                    assert cells["c_topo"] == 1, "paper §IV optimum"
                    assert cells["hot_total"] == 0
                    assert cells["used_top"] == 8
                assert cells["total_top"] == 16
            if fault == "links:2":
                assert dead_links == 2
            if fault == "stage:3:4":
                assert dead_links == 4
                owners = {topo.port_owner[topo.link_up[l]] for l in dead}
                assert len(owners) == 1, "stage cut concentrates on one bundle"
                if algo == "gdmodk":
                    assert routes_changed > 0, "gdmodk uses every L2 bundle"

            rows.append([
                "case-study", "io:last:1", algo, "c2io-sym", fault, str(seed),
                str(len(flows)), str(cells["c_topo"]), str(cells["hot_total"]),
                join_nums(cells["hot_per_level"]), join_nums(cells["c_max_up"]),
                join_nums(cells["c_max_down"]), str(cells["used_top"]),
                str(cells["total_top"]), str(dead_links), str(routes_changed), "1",
            ] + EMPTY_TAIL)
    return rows


def golden_csv() -> str:
    rows = golden_rows()
    out = [",".join(COLUMNS)]
    out += [",".join(r) for r in rows]
    return "\n".join(out) + "\n"


def main() -> int:
    csv = golden_csv()
    here = os.path.dirname(os.path.abspath(__file__))
    dest = os.path.normpath(
        os.path.join(here, "..", "..", "rust", "tests", "golden", "faults_case_study.csv")
    )
    os.makedirs(os.path.dirname(dest), exist_ok=True)
    with open(dest, "w", encoding="utf-8", newline="") as f:
        f.write(csv)
    sys.stderr.write(f"wrote {dest} ({len(csv.splitlines()) - 1} rows)\n")
    sys.stdout.write(csv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
