#!/usr/bin/env python3
"""Cross-check a ``pgft --record`` time-series document against the
golden Python injection mirror.

The Rust flight recorder exports windowed per-link series in a
``pgft-timeseries/1`` document.  This script verifies, per recording:

* structural discipline: schema tag, ``host_cpus`` provenance, the
  sampling config (window/top_k/max_windows), and no ``null`` anywhere;
* window geometry: retained windows tile the cycle axis contiguously,
  the first retained index equals the shed count, every window is at
  most ``window`` cycles long (shorter only at a forced phase/horizon
  rollover) and the last window closes exactly at the horizon;
* flit conservation: the per-window deltas of all three series
  (injected / delivered / forwarded), plus the shed aggregate, sum to
  the whole-run totals — nothing vanishes when the bounded ring sheds;
* top-K sanity: at most ``top_k`` ports per window, sorted descending
  by forwarded flits (ties toward the lower port id), no port forwards
  more than one flit per cycle of its window, the per-port sum never
  exceeds the window's forwarded total and every high-water vector has
  one slot per VC;
* the exact injection replay: for unphased, unshed ``bernoulli``
  case-study runs, the per-window ``injected_flits`` series is replayed
  flit-for-flit from the same closed-form geometric-gap arrival process
  (xoshiro256** per-flow streams) the engine uses — the recorder's
  window bucketing is pinned against an independent implementation.

Recordings are self-describing (seed, rate, flow count, horizon ride in
the document), so no engine parameters need to be passed.  Runs outside
the replayable set (phased, shed, non-bernoulli, non-case-study) still
get the structural checks and are reported as partially checked.

Usage::

    pgft netsim --topo case-study --algo dmodk,gdmodk --pattern c2io-sym \
        --rates 0.8 --warmup 100 --measure 400 --drain 100 \
        --record ts.json --format csv --out /dev/null
    python3 python/tools/check_timeseries.py ts.json [--trace trace.json]

``--trace`` additionally validates a ``--trace`` Perfetto/Chrome-trace
export: well-formed JSON, a non-empty ``traceEvents`` array, the event
phase grammar and the no-null discipline.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, HERE)

from check_telemetry import GOLDEN_GAMMA, CheckError, draw_gap, ensure  # noqa: E402
from gen_faults_golden import MASK, Xoshiro256  # noqa: E402


def replay_window_injection(rec: dict, window: int) -> list:
    """Per-window injected-flit series replayed from the arrival process.

    Mirrors the engine: each flow's xoshiro256** stream is seeded at
    ``seed + (flow+1) * golden gamma``; arrivals walk closed-form
    geometric gaps with ``p = rate / packet_flits``; an arrival at cycle
    ``t`` (``0 < t <= horizon``) injects ``packet_flits`` flits into the
    window spanning ``(start, end]`` that contains ``t``.  Windows are
    uniform here (the replayable set excludes phased runs), so the
    bucket is ``(t - 1) // window``.
    """
    horizon = rec["horizon"]
    p = rec["rate"] / float(rec["packet_flits"])
    pf = rec["packet_flits"]
    out = [0] * len(rec["windows"])
    for f in range(rec["flows"]):
        rng = Xoshiro256((rec["seed"] + (f + 1) * GOLDEN_GAMMA) & MASK)
        t = 0
        while True:
            t = min(t + draw_gap(rng, p), MASK)
            if t > horizon:
                break
            out[(t - 1) // window] += pf
    return out


def check_geometry(name: str, rec: dict, window: int, top_k: int) -> None:
    """Window tiling, ring indices and top-K ordering of one recording."""
    windows = rec["windows"]
    ensure(windows, f"{name}: no retained windows")
    ensure(
        windows[0]["index"] == rec["shed"]["windows"],
        f"{name}: first retained index must equal the shed count",
    )
    prev_end = windows[0]["start"]
    for i, w in enumerate(windows):
        ensure(w["index"] == windows[0]["index"] + i, f"{name}: indices not monotone")
        ensure(w["start"] == prev_end, f"{name}: window {i} does not tile the axis")
        span = w["end"] - w["start"]
        ensure(0 < span <= window, f"{name}: window {i} span {span} out of range")
        prev_end = w["end"]
        ports = w["ports"]
        ensure(len(ports) <= top_k, f"{name}: window {i} exceeds top_k")
        for a, b in zip(ports, ports[1:]):
            ensure(
                (a["forwarded"], -a["port"]) >= (b["forwarded"], -b["port"]),
                f"{name}: window {i} top-K not sorted (desc, ties to lower id)",
            )
        for pw in ports:
            ensure(
                pw["forwarded"] <= span,
                f"{name}: port {pw['port']} forwards >1 flit/cycle in window {i}",
            )
            ensure(
                len(pw["vc_hwm"]) == rec["vcs"],
                f"{name}: port {pw['port']} high-water vector != vcs slots",
            )
        ensure(
            sum(pw["forwarded"] for pw in ports) <= w["forwarded_flits"],
            f"{name}: window {i} top-K forwards more than the window total",
        )
    ensure(prev_end == rec["horizon"], f"{name}: last window must close at the horizon")
    if rec["shed"]["windows"] == 0:
        ensure(windows[0]["start"] == 0, f"{name}: unshed series must start at cycle 0")


def check_conservation(name: str, rec: dict) -> None:
    """Retained + shed window deltas must sum to the run totals."""
    for series in ("injected_flits", "delivered_flits", "forwarded_flits"):
        retained = sum(w[series] for w in rec["windows"])
        total = retained + rec["shed"][series]
        ensure(
            total == rec["totals"][series],
            f"{name}: {series} windows+shed {total} != totals {rec['totals'][series]}",
        )


def replayable(rec: dict) -> bool:
    """Whether the exact injection replay applies to this recording."""
    return (
        rec["injection"] == "bernoulli"
        and not rec["phases"]
        and rec["shed"]["windows"] == 0
        and rec["topo"] == "case-study"
        and rec.get("label", {}).get("pattern") == "c2io-sym"
    )


def check_recording(rec: dict, window: int, top_k: int) -> bool:
    """Check one recording; returns True when the replay ran too."""
    name = ",".join(f"{k}={v}" for k, v in sorted(rec.get("label", {}).items())) or "run"
    check_geometry(name, rec, window, top_k)
    check_conservation(name, rec)
    if not replayable(rec):
        return False
    expected = replay_window_injection(rec, window)
    got = [w["injected_flits"] for w in rec["windows"]]
    ensure(
        got == expected,
        f"{name}: per-window injected series diverges from the Python replay: "
        f"got {got}, expected {expected}",
    )
    ensure(
        sum(expected) == rec["totals"]["injected_flits"],
        f"{name}: replay total != recorded injected total",
    )
    return True


def check_document(doc: dict) -> tuple:
    """Check a whole time-series document; returns (replayed, partial)."""
    ensure(doc.get("schema") == "pgft-timeseries/1", "wrong or missing schema tag")
    ensure(doc.get("host_cpus", 0) >= 1, "host_cpus provenance missing")
    ensure(doc.get("window", 0) >= 1, "window provenance missing")
    ensure(doc.get("top_k", 0) >= 1, "top_k provenance missing")
    ensure(doc.get("max_windows", 0) >= 1, "max_windows provenance missing")
    runs = doc.get("runs", [])
    ensure(runs, "document carries no recordings")
    replayed, partial = 0, 0
    for rec in runs:
        if check_recording(rec, doc["window"], doc["top_k"]):
            replayed += 1
        else:
            partial += 1
    return replayed, partial


def check_trace(path: str) -> int:
    """Validate a Chrome-trace/Perfetto export; returns the event count."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    ensure("null" not in text, "trace documents must not carry null")
    doc = json.loads(text)
    events = doc.get("traceEvents")
    ensure(isinstance(events, list) and events, "traceEvents missing or empty")
    for ev in events:
        ensure(
            isinstance(ev.get("name"), str) and ev.get("pid") == 1,
            f"malformed trace event: {ev}",
        )
        ph = ev.get("ph")
        ensure(ph in ("M", "X", "C"), f"unknown event phase {ph!r}")
        if ph in ("X", "C"):
            ensure(ev.get("ts", -1) >= 0, f"event without timestamp: {ev}")
        if ph == "X":
            ensure(ev.get("dur", 0) >= 1, f"zero-width slice: {ev}")
    return len(events)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("timeseries", help="pgft-timeseries/1 JSON from pgft --record")
    ap.add_argument("--trace", help="optional Perfetto export from pgft --trace")
    cfg = ap.parse_args(argv)
    with open(cfg.timeseries, encoding="utf-8") as f:
        text = f.read()
    try:
        ensure("null" not in text, "time-series documents must not carry null")
        replayed, partial = check_document(json.loads(text))
        events = check_trace(cfg.trace) if cfg.trace else 0
    except CheckError as e:
        sys.stderr.write(f"FAIL {cfg.timeseries}: {e}\n")
        return 1
    msg = (
        f"OK {cfg.timeseries}: {replayed} recording(s) replayed flit-for-flit, "
        f"{partial} structurally checked"
    )
    if cfg.trace:
        msg += f"; {cfg.trace}: {events} trace events validated"
    sys.stderr.write(msg + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
