#!/usr/bin/env python3
"""Parameterized PGFT mirror for the eval size ladder.

``gen_faults_golden.py`` is the *golden-pinned* mirror of the paper's
case study — its topology constants are deliberately hard-coded so the
golden CSV can never drift.  This module is the generalization that the
large-fabric work needs: the same id-assignment, routing, fault and
rerouting semantics as the Rust side (``topology::build``,
``routing::xmodk``, ``faults::scenario``, ``faults::router``,
``eval::ladder``), parameterized over any ``PGFT(h; m; w; p)`` spec and
engineered to stay tractable at 16k-256k endpoints in pure Python:

* ports/peers are flat ``array``-friendly int lists (a peer is ``nid``
  for a node or ``num_nodes + sid`` for a switch), not tuples;
* the degraded router is **lazy**: per-destination reachability is
  memoized on first use instead of materialized for every destination
  up front (the dense per-dst tables that are fine at 64 nodes are the
  exact thing DESIGN.md §10 rules out at scale).

The RNG classes are imported from ``gen_faults_golden`` so the two
mirrors can never disagree about the bit streams; the ladder specs and
the sampled-pair generator mirror ``rust/src/eval/ladder.rs`` constant
for constant.  ``python/tests/test_ladder_mirror.py`` cross-checks this
module against the golden mirror on the case study.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from gen_faults_golden import Xoshiro256  # noqa: E402  (shared RNG mirror)

# Mirrors eval::ladder::PAIR_SEED_XOR and faults::scenario's seed domain.
PAIR_SEED_XOR = 0x5A3B_1E0D_C4F2_9786
FAULT_SEED_XOR = 0xFA_0175_CE4A_5105

# Mirrors eval::ladder::LADDER (name, topology, dsts_per_node, fault_links).
LADDER = [
    ("16k", "xl-16k", 4, 320),
    ("64k", "xl-64k", 2, 1280),
    ("256k", "xl-256k", 1, 2560),
    ("1m", "xl-1m", 1, 5120),
]

# Mirrors topology::families::named_spec for the specs the ladder needs.
NAMED_SPECS = {
    "case-study": ([8, 4, 2], [1, 2, 1], [1, 1, 4]),
    "medium-512": ([16, 8, 4], [1, 4, 2], [1, 1, 2]),
    "xl-16k": ([32, 32, 16], [1, 16, 8], [1, 1, 2]),
    "xl-64k": ([32, 32, 64], [1, 16, 8], [1, 1, 2]),
    "xl-256k": ([64, 64, 64], [1, 32, 16], [1, 1, 2]),
    "xl-1m": ([64, 64, 256], [1, 32, 16], [1, 1, 2]),
}

# Mirrors faults::router: the default lazy-reachability arena budget and
# the per-entry accounting constants (approximations for budget math,
# not an allocator — same numbers the rust side charges).
DEFAULT_REACH_BUDGET = 256 << 20
MEMO_ENTRY_BYTES = 48
REACH_ENTRY_OVERHEAD = 72


class Spec:
    """``PgftSpec`` mirror: ``PGFT(h; m; w; p)``."""

    def __init__(self, m: list, w: list, p: list) -> None:
        assert len(m) == len(w) == len(p)
        self.h = len(m)
        self.m = list(m)
        self.w = list(w)
        self.p = list(p)

    @property
    def num_nodes(self) -> int:
        out = 1
        for x in self.m:
            out *= x
        return out

    def w_prefix(self, l: int) -> int:
        out = 1
        for x in self.w[:l]:
            out *= x
        return out

    def minimal_hops(self, src: int, dst: int) -> int:
        """Mirror of ``PgftSpec::minimal_hops``."""
        if src == dst:
            return 0
        a, b = src, dst
        for l, m in enumerate(self.m):
            a //= m
            b //= m
            if a == b:
                return 2 * (l + 1)
        return 2 * self.h


def named_spec(name: str) -> Spec:
    m, w, p = NAMED_SPECS[name]
    return Spec(m, w, p)


class Topo:
    """Parameterized mirror of ``topology::build::build_pgft``.

    Same switch/port/link id assignment as the golden mirror; peers are
    encoded as ints (``peer < n`` = node id, else ``peer - n`` = switch
    id) so tracing at 256k endpoints does not chase tuples.
    """

    def __init__(self, spec: Spec) -> None:
        self.spec = spec
        h, m, w, p = spec.h, spec.m, spec.w, spec.p
        n = spec.num_nodes
        self.num_nodes = n

        self.sw_level: list = []
        self.sw_top: list = []
        self.sw_bottom: list = []
        self.sw_up: list = []
        self.sw_down: list = []
        self.level_start = []
        for l in range(1, h + 1):
            self.level_start.append(len(self.sw_level))
            above = 1
            for x in m[l:]:
                above *= x
            below = spec.w_prefix(l)
            for within in range(above * below):
                x = within
                bottom = []
                for j in range(l):
                    bottom.append(x % w[j])
                    x //= w[j]
                top = []
                for j in range(h - l):
                    top.append(x % m[l + j])
                    x //= m[l + j]
                assert x == 0
                self.sw_level.append(l)
                self.sw_top.append(top)
                self.sw_bottom.append(bottom)
                self.sw_up.append([None] * self.up_ports_at(l))
                self.sw_down.append([None] * self.down_ports_at(l))
        self.level_start.append(len(self.sw_level))
        self.num_switches = len(self.sw_level)

        self.node_up = [[None] * self.up_ports_at(0) for _ in range(n)]

        # ports: peer (int-encoded), up?, link, index-on-owner
        self.port_peer: list = []
        self.port_up: list = []
        self.port_link: list = []
        self.port_index: list = []
        self.link_up: list = []
        self.link_stage: list = []

        # stage 1: nodes to leaves
        for nid in range(n):
            digits = self._digits(nid)
            child_idx = digits[0]
            for c in range(w[0]):
                leaf = self.switch_at(1, digits[1:], [c])
                for j in range(p[0]):
                    up_idx = c + w[0] * j
                    down_idx = child_idx * p[0] + j
                    self._add_link(nid, True, up_idx, leaf, down_idx, 1)

        # stages 2..h
        for l in range(1, h):
            for sid in range(self.level_start[l - 1], self.level_start[l]):
                top = self.sw_top[sid]
                bottom = self.sw_bottom[sid]
                child_idx = top[0]
                for c in range(w[l]):
                    parent = self.switch_at(l + 1, top[1:], bottom + [c])
                    for j in range(p[l]):
                        up_idx = c + w[l] * j
                        down_idx = child_idx * p[l] + j
                        self._add_link(sid, False, up_idx, parent, down_idx, l + 1)

        self.num_ports = len(self.port_peer)
        self.num_links = len(self.link_up)

    def _digits(self, nid: int) -> list:
        d = []
        x = nid
        for l in range(self.spec.h):
            d.append(x % self.spec.m[l])
            x //= self.spec.m[l]
        return d

    def up_ports_at(self, l: int) -> int:
        s = self.spec
        return 0 if l >= s.h else s.w[l] * s.p[l]

    def down_ports_at(self, l: int) -> int:
        s = self.spec
        return s.m[l - 1] * s.p[l - 1]

    def switch_at(self, level: int, top: list, bottom: list) -> int:
        s = self.spec
        bot = 0
        for j in range(level - 1, -1, -1):
            bot = bot * s.w[j] + bottom[j]
        topv = 0
        for j in range(s.h - level - 1, -1, -1):
            topv = topv * s.m[level + j] + top[j]
        within = topv * s.w_prefix(level) + bot
        return self.level_start[level - 1] + within

    def _add_link(self, lower, lower_is_node, up_idx, upper_sw, down_idx, stage):
        n = self.num_nodes
        link_id = len(self.link_up)
        up_port = len(self.port_peer)
        self.port_peer += [n + upper_sw, lower if lower_is_node else n + lower]
        self.port_up += [True, False]
        self.port_link += [link_id, link_id]
        self.port_index += [up_idx, down_idx]
        self.link_up.append(up_port)
        self.link_stage.append(stage)
        if lower_is_node:
            self.node_up[lower][up_idx] = up_port
        else:
            self.sw_up[lower][up_idx] = up_port
        self.sw_down[upper_sw][down_idx] = up_port + 1

    def is_ancestor(self, sw: int, nid: int) -> bool:
        level = self.sw_level[sw]
        d = self._digits(nid)
        return all(d[level + j] == t for j, t in enumerate(self.sw_top[sw]))

    def child_index_toward(self, sw: int, nid: int) -> int:
        return self._digits(nid)[self.sw_level[sw] - 1]

    def down_port_toward(self, sw: int, nid: int, j: int) -> int:
        p_l = self.spec.p[self.sw_level[sw] - 1]
        c = self.child_index_toward(sw, nid)
        return self.sw_down[sw][c * p_l + j]

    def eligible_links(self) -> list:
        """Fault-eligible links (stage >= 2), in id order."""
        return [l for l in range(self.num_links) if self.link_stage[l] >= 2]


class _Fn:
    """List-shaped view over a closed-form accessor, so the implicit
    topology can stand in wherever ``Topo``'s flat lists are indexed."""

    def __init__(self, fn) -> None:
        self._fn = fn

    def __getitem__(self, i: int):
        return self._fn(i)


class ImplicitTopo:
    """Mirror of ``topology::view::ImplicitTopology``: every ``Topo``
    query answered arithmetically from the spec — O(h) resident state,
    no port/link tables.  Port ids are the same as ``Topo``'s by
    construction (``up = 2·link``, ``down = 2·link + 1``, links in the
    nested cabling order of ``build_pgft``), which is the contract
    ``rust/src/topology/view.rs`` pins exhaustively and the xl-1m
    golden cross-check rides on.
    """

    def __init__(self, spec: Spec) -> None:
        self.spec = spec
        h = spec.h
        self.mprod = [1] * (h + 1)
        for l in range(h):
            self.mprod[l + 1] = self.mprod[l] * spec.m[l]
        self.wpref = [spec.w_prefix(l) for l in range(h + 1)]
        self.num_nodes = spec.num_nodes
        self.level_start = []
        acc = 0
        for l in range(1, h + 1):
            self.level_start.append(acc)
            acc += (self.num_nodes // self.mprod[l]) * self.wpref[l]
        self.level_start.append(acc)
        self.num_switches = acc
        self.stage_first = []
        lacc = 0
        for s in range(h):
            self.stage_first.append(lacc)
            lower = (
                self.num_nodes
                if s == 0
                else (self.num_nodes // self.mprod[s]) * self.wpref[s]
            )
            lacc += lower * spec.w[s] * spec.p[s]
        self.stage_first.append(lacc)
        self.num_links = lacc
        self.num_ports = 2 * lacc
        # The list-shaped faces Topo consumers index into.
        self.port_peer = _Fn(self._port_peer)
        self.port_link = _Fn(lambda p: p >> 1)
        self.port_up = _Fn(lambda p: p & 1 == 0)
        self.port_index = _Fn(self._port_index)
        self.link_stage = _Fn(lambda l: self._locate_link(l)[0] + 1)
        self.sw_level = _Fn(lambda sw: self._locate(sw)[0])
        self.node_up = _Fn(self._node_up_ports)
        self.sw_up = _Fn(self._sw_up_ports)

    # -- shared digit/placement arithmetic (same forms as Topo) --------

    def _digits(self, nid: int) -> list:
        d = []
        x = nid
        for l in range(self.spec.h):
            d.append(x % self.spec.m[l])
            x //= self.spec.m[l]
        return d

    def up_ports_at(self, l: int) -> int:
        s = self.spec
        return 0 if l >= s.h else s.w[l] * s.p[l]

    def down_ports_at(self, l: int) -> int:
        s = self.spec
        return s.m[l - 1] * s.p[l - 1]

    def switch_at(self, level: int, top: list, bottom: list) -> int:
        s = self.spec
        bot = 0
        for j in range(level - 1, -1, -1):
            bot = bot * s.w[j] + bottom[j]
        topv = 0
        for j in range(s.h - level - 1, -1, -1):
            topv = topv * s.m[level + j] + top[j]
        within = topv * self.wpref[level] + bot
        return self.level_start[level - 1] + within

    # -- closed-form locate + accessors (mirror of view.rs) ------------

    def _locate(self, sw: int):
        for l in range(1, self.spec.h + 1):
            if sw < self.level_start[l]:
                return l, sw - self.level_start[l - 1]
        raise IndexError(f"switch id {sw} out of range")

    def _locate_link(self, link: int):
        for s in range(self.spec.h - 1, -1, -1):
            if link >= self.stage_first[s]:
                return s, link - self.stage_first[s]
        raise IndexError(f"link id {link} out of range")

    def _node_up_ports(self, nid: int) -> list:
        w, p = self.spec.w[0], self.spec.p[0]
        out = []
        for idx in range(w * p):
            c, j = idx % w, idx // w
            out.append(2 * (nid * w * p + c * p + j))
        return out

    def _sw_up_ports(self, sw: int) -> list:
        l, within = self._locate(sw)
        if l == self.spec.h:  # top-level switches have no up-ports
            return []
        w, p = self.spec.w[l], self.spec.p[l]
        out = []
        for idx in range(w * p):
            c, j = idx % w, idx // w
            out.append(2 * (self.stage_first[l] + within * w * p + c * p + j))
        return out

    def _port_peer(self, port: int) -> int:
        s, off = self._locate_link(port >> 1)
        w, par = self.spec.w[s], self.spec.p[s]
        lower = off // (w * par)
        c = (off % (w * par)) // par
        if port & 1:  # down-port: the peer is the lower element
            if s == 0:
                return lower
            return self.num_nodes + self.level_start[s - 1] + lower
        # Up-port: the level-(s+1) parent. A node is "all top digits".
        if s == 0:
            topv, bot = lower, 0
        else:
            topv, bot = lower // self.wpref[s], lower % self.wpref[s]
        within = (topv // self.spec.m[s]) * self.wpref[s + 1] + self.wpref[s] * c + bot
        return self.num_nodes + self.level_start[s] + within

    def _port_index(self, port: int) -> int:
        s, off = self._locate_link(port >> 1)
        w, par = self.spec.w[s], self.spec.p[s]
        lower = off // (w * par)
        rem = off % (w * par)
        c, j = rem // par, rem % par
        if port & 1 == 0:
            return c + w * j
        a = lower % self.spec.m[0] if s == 0 else (lower // self.wpref[s]) % self.spec.m[s]
        return a * par + j

    def is_ancestor(self, sw: int, nid: int) -> bool:
        l, within = self._locate(sw)
        return within // self.wpref[l] == nid // self.mprod[l]

    def child_index_toward(self, sw: int, nid: int) -> int:
        l, _ = self._locate(sw)
        return (nid // self.mprod[l - 1]) % self.spec.m[l - 1]

    def down_port_toward(self, sw: int, nid: int, j: int) -> int:
        l, within = self._locate(sw)
        par = self.spec.p[l - 1]
        if l == 1:
            plane = within % self.wpref[1]
            link = nid * self.wpref[1] * par + plane * par + j
        else:
            bot = within % self.wpref[l]
            topv = within // self.wpref[l]
            plane = bot // self.wpref[l - 1]
            child_bot = bot % self.wpref[l - 1]
            a = (nid // self.mprod[l - 1]) % self.spec.m[l - 1]
            child_within = (topv * self.spec.m[l - 1] + a) * self.wpref[l - 1] + child_bot
            link = (
                self.stage_first[l - 1]
                + child_within * self.spec.w[l - 1] * par
                + plane * par
                + j
            )
        return 2 * link + 1

    def eligible_links(self) -> range:
        """Fault-eligible links (stage >= 2): a contiguous id range —
        the property ``FaultModel::generate_view`` relies on."""
        return range(self.stage_first[1], self.num_links)


# ---------------------------------------------------------------------------
# routing — Xmodk closed forms + trace (parameterized golden mirror)
# ---------------------------------------------------------------------------


class XmodkRouter:
    """Dmodk (``key = dst``) or Gdmodk (``key = gnid[dst]``)."""

    def __init__(self, topo: Topo, gnid=None) -> None:
        self.topo = topo
        self.gnid = gnid

    def key(self, src: int, dst: int) -> int:
        return self.gnid[dst] if self.gnid is not None else dst

    def _up_index(self, level: int, key: int) -> int:
        s = self.topo.spec
        k = s.w[level] * s.p[level]
        return (key // s.w_prefix(level)) % k

    def inject_port(self, src: int, dst: int) -> int:
        return self.topo.node_up[src][self._up_index(0, self.key(src, dst))]

    def up_port(self, sw: int, src: int, dst: int) -> int:
        level = self.topo.sw_level[sw]
        return self.topo.sw_up[sw][self._up_index(level, self.key(src, dst))]

    def down_link(self, sw: int, src: int, dst: int) -> int:
        s = self.topo.spec
        level = self.topo.sw_level[sw]
        return (self.key(src, dst) // s.w_prefix(level)) % s.p[level - 1]

    def descend_at(self, sw: int, dst: int) -> bool:
        return self.topo.is_ancestor(sw, dst)


def trace_route(topo: Topo, router, src: int, dst: int) -> list:
    """Mirror of ``routing::trace::trace_route_into``."""
    if src == dst:
        return []
    n = topo.num_nodes
    ports = [router.inject_port(src, dst)]
    cur = topo.port_peer[ports[0]]
    while True:
        if cur < n:
            assert cur == dst, f"route ended at node {cur}, wanted {dst}"
            return ports
        sw = cur - n
        if router.descend_at(sw, dst):
            j = router.down_link(sw, src, dst)
            out = topo.down_port_toward(sw, dst, j)
        else:
            out = router.up_port(sw, src, dst)
        ports.append(out)
        cur = topo.port_peer[out]
        assert len(ports) <= 2 * topo.spec.h + 1, "route too long: loop?"


# ---------------------------------------------------------------------------
# faults — links:K expansion + the lazy degraded router
# ---------------------------------------------------------------------------


def generate_link_faults(topo: Topo, count: int, seed: int) -> list:
    """Mirror of ``FaultModel::generate`` for ``links:K``."""
    rng = Xoshiro256(seed ^ FAULT_SEED_XOR)
    eligible = topo.eligible_links()
    k = min(count, len(eligible))
    idx = rng.sample_indices(max(len(eligible), 1), k)
    rng.shuffle(idx)
    return [eligible[i] for i in idx]


class LazyDegradedRouter:
    """Same routing decisions as the golden mirror's ``DegradedRouter``,
    with per-destination reachability memoized on demand.

    ``descend`` is only ever true on ancestors of ``dst`` (a sparse set:
    ``sum_l w_prefix(l)`` switches), so it is stored per destination as
    a dict over those ancestors.  Switch goodness recurses upward
    (``good(sw) = descend[sw] or any alive up-port with a good
    parent``) and memoizes per (dst, switch) — only the switches a
    trace actually inspects are ever evaluated, which is what makes
    repair tractable at 64k endpoints where the golden mirror's dense
    per-dst tables would be ~70 GiB.
    """

    def __init__(self, topo: Topo, dead: set, base, budget: int = 0) -> None:
        self.topo = topo
        self.dead = dead
        self.base = base
        self._descend: dict = {}  # dst -> {ancestor_sw: bool}
        self._good: dict = {}  # dst -> {sw: bool}
        # Mirror of faults::router::LazyReach budget accounting: an
        # entry costs its packed descend bits plus a fixed overhead,
        # each memoized good verdict MEMO_ENTRY_BYTES; exceeding the
        # budget flushes the whole arena (deterministic O(1) amortized
        # eviction — DESIGN.md §12). budget=0 keeps the memos unbounded
        # (the pre-existing behavior the mirror tests pin).
        self.budget = budget
        total_bits = sum(topo.spec.w_prefix(l) for l in range(1, topo.spec.h + 1))
        self._entry_bytes = ((total_bits + 63) // 64) * 8 + REACH_ENTRY_OVERHEAD
        self.stats = {
            "computed": 0, "hits": 0, "evictions": 0,
            "resident_bytes": 0, "peak_bytes": 0,
        }

    def _alive(self, port: int) -> bool:
        return self.topo.port_link[port] not in self.dead

    def _charge(self, cost: int) -> None:
        st = self.stats
        st["resident_bytes"] += cost
        st["peak_bytes"] = max(st["peak_bytes"], st["resident_bytes"])

    def _descend_map(self, dst: int) -> dict:
        d = self._descend.get(dst)
        if d is not None:
            self.stats["hits"] += 1
            return d
        if (
            self.budget
            and self._descend
            and self.stats["resident_bytes"] + self._entry_bytes > self.budget
        ):
            self.stats["evictions"] += len(self._descend)
            self._descend.clear()
            self._good.clear()
            self.stats["resident_bytes"] = 0
        topo, spec = self.topo, self.topo.spec
        d = {}
        digits = topo._digits(dst)
        # Level by level, bottom up (mirror of DegradedTopology::reach):
        # an ancestor can descend iff one of its parallel links toward
        # dst reaches the node (level 1) or a descending child ancestor.
        for l in range(1, spec.h + 1):
            top = digits[l:]
            wl = spec.w_prefix(l)
            bottom = [0] * l
            for _ in range(wl):
                sw = topo.switch_at(l, top, bottom)
                ok = False
                for j in range(spec.p[l - 1]):
                    port = topo.down_port_toward(sw, dst, j)
                    if not self._alive(port):
                        continue
                    peer = topo.port_peer[port]
                    if peer < topo.num_nodes:
                        if peer == dst:
                            ok = True
                            break
                    elif d.get(peer - topo.num_nodes, False):
                        ok = True
                        break
                d[sw] = ok
                for j in range(l):
                    bottom[j] += 1
                    if bottom[j] < spec.w[j]:
                        break
                    bottom[j] = 0
        self.stats["computed"] += 1
        self._charge(self._entry_bytes)
        return self._descend.setdefault(dst, d)

    def _switch_good(self, sw: int, dst: int) -> bool:
        memo = self._good.setdefault(dst, {})
        g = memo.get(sw)
        if g is not None:
            return g
        descend = self._descend_map(dst)
        if descend.get(sw, False):
            memo[sw] = True
            self._charge(MEMO_ENTRY_BYTES)
            return True
        memo[sw] = False  # cycle guard; up-recursion is acyclic anyway
        topo = self.topo
        g = False
        for p in self.topo.sw_up[sw]:
            if self._alive(p):
                peer = topo.port_peer[p]
                if peer >= topo.num_nodes and self._switch_good(peer - topo.num_nodes, dst):
                    g = True
                    break
        memo[sw] = g
        self._charge(MEMO_ENTRY_BYTES)
        return g

    def _up_viable(self, port: int, dst: int) -> bool:
        if not self._alive(port):
            return False
        peer = self.topo.port_peer[port]
        return peer >= self.topo.num_nodes and self._switch_good(
            peer - self.topo.num_nodes, dst
        )

    def _pick_up(self, ports: list, preferred: int, dst: int) -> int:
        start = self.topo.port_index[preferred]
        assert ports[start] == preferred
        for i in range(len(ports)):
            port = ports[(start + i) % len(ports)]
            if self._up_viable(port, dst):
                return port
        raise RuntimeError("no viable up-port: fabric partitioned toward dst")

    def inject_port(self, src: int, dst: int) -> int:
        preferred = self.base.inject_port(src, dst)
        return self._pick_up(self.topo.node_up[src], preferred, dst)

    def up_port(self, sw: int, src: int, dst: int) -> int:
        preferred = self.base.up_port(sw, src, dst)
        return self._pick_up(self.topo.sw_up[sw], preferred, dst)

    def down_link(self, sw: int, src: int, dst: int) -> int:
        p_l = self.topo.spec.p[self.topo.sw_level[sw] - 1]
        preferred = self.base.down_link(sw, src, dst) % p_l
        for i in range(p_l):
            j = (preferred + i) % p_l
            if self._alive(self.topo.down_port_toward(sw, dst, j)):
                return j
        raise RuntimeError("descend_at guaranteed an alive parallel link")

    def descend_at(self, sw: int, dst: int) -> bool:
        return self._descend_map(dst).get(sw, False)


# ---------------------------------------------------------------------------
# eval — sampled pairs, dirty flows, FlowSet byte accounting
# ---------------------------------------------------------------------------


def sample_pairs(num_nodes: int, dsts_per_node: int, seed: int) -> list:
    """Mirror of ``eval::ladder::sample_pairs`` (same RNG stream)."""
    assert num_nodes >= 2
    rng = Xoshiro256(seed ^ PAIR_SEED_XOR)
    out = []
    for src in range(num_nodes):
        for _ in range(dsts_per_node):
            dst = rng.next_below(num_nodes - 1)
            if dst >= src:
                dst += 1
            out.append((src, dst))
    return out


def dirty_flows(routes: list, topo: Topo, dead: set) -> list:
    """Mirror of ``FlowSet::dirty_flows``: indices of flows whose
    pristine route crosses a dead link (empty fault set short-circuits).
    """
    if not dead:
        return []
    link = topo.port_link
    return [
        f for f, ports in enumerate(routes) if any(link[p] in dead for p in ports)
    ]


def arena_bytes(num_flows: int, total_hops: int) -> int:
    """Mirror of ``FlowSet::arena_bytes``: pairs (2×u32) + weights (u32)
    + CSR offsets (u32, flows+1) + port arena (u32 per hop)."""
    return 8 * num_flows + 4 * num_flows + 4 * (num_flows + 1) + 4 * total_hops


# ---------------------------------------------------------------------------
# metrics — the blocked and striped congestion kernels
# ---------------------------------------------------------------------------

# Mirrors metrics::STRIPE: node-id block width = STRIPE × 64.
KERNEL_STRIPE = 4


def _port_loads(flows, routes, num_ports, words_per_port):
    """One structural mirror serves both kernels: sweep the node-id
    space in ``words_per_port × 64``-id blocks, keep one bitmap stripe
    per *touched* port (epoch stamps make the reset cheap), popcount on
    block exit.  ``words_per_port=1`` is the blocked single-word kernel,
    ``KERNEL_STRIPE`` the striped one (``metrics::BitmapAccum``).

    Returns ``(src_counts, dst_counts)`` — per-port distinct sources /
    destinations, the inputs of ``C_p = min(src, dst)``.
    """
    span = words_per_port * 64
    counts = ([0] * num_ports, [0] * num_ports)
    stamp = [0] * num_ports
    words = [0] * (num_ports * words_per_port)
    epoch = 0
    for which in (0, 1):
        out = counts[which]
        blocks: dict = {}
        for f, (src, dst) in enumerate(flows):
            key = (src, dst)[which]
            blocks.setdefault(key // span, []).append(f)
        for b in sorted(blocks):
            epoch += 1
            touched = []
            base = b * span
            for f in blocks[b]:
                rel = (flows[f][which] - base)
                wi, bit = rel // 64, 1 << (rel % 64)
                for p in routes[f]:
                    if stamp[p] != epoch:
                        stamp[p] = epoch
                        lo = p * words_per_port
                        for k in range(words_per_port):
                            words[lo + k] = 0
                        touched.append(p)
                    words[p * words_per_port + wi] |= bit
            for p in touched:
                lo = p * words_per_port
                out[p] += sum(
                    words[lo + k].bit_count() for k in range(words_per_port)
                )
    return counts


def port_loads_blocked(flows, routes, num_ports):
    """Mirror of ``CongestionReport::compute_flowset_blocked``."""
    return _port_loads(flows, routes, num_ports, 1)


def port_loads_striped(flows, routes, num_ports):
    """Mirror of ``CongestionReport::compute_flowset_stats``'s kernel."""
    return _port_loads(flows, routes, num_ports, KERNEL_STRIPE)


def c_topo(src_counts, dst_counts) -> int:
    """``C_topo = max_p min(src(p), dst(p))`` over switch output ports
    (every port here — node injection ports never carry transit)."""
    return max(
        (min(s, d) for s, d in zip(src_counts, dst_counts)), default=0
    )
