#!/usr/bin/env python3
"""Parameterized PGFT mirror for the eval size ladder.

``gen_faults_golden.py`` is the *golden-pinned* mirror of the paper's
case study — its topology constants are deliberately hard-coded so the
golden CSV can never drift.  This module is the generalization that the
large-fabric work needs: the same id-assignment, routing, fault and
rerouting semantics as the Rust side (``topology::build``,
``routing::xmodk``, ``faults::scenario``, ``faults::router``,
``eval::ladder``), parameterized over any ``PGFT(h; m; w; p)`` spec and
engineered to stay tractable at 16k-256k endpoints in pure Python:

* ports/peers are flat ``array``-friendly int lists (a peer is ``nid``
  for a node or ``num_nodes + sid`` for a switch), not tuples;
* the degraded router is **lazy**: per-destination reachability is
  memoized on first use instead of materialized for every destination
  up front (the dense per-dst tables that are fine at 64 nodes are the
  exact thing DESIGN.md §10 rules out at scale).

The RNG classes are imported from ``gen_faults_golden`` so the two
mirrors can never disagree about the bit streams; the ladder specs and
the sampled-pair generator mirror ``rust/src/eval/ladder.rs`` constant
for constant.  ``python/tests/test_ladder_mirror.py`` cross-checks this
module against the golden mirror on the case study.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from gen_faults_golden import Xoshiro256  # noqa: E402  (shared RNG mirror)

# Mirrors eval::ladder::PAIR_SEED_XOR and faults::scenario's seed domain.
PAIR_SEED_XOR = 0x5A3B_1E0D_C4F2_9786
FAULT_SEED_XOR = 0xFA_0175_CE4A_5105

# Mirrors eval::ladder::LADDER (name, topology, dsts_per_node, fault_links).
LADDER = [
    ("16k", "xl-16k", 4, 320),
    ("64k", "xl-64k", 2, 1280),
    ("256k", "xl-256k", 1, 0),
]

# Mirrors topology::families::named_spec for the specs the ladder needs.
NAMED_SPECS = {
    "case-study": ([8, 4, 2], [1, 2, 1], [1, 1, 4]),
    "medium-512": ([16, 8, 4], [1, 4, 2], [1, 1, 2]),
    "xl-16k": ([32, 32, 16], [1, 16, 8], [1, 1, 2]),
    "xl-64k": ([32, 32, 64], [1, 16, 8], [1, 1, 2]),
    "xl-256k": ([64, 64, 64], [1, 32, 16], [1, 1, 2]),
}


class Spec:
    """``PgftSpec`` mirror: ``PGFT(h; m; w; p)``."""

    def __init__(self, m: list, w: list, p: list) -> None:
        assert len(m) == len(w) == len(p)
        self.h = len(m)
        self.m = list(m)
        self.w = list(w)
        self.p = list(p)

    @property
    def num_nodes(self) -> int:
        out = 1
        for x in self.m:
            out *= x
        return out

    def w_prefix(self, l: int) -> int:
        out = 1
        for x in self.w[:l]:
            out *= x
        return out

    def minimal_hops(self, src: int, dst: int) -> int:
        """Mirror of ``PgftSpec::minimal_hops``."""
        if src == dst:
            return 0
        a, b = src, dst
        for l, m in enumerate(self.m):
            a //= m
            b //= m
            if a == b:
                return 2 * (l + 1)
        return 2 * self.h


def named_spec(name: str) -> Spec:
    m, w, p = NAMED_SPECS[name]
    return Spec(m, w, p)


class Topo:
    """Parameterized mirror of ``topology::build::build_pgft``.

    Same switch/port/link id assignment as the golden mirror; peers are
    encoded as ints (``peer < n`` = node id, else ``peer - n`` = switch
    id) so tracing at 256k endpoints does not chase tuples.
    """

    def __init__(self, spec: Spec) -> None:
        self.spec = spec
        h, m, w, p = spec.h, spec.m, spec.w, spec.p
        n = spec.num_nodes
        self.num_nodes = n

        self.sw_level: list = []
        self.sw_top: list = []
        self.sw_bottom: list = []
        self.sw_up: list = []
        self.sw_down: list = []
        self.level_start = []
        for l in range(1, h + 1):
            self.level_start.append(len(self.sw_level))
            above = 1
            for x in m[l:]:
                above *= x
            below = spec.w_prefix(l)
            for within in range(above * below):
                x = within
                bottom = []
                for j in range(l):
                    bottom.append(x % w[j])
                    x //= w[j]
                top = []
                for j in range(h - l):
                    top.append(x % m[l + j])
                    x //= m[l + j]
                assert x == 0
                self.sw_level.append(l)
                self.sw_top.append(top)
                self.sw_bottom.append(bottom)
                self.sw_up.append([None] * self.up_ports_at(l))
                self.sw_down.append([None] * self.down_ports_at(l))
        self.level_start.append(len(self.sw_level))
        self.num_switches = len(self.sw_level)

        self.node_up = [[None] * self.up_ports_at(0) for _ in range(n)]

        # ports: peer (int-encoded), up?, link, index-on-owner
        self.port_peer: list = []
        self.port_up: list = []
        self.port_link: list = []
        self.port_index: list = []
        self.link_up: list = []
        self.link_stage: list = []

        # stage 1: nodes to leaves
        for nid in range(n):
            digits = self._digits(nid)
            child_idx = digits[0]
            for c in range(w[0]):
                leaf = self.switch_at(1, digits[1:], [c])
                for j in range(p[0]):
                    up_idx = c + w[0] * j
                    down_idx = child_idx * p[0] + j
                    self._add_link(nid, True, up_idx, leaf, down_idx, 1)

        # stages 2..h
        for l in range(1, h):
            for sid in range(self.level_start[l - 1], self.level_start[l]):
                top = self.sw_top[sid]
                bottom = self.sw_bottom[sid]
                child_idx = top[0]
                for c in range(w[l]):
                    parent = self.switch_at(l + 1, top[1:], bottom + [c])
                    for j in range(p[l]):
                        up_idx = c + w[l] * j
                        down_idx = child_idx * p[l] + j
                        self._add_link(sid, False, up_idx, parent, down_idx, l + 1)

        self.num_ports = len(self.port_peer)
        self.num_links = len(self.link_up)

    def _digits(self, nid: int) -> list:
        d = []
        x = nid
        for l in range(self.spec.h):
            d.append(x % self.spec.m[l])
            x //= self.spec.m[l]
        return d

    def up_ports_at(self, l: int) -> int:
        s = self.spec
        return 0 if l >= s.h else s.w[l] * s.p[l]

    def down_ports_at(self, l: int) -> int:
        s = self.spec
        return s.m[l - 1] * s.p[l - 1]

    def switch_at(self, level: int, top: list, bottom: list) -> int:
        s = self.spec
        bot = 0
        for j in range(level - 1, -1, -1):
            bot = bot * s.w[j] + bottom[j]
        topv = 0
        for j in range(s.h - level - 1, -1, -1):
            topv = topv * s.m[level + j] + top[j]
        within = topv * s.w_prefix(level) + bot
        return self.level_start[level - 1] + within

    def _add_link(self, lower, lower_is_node, up_idx, upper_sw, down_idx, stage):
        n = self.num_nodes
        link_id = len(self.link_up)
        up_port = len(self.port_peer)
        self.port_peer += [n + upper_sw, lower if lower_is_node else n + lower]
        self.port_up += [True, False]
        self.port_link += [link_id, link_id]
        self.port_index += [up_idx, down_idx]
        self.link_up.append(up_port)
        self.link_stage.append(stage)
        if lower_is_node:
            self.node_up[lower][up_idx] = up_port
        else:
            self.sw_up[lower][up_idx] = up_port
        self.sw_down[upper_sw][down_idx] = up_port + 1

    def is_ancestor(self, sw: int, nid: int) -> bool:
        level = self.sw_level[sw]
        d = self._digits(nid)
        return all(d[level + j] == t for j, t in enumerate(self.sw_top[sw]))

    def child_index_toward(self, sw: int, nid: int) -> int:
        return self._digits(nid)[self.sw_level[sw] - 1]

    def down_port_toward(self, sw: int, nid: int, j: int) -> int:
        p_l = self.spec.p[self.sw_level[sw] - 1]
        c = self.child_index_toward(sw, nid)
        return self.sw_down[sw][c * p_l + j]

    def eligible_links(self) -> list:
        """Fault-eligible links (stage >= 2), in id order."""
        return [l for l in range(self.num_links) if self.link_stage[l] >= 2]


# ---------------------------------------------------------------------------
# routing — Xmodk closed forms + trace (parameterized golden mirror)
# ---------------------------------------------------------------------------


class XmodkRouter:
    """Dmodk (``key = dst``) or Gdmodk (``key = gnid[dst]``)."""

    def __init__(self, topo: Topo, gnid=None) -> None:
        self.topo = topo
        self.gnid = gnid

    def key(self, src: int, dst: int) -> int:
        return self.gnid[dst] if self.gnid is not None else dst

    def _up_index(self, level: int, key: int) -> int:
        s = self.topo.spec
        k = s.w[level] * s.p[level]
        return (key // s.w_prefix(level)) % k

    def inject_port(self, src: int, dst: int) -> int:
        return self.topo.node_up[src][self._up_index(0, self.key(src, dst))]

    def up_port(self, sw: int, src: int, dst: int) -> int:
        level = self.topo.sw_level[sw]
        return self.topo.sw_up[sw][self._up_index(level, self.key(src, dst))]

    def down_link(self, sw: int, src: int, dst: int) -> int:
        s = self.topo.spec
        level = self.topo.sw_level[sw]
        return (self.key(src, dst) // s.w_prefix(level)) % s.p[level - 1]

    def descend_at(self, sw: int, dst: int) -> bool:
        return self.topo.is_ancestor(sw, dst)


def trace_route(topo: Topo, router, src: int, dst: int) -> list:
    """Mirror of ``routing::trace::trace_route_into``."""
    if src == dst:
        return []
    n = topo.num_nodes
    ports = [router.inject_port(src, dst)]
    cur = topo.port_peer[ports[0]]
    while True:
        if cur < n:
            assert cur == dst, f"route ended at node {cur}, wanted {dst}"
            return ports
        sw = cur - n
        if router.descend_at(sw, dst):
            j = router.down_link(sw, src, dst)
            out = topo.down_port_toward(sw, dst, j)
        else:
            out = router.up_port(sw, src, dst)
        ports.append(out)
        cur = topo.port_peer[out]
        assert len(ports) <= 2 * topo.spec.h + 1, "route too long: loop?"


# ---------------------------------------------------------------------------
# faults — links:K expansion + the lazy degraded router
# ---------------------------------------------------------------------------


def generate_link_faults(topo: Topo, count: int, seed: int) -> list:
    """Mirror of ``FaultModel::generate`` for ``links:K``."""
    rng = Xoshiro256(seed ^ FAULT_SEED_XOR)
    eligible = topo.eligible_links()
    k = min(count, len(eligible))
    idx = rng.sample_indices(max(len(eligible), 1), k)
    rng.shuffle(idx)
    return [eligible[i] for i in idx]


class LazyDegradedRouter:
    """Same routing decisions as the golden mirror's ``DegradedRouter``,
    with per-destination reachability memoized on demand.

    ``descend`` is only ever true on ancestors of ``dst`` (a sparse set:
    ``sum_l w_prefix(l)`` switches), so it is stored per destination as
    a dict over those ancestors.  Switch goodness recurses upward
    (``good(sw) = descend[sw] or any alive up-port with a good
    parent``) and memoizes per (dst, switch) — only the switches a
    trace actually inspects are ever evaluated, which is what makes
    repair tractable at 64k endpoints where the golden mirror's dense
    per-dst tables would be ~70 GiB.
    """

    def __init__(self, topo: Topo, dead: set, base) -> None:
        self.topo = topo
        self.dead = dead
        self.base = base
        self._descend: dict = {}  # dst -> {ancestor_sw: bool}
        self._good: dict = {}  # dst -> {sw: bool}

    def _alive(self, port: int) -> bool:
        return self.topo.port_link[port] not in self.dead

    def _descend_map(self, dst: int) -> dict:
        d = self._descend.get(dst)
        if d is not None:
            return d
        topo, spec = self.topo, self.topo.spec
        d = {}
        digits = topo._digits(dst)
        # Level by level, bottom up (mirror of DegradedTopology::reach):
        # an ancestor can descend iff one of its parallel links toward
        # dst reaches the node (level 1) or a descending child ancestor.
        for l in range(1, spec.h + 1):
            top = digits[l:]
            wl = spec.w_prefix(l)
            bottom = [0] * l
            for _ in range(wl):
                sw = topo.switch_at(l, top, bottom)
                ok = False
                for j in range(spec.p[l - 1]):
                    port = topo.down_port_toward(sw, dst, j)
                    if not self._alive(port):
                        continue
                    peer = topo.port_peer[port]
                    if peer < topo.num_nodes:
                        if peer == dst:
                            ok = True
                            break
                    elif d.get(peer - topo.num_nodes, False):
                        ok = True
                        break
                d[sw] = ok
                for j in range(l):
                    bottom[j] += 1
                    if bottom[j] < spec.w[j]:
                        break
                    bottom[j] = 0
        return self._descend.setdefault(dst, d)

    def _switch_good(self, sw: int, dst: int) -> bool:
        memo = self._good.setdefault(dst, {})
        g = memo.get(sw)
        if g is not None:
            return g
        descend = self._descend_map(dst)
        if descend.get(sw, False):
            memo[sw] = True
            return True
        memo[sw] = False  # cycle guard; up-recursion is acyclic anyway
        topo = self.topo
        g = False
        for p in self.topo.sw_up[sw]:
            if self._alive(p):
                peer = topo.port_peer[p]
                if peer >= topo.num_nodes and self._switch_good(peer - topo.num_nodes, dst):
                    g = True
                    break
        memo[sw] = g
        return g

    def _up_viable(self, port: int, dst: int) -> bool:
        if not self._alive(port):
            return False
        peer = self.topo.port_peer[port]
        return peer >= self.topo.num_nodes and self._switch_good(
            peer - self.topo.num_nodes, dst
        )

    def _pick_up(self, ports: list, preferred: int, dst: int) -> int:
        start = self.topo.port_index[preferred]
        assert ports[start] == preferred
        for i in range(len(ports)):
            port = ports[(start + i) % len(ports)]
            if self._up_viable(port, dst):
                return port
        raise RuntimeError("no viable up-port: fabric partitioned toward dst")

    def inject_port(self, src: int, dst: int) -> int:
        preferred = self.base.inject_port(src, dst)
        return self._pick_up(self.topo.node_up[src], preferred, dst)

    def up_port(self, sw: int, src: int, dst: int) -> int:
        preferred = self.base.up_port(sw, src, dst)
        return self._pick_up(self.topo.sw_up[sw], preferred, dst)

    def down_link(self, sw: int, src: int, dst: int) -> int:
        p_l = self.topo.spec.p[self.topo.sw_level[sw] - 1]
        preferred = self.base.down_link(sw, src, dst) % p_l
        for i in range(p_l):
            j = (preferred + i) % p_l
            if self._alive(self.topo.down_port_toward(sw, dst, j)):
                return j
        raise RuntimeError("descend_at guaranteed an alive parallel link")

    def descend_at(self, sw: int, dst: int) -> bool:
        return self._descend_map(dst).get(sw, False)


# ---------------------------------------------------------------------------
# eval — sampled pairs, dirty flows, FlowSet byte accounting
# ---------------------------------------------------------------------------


def sample_pairs(num_nodes: int, dsts_per_node: int, seed: int) -> list:
    """Mirror of ``eval::ladder::sample_pairs`` (same RNG stream)."""
    assert num_nodes >= 2
    rng = Xoshiro256(seed ^ PAIR_SEED_XOR)
    out = []
    for src in range(num_nodes):
        for _ in range(dsts_per_node):
            dst = rng.next_below(num_nodes - 1)
            if dst >= src:
                dst += 1
            out.append((src, dst))
    return out


def dirty_flows(routes: list, topo: Topo, dead: set) -> list:
    """Mirror of ``FlowSet::dirty_flows``: indices of flows whose
    pristine route crosses a dead link (empty fault set short-circuits).
    """
    if not dead:
        return []
    link = topo.port_link
    return [
        f for f, ports in enumerate(routes) if any(link[p] in dead for p in ports)
    ]


def arena_bytes(num_flows: int, total_hops: int) -> int:
    """Mirror of ``FlowSet::arena_bytes``: pairs (2×u32) + weights (u32)
    + CSR offsets (u32, flows+1) + port arena (u32 per hop)."""
    return 8 * num_flows + 4 * num_flows + 4 * (num_flows + 1) + 4 * total_hops
