#!/usr/bin/env python3
"""Warn-only bench-regression guard for the CI eval-bench smoke.

Compares the ladder throughputs of a freshly measured
``BENCH_eval.ci.json`` (written by ``PGFT_BENCH_SMOKE=1
PGFT_BENCH_EVAL_OUT=... cargo bench --bench bench_eval``) against the
committed ``BENCH_eval.json`` reference.  Ladder entries are matched by
``(rung, mode)`` and their ``flows_per_sec`` compared; a drop beyond
the threshold prints a GitHub Actions ``::warning::`` annotation.

CI runners are noisy, shared and unlike the machine that produced the
committed reference, so this guard NEVER fails the build — it always
exits 0.  It exists to put a visible marker on pull requests whose
trace/retrace throughput cratered, not to gate them.

Usage::

    python3 python/tools/bench_guard.py BENCH_eval.ci.json BENCH_eval.json
"""

from __future__ import annotations

import argparse
import json
import sys

# Fractional flows_per_sec drop (vs the committed reference) that
# triggers a warning annotation. Generous: CI boxes are slow and noisy.
DROP_THRESHOLD = 0.30


def ladder_map(doc: dict) -> dict:
    """``(rung, mode) -> flows_per_sec`` for every ladder entry."""
    out = {}
    for entry in doc.get("ladder", []):
        key = (entry.get("rung"), entry.get("mode"))
        fps = entry.get("flows_per_sec")
        if key[0] is not None and isinstance(fps, (int, float)):
            out[key] = float(fps)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("measured", help="BENCH_eval.ci.json from the CI bench smoke")
    ap.add_argument("reference", help="committed BENCH_eval.json reference")
    args = ap.parse_args(argv)
    try:
        with open(args.measured, encoding="utf-8") as f:
            measured = ladder_map(json.load(f))
        with open(args.reference, encoding="utf-8") as f:
            reference = ladder_map(json.load(f))
    except (OSError, ValueError) as e:
        print(f"::warning::bench_guard: could not read inputs: {e}")
        return 0
    if not measured or not reference:
        print("::warning::bench_guard: no comparable ladder entries found")
        return 0
    compared = warned = 0
    for key, ref_fps in sorted(reference.items()):
        if key not in measured or ref_fps <= 0:
            continue
        compared += 1
        got = measured[key]
        drop = (ref_fps - got) / ref_fps
        rung, mode = key
        if drop > DROP_THRESHOLD:
            warned += 1
            print(
                f"::warning::bench_guard: ladder {rung}/{mode} throughput "
                f"{got:.0f} flows/s is {drop:.0%} below the committed "
                f"reference {ref_fps:.0f} flows/s"
            )
        else:
            sys.stderr.write(
                f"bench_guard: {rung}/{mode} {got:.0f} flows/s "
                f"(reference {ref_fps:.0f}, {'+' if drop < 0 else '-'}{abs(drop):.0%})\n"
            )
    sys.stderr.write(
        f"bench_guard: {compared} ladder entr{'y' if compared == 1 else 'ies'} "
        f"compared, {warned} warning(s) — informational only, always exit 0\n"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
