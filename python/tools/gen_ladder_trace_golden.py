#!/usr/bin/env python3
"""Generate `rust/tests/golden/ladder_trace_1m.csv`: dmodk routes for a
deterministic sample of xl-1m (1,048,576-endpoint) flows, traced through
the Python `ImplicitTopo` mirror.

The rust side (`tests/implicit_ladder_golden.rs`) traces the *same*
flows through `topology::view::ImplicitTopology` and compares byte for
byte — a cross-language pin of the closed-form port arithmetic at the
top of the size ladder, where no materialized table exists to diff
against.

Flow subset: `sample_pairs(n, 1, 1)` (the exact xl-1m ladder sample,
seed 1) strided by 8192 → 128 flows spanning the whole source space.

Row format: `src,dst,port;port;...;port` (global port ids in hop order).

Usage: python3 python/tools/gen_ladder_trace_golden.py
"""

from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import pgft_ladder as lad  # noqa: E402

STRIDE = 8192


def main() -> int:
    topo = lad.ImplicitTopo(lad.named_spec("xl-1m"))
    router = lad.XmodkRouter(topo)  # dmodk: key = dst
    flows = lad.sample_pairs(topo.num_nodes, 1, 1)[::STRIDE]
    lines = []
    for src, dst in flows:
        route = lad.trace_route(topo, router, src, dst)
        lines.append(f"{src},{dst}," + ";".join(str(p) for p in route))
    out = (
        pathlib.Path(__file__).resolve().parents[2]
        / "rust" / "tests" / "golden" / "ladder_trace_1m.csv"
    )
    out.write_text("\n".join(lines) + "\n")
    print(f"wrote {out} ({len(lines)} flows)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
