//! Quickstart: build the paper's case-study PGFT, place IO nodes, route
//! it five ways, and print the congestion analysis.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use pgft::metrics::{render_algorithm_table, AlgoSummary};
use pgft::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. The topology: PGFT(3; 8,4,2; 1,2,1; 1,1,4) — 64 nodes, 8 leaves,
    //    slimmed top (nonfull CBB), quadrupled L2→top links.
    let topo = build_pgft(&PgftSpec::case_study());
    pgft::topology::validate::validate(&topo)?;
    println!("{}", pgft::topology::render::render_summary(&topo, None));

    // 2. Heterogeneity: one IO node on the last port of every leaf
    //    (IO NIDs ≡ 7 mod 8, exactly Fig. 1).
    let types = Placement::paper_io().apply(&topo)?;
    println!("node types: {}", types.census());

    // 3. The pattern: data collection, compute → IO of the symmetric leaf.
    let pattern = Pattern::C2ioSym;
    let flows = pattern.flows(&topo, &types)?;
    println!("pattern {}: {} flows, all crossing the top level\n", pattern.name(), flows.len());

    // 4. Route it with every algorithm and compare the static congestion
    //    metric C_topo = max_p min(src(p), dst(p)).
    let mut rows = Vec::new();
    for kind in AlgorithmKind::ALL {
        rows.push(AlgoSummary::compute(&topo, &types, kind, &pattern, 42)?);
    }
    print!("{}", render_algorithm_table(&rows));

    // 5. The paper's takeaway, as assertions.
    let c = |name: &str| rows.iter().find(|r| r.algorithm == name).unwrap().c_topo;
    assert_eq!(c("dmodk"), 4, "§III.B");
    assert_eq!(c("smodk"), 4, "§III.C");
    assert_eq!(c("gdmodk"), 1, "§IV: grouped routing reaches the optimum");
    println!("\nGdmodk turns C_topo {} (Dmodk) into {} — congestion removed.", c("dmodk"), c("gdmodk"));

    // 6. The same scoring through the unified eval layer: trace once
    //    into an arena-backed FlowSet, then run any evaluator stack
    //    over the shared store (this is how sweep cells work inside).
    let router = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 42);
    let set = FlowSet::trace(&topo, &*router, &flows);
    let cells = pgft::eval::evaluate_all(
        &pgft::eval::parse_evaluators("congestion,fairrate")?,
        &topo,
        &set,
        42,
    );
    assert_eq!(cells.congestion.unwrap().c_topo(), 1);
    let fair = cells.fairrate.unwrap();
    println!(
        "eval layer: {} flows, {} hops in one arena, fair-rate aggregate {:.2}",
        set.len(),
        set.total_hops(),
        fair.aggregate_throughput
    );
    Ok(())
}
