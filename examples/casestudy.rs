//! The full paper walk-through: reproduces every number of §III and §IV
//! on the case study — the headline table through the parallel sweep
//! engine, plus the per-port views behind Figures 4-7.
//!
//! ```sh
//! cargo run --release --example casestudy
//! ```

use pgft::metrics::CongestionReport;
use pgft::prelude::*;

fn report(
    topo: &Topology,
    types: &NodeTypeMap,
    kind: AlgorithmKind,
    pat: &Pattern,
) -> CongestionReport {
    let router = kind.build(topo, Some(types), 1);
    let flows = pat.flows(topo, types).unwrap();
    let routes = trace_flows(topo, &*router, &flows);
    CongestionReport::compute(topo, &routes)
}

fn show_top_ports(topo: &Topology, rep: &CongestionReport, label: &str) {
    println!("  {label}: top-level down-ports (routes/srcs/dsts → C_p):");
    for sw in topo.level_switches(topo.spec.h) {
        let cells: Vec<String> = topo.switches[sw]
            .down_ports
            .iter()
            .map(|&p| {
                let s = rep.per_port[p];
                let rank = topo.ports[p].index + 1;
                format!("{}:{}/{}/{}→{}", rank, s.routes, s.srcs, s.dsts, s.c())
            })
            .collect();
        println!("    {} [{}]", topo.switch_label(sw), cells.join(" "));
    }
}

fn main() -> anyhow::Result<()> {
    let topo = build_pgft(&PgftSpec::case_study());
    let types = Placement::paper_io().apply(&topo)?;

    println!("== Fig 1: the case-study topology ==");
    print!("{}", pgft::topology::render::render_summary(&topo, Some(&types)));
    print!("{}", pgft::topology::render::render_leaves(&topo, &types));

    // The §III/§IV comparison table is one declarative sweep: every
    // algorithm on both C2IO readings, fanned out in parallel.
    println!("\n== §III-§IV congestion table (sweep engine) ==");
    let spec = SweepSpec {
        topologies: vec!["case-study".into()],
        placements: vec!["io:last:1".into()],
        patterns: vec![Pattern::C2ioSym, Pattern::C2ioAll],
        algorithms: AlgorithmKind::ALL.to_vec(),
        faults: vec!["none".into()],
        seeds: vec![1],
        simulate: false,
        netsim: Vec::new(),
        workloads: Vec::new(),
    };
    let rows = run_sweep(&spec, &SweepOptions::default())?;
    print!("{}", pgft::metrics::render_algorithm_table(&pgft::sweep::summaries(&rows)));
    let cell = |algo: &str, pat: &str| {
        rows.iter()
            .find(|r| r.summary.algorithm == algo && r.summary.pattern == pat)
            .unwrap()
            .summary
            .c_topo
    };
    assert_eq!(cell("dmodk", "c2io-sym"), 4, "§III.B");
    assert_eq!(cell("smodk", "c2io-sym"), 4, "§III.C");
    assert_eq!(cell("gdmodk", "c2io-all"), 2, "§IV.B.1");
    assert_eq!(cell("gdmodk", "c2io-sym"), 1, "§IV optimum");
    assert_eq!(cell("gsmodk", "c2io-sym"), 4, "§IV.B.2");

    println!("\n== §III.B / Fig 4: Dmodk ==");
    let dmodk = report(&topo, &types, AlgorithmKind::Dmodk, &Pattern::C2ioSym);
    show_top_ports(&topo, &dmodk, "C2IO(Dmodk)");
    println!("  C_topo = {} (paper: 4); hot top-ports: {} (paper: the two last ports of (2,0,1))",
        dmodk.c_topo(), dmodk.hot_ports_at(&topo, 3, false).len());
    assert_eq!(dmodk.c_topo(), 4);

    println!("\n== §III.C / Fig 5: Smodk ==");
    let smodk = report(&topo, &types, AlgorithmKind::Smodk, &Pattern::C2ioSym);
    show_top_ports(&topo, &smodk, "C2IO(Smodk)");
    println!("  C_topo = {} (paper: 4); used top-ports: {} (paper: fourteen, two idle)",
        smodk.c_topo(), smodk.used_ports_at(&topo, 3, false));
    assert_eq!(smodk.used_ports_at(&topo, 3, false), 14);

    println!("\n== §III.D: Random ==");
    let mut hist = std::collections::BTreeMap::new();
    for seed in 0..100u64 {
        let r = report_seeded(&topo, &types, AlgorithmKind::RandomPair, seed);
        *hist.entry(r.c_topo()).or_insert(0u32) += 1;
    }
    println!("  C_topo histogram over 100 seeds (per-route dispersion): {hist:?}");
    println!("  (paper: 'values of either 3 or 4')");

    println!("\n== §IV.B.1 / Fig 6: Gdmodk ==");
    let gd_all = report(&topo, &types, AlgorithmKind::Gdmodk, &Pattern::C2ioAll);
    show_top_ports(&topo, &gd_all, "C2IO(Gdmodk), dense");
    println!("  dense reading: C_topo = {} (paper: 2, at leaf up-ports only)", gd_all.c_topo());
    let gd_sym = report(&topo, &types, AlgorithmKind::Gdmodk, &Pattern::C2ioSym);
    println!("  1:1 reading:  C_topo = {} (§III.B's optimum R_dst = 1)", gd_sym.c_topo());
    assert_eq!(gd_all.c_topo(), 2);
    assert_eq!(gd_sym.c_topo(), 1);

    println!("\n== §IV.B.2 / Fig 7: Gsmodk ==");
    let gs = report(&topo, &types, AlgorithmKind::Gsmodk, &Pattern::C2ioSym);
    show_top_ports(&topo, &gs, "C2IO(Gsmodk)");
    println!("  C_topo = {} (paper: 4 — source-based can't beat it on a many-to-few pattern),\n  \
               but all {} top-ports now carry load (Smodk wasted 2)",
        gs.c_topo(), gs.used_ports_at(&topo, 3, false));

    println!("\n== Conclusions ==");
    println!(
        "  at-risk top-ports: Smodk {} → Dmodk {} → Gdmodk {}  ('a sevenfold decrease in congestion risk')",
        smodk.used_ports_at(&topo, 3, false),
        dmodk.hot_ports_at(&topo, 3, false).len(),
        gd_all.hot_ports_at(&topo, 3, false).len(),
    );
    Ok(())
}

fn report_seeded(
    topo: &Topology,
    types: &NodeTypeMap,
    kind: AlgorithmKind,
    seed: u64,
) -> CongestionReport {
    let router = kind.build(topo, Some(types), seed);
    let flows = Pattern::C2ioSym.flows(topo, types).unwrap();
    let routes = trace_flows(topo, &*router, &flows);
    CongestionReport::compute(topo, &routes)
}
