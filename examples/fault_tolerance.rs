//! Fabric-manager lifecycle: bring up a coordinator, analyze, inject
//! link failures (PGFT parallel-link fault tolerance), watch incremental
//! reroutes, heal, and verify the Gdmodk optimum returns.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! ```

use pgft::coordinator::Coordinator;
use pgft::prelude::*;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let topo = Arc::new(build_pgft(&PgftSpec::case_study()));
    let types = Placement::paper_io().apply(&topo)?;
    let coord = Coordinator::start(topo.clone(), types, AlgorithmKind::Gdmodk, 1)?;

    let s = coord.stats()?;
    println!(
        "fabric up: algo={} tables v{} ({} entries)",
        s.algorithm, s.table_version, s.table_entries
    );
    println!("healthy C2IO C_topo = {}", coord.analyze(Pattern::C2ioSym)?.c_topo);

    // Fault storm: 3 of the 4 parallel links of the first L2→top bundle.
    let l2 = topo.level_switches(2).next().unwrap();
    let victims: Vec<_> = topo.switches[l2]
        .up_ports
        .iter()
        .take(3)
        .map(|&p| topo.ports[p].link)
        .collect();
    for &v in &victims {
        coord.link_down(v);
        let s = coord.stats()?;
        println!(
            "link {v} down → tables v{} in {} µs, pushing {} changed entries",
            s.table_version, s.last_reroute_micros, s.last_diff_entries
        );
    }

    // The fabric still routes everything (the 4th parallel link carries
    // the bundle) — verify through the coordinator.
    let flows: Vec<(u32, u32)> =
        (0..64).flat_map(|s| (0..64).filter(move |&d| d != s).map(move |d| (s, d))).collect();
    let routes = coord.trace(flows)?;
    let rep = pgft::routing::verify::verify_routes(&topo, &routes)?;
    println!(
        "degraded fabric: {}/{} flows routed, deadlock-free: {}",
        rep.flows, rep.flows, rep.deadlock_free
    );
    let degraded = coord.analyze(Pattern::C2ioSym)?;
    println!("degraded C2IO C_topo = {}", degraded.c_topo);

    // Heal and confirm the optimum returns.
    for &v in &victims {
        coord.link_up(v);
    }
    let healed = coord.analyze(Pattern::C2ioSym)?;
    println!("healed C2IO C_topo = {} (Gdmodk optimum restored)", healed.c_topo);
    assert_eq!(healed.c_topo, 1);

    // Live algorithm migration, as an operator would.
    coord.set_algorithm(AlgorithmKind::Dmodk);
    println!("migrated to dmodk: C_topo = {}", coord.analyze(Pattern::C2ioSym)?.c_topo);
    coord.shutdown();
    Ok(())
}
