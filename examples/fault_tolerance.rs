//! Fabric-manager lifecycle: bring up a coordinator, analyze, inject
//! link failures (PGFT parallel-link fault tolerance), watch incremental
//! reroutes, heal, and verify the Gdmodk optimum returns.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! ```

use pgft::coordinator::Coordinator;
use pgft::prelude::*;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let topo = Arc::new(build_pgft(&PgftSpec::case_study()));
    let types = Placement::paper_io().apply(&topo)?;
    let coord = Coordinator::start(topo.clone(), types, AlgorithmKind::Gdmodk, 1)?;

    let s = coord.stats();
    println!(
        "fabric up: algo={} tables v{} ({} entries)",
        s.algorithm, s.table_version, s.table_entries
    );
    println!("healthy C2IO C_topo = {}", coord.analyze(Pattern::C2ioSym)?.c_topo);

    // Fault storm: 3 of the 4 parallel links of the first L2→top bundle.
    let l2 = topo.level_switches(2).next().unwrap();
    let victims: Vec<_> = topo.switches[l2]
        .up_ports
        .iter()
        .take(3)
        .map(|&p| topo.ports[p].link)
        .collect();
    for &v in &victims {
        coord.link_down(v);
        coord.sync()?;
        let s = coord.stats();
        println!(
            "link {v} down → tables v{} in {} µs, pushing {} changed entries",
            s.table_version, s.last_reroute_micros, s.last_diff_entries
        );
    }

    // The whole storm again as ONE atomic burst: the leader coalesces
    // it into a single incremental repair and a single table push.
    for &v in &victims {
        coord.link_up(v);
    }
    coord.sync()?;
    coord.inject_burst(victims.iter().map(|&v| LinkEvent::Down(v)).collect());
    coord.sync()?;
    let s = coord.stats();
    println!(
        "burst of {} events → ONE repair: tables v{} in {} µs, {} changed entries",
        s.last_batch_events, s.table_version, s.last_reroute_micros, s.last_diff_entries
    );

    // The fabric still routes everything (the 4th parallel link carries
    // the bundle) — verify through the coordinator.
    let flows: Vec<(u32, u32)> =
        (0..64).flat_map(|s| (0..64).filter(move |&d| d != s).map(move |d| (s, d))).collect();
    let routes = coord.trace(&flows);
    let rep = pgft::routing::verify::verify_routes(&topo, &routes);
    rep.ensure_valid()?;
    println!(
        "degraded fabric: {}/{} flows routed, deadlock-free: {}",
        rep.flows, rep.flows, rep.deadlock_free
    );
    let degraded = coord.analyze(Pattern::C2ioSym)?;
    println!("degraded C2IO C_topo = {}", degraded.c_topo);

    // Heal and confirm the optimum returns.
    for &v in &victims {
        coord.link_up(v);
    }
    coord.sync()?;
    let healed = coord.analyze(Pattern::C2ioSym)?;
    println!("healed C2IO C_topo = {} (Gdmodk optimum restored)", healed.c_topo);
    assert_eq!(healed.c_topo, 1);

    // Live algorithm migration, as an operator would.
    coord.set_algorithm(AlgorithmKind::Dmodk);
    coord.sync()?;
    println!("migrated to dmodk: C_topo = {}", coord.analyze(Pattern::C2ioSym)?.c_topo);
    coord.shutdown();

    // --- Generated fault scenarios (the `faults` subsystem) ------------
    // A seeded cascade: links die one by one; after every event the
    // pristine route store is repaired through the eval layer's
    // incremental re-trace (only flows crossing a dead link move — no
    // full re-trace, byte-identical to one) and we report the cost.
    println!("\n== cascading failure drill (seeded, incremental re-trace) ==");
    let types = Placement::paper_io().apply(&topo)?;
    let scenario = FaultModel::parse("cascade:4")?.generate(&topo, 1);
    let flows = Pattern::C2ioSym.flows(&topo, &types)?;
    let base = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1);
    let pristine = FlowSet::trace(&topo, &*base, &flows);
    for (step, faults) in scenario.stages(&topo).iter().enumerate() {
        match AlgorithmKind::Gdmodk.build_degraded(&topo, Some(&types), 1, faults) {
            Ok(router) => {
                let (rerouted, moved) = pristine.retrace_incremental(&topo, faults, &*router);
                let rep = pgft::routing::verify::verify_routes(&topo, &rerouted.to_routes());
                assert!(rep.deadlock_free, "reroutes stay deadlock-free");
                println!(
                    "step {}: {} dead links, {}/{} routes moved, deadlock-free: {}",
                    step + 1,
                    faults.num_dead(),
                    moved,
                    rerouted.len(),
                    rep.deadlock_free
                );
            }
            Err(e) => println!("step {}: fabric partitioned ({e})", step + 1),
        }
    }

    // The same study as one grid: `pgft faults` in library form.
    println!("\n== fault grid (pgft faults equivalent) ==");
    let spec = SweepSpec {
        topologies: vec!["case-study".into()],
        placements: vec!["io:last:1".into()],
        patterns: vec![Pattern::C2ioSym],
        algorithms: vec![AlgorithmKind::Dmodk, AlgorithmKind::Gdmodk],
        faults: vec!["none".into(), "links:2".into(), "stage:3:4".into()],
        seeds: vec![1],
        simulate: true,
        netsim: Vec::new(),
        workloads: Vec::new(),
    };
    let rows = run_sweep(&spec, &SweepOptions::default())?;
    print!("{}", pgft::sweep::fault_table(&rows).to_text());
    Ok(())
}
