//! END-TO-END DRIVER — proves all three layers compose on a real
//! workload (recorded in EXPERIMENTS.md):
//!
//!   L3 rust: build two PGFTs, place node types, route with all six
//!            algorithms, generate the paper's C2IO patterns;
//!   L2/L1:   the AOT-compiled JAX fair-rate solver (whose inner step is
//!            the Pallas dual-contraction kernel) executes through the
//!            PJRT runtime — one `execute` per solve, no python;
//!   checks:  XLA rates vs the exact rust solver (parity), plus the
//!            packet-level simulator as an independent witness that the
//!            static metric's ordering is real.
//!
//! ```sh
//! make artifacts && cargo run --release --example simulate_e2e
//! ```

use pgft::prelude::*;
use pgft::runtime::Runtime;
use pgft::sim::{
    render_sim_table, simulate_flow_level, solve_fairrate_exact, IncidenceMatrix, PacketSim,
    PacketSimConfig,
};

fn main() -> anyhow::Result<()> {
    let runtime = Runtime::open_default()?;
    println!(
        "PJRT platform: {} | artifacts: {}",
        runtime.platform(),
        runtime
            .manifest()
            .iter()
            .map(|a| a.name.as_str())
            .collect::<Vec<_>>()
            .join(", ")
    );

    let mut all_rows = Vec::new();
    for topo_name in ["case-study", "medium-512"] {
        let topo = families::named(topo_name)?;
        pgft::topology::validate::validate(&topo)?;
        let types = Placement::paper_io().apply(&topo)?;
        println!(
            "\n==== {} ({} nodes, {} ports) ====",
            topo_name,
            topo.num_nodes(),
            topo.num_ports()
        );

        // --- flow-level simulation through the XLA artifact -------------
        let mut rows = Vec::new();
        for pattern in [Pattern::C2ioSym, Pattern::C2ioAll] {
            for kind in AlgorithmKind::ALL {
                let row =
                    simulate_flow_level(&topo, &types, kind, &pattern, 1, Some(&runtime))?;
                rows.push(row);
            }
        }
        print!("{}", render_sim_table(&rows));

        // --- cross-check one cell against the exact rust solver ---------
        let router = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1);
        let flows = Pattern::C2ioSym.flows(&topo, &types)?;
        let routes = trace_flows(&topo, &*router, &flows);
        let inc = IncidenceMatrix::from_routes(&topo, &routes);
        if runtime.pick("fairrate", inc.num_flows(), inc.num_ports()).is_ok() {
            let cap = vec![1.0f32; inc.num_ports()];
            let valid = vec![1.0f32; inc.num_flows()];
            let xla = runtime
                .solve_fairrate(inc.dense(), inc.num_flows(), inc.num_ports(), &cap, &valid)?;
            let exact = solve_fairrate_exact(&inc, &vec![1.0f64; inc.num_ports()]);
            let max_err = xla
                .iter()
                .zip(&exact)
                .map(|(&x, &e)| (x as f64 - e).abs())
                .fold(0.0f64, f64::max);
            println!("XLA vs exact solver: {} flows, max |Δrate| = {max_err:.2e}", xla.len());
            anyhow::ensure!(max_err < 1e-3, "solver parity violated");
        } else {
            println!(
                "({} flows × {} ports exceeds compiled artifact shapes; rust solver used)",
                inc.num_flows(),
                inc.num_ports()
            );
        }

        // --- packet-level witness ---------------------------------------
        let mut dmodk_slots = 0;
        for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gdmodk] {
            let router = kind.build(&topo, Some(&types), 1);
            let routes = trace_flows(&topo, &*router, &flows);
            let res = PacketSim::new(
                &topo,
                &routes,
                PacketSimConfig { message_packets: 64, ..Default::default() },
            )
            .run()?;
            println!(
                "packet-sim {kind}: completion {} slots, {:.2} pkt/slot",
                res.completion_slots, res.throughput
            );
            if kind == AlgorithmKind::Dmodk {
                dmodk_slots = res.completion_slots;
            } else {
                let speedup = dmodk_slots as f64 / res.completion_slots as f64;
                println!("packet-sim speedup Gdmodk vs Dmodk: {speedup:.2}x");
                anyhow::ensure!(speedup > 1.5, "grouped routing must win end-to-end");
            }
        }
        all_rows.extend(rows);
    }

    // Headline: the paper's claim holds through the whole stack.
    let cell = |algo: &str, pat: &str| {
        all_rows
            .iter()
            .find(|r| r.algorithm == algo && r.pattern == pat && r.flows == 56)
            .unwrap()
            .aggregate_throughput
    };
    let gain = cell("gdmodk", "c2io-sym") / cell("dmodk", "c2io-sym");
    println!(
        "\nHEADLINE (case study, C2IO collection): Gdmodk/Dmodk aggregate throughput = {gain:.2}x \
         (static metric predicted 4→1 congestion)"
    );
    anyhow::ensure!(gain > 3.0);
    println!("END-TO-END OK");
    Ok(())
}
