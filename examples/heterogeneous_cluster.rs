//! A production-shaped heterogeneous cluster: 512 nodes with IO, service
//! and GPGPU nodes placed per §II, analyzed under several type-specific
//! patterns — the scenario the paper's introduction motivates.
//!
//! ```sh
//! cargo run --release --example heterogeneous_cluster
//! ```

use pgft::metrics::{render_algorithm_table, AlgoSummary};
use pgft::prelude::*;
use pgft::sim::{render_sim_table, simulate_flow_level};
use pgft::workload::{evaluate_makespan, lower, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    // 512-node slimmed 3-level PGFT (16 nodes/leaf, 32 leaves).
    let topo = families::named("medium-512")?;
    pgft::topology::validate::validate(&topo)?;

    // Realistic placement stack: IO proxies on the last port of every
    // leaf (BXI-style optical ports), one service node on the first port
    // of every leaf, and two GPGPU leaves at the end of the machine.
    let placement = Placement::parse("io:last:1,service:first:1,gpgpu:leaves:2")?;
    let types = placement.apply(&topo)?;
    println!("{}", pgft::topology::render::render_summary(&topo, Some(&types)));

    // Type-specific worst cases: compute→IO collection, IO→compute
    // distribution, compute→GPGPU offload, everyone→service (login/IO
    // metadata hotspot).
    let patterns = vec![
        Pattern::C2ioSym,
        Pattern::Io2cSym,
        Pattern::TypeDense {
            src_ty: NodeType::Compute,
            dst_ty: NodeType::Gpgpu,
            cross_top_only: false,
        },
        Pattern::TypeDense {
            src_ty: NodeType::Compute,
            dst_ty: NodeType::Service,
            cross_top_only: true,
        },
    ];

    let mut rows = Vec::new();
    for pattern in &patterns {
        for kind in [
            AlgorithmKind::Dmodk,
            AlgorithmKind::Smodk,
            AlgorithmKind::Gdmodk,
            AlgorithmKind::Gsmodk,
        ] {
            rows.push(AlgoSummary::compute(&topo, &types, kind, pattern, 1)?);
        }
    }
    print!("{}", render_algorithm_table(&rows));

    // Flow-level throughput for the collection pattern (rust solver; the
    // XLA artifacts cover this size too, see simulate_e2e).
    println!("\nflow-level max-min rates (compute→IO collection):");
    let mut sims = Vec::new();
    for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gdmodk] {
        sims.push(simulate_flow_level(&topo, &types, kind, &Pattern::C2ioSym, 1, None)?);
    }
    print!("{}", render_sim_table(&sims));

    let gain = sims[1].aggregate_throughput / sims[0].aggregate_throughput;
    println!("\nGdmodk aggregate-throughput gain over Dmodk on collection: {gain:.2}x");
    assert!(gain > 1.5, "grouped routing must pay off at scale");

    // Finally, the workload view: an overlapping application mix — the
    // GPGPU leaves run ring-allreduce training iterations while the
    // compute partition bursts a checkpoint at the IO nodes. The fluid
    // makespan compares gdmodk and dmodk on the *whole mix* rather than
    // one pattern at a time (same comparison as `pgft workload`).
    println!("\napplication mix (GPGPU allreduce + compute→IO checkpoint):");
    let lowered = lower(&WorkloadSpec::mix(), &topo, &types)?;
    let mut makespans = Vec::new();
    for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gdmodk] {
        let router = kind.build(&topo, Some(&types), 1);
        let eval = evaluate_makespan(&topo, &*router, &lowered)?;
        println!(
            "  {kind}: makespan {:.1} over {} global phases ({})",
            eval.makespan,
            eval.phases.len(),
            eval.job_times
                .iter()
                .map(|(name, time)| format!("{name} done at {time:.1}"))
                .collect::<Vec<_>>()
                .join(", ")
        );
        makespans.push(eval.makespan);
    }
    println!(
        "Gdmodk mix-makespan gain over Dmodk: {:.2}x",
        makespans[0] / makespans[1]
    );
    assert!(
        makespans[1] < makespans[0],
        "the node-type-balancing claim must hold at workload level"
    );
    Ok(())
}
