//! Route tracing: turn a [`Router`]'s local decisions into the full
//! sequence of output ports a packet traverses from `src` to `dst`.
//!
//! All produced routes are minimal up\*/down\* paths: the trace climbs
//! while the current switch is not an ancestor of the destination, then
//! descends along destination digits. This is the invariant that makes
//! fat-tree routing deadlock-free (§I.A), and `debug_assert`s enforce it.

use super::Router;
use crate::topology::{Endpoint, Nid, PortId, Topology, TopologyView};

/// A traced route: every output port the flow occupies, in order,
/// including the source node's injection port and the last switch's
/// down-port to the destination node.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoutePorts {
    /// Source node id.
    pub src: Nid,
    /// Destination node id.
    pub dst: Nid,
    /// Output ports occupied, in traversal order.
    pub ports: Vec<PortId>,
}

impl RoutePorts {
    /// Number of switch-to-switch or node-to-switch hops.
    pub fn len(&self) -> usize {
        self.ports.len()
    }

    /// True for self-routes (`src == dst`), which occupy no ports.
    pub fn is_empty(&self) -> bool {
        self.ports.is_empty()
    }
}

/// Trace the route for one (src, dst) flow. `src == dst` yields an empty
/// route (no network traversal).
pub fn trace_route(
    topo: &dyn TopologyView,
    router: &dyn Router,
    src: Nid,
    dst: Nid,
) -> RoutePorts {
    let mut ports = Vec::with_capacity(2 * topo.spec().h);
    trace_route_into(topo, router, src, dst, &mut ports);
    RoutePorts { src, dst, ports }
}

/// Allocation-free tracing into a caller-provided buffer (the fused
/// metric hot path, see `CongestionReport::compute_flows`).
pub fn trace_route_into(
    topo: &dyn TopologyView,
    router: &dyn Router,
    src: Nid,
    dst: Nid,
    ports: &mut Vec<PortId>,
) {
    if src == dst {
        return;
    }
    // Injection.
    let inject = router.inject_port(topo, src, dst);
    ports.push(inject);
    let mut cur = topo.port_peer(inject);
    let mut went_down = false;

    loop {
        let sw = match cur {
            Endpoint::Node(n) => {
                debug_assert_eq!(n, dst, "route ended at wrong node");
                break;
            }
            Endpoint::Switch(s) => s,
        };
        // `descend_at` is "is an ancestor" on pristine fabrics; fault-aware
        // routers keep climbing past ancestors whose descent path died.
        let out = if router.descend_at(topo, sw, dst) {
            went_down = true;
            let j = router.down_link(topo, sw, src, dst);
            topo.down_port_toward(sw, dst, j)
        } else {
            debug_assert!(!went_down, "valley route: up after down");
            router.up_port(topo, sw, src, dst)
        };
        ports.push(out);
        cur = topo.port_peer(out);
        debug_assert!(ports.len() <= 2 * topo.spec().h + 1, "route too long: loop?");
    }
}

/// Trace a batch of flows.
pub fn trace_flows(
    topo: &dyn TopologyView,
    router: &dyn Router,
    flows: &[(Nid, Nid)],
) -> Vec<RoutePorts> {
    flows.iter().map(|&(s, d)| trace_route(topo, router, s, d)).collect()
}

/// Hop distance of a minimal route between two nodes: `2·(nca_level)`
/// where `nca_level` is the lowest level at which their digit prefixes
/// agree (plus the two node-leaf hops counted in the port sequence).
pub fn minimal_hops(topo: &Topology, src: Nid, dst: Nid) -> usize {
    if src == dst {
        return 0;
    }
    let a = topo.nid_digits(src);
    let b = topo.nid_digits(dst);
    let h = topo.spec.h;
    // NCA level = highest index where digits differ, +1 (levels 1-based).
    let mut nca = 1;
    for l in (0..h).rev() {
        if a[l] != b[l] {
            nca = l + 1;
            break;
        }
    }
    // Ports: 1 injection + (nca-1) switch up-ports + nca down-ports.
    2 * nca
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::xmodk::{Basis, Xmodk};
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};
    use crate::util::prop::Prop;

    #[test]
    fn trace_reaches_destination_and_is_minimal() {
        let topo = build_pgft(&PgftSpec::case_study());
        let r = Xmodk::plain(Basis::Dest);
        for src in 0..64u32 {
            for dst in 0..64u32 {
                let route = trace_route(&topo, &r, src, dst);
                assert_eq!(route.ports.len(), minimal_hops(&topo, src, dst), "{src}->{dst}");
                if src != dst {
                    // Last port lands on the destination node.
                    let last = *route.ports.last().unwrap();
                    assert_eq!(topo.port_peer(last), Endpoint::Node(dst));
                }
            }
        }
    }

    #[test]
    fn same_leaf_routes_stay_local() {
        let topo = build_pgft(&PgftSpec::case_study());
        let r = Xmodk::plain(Basis::Source);
        // 0 → 5: same leaf, exactly 2 ports (inject + leaf down).
        let route = trace_route(&topo, &r, 0, 5);
        assert_eq!(route.ports.len(), 2);
        // 0 → 8: adjacent leaf, through one L2 switch: 4 ports.
        let route = trace_route(&topo, &r, 0, 8);
        assert_eq!(route.ports.len(), 4);
        // 0 → 63: cross subgroup, through top: 6 ports.
        let route = trace_route(&topo, &r, 0, 63);
        assert_eq!(route.ports.len(), 6);
    }

    #[test]
    fn up_then_down_shape_for_all_algorithms() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = crate::nodes::Placement::paper_io().apply(&topo).unwrap();
        for kind in AlgorithmKind::ALL {
            let r = kind.build(&topo, Some(&types), 7);
            for (src, dst) in [(0u32, 63u32), (12, 3), (40, 17), (63, 0)] {
                let route = trace_route(&topo, &*r, src, dst);
                // Direction flags must be monotone: all up then all down.
                let dirs: Vec<bool> = route.ports.iter().map(|&p| topo.ports[p].up).collect();
                let first_down = dirs.iter().position(|&u| !u).unwrap_or(dirs.len());
                assert!(
                    dirs[first_down..].iter().all(|&u| !u),
                    "{kind}: valley in route {src}->{dst}: {dirs:?}"
                );
            }
        }
    }

    #[test]
    fn prop_all_pairs_reach_on_random_pgfts() {
        Prop::new("trace-reaches").cases(25).run(|g| {
            let h = g.usize_in(2, 3);
            let m: Vec<u32> = (0..h).map(|_| g.usize_in(2, 4) as u32).collect();
            let w: Vec<u32> = (0..h)
                .map(|i| if i == 0 { 1 } else { g.usize_in(1, 3) as u32 })
                .collect();
            let p: Vec<u32> = (0..h).map(|_| g.usize_in(1, 2) as u32).collect();
            let spec = PgftSpec::new(m, w, p).unwrap();
            if spec.num_nodes() > 64 {
                return;
            }
            let topo = build_pgft(&spec);
            let n = topo.num_nodes() as u32;
            for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Smodk, AlgorithmKind::Random] {
                let r = kind.build(&topo, None, 99);
                for src in 0..n {
                    for dst in 0..n {
                        let route = trace_route(&topo, &*r, src, dst);
                        assert_eq!(route.ports.len(), minimal_hops(&topo, src, dst));
                    }
                }
            }
        });
    }
}
