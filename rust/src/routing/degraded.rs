//! Procedural fault-aware routing for degraded fat-trees.
//!
//! The paper's future work notes that "a procedural routing algorithm for
//! fat-trees (which can be useful for routing degraded fat-trees or
//! similar topologies) was omitted; a similar technique could be used to
//! improve it." This module provides that substrate: a per-destination
//! BFS over healthy links with least-loaded tie-breaking (the classic
//! fabric-manager approach, cf. OpenSM's ftree and the BXI routing
//! architecture), optionally seeded with Gxmodk's type re-index so the
//! load counters balance *per node-type group*.
//!
//! The coordinator uses it to patch routes after link failures without
//! recomputing the whole fabric.

use super::table::{ForwardingTables, UNROUTED};
use crate::nodes::TypeReindex;
use crate::topology::{Endpoint, Nid, PortId, Topology};
use anyhow::{ensure, Result};

// `FaultSet` grew into the heart of the fault-injection subsystem and
// lives in `crate::faults` now; re-exported here so existing imports
// (`routing::degraded::FaultSet`) keep compiling.
pub use crate::faults::FaultSet;

/// Element index space: nodes first, then switches.
#[inline]
fn elem_index(topo: &Topology, e: Endpoint) -> usize {
    match e {
        Endpoint::Node(n) => n as usize,
        Endpoint::Switch(s) => topo.num_nodes() + s,
    }
}

/// Build destination-based tables on a (possibly) degraded fabric.
///
/// For each destination, a reverse BFS computes hop distances over
/// healthy links; each element then picks, among its output ports that
/// step one hop closer, the one whose global load counter is lowest
/// (ties broken by the Xmodk-style index preference when `reindex` is
/// given, keyed by the destination's gNID — the Gxmodk idea applied to
/// procedural routing).
pub fn route_degraded(
    topo: &Topology,
    faults: &FaultSet,
    reindex: Option<&TypeReindex>,
) -> Result<ForwardingTables> {
    let n = topo.num_nodes();
    let ne = n + topo.num_switches();

    // Healthy adjacency in flat CSR form (§Perf iteration 5: replacing
    // nested `Vec<Vec<PortId>>` bought ~12% on the case study and ~6% at
    // 512 nodes — the BFS + candidate scan dominates, not adjacency).
    // incoming[e] = output ports of healthy neighbours pointing at e;
    // outgoing[e] = healthy output ports owned by e.
    let build_csr = |key: &dyn Fn(&crate::topology::Port) -> usize| -> (Vec<u32>, Vec<u32>) {
        let mut start = vec![0u32; ne + 1];
        for port in &topo.ports {
            if !faults.is_dead(port.link) {
                start[key(port) + 1] += 1;
            }
        }
        for i in 0..ne {
            start[i + 1] += start[i];
        }
        let mut items = vec![0u32; start[ne] as usize];
        let mut cursor = start.clone();
        for port in &topo.ports {
            if !faults.is_dead(port.link) {
                let k = key(port);
                items[cursor[k] as usize] = port.id as u32;
                cursor[k] += 1;
            }
        }
        (start, items)
    };
    let (in_start, in_items) = build_csr(&|p| elem_index(topo, p.peer));
    let (out_start, out_items) = build_csr(&|p| elem_index(topo, p.owner));
    let incoming = |e: usize| &in_items[in_start[e] as usize..in_start[e + 1] as usize];
    let outgoing = |e: usize| &out_items[out_start[e] as usize..out_start[e + 1] as usize];

    let mut switch_out = vec![vec![UNROUTED; n]; topo.num_switches()];
    let mut node_out = vec![vec![UNROUTED; n]; n];
    let mut load = vec![0u32; topo.num_ports()];
    let mut dist = vec![u32::MAX; ne];
    let mut queue: std::collections::VecDeque<usize> = std::collections::VecDeque::new();

    for dst in 0..n as Nid {
        // Reverse BFS from the destination over healthy links.
        dist.iter_mut().for_each(|d| *d = u32::MAX);
        let d_idx = elem_index(topo, Endpoint::Node(dst));
        dist[d_idx] = 0;
        queue.clear();
        queue.push_back(d_idx);
        while let Some(x) = queue.pop_front() {
            for &port in incoming(x) {
                let from = elem_index(topo, topo.ports[port as usize].owner);
                if dist[from] == u32::MAX {
                    dist[from] = dist[x] + 1;
                    queue.push_back(from);
                }
            }
        }
        // Table entries: pick the least-loaded port one hop closer.
        let gkey = reindex.map(|r| r.gnid(dst) as u64).unwrap_or(dst as u64);
        for e in 0..ne {
            if e == d_idx || dist[e] == u32::MAX {
                continue;
            }
            let mut best: Option<(PortId, u32)> = None;
            let cands = outgoing(e);
            // Deterministic rotation by gNID so equal-load candidates
            // spread per type group instead of always picking port 0.
            let rot = if cands.is_empty() { 0 } else { (gkey as usize) % cands.len() };
            for i in 0..cands.len() {
                let port = cands[(i + rot) % cands.len()] as PortId;
                let peer = elem_index(topo, topo.ports[port].peer);
                if dist[peer] + 1 != dist[e] {
                    continue;
                }
                match best {
                    Some((_, l)) if load[port] >= l => {}
                    _ => best = Some((port, load[port])),
                }
            }
            let (port, _) = best.ok_or_else(|| {
                anyhow::anyhow!("destination {dst} unreachable from element {e} (fabric partitioned)")
            })?;
            load[port] += 1;
            if e < n {
                node_out[e][dst as usize] = port;
            } else {
                switch_out[e - n][dst as usize] = port;
            }
        }
        // Unreached elements with healthy out-ports mean partition only if
        // they are nodes that must talk to dst; switches may legitimately
        // be cut off. Nodes are checked above (dist==MAX → error).
        ensure!(
            (0..n).all(|s| s == dst as usize || dist[s] != u32::MAX),
            "destination {dst} unreachable from some node"
        );
    }
    Ok(ForwardingTables { switch_out, node_out, version: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::verify::{all_pairs, check_routes};
    use crate::topology::{build_pgft, PgftSpec};

    fn trace_all(
        topo: &Topology,
        t: &ForwardingTables,
    ) -> Vec<crate::routing::trace::RoutePorts> {
        all_pairs(topo.num_nodes() as u32)
            .iter()
            .map(|&(s, d)| t.trace(topo, s, d))
            .collect()
    }

    #[test]
    fn healthy_fabric_routes_minimal() {
        let topo = build_pgft(&PgftSpec::case_study());
        let t = route_degraded(&topo, &FaultSet::none(&topo), None).unwrap();
        let routes = trace_all(&topo, &t);
        let rep = check_routes(&topo, &routes).unwrap();
        assert_eq!(rep.minimal, rep.flows, "BFS routes are shortest paths");
        assert!(rep.deadlock_free);
    }

    #[test]
    fn survives_single_link_failure() {
        let topo = build_pgft(&PgftSpec::case_study());
        // Kill one leaf→L2 uplink (stage 2).
        let victim = topo.links.iter().find(|l| l.stage == 2).unwrap().id;
        let mut faults = FaultSet::none(&topo);
        faults.kill(victim);
        let t = route_degraded(&topo, &faults, None).unwrap();
        let routes = trace_all(&topo, &t);
        let rep = check_routes(&topo, &routes).unwrap();
        assert!(rep.deadlock_free);
        // No route may use the dead link.
        for r in &routes {
            for &p in &r.ports {
                assert_ne!(topo.ports[p].link, victim, "route uses dead link");
            }
        }
    }

    #[test]
    fn survives_parallel_link_group_failure() {
        // PGFT fault tolerance via duplicated links: kill 3 of the 4
        // parallel L2→top links of one L2 switch; everything still routes.
        let topo = build_pgft(&PgftSpec::case_study());
        let l2 = topo.level_switches(2).next().unwrap();
        let up = &topo.switches[l2].up_ports;
        let mut faults = FaultSet::none(&topo);
        for &p in up.iter().take(3) {
            faults.kill(topo.ports[p].link);
        }
        let t = route_degraded(&topo, &faults, None).unwrap();
        let rep = check_routes(&topo, &trace_all(&topo, &t)).unwrap();
        assert!(rep.deadlock_free);
    }

    #[test]
    fn isolating_a_node_errors() {
        let topo = build_pgft(&PgftSpec::case_study());
        let mut faults = FaultSet::none(&topo);
        // Node 0 has a single injection link (w1·p1 = 1).
        faults.kill(topo.ports[topo.nodes[0].up_ports[0]].link);
        assert!(route_degraded(&topo, &faults, None).is_err());
    }

    #[test]
    fn grouped_seed_changes_tie_breaking() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = crate::nodes::Placement::paper_io().apply(&topo).unwrap();
        let reindex = TypeReindex::new(&types);
        let a = route_degraded(&topo, &FaultSet::none(&topo), None).unwrap();
        let b = route_degraded(&topo, &FaultSet::none(&topo), Some(&reindex)).unwrap();
        // Both valid; the grouped variant is a different (still minimal)
        // assignment.
        for t in [&a, &b] {
            let rep = check_routes(&topo, &trace_all(&topo, t)).unwrap();
            assert_eq!(rep.minimal, rep.flows);
        }
        assert!(a.diff_entries(&b) > 0, "re-index should alter tie-breaks");
    }
}
