//! Materialized linear forwarding tables (LFTs) — what the fabric
//! manager actually uploads to switches.
//!
//! Destination-based routers (Dmodk, Gdmodk, Random) compress to one
//! output port per (switch, destination). Source-based routers (Smodk,
//! Gsmodk) need the source too — real fabrics implement them with
//! per-ingress-port tables; we materialize the equivalent
//! (ingress-port, destination) form.

use super::{Router, trace::RoutePorts};
use crate::topology::{Endpoint, Nid, PortId, Topology};
use anyhow::{ensure, Result};

/// Per-switch destination-indexed tables plus per-node injection tables.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ForwardingTables {
    /// `switch_out[sw][dst]` — output port, `usize::MAX` when `dst` is
    /// not routed via `sw` as origin of a hop (never happens for
    /// complete tables; kept for partial/degraded tables).
    pub switch_out: Vec<Vec<PortId>>,
    /// `node_out[src][dst]` — injection port (`usize::MAX` on diagonal).
    pub node_out: Vec<Vec<PortId>>,
    /// Table generation, bumped by the coordinator on reroutes.
    pub version: u64,
}

/// Sentinel for "no output port" in partial/degraded tables.
pub const UNROUTED: PortId = usize::MAX;

impl ForwardingTables {
    /// Materialize a destination-based router into LFTs.
    pub fn build(topo: &Topology, router: &dyn Router) -> Result<ForwardingTables> {
        ensure!(
            router.dest_based(),
            "{} is source-based; materialize per-ingress tables instead",
            router.name()
        );
        let n = topo.num_nodes();
        let mut switch_out = vec![vec![UNROUTED; n]; topo.num_switches()];
        for (sw_id, sw) in topo.switches.iter().enumerate() {
            for dst in 0..n as Nid {
                // Switches cut off from `dst` (possible on degraded
                // fabrics; never on pristine ones) keep UNROUTED —
                // no valid route ever transits them toward `dst`.
                if !router.reaches(topo, sw_id, dst) {
                    continue;
                }
                let port = if router.descend_at(topo, sw_id, dst) {
                    let j = router.down_link(topo, sw_id, 0, dst);
                    topo.down_port_toward(sw_id, dst, j)
                } else {
                    router.up_port(topo, sw_id, 0, dst)
                };
                switch_out[sw.id][dst as usize] = port;
            }
        }
        let mut node_out = vec![vec![UNROUTED; n]; n];
        for src in 0..n as Nid {
            for dst in 0..n as Nid {
                if src != dst {
                    node_out[src as usize][dst as usize] = router.inject_port(topo, src, dst);
                }
            }
        }
        Ok(ForwardingTables { switch_out, node_out, version: 0 })
    }

    /// Walk the tables for one flow.
    pub fn trace(&self, topo: &Topology, src: Nid, dst: Nid) -> RoutePorts {
        let mut ports = Vec::new();
        if src == dst {
            return RoutePorts { src, dst, ports };
        }
        let mut port = self.node_out[src as usize][dst as usize];
        loop {
            assert_ne!(port, UNROUTED, "unrouted hop {src}->{dst}");
            ports.push(port);
            match topo.port_peer(port) {
                Endpoint::Node(n) => {
                    assert_eq!(n, dst, "table walk ended at node {n}, wanted {dst}");
                    break;
                }
                Endpoint::Switch(s) => {
                    port = self.switch_out[s][dst as usize];
                }
            }
            assert!(ports.len() <= 4 * topo.spec.h + 2, "table loop {src}->{dst}");
        }
        RoutePorts { src, dst, ports }
    }

    /// Total number of (switch, dst) entries — the size a fabric manager
    /// would push over the management network.
    pub fn num_entries(&self) -> usize {
        self.switch_out.iter().map(|t| t.len()).sum()
    }

    /// Entries that differ from `other` (for incremental distribution).
    pub fn diff_entries(&self, other: &ForwardingTables) -> usize {
        self.switch_out
            .iter()
            .zip(&other.switch_out)
            .map(|(a, b)| a.iter().zip(b).filter(|(x, y)| x != y).count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::trace::trace_route;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    #[test]
    fn tables_reproduce_traced_routes() {
        let topo = build_pgft(&PgftSpec::case_study());
        for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Random] {
            let r = kind.build(&topo, None, 11);
            let t = ForwardingTables::build(&topo, &*r).unwrap();
            for src in 0..64u32 {
                for dst in 0..64u32 {
                    assert_eq!(
                        t.trace(&topo, src, dst).ports,
                        trace_route(&topo, &*r, src, dst).ports,
                        "{kind} {src}->{dst}"
                    );
                }
            }
        }
    }

    #[test]
    fn source_based_rejected() {
        let topo = build_pgft(&PgftSpec::case_study());
        let r = AlgorithmKind::Smodk.build(&topo, None, 0);
        assert!(ForwardingTables::build(&topo, &*r).is_err());
    }

    #[test]
    fn entry_count_and_diff() {
        let topo = build_pgft(&PgftSpec::case_study());
        let d = ForwardingTables::build(&topo, &*AlgorithmKind::Dmodk.build(&topo, None, 0)).unwrap();
        assert_eq!(d.num_entries(), 14 * 64);
        let r = ForwardingTables::build(&topo, &*AlgorithmKind::Random.build(&topo, None, 5)).unwrap();
        assert_eq!(d.diff_entries(&d), 0);
        assert!(d.diff_entries(&r) > 0);
    }
}
