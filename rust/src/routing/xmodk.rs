//! The Xmodk family: Dmodk, Smodk (Zahavi's closed forms) and the
//! paper's grouped variants Gdmodk / Gsmodk.
//!
//! Up-port selection at a level-`l` element for key `x` (destination NID
//! for Dmodk, source NID for Smodk):
//!
//! ```text
//!     u = ⌊ x / Π_{k=1..l} w_k ⌋ mod (w_{l+1} · p_{l+1})
//! ```
//!
//! `u` indexes the element's up-ports in round-robin order (parent
//! `u mod w_{l+1}`, parallel link `⌊u / w_{l+1}⌋`), which is exactly how
//! [`crate::topology::build`] numbers them — "all up-switches are
//! assigned a route before multiple routes are assigned towards a single
//! switch" (§I.D.2).
//!
//! Descending from level `l`, the parallel-link choice is
//! `⌊ x / Π_{k=1..l-1} w_k ⌋ mod p_l`, the same stream of digits the
//! up-path consumed, so routes to/from `x` stay within the single-root
//! subtree Dmodk concentrates them in.
//!
//! The grouped variants apply the identical formulas to **gNIDs**
//! (Algorithm 1 re-index, [`TypeReindex`]): `Gdmodk(d) = Dmodk(g(d))`,
//! `Gsmodk(s) = Smodk(g(s))`.

use super::Router;
use crate::nodes::TypeReindex;
use crate::topology::{Nid, PgftSpec, PortId, SwitchId, TopologyView};
use std::sync::Arc;

/// Which endpoint's NID feeds the modulo formulas.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Basis {
    /// Key on the destination NID (Dmodk / Gdmodk).
    Dest,
    /// Key on the source NID (Smodk / Gsmodk).
    Source,
}

/// Dmodk / Smodk / Gdmodk / Gsmodk, depending on `basis` and `reindex`.
#[derive(Clone)]
pub struct Xmodk {
    basis: Basis,
    reindex: Option<Arc<TypeReindex>>,
}

impl Xmodk {
    /// Plain (ungrouped) Dmodk or Smodk.
    pub fn plain(basis: Basis) -> Xmodk {
        Xmodk { basis, reindex: None }
    }

    /// The paper's grouped variant: identical formulas over gNIDs.
    pub fn grouped(basis: Basis, reindex: Arc<TypeReindex>) -> Xmodk {
        Xmodk { basis, reindex: Some(reindex) }
    }

    /// The key fed to the formulas for flow (src, dst): the chosen
    /// endpoint's NID, re-indexed if grouped.
    #[inline]
    pub fn key(&self, src: Nid, dst: Nid) -> u64 {
        let x = match self.basis {
            Basis::Dest => dst,
            Basis::Source => src,
        };
        match &self.reindex {
            Some(r) => r.gnid(x) as u64,
            None => x as u64,
        }
    }

    /// Up-port index at a level-`l` element (0 = node): the closed form.
    /// Takes the spec directly — the formulas never touch the graph, which
    /// is why Xmodk routes identically through tables or the implicit view.
    #[inline]
    pub fn up_index(spec: &PgftSpec, level: usize, key: u64) -> u32 {
        let k = spec.w[level] as u64 * spec.p[level] as u64;
        ((key / spec.w_prefix(level)) % k) as u32
    }

    /// Parallel-link index when descending from level `l`:
    /// `⌊x / Π_{k=1..l} w_k⌋ mod p_l` — the *link half* of the up-port
    /// index a level-`l-1` element computes for the same key, so the
    /// descent retraces the parallel links of the single-root subtree the
    /// ascent selected. (Using `W_{l-1}` instead would still match the
    /// paper's case study, where the only parallel stage has `w_3 = 1`,
    /// but would break the §IV.B duality
    /// `C_topo(P(Dmodk)) = C_topo(Q(Smodk))` on PGFTs with a stage where
    /// both `w_l > 1` and `p_l > 1` — see `rust/tests/symmetry.rs`.)
    #[inline]
    pub fn down_index(spec: &PgftSpec, level: usize, key: u64) -> u32 {
        ((key / spec.w_prefix(level)) % spec.p[level - 1] as u64) as u32
    }
}

impl Router for Xmodk {
    fn name(&self) -> String {
        match (self.basis, self.reindex.is_some()) {
            (Basis::Dest, false) => "dmodk".into(),
            (Basis::Source, false) => "smodk".into(),
            (Basis::Dest, true) => "gdmodk".into(),
            (Basis::Source, true) => "gsmodk".into(),
        }
    }

    fn inject_port(&self, topo: &dyn TopologyView, src: Nid, dst: Nid) -> PortId {
        let u = Self::up_index(topo.spec(), 0, self.key(src, dst));
        topo.node_up_port(src, u)
    }

    fn up_port(&self, topo: &dyn TopologyView, sw: SwitchId, src: Nid, dst: Nid) -> PortId {
        let level = topo.switch_level(sw);
        let u = Self::up_index(topo.spec(), level, self.key(src, dst));
        topo.switch_up_port(sw, u)
    }

    fn down_link(&self, topo: &dyn TopologyView, sw: SwitchId, src: Nid, dst: Nid) -> u32 {
        let level = topo.switch_level(sw);
        Self::down_index(topo.spec(), level, self.key(src, dst))
    }

    fn dest_based(&self) -> bool {
        self.basis == Basis::Dest
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::topology::{build_pgft, Endpoint, PgftSpec, Topology};

    fn t() -> Topology {
        build_pgft(&PgftSpec::case_study())
    }

    /// §III.B: "47 mod 2 = 1, thus destination 47 is assigned the second
    /// L2 switch of each subgroup" and "[IO destinations] are assigned
    /// the last port of the four leading to their subgroup".
    #[test]
    fn dmodk_paper_examples() {
        let topo = t();
        // Leaf level (l=1): up index for dest 47 = 47 mod (w2·p2 = 2) = 1.
        assert_eq!(Xmodk::up_index(&topo.spec,1, 47), 1);
        // All IO destinations (≡7 mod 8) share that L2 parity.
        for d in [7u64, 15, 23, 31, 39, 47, 55, 63] {
            assert_eq!(Xmodk::up_index(&topo.spec,1, d), 1, "dest {d}");
            // L2 level (l=2): ⌊d/2⌋ mod (w3·p3 = 4) = 3 → last parallel port.
            assert_eq!(Xmodk::up_index(&topo.spec,2, d), 3, "dest {d}");
            // Top-level down parallel link = ⌊d/2⌋ mod p3 = 3.
            assert_eq!(Xmodk::down_index(&topo.spec,3, d), 3, "dest {d}");
        }
        // Compute destinations spread: dests 0..7 hit alternating parity.
        assert_eq!(Xmodk::up_index(&topo.spec,1, 0), 0);
        assert_eq!(Xmodk::up_index(&topo.spec,1, 1), 1);
        assert_eq!(Xmodk::up_index(&topo.spec,1, 2), 0);
    }

    /// All Dmodk routes to a destination converge on one top switch (the
    /// "single-root subtree" property).
    #[test]
    fn dmodk_single_root_subtree() {
        let topo = t();
        let r = Xmodk::plain(Basis::Dest);
        for dst in 0..64u32 {
            let mut tops = std::collections::HashSet::new();
            for src in 0..64u32 {
                if src == dst || topo.nid_digits(src)[2] == topo.nid_digits(dst)[2] {
                    continue; // only cross-subgroup routes reach the top
                }
                let ports = super::super::trace_route(&topo, &*Box::new(r.clone()), src, dst);
                for &p in &ports.ports {
                    if let Endpoint::Switch(s) = topo.ports[p].owner {
                        if topo.switches[s].level == 3 {
                            tops.insert(s);
                        }
                    }
                }
            }
            assert_eq!(tops.len(), 1, "dest {dst} should use exactly one top switch");
        }
    }

    /// §IV.B.1: Gdmodk assigns each IO destination a *unique* L2 parity —
    /// "e.g.: gNID 61 is assigned (1,0,1) and (1,1,1)" — and splits the
    /// four top-level parallel links two-per-L2-switch.
    #[test]
    fn gdmodk_paper_examples() {
        let topo = t();
        let types = Placement::paper_io().apply(&topo).unwrap();
        let r = Xmodk::grouped(Basis::Dest, Arc::new(TypeReindex::new(&types)));
        // gNIDs for IO nodes 7,15,…,63 are 56..63 → leaf parity alternates.
        let gkeys: Vec<u64> = [7u32, 15, 23, 31, 39, 47, 55, 63]
            .iter()
            .map(|&d| r.key(0, d))
            .collect();
        assert_eq!(gkeys, vec![56, 57, 58, 59, 60, 61, 62, 63]);
        // NID 47 → gNID 61 → leaf up index 61 mod 2 = 1 (second L2 switch).
        assert_eq!(Xmodk::up_index(&topo.spec,1, 61), 1);
        // L2 up index for gNID 61: ⌊61/2⌋ mod 4 = 2 (third parallel port,
        // not the shared last one).
        assert_eq!(Xmodk::up_index(&topo.spec,2, 61), 2);
        // The four right-subgroup IO gNIDs 60..63 use parallel links
        // 2,2,3,3 — half the links, balanced.
        let links: Vec<u32> = (60..64).map(|g| Xmodk::up_index(&topo.spec,2, g)).collect();
        assert_eq!(links, vec![2, 2, 3, 3]);
        // And the left-subgroup IO gNIDs 56..59 use links 0,0,1,1.
        let links_l: Vec<u32> = (56..60).map(|g| Xmodk::up_index(&topo.spec,2, g)).collect();
        assert_eq!(links_l, vec![0, 0, 1, 1]);
    }

    /// §III.C: Smodk maps source s to top switch (s mod 2) via parallel
    /// link ⌊s/2⌋ mod 4; sources ≡ 7 mod 8 would map to the last port of
    /// the second top switch — but those are IO nodes, so two top-ports
    /// carry no compute source.
    #[test]
    fn smodk_source_port_period() {
        let topo = t();
        for s in 0..32u64 {
            assert_eq!(Xmodk::up_index(&topo.spec,1, s), (s % 2) as u32);
            assert_eq!(Xmodk::up_index(&topo.spec,2, s), ((s / 2) % 4) as u32);
        }
        // Combo (parity, link) cycles with period 8; s ≡ 7 mod 8 is combo
        // (1, 3) — the skipped one.
        let combo = |s: u64| (Xmodk::up_index(&topo.spec,1, s), Xmodk::up_index(&topo.spec,2, s));
        assert_eq!(combo(7), (1, 3));
        assert_eq!(combo(15), (1, 3));
        let mut seen = std::collections::HashSet::new();
        for s in 0..8 {
            seen.insert(combo(s));
        }
        assert_eq!(seen.len(), 8, "8 consecutive NIDs cover all 8 top-port combos");
    }

    #[test]
    fn grouped_with_identity_reindex_equals_plain() {
        let topo = t();
        let id = Arc::new(TypeReindex::identity(64));
        let g = Xmodk::grouped(Basis::Dest, id);
        let d = Xmodk::plain(Basis::Dest);
        for src in [0u32, 13, 40] {
            for dst in 0..64u32 {
                if src == dst {
                    continue;
                }
                assert_eq!(
                    super::super::trace_route(&topo, &g, src, dst).ports,
                    super::super::trace_route(&topo, &d, src, dst).ports
                );
            }
        }
    }
}
