//! Routing verification: reachability, minimality, up\*/down\* shape and
//! deadlock freedom — with **structured violation reports** so property
//! tests can say exactly which flow broke at which port of which switch.
//!
//! Deadlock freedom is checked the strong way — build the channel
//! dependency graph (CDG) over output ports from the actual traced
//! routes and assert acyclicity — so it also covers degraded/procedural
//! tables where the up\*/down\* argument does not apply verbatim. When a
//! cycle exists, one concrete cycle is extracted and reported port by
//! port.
//!
//! [`verify_routes`] never fails: it returns a [`VerifyReport`] whose
//! [`VerifyReport::violations`] list is empty for a fully clean route
//! set. *Hard* violations (mis-delivery, discontiguity, CDG cycles)
//! invalidate a route set; non-minimality and valleys are recorded but
//! are legitimate on degraded fabrics — [`VerifyReport::ensure_valid`]
//! draws that line, and [`check_routes`] is the one-call form of
//! "verify and error out on hard violations".

use super::trace::{minimal_hops, RoutePorts};
use crate::topology::{Endpoint, Nid, PortId, SwitchId, Topology};
use anyhow::{ensure, Result};

/// What went wrong with one route (or the route set).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ViolationKind {
    /// A self-route (`src == dst`) occupies ports.
    SelfRouteHasHops,
    /// The last port does not deliver to the destination node.
    EndsElsewhere,
    /// A hop's output port is not owned by the previous port's peer.
    Discontiguous,
    /// Route is longer than the pristine minimal up\*/down\* distance
    /// (legitimate on degraded fabrics; a bug on pristine ones).
    NonMinimal {
        /// Hops the route takes.
        hops: usize,
        /// The pristine minimal hop count.
        minimal: usize,
    },
    /// The route climbs again after descending (not valley-free).
    Valley,
    /// The channel dependency graph has a cycle (credit-loop deadlock
    /// possible); carries one concrete cycle, in port order.
    CdgCycle {
        /// Output ports forming the cycle (last depends on first).
        cycle: Vec<PortId>,
    },
}

impl ViolationKind {
    /// Hard violations invalidate a route set on any fabric; soft ones
    /// (non-minimality, valleys) are legitimate on degraded fabrics.
    pub fn is_hard(&self) -> bool {
        !matches!(self, ViolationKind::NonMinimal { .. } | ViolationKind::Valley)
    }
}

/// One structured violation: the kind plus where it happened — flow
/// (`src -> dst`), hop index, port, and the switch owning that port.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// What kind of violation.
    pub kind: ViolationKind,
    /// Source node of the offending flow (0 for set-level violations).
    pub src: Nid,
    /// Destination node of the offending flow (0 for set-level ones).
    pub dst: Nid,
    /// Hop index within the route, when the violation is hop-local.
    pub hop: Option<usize>,
    /// The offending output port, when port-local.
    pub port: Option<PortId>,
    /// The switch owning that port (None for node-owned ports or
    /// set-level violations).
    pub switch: Option<SwitchId>,
}

impl Violation {
    fn at(kind: ViolationKind, topo: &Topology, r: &RoutePorts, hop: usize) -> Violation {
        let port = r.ports.get(hop).copied();
        let switch = port.and_then(|p| match topo.ports[p].owner {
            Endpoint::Switch(s) => Some(s),
            Endpoint::Node(_) => None,
        });
        Violation { kind, src: r.src, dst: r.dst, hop: Some(hop), port, switch }
    }
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.kind {
            ViolationKind::SelfRouteHasHops => {
                write!(f, "self-route {} occupies ports", self.src)
            }
            ViolationKind::EndsElsewhere => {
                write!(f, "route {}->{} does not deliver to {}", self.src, self.dst, self.dst)
            }
            ViolationKind::Discontiguous => {
                let (s, d) = (self.src, self.dst);
                write!(f, "route {s}->{d} is not contiguous at hop {:?}", self.hop)
            }
            ViolationKind::NonMinimal { hops, minimal } => write!(
                f,
                "route {}->{} takes {hops} hops (minimal {minimal})",
                self.src, self.dst
            ),
            ViolationKind::Valley => {
                let (s, d) = (self.src, self.dst);
                write!(f, "route {s}->{d} climbs after descending at hop {:?}", self.hop)
            }
            ViolationKind::CdgCycle { cycle } => {
                write!(f, "channel dependency cycle over {} ports: {:?}", cycle.len(), cycle)
            }
        }?;
        if let (Some(sw), Some(p)) = (self.switch, self.port) {
            write!(f, " (switch {sw}, port {p})")?;
        } else if let Some(p) = self.port {
            write!(f, " (port {p})")?;
        }
        Ok(())
    }
}

/// Verification report over a set of traced routes.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    /// Routes checked.
    pub flows: usize,
    /// Routes whose hop count equals the minimal up*/down* distance.
    pub minimal: usize,
    /// Routes that never go up after going down.
    pub valley_free: usize,
    /// Distinct edges of the channel dependency graph.
    pub cdg_edges: usize,
    /// Whether the CDG is acyclic (no credit-loop deadlock possible).
    pub deadlock_free: bool,
    /// Every violation found, in route order (set-level CDG violations
    /// last). Empty for a fully clean (minimal, valley-free, delivered,
    /// deadlock-free) route set.
    pub violations: Vec<Violation>,
}

impl VerifyReport {
    /// Violations that invalidate the route set on any fabric
    /// (everything except non-minimality and valleys).
    pub fn hard_violations(&self) -> Vec<&Violation> {
        self.violations.iter().filter(|v| v.kind.is_hard()).collect()
    }

    /// True when no violations of any kind were found.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Error (listing up to the first 5 violations) if any *hard*
    /// violation exists; detoured/valley routes alone pass.
    pub fn ensure_valid(&self) -> Result<()> {
        let hard = self.hard_violations();
        ensure!(
            hard.is_empty(),
            "{} hard routing violation(s): {}",
            hard.len(),
            hard.iter()
                .take(5)
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("; ")
        );
        Ok(())
    }
}

/// Verify a complete set of routes (usually all-pairs). Never fails;
/// inspect [`VerifyReport::violations`] or call
/// [`VerifyReport::ensure_valid`] / [`check_routes`].
pub fn verify_routes(topo: &Topology, routes: &[RoutePorts]) -> VerifyReport {
    let mut rep = VerifyReport { flows: routes.len(), deadlock_free: true, ..Default::default() };

    for r in routes {
        if r.src == r.dst {
            if !r.ports.is_empty() {
                rep.violations.push(Violation::at(ViolationKind::SelfRouteHasHops, topo, r, 0));
            } else {
                rep.minimal += 1;
                rep.valley_free += 1;
            }
            continue;
        }
        let mut broken = false;
        // Reaches destination.
        match r.ports.last() {
            Some(&last) if topo.port_peer(last) == Endpoint::Node(r.dst) => {}
            _ => {
                let hop = r.ports.len().saturating_sub(1);
                rep.violations.push(Violation::at(ViolationKind::EndsElsewhere, topo, r, hop));
                broken = true;
            }
        }
        // Contiguity: each port's peer owns the next port.
        for (i, win) in r.ports.windows(2).enumerate() {
            let peer = topo.port_peer(win[0]);
            let next_owner = topo.ports[win[1]].owner;
            if peer != next_owner {
                rep.violations.push(Violation::at(ViolationKind::Discontiguous, topo, r, i + 1));
                broken = true;
            }
        }
        if broken {
            continue; // shape checks on a malformed route are noise
        }
        let minimal = minimal_hops(topo, r.src, r.dst);
        if r.ports.len() == minimal {
            rep.minimal += 1;
        } else {
            rep.violations.push(Violation::at(
                ViolationKind::NonMinimal { hops: r.ports.len(), minimal },
                topo,
                r,
                0,
            ));
        }
        // Valley-free (up* then down*).
        let dirs: Vec<bool> = r.ports.iter().map(|&p| topo.ports[p].up).collect();
        let first_down = dirs.iter().position(|&u| !u).unwrap_or(dirs.len());
        match dirs[first_down..].iter().position(|&u| u) {
            None => rep.valley_free += 1,
            Some(offset) => {
                let hop = first_down + offset;
                rep.violations.push(Violation::at(ViolationKind::Valley, topo, r, hop));
            }
        }
    }

    // Channel dependency graph over ports.
    let np = topo.num_ports();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for r in routes {
        for win in r.ports.windows(2) {
            edges.push((win[0] as u32, win[1] as u32));
        }
    }
    edges.sort_unstable();
    edges.dedup();
    rep.cdg_edges = edges.len();
    match find_cycle(np, &edges) {
        None => rep.deadlock_free = true,
        Some(cycle) => {
            rep.deadlock_free = false;
            let port = cycle.first().copied();
            let switch = port.and_then(|p| match topo.ports[p].owner {
                Endpoint::Switch(s) => Some(s),
                Endpoint::Node(_) => None,
            });
            rep.violations.push(Violation {
                kind: ViolationKind::CdgCycle { cycle },
                src: 0,
                dst: 0,
                hop: None,
                port,
                switch,
            });
        }
    }
    rep
}

/// Verify and error out on hard violations (the old fail-fast behaviour,
/// now with a full structured report behind the error).
pub fn check_routes(topo: &Topology, routes: &[RoutePorts]) -> Result<VerifyReport> {
    let rep = verify_routes(topo, routes);
    rep.ensure_valid()?;
    Ok(rep)
}

/// Kahn's algorithm; on failure, extract one concrete cycle from the
/// residual graph (every residual node lies on or upstream of a cycle,
/// so walking successors within the residual set must revisit a node).
fn find_cycle(n: usize, edges: &[(u32, u32)]) -> Option<Vec<PortId>> {
    let mut indeg = vec![0u32; n];
    let mut adj_start = vec![0usize; n + 1];
    for &(a, _) in edges {
        adj_start[a as usize + 1] += 1;
    }
    for i in 0..n {
        adj_start[i + 1] += adj_start[i];
    }
    let mut adj = vec![0u32; edges.len()];
    let mut cursor = adj_start.clone();
    for &(a, b) in edges {
        adj[cursor[a as usize]] = b;
        cursor[a as usize] += 1;
        indeg[b as usize] += 1;
    }
    let mut queue: Vec<u32> = (0..n as u32).filter(|&i| indeg[i as usize] == 0).collect();
    let mut seen = 0usize;
    while let Some(v) = queue.pop() {
        seen += 1;
        for i in adj_start[v as usize]..adj_start[v as usize + 1] {
            let w = adj[i];
            indeg[w as usize] -= 1;
            if indeg[w as usize] == 0 {
                queue.push(w);
            }
        }
    }
    if seen == n {
        return None;
    }
    // The residual graph (nodes Kahn could not remove, indeg > 0)
    // contains every cycle, but may also hold acyclic tails hanging off
    // them — an iterative DFS with a gray path finds one actual cycle.
    let residual = |v: usize| indeg[v] > 0;
    let mut color = vec![0u8; n]; // 0 = white, 1 = on path, 2 = done
    let mut path: Vec<usize> = Vec::new();
    let mut path_pos = vec![usize::MAX; n];
    // (node, next adjacency cursor) stack.
    let mut stack: Vec<(usize, usize)> = Vec::new();
    for s in 0..n {
        if !residual(s) || color[s] != 0 {
            continue;
        }
        stack.push((s, adj_start[s]));
        while let Some(&(v, _)) = stack.last() {
            if color[v] == 0 {
                color[v] = 1;
                path_pos[v] = path.len();
                path.push(v);
            }
            // Advance v's cursor to its next interesting successor.
            let mut next_child: Option<usize> = None;
            let mut cycle_entry: Option<usize> = None;
            {
                let cur = &mut stack.last_mut().expect("frame exists").1;
                while *cur < adj_start[v + 1] {
                    let w = adj[*cur] as usize;
                    *cur += 1;
                    if !residual(w) {
                        continue;
                    }
                    match color[w] {
                        1 => {
                            cycle_entry = Some(w);
                            break;
                        }
                        0 => {
                            next_child = Some(w);
                            break;
                        }
                        _ => {}
                    }
                }
            }
            if let Some(w) = cycle_entry {
                return Some(path[path_pos[w]..].to_vec());
            }
            match next_child {
                Some(w) => stack.push((w, adj_start[w])),
                None => {
                    color[v] = 2;
                    path_pos[v] = usize::MAX;
                    path.pop();
                    stack.pop();
                }
            }
        }
    }
    unreachable!("Kahn reported a cycle but DFS found none")
}

/// All-pairs flow list for a topology.
pub fn all_pairs(n: Nid) -> Vec<(Nid, Nid)> {
    let mut v = Vec::with_capacity((n as usize) * (n as usize - 1));
    for s in 0..n {
        for d in 0..n {
            if s != d {
                v.push((s, d));
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::trace::trace_flows;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    #[test]
    fn all_algorithms_verify_on_case_study() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = crate::nodes::Placement::paper_io().apply(&topo).unwrap();
        let flows = all_pairs(64);
        for kind in AlgorithmKind::ALL {
            let r = kind.build(&topo, Some(&types), 1);
            let routes = trace_flows(&topo, &*r, &flows);
            let rep = verify_routes(&topo, &routes);
            assert!(rep.is_clean(), "{kind}: {:?}", rep.violations.first());
            assert_eq!(rep.minimal, rep.flows, "{kind}: all routes minimal");
            assert_eq!(rep.valley_free, rep.flows, "{kind}: all routes valley-free");
            assert!(rep.deadlock_free);
            rep.ensure_valid().unwrap();
        }
    }

    #[test]
    fn cycle_detection_works() {
        assert!(find_cycle(3, &[(0, 1), (1, 2)]).is_none());
        let cycle = find_cycle(3, &[(0, 1), (1, 2), (2, 0)]).expect("cycle");
        assert_eq!(cycle.len(), 3);
        assert!(find_cycle(1, &[]).is_none());
        // A tail leading into a cycle: the cycle alone is extracted.
        let cycle = find_cycle(4, &[(3, 0), (0, 1), (1, 2), (2, 0)]).expect("cycle");
        assert_eq!(cycle.len(), 3);
        assert!(!cycle.contains(&3));
    }

    #[test]
    fn broken_route_reported_with_location() {
        let topo = build_pgft(&PgftSpec::case_study());
        // A route that claims to end somewhere else.
        let bogus = RoutePorts { src: 0, dst: 63, ports: vec![topo.nodes[0].up_ports[0]] };
        let rep = verify_routes(&topo, &[bogus]);
        assert!(!rep.is_clean());
        assert!(rep.ensure_valid().is_err());
        assert!(check_routes(&topo, &[RoutePorts {
            src: 0,
            dst: 63,
            ports: vec![topo.nodes[0].up_ports[0]],
        }])
        .is_err());
        let hard = rep.hard_violations();
        let v = hard[0];
        assert_eq!(v.kind, ViolationKind::EndsElsewhere);
        assert_eq!((v.src, v.dst), (0, 63));
        assert!(v.port.is_some());
        assert!(v.to_string().contains("0->63"), "{v}");
    }

    #[test]
    fn soft_violations_pass_ensure_valid() {
        let topo = build_pgft(&PgftSpec::case_study());
        // A contiguous, delivered, valley-free but NON-minimal route:
        // 0 -> 1 via L2 and back (4 hops; the minimum is 2 within a
        // leaf). Exactly what a degraded fabric produces legitimately.
        let inject = topo.nodes[0].up_ports[0];
        let leaf = match topo.port_peer(inject) {
            Endpoint::Switch(s) => s,
            Endpoint::Node(_) => unreachable!(),
        };
        let leaf_up = topo.switches[leaf].up_ports[0];
        let l2 = match topo.port_peer(leaf_up) {
            Endpoint::Switch(s) => s,
            Endpoint::Node(_) => unreachable!(),
        };
        let detour = RoutePorts {
            src: 0,
            dst: 1,
            ports: vec![
                inject,
                leaf_up,
                topo.down_port_toward(l2, 1, 0),
                topo.down_port_toward(leaf, 1, 0),
            ],
        };
        let rep = verify_routes(&topo, &[detour]);
        assert!(rep.deadlock_free);
        assert_eq!(rep.minimal, 0);
        assert_eq!(rep.valley_free, 1);
        assert!(!rep.is_clean(), "the detour is recorded...");
        assert!(rep.ensure_valid().is_ok(), "...but is not a hard violation");
        assert_eq!(rep.hard_violations().len(), 0);
        assert!(matches!(
            rep.violations[0].kind,
            ViolationKind::NonMinimal { hops: 4, minimal: 2 }
        ));
    }

    #[test]
    fn valley_route_is_soft_and_located() {
        let topo = build_pgft(&PgftSpec::case_study());
        // 0 -> 8 descending into leaf 1 then climbing again to re-descend
        // would be a valley; fabricate the simplest one: inject, up, down
        // to leaf, up again, down, down — instead take the real 0->8
        // route and append a climb+descend pair from node 8's leaf.
        let r = AlgorithmKind::Dmodk.build(&topo, None, 0);
        let mut route = crate::routing::trace::trace_route(&topo, &*r, 0, 8);
        // Replace the final leaf->node hop with leaf up, L2 down, leaf
        // down — climbing to the *other* L2 (up_ports[1]) so no output
        // port repeats and the CDG stays acyclic: the valley must be the
        // only finding.
        let last = route.ports.pop().unwrap();
        let leaf = match topo.ports[last].owner {
            Endpoint::Switch(s) => s,
            Endpoint::Node(_) => unreachable!(),
        };
        let leaf_up = topo.switches[leaf].up_ports[1];
        let l2 = match topo.port_peer(leaf_up) {
            Endpoint::Switch(s) => s,
            Endpoint::Node(_) => unreachable!(),
        };
        route.ports.push(leaf_up);
        route.ports.push(topo.down_port_toward(l2, 8, 0));
        route.ports.push(topo.down_port_toward(leaf, 8, 0));
        let rep = verify_routes(&topo, &[route]);
        assert_eq!(rep.valley_free, 0);
        let valley: Vec<_> = rep
            .violations
            .iter()
            .filter(|v| v.kind == ViolationKind::Valley)
            .collect();
        assert_eq!(valley.len(), 1);
        assert!(valley[0].hop.is_some() && valley[0].switch.is_some());
        assert!(rep.ensure_valid().is_ok(), "a lone valley is soft");
    }

    #[test]
    fn self_route_with_hops_flagged() {
        let topo = build_pgft(&PgftSpec::case_study());
        let bad = RoutePorts { src: 3, dst: 3, ports: vec![topo.nodes[3].up_ports[0]] };
        let rep = verify_routes(&topo, &[bad]);
        assert_eq!(rep.hard_violations().len(), 1);
        assert_eq!(rep.hard_violations()[0].kind, ViolationKind::SelfRouteHasHops);
    }
}
