//! Routing verification: reachability, minimality, up\*/down\* shape and
//! deadlock freedom.
//!
//! Deadlock freedom is checked the strong way — build the channel
//! dependency graph (CDG) over output ports from the actual traced
//! routes and assert acyclicity — so it also covers degraded/procedural
//! tables where the up\*/down\* argument does not apply verbatim.

use super::trace::{minimal_hops, RoutePorts};
use crate::topology::{Endpoint, Nid, Topology};
use anyhow::{ensure, Result};

/// Verification report over a set of traced routes.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    /// Routes checked.
    pub flows: usize,
    /// Routes whose hop count equals the minimal up*/down* distance.
    pub minimal: usize,
    /// Routes that never go up after going down.
    pub valley_free: usize,
    /// Distinct edges of the channel dependency graph.
    pub cdg_edges: usize,
    /// Whether the CDG is acyclic (no credit-loop deadlock possible).
    pub deadlock_free: bool,
}

/// Verify a complete set of routes (usually all-pairs).
pub fn verify_routes(topo: &Topology, routes: &[RoutePorts]) -> Result<VerifyReport> {
    let mut rep = VerifyReport { flows: routes.len(), deadlock_free: true, ..Default::default() };

    for r in routes {
        if r.src == r.dst {
            ensure!(r.ports.is_empty(), "self-route {} has hops", r.src);
            continue;
        }
        // Reaches destination.
        let last = *r.ports.last().expect("non-empty route");
        ensure!(
            topo.port_peer(last) == Endpoint::Node(r.dst),
            "route {}->{} ends at {:?}",
            r.src,
            r.dst,
            topo.port_peer(last)
        );
        // Contiguity: each port's peer owns the next port.
        for win in r.ports.windows(2) {
            let peer = topo.port_peer(win[0]);
            let next_owner = topo.ports[win[1]].owner;
            ensure!(peer == next_owner, "route {}->{} not contiguous", r.src, r.dst);
        }
        if r.ports.len() == minimal_hops(topo, r.src, r.dst) {
            rep.minimal += 1;
        }
        // Valley-free (up* then down*).
        let dirs: Vec<bool> = r.ports.iter().map(|&p| topo.ports[p].up).collect();
        let first_down = dirs.iter().position(|&u| !u).unwrap_or(dirs.len());
        if dirs[first_down..].iter().all(|&u| !u) {
            rep.valley_free += 1;
        }
    }

    // Channel dependency graph over ports.
    let np = topo.num_ports();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for r in routes {
        for win in r.ports.windows(2) {
            edges.push((win[0] as u32, win[1] as u32));
        }
    }
    edges.sort_unstable();
    edges.dedup();
    rep.cdg_edges = edges.len();
    rep.deadlock_free = is_acyclic(np, &edges);
    ensure!(rep.deadlock_free, "channel dependency graph has a cycle");
    Ok(rep)
}

/// Kahn's algorithm.
fn is_acyclic(n: usize, edges: &[(u32, u32)]) -> bool {
    let mut indeg = vec![0u32; n];
    let mut adj_start = vec![0usize; n + 1];
    for &(a, _) in edges {
        adj_start[a as usize + 1] += 1;
    }
    for i in 0..n {
        adj_start[i + 1] += adj_start[i];
    }
    let mut adj = vec![0u32; edges.len()];
    let mut cursor = adj_start.clone();
    for &(a, b) in edges {
        adj[cursor[a as usize]] = b;
        cursor[a as usize] += 1;
        indeg[b as usize] += 1;
    }
    let mut queue: Vec<u32> = (0..n as u32).filter(|&i| indeg[i as usize] == 0).collect();
    let mut seen = 0usize;
    while let Some(v) = queue.pop() {
        seen += 1;
        for i in adj_start[v as usize]..adj_start[v as usize + 1] {
            let w = adj[i];
            indeg[w as usize] -= 1;
            if indeg[w as usize] == 0 {
                queue.push(w);
            }
        }
    }
    seen == n
}

/// All-pairs flow list for a topology.
pub fn all_pairs(n: Nid) -> Vec<(Nid, Nid)> {
    let mut v = Vec::with_capacity((n as usize) * (n as usize - 1));
    for s in 0..n {
        for d in 0..n {
            if s != d {
                v.push((s, d));
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::trace::trace_flows;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    #[test]
    fn all_algorithms_verify_on_case_study() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = crate::nodes::Placement::paper_io().apply(&topo).unwrap();
        let flows = all_pairs(64);
        for kind in AlgorithmKind::ALL {
            let r = kind.build(&topo, Some(&types), 1);
            let routes = trace_flows(&topo, &*r, &flows);
            let rep = verify_routes(&topo, &routes).unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert_eq!(rep.minimal, rep.flows, "{kind}: all routes minimal");
            assert_eq!(rep.valley_free, rep.flows, "{kind}: all routes valley-free");
            assert!(rep.deadlock_free);
        }
    }

    #[test]
    fn cycle_detection_works() {
        assert!(is_acyclic(3, &[(0, 1), (1, 2)]));
        assert!(!is_acyclic(3, &[(0, 1), (1, 2), (2, 0)]));
        assert!(is_acyclic(1, &[]));
    }

    #[test]
    fn broken_route_rejected() {
        let topo = build_pgft(&PgftSpec::case_study());
        // A route that claims to end somewhere else.
        let bogus = RoutePorts { src: 0, dst: 63, ports: vec![topo.nodes[0].up_ports[0]] };
        assert!(verify_routes(&topo, &[bogus]).is_err());
    }
}
