//! Routing algorithms for PGFTs.
//!
//! All fat-tree routes are *minimal up\*/down\* paths*: climb from the
//! source to a nearest common ancestor (NCA) of source and destination,
//! then descend. An algorithm therefore only decides
//!   1. which up-port to take at each non-ancestor element, and
//!   2. which of the `p_l` parallel links to take on the way down.
//!
//! [`Router`] captures exactly those two choices plus the injection port;
//! [`trace`] turns them into concrete routes; [`table`] materializes them
//! into per-switch linear forwarding tables (what a fabric manager
//! uploads to switches).
//!
//! Implemented algorithms (paper §I.D, §IV):
//! * [`xmodk`] — Dmodk / Smodk closed forms, and their type-grouped
//!   Gdmodk / Gsmodk variants (the paper's contribution),
//! * [`random`] — seeded random up-port / parallel-link choice,
//! * [`degraded`] — procedural fault-aware baseline used for rerouting.

pub mod degraded;
pub mod random;
pub mod table;
pub mod trace;
pub mod verify;
pub mod xmodk;

pub use table::ForwardingTables;
pub use trace::{trace_route, RoutePorts};
pub use xmodk::{Basis, Xmodk};

use crate::nodes::{NodeTypeMap, TypeReindex};
use crate::topology::{Nid, PortId, SwitchId, Topology, TopologyView};
use anyhow::Result;
use std::sync::Arc;

/// The routing decision interface: enough to derive any minimal route.
///
/// Routers see the fabric through [`TopologyView`], so the same
/// implementation traces against the materialized [`Topology`] tables or
/// the arithmetic [`crate::topology::ImplicitTopology`] (the 1M-endpoint
/// rung) — a `&Topology` coerces to `&dyn TopologyView` at every call
/// site.
pub trait Router: Send + Sync {
    /// Human-readable algorithm name (seeds included where relevant).
    fn name(&self) -> String;

    /// Injection port of `src` (among its `w_1·p_1` node up-ports).
    fn inject_port(&self, topo: &dyn TopologyView, src: Nid, dst: Nid) -> PortId;

    /// Up-port taken at switch `sw` (not an ancestor of `dst`).
    fn up_port(&self, topo: &dyn TopologyView, sw: SwitchId, src: Nid, dst: Nid) -> PortId;

    /// Parallel-link index (`0..p_l`) used when descending from `sw`
    /// toward `dst`.
    fn down_link(&self, topo: &dyn TopologyView, sw: SwitchId, src: Nid, dst: Nid) -> u32;

    /// Whether the route should switch from climbing to descending at
    /// `sw`. On a pristine fabric that is exactly "is `sw` an ancestor
    /// of `dst`" (the default); fault-aware routers override it to keep
    /// climbing past ancestors whose descent path died
    /// (see [`crate::faults::DegradedRouter`]).
    fn descend_at(&self, topo: &dyn TopologyView, sw: SwitchId, dst: Nid) -> bool {
        topo.is_ancestor(sw, dst)
    }

    /// Whether `sw` can reach `dst` at all under this router. Always
    /// true on a pristine fabric (the default); fault-aware routers
    /// report switches cut off from a destination, and
    /// [`table::ForwardingTables::build`] leaves those entries
    /// [`table::UNROUTED`].
    fn reaches(&self, topo: &dyn TopologyView, sw: SwitchId, dst: Nid) -> bool {
        let _ = (topo, sw, dst);
        true
    }

    /// Whether tables depend only on the destination (true for Dmodk,
    /// Gdmodk, Random; false for Smodk/Gsmodk). Dest-based routers can be
    /// materialized into plain linear forwarding tables.
    fn dest_based(&self) -> bool;
}

/// Algorithm selector, the user-facing name set.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgorithmKind {
    /// Seeded random per-destination tables (§I.D.1).
    Random,
    /// The paper's §III.D per-route dispersion model (see
    /// [`random::PerPairRandom`]).
    RandomPair,
    /// Destination-mod-k closed form (Zahavi).
    Dmodk,
    /// Source-mod-k closed form.
    Smodk,
    /// Grouped (type-reindexed) Dmodk — the paper's contribution.
    Gdmodk,
    /// Grouped (type-reindexed) Smodk.
    Gsmodk,
}

impl AlgorithmKind {
    /// Every algorithm, in canonical comparison order.
    pub const ALL: [AlgorithmKind; 6] = [
        AlgorithmKind::Random,
        AlgorithmKind::RandomPair,
        AlgorithmKind::Dmodk,
        AlgorithmKind::Smodk,
        AlgorithmKind::Gdmodk,
        AlgorithmKind::Gsmodk,
    ];

    /// Parse a CLI/config algorithm name.
    pub fn parse(s: &str) -> Result<AlgorithmKind> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Ok(AlgorithmKind::Random),
            "random-pair" | "randompair" => Ok(AlgorithmKind::RandomPair),
            "dmodk" => Ok(AlgorithmKind::Dmodk),
            "smodk" => Ok(AlgorithmKind::Smodk),
            "gdmodk" => Ok(AlgorithmKind::Gdmodk),
            "gsmodk" => Ok(AlgorithmKind::Gsmodk),
            other => anyhow::bail!("unknown algorithm {other:?} (random|random-pair|dmodk|smodk|gdmodk|gsmodk)"),
        }
    }

    /// Canonical lower-case name (inverse of [`AlgorithmKind::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            AlgorithmKind::Random => "random",
            AlgorithmKind::RandomPair => "random-pair",
            AlgorithmKind::Dmodk => "dmodk",
            AlgorithmKind::Smodk => "smodk",
            AlgorithmKind::Gdmodk => "gdmodk",
            AlgorithmKind::Gsmodk => "gsmodk",
        }
    }

    /// Whether this is one of the paper's type-grouped variants.
    pub fn is_grouped(&self) -> bool {
        matches!(self, AlgorithmKind::Gdmodk | AlgorithmKind::Gsmodk)
    }

    /// Instantiate a router. Grouped variants need the node-type map to
    /// build Algorithm 1's re-index; `seed` only affects `Random`.
    pub fn build(
        &self,
        topo: &Topology,
        types: Option<&NodeTypeMap>,
        seed: u64,
    ) -> Box<dyn Router> {
        let reindex = |basis: Basis| -> Box<dyn Router> {
            let r = match types {
                Some(m) => Arc::new(TypeReindex::new(m)),
                None => Arc::new(TypeReindex::identity(topo.num_nodes() as u32)),
            };
            Box::new(Xmodk::grouped(basis, r))
        };
        match self {
            AlgorithmKind::Random => Box::new(random::RandomRouter::new(topo, seed)),
            AlgorithmKind::RandomPair => Box::new(random::PerPairRandom::new(seed)),
            AlgorithmKind::Dmodk => Box::new(Xmodk::plain(Basis::Dest)),
            AlgorithmKind::Smodk => Box::new(Xmodk::plain(Basis::Source)),
            AlgorithmKind::Gdmodk => reindex(Basis::Dest),
            AlgorithmKind::Gsmodk => reindex(Basis::Source),
        }
    }

    /// Instantiate a router against any [`TopologyView`] — the
    /// constructor path for the implicit 1M-endpoint rung, where no
    /// materialized [`Topology`] exists. Every closed-form algorithm
    /// works; `Random` errors because its constructor samples the
    /// materialized per-switch tables up front (at implicit scales that
    /// table is the thing being avoided — use `random-pair`, the
    /// paper's §III.D dispersion model, instead).
    pub fn build_view(
        &self,
        view: &dyn TopologyView,
        types: Option<&NodeTypeMap>,
        seed: u64,
    ) -> Result<Box<dyn Router>> {
        let reindex = |basis: Basis| -> Box<dyn Router> {
            let r = match types {
                Some(m) => Arc::new(TypeReindex::new(m)),
                None => Arc::new(TypeReindex::identity(view.num_nodes() as u32)),
            };
            Box::new(Xmodk::grouped(basis, r))
        };
        Ok(match self {
            AlgorithmKind::Random => anyhow::bail!(
                "algorithm 'random' materializes per-switch tables and cannot run \
                 on an implicit topology; use 'random-pair'"
            ),
            AlgorithmKind::RandomPair => Box::new(random::PerPairRandom::new(seed)),
            AlgorithmKind::Dmodk => Box::new(Xmodk::plain(Basis::Dest)),
            AlgorithmKind::Smodk => Box::new(Xmodk::plain(Basis::Source)),
            AlgorithmKind::Gdmodk => reindex(Basis::Dest),
            AlgorithmKind::Gsmodk => reindex(Basis::Source),
        })
    }

    /// Instantiate a router that routes around the given fault set:
    /// [`AlgorithmKind::build`] wrapped in a
    /// [`crate::faults::DegradedRouter`]. With zero faults the result is
    /// byte-identical to the plain router. Errors when the surviving
    /// fabric no longer connects every node pair.
    pub fn build_degraded(
        &self,
        topo: &Topology,
        types: Option<&NodeTypeMap>,
        seed: u64,
        faults: &crate::faults::FaultSet,
    ) -> Result<Box<dyn Router>> {
        let base = self.build(topo, types, seed);
        Ok(Box::new(crate::faults::DegradedRouter::new(topo, faults, base)?))
    }
}

impl std::fmt::Display for AlgorithmKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::topology::{build_pgft, PgftSpec};

    #[test]
    fn parse_all_kinds() {
        for k in AlgorithmKind::ALL {
            assert_eq!(AlgorithmKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(AlgorithmKind::parse("ftree").is_err());
    }

    #[test]
    fn build_all_kinds() {
        let t = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&t).unwrap();
        for k in AlgorithmKind::ALL {
            let r = k.build(&t, Some(&types), 42);
            assert!(!r.name().is_empty());
            assert_eq!(
                r.dest_based(),
                matches!(k, AlgorithmKind::Random | AlgorithmKind::Dmodk | AlgorithmKind::Gdmodk),
                "{k}"
            );
        }
    }
}
