//! Random routing (§I.D.1): when several NCAs (and parallel links) are
//! available, pick upward routes uniformly at random — per (switch,
//! destination) table entry, as a fabric manager would, so routes stay
//! deterministic once computed.
//!
//! "On average, the routes are randomly load-balanced … Deviations from
//! the average will, however, cause routes to overlap and induce network
//! congestion." (§III.D quantifies this on the case study.)

use super::Router;
use crate::topology::{Nid, PortId, SwitchId, Topology, TopologyView};
use crate::util::rng::Xoshiro256;

/// Materialized random choices: one up-port index per (element, dest) and
/// one parallel-link index per (switch, dest).
pub struct RandomRouter {
    seed: u64,
    n: usize,
    /// `node_up[src·n + dst? ]` — injection choice depends on dst for
    /// table-per-destination semantics: indexed `[src][dst]` flattened.
    node_up: Vec<u16>,
    /// `sw_up[sw][dst]` flattened: chosen up-port *index*.
    sw_up: Vec<u16>,
    /// `sw_down[sw][dst]` flattened: chosen parallel-link index.
    sw_down: Vec<u16>,
    num_switches: usize,
}

impl RandomRouter {
    /// Materialize seeded random per-destination choices for `topo`.
    pub fn new(topo: &Topology, seed: u64) -> RandomRouter {
        let n = topo.num_nodes();
        let ns = topo.num_switches();
        let mut rng = Xoshiro256::new(seed);
        let mut node_up = vec![0u16; n * n];
        let up0 = topo.spec.up_ports_at(0) as u64;
        for v in node_up.iter_mut() {
            *v = rng.next_below(up0) as u16;
        }
        let mut sw_up = vec![0u16; ns * n];
        let mut sw_down = vec![0u16; ns * n];
        for sw in 0..ns {
            let level = topo.switches[sw].level;
            let ups = topo.spec.up_ports_at(level) as u64;
            let par = topo.spec.p[level - 1] as u64;
            for dst in 0..n {
                if ups > 0 {
                    sw_up[sw * n + dst] = rng.next_below(ups) as u16;
                }
                sw_down[sw * n + dst] = rng.next_below(par) as u16;
            }
        }
        RandomRouter { seed, n, node_up, sw_up, sw_down, num_switches: ns }
    }
}

impl Router for RandomRouter {
    fn name(&self) -> String {
        format!("random(seed={})", self.seed)
    }

    fn inject_port(&self, topo: &dyn TopologyView, src: Nid, dst: Nid) -> PortId {
        let idx = self.node_up[src as usize * self.n + dst as usize] as u32;
        topo.node_up_port(src, idx)
    }

    fn up_port(&self, topo: &dyn TopologyView, sw: SwitchId, _src: Nid, dst: Nid) -> PortId {
        debug_assert!(sw < self.num_switches);
        let idx = self.sw_up[sw * self.n + dst as usize] as u32;
        topo.switch_up_port(sw, idx)
    }

    fn down_link(&self, _topo: &dyn TopologyView, sw: SwitchId, _src: Nid, dst: Nid) -> u32 {
        self.sw_down[sw * self.n + dst as usize] as u32
    }

    fn dest_based(&self) -> bool {
        true
    }
}

/// Per-*pair* random routing — the model behind the paper's §III.D
/// footnote ("distributing each group of 28 routes into its
/// corresponding 8 top-ports"): every (src, dst) route spreads
/// independently, so same-destination routes do *not* coalesce. Not
/// realizable with plain per-destination tables (it needs source-adaptive
/// dispersive tables), but it is the right baseline for the collision
/// arithmetic the paper quotes; `random` (per-destination tables, above)
/// is what a fabric manager would actually upload.
pub struct PerPairRandom {
    seed: u64,
}

impl PerPairRandom {
    /// Stateless per-pair dispersive router with the given seed.
    pub fn new(seed: u64) -> PerPairRandom {
        PerPairRandom { seed }
    }

    /// Stateless per-(element, src, dst) uniform draw via SplitMix64.
    #[inline]
    fn draw(&self, elem: u64, src: Nid, dst: Nid, bound: u64) -> u64 {
        let mut x = self.seed
            ^ elem.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ ((src as u64) << 32 | dst as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        // One SplitMix64 scramble round.
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        x % bound
    }
}

impl Router for PerPairRandom {
    fn name(&self) -> String {
        format!("random-pair(seed={})", self.seed)
    }

    fn inject_port(&self, topo: &dyn TopologyView, src: Nid, dst: Nid) -> PortId {
        let ups = topo.spec().up_ports_at(0) as u64;
        topo.node_up_port(src, self.draw(u64::MAX, src, dst, ups) as u32)
    }

    fn up_port(&self, topo: &dyn TopologyView, sw: SwitchId, src: Nid, dst: Nid) -> PortId {
        let ups = topo.spec().up_ports_at(topo.switch_level(sw)) as u64;
        topo.switch_up_port(sw, self.draw(sw as u64, src, dst, ups) as u32)
    }

    fn down_link(&self, topo: &dyn TopologyView, sw: SwitchId, src: Nid, dst: Nid) -> u32 {
        let level = topo.switch_level(sw);
        let par = topo.spec().p[level - 1] as u64;
        self.draw((sw as u64) | (1 << 40), src, dst, par) as u32
    }

    fn dest_based(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::trace::{minimal_hops, trace_route};
    use crate::topology::{build_pgft, PgftSpec};

    #[test]
    fn deterministic_per_seed() {
        let topo = build_pgft(&PgftSpec::case_study());
        let a = RandomRouter::new(&topo, 1);
        let b = RandomRouter::new(&topo, 1);
        let c = RandomRouter::new(&topo, 2);
        let mut diff = 0;
        for (s, d) in [(0u32, 63u32), (5, 40), (33, 2), (12, 55)] {
            assert_eq!(trace_route(&topo, &a, s, d).ports, trace_route(&topo, &b, s, d).ports);
            if trace_route(&topo, &a, s, d).ports != trace_route(&topo, &c, s, d).ports {
                diff += 1;
            }
        }
        assert!(diff > 0, "different seeds should differ somewhere");
    }

    #[test]
    fn routes_are_minimal() {
        let topo = build_pgft(&PgftSpec::case_study());
        let r = RandomRouter::new(&topo, 7);
        for src in (0..64u32).step_by(5) {
            for dst in 0..64u32 {
                assert_eq!(
                    trace_route(&topo, &r, src, dst).ports.len(),
                    minimal_hops(&topo, src, dst)
                );
            }
        }
    }

    #[test]
    fn per_pair_routes_are_minimal_and_deterministic() {
        let topo = build_pgft(&PgftSpec::case_study());
        let r = PerPairRandom::new(5);
        for src in (0..64u32).step_by(7) {
            for dst in 0..64u32 {
                let a = trace_route(&topo, &r, src, dst);
                assert_eq!(a.ports.len(), minimal_hops(&topo, src, dst));
                assert_eq!(a.ports, trace_route(&topo, &r, src, dst).ports);
            }
        }
    }

    #[test]
    fn per_pair_spreads_same_destination_routes() {
        // The defining difference from per-destination tables: routes to
        // one destination take several top-ports.
        let topo = build_pgft(&PgftSpec::case_study());
        let r = PerPairRandom::new(1);
        let mut tops = std::collections::HashSet::new();
        for src in 0..32u32 {
            for &p in &trace_route(&topo, &r, src, 63).ports {
                if topo.port_level(p) == 3 {
                    tops.insert(p);
                }
            }
        }
        assert!(tops.len() >= 3, "per-pair must disperse: {}", tops.len());
    }

    #[test]
    fn uses_multiple_top_ports_for_one_destination() {
        // Unlike Dmodk, random routing spreads routes to one destination
        // across several top switches/links with high probability.
        let topo = build_pgft(&PgftSpec::case_study());
        let r = RandomRouter::new(&topo, 3);
        let mut top_ports = std::collections::HashSet::new();
        for src in 0..32u32 {
            let route = trace_route(&topo, &r, src, 63);
            for &p in &route.ports {
                if topo.port_level(p) == 3 {
                    top_ports.insert(p);
                }
            }
        }
        assert!(top_ports.len() > 1, "random should spread dest-63 routes");
    }
}
