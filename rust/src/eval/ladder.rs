//! The eval size ladder: named large-fabric rungs and sampled-pair
//! flow generation.
//!
//! The paper's evaluation lives on a 64-node case study; the pipeline
//! itself is built to score production-shaped fabrics. This module
//! names the rungs the scaling story is measured on — 3-level PGFTs at
//! 16k / 64k / 256k / 1M endpoints (see `xl-*` in
//! [`crate::topology::families`]) — and generates the deterministic
//! *sampled-pair* patterns that make them tractable: all-pairs at 256k
//! endpoints is ~69 G flows (petabytes of arena), while `dsts_per_node`
//! sampled destinations per source keep the flow count linear in the
//! node count and still exercise every source and (with overwhelming
//! probability) every inter-switch link. The top rung (1M endpoints)
//! additionally requires the implicit topology
//! ([`crate::topology::ImplicitTopology`]) — its port tables would not
//! fit a sensible memory budget materialized.
//!
//! The generator is mirrored byte-for-byte in
//! `python/tools/pgft_ladder.py`; `python/tests/test_ladder_mirror.py`
//! cross-checks the two. `pgft eval --size` and `benches/bench_eval.rs`
//! both select rungs from [`LADDER`].

use crate::topology::Nid;
use crate::util::rng::Xoshiro256;

/// Seed-domain separator for sampled-pair generation, so a rung's pair
/// sample never reuses the RNG stream of its fault scenario at the same
/// user seed. Mirrored in `python/tools/pgft_ladder.py`.
const PAIR_SEED_XOR: u64 = 0x5A3B_1E0D_C4F2_9786;

/// One rung of the size ladder.
#[derive(Clone, Copy, Debug)]
pub struct LadderRung {
    /// Short CLI name (`pgft eval --size 16k`).
    pub name: &'static str,
    /// Named topology in [`crate::topology::families`].
    pub topology: &'static str,
    /// Sampled destinations per source node.
    pub dsts_per_node: usize,
    /// Dead links for the rung's retrace measurement (a `links:K` fault
    /// scenario; ~10% of flows dirty at 4 eligible hops per route).
    /// Every rung runs the retrace leg: the fault-aware router builds
    /// its per-destination reachability *lazily* under a fixed memory
    /// budget ([`crate::faults::DEFAULT_REACH_BUDGET`], DESIGN.md §12),
    /// so dirty destinations are the only ones that ever pay for a
    /// reach table. `0` would skip the leg; no current rung uses it.
    pub fault_links: usize,
}

impl LadderRung {
    /// Total sampled flows on this rung's topology (`nodes ×
    /// dsts_per_node`).
    pub fn num_flows(&self, num_nodes: usize) -> usize {
        num_nodes * self.dsts_per_node
    }
}

/// The ladder, smallest rung first.
pub const LADDER: [LadderRung; 4] = [
    LadderRung { name: "16k", topology: "xl-16k", dsts_per_node: 4, fault_links: 320 },
    LadderRung { name: "64k", topology: "xl-64k", dsts_per_node: 2, fault_links: 1280 },
    LadderRung { name: "256k", topology: "xl-256k", dsts_per_node: 1, fault_links: 2560 },
    LadderRung { name: "1m", topology: "xl-1m", dsts_per_node: 1, fault_links: 5120 },
];

/// Look a rung up by its CLI name (`"16k"`) or topology name
/// (`"xl-16k"`), case-insensitively.
pub fn rung(size: &str) -> Option<&'static LadderRung> {
    let key = size.trim().to_ascii_lowercase();
    LADDER.iter().find(|r| r.name == key || r.topology == key)
}

/// Deterministic sampled pairs: for each source in id order,
/// `dsts_per_node` destinations drawn uniformly from the *other* nodes
/// (no self-flows; repeats across draws are allowed — they model
/// multi-flow endpoints and keep the generator one-pass). The `dst >=
/// src` shift makes the draw uniform over `n - 1` candidates without
/// rejection, so the stream is exactly reproducible by the Python
/// mirror.
pub fn sample_pairs(num_nodes: usize, dsts_per_node: usize, seed: u64) -> Vec<(Nid, Nid)> {
    assert!(num_nodes >= 2, "sampled pairs need at least two nodes");
    let mut rng = Xoshiro256::new(seed ^ PAIR_SEED_XOR);
    let n = num_nodes as u64;
    let mut out = Vec::with_capacity(num_nodes * dsts_per_node);
    for src in 0..num_nodes as Nid {
        for _ in 0..dsts_per_node {
            let mut dst = rng.next_below(n - 1) as Nid;
            if dst >= src {
                dst += 1;
            }
            out.push((src, dst));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::families::named_spec;

    #[test]
    fn ladder_rungs_resolve_to_named_topologies() {
        for r in &LADDER {
            let spec = named_spec(r.topology).unwrap_or_else(|e| panic!("{}: {e}", r.topology));
            assert!(spec.num_nodes() >= 16_384, "{}", r.name);
            assert_eq!(rung(r.name).unwrap().topology, r.topology);
            assert_eq!(rung(&r.topology.to_uppercase()).unwrap().name, r.name);
        }
        assert!(rung("1k").is_none());
    }

    #[test]
    fn sample_pairs_is_deterministic_and_self_free() {
        let a = sample_pairs(512, 3, 42);
        let b = sample_pairs(512, 3, 42);
        assert_eq!(a, b, "same seed, same pairs");
        assert_ne!(a, sample_pairs(512, 3, 43), "seed drives the sample");
        assert_eq!(a.len(), 512 * 3);
        for (i, &(src, dst)) in a.iter().enumerate() {
            assert_eq!(src, (i / 3) as Nid, "sources run in id order");
            assert_ne!(src, dst, "no self-flows");
            assert!((dst as usize) < 512);
        }
    }

    #[test]
    fn sample_pairs_covers_the_destination_space() {
        // With 8 draws per source over 64 nodes, every node should be
        // hit as a destination (P(miss) ≈ 64·(1-1/63)^512 ≈ 2e-2... use
        // a fixed seed so the test is not flaky but meaningful).
        let pairs = sample_pairs(64, 8, 1);
        let mut seen = [false; 64];
        for &(_, dst) in &pairs {
            seen[dst as usize] = true;
        }
        let hit = seen.iter().filter(|&&s| s).count();
        assert!(hit >= 60, "destination coverage too thin: {hit}/64");
    }
}
