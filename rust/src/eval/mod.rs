//! The unified evaluation core: one route store, one evaluator seam.
//!
//! The paper's results all come from the same conceptual pipeline —
//! trace the routes of a pattern, then *score* them: the static
//! congestion metric `C_p`/`C_topo` (§III.A), max-min fair-rate
//! throughput, or simulated flit-level latency. Before this module each
//! scorer owned its inputs: `metrics`, `sim::fairrate` and `netsim`
//! every one consumed its own per-flow `Vec<RoutePorts>`, re-traced and
//! re-allocated per sweep cell. Here the pipeline is factored into two
//! halves:
//!
//!  * [`FlowSet`] — the arena-backed CSR route store, traced once per
//!    cell and shared (borrowed) by every scorer, with
//!    [`FlowSet::retrace_incremental`] repairing it allocation-lean
//!    after a fault event;
//!  * [`Evaluator`] — the scorer interface
//!    (`evaluate(topo, flows, seed) -> EvalCells`), implemented by
//!    [`CongestionEval`] (static metric), [`FairRateEval`] (max-min
//!    throughput) and [`NetsimEval`] (flit-level simulation), and the
//!    seam any future scorer (adaptive routing, queueing models) plugs
//!    into.
//!
//! `sweep::runner`, the `pgft eval` subcommand and the examples all
//! select evaluators uniformly through this interface instead of
//! hand-wiring each engine.
//!
//! ```
//! use pgft::prelude::*;
//! use pgft::eval::{CongestionEval, Evaluator, FairRateEval, FlowSet};
//! let topo = build_pgft(&PgftSpec::case_study());
//! let types = Placement::paper_io().apply(&topo).unwrap();
//! let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
//! let router = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1);
//! // One trace, shared by every evaluator.
//! let set = FlowSet::trace(&topo, &*router, &flows);
//! let c = CongestionEval.evaluate(&topo, &set, 1);
//! assert_eq!(c.congestion.unwrap().c_topo(), 1); // §IV optimum
//! let f = FairRateEval.evaluate(&topo, &set, 1);
//! assert!(f.fairrate.unwrap().aggregate_throughput > 7.9);
//! ```

pub mod flowset;
pub mod ladder;

pub use flowset::{repair_threads, FlowSet, RetraceTiming};
pub use ladder::{sample_pairs, LadderRung, LADDER};

use crate::metrics::CongestionReport;
use crate::netsim::{run_netsim, NetsimConfig, NetsimReport};
use crate::sim::fair_rates;
use crate::topology::Topology;
use anyhow::{ensure, Result};

/// Max-min fair-rate figures of one evaluated route set (the columns
/// `simulate` sweeps attach to every cell; re-exported by
/// `sweep::result` as `SweepSim` for the CSV surface).
#[derive(Clone, Debug, PartialEq)]
pub struct FairRateStats {
    /// Sum of max-min fair rates over all flows (links have capacity 1).
    pub aggregate_throughput: f64,
    /// Worst flow rate — the pattern's completion is bound by it.
    pub min_rate: f64,
    /// Time to deliver one unit of data per flow: `1 / min_rate`.
    pub completion_time: f64,
}

impl FairRateStats {
    /// Summarize a per-flow rate vector.
    pub fn from_rates(rates: &[f64]) -> FairRateStats {
        let sum: f64 = rates.iter().sum();
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        FairRateStats { aggregate_throughput: sum, min_rate: min, completion_time: 1.0 / min }
    }
}

/// Flit-level simulation figures of one evaluated route set at one
/// offered load (the `ns_*` sweep columns; re-exported by
/// `sweep::result`). See [`crate::netsim`].
#[derive(Clone, Debug, PartialEq)]
pub struct NetsimStats {
    /// Offered load per flow (flits/cycle) — the swept injection rate.
    pub offered: f64,
    /// Accepted aggregate throughput (flits/cycle, measurement window).
    pub accepted: f64,
    /// Mean packet latency in cycles (packets injected in the window).
    pub mean_latency: f64,
    /// 99th-percentile packet latency in cycles.
    pub p99_latency: f64,
    /// Whether the run ran past its saturation point
    /// (accepted < [`crate::netsim::SATURATION_FRACTION`] × offered
    /// aggregate).
    pub saturated: bool,
}

impl From<&NetsimReport> for NetsimStats {
    fn from(r: &NetsimReport) -> NetsimStats {
        NetsimStats {
            offered: r.offered,
            accepted: r.accepted,
            mean_latency: r.mean_latency,
            p99_latency: r.p99_latency,
            saturated: r.saturated,
        }
    }
}

/// What one or more evaluators produced for one route set. Every field
/// is optional — an evaluator fills the cells it owns and
/// [`EvalCells::absorb`] merges the contributions of an evaluator
/// stack into one record.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EvalCells {
    /// Per-port congestion statistics ([`CongestionEval`]).
    pub congestion: Option<CongestionReport>,
    /// Max-min fair-rate throughput ([`FairRateEval`]).
    pub fairrate: Option<FairRateStats>,
    /// Flit-level simulation figures ([`NetsimEval`]).
    pub netsim: Option<NetsimStats>,
}

impl EvalCells {
    /// Merge another evaluator's cells into this record (later
    /// contributions win per field — evaluator stacks are expected to
    /// fill disjoint fields).
    pub fn absorb(&mut self, other: EvalCells) {
        if other.congestion.is_some() {
            self.congestion = other.congestion;
        }
        if other.fairrate.is_some() {
            self.fairrate = other.fairrate;
        }
        if other.netsim.is_some() {
            self.netsim = other.netsim;
        }
    }
}

/// A route-set scorer: anything that turns a traced [`FlowSet`] into
/// result cells. The three shipped engines implement it; the sweep
/// runner, the `pgft eval` subcommand and the examples are generic over
/// it, so adding a fourth engine means implementing this trait — not
/// rewiring every caller.
pub trait Evaluator: Send + Sync {
    /// Human-readable evaluator name (used in tables and logs).
    fn name(&self) -> String;

    /// Score a traced route set. `seed` drives evaluators with internal
    /// randomness (netsim injection streams); deterministic evaluators
    /// ignore it.
    fn evaluate(&self, topo: &Topology, flows: &FlowSet, seed: u64) -> EvalCells;
}

/// The static congestion metric (§III.A): fills
/// [`EvalCells::congestion`] with per-port `C_p` statistics over the
/// canonical bitmap kernel.
#[derive(Clone, Copy, Debug, Default)]
pub struct CongestionEval;

impl Evaluator for CongestionEval {
    fn name(&self) -> String {
        "congestion".to_string()
    }

    fn evaluate(&self, topo: &Topology, flows: &FlowSet, _seed: u64) -> EvalCells {
        EvalCells {
            congestion: Some(CongestionReport::compute_flowset(topo, flows)),
            ..Default::default()
        }
    }
}

/// Exact max-min fair-rate throughput (the deterministic pure-rust
/// solver, `sim::fairrate`): fills [`EvalCells::fairrate`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FairRateEval;

impl Evaluator for FairRateEval {
    fn name(&self) -> String {
        "fairrate".to_string()
    }

    fn evaluate(&self, topo: &Topology, flows: &FlowSet, _seed: u64) -> EvalCells {
        EvalCells {
            fairrate: Some(FairRateStats::from_rates(&fair_rates(topo, flows))),
            ..Default::default()
        }
    }
}

/// The event-driven flit-level simulator at one offered load: fills
/// [`EvalCells::netsim`]. The `evaluate` seed seeds the injection
/// streams (overriding `config.seed`), so sweep cells stay
/// seed-sensitive exactly like the pre-refactor engine.
///
/// A route set with no simulatable flow (all self-flows) yields empty
/// netsim cells rather than an error — grid cells degrade, they don't
/// fail (the policy `sweep::runner` always had).
#[derive(Clone, Debug)]
pub struct NetsimEval {
    /// Simulator tunables (packet size, VCs, windows, injection).
    pub config: NetsimConfig,
    /// Offered load per flow, flits/cycle in `(0, 1]`.
    pub rate: f64,
}

impl NetsimEval {
    /// A netsim evaluator at `rate` with default tunables (the shape
    /// the `SweepSpec.netsim` axis runs).
    pub fn at(rate: f64) -> NetsimEval {
        NetsimEval { config: NetsimConfig::default(), rate }
    }
}

impl Evaluator for NetsimEval {
    fn name(&self) -> String {
        format!("netsim:{}", self.rate)
    }

    fn evaluate(&self, topo: &Topology, flows: &FlowSet, seed: u64) -> EvalCells {
        let cfg = NetsimConfig { seed, ..self.config.clone() };
        EvalCells {
            netsim: run_netsim(topo, flows, &cfg, self.rate).ok().map(|r| NetsimStats::from(&r)),
            ..Default::default()
        }
    }
}

/// Parse a comma-separated evaluator selection — the uniform CLI
/// surface (`pgft eval --evaluators congestion,fairrate,netsim:0.3`):
/// `congestion`, `fairrate`, and `netsim:RATE` (offered load per flow
/// in `(0, 1]`). Duplicate kinds are rejected: [`EvalCells::absorb`]
/// keeps one set of cells per kind, so a second `netsim:R` would be
/// paid for and silently discarded (sweep the `netsim` axis, or run
/// `pgft eval` once per rate, for multiple load points).
pub fn parse_evaluators(spec: &str) -> Result<Vec<Box<dyn Evaluator>>> {
    let mut out: Vec<Box<dyn Evaluator>> = Vec::new();
    let (mut congestion, mut fairrate, mut netsim) = (false, false, false);
    let once = |seen: &mut bool, part: &str| -> Result<()> {
        ensure!(!*seen, "duplicate evaluator kind {part:?}: its cells would overwrite the first");
        *seen = true;
        Ok(())
    };
    for part in spec.split(',') {
        match part {
            "congestion" => {
                once(&mut congestion, part)?;
                out.push(Box::new(CongestionEval));
            }
            "fairrate" => {
                once(&mut fairrate, part)?;
                out.push(Box::new(FairRateEval));
            }
            _ => match part.strip_prefix("netsim:") {
                Some(rate) => {
                    once(&mut netsim, part)?;
                    let rate: f64 = rate
                        .parse()
                        .map_err(|e| anyhow::anyhow!("evaluator {part:?}: bad rate ({e})"))?;
                    ensure!(
                        rate > 0.0 && rate <= 1.0,
                        "evaluator {part:?}: offered load outside (0, 1]"
                    );
                    out.push(Box::new(NetsimEval::at(rate)));
                }
                None => anyhow::bail!(
                    "unknown evaluator {part:?} (congestion|fairrate|netsim:RATE)"
                ),
            },
        }
    }
    ensure!(!out.is_empty(), "no evaluators selected");
    Ok(out)
}

/// Run an evaluator stack over one route set and merge the cells.
pub fn evaluate_all(
    evaluators: &[Box<dyn Evaluator>],
    topo: &Topology,
    flows: &FlowSet,
    seed: u64,
) -> EvalCells {
    let mut cells = EvalCells::default();
    for e in evaluators {
        cells.absorb(e.evaluate(topo, flows, seed));
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::patterns::Pattern;
    use crate::routing::trace::trace_flows;
    use crate::routing::AlgorithmKind;
    use crate::sim::{solve_fairrate_exact, IncidenceMatrix};
    use crate::topology::{build_pgft, PgftSpec};

    fn case(kind: AlgorithmKind) -> (Topology, FlowSet, Vec<crate::routing::RoutePorts>) {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
        let router = kind.build(&topo, Some(&types), 1);
        let set = FlowSet::trace(&topo, &*router, &flows);
        let routes = trace_flows(&topo, &*router, &flows);
        (topo, set, routes)
    }

    #[test]
    fn congestion_eval_matches_pre_refactor_kernel() {
        for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gdmodk, AlgorithmKind::Random] {
            let (topo, set, routes) = case(kind);
            let cells = CongestionEval.evaluate(&topo, &set, 1);
            let rep = cells.congestion.expect("congestion cells filled");
            let reference = CongestionReport::compute(&topo, &routes);
            assert_eq!(rep.per_port, reference.per_port, "{kind}: C_p must be byte-identical");
            assert!(cells.fairrate.is_none() && cells.netsim.is_none());
        }
    }

    #[test]
    fn fairrate_eval_matches_exact_solver() {
        let (topo, set, routes) = case(AlgorithmKind::Dmodk);
        let cells = FairRateEval.evaluate(&topo, &set, 1);
        let stats = cells.fairrate.expect("fairrate cells filled");
        let inc = IncidenceMatrix::from_routes(&topo, &routes);
        let rates = solve_fairrate_exact(&inc, &vec![1.0; inc.num_ports()]);
        let reference = FairRateStats::from_rates(&rates);
        assert_eq!(stats, reference, "bit-exact against the pre-refactor path");
        // Dmodk funnels 56 flows through 2 top ports: min rate 1/28.
        assert!((stats.min_rate - 1.0 / 28.0).abs() < 1e-12);
    }

    #[test]
    fn netsim_eval_is_seeded_and_degrades_cleanly() {
        let (topo, set, _) = case(AlgorithmKind::Gdmodk);
        let ev = NetsimEval {
            config: NetsimConfig { warmup: 100, measure: 400, drain: 100, ..Default::default() },
            rate: 0.05,
        };
        let a = ev.evaluate(&topo, &set, 7);
        let b = ev.evaluate(&topo, &set, 7);
        assert_eq!(a, b, "same seed, same cells");
        let c = ev.evaluate(&topo, &set, 8);
        assert_ne!(a, c, "the evaluate seed drives the injection streams");
        // All-self-flow sets degrade to empty cells, not errors.
        let router = AlgorithmKind::Dmodk.build(&topo, None, 0);
        let selfs = FlowSet::trace(&topo, &*router, &[(3, 3)]);
        assert_eq!(ev.evaluate(&topo, &selfs, 7), EvalCells::default());
    }

    #[test]
    fn absorb_merges_disjoint_fields() {
        let (topo, set, _) = case(AlgorithmKind::Gdmodk);
        let stack = parse_evaluators("congestion,fairrate").unwrap();
        let cells = evaluate_all(&stack, &topo, &set, 1);
        assert!(cells.congestion.is_some());
        assert!(cells.fairrate.is_some());
        assert!(cells.netsim.is_none());
        assert_eq!(cells.congestion.unwrap().c_topo(), 1, "§IV optimum");
    }

    #[test]
    fn parse_evaluators_rejects_bad_specs() {
        assert!(parse_evaluators("congestion,fairrate,netsim:0.3").is_ok());
        assert!(parse_evaluators("").is_err());
        assert!(parse_evaluators("frobnicate").is_err());
        assert!(parse_evaluators("netsim:0").is_err());
        assert!(parse_evaluators("netsim:1.5").is_err());
        assert!(parse_evaluators("netsim:fast").is_err());
        // Duplicate kinds would silently overwrite each other's cells.
        assert!(parse_evaluators("congestion,congestion").is_err());
        assert!(parse_evaluators("netsim:0.1,netsim:0.5").is_err());
        let names: Vec<String> = parse_evaluators("congestion,netsim:0.25")
            .unwrap()
            .iter()
            .map(|e| e.name())
            .collect();
        assert_eq!(names, vec!["congestion".to_string(), "netsim:0.25".to_string()]);
    }
}
