//! [`FlowSet`] — the arena-backed route store every evaluator consumes.
//!
//! Before the eval layer existed, each consumer (`metrics`, the
//! fair-rate solver, the packet/flit simulators) took its own
//! `Vec<RoutePorts>`: one heap allocation per flow, re-traced per
//! consumer. A `FlowSet` stores the same information once, in CSR form —
//! a flat port buffer plus per-flow offsets and a flow table — so a
//! sweep cell traces each flow exactly once into one contiguous arena
//! and every evaluator reads the same bytes.
//!
//! The store also knows how to *repair itself* under faults:
//! [`FlowSet::retrace_incremental`] re-traces only the flows whose
//! stored path crosses a dead link (flows routed entirely over healthy
//! links are copied verbatim) and is byte-identical to a full re-trace
//! with the same fault-aware router — the invariant
//! `tests/eval_agreement.rs` pins across randomized scenarios. The
//! identity holds because every [`Router`] in this crate is stateless
//! per (src, dst) query and [`crate::faults::DegradedRouter`] keeps the
//! base algorithm's decisions wherever their links survive, so a flow
//! that touches no dead link re-traces to exactly its pristine ports.
//!
//! The same argument *composes across growing fault sets*: up\*/down\*
//! reachability under `DegradedRouter` only shrinks as faults
//! accumulate, so for `F_new ⊇ F_old` a store that is correct for
//! `F_old`, repaired incrementally against `F_new`, equals a full trace
//! under `F_new` — every stored route is a healthy-link witness that
//! the degraded router reproduces verbatim, and the dirty ones are
//! re-traced fresh. The online coordinator
//! ([`crate::coordinator`]) leans on exactly this to chain cascade
//! repairs from the previous stage's store; once a *revive* breaks the
//! superset relation it must restart from the pristine store (revived
//! links can make previously-moved routes attractive again).

use crate::faults::FaultSet;
use crate::routing::trace::{trace_route_into, RoutePorts};
use crate::routing::Router;
use crate::topology::{Nid, PortId, Topology};

/// A compact, contiguous store of traced routes: CSR layout with a
/// flow → (src, dst, weight) table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FlowSet {
    /// `(src, dst)` per flow, in trace order.
    pairs: Vec<(Nid, Nid)>,
    /// Per-flow demand weight (1 unless a weighted pattern set it).
    weights: Vec<u32>,
    /// CSR offsets into `ports`; `offsets.len() == pairs.len() + 1`.
    offsets: Vec<u32>,
    /// Flat arena of every route's output ports, concatenated.
    ports: Vec<PortId>,
}

impl FlowSet {
    /// An empty store (useful as a fold seed).
    pub fn empty() -> FlowSet {
        FlowSet { pairs: Vec::new(), weights: Vec::new(), offsets: vec![0], ports: Vec::new() }
    }

    /// Trace every `(src, dst)` flow with `router` into one contiguous
    /// arena (unit weights). This is the single trace a sweep cell
    /// performs; every evaluator then shares the result.
    pub fn trace(topo: &Topology, router: &dyn Router, flows: &[(Nid, Nid)]) -> FlowSet {
        let mut set = FlowSet {
            pairs: Vec::with_capacity(flows.len()),
            weights: vec![1; flows.len()],
            offsets: Vec::with_capacity(flows.len() + 1),
            ports: Vec::with_capacity(flows.len() * 2 * topo.spec.h),
        };
        set.offsets.push(0);
        for &(src, dst) in flows {
            set.pairs.push((src, dst));
            trace_route_into(topo, router, src, dst, &mut set.ports);
            set.offsets.push(set.ports.len() as u32);
        }
        set
    }

    /// Like [`FlowSet::trace`] for weighted flows (`weight` is carried
    /// per flow for demand-aware evaluators; the built-in evaluators
    /// treat every flow as one unit of demand).
    pub fn trace_weighted(
        topo: &Topology,
        router: &dyn Router,
        flows: &[(Nid, Nid, u32)],
    ) -> FlowSet {
        let pairs: Vec<(Nid, Nid)> = flows.iter().map(|&(s, d, _)| (s, d)).collect();
        let mut set = FlowSet::trace(topo, router, &pairs);
        set.weights = flows.iter().map(|&(_, _, w)| w).collect();
        set
    }

    /// Import routes traced elsewhere (interop with the
    /// [`RoutePorts`]-shaped legacy surface, e.g. `trace_flows`).
    pub fn from_routes(routes: &[RoutePorts]) -> FlowSet {
        let mut set = FlowSet::empty();
        set.pairs.reserve(routes.len());
        set.weights = vec![1; routes.len()];
        set.ports.reserve(routes.iter().map(|r| r.ports.len()).sum());
        for r in routes {
            set.pairs.push((r.src, r.dst));
            set.ports.extend_from_slice(&r.ports);
            set.offsets.push(set.ports.len() as u32);
        }
        set
    }

    /// Concatenate several stores into one contiguous arena, in order
    /// (flow `i` of set `k` lands after every flow of sets `0..k`). The
    /// phase-sequenced simulator ([`crate::netsim::run_netsim_phased`])
    /// uses this to fuse per-phase route stores into one simulatable
    /// union without re-tracing anything.
    pub fn concat(sets: &[&FlowSet]) -> FlowSet {
        let mut out = FlowSet::empty();
        out.pairs.reserve(sets.iter().map(|s| s.len()).sum());
        out.ports.reserve(sets.iter().map(|s| s.total_hops()).sum());
        for set in sets {
            out.pairs.extend_from_slice(&set.pairs);
            out.weights.extend_from_slice(&set.weights);
            for f in 0..set.len() {
                out.ports.extend_from_slice(set.route(f));
                out.offsets.push(out.ports.len() as u32);
            }
        }
        out
    }

    /// Materialize per-flow [`RoutePorts`] (interop with consumers that
    /// still want owned per-route vectors, e.g. `routing::verify`).
    pub fn to_routes(&self) -> Vec<RoutePorts> {
        (0..self.len())
            .map(|f| {
                let (src, dst) = self.pairs[f];
                RoutePorts { src, dst, ports: self.route(f).to_vec() }
            })
            .collect()
    }

    /// Number of flows (self-flows included).
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    /// True when the store holds no flows at all.
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Flows that traverse at least one port (i.e. `src != dst`).
    pub fn num_active(&self) -> usize {
        (0..self.len()).filter(|&f| !self.route(f).is_empty()).count()
    }

    /// Total hops over all flows (= length of the port arena).
    pub fn total_hops(&self) -> usize {
        self.ports.len()
    }

    /// `(src, dst)` of one flow.
    #[inline]
    pub fn pair(&self, flow: usize) -> (Nid, Nid) {
        self.pairs[flow]
    }

    /// Demand weight of one flow.
    #[inline]
    pub fn weight(&self, flow: usize) -> u32 {
        self.weights[flow]
    }

    /// The traced route of one flow: every output port in traversal
    /// order (empty for self-flows). Borrowed straight from the arena —
    /// no per-route allocation anywhere.
    #[inline]
    pub fn route(&self, flow: usize) -> &[PortId] {
        &self.ports[self.offsets[flow] as usize..self.offsets[flow + 1] as usize]
    }

    /// Iterate `((src, dst), route)` in flow order.
    pub fn iter(&self) -> impl Iterator<Item = ((Nid, Nid), &[PortId])> + '_ {
        (0..self.len()).map(|f| (self.pairs[f], self.route(f)))
    }

    /// Whether a flow's stored route crosses a link the fault set killed.
    #[inline]
    pub fn crosses_fault(&self, topo: &Topology, faults: &FaultSet, flow: usize) -> bool {
        self.route(flow).iter().any(|&p| faults.is_dead(topo.ports[p].link))
    }

    /// Flows whose stored route crosses a dead link — exactly the set a
    /// fault event forces to move.
    pub fn dirty_flows(&self, topo: &Topology, faults: &FaultSet) -> Vec<usize> {
        (0..self.len()).filter(|&f| self.crosses_fault(topo, faults, f)).collect()
    }

    /// Repair the store after a fault event: re-trace **only** the flows
    /// whose stored route crosses a dead link, copying every other route
    /// verbatim from the arena. Returns the repaired store and the
    /// number of flows whose route changed.
    ///
    /// `router` must be a fault-aware router for the same `faults` (in
    /// practice a [`crate::faults::DegradedRouter`] wrapping the cell's
    /// base algorithm). The result is **byte-identical to a full
    /// re-trace** with the same router (see the module docs for why;
    /// `debug_assert`ed here per retraced flow, property-pinned in
    /// `tests/eval_agreement.rs`), at the cost of re-tracing only the
    /// dirty flows — on a single-link fault that is a small fraction of
    /// the pattern, which is what makes fault grids cheap
    /// (`benches/bench_eval.rs` records the speedup).
    pub fn retrace_incremental(
        &self,
        topo: &Topology,
        faults: &FaultSet,
        router: &dyn Router,
    ) -> (FlowSet, usize) {
        let mut out = FlowSet {
            pairs: self.pairs.clone(),
            weights: self.weights.clone(),
            offsets: Vec::with_capacity(self.offsets.len()),
            ports: Vec::with_capacity(self.ports.len()),
        };
        out.offsets.push(0);
        let mut changed = 0usize;
        for f in 0..self.len() {
            let (src, dst) = self.pairs[f];
            if self.crosses_fault(topo, faults, f) {
                let start = out.ports.len();
                trace_route_into(topo, router, src, dst, &mut out.ports);
                // A dirty flow always moves: its old route used a dead
                // link the fault-aware router can no longer take.
                debug_assert_ne!(
                    &out.ports[start..],
                    self.route(f),
                    "retrace of a dirty flow {src}->{dst} reproduced a dead-link route"
                );
                changed += 1;
            } else {
                out.ports.extend_from_slice(self.route(f));
            }
            out.offsets.push(out.ports.len() as u32);
        }
        (out, changed)
    }

    /// Number of flows whose route differs between two stores over the
    /// same flow list (the rerouting-cost figure sweep rows report).
    pub fn diff_count(&self, other: &FlowSet) -> usize {
        assert_eq!(self.pairs, other.pairs, "diff_count compares stores over the same flows");
        (0..self.len()).filter(|&f| self.route(f) != other.route(f)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::patterns::Pattern;
    use crate::routing::trace::trace_flows;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    fn setup() -> (Topology, Vec<(Nid, Nid)>) {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
        (topo, flows)
    }

    #[test]
    fn trace_matches_route_ports_surface() {
        let (topo, flows) = setup();
        for kind in AlgorithmKind::ALL {
            let router = kind.build(&topo, None, 3);
            let set = FlowSet::trace(&topo, &*router, &flows);
            let routes = trace_flows(&topo, &*router, &flows);
            assert_eq!(set.len(), routes.len());
            assert_eq!(set.total_hops(), routes.iter().map(|r| r.ports.len()).sum::<usize>());
            for (f, r) in routes.iter().enumerate() {
                assert_eq!(set.pair(f), (r.src, r.dst), "{kind}");
                assert_eq!(set.route(f), r.ports.as_slice(), "{kind}");
                assert_eq!(set.weight(f), 1);
            }
            assert_eq!(set.to_routes(), routes, "{kind}");
            assert_eq!(FlowSet::from_routes(&routes), set, "{kind}");
        }
    }

    #[test]
    fn self_flows_are_empty_and_inactive() {
        let (topo, _) = setup();
        let router = AlgorithmKind::Dmodk.build(&topo, None, 0);
        let set = FlowSet::trace(&topo, &*router, &[(0, 0), (0, 63), (5, 5)]);
        assert_eq!(set.len(), 3);
        assert_eq!(set.num_active(), 1);
        assert!(set.route(0).is_empty() && set.route(2).is_empty());
        assert_eq!(set.route(1).len(), 6);
        let collected: Vec<_> = set.iter().map(|(pair, route)| (pair, route.len())).collect();
        assert_eq!(collected, vec![((0, 0), 0), ((0, 63), 6), ((5, 5), 0)]);
    }

    #[test]
    fn weighted_trace_carries_weights() {
        let (topo, _) = setup();
        let router = AlgorithmKind::Dmodk.build(&topo, None, 0);
        let set = FlowSet::trace_weighted(&topo, &*router, &[(0, 63, 4), (1, 62, 1)]);
        assert_eq!(set.weight(0), 4);
        assert_eq!(set.weight(1), 1);
        let unit = FlowSet::trace(&topo, &*router, &[(0, 63), (1, 62)]);
        assert_eq!(set.route(0), unit.route(0), "weights never change routing");
    }

    #[test]
    fn concat_preserves_routes_and_order() {
        let (topo, flows) = setup();
        let router = AlgorithmKind::Gdmodk.build(&topo, None, 1);
        let a = FlowSet::trace(&topo, &*router, &flows[..10]);
        let b = FlowSet::trace(&topo, &*router, &flows[10..]);
        let union = FlowSet::concat(&[&a, &b]);
        let whole = FlowSet::trace(&topo, &*router, &flows);
        assert_eq!(union, whole, "concat of a split trace equals the whole trace");
        assert_eq!(FlowSet::concat(&[&FlowSet::empty(), &whole]), whole);
        assert_eq!(FlowSet::concat(&[]), FlowSet::empty());
    }

    #[test]
    fn incremental_retrace_equals_full_retrace() {
        let (topo, flows) = setup();
        // Kill 2 of the 4 parallel links of the first L2→top bundle.
        let l2 = topo.level_switches(2).next().unwrap();
        let mut faults = FaultSet::none(&topo);
        for &p in topo.switches[l2].up_ports.iter().take(2) {
            faults.kill(topo.ports[p].link);
        }
        for kind in AlgorithmKind::ALL {
            let base = kind.build(&topo, None, 7);
            let pristine = FlowSet::trace(&topo, &*base, &flows);
            let degraded = crate::faults::DegradedRouter::new(
                &topo,
                &faults,
                kind.build(&topo, None, 7),
            )
            .unwrap();
            let (incremental, changed) = pristine.retrace_incremental(&topo, &faults, &degraded);
            let full = FlowSet::trace(&topo, &degraded, &flows);
            assert_eq!(incremental, full, "{kind}: incremental must be byte-identical to full");
            assert_eq!(changed, pristine.diff_count(&full), "{kind}");
            assert_eq!(changed, pristine.dirty_flows(&topo, &faults).len(), "{kind}");
        }
    }

    #[test]
    fn incremental_repair_composes_across_cascade() {
        // The coordinator's chaining invariant: repairing the *previous
        // stage's* store against the grown fault set equals a full
        // trace — see the module docs' monotonicity argument.
        let (topo, _) = setup();
        let flows = crate::routing::verify::all_pairs(topo.num_nodes() as Nid);
        let scenario =
            crate::faults::FaultModel::parse("cascade:4").unwrap().generate(&topo, 2);
        for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gsmodk] {
            let base = kind.build(&topo, None, 3);
            let mut store = FlowSet::trace(&topo, &*base, &flows);
            for faults in scenario.stages(&topo) {
                let router = crate::faults::DegradedRouter::new(
                    &topo,
                    &faults,
                    kind.build(&topo, None, 3),
                )
                .unwrap();
                let (repaired, _) = store.retrace_incremental(&topo, &faults, &router);
                let full = FlowSet::trace(&topo, &router, &flows);
                assert_eq!(repaired, full, "{kind}: stage must compose from the previous one");
                store = repaired;
            }
        }
    }

    #[test]
    fn zero_faults_retrace_is_identity() {
        let (topo, flows) = setup();
        let faults = FaultSet::none(&topo);
        let base = AlgorithmKind::Gdmodk.build(&topo, None, 1);
        let pristine = FlowSet::trace(&topo, &*base, &flows);
        let degraded =
            crate::faults::DegradedRouter::new(&topo, &faults, AlgorithmKind::Gdmodk.build(&topo, None, 1))
                .unwrap();
        let (repaired, changed) = pristine.retrace_incremental(&topo, &faults, &degraded);
        assert_eq!(changed, 0);
        assert_eq!(repaired, pristine);
    }
}
