//! [`FlowSet`] — the arena-backed route store every evaluator consumes.
//!
//! Before the eval layer existed, each consumer (`metrics`, the
//! fair-rate solver, the packet/flit simulators) took its own
//! `Vec<RoutePorts>`: one heap allocation per flow, re-traced per
//! consumer. A `FlowSet` stores the same information once, in CSR form —
//! a flat port buffer plus per-flow offsets and a flow table — so a
//! sweep cell traces each flow exactly once into one contiguous arena
//! and every evaluator reads the same bytes.
//!
//! # Large-fabric layout
//!
//! The store is sized for the eval ladder's 256k-endpoint rung
//! (`pgft eval --size`, DESIGN.md §10): port ids live in the arena as
//! `u32` (not `usize` — halves the dominant allocation), the arena is
//! pre-sized *exactly* from [`crate::topology::PgftSpec::minimal_hops`]
//! (pristine routes are minimal, so no doubling overshoot), and the
//! rare growth past the pre-size (fault-aware routers can route longer
//! than minimal) reserves in bounded [`ARENA_CHUNK`]-entry steps
//! instead of doubling a GiB-scale buffer. CSR offsets are `u32`, which
//! caps the arena at [`FlowSet::MAX_ARENA_LEN`] total hops; every
//! append path goes through a checked conversion that panics with a
//! capacity error instead of silently wrapping offsets.
//!
//! # Incremental repair
//!
//! The store also knows how to *repair itself* under faults:
//! [`FlowSet::retrace_incremental`] re-traces only the flows whose
//! stored path crosses a dead link (flows routed entirely over healthy
//! links are copied verbatim) and is byte-identical to a full re-trace
//! with the same fault-aware router — the invariant
//! `tests/eval_agreement.rs` pins across randomized scenarios. The
//! identity holds because every [`Router`] in this crate is stateless
//! per (src, dst) query and [`crate::faults::DegradedRouter`] keeps the
//! base algorithm's decisions wherever their links survive, so a flow
//! that touches no dead link re-traces to exactly its pristine ports.
//!
//! [`FlowSet::retrace_incremental_par`] fans the dirty flows out over
//! [`crate::util::par::par_map`]: the dirty list is split into
//! consecutive chunks, each worker traces its chunk into a private
//! sub-arena, and the caller splices sub-arenas back in ascending flow
//! order. Because routers are stateless and the splice preserves flow
//! order, the output is **byte-identical to the serial path for every
//! thread count** — also property-pinned in `tests/eval_agreement.rs`.
//!
//! # Composition across growing fault sets
//!
//! The repair argument *composes across growing fault sets*: up\*/down\*
//! reachability under `DegradedRouter` only shrinks as faults
//! accumulate, so for `F_new ⊇ F_old` a store that is correct for
//! `F_old`, repaired incrementally against `F_new`, equals a full trace
//! under `F_new` — every stored route is a healthy-link witness that
//! the degraded router reproduces verbatim, and the dirty ones are
//! re-traced fresh. The online coordinator
//! ([`crate::coordinator`]) leans on exactly this to chain cascade
//! repairs from the previous stage's store; once a *revive* breaks the
//! superset relation it must restart from the pristine store (revived
//! links can make previously-moved routes attractive again).

use crate::faults::FaultSet;
use crate::routing::trace::{trace_route_into, RoutePorts};
use crate::routing::Router;
use crate::telemetry::Telemetry;
use crate::topology::{Nid, PortId, TopologyView};
use crate::util::par::par_map;
use std::time::Instant;

/// Growth quantum for the port arena once a store outgrows its exact
/// pre-size (only fault-aware routers can — they may route longer than
/// minimal). A bounded step instead of `Vec`'s doubling: at the
/// 256k-endpoint rung a doubling step would transiently hold two
/// GiB-scale buffers for a few extra hops.
const ARENA_CHUNK: usize = 1 << 20;

/// Checked CSR offset conversion: every arena append goes through this
/// so an oversized pattern fails with a capacity error instead of
/// wrapping offsets at `u32::MAX` and corrupting every later route
/// slice.
#[inline]
fn arena_offset(len: usize) -> u32 {
    match u32::try_from(len) {
        Ok(o) => o,
        Err(_) => panic!(
            "FlowSet port arena overflow: {len} hop entries exceed the u32 CSR offset \
             limit of {}; split the pattern or use sampled pairs (see DESIGN.md §10)",
            u32::MAX
        ),
    }
}

/// Port ids are stored 32-bit; no buildable topology comes near the
/// limit (the 256k-endpoint rung has <1M ports), so this is a
/// debug-only tripwire rather than a hot-path branch.
#[inline]
fn port_u32(p: PortId) -> u32 {
    debug_assert!(p <= u32::MAX as usize, "port id {p} exceeds the u32 arena element width");
    p as u32
}

/// Reserve room for `extra` more arena entries in bounded chunks (see
/// [`ARENA_CHUNK`]); no-op while the existing capacity suffices.
#[inline]
fn reserve_chunked(ports: &mut Vec<u32>, extra: usize) {
    if ports.capacity() - ports.len() < extra {
        ports.reserve_exact(ARENA_CHUNK.max(extra));
    }
}

/// Append a `PortId`-typed route (legacy tracing surface) to an arena.
#[inline]
fn push_route(ports: &mut Vec<u32>, route: &[PortId]) {
    reserve_chunked(ports, route.len());
    ports.extend(route.iter().map(|&p| port_u32(p)));
}

/// Append an already-32-bit route (arena-to-arena copy).
#[inline]
fn push_route_u32(ports: &mut Vec<u32>, route: &[u32]) {
    reserve_chunked(ports, route.len());
    ports.extend_from_slice(route);
}

/// Worker-thread count policy for store repairs. Parallel retrace pays
/// a scoped-thread spawn per call, which swamps the win on small
/// fabrics (a whole case-study repair is tens of microseconds), so
/// repair sites only go wide when the store is large enough to
/// amortize the spawns; below the threshold the serial path is both
/// simpler and faster.
pub fn repair_threads(flows: usize) -> usize {
    /// Smallest store for which the fan-out pays for itself; the 16k
    /// ladder rung (65k flows) is comfortably above, every case-study /
    /// medium-512 sweep cell is below.
    const PAR_REPAIR_MIN_FLOWS: usize = 32_768;
    if flows >= PAR_REPAIR_MIN_FLOWS {
        crate::util::par::max_threads()
    } else {
        1
    }
}

/// Wall-clock phase breakdown of one incremental repair. Diagnostic
/// only: it feeds the coordinator's event journal and the telemetry
/// registry, never a deterministic output (the repaired bytes are
/// identical whether or not anyone reads the clock).
#[derive(Clone, Copy, Debug, Default)]
pub struct RetraceTiming {
    /// Scanning the store for flows crossing dead links.
    pub dirty_scan_ns: u64,
    /// Re-tracing the dirty flows (all workers, wall-clock).
    pub trace_ns: u64,
    /// The ordered splice into the repaired arena.
    pub splice_ns: u64,
}

/// A compact, contiguous store of traced routes: CSR layout with a
/// flow → (src, dst, weight) table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FlowSet {
    /// `(src, dst)` per flow, in trace order.
    pairs: Vec<(Nid, Nid)>,
    /// Per-flow demand weight (1 unless a weighted pattern set it).
    weights: Vec<u32>,
    /// CSR offsets into `ports`; `offsets.len() == pairs.len() + 1`.
    offsets: Vec<u32>,
    /// Flat arena of every route's output ports, concatenated (32-bit
    /// ids — see the module docs on the large-fabric layout).
    ports: Vec<u32>,
}

impl FlowSet {
    /// Largest port arena a store can address: CSR offsets are `u32`.
    /// At ~6 hops per flow this is room for ~700M flows — appends past
    /// it fail with a capacity error (see [`FlowSet::trace`]).
    pub const MAX_ARENA_LEN: usize = u32::MAX as usize;

    /// An empty store (useful as a fold seed).
    pub fn empty() -> FlowSet {
        FlowSet { pairs: Vec::new(), weights: Vec::new(), offsets: vec![0], ports: Vec::new() }
    }

    /// Trace every `(src, dst)` flow with `router` into one contiguous
    /// arena (unit weights). This is the single trace a sweep cell
    /// performs; every evaluator then shares the result.
    ///
    /// # Panics
    ///
    /// If the total hop count exceeds [`FlowSet::MAX_ARENA_LEN`] (the
    /// u32 CSR offset limit), with a capacity error naming the limit.
    pub fn trace(topo: &dyn TopologyView, router: &dyn Router, flows: &[(Nid, Nid)]) -> FlowSet {
        // Exact pre-size: pristine routers produce minimal routes, so
        // the arena holds exactly the sum of minimal hop counts. A
        // fault-aware router can exceed a flow's minimal length; the
        // append path then grows in bounded chunks.
        let spec = topo.spec();
        let cap: usize =
            flows.iter().map(|&(s, d)| spec.minimal_hops(s as u64, d as u64)).sum();
        let mut set = FlowSet {
            pairs: Vec::with_capacity(flows.len()),
            weights: vec![1; flows.len()],
            offsets: Vec::with_capacity(flows.len() + 1),
            ports: Vec::with_capacity(cap),
        };
        set.offsets.push(0);
        let mut scratch: Vec<PortId> = Vec::with_capacity(2 * spec.h + 1);
        for &(src, dst) in flows {
            set.pairs.push((src, dst));
            scratch.clear();
            trace_route_into(topo, router, src, dst, &mut scratch);
            push_route(&mut set.ports, &scratch);
            set.offsets.push(arena_offset(set.ports.len()));
        }
        set
    }

    /// Like [`FlowSet::trace`] for weighted flows (`weight` is carried
    /// per flow for demand-aware evaluators; the built-in evaluators
    /// treat every flow as one unit of demand).
    pub fn trace_weighted(
        topo: &dyn TopologyView,
        router: &dyn Router,
        flows: &[(Nid, Nid, u32)],
    ) -> FlowSet {
        let pairs: Vec<(Nid, Nid)> = flows.iter().map(|&(s, d, _)| (s, d)).collect();
        let mut set = FlowSet::trace(topo, router, &pairs);
        set.weights = flows.iter().map(|&(_, _, w)| w).collect();
        set
    }

    /// Import routes traced elsewhere (interop with the
    /// [`RoutePorts`]-shaped legacy surface, e.g. `trace_flows`).
    pub fn from_routes(routes: &[RoutePorts]) -> FlowSet {
        let mut set = FlowSet::empty();
        set.pairs.reserve(routes.len());
        set.weights = vec![1; routes.len()];
        set.ports.reserve(routes.iter().map(|r| r.ports.len()).sum());
        for r in routes {
            set.pairs.push((r.src, r.dst));
            push_route(&mut set.ports, &r.ports);
            set.offsets.push(arena_offset(set.ports.len()));
        }
        set
    }

    /// Concatenate several stores into one contiguous arena, in order
    /// (flow `i` of set `k` lands after every flow of sets `0..k`). The
    /// phase-sequenced simulator ([`crate::netsim::run_netsim_phased`])
    /// uses this to fuse per-phase route stores into one simulatable
    /// union without re-tracing anything.
    pub fn concat(sets: &[&FlowSet]) -> FlowSet {
        let mut out = FlowSet::empty();
        out.pairs.reserve(sets.iter().map(|s| s.len()).sum());
        out.ports.reserve(sets.iter().map(|s| s.total_hops()).sum());
        for set in sets {
            out.pairs.extend_from_slice(&set.pairs);
            out.weights.extend_from_slice(&set.weights);
            for f in 0..set.len() {
                push_route_u32(&mut out.ports, set.route(f));
                out.offsets.push(arena_offset(out.ports.len()));
            }
        }
        out
    }

    /// Materialize per-flow [`RoutePorts`] (interop with consumers that
    /// still want owned per-route vectors, e.g. `routing::verify`).
    pub fn to_routes(&self) -> Vec<RoutePorts> {
        (0..self.len())
            .map(|f| {
                let (src, dst) = self.pairs[f];
                let ports = self.route(f).iter().map(|&p| p as PortId).collect();
                RoutePorts { src, dst, ports }
            })
            .collect()
    }

    /// Number of flows (self-flows included).
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    /// True when the store holds no flows at all.
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Flows that traverse at least one port (i.e. `src != dst`).
    pub fn num_active(&self) -> usize {
        (0..self.len()).filter(|&f| !self.route(f).is_empty()).count()
    }

    /// Total hops over all flows (= length of the port arena).
    pub fn total_hops(&self) -> usize {
        self.ports.len()
    }

    /// Resident bytes of the store (flow table + weights + CSR offsets +
    /// port arena) — the `bytes_per_flow` figure `BENCH_eval.json`
    /// tracks per ladder rung.
    pub fn arena_bytes(&self) -> usize {
        self.pairs.len() * std::mem::size_of::<(Nid, Nid)>()
            + self.weights.len() * std::mem::size_of::<u32>()
            + self.offsets.len() * std::mem::size_of::<u32>()
            + self.ports.len() * std::mem::size_of::<u32>()
    }

    /// `(src, dst)` of one flow.
    #[inline]
    pub fn pair(&self, flow: usize) -> (Nid, Nid) {
        self.pairs[flow]
    }

    /// Demand weight of one flow.
    #[inline]
    pub fn weight(&self, flow: usize) -> u32 {
        self.weights[flow]
    }

    /// The traced route of one flow: every output port in traversal
    /// order (empty for self-flows). Borrowed straight from the arena —
    /// no per-route allocation anywhere. Elements are 32-bit port ids;
    /// cast to `usize` to index topology tables.
    #[inline]
    pub fn route(&self, flow: usize) -> &[u32] {
        &self.ports[self.offsets[flow] as usize..self.offsets[flow + 1] as usize]
    }

    /// Iterate `((src, dst), route)` in flow order.
    pub fn iter(&self) -> impl Iterator<Item = ((Nid, Nid), &[u32])> + '_ {
        (0..self.len()).map(|f| (self.pairs[f], self.route(f)))
    }

    /// Whether a flow's stored route crosses a link the fault set killed.
    #[inline]
    pub fn crosses_fault(&self, topo: &dyn TopologyView, faults: &FaultSet, flow: usize) -> bool {
        self.route(flow).iter().any(|&p| faults.is_dead(topo.port_link(p as usize)))
    }

    /// Flows whose stored route crosses a dead link — exactly the set a
    /// fault event forces to move. An empty fault set short-circuits
    /// without touching the arena: a zero-fault sweep cell at the
    /// 256k-endpoint rung must not pay a full-arena scan to learn that
    /// nothing is dirty.
    pub fn dirty_flows(&self, topo: &dyn TopologyView, faults: &FaultSet) -> Vec<usize> {
        if faults.num_dead() == 0 {
            return Vec::new();
        }
        (0..self.len()).filter(|&f| self.crosses_fault(topo, faults, f)).collect()
    }

    /// Repair the store after a fault event: re-trace **only** the flows
    /// whose stored route crosses a dead link, copying every other route
    /// verbatim from the arena. Returns the repaired store and the
    /// number of flows whose route changed.
    ///
    /// `router` must be a fault-aware router for the same `faults` (in
    /// practice a [`crate::faults::DegradedRouter`] wrapping the cell's
    /// base algorithm). The result is **byte-identical to a full
    /// re-trace** with the same router (see the module docs for why;
    /// `debug_assert`ed here per retraced flow, property-pinned in
    /// `tests/eval_agreement.rs`), at the cost of re-tracing only the
    /// dirty flows — on a single-link fault that is a small fraction of
    /// the pattern, which is what makes fault grids cheap
    /// (`benches/bench_eval.rs` records the speedup).
    pub fn retrace_incremental(
        &self,
        topo: &dyn TopologyView,
        faults: &FaultSet,
        router: &dyn Router,
    ) -> (FlowSet, usize) {
        self.retrace_incremental_par(topo, faults, router, 1)
    }

    /// [`FlowSet::retrace_incremental`] with the dirty flows fanned out
    /// over up to `threads` workers ([`crate::util::par::par_map`]).
    ///
    /// The dirty list is split into consecutive chunks; each worker
    /// traces its chunk into a private sub-arena, and the sub-arenas
    /// are spliced back in ascending flow order. Routers are stateless
    /// per (src, dst) query, so the traced bytes do not depend on which
    /// worker produced them, and the order-preserving splice makes the
    /// result **byte-identical to the serial path for every thread
    /// count** (property-pinned in `tests/eval_agreement.rs`).
    ///
    /// Thread-count policy lives with the callers ([`repair_threads`]):
    /// below ~32k flows the scoped-thread spawns cost more than the
    /// retrace itself.
    pub fn retrace_incremental_par(
        &self,
        topo: &dyn TopologyView,
        faults: &FaultSet,
        router: &dyn Router,
        threads: usize,
    ) -> (FlowSet, usize) {
        let (out, changed, _, _) = self.retrace_core(topo, faults, router, threads);
        (out, changed)
    }

    /// [`FlowSet::retrace_incremental_par`] returning the wall-clock
    /// phase breakdown as well — the coordinator leader journals it per
    /// fault batch. The repaired store is byte-identical to the
    /// untimed paths.
    pub fn retrace_incremental_timed(
        &self,
        topo: &dyn TopologyView,
        faults: &FaultSet,
        router: &dyn Router,
        threads: usize,
    ) -> (FlowSet, usize, RetraceTiming) {
        let (out, changed, timing, _) = self.retrace_core(topo, faults, router, threads);
        (out, changed, timing)
    }

    /// [`FlowSet::retrace_incremental_par`] recording into a
    /// [`Telemetry`] handle: dirty-flow and arena-byte counters, the
    /// dirty-scan/trace/splice span breakdown, and one
    /// `eval.retrace.chunk` span per worker chunk. Workers never touch
    /// the handle — per-chunk durations ride back on the existing
    /// result channel and everything merges in one shard at the end —
    /// so a disabled handle is exactly the plain parallel path.
    pub fn retrace_incremental_telem(
        &self,
        topo: &dyn TopologyView,
        faults: &FaultSet,
        router: &dyn Router,
        threads: usize,
        telem: &Telemetry,
    ) -> (FlowSet, usize) {
        if !telem.is_enabled() {
            return self.retrace_incremental_par(topo, faults, router, threads);
        }
        let (out, changed, timing, chunk_ns) = self.retrace_core(topo, faults, router, threads);
        self.record_retrace(telem, &out, changed, &timing, &chunk_ns);
        (out, changed)
    }

    /// [`FlowSet::retrace_incremental_timed`] that additionally records
    /// the [`FlowSet::retrace_incremental_telem`] counters and spans
    /// when the handle is live — the coordinator leader journals the
    /// timing per batch *and* surfaces `eval.retrace.*` in
    /// `pgft fabric --telemetry`. Byte-identical to every other repair
    /// variant.
    pub fn retrace_incremental_timed_telem(
        &self,
        topo: &dyn TopologyView,
        faults: &FaultSet,
        router: &dyn Router,
        threads: usize,
        telem: &Telemetry,
    ) -> (FlowSet, usize, RetraceTiming) {
        let (out, changed, timing, chunk_ns) = self.retrace_core(topo, faults, router, threads);
        if telem.is_enabled() {
            self.record_retrace(telem, &out, changed, &timing, &chunk_ns);
        }
        (out, changed, timing)
    }

    /// Fold one repair's counters and spans into `telem` (the shared
    /// tail of the `_telem` variants).
    fn record_retrace(
        &self,
        telem: &Telemetry,
        out: &FlowSet,
        changed: usize,
        timing: &RetraceTiming,
        chunk_ns: &[u64],
    ) {
        let mut shard = telem.shard();
        shard.add("eval.retrace.calls", 1);
        shard.add("eval.retrace.flows", self.len() as u64);
        shard.add("eval.retrace.dirty_flows", changed as u64);
        shard.add("eval.retrace.arena_bytes", out.arena_bytes() as u64);
        shard.span_ns("eval.retrace.dirty_scan", timing.dirty_scan_ns);
        shard.span_ns("eval.retrace.trace", timing.trace_ns);
        shard.span_ns("eval.retrace.splice", timing.splice_ns);
        for &ns in chunk_ns {
            shard.span_ns("eval.retrace.chunk", ns);
        }
        telem.merge(shard);
    }

    /// The one repair implementation every public variant delegates to.
    /// Returns the repaired store, the dirty count, the phase timing,
    /// and the per-chunk trace durations (empty when nothing was
    /// dirty). The `Instant` reads cost nanoseconds against a retrace
    /// and never influence the repaired bytes.
    fn retrace_core(
        &self,
        topo: &dyn TopologyView,
        faults: &FaultSet,
        router: &dyn Router,
        threads: usize,
    ) -> (FlowSet, usize, RetraceTiming, Vec<u64>) {
        let t0 = Instant::now();
        let dirty = self.dirty_flows(topo, faults);
        let dirty_scan_ns = t0.elapsed().as_nanos() as u64;
        if dirty.is_empty() {
            let timing = RetraceTiming { dirty_scan_ns, ..Default::default() };
            return (self.clone(), 0, timing, Vec::new());
        }
        // 4 chunks per worker keeps the atomic-cursor work stealing
        // meaningful (dirty flows cluster around the dead links, so
        // chunk costs vary) without shredding the sub-arenas.
        let threads = threads.max(1);
        let chunk = dirty.len().div_ceil(threads * 4).max(1);
        let groups: Vec<&[usize]> = dirty.chunks(chunk).collect();
        // Each worker returns (sub-arena, per-flow hop counts, chunk
        // duration) for its chunk; lens delimit the sub-arena the same
        // way CSR offsets do.
        let t1 = Instant::now();
        let h = topo.spec().h;
        let traced: Vec<(Vec<u32>, Vec<u32>, u64)> = par_map(threads, &groups, |_, group| {
            let tc = Instant::now();
            let mut arena: Vec<u32> = Vec::with_capacity(group.len() * 2 * h);
            let mut lens: Vec<u32> = Vec::with_capacity(group.len());
            let mut scratch: Vec<PortId> = Vec::with_capacity(2 * h + 1);
            for &f in *group {
                let (src, dst) = self.pairs[f];
                scratch.clear();
                trace_route_into(topo, router, src, dst, &mut scratch);
                let start = arena.len();
                push_route(&mut arena, &scratch);
                lens.push(arena_offset(arena.len() - start));
                // A dirty flow always moves: its old route used a dead
                // link the fault-aware router can no longer take.
                debug_assert_ne!(
                    &arena[start..],
                    self.route(f),
                    "retrace of a dirty flow {src}->{dst} reproduced a dead-link route"
                );
            }
            (arena, lens, tc.elapsed().as_nanos() as u64)
        });
        let trace_ns = t1.elapsed().as_nanos() as u64;
        // Splice: one ordered walk over all flows, copying clean routes
        // from the old arena and dirty routes from the sub-arenas. The
        // chunks partition the ascending dirty list consecutively, so
        // three cursors (group, len index, sub-arena position) advance
        // monotonically and the output bytes equal the serial path's.
        let t2 = Instant::now();
        let mut out = FlowSet {
            pairs: self.pairs.clone(),
            weights: self.weights.clone(),
            offsets: Vec::with_capacity(self.offsets.len()),
            ports: Vec::with_capacity(self.ports.len()),
        };
        out.offsets.push(0);
        let mut di = 0usize;
        let (mut gi, mut li, mut ai) = (0usize, 0usize, 0usize);
        for f in 0..self.len() {
            if di < dirty.len() && dirty[di] == f {
                let (arena, lens, _) = &traced[gi];
                let len = lens[li] as usize;
                push_route_u32(&mut out.ports, &arena[ai..ai + len]);
                di += 1;
                li += 1;
                ai += len;
                if li == lens.len() && gi + 1 < traced.len() {
                    gi += 1;
                    li = 0;
                    ai = 0;
                }
            } else {
                push_route_u32(&mut out.ports, self.route(f));
            }
            out.offsets.push(arena_offset(out.ports.len()));
        }
        let splice_ns = t2.elapsed().as_nanos() as u64;
        let chunk_ns: Vec<u64> = traced.iter().map(|t| t.2).collect();
        let timing = RetraceTiming { dirty_scan_ns, trace_ns, splice_ns };
        (out, dirty.len(), timing, chunk_ns)
    }

    /// Number of flows whose route differs between two stores over the
    /// same flow list (the rerouting-cost figure sweep rows report).
    pub fn diff_count(&self, other: &FlowSet) -> usize {
        assert_eq!(self.pairs, other.pairs, "diff_count compares stores over the same flows");
        (0..self.len()).filter(|&f| self.route(f) != other.route(f)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::patterns::Pattern;
    use crate::routing::trace::trace_flows;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec, Topology};

    fn setup() -> (Topology, Vec<(Nid, Nid)>) {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
        (topo, flows)
    }

    fn as_u32(ports: &[PortId]) -> Vec<u32> {
        ports.iter().map(|&p| p as u32).collect()
    }

    #[test]
    fn trace_matches_route_ports_surface() {
        let (topo, flows) = setup();
        for kind in AlgorithmKind::ALL {
            let router = kind.build(&topo, None, 3);
            let set = FlowSet::trace(&topo, &*router, &flows);
            let routes = trace_flows(&topo, &*router, &flows);
            assert_eq!(set.len(), routes.len());
            assert_eq!(set.total_hops(), routes.iter().map(|r| r.ports.len()).sum::<usize>());
            for (f, r) in routes.iter().enumerate() {
                assert_eq!(set.pair(f), (r.src, r.dst), "{kind}");
                assert_eq!(set.route(f), as_u32(&r.ports).as_slice(), "{kind}");
                assert_eq!(set.weight(f), 1);
            }
            assert_eq!(set.to_routes(), routes, "{kind}");
            assert_eq!(FlowSet::from_routes(&routes), set, "{kind}");
        }
    }

    #[test]
    fn trace_presizes_the_arena_exactly() {
        let (topo, flows) = setup();
        for kind in AlgorithmKind::ALL {
            let router = kind.build(&topo, None, 3);
            let set = FlowSet::trace(&topo, &*router, &flows);
            let minimal: usize = flows
                .iter()
                .map(|&(s, d)| topo.spec.minimal_hops(s as u64, d as u64))
                .sum();
            assert_eq!(
                set.total_hops(),
                minimal,
                "{kind}: pristine routes must be minimal (the pre-size contract)"
            );
        }
    }

    #[test]
    fn self_flows_are_empty_and_inactive() {
        let (topo, _) = setup();
        let router = AlgorithmKind::Dmodk.build(&topo, None, 0);
        let set = FlowSet::trace(&topo, &*router, &[(0, 0), (0, 63), (5, 5)]);
        assert_eq!(set.len(), 3);
        assert_eq!(set.num_active(), 1);
        assert!(set.route(0).is_empty() && set.route(2).is_empty());
        assert_eq!(set.route(1).len(), 6);
        let collected: Vec<_> = set.iter().map(|(pair, route)| (pair, route.len())).collect();
        assert_eq!(collected, vec![((0, 0), 0), ((0, 63), 6), ((5, 5), 0)]);
    }

    #[test]
    fn weighted_trace_carries_weights() {
        let (topo, _) = setup();
        let router = AlgorithmKind::Dmodk.build(&topo, None, 0);
        let set = FlowSet::trace_weighted(&topo, &*router, &[(0, 63, 4), (1, 62, 1)]);
        assert_eq!(set.weight(0), 4);
        assert_eq!(set.weight(1), 1);
        let unit = FlowSet::trace(&topo, &*router, &[(0, 63), (1, 62)]);
        assert_eq!(set.route(0), unit.route(0), "weights never change routing");
    }

    #[test]
    fn concat_preserves_routes_and_order() {
        let (topo, flows) = setup();
        let router = AlgorithmKind::Gdmodk.build(&topo, None, 1);
        let a = FlowSet::trace(&topo, &*router, &flows[..10]);
        let b = FlowSet::trace(&topo, &*router, &flows[10..]);
        let union = FlowSet::concat(&[&a, &b]);
        let whole = FlowSet::trace(&topo, &*router, &flows);
        assert_eq!(union, whole, "concat of a split trace equals the whole trace");
        assert_eq!(FlowSet::concat(&[&FlowSet::empty(), &whole]), whole);
        assert_eq!(FlowSet::concat(&[]), FlowSet::empty());
    }

    #[test]
    fn arena_offset_accepts_the_boundary() {
        assert_eq!(arena_offset(0), 0);
        assert_eq!(arena_offset(FlowSet::MAX_ARENA_LEN), u32::MAX);
    }

    #[test]
    #[should_panic(expected = "port arena overflow")]
    fn arena_offset_rejects_past_the_boundary() {
        // One entry past the u32 CSR limit: the exact wrap point the
        // pre-guard `as u32` casts silently corrupted.
        arena_offset(FlowSet::MAX_ARENA_LEN + 1);
    }

    #[test]
    fn dirty_flows_short_circuits_empty_fault_sets() {
        let (topo, flows) = setup();
        let router = AlgorithmKind::Dmodk.build(&topo, None, 0);
        let set = FlowSet::trace(&topo, &*router, &flows);
        assert!(set.dirty_flows(&topo, &FaultSet::none(&topo)).is_empty());
    }

    fn bundle_faults(topo: &Topology) -> FaultSet {
        // Kill 2 of the 4 parallel links of the first L2→top bundle.
        let l2 = topo.level_switches(2).next().unwrap();
        let mut faults = FaultSet::none(topo);
        for &p in topo.switches[l2].up_ports.iter().take(2) {
            faults.kill(topo.ports[p].link);
        }
        faults
    }

    #[test]
    fn incremental_retrace_equals_full_retrace() {
        let (topo, flows) = setup();
        let faults = bundle_faults(&topo);
        for kind in AlgorithmKind::ALL {
            let base = kind.build(&topo, None, 7);
            let pristine = FlowSet::trace(&topo, &*base, &flows);
            let degraded = crate::faults::DegradedRouter::new(
                &topo,
                &faults,
                kind.build(&topo, None, 7),
            )
            .unwrap();
            let (incremental, changed) = pristine.retrace_incremental(&topo, &faults, &degraded);
            let full = FlowSet::trace(&topo, &degraded, &flows);
            assert_eq!(incremental, full, "{kind}: incremental must be byte-identical to full");
            assert_eq!(changed, pristine.diff_count(&full), "{kind}");
            assert_eq!(changed, pristine.dirty_flows(&topo, &faults).len(), "{kind}");
        }
    }

    #[test]
    fn parallel_retrace_equals_serial_for_every_thread_count() {
        let (topo, flows) = setup();
        let faults = bundle_faults(&topo);
        for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gsmodk] {
            let pristine = FlowSet::trace(&topo, &*kind.build(&topo, None, 7), &flows);
            let degraded = crate::faults::DegradedRouter::new(
                &topo,
                &faults,
                kind.build(&topo, None, 7),
            )
            .unwrap();
            let (serial, serial_changed) =
                pristine.retrace_incremental(&topo, &faults, &degraded);
            for threads in [1usize, 2, 4, 8] {
                let (par, changed) =
                    pristine.retrace_incremental_par(&topo, &faults, &degraded, threads);
                assert_eq!(par, serial, "{kind}, {threads} threads: splice must be byte-stable");
                assert_eq!(changed, serial_changed, "{kind}, {threads} threads");
            }
        }
    }

    #[test]
    fn incremental_repair_composes_across_cascade() {
        // The coordinator's chaining invariant: repairing the *previous
        // stage's* store against the grown fault set equals a full
        // trace — see the module docs' monotonicity argument.
        let (topo, _) = setup();
        let flows = crate::routing::verify::all_pairs(topo.num_nodes() as Nid);
        let scenario =
            crate::faults::FaultModel::parse("cascade:4").unwrap().generate(&topo, 2);
        for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gsmodk] {
            let base = kind.build(&topo, None, 3);
            let mut store = FlowSet::trace(&topo, &*base, &flows);
            for faults in scenario.stages(&topo) {
                let router = crate::faults::DegradedRouter::new(
                    &topo,
                    &faults,
                    kind.build(&topo, None, 3),
                )
                .unwrap();
                let (repaired, _) = store.retrace_incremental(&topo, &faults, &router);
                let full = FlowSet::trace(&topo, &router, &flows);
                assert_eq!(repaired, full, "{kind}: stage must compose from the previous one");
                store = repaired;
            }
        }
    }

    #[test]
    fn zero_faults_retrace_is_identity() {
        let (topo, flows) = setup();
        let faults = FaultSet::none(&topo);
        let base = AlgorithmKind::Gdmodk.build(&topo, None, 1);
        let pristine = FlowSet::trace(&topo, &*base, &flows);
        let degraded =
            crate::faults::DegradedRouter::new(&topo, &faults, AlgorithmKind::Gdmodk.build(&topo, None, 1))
                .unwrap();
        let (repaired, changed) = pristine.retrace_incremental(&topo, &faults, &degraded);
        assert_eq!(changed, 0);
        assert_eq!(repaired, pristine);
    }

    #[test]
    fn repair_threads_policy_gates_on_store_size() {
        assert_eq!(repair_threads(0), 1);
        assert_eq!(repair_threads(4096), 1, "case-study cells stay serial");
        assert!(repair_threads(65_536) >= 1);
        assert_eq!(repair_threads(65_536), crate::util::par::max_threads());
    }
}
