//! [`DegradedRouter`] — online rerouting for *any* base algorithm.
//!
//! The wrapper keeps the base algorithm's decisions wherever they
//! survive and deterministically falls back where they don't:
//!
//!  * **climb** — at an element that cannot pure-descend to the
//!    destination, take the base algorithm's up-port if its link is
//!    alive and its parent still reaches the destination; otherwise
//!    rotate to the next healthy viable up-port (cyclically from the
//!    preferred index, so the fallback is deterministic and stays close
//!    to the base distribution);
//!  * **descend** — start descending exactly at the first element whose
//!    pure-descent path survives ([`ReachField::descend`]); among the
//!    parallel links toward the destination's subtree, take the base
//!    algorithm's choice if alive, else rotate.
//!
//! Because routes are strictly "climb while descent is broken, then
//! descend", they are valley-free and loop-free for every fault set, so
//! the channel dependency graph stays acyclic (deadlock freedom is
//! structural, not incidental). With zero faults the preferred choice is
//! always viable and the wrapper is **byte-identical** to the base
//! router — the property `tests/fault_rerouting.rs` pins.
//!
//! Construction fails (cleanly, with the broken pair named) when some
//! node pair has no surviving up\*/down\* path — the caller decides
//! whether that scenario is an error or a skipped sweep cell.

use super::view::DegradedTopology;
use super::FaultSet;
use crate::routing::Router;
use crate::topology::{Endpoint, Nid, PortId, SwitchId, Topology};
use anyhow::{ensure, Result};

/// Bit test in a packed `Vec<u64>` bitset.
#[inline]
fn get_bit(bits: &[u64], i: usize) -> bool {
    bits[i >> 6] & (1u64 << (i & 63)) != 0
}

/// Bit set in a packed `Vec<u64>` bitset.
#[inline]
fn set_bit(bits: &mut [u64], i: usize) {
    bits[i >> 6] |= 1u64 << (i & 63);
}

/// A fault-aware wrapper around any [`Router`] (see module docs).
///
/// The per-destination reachability tables are bit-packed: the dense
/// `Vec<bool>` layout cost `n·(n + ns)` bytes — ~4.5 GiB at the 64k
/// rung of the eval ladder — while the packed form is 8× leaner and
/// indexes identically. (At 256k endpoints even the packed tables are
/// ~8.6 GiB, which is why the ladder's top rung skips the retrace leg;
/// see DESIGN.md §10.)
pub struct DegradedRouter {
    base: Box<dyn Router>,
    faults: FaultSet,
    /// Node count of the topology this was built for.
    n: usize,
    /// Switch count of the topology this was built for.
    ns: usize,
    /// Bit `dst · ns + sw` — can `sw` pure-descend to `dst`?
    descend: Vec<u64>,
    /// Bit `dst · (n + ns) + elem` — does an up\*/down\* path survive?
    /// (elements nodes-first, as in [`super::view::ReachField`]).
    good: Vec<u64>,
}

impl DegradedRouter {
    /// Wrap `base` for routing on `topo` with the given fault mask.
    /// Precomputes per-destination reachability; errors if the surviving
    /// fabric no longer connects every node pair via up\*/down\* paths.
    pub fn new(
        topo: &Topology,
        faults: &FaultSet,
        base: Box<dyn Router>,
    ) -> Result<DegradedRouter> {
        let n = topo.num_nodes();
        let ns = topo.num_switches();
        let view = DegradedTopology::new(topo, faults);
        let mut descend = vec![0u64; (n * ns).div_ceil(64)];
        let mut good = vec![0u64; (n * (n + ns)).div_ceil(64)];
        for dst in 0..n as Nid {
            let field = view.reach(dst);
            for src in 0..n {
                ensure!(
                    field.good[src],
                    "fabric partitioned: no surviving up*/down* path {src} -> {dst} \
                     ({} dead links)",
                    faults.num_dead()
                );
            }
            let d = dst as usize;
            for (sw, &v) in field.descend.iter().enumerate() {
                if v {
                    set_bit(&mut descend, d * ns + sw);
                }
            }
            for (e, &v) in field.good.iter().enumerate() {
                if v {
                    set_bit(&mut good, d * (n + ns) + e);
                }
            }
        }
        Ok(DegradedRouter { base, faults: faults.clone(), n, ns, descend, good })
    }

    /// The fault mask this router routes around.
    pub fn faults(&self) -> &FaultSet {
        &self.faults
    }

    /// Whether element `sw` still reaches `dst` (up\*/down\*).
    #[inline]
    fn switch_good(&self, sw: SwitchId, dst: Nid) -> bool {
        get_bit(&self.good, dst as usize * (self.n + self.ns) + self.n + sw)
    }

    /// An up-port is viable if its cable is alive and its parent still
    /// reaches the destination.
    #[inline]
    fn up_viable(&self, topo: &Topology, port: PortId, dst: Nid) -> bool {
        if self.faults.is_dead(topo.ports[port].link) {
            return false;
        }
        match topo.port_peer(port) {
            Endpoint::Switch(parent) => self.switch_good(parent, dst),
            Endpoint::Node(_) => false,
        }
    }

    /// First viable up-port scanning cyclically from the preferred one.
    fn pick_up(&self, topo: &Topology, ports: &[PortId], preferred: PortId, dst: Nid) -> PortId {
        let start = topo.ports[preferred].index as usize;
        debug_assert_eq!(ports[start], preferred, "preferred port not owned by element");
        for i in 0..ports.len() {
            let port = ports[(start + i) % ports.len()];
            if self.up_viable(topo, port, dst) {
                return port;
            }
        }
        unreachable!(
            "no viable up-port toward {dst}: connectivity was validated at construction"
        )
    }
}

impl Router for DegradedRouter {
    fn name(&self) -> String {
        format!("degraded[{} dead]({})", self.faults.num_dead(), self.base.name())
    }

    fn inject_port(&self, topo: &Topology, src: Nid, dst: Nid) -> PortId {
        let preferred = self.base.inject_port(topo, src, dst);
        self.pick_up(topo, &topo.nodes[src as usize].up_ports, preferred, dst)
    }

    fn up_port(&self, topo: &Topology, sw: SwitchId, src: Nid, dst: Nid) -> PortId {
        let preferred = self.base.up_port(topo, sw, src, dst);
        self.pick_up(topo, &topo.switches[sw].up_ports, preferred, dst)
    }

    fn down_link(&self, topo: &Topology, sw: SwitchId, src: Nid, dst: Nid) -> u32 {
        let level = topo.switches[sw].level;
        let p_l = topo.spec.p[level - 1];
        let preferred = self.base.down_link(topo, sw, src, dst) % p_l;
        for i in 0..p_l {
            let j = (preferred + i) % p_l;
            if !self.faults.is_dead(topo.ports[topo.down_port_toward(sw, dst, j)].link) {
                return j;
            }
        }
        unreachable!("descend_at guaranteed an alive parallel link toward {dst} at switch {sw}")
    }

    fn descend_at(&self, _topo: &Topology, sw: SwitchId, dst: Nid) -> bool {
        get_bit(&self.descend, dst as usize * self.ns + sw)
    }

    fn reaches(&self, _topo: &Topology, sw: SwitchId, dst: Nid) -> bool {
        self.switch_good(sw, dst)
    }

    fn dest_based(&self) -> bool {
        self.base.dest_based()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::routing::trace::trace_flows;
    use crate::routing::verify::{all_pairs, verify_routes};
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    fn topo() -> crate::topology::Topology {
        build_pgft(&PgftSpec::case_study())
    }

    #[test]
    fn zero_faults_is_byte_identical_to_base() {
        let t = topo();
        let types = Placement::paper_io().apply(&t).unwrap();
        let faults = FaultSet::none(&t);
        let flows = all_pairs(64);
        for kind in AlgorithmKind::ALL {
            let base = kind.build(&t, Some(&types), 3);
            let wrapped =
                DegradedRouter::new(&t, &faults, kind.build(&t, Some(&types), 3)).unwrap();
            let a = trace_flows(&t, &*base, &flows);
            let b = trace_flows(&t, &wrapped, &flows);
            assert_eq!(a, b, "{kind}: zero faults must not change a single port");
        }
    }

    #[test]
    fn reroutes_around_dead_parallel_links() {
        let t = topo();
        let types = Placement::paper_io().apply(&t).unwrap();
        // Kill 3 of 4 parallel links of the first L2→top bundle.
        let l2 = t.level_switches(2).next().unwrap();
        let mut faults = FaultSet::none(&t);
        for &p in t.switches[l2].up_ports.iter().take(3) {
            faults.kill(t.ports[p].link);
        }
        let flows = all_pairs(64);
        for kind in AlgorithmKind::ALL {
            let r = DegradedRouter::new(&t, &faults, kind.build(&t, Some(&types), 1))
                .unwrap_or_else(|e| panic!("{kind}: {e}"));
            let routes = trace_flows(&t, &r, &flows);
            let rep = verify_routes(&t, &routes);
            rep.ensure_valid().unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert!(rep.deadlock_free, "{kind}");
            assert_eq!(rep.valley_free, rep.flows, "{kind}: reroutes stay valley-free");
            for route in &routes {
                for &p in &route.ports {
                    assert!(!faults.is_dead(t.ports[p].link), "{kind} uses a dead link");
                }
            }
        }
    }

    #[test]
    fn partition_is_a_clean_error() {
        let t = topo();
        let mut faults = FaultSet::none(&t);
        faults.kill(t.ports[t.nodes[0].up_ports[0]].link); // node 0 isolated
        let err = DegradedRouter::new(&t, &faults, AlgorithmKind::Dmodk.build(&t, None, 0))
            .err()
            .expect("partition must be rejected");
        assert!(err.to_string().contains("partitioned"), "{err}");
    }

    #[test]
    fn whole_bundle_death_shifts_to_surviving_top() {
        let t = topo();
        // Kill the whole 4-link bundle of L2 switch 0: destinations in
        // subgroup 0 can no longer be reached through its paired top, so
        // every cross-subgroup flow shifts to the other top. All routes
        // stay minimal (the sibling L2 path has the same length).
        let l2 = t.level_switches(2).next().unwrap();
        let mut faults = FaultSet::none(&t);
        for &p in &t.switches[l2].up_ports {
            faults.kill(t.ports[p].link);
        }
        let r = DegradedRouter::new(&t, &faults, AlgorithmKind::Gdmodk.build(&t, None, 0))
            .unwrap();
        let routes = trace_flows(&t, &r, &all_pairs(64));
        let rep = verify_routes(&t, &routes);
        rep.ensure_valid().unwrap();
        assert!(rep.deadlock_free);
        assert_eq!(rep.minimal, rep.flows, "sibling-L2 reroutes keep minimal length");
        for route in &routes {
            for &p in &route.ports {
                assert!(!faults.is_dead(t.ports[p].link));
            }
        }
    }

    #[test]
    fn dead_node_link_forces_plane_selection() {
        // A PGFT with w1 = 2 is two independent routing "planes" (every
        // bottom digit commits the descent path). Killing one of the
        // destination's two node links poisons that whole plane for the
        // destination: the reachability fields propagate the breakage
        // down to the injection choice, every route to node 0 enters at
        // plane 1, and — because PGFT descent is committed per plane —
        // all reroutes stay minimal.
        let spec = PgftSpec::new(vec![4, 4], vec![2, 2], vec![1, 1]).unwrap();
        let t = build_pgft(&spec);
        let dead_port = t.nodes[0].up_ports[0];
        let mut faults = FaultSet::none(&t);
        faults.kill(t.ports[dead_port].link);
        let r = DegradedRouter::new(&t, &faults, AlgorithmKind::Dmodk.build(&t, None, 0))
            .unwrap();
        let routes = trace_flows(&t, &r, &all_pairs(t.num_nodes() as u32));
        let rep = verify_routes(&t, &routes);
        rep.ensure_valid().unwrap();
        assert!(rep.deadlock_free);
        assert_eq!(rep.minimal, rep.flows, "plane selection keeps routes minimal");
        // Every route to node 0 must arrive through the surviving plane:
        // its final hop is node 0's other (plane-1) leaf link.
        let alive_leaf = match t.port_peer(t.nodes[0].up_ports[1]) {
            Endpoint::Switch(s) => s,
            Endpoint::Node(_) => unreachable!(),
        };
        for route in routes.iter().filter(|r| r.dst == 0 && r.src != 0) {
            let last = *route.ports.last().unwrap();
            match t.ports[last].owner {
                Endpoint::Switch(s) => assert_eq!(s, alive_leaf, "{}->0", route.src),
                Endpoint::Node(_) => panic!("final hop must be a leaf down-port"),
            }
        }
    }
}
