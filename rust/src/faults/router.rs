//! [`DegradedRouter`] — online rerouting for *any* base algorithm.
//!
//! The wrapper keeps the base algorithm's decisions wherever they
//! survive and deterministically falls back where they don't:
//!
//!  * **climb** — at an element that cannot pure-descend to the
//!    destination, take the base algorithm's up-port if its link is
//!    alive and its parent still reaches the destination; otherwise
//!    rotate to the next healthy viable up-port (cyclically from the
//!    preferred index, so the fallback is deterministic and stays close
//!    to the base distribution);
//!  * **descend** — start descending exactly at the first element whose
//!    pure-descent path survives ([`ReachField::descend`]); among the
//!    parallel links toward the destination's subtree, take the base
//!    algorithm's choice if alive, else rotate.
//!
//! Because routes are strictly "climb while descent is broken, then
//! descend", they are valley-free and loop-free for every fault set, so
//! the channel dependency graph stays acyclic (deadlock freedom is
//! structural, not incidental). With zero faults the preferred choice is
//! always viable and the wrapper is **byte-identical** to the base
//! router — the property `tests/fault_rerouting.rs` pins.
//!
//! Construction fails (cleanly, with the broken pair named) when some
//! node pair has no surviving up\*/down\* path — the caller decides
//! whether that scenario is an error or a skipped sweep cell.

use super::view::DegradedTopology;
use super::FaultSet;
use crate::routing::Router;
use crate::topology::{Endpoint, Nid, PortId, SwitchId, Topology, TopologyView};
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// Bit test in a packed `Vec<u64>` bitset.
#[inline]
fn get_bit(bits: &[u64], i: usize) -> bool {
    bits[i >> 6] & (1u64 << (i & 63)) != 0
}

/// Bit set in a packed `Vec<u64>` bitset.
#[inline]
fn set_bit(bits: &mut [u64], i: usize) {
    bits[i >> 6] |= 1u64 << (i & 63);
}

/// Default reach-arena budget for [`DegradedRouter::new_lazy`]: 256 MiB,
/// far above what a retrace's dirty-destination working set needs at any
/// ladder rung, far below the ~8.6 GiB the eager tables cost at 256k.
pub const DEFAULT_REACH_BUDGET: usize = 256 << 20;

/// Residency/throughput counters of the lazy reachability arena
/// (all zero in eager mode). Exported to telemetry as `eval.reach.*`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReachStats {
    /// Destination entries computed (arena misses).
    pub computed: u64,
    /// Queries served by a resident destination entry.
    pub hits: u64,
    /// Destination entries dropped by arena flushes.
    pub evictions: u64,
    /// Approximate resident bytes right now.
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes`.
    pub peak_bytes: u64,
}

/// Per-destination lazy reachability: descend bits for the destination's
/// ancestor cone plus a memo of good-switch verdicts actually queried.
struct ReachEntry {
    /// Packed descend bits: level `l`'s `W_l` ancestors at bit offset
    /// `level_bit_off[l-1]` (non-ancestors can never pure-descend).
    descend: Vec<u64>,
    /// Memoized "does `sw` still reach dst" verdicts, filled by the
    /// upward recursion as routes actually query them.
    good: HashMap<SwitchId, bool>,
}

/// The lazy arena: destination entries under a byte budget. When the
/// budget would be exceeded the whole arena is reclaimed (arena-style
/// flush, not per-entry LRU: eviction is O(1) amortized, deterministic,
/// and a retrace's dirty destinations are visited in grouped runs, so
/// refaulting is rare — see DESIGN.md §12).
struct LazyReach {
    budget: usize,
    bytes: usize,
    /// Bit offset of each level's ancestor slice in a `ReachEntry`
    /// (`level_bit_off[h]` = total bits).
    level_bit_off: Vec<usize>,
    entries: HashMap<Nid, ReachEntry>,
    stats: ReachStats,
}

/// Approximate heap bytes of one memoized good verdict (HashMap entry
/// plus load-factor slack) — only budget accounting, not an allocator.
const MEMO_ENTRY_BYTES: usize = 48;

impl LazyReach {
    fn new(spec: &crate::topology::PgftSpec, budget: usize) -> LazyReach {
        let mut level_bit_off = Vec::with_capacity(spec.h + 1);
        let mut acc = 0usize;
        for l in 1..=spec.h {
            level_bit_off.push(acc);
            acc += spec.w_prefix(l) as usize;
        }
        level_bit_off.push(acc);
        LazyReach { budget, bytes: 0, level_bit_off, entries: HashMap::new(), stats: ReachStats::default() }
    }

    /// Ensure `dst`'s entry is resident, flushing the arena first if the
    /// budget would be exceeded. Returns whether it was computed fresh.
    fn ensure(&mut self, topo: &dyn TopologyView, faults: &FaultSet, dst: Nid) -> bool {
        if self.entries.contains_key(&dst) {
            self.stats.hits += 1;
            return false;
        }
        let total_bits = *self.level_bit_off.last().unwrap();
        let entry_bytes = total_bits.div_ceil(64) * 8 + std::mem::size_of::<ReachEntry>();
        if self.bytes + entry_bytes > self.budget && !self.entries.is_empty() {
            self.stats.evictions += self.entries.len() as u64;
            self.entries.clear();
            self.bytes = 0;
        }
        // Bottom-up over the ancestor cone only (Σ W_l switches, not ns):
        // a switch pure-descends iff some alive parallel link leads to a
        // child that pure-descends (level 1: to the destination node).
        // Identical to the full-fabric pass in `DegradedTopology::reach`
        // restricted to ancestors — non-ancestors never descend.
        let spec = topo.spec();
        let mut descend = vec![0u64; total_bits.div_ceil(64)];
        for l in 1..=spec.h {
            let anc = topo.ancestors_at(l, dst);
            let child_anc_start = if l > 1 { topo.ancestors_at(l - 1, dst).start } else { 0 };
            for sw in anc.clone() {
                let off = self.level_bit_off[l - 1] + (sw - anc.start);
                let alive = (0..spec.p[l - 1]).any(|j| {
                    let port = topo.down_port_toward(sw, dst, j);
                    if faults.is_dead(topo.port_link(port)) {
                        return false;
                    }
                    match topo.port_peer(port) {
                        Endpoint::Node(peer) => peer == dst,
                        Endpoint::Switch(child) => {
                            let coff = self.level_bit_off[l - 2] + (child - child_anc_start);
                            get_bit(&descend, coff)
                        }
                    }
                });
                if alive {
                    set_bit(&mut descend, off);
                }
            }
        }
        self.entries.insert(dst, ReachEntry { descend, good: HashMap::new() });
        self.bytes += entry_bytes;
        self.stats.computed += 1;
        self.stats.resident_bytes = self.bytes as u64;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.bytes as u64);
        true
    }

    /// Descend bit for an arbitrary switch (false off the ancestor cone).
    fn descend_at(&mut self, topo: &dyn TopologyView, faults: &FaultSet, sw: SwitchId, dst: Nid) -> bool {
        self.ensure(topo, faults, dst);
        let l = topo.switch_level(sw);
        let anc = topo.ancestors_at(l, dst);
        if !anc.contains(&sw) {
            return false;
        }
        let off = self.level_bit_off[l - 1] + (sw - anc.start);
        get_bit(&self.entries[&dst].descend, off)
    }

    /// Memoized upward recursion: `sw` reaches `dst` iff it
    /// pure-descends or some alive up-link leads to a parent that does.
    /// The one-pass top-down sweep of the eager tables computes exactly
    /// this fixpoint (up-links are strictly level-increasing, so the
    /// recursion terminates at the top level), which keeps lazy and
    /// eager verdicts — and therefore every routing decision —
    /// byte-identical.
    fn switch_good(&mut self, topo: &dyn TopologyView, faults: &FaultSet, sw: SwitchId, dst: Nid) -> bool {
        self.ensure(topo, faults, dst);
        if let Some(&v) = self.entries[&dst].good.get(&sw) {
            self.stats.hits += 1;
            return v;
        }
        let v = if self.descend_at(topo, faults, sw, dst) {
            true
        } else {
            let l = topo.switch_level(sw);
            let spec = topo.spec();
            (0..spec.up_ports_at(l)).any(|u| {
                let port = topo.switch_up_port(sw, u);
                if faults.is_dead(topo.port_link(port)) {
                    return false;
                }
                match topo.port_peer(port) {
                    Endpoint::Switch(parent) => self.switch_good(topo, faults, parent, dst),
                    Endpoint::Node(_) => false,
                }
            })
        };
        self.entries.get_mut(&dst).expect("entry resident").good.insert(sw, v);
        self.bytes += MEMO_ENTRY_BYTES;
        self.stats.resident_bytes = self.bytes as u64;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.bytes as u64);
        v
    }
}

/// Where the per-destination reachability verdicts come from.
enum ReachStore {
    /// All destinations precomputed and bit-packed at construction
    /// (validates full connectivity; `n·(n+2·ns)` bits — ~8.6 GiB at the
    /// 256k rung, which is what priced the big rungs out before the lazy
    /// mode existed).
    Eager {
        /// Bit `dst · ns + sw` — can `sw` pure-descend to `dst`?
        descend: Vec<u64>,
        /// Bit `dst · (n + ns) + elem` — does an up\*/down\* path
        /// survive? (elements nodes-first, as in
        /// [`super::view::ReachField`]).
        good: Vec<u64>,
    },
    /// Destinations computed on first query under a byte budget —
    /// O(dirty destinations), not O(n), during an incremental retrace.
    Lazy(Mutex<LazyReach>),
}

/// A fault-aware wrapper around any [`Router`] (see module docs).
///
/// Two reachability strategies share identical routing decisions:
///
/// * [`DegradedRouter::new`] — **eager**: every destination's bit-packed
///   descend/good tables precomputed, full connectivity validated up
///   front (a partition is a clean `Err`). `n·(n+2·ns)` bits: fine
///   through 64k endpoints, ~8.6 GiB at 256k.
/// * [`DegradedRouter::new_lazy`] — **memory-bounded**: per-destination
///   reachability computed on first query (descend over the Σ W_l
///   ancestor cone, good via memoized upward recursion) and kept in an
///   arena under a byte budget. An incremental retrace only queries the
///   fault-dirty destinations, so the 256k retrace leg and the 1M
///   `links:K` legs run in tens of MiB. No up-front validation: routing
///   a pair the surviving fabric no longer connects panics with the
///   partition named (the ladder's stage≥2 `links:K` scenarios cannot
///   partition node links).
pub struct DegradedRouter {
    base: Box<dyn Router>,
    faults: FaultSet,
    /// Node count of the topology this was built for.
    n: usize,
    /// Switch count of the topology this was built for.
    ns: usize,
    reach: ReachStore,
}

impl DegradedRouter {
    /// Wrap `base` for routing on `topo` with the given fault mask.
    /// Precomputes per-destination reachability; errors if the surviving
    /// fabric no longer connects every node pair via up\*/down\* paths.
    pub fn new(
        topo: &Topology,
        faults: &FaultSet,
        base: Box<dyn Router>,
    ) -> Result<DegradedRouter> {
        let n = topo.num_nodes();
        let ns = topo.num_switches();
        let view = DegradedTopology::new(topo, faults);
        let mut descend = vec![0u64; (n * ns).div_ceil(64)];
        let mut good = vec![0u64; (n * (n + ns)).div_ceil(64)];
        for dst in 0..n as Nid {
            let field = view.reach(dst);
            for src in 0..n {
                ensure!(
                    field.good[src],
                    "fabric partitioned: no surviving up*/down* path {src} -> {dst} \
                     ({} dead links)",
                    faults.num_dead()
                );
            }
            let d = dst as usize;
            for (sw, &v) in field.descend.iter().enumerate() {
                if v {
                    set_bit(&mut descend, d * ns + sw);
                }
            }
            for (e, &v) in field.good.iter().enumerate() {
                if v {
                    set_bit(&mut good, d * (n + ns) + e);
                }
            }
        }
        Ok(DegradedRouter {
            base,
            faults: faults.clone(),
            n,
            ns,
            reach: ReachStore::Eager { descend, good },
        })
    }

    /// Memory-bounded wrapper over any [`TopologyView`]: reachability is
    /// computed per destination on first query and kept in an arena of at
    /// most ~`budget` bytes (see [`DEFAULT_REACH_BUDGET`]). Construction
    /// is O(1); routing decisions are byte-identical to [`DegradedRouter::new`].
    pub fn new_lazy(
        topo: &dyn TopologyView,
        faults: &FaultSet,
        base: Box<dyn Router>,
        budget: usize,
    ) -> DegradedRouter {
        DegradedRouter {
            base,
            faults: faults.clone(),
            n: topo.num_nodes(),
            ns: topo.num_switches(),
            reach: ReachStore::Lazy(Mutex::new(LazyReach::new(topo.spec(), budget))),
        }
    }

    /// [`DegradedRouter::new_lazy`] with the eager constructor's
    /// up-front connectivity validation: a partitioned surviving fabric
    /// is a clean `Err` (with the broken pair named) instead of a panic
    /// on first query. Costs one reachability field per destination at
    /// construction — nothing is retained — then routes through the
    /// memory-bounded lazy arena. This is what long-lived services (the
    /// coordinator leader) use: eager validation semantics, lazy
    /// memory, live [`ReachStats`].
    pub fn new_lazy_checked(
        topo: &Topology,
        faults: &FaultSet,
        base: Box<dyn Router>,
        budget: usize,
    ) -> Result<DegradedRouter> {
        let n = topo.num_nodes();
        let view = DegradedTopology::new(topo, faults);
        for dst in 0..n as Nid {
            let field = view.reach(dst);
            for src in 0..n {
                ensure!(
                    field.good[src],
                    "fabric partitioned: no surviving up*/down* path {src} -> {dst} \
                     ({} dead links)",
                    faults.num_dead()
                );
            }
        }
        Ok(DegradedRouter::new_lazy(topo, faults, base, budget))
    }

    /// The fault mask this router routes around.
    pub fn faults(&self) -> &FaultSet {
        &self.faults
    }

    /// Residency counters of the lazy reach arena (zeros in eager mode).
    pub fn reach_stats(&self) -> ReachStats {
        match &self.reach {
            ReachStore::Eager { .. } => ReachStats::default(),
            ReachStore::Lazy(m) => m.lock().expect("reach arena poisoned").stats,
        }
    }

    /// Whether element `sw` still reaches `dst` (up\*/down\*).
    #[inline]
    fn switch_good(&self, topo: &dyn TopologyView, sw: SwitchId, dst: Nid) -> bool {
        match &self.reach {
            ReachStore::Eager { good, .. } => {
                get_bit(good, dst as usize * (self.n + self.ns) + self.n + sw)
            }
            ReachStore::Lazy(m) => m
                .lock()
                .expect("reach arena poisoned")
                .switch_good(topo, &self.faults, sw, dst),
        }
    }

    /// An up-port is viable if its cable is alive and its parent still
    /// reaches the destination.
    #[inline]
    fn up_viable(&self, topo: &dyn TopologyView, port: PortId, dst: Nid) -> bool {
        if self.faults.is_dead(topo.port_link(port)) {
            return false;
        }
        match topo.port_peer(port) {
            Endpoint::Switch(parent) => self.switch_good(topo, parent, dst),
            Endpoint::Node(_) => false,
        }
    }

    /// First viable up-port scanning cyclically from the preferred one;
    /// `port_of` maps an up-port index to the port id (node or switch
    /// accessor) and `count` is the element's up-port count.
    fn pick_up(
        &self,
        topo: &dyn TopologyView,
        count: u32,
        port_of: &dyn Fn(u32) -> PortId,
        preferred: PortId,
        dst: Nid,
    ) -> PortId {
        let start = topo.port_index(preferred);
        debug_assert_eq!(port_of(start), preferred, "preferred port not owned by element");
        for i in 0..count {
            let port = port_of((start + i) % count);
            if self.up_viable(topo, port, dst) {
                return port;
            }
        }
        unreachable!(
            "no viable up-port toward {dst}: fabric partitioned (eager mode validates \
             this at construction; lazy mode surfaces it here)"
        )
    }
}

impl Router for DegradedRouter {
    fn name(&self) -> String {
        format!("degraded[{} dead]({})", self.faults.num_dead(), self.base.name())
    }

    fn inject_port(&self, topo: &dyn TopologyView, src: Nid, dst: Nid) -> PortId {
        let preferred = self.base.inject_port(topo, src, dst);
        let count = topo.spec().up_ports_at(0);
        self.pick_up(topo, count, &|u| topo.node_up_port(src, u), preferred, dst)
    }

    fn up_port(&self, topo: &dyn TopologyView, sw: SwitchId, src: Nid, dst: Nid) -> PortId {
        let preferred = self.base.up_port(topo, sw, src, dst);
        let count = topo.spec().up_ports_at(topo.switch_level(sw));
        self.pick_up(topo, count, &|u| topo.switch_up_port(sw, u), preferred, dst)
    }

    fn down_link(&self, topo: &dyn TopologyView, sw: SwitchId, src: Nid, dst: Nid) -> u32 {
        let level = topo.switch_level(sw);
        let p_l = topo.spec().p[level - 1];
        let preferred = self.base.down_link(topo, sw, src, dst) % p_l;
        for i in 0..p_l {
            let j = (preferred + i) % p_l;
            if !self.faults.is_dead(topo.port_link(topo.down_port_toward(sw, dst, j))) {
                return j;
            }
        }
        unreachable!("descend_at guaranteed an alive parallel link toward {dst} at switch {sw}")
    }

    fn descend_at(&self, topo: &dyn TopologyView, sw: SwitchId, dst: Nid) -> bool {
        match &self.reach {
            ReachStore::Eager { descend, .. } => get_bit(descend, dst as usize * self.ns + sw),
            ReachStore::Lazy(m) => m
                .lock()
                .expect("reach arena poisoned")
                .descend_at(topo, &self.faults, sw, dst),
        }
    }

    fn reaches(&self, topo: &dyn TopologyView, sw: SwitchId, dst: Nid) -> bool {
        self.switch_good(topo, sw, dst)
    }

    fn dest_based(&self) -> bool {
        self.base.dest_based()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::routing::trace::trace_flows;
    use crate::routing::verify::{all_pairs, verify_routes};
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    fn topo() -> crate::topology::Topology {
        build_pgft(&PgftSpec::case_study())
    }

    #[test]
    fn zero_faults_is_byte_identical_to_base() {
        let t = topo();
        let types = Placement::paper_io().apply(&t).unwrap();
        let faults = FaultSet::none(&t);
        let flows = all_pairs(64);
        for kind in AlgorithmKind::ALL {
            let base = kind.build(&t, Some(&types), 3);
            let wrapped =
                DegradedRouter::new(&t, &faults, kind.build(&t, Some(&types), 3)).unwrap();
            let a = trace_flows(&t, &*base, &flows);
            let b = trace_flows(&t, &wrapped, &flows);
            assert_eq!(a, b, "{kind}: zero faults must not change a single port");
        }
    }

    #[test]
    fn reroutes_around_dead_parallel_links() {
        let t = topo();
        let types = Placement::paper_io().apply(&t).unwrap();
        // Kill 3 of 4 parallel links of the first L2→top bundle.
        let l2 = t.level_switches(2).next().unwrap();
        let mut faults = FaultSet::none(&t);
        for &p in t.switches[l2].up_ports.iter().take(3) {
            faults.kill(t.ports[p].link);
        }
        let flows = all_pairs(64);
        for kind in AlgorithmKind::ALL {
            let r = DegradedRouter::new(&t, &faults, kind.build(&t, Some(&types), 1))
                .unwrap_or_else(|e| panic!("{kind}: {e}"));
            let routes = trace_flows(&t, &r, &flows);
            let rep = verify_routes(&t, &routes);
            rep.ensure_valid().unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert!(rep.deadlock_free, "{kind}");
            assert_eq!(rep.valley_free, rep.flows, "{kind}: reroutes stay valley-free");
            for route in &routes {
                for &p in &route.ports {
                    assert!(!faults.is_dead(t.ports[p].link), "{kind} uses a dead link");
                }
            }
        }
    }

    #[test]
    fn partition_is_a_clean_error() {
        let t = topo();
        let mut faults = FaultSet::none(&t);
        faults.kill(t.ports[t.nodes[0].up_ports[0]].link); // node 0 isolated
        let err = DegradedRouter::new(&t, &faults, AlgorithmKind::Dmodk.build(&t, None, 0))
            .err()
            .expect("partition must be rejected");
        assert!(err.to_string().contains("partitioned"), "{err}");
    }

    /// The checked-lazy constructor validates like eager, routes like
    /// lazy (live reach stats included).
    #[test]
    fn lazy_checked_validates_and_routes_like_eager() {
        let t = topo();
        let mut faults = FaultSet::none(&t);
        faults.kill(t.ports[t.nodes[0].up_ports[0]].link); // node 0 isolated
        let err = DegradedRouter::new_lazy_checked(
            &t,
            &faults,
            AlgorithmKind::Dmodk.build(&t, None, 0),
            DEFAULT_REACH_BUDGET,
        )
        .err()
        .expect("partition must be rejected up front");
        assert!(err.to_string().contains("partitioned"), "{err}");

        let mut faults = FaultSet::none(&t);
        let l2 = t.level_switches(2).next().unwrap();
        for &p in t.switches[l2].up_ports.iter().take(3) {
            faults.kill(t.ports[p].link);
        }
        let flows = all_pairs(64);
        let eager =
            DegradedRouter::new(&t, &faults, AlgorithmKind::Gdmodk.build(&t, None, 1)).unwrap();
        let checked = DegradedRouter::new_lazy_checked(
            &t,
            &faults,
            AlgorithmKind::Gdmodk.build(&t, None, 1),
            DEFAULT_REACH_BUDGET,
        )
        .unwrap();
        assert_eq!(trace_flows(&t, &eager, &flows), trace_flows(&t, &checked, &flows));
        let stats = checked.reach_stats();
        assert!(stats.computed > 0 && stats.peak_bytes > 0, "{stats:?}");
    }

    #[test]
    fn whole_bundle_death_shifts_to_surviving_top() {
        let t = topo();
        // Kill the whole 4-link bundle of L2 switch 0: destinations in
        // subgroup 0 can no longer be reached through its paired top, so
        // every cross-subgroup flow shifts to the other top. All routes
        // stay minimal (the sibling L2 path has the same length).
        let l2 = t.level_switches(2).next().unwrap();
        let mut faults = FaultSet::none(&t);
        for &p in &t.switches[l2].up_ports {
            faults.kill(t.ports[p].link);
        }
        let r = DegradedRouter::new(&t, &faults, AlgorithmKind::Gdmodk.build(&t, None, 0))
            .unwrap();
        let routes = trace_flows(&t, &r, &all_pairs(64));
        let rep = verify_routes(&t, &routes);
        rep.ensure_valid().unwrap();
        assert!(rep.deadlock_free);
        assert_eq!(rep.minimal, rep.flows, "sibling-L2 reroutes keep minimal length");
        for route in &routes {
            for &p in &route.ports {
                assert!(!faults.is_dead(t.ports[p].link));
            }
        }
    }

    /// Lazy (memory-bounded) reachability must reproduce the eager
    /// tables' routing decisions port for port — on the materialized
    /// graph *and* through the implicit topology view.
    #[test]
    fn lazy_reach_is_byte_identical_to_eager() {
        let spec = PgftSpec::case_study();
        let t = topo();
        let implicit = crate::topology::ImplicitTopology::new(&spec);
        let mut faults = FaultSet::none(&t);
        // A mixed scenario: part of a parallel bundle plus a leaf uplink.
        let l2 = t.level_switches(2).next().unwrap();
        for &p in t.switches[l2].up_ports.iter().take(2) {
            faults.kill(t.ports[p].link);
        }
        let leaf = t.level_switches(1).next().unwrap();
        faults.kill(t.ports[t.switches[leaf].up_ports[0]].link);
        let flows = all_pairs(64);
        for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gsmodk, AlgorithmKind::RandomPair] {
            let eager = DegradedRouter::new(&t, &faults, kind.build(&t, None, 9)).unwrap();
            let lazy = DegradedRouter::new_lazy(
                &t,
                &faults,
                kind.build(&t, None, 9),
                super::DEFAULT_REACH_BUDGET,
            );
            let lazy_impl = DegradedRouter::new_lazy(
                &implicit,
                &faults,
                kind.build(&t, None, 9),
                super::DEFAULT_REACH_BUDGET,
            );
            let a = trace_flows(&t, &eager, &flows);
            assert_eq!(a, trace_flows(&t, &lazy, &flows), "{kind}: lazy != eager");
            assert_eq!(a, trace_flows(&implicit, &lazy_impl, &flows), "{kind}: implicit != tables");
            let stats = lazy.reach_stats();
            assert_eq!(stats.computed, 64, "one reach entry per destination");
            assert!(stats.hits > 0 && stats.resident_bytes > 0);
            assert_eq!(eager.reach_stats(), super::ReachStats::default());
        }
    }

    /// A starvation-level budget forces arena flushes but must not change
    /// a single routing decision.
    #[test]
    fn tiny_budget_evicts_but_routes_identically() {
        let t = topo();
        let mut faults = FaultSet::none(&t);
        let l2 = t.level_switches(2).next().unwrap();
        for &p in t.switches[l2].up_ports.iter().take(3) {
            faults.kill(t.ports[p].link);
        }
        let flows = all_pairs(64);
        let eager = DegradedRouter::new(&t, &faults, AlgorithmKind::Dmodk.build(&t, None, 0)).unwrap();
        let lazy =
            DegradedRouter::new_lazy(&t, &faults, AlgorithmKind::Dmodk.build(&t, None, 0), 1);
        assert_eq!(trace_flows(&t, &eager, &flows), trace_flows(&t, &lazy, &flows));
        let stats = lazy.reach_stats();
        assert!(stats.evictions > 0, "a 1-byte budget must flush between destinations");
        assert!(stats.computed >= 64, "flushed destinations recompute on refault");
    }

    #[test]
    fn dead_node_link_forces_plane_selection() {
        // A PGFT with w1 = 2 is two independent routing "planes" (every
        // bottom digit commits the descent path). Killing one of the
        // destination's two node links poisons that whole plane for the
        // destination: the reachability fields propagate the breakage
        // down to the injection choice, every route to node 0 enters at
        // plane 1, and — because PGFT descent is committed per plane —
        // all reroutes stay minimal.
        let spec = PgftSpec::new(vec![4, 4], vec![2, 2], vec![1, 1]).unwrap();
        let t = build_pgft(&spec);
        let dead_port = t.nodes[0].up_ports[0];
        let mut faults = FaultSet::none(&t);
        faults.kill(t.ports[dead_port].link);
        let r = DegradedRouter::new(&t, &faults, AlgorithmKind::Dmodk.build(&t, None, 0))
            .unwrap();
        let routes = trace_flows(&t, &r, &all_pairs(t.num_nodes() as u32));
        let rep = verify_routes(&t, &routes);
        rep.ensure_valid().unwrap();
        assert!(rep.deadlock_free);
        assert_eq!(rep.minimal, rep.flows, "plane selection keeps routes minimal");
        // Every route to node 0 must arrive through the surviving plane:
        // its final hop is node 0's other (plane-1) leaf link.
        let alive_leaf = match t.port_peer(t.nodes[0].up_ports[1]) {
            Endpoint::Switch(s) => s,
            Endpoint::Node(_) => unreachable!(),
        };
        for route in routes.iter().filter(|r| r.dst == 0 && r.src != 0) {
            let last = *route.ports.last().unwrap();
            match t.ports[last].owner {
                Endpoint::Switch(s) => assert_eq!(s, alive_leaf, "{}->0", route.src),
                Endpoint::Node(_) => panic!("final hop must be a leaf down-port"),
            }
        }
    }
}
