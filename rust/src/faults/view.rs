//! A fault-masking view over a built topology.
//!
//! [`DegradedTopology`] borrows a [`Topology`] and a [`FaultSet`] and
//! answers "is this port usable?" without rebuilding or copying the
//! graph — the graph is immutable, only the mask changes, which is what
//! lets the coordinator reroute in microseconds.
//!
//! The view also computes the *up\*/down\* reachability fields* the
//! fault-aware router needs ([`ReachField`]): for a destination `d`,
//!
//!  * `descend[sw]` — switch `sw` can reach `d` by **descending only**
//!    over healthy links (this implies `sw` is an ancestor of `d`; the
//!    descent path through `d`'s digits is forced, only the parallel-link
//!    choice is free);
//!  * `good[e]` — element `e` can reach `d` by a (possibly empty) healthy
//!    climb followed by a healthy descent — i.e. an up\*/down\* path
//!    survives.
//!
//! Routes restricted to "climb while `!descend`, then descend" are
//! loop-free and valley-free by construction, which keeps the channel
//! dependency graph acyclic (deadlock freedom) no matter what failed.

use super::FaultSet;
use crate::topology::{Endpoint, LinkId, Nid, PortId, Topology};
use anyhow::{ensure, Result};

/// A borrowed (topology, fault set) pair: the degraded fabric.
#[derive(Clone, Copy)]
pub struct DegradedTopology<'a> {
    /// The underlying (pristine) graph.
    pub topo: &'a Topology,
    /// The failure mask.
    pub faults: &'a FaultSet,
}

/// Per-destination up\*/down\* reachability on a degraded fabric (see
/// the module docs for the exact semantics).
#[derive(Clone, Debug)]
pub struct ReachField {
    /// The destination these fields describe.
    pub dst: Nid,
    /// `descend[sw]` — can `sw` pure-descend to `dst`? Indexed by
    /// [`crate::topology::SwitchId`].
    pub descend: Vec<bool>,
    /// `good[e]` — does an up\*/down\* path from `e` to `dst` survive?
    /// Element-indexed: nodes first (`0..n`), then switches (`n..n+s`).
    pub good: Vec<bool>,
}

impl ReachField {
    /// Element index of a node (nodes-first convention).
    #[inline]
    pub fn node_elem(nid: Nid) -> usize {
        nid as usize
    }

    /// Element index of a switch in a fabric with `n` nodes.
    #[inline]
    pub fn switch_elem(n: usize, sw: usize) -> usize {
        n + sw
    }
}

impl<'a> DegradedTopology<'a> {
    /// Wrap a topology with a failure mask.
    pub fn new(topo: &'a Topology, faults: &'a FaultSet) -> DegradedTopology<'a> {
        DegradedTopology { topo, faults }
    }

    /// Whether a link survives.
    #[inline]
    pub fn link_alive(&self, l: LinkId) -> bool {
        !self.faults.is_dead(l)
    }

    /// Whether a directed output port's cable survives.
    #[inline]
    pub fn port_alive(&self, p: PortId) -> bool {
        !self.faults.is_dead(self.topo.ports[p].link)
    }

    /// Number of dead links in the mask.
    pub fn num_dead_links(&self) -> usize {
        self.faults.num_dead()
    }

    /// Compute the up\*/down\* reachability fields for one destination.
    pub fn reach(&self, dst: Nid) -> ReachField {
        let topo = self.topo;
        let n = topo.num_nodes();
        let ns = topo.num_switches();
        let h = topo.spec.h;
        let mut descend = vec![false; ns];
        let mut good = vec![false; n + ns];
        good[dst as usize] = true;

        // Descent feasibility, bottom-up: an ancestor can descend iff
        // one of its parallel links toward dst's subtree survives AND
        // the element below it can keep descending (the node itself at
        // level 1). Only the W_l ancestors per level matter —
        // `ancestors_at` enumerates them directly instead of scanning
        // the level.
        for l in 1..=h {
            for sw in topo.ancestors_at(l, dst) {
                let p_l = topo.spec.p[l - 1];
                descend[sw] = (0..p_l).any(|j| {
                    let port = topo.down_port_toward(sw, dst, j);
                    if !self.port_alive(port) {
                        return false;
                    }
                    match topo.port_peer(port) {
                        Endpoint::Node(peer) => peer == dst,
                        Endpoint::Switch(child) => descend[child],
                    }
                });
            }
        }

        // Up*/down* reachability, top-down: an element is good if it can
        // descend, or if a healthy up-link reaches a good parent.
        for l in (1..=h).rev() {
            for sw in topo.level_switches(l) {
                let s = &topo.switches[sw];
                good[n + sw] = descend[sw]
                    || s.up_ports.iter().any(|&p| {
                        self.port_alive(p)
                            && match topo.port_peer(p) {
                                Endpoint::Switch(parent) => good[n + parent],
                                Endpoint::Node(_) => false,
                            }
                    });
            }
        }
        for node in &topo.nodes {
            if node.nid == dst {
                continue;
            }
            good[node.nid as usize] = node.up_ports.iter().any(|&p| {
                self.port_alive(p)
                    && match topo.port_peer(p) {
                        Endpoint::Switch(leaf) => good[n + leaf],
                        Endpoint::Node(_) => false,
                    }
            });
        }

        ReachField { dst, descend, good }
    }

    /// Whether every node pair still has a surviving up\*/down\* path —
    /// the "surviving spanning fabric" predicate the rerouting tests
    /// condition on. `O(n · E)`.
    pub fn updown_connected(&self) -> bool {
        let n = self.topo.num_nodes() as Nid;
        (0..n).all(|dst| {
            let f = self.reach(dst);
            (0..n).all(|src| f.good[src as usize])
        })
    }

    /// Like [`DegradedTopology::updown_connected`] but reports the first
    /// broken pair for diagnostics.
    pub fn ensure_updown_connected(&self) -> Result<()> {
        let n = self.topo.num_nodes() as Nid;
        for dst in 0..n {
            let f = self.reach(dst);
            for src in 0..n {
                ensure!(
                    f.good[src as usize],
                    "fabric partitioned: no surviving up*/down* path {src} -> {dst} \
                     ({} dead links)",
                    self.faults.num_dead()
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_pgft, PgftSpec};

    fn topo() -> Topology {
        build_pgft(&PgftSpec::case_study())
    }

    #[test]
    fn pristine_fields_match_ancestry() {
        let t = topo();
        let f = FaultSet::none(&t);
        let v = DegradedTopology::new(&t, &f);
        assert!(v.updown_connected());
        for dst in [0u32, 17, 63] {
            let r = v.reach(dst);
            for sw in 0..t.num_switches() {
                assert_eq!(r.descend[sw], t.is_ancestor(sw, dst), "sw {sw} dst {dst}");
            }
            assert!(r.good.iter().all(|&g| g), "everything reaches on pristine fabric");
        }
    }

    #[test]
    fn masking_respects_faults() {
        let t = topo();
        let mut f = FaultSet::none(&t);
        let victim = t.links.iter().find(|l| l.stage == 3).unwrap().id;
        f.kill(victim);
        let v = DegradedTopology::new(&t, &f);
        assert!(!v.link_alive(victim));
        assert!(!v.port_alive(t.links[victim].up_port));
        assert!(!v.port_alive(t.links[victim].down_port));
        assert_eq!(v.num_dead_links(), 1);
        // One dead parallel link out of four leaves the fabric connected.
        assert!(v.updown_connected());
    }

    #[test]
    fn broken_descent_clears_descend_bit() {
        let t = topo();
        // In the case study every L2 switch's 4 parallel up-links form
        // one bundle to a single top switch. Killing the whole bundle
        // removes that top's only descent into the subgroup, while the
        // subgroup's sibling L2 (wired to the other top) keeps carrying
        // it — the fabric stays connected, routed via the other top.
        let l2 = t.level_switches(2).next().unwrap();
        let paired_top = match t.port_peer(t.switches[l2].up_ports[0]) {
            Endpoint::Switch(s) => s,
            Endpoint::Node(_) => unreachable!("L2 up-port cabled to a node"),
        };
        let mut f = FaultSet::none(&t);
        for &p in &t.switches[l2].up_ports {
            f.kill(t.ports[p].link);
        }
        let v = DegradedTopology::new(&t, &f);
        let dst = (0..64u32).find(|&d| t.is_ancestor(l2, d)).unwrap();
        let r = v.reach(dst);
        // l2 itself still pure-descends to its subtree...
        assert!(r.descend[l2]);
        // ...but its paired top lost descent (only path was through l2),
        // and with no up-ports a top without descent is not good either.
        assert!(!r.descend[paired_top]);
        assert!(!r.good[t.num_nodes() + paired_top]);
        // The other top still descends via the sibling L2.
        let other = t.level_switches(3).find(|&s| s != paired_top).unwrap();
        assert!(r.descend[other]);
        assert!(v.updown_connected());
    }

    #[test]
    fn isolating_a_node_breaks_connectivity() {
        let t = topo();
        let mut f = FaultSet::none(&t);
        f.kill(t.ports[t.nodes[0].up_ports[0]].link);
        let v = DegradedTopology::new(&t, &f);
        assert!(!v.updown_connected());
        assert!(v.ensure_updown_connected().is_err());
        let r = v.reach(5);
        assert!(!r.good[0], "node 0 is cut off");
        assert!(r.good[5]);
    }
}
