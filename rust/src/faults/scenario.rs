//! Seeded, deterministic fault-scenario generation.
//!
//! A [`FaultModel`] is a compact description of *how* a fabric degrades
//! (parseable from CLI/config strings so it can ride on sweep grids);
//! [`FaultModel::generate`] expands it against a concrete topology and
//! seed into a [`FaultScenario`] — an *ordered* list of link deaths.
//! The order matters for cascading-failure studies: every prefix of the
//! event list is itself a valid (smaller) scenario, exposed by
//! [`FaultScenario::stages`].
//!
//! Generation is a pure function of `(model, topology, seed)`, so sweep
//! cells and CLI runs reproduce byte-identically.
//!
//! Unless a stage is named explicitly, the random models draw only from
//! *switch-to-switch* links (stage ≥ 2): with the common `w_1 = 1`
//! wiring every node has a single injection cable, so killing a stage-1
//! link always partitions the fabric and tells us nothing about
//! rerouting quality. `stage:1:K` still targets node links explicitly.

use super::FaultSet;
use crate::topology::{LinkId, Topology, TopologyView};
use crate::util::rng::Xoshiro256;
use anyhow::{bail, ensure, Context, Result};

/// A parseable description of how to degrade a fabric.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultModel {
    /// No faults (the pristine reference row of a sweep).
    None,
    /// Every eligible (stage ≥ 2) link dies independently with this
    /// probability.
    LinkRate {
        /// Per-link failure probability in `[0, 1]`.
        rate: f64,
    },
    /// Exactly `count` distinct eligible links die, sampled uniformly.
    LinkCount {
        /// Number of links to kill.
        count: usize,
    },
    /// `count` switches die (all their links fail), sampled uniformly
    /// from the non-leaf levels `2..=h` (leaf deaths always partition
    /// `w_1 = 1` fabrics).
    SwitchCount {
        /// Number of switches to kill.
        count: usize,
    },
    /// Targeted worst-case cut at one stage: kills `count` links of the
    /// stage *concentrated on consecutive up-link bundles* of one lower
    /// element (spilling into the next element's bundle), which is the
    /// adversarial pattern that removes path diversity fastest. The seed
    /// rotates which element is hit first.
    StageCut {
        /// Link stage to attack (stage `l` joins levels `l-1` and `l`).
        stage: usize,
        /// Number of links to kill at that stage.
        count: usize,
    },
    /// A cascading failure: `count` sequential random single-link
    /// deaths. The final fault set equals `LinkCount`, but the scenario
    /// records the order so [`FaultScenario::stages`] can replay the
    /// cascade step by step.
    Cascade {
        /// Number of cascade steps (one link per step).
        count: usize,
    },
}

impl FaultModel {
    /// Parse a compact spec string:
    ///
    /// | spec          | meaning                                        |
    /// |---------------|------------------------------------------------|
    /// | `none`        | pristine fabric                                |
    /// | `rate:R`      | each eligible link dies with probability `R`   |
    /// | `links:K`     | `K` uniform random eligible links die          |
    /// | `switches:K`  | `K` random non-leaf switches die entirely      |
    /// | `stage:L:K`   | worst-case cut of `K` links at stage `L`       |
    /// | `cascade:K`   | `K` sequential single-link failures            |
    pub fn parse(s: &str) -> Result<FaultModel> {
        let parts: Vec<&str> = s.split(':').collect();
        let arg = |i: usize| -> Result<usize> {
            parts
                .get(i)
                .with_context(|| format!("fault spec {s:?}: missing arg {i}"))?
                .parse()
                .map_err(|e| anyhow::anyhow!("fault spec {s:?}: {e}"))
        };
        Ok(match parts[0] {
            "none" => FaultModel::None,
            "rate" => {
                let rate: f64 = parts
                    .get(1)
                    .with_context(|| format!("fault spec {s:?}: missing rate"))?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("fault spec {s:?}: {e}"))?;
                ensure!((0.0..=1.0).contains(&rate), "fault rate {rate} outside [0, 1]");
                FaultModel::LinkRate { rate }
            }
            "links" => FaultModel::LinkCount { count: arg(1)? },
            "switches" => FaultModel::SwitchCount { count: arg(1)? },
            "stage" => FaultModel::StageCut { stage: arg(1)?, count: arg(2)? },
            "cascade" => FaultModel::Cascade { count: arg(1)? },
            other => bail!("unknown fault model {other:?} (none|rate:R|links:K|switches:K|stage:L:K|cascade:K)"),
        })
    }

    /// Canonical spec string (inverse of [`FaultModel::parse`]).
    pub fn name(&self) -> String {
        match self {
            FaultModel::None => "none".into(),
            FaultModel::LinkRate { rate } => format!("rate:{rate}"),
            FaultModel::LinkCount { count } => format!("links:{count}"),
            FaultModel::SwitchCount { count } => format!("switches:{count}"),
            FaultModel::StageCut { stage, count } => format!("stage:{stage}:{count}"),
            FaultModel::Cascade { count } => format!("cascade:{count}"),
        }
    }

    /// Whether this model produces no faults regardless of seed.
    pub fn is_none(&self) -> bool {
        matches!(self, FaultModel::None)
    }

    /// Check the model against a concrete topology shape ([`parse`] only
    /// sees the string): a `stage:L:K` cut must name an existing stage,
    /// otherwise it would silently expand to a zero-fault scenario and a
    /// typo would masquerade as "this fault costs nothing".
    ///
    /// [`parse`]: FaultModel::parse
    pub fn validate_for(&self, spec: &crate::topology::PgftSpec) -> Result<()> {
        if let FaultModel::StageCut { stage, .. } = self {
            ensure!(
                (1..=spec.h).contains(stage),
                "fault spec {:?}: stage {stage} does not exist on an h={} topology \
                 (stages are 1..={})",
                self.name(),
                spec.h,
                spec.h
            );
        }
        Ok(())
    }

    /// Expand the model against a topology into a concrete, ordered
    /// scenario. Deterministic in `(self, topo, seed)`. Counts larger
    /// than the eligible population saturate (everything eligible dies).
    pub fn generate(&self, topo: &Topology, seed: u64) -> FaultScenario {
        let mut rng = Xoshiro256::new(seed ^ 0xFA_0175_CE4A_5105);
        let eligible: Vec<LinkId> = topo
            .links
            .iter()
            .filter(|l| l.stage >= 2)
            .map(|l| l.id)
            .collect();
        let events: Vec<LinkId> = match self {
            FaultModel::None => Vec::new(),
            FaultModel::LinkRate { rate } => eligible
                .iter()
                .copied()
                .filter(|_| rng.next_f64() < *rate)
                .collect(),
            FaultModel::LinkCount { count } | FaultModel::Cascade { count } => {
                let k = (*count).min(eligible.len());
                let mut idx = rng.sample_indices(eligible.len().max(1), k);
                // sample_indices is unordered between runs of different k;
                // for LinkCount the order is irrelevant, for Cascade it IS
                // the cascade order — keep the sampled order as drawn, but
                // shuffle so the cascade does not trend toward high ids.
                rng.shuffle(&mut idx);
                idx.into_iter().map(|i| eligible[i]).collect()
            }
            FaultModel::SwitchCount { count } => {
                let candidates: Vec<usize> = (2..=topo.spec.h)
                    .flat_map(|l| topo.level_switches(l))
                    .collect();
                let k = (*count).min(candidates.len());
                let picks = rng.sample_indices(candidates.len().max(1), k);
                let mut events = Vec::new();
                for i in picks {
                    let s = &topo.switches[candidates[i]];
                    for &p in s.up_ports.iter().chain(&s.down_ports) {
                        let link = topo.ports[p].link;
                        if !events.contains(&link) {
                            events.push(link);
                        }
                    }
                }
                events
            }
            FaultModel::StageCut { stage, count } => {
                let stage_links: Vec<LinkId> = topo
                    .links
                    .iter()
                    .filter(|l| l.stage == *stage)
                    .map(|l| l.id)
                    .collect();
                if stage_links.is_empty() {
                    Vec::new()
                } else {
                    // Links of one stage are contiguous bundles per lower
                    // element in id order (w_l · p_l up-links each); start
                    // at a seeded bundle boundary and kill consecutively.
                    let bundle = (topo.spec.up_ports_at(*stage - 1) as usize).max(1);
                    let bundles = (stage_links.len() / bundle).max(1);
                    let start = (rng.next_below(bundles as u64) as usize) * bundle;
                    let k = (*count).min(stage_links.len());
                    (0..k)
                        .map(|i| stage_links[(start + i) % stage_links.len()])
                        .collect()
                }
            }
        };
        FaultScenario { model: self.name(), seed, events }
    }

    /// Expand against any [`TopologyView`] — the generation path for
    /// implicit topologies, where no link table exists to filter. Uses
    /// the fact that each stage's links occupy one contiguous id range
    /// (eligible stage ≥ 2 links are `stage_first_link(2)..num_links`),
    /// so the result is **byte-identical** to [`FaultModel::generate`]
    /// for every link-based model. `switches:K` needs the materialized
    /// per-switch port lists and errors here.
    pub fn generate_view(&self, view: &dyn TopologyView, seed: u64) -> Result<FaultScenario> {
        let mut rng = Xoshiro256::new(seed ^ 0xFA_0175_CE4A_5105);
        let spec = view.spec();
        let elig_start = if spec.h >= 2 { view.stage_first_link(2) } else { view.num_links() };
        let elig_len = view.num_links() - elig_start;
        let events: Vec<LinkId> = match self {
            FaultModel::None => Vec::new(),
            FaultModel::LinkRate { rate } => (elig_start..view.num_links())
                .filter(|_| rng.next_f64() < *rate)
                .collect(),
            FaultModel::LinkCount { count } | FaultModel::Cascade { count } => {
                let k = (*count).min(elig_len);
                let mut idx = rng.sample_indices(elig_len.max(1), k);
                rng.shuffle(&mut idx);
                idx.into_iter().map(|i| elig_start + i).collect()
            }
            FaultModel::SwitchCount { .. } => bail!(
                "fault model {:?} walks per-switch port lists and needs a materialized \
                 topology (use a link-based model on implicit topologies)",
                self.name()
            ),
            FaultModel::StageCut { stage, count } => {
                let lo = view.stage_first_link(*stage);
                let hi = if *stage < spec.h { view.stage_first_link(*stage + 1) } else { view.num_links() };
                if lo == hi {
                    Vec::new()
                } else {
                    let stage_len = hi - lo;
                    let bundle = (spec.up_ports_at(*stage - 1) as usize).max(1);
                    let bundles = (stage_len / bundle).max(1);
                    let start = (rng.next_below(bundles as u64) as usize) * bundle;
                    let k = (*count).min(stage_len);
                    (0..k).map(|i| lo + (start + i) % stage_len).collect()
                }
            }
        };
        Ok(FaultScenario { model: self.name(), seed, events })
    }
}

impl std::fmt::Display for FaultModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

/// One link state transition, as consumed by the online fabric
/// coordinator ([`crate::coordinator`]): scenarios expand to ordered
/// event streams via [`FaultScenario::as_events`] /
/// [`FaultScenario::drill_events`] and are replayed through the
/// coordinator's mpsc channel like live SNMP traps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkEvent {
    /// The link died.
    Down(LinkId),
    /// The link came back (repair / cable reseat).
    Up(LinkId),
}

impl LinkEvent {
    /// The affected link.
    pub fn link(&self) -> LinkId {
        match *self {
            LinkEvent::Down(l) | LinkEvent::Up(l) => l,
        }
    }
}

impl std::fmt::Display for LinkEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinkEvent::Down(l) => write!(f, "down:{l}"),
            LinkEvent::Up(l) => write!(f, "up:{l}"),
        }
    }
}

/// A concrete, ordered fault scenario: the expansion of one
/// [`FaultModel`] against one topology and seed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultScenario {
    /// Canonical model spec this was generated from.
    pub model: String,
    /// Generation seed.
    pub seed: u64,
    /// Ordered link deaths (duplicates never occur).
    pub events: Vec<LinkId>,
}

impl FaultScenario {
    /// Number of dead links in the final state.
    pub fn num_faults(&self) -> usize {
        self.events.len()
    }

    /// The final fault set (all events applied).
    pub fn fault_set(&self, topo: &Topology) -> FaultSet {
        self.fault_set_sized(topo.links.len())
    }

    /// The final fault set by link count (implicit-topology path).
    pub fn fault_set_sized(&self, num_links: usize) -> FaultSet {
        let mut f = FaultSet::none_sized(num_links);
        for &l in &self.events {
            f.kill(l);
        }
        f
    }

    /// Cumulative fault sets after each event — `stages()[i]` holds the
    /// first `i + 1` deaths. Empty for a zero-fault scenario. Replays a
    /// cascade step by step.
    pub fn stages(&self, topo: &Topology) -> Vec<FaultSet> {
        let mut out = Vec::with_capacity(self.events.len());
        let mut f = FaultSet::none(topo);
        for &l in &self.events {
            f.kill(l);
            out.push(f.clone());
        }
        out
    }

    /// Short human label, e.g. `links:4@seed1(4 dead)`.
    pub fn label(&self) -> String {
        format!("{}@seed{}({} dead)", self.model, self.seed, self.events.len())
    }

    /// The scenario as a coordinator event stream: one
    /// [`LinkEvent::Down`] per death, in cascade order.
    pub fn as_events(&self) -> Vec<LinkEvent> {
        self.events.iter().map(|&l| LinkEvent::Down(l)).collect()
    }

    /// A full failure-and-repair drill: every death in cascade order,
    /// then every repair in reverse order (last link to die is the
    /// first to be fixed), ending back at the pristine fabric.
    pub fn drill_events(&self) -> Vec<LinkEvent> {
        self.events
            .iter()
            .map(|&l| LinkEvent::Down(l))
            .chain(self.events.iter().rev().map(|&l| LinkEvent::Up(l)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_pgft, PgftSpec};

    fn topo() -> Topology {
        build_pgft(&PgftSpec::case_study())
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["none", "rate:0.05", "links:4", "switches:2", "stage:3:2", "cascade:5"] {
            let m = FaultModel::parse(s).unwrap_or_else(|e| panic!("{s}: {e}"));
            assert_eq!(m.name(), s);
            assert_eq!(FaultModel::parse(&m.name()).unwrap(), m);
        }
        assert!(FaultModel::parse("meteor:3").is_err());
        assert!(FaultModel::parse("rate:1.5").is_err());
        assert!(FaultModel::parse("links").is_err());
        assert!(FaultModel::parse("stage:3").is_err());
    }

    #[test]
    fn generation_is_deterministic() {
        let t = topo();
        for spec in ["rate:0.2", "links:4", "switches:1", "stage:3:2", "cascade:3"] {
            let m = FaultModel::parse(spec).unwrap();
            let a = m.generate(&t, 7);
            let b = m.generate(&t, 7);
            assert_eq!(a, b, "{spec} must be deterministic");
            let c = m.generate(&t, 8);
            // Different seeds (almost surely) differ for random models.
            if spec.starts_with("links") || spec.starts_with("cascade") {
                assert_ne!(a.events, c.events, "{spec} should vary with seed");
            }
        }
    }

    #[test]
    fn counts_and_eligibility() {
        let t = topo();
        let s = FaultModel::LinkCount { count: 4 }.generate(&t, 1);
        assert_eq!(s.num_faults(), 4);
        // Only switch-to-switch links are eligible.
        for &l in &s.events {
            assert!(t.links[l].stage >= 2, "link {l} is a node link");
        }
        // Saturation: more than the 32 eligible links of the case study.
        let s = FaultModel::LinkCount { count: 10_000 }.generate(&t, 1);
        assert_eq!(s.num_faults(), 32);
        // Zero-fault scenarios.
        assert_eq!(FaultModel::None.generate(&t, 1).num_faults(), 0);
        assert_eq!(FaultModel::LinkRate { rate: 0.0 }.generate(&t, 1).num_faults(), 0);
        assert_eq!(FaultModel::LinkCount { count: 0 }.generate(&t, 1).num_faults(), 0);
        // Rate 1 kills every eligible link.
        assert_eq!(FaultModel::LinkRate { rate: 1.0 }.generate(&t, 1).num_faults(), 32);
    }

    #[test]
    fn out_of_range_stage_rejected_by_validate_for() {
        let t = topo();
        let m = FaultModel::parse("stage:4:2").unwrap(); // h = 3: no stage 4
        assert!(m.validate_for(&t.spec).is_err());
        assert!(FaultModel::parse("stage:0:2").unwrap().validate_for(&t.spec).is_err());
        for ok in ["stage:1:1", "stage:2:1", "stage:3:4", "rate:0.5", "none"] {
            FaultModel::parse(ok).unwrap().validate_for(&t.spec).unwrap();
        }
    }

    /// The implicit generation path must reproduce the table-walking one
    /// event for event (it feeds the same seeds at the same rungs).
    #[test]
    fn generate_view_is_byte_identical_to_generate() {
        let t = topo();
        let v = crate::topology::ImplicitTopology::new(&t.spec);
        for spec in ["none", "rate:0.2", "links:4", "cascade:3", "stage:3:2", "stage:2:3"] {
            let m = FaultModel::parse(spec).unwrap();
            for seed in [0u64, 1, 7, 99] {
                assert_eq!(
                    m.generate(&t, seed),
                    m.generate_view(&v, seed).unwrap(),
                    "{spec} seed {seed}"
                );
            }
        }
        assert!(FaultModel::parse("switches:1").unwrap().generate_view(&v, 0).is_err());
        // fault_set_sized mirrors fault_set.
        let s = FaultModel::parse("links:4").unwrap().generate(&t, 1);
        assert_eq!(s.fault_set(&t), s.fault_set_sized(t.links.len()));
    }

    #[test]
    fn switch_death_kills_incident_links() {
        let t = topo();
        let s = FaultModel::SwitchCount { count: 1 }.generate(&t, 3);
        let f = s.fault_set(&t);
        // A dead L2 switch has 8 links, a dead top switch has 8 links.
        assert_eq!(f.num_dead(), 8);
    }

    #[test]
    fn stage_cut_concentrates_on_bundles() {
        let t = topo();
        // Stage 3 = L2→top, bundled 4 parallel links per L2 switch.
        let s = FaultModel::StageCut { stage: 3, count: 4 }.generate(&t, 0);
        assert_eq!(s.num_faults(), 4);
        // All four dead links hang off the same L2 switch (one bundle).
        let owners: std::collections::HashSet<_> = s
            .events
            .iter()
            .map(|&l| t.ports[t.links[l].up_port].owner)
            .collect();
        assert_eq!(owners.len(), 1, "worst-case cut should hit one bundle");
        for &l in &s.events {
            assert_eq!(t.links[l].stage, 3);
        }
    }

    #[test]
    fn cascade_stages_are_cumulative() {
        let t = topo();
        let s = FaultModel::Cascade { count: 3 }.generate(&t, 5);
        let stages = s.stages(&t);
        assert_eq!(stages.len(), 3);
        for (i, st) in stages.iter().enumerate() {
            assert_eq!(st.num_dead(), i + 1);
            // Each stage contains the previous one.
            if i > 0 {
                for l in stages[i - 1].dead_links() {
                    assert!(st.is_dead(l));
                }
            }
        }
        assert_eq!(stages.last().unwrap(), &s.fault_set(&t));
    }

    #[test]
    fn event_streams_mirror_the_scenario() {
        let t = topo();
        let s = FaultModel::Cascade { count: 3 }.generate(&t, 5);
        let down = s.as_events();
        assert_eq!(down.len(), 3);
        for (e, &l) in down.iter().zip(&s.events) {
            assert_eq!(*e, LinkEvent::Down(l));
            assert_eq!(e.link(), l);
        }
        let drill = s.drill_events();
        assert_eq!(drill.len(), 6);
        assert_eq!(&drill[..3], &down[..]);
        // Repairs run in reverse death order and cancel out.
        let mut f = FaultSet::none(&t);
        for e in &drill {
            match *e {
                LinkEvent::Down(l) => f.kill(l),
                LinkEvent::Up(l) => f.revive(l),
            }
        }
        assert_eq!(f.num_dead(), 0);
        assert_eq!(drill[3], LinkEvent::Up(s.events[2]));
        assert_eq!(format!("{}", drill[0]), format!("down:{}", s.events[0]));
        assert_eq!(format!("{}", drill[3]), format!("up:{}", s.events[2]));
    }
}
