//! Fault injection & online rerouting — the "real fabrics degrade"
//! scenario family the paper's companion works (*High-Quality
//! Fault-Resiliency in Fat-Trees*) study.
//!
//! The subsystem has three layers:
//!
//!  * [`FaultSet`] — the ground truth: which links are currently dead.
//!    (Moved here from `routing::degraded`, which re-exports it.)
//!  * [`scenario`] — seeded, deterministic *generators* of fault sets:
//!    random link failures by rate or count, random switch deaths,
//!    targeted worst-case cuts per stage, and cascading-failure
//!    sequences ([`FaultModel`] / [`FaultScenario`]).
//!  * [`view`] / [`router`] — *online rerouting*: [`DegradedTopology`]
//!    masks failed ports without rebuilding the graph and computes
//!    up\*/down\* reachability; [`DegradedRouter`] wraps any base
//!    [`crate::routing::Router`] (Dmodk, Smodk, Gdmodk, Gsmodk, random,
//!    …) so the same algorithm routes around faults — falling back to
//!    the next healthy candidate port deterministically, and descending
//!    only where the descent path survives. With zero faults the wrapped
//!    router is byte-identical to the base router.
//!
//! Faults are a first-class sweep axis ([`crate::sweep::SweepSpec::faults`])
//! and a CLI subcommand (`pgft faults`), which report per-cell rerouting
//! cost (routes changed vs. pristine) and fair-rate throughput retention.
//!
//! ```
//! use pgft::prelude::*;
//! let topo = build_pgft(&PgftSpec::case_study());
//! let types = Placement::paper_io().apply(&topo).unwrap();
//! // Worst-case cut: 2 of the 4 parallel links of one L2→top bundle.
//! let scenario = FaultModel::parse("stage:3:2").unwrap().generate(&topo, 1);
//! let faults = scenario.fault_set(&topo);
//! let router = AlgorithmKind::Gdmodk.build_degraded(&topo, Some(&types), 1, &faults).unwrap();
//! let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
//! let routes = trace_flows(&topo, &*router, &flows);
//! let rep = pgft::routing::verify::verify_routes(&topo, &routes);
//! assert!(rep.deadlock_free && rep.ensure_valid().is_ok());
//! ```

pub mod router;
pub mod scenario;
pub mod view;

pub use router::{DegradedRouter, ReachStats, DEFAULT_REACH_BUDGET};
pub use scenario::{FaultModel, FaultScenario, LinkEvent};
pub use view::{DegradedTopology, ReachField};

use crate::topology::{LinkId, Topology};

/// Set of failed links.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSet {
    dead: Vec<bool>,
    count: usize,
}

impl FaultSet {
    /// A fully healthy fabric (no dead links).
    pub fn none(topo: &Topology) -> FaultSet {
        FaultSet::none_sized(topo.links.len())
    }

    /// A fully healthy fabric by link count — the constructor for
    /// implicit topologies ([`crate::topology::TopologyView::num_links`]),
    /// where no link table exists to measure.
    pub fn none_sized(num_links: usize) -> FaultSet {
        FaultSet { dead: vec![false; num_links], count: 0 }
    }

    /// A fault set with the given links dead.
    pub fn from_links(topo: &Topology, links: &[LinkId]) -> FaultSet {
        let mut f = FaultSet::none(topo);
        for &l in links {
            f.kill(l);
        }
        f
    }

    /// Mark a link dead (idempotent).
    pub fn kill(&mut self, link: LinkId) {
        if !self.dead[link] {
            self.dead[link] = true;
            self.count += 1;
        }
    }

    /// Kill every link incident to a switch (models a switch death).
    pub fn kill_switch(&mut self, topo: &Topology, sw: crate::topology::SwitchId) {
        let s = &topo.switches[sw];
        for &p in s.up_ports.iter().chain(&s.down_ports) {
            self.kill(topo.ports[p].link);
        }
    }

    /// Mark a link healthy again (idempotent).
    pub fn revive(&mut self, link: LinkId) {
        if self.dead[link] {
            self.dead[link] = false;
            self.count -= 1;
        }
    }

    /// Whether a link is currently dead.
    #[inline]
    pub fn is_dead(&self, link: LinkId) -> bool {
        self.dead[link]
    }

    /// Number of dead links.
    pub fn num_dead(&self) -> usize {
        self.count
    }

    /// Ids of all dead links, ascending.
    pub fn dead_links(&self) -> Vec<LinkId> {
        self.dead
            .iter()
            .enumerate()
            .filter(|(_, &d)| d)
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_pgft, PgftSpec};

    #[test]
    fn fault_set_bookkeeping() {
        let topo = build_pgft(&PgftSpec::case_study());
        let mut f = FaultSet::none(&topo);
        assert_eq!(f.num_dead(), 0);
        f.kill(3);
        f.kill(3);
        f.kill(7);
        assert_eq!(f.num_dead(), 2);
        assert_eq!(f.dead_links(), vec![3, 7]);
        f.revive(3);
        assert_eq!(f.num_dead(), 1);
        assert!(f.is_dead(7) && !f.is_dead(3));
    }

    #[test]
    fn from_links_and_kill_switch() {
        let topo = build_pgft(&PgftSpec::case_study());
        let f = FaultSet::from_links(&topo, &[1, 5, 5]);
        assert_eq!(f.num_dead(), 2);
        let mut g = FaultSet::none(&topo);
        let l2 = topo.level_switches(2).next().unwrap();
        g.kill_switch(&topo, l2);
        // L2 switch of the case study: 4 down + 4 up links.
        assert_eq!(g.num_dead(), 8);
        for &p in &topo.switches[l2].up_ports {
            assert!(g.is_dead(topo.ports[p].link));
        }
    }
}
