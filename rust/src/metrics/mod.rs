//! The paper's static congestion metric (§III.A).
//!
//! For a set of routes `R` and an output port `p`:
//!
//! ```text
//!     C_p(R)    = min( src(R,p), dst(R,p) )
//!     C_topo(R) = max_p C_p(R)
//! ```
//!
//! where `src(R,p)` / `dst(R,p)` count *distinct* sources / destinations
//! of the routes whose output includes `p`. `C_p ≤ 1` means the port only
//! ever carries one flow's worth of unrelated traffic (Fig. 2); `C_p > 1`
//! flags potentially avoidable network congestion (Fig. 3).

pub mod report;

pub use report::{render_algorithm_table, AlgoSummary};

use crate::routing::trace::RoutePorts;
use crate::topology::{PortId, Topology, TopologyView};

/// Per-port flow statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PortStats {
    /// Routes whose output includes this port.
    pub routes: u32,
    /// Distinct sources among them: `src(R,p)`.
    pub srcs: u32,
    /// Distinct destinations among them: `dst(R,p)`.
    pub dsts: u32,
}

impl PortStats {
    /// `C_p(R) = min(src, dst)`.
    #[inline]
    pub fn c(&self) -> u32 {
        self.srcs.min(self.dsts)
    }
}

/// Congestion analysis of a route set over a topology.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CongestionReport {
    /// Per-output-port statistics, indexed by global `PortId`.
    pub per_port: Vec<PortStats>,
}

/// Words per port in the striped kernel: each block of the node-id
/// space covers `STRIPE × 64` ids, and a port's per-block state is a
/// contiguous stripe of `STRIPE` `u64` words. The stripe is a fixed,
/// small power of two so the per-port fold is a straight-line loop the
/// compiler auto-vectorizes (one 256-bit OR/popcount chain on AVX2) —
/// no unstable SIMD intrinsics anywhere. 4 words won over 8 in
/// `bench_eval`'s kernel leg: the wider stripe halves the block count
/// but doubles the reset/merge footprint of every touched port, and
/// sampled-pair patterns touch many ports per block.
const STRIPE: usize = 4;

/// Counters from one striped-kernel run — the `eval.kernel.*`
/// telemetry surface (`pgft eval` records them per rung).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelStats {
    /// Stripe blocks swept across both (source, destination) passes.
    pub blocks: u64,
    /// Port-stripe activations: a port touched in a block it had not
    /// yet been touched in (stamp misses ⇒ stripe resets).
    pub touched_ports: u64,
    /// `u64` words folded into distinct counts (`STRIPE` per touched
    /// port per block) — the kernel's popcount volume.
    pub merged_words: u64,
}

/// The one congestion kernel, in *striped/word-parallel* form. The
/// original shape kept two dense `ports × ⌈N/64⌉` bitset arenas — fine
/// at 512 nodes (180 KiB) but ~60 GiB at the 256k-endpoint rung of the
/// eval ladder. This form buffers the flow incidences once (`O(hops)`,
/// the same order as the route arena it summarizes) and then sweeps the
/// node-id space in [`STRIPE`]`×64`-node *blocks*: within one block
/// every port needs only a `STRIPE`-word stripe, so the whole per-port
/// state is three flat `O(ports)` arrays, the distinct-count merge is
/// one fixed-width popcount fold per *touched* port per block, and
/// epoch stamps make the per-block reset `O(touched ports)` instead of
/// `O(ports)`. Total: `O(hops)` work and `O(hops + ports)` memory,
/// independent of the node count. The pre-striping single-word variant
/// survives as [`CongestionReport::compute_flowset_blocked`] so
/// `bench_eval` can record the striping speedup; per-port `HashSet`s
/// and scatter+sort+dedup (measured in `bench_perf`, EXPERIMENTS.md
/// §Perf) survive only as `#[cfg(test)]` cross-checks below, which
/// also pin both word kernels on randomized ragged block boundaries.
/// Every public entry point (`compute`, `compute_flows`,
/// `compute_flowset`) accumulates through this accumulator, so there
/// is exactly one shipped implementation of the metric.
struct BitmapAccum {
    num_nodes: usize,
    per_port: Vec<PortStats>,
    /// Buffered incidences: `(src, dst)` per flow plus a CSR hop arena
    /// (`routes` is counted eagerly in [`BitmapAccum::add`]; the
    /// distinct counts need the full flow list, so they wait for
    /// [`BitmapAccum::finish`]).
    flows: Vec<(u32, u32)>,
    offsets: Vec<usize>,
    hops: Vec<u32>,
}

impl BitmapAccum {
    fn new(num_ports: usize, num_nodes: usize) -> BitmapAccum {
        BitmapAccum {
            num_nodes,
            per_port: vec![PortStats::default(); num_ports],
            flows: Vec::new(),
            offsets: vec![0],
            hops: Vec::new(),
        }
    }

    #[inline]
    fn add(&mut self, src: u32, dst: u32, ports: impl IntoIterator<Item = u32>) {
        for p in ports {
            self.per_port[p as usize].routes += 1;
            self.hops.push(p);
        }
        self.flows.push((src, dst));
        self.offsets.push(self.hops.len());
    }

    fn finish(self) -> CongestionReport {
        self.finish_striped().0
    }

    /// The shipped kernel: sweep the node-id space in `STRIPE×64`-node
    /// blocks, one `STRIPE`-word stripe of state per touched port.
    fn finish_striped(self) -> (CongestionReport, KernelStats) {
        let BitmapAccum { num_nodes, mut per_port, flows, offsets, hops } = self;
        let span = STRIPE * 64;
        let blocks = num_nodes.div_ceil(span).max(1);
        let num_ports = per_port.len();
        // Per-port stripe state for the current block, with epoch stamps
        // (a stale stamp means "stripe not yet touched this block") and
        // the touched-port list driving the merge + reset.
        let mut words = vec![0u64; num_ports * STRIPE];
        let mut stamp = vec![0u32; num_ports];
        let mut touched: Vec<u32> = Vec::new();
        // Counting-sort scratch: flow indices bucketed by key block.
        let mut order = vec![0u32; flows.len()];
        let mut starts = vec![0usize; blocks + 1];
        let mut epoch = 0u32;
        let mut stats = KernelStats::default();
        // Two passes over the same buffered incidences: distinct
        // *sources* per port, then distinct *destinations*.
        for pick_src in [true, false] {
            let key = |f: usize| if pick_src { flows[f].0 } else { flows[f].1 };
            // Stable counting sort of flows by the block their key falls
            // in, so each block's flows are visited together.
            starts.iter_mut().for_each(|s| *s = 0);
            for f in 0..flows.len() {
                starts[key(f) as usize / span + 1] += 1;
            }
            for b in 0..blocks {
                starts[b + 1] += starts[b];
            }
            let mut cursor = starts.clone();
            for f in 0..flows.len() {
                let b = key(f) as usize / span;
                order[cursor[b]] = f as u32;
                cursor[b] += 1;
            }
            for b in 0..blocks {
                if starts[b] == starts[b + 1] {
                    continue;
                }
                epoch += 1;
                stats.blocks += 1;
                let base = (b * span) as u32;
                for &fi in &order[starts[b]..starts[b + 1]] {
                    let f = fi as usize;
                    let rel = (key(f) - base) as usize;
                    let (wi, bit) = (rel / 64, 1u64 << (rel % 64));
                    for &p in &hops[offsets[f]..offsets[f + 1]] {
                        let p = p as usize;
                        if stamp[p] != epoch {
                            stamp[p] = epoch;
                            words[p * STRIPE..(p + 1) * STRIPE].fill(0);
                            touched.push(p as u32);
                        }
                        words[p * STRIPE + wi] |= bit;
                    }
                }
                stats.touched_ports += touched.len() as u64;
                stats.merged_words += (touched.len() * STRIPE) as u64;
                for &p in &touched {
                    let p = p as usize;
                    // Fixed-width fold over the stripe: a straight-line
                    // popcount chain the compiler keeps in vector
                    // registers — the kernel's only hot reduction.
                    let stripe = &words[p * STRIPE..(p + 1) * STRIPE];
                    let mut ones = 0u32;
                    for w in stripe {
                        ones += w.count_ones();
                    }
                    let st = &mut per_port[p];
                    if pick_src {
                        st.srcs += ones;
                    } else {
                        st.dsts += ones;
                    }
                }
                touched.clear();
            }
        }
        (CongestionReport { per_port }, stats)
    }

    /// The pre-striping kernel (single-word 64-node blocks), kept as the
    /// measured baseline for the striping speedup in `bench_eval` and as
    /// a bit-exactness oracle in the kernel property tests. Same
    /// counting-sort structure; the only difference is one word of block
    /// state per port instead of a stripe.
    fn finish_blocked(self) -> CongestionReport {
        let BitmapAccum { num_nodes, mut per_port, flows, offsets, hops } = self;
        let blocks = num_nodes.div_ceil(64).max(1);
        let num_ports = per_port.len();
        let mut word = vec![0u64; num_ports];
        let mut stamp = vec![0u32; num_ports];
        let mut touched: Vec<u32> = Vec::new();
        let mut order = vec![0u32; flows.len()];
        let mut starts = vec![0usize; blocks + 1];
        let mut epoch = 0u32;
        for pick_src in [true, false] {
            let key = |f: usize| if pick_src { flows[f].0 } else { flows[f].1 };
            starts.iter_mut().for_each(|s| *s = 0);
            for f in 0..flows.len() {
                starts[(key(f) / 64) as usize + 1] += 1;
            }
            for b in 0..blocks {
                starts[b + 1] += starts[b];
            }
            let mut cursor = starts.clone();
            for f in 0..flows.len() {
                let b = (key(f) / 64) as usize;
                order[cursor[b]] = f as u32;
                cursor[b] += 1;
            }
            for b in 0..blocks {
                if starts[b] == starts[b + 1] {
                    continue;
                }
                epoch += 1;
                for &fi in &order[starts[b]..starts[b + 1]] {
                    let f = fi as usize;
                    let bit = 1u64 << (key(f) % 64);
                    for &p in &hops[offsets[f]..offsets[f + 1]] {
                        let p = p as usize;
                        if stamp[p] != epoch {
                            stamp[p] = epoch;
                            word[p] = 0;
                            touched.push(p as u32);
                        }
                        word[p] |= bit;
                    }
                }
                for &p in &touched {
                    let p = p as usize;
                    let st = &mut per_port[p];
                    if pick_src {
                        st.srcs += word[p].count_ones();
                    } else {
                        st.dsts += word[p].count_ones();
                    }
                }
                touched.clear();
            }
        }
        CongestionReport { per_port }
    }
}

impl CongestionReport {
    /// Compute per-port distinct-source/destination counts over owned
    /// per-route vectors (the [`RoutePorts`] surface). One bitmap
    /// kernel (the private `BitmapAccum`) serves every entry point.
    pub fn compute(topo: &dyn TopologyView, routes: &[RoutePorts]) -> CongestionReport {
        let mut acc = BitmapAccum::new(topo.num_ports(), topo.num_nodes());
        for r in routes {
            acc.add(r.src, r.dst, r.ports.iter().map(|&p| p as u32));
        }
        acc.finish()
    }

    /// Compute over an arena-backed [`crate::eval::FlowSet`] — the
    /// canonical eval-layer entry point ([`crate::eval::CongestionEval`]):
    /// same kernel, zero per-route allocation, shared trace. Takes any
    /// [`TopologyView`], so the 1M-endpoint rung scores through the
    /// implicit topology without port tables.
    pub fn compute_flowset(
        topo: &dyn TopologyView,
        flows: &crate::eval::FlowSet,
    ) -> CongestionReport {
        CongestionReport::compute_flowset_stats(topo, flows).0
    }

    /// [`CongestionReport::compute_flowset`] returning the kernel's
    /// work counters as well — the `eval.kernel.*` telemetry surface.
    pub fn compute_flowset_stats(
        topo: &dyn TopologyView,
        flows: &crate::eval::FlowSet,
    ) -> (CongestionReport, KernelStats) {
        let mut acc = BitmapAccum::new(topo.num_ports(), topo.num_nodes());
        for ((src, dst), ports) in flows.iter() {
            acc.add(src, dst, ports.iter().copied());
        }
        acc.finish_striped()
    }

    /// The pre-striping single-word kernel over a flow store. Not part
    /// of the metric's public contract — it exists so `bench_eval` can
    /// measure the striping speedup against a live baseline. Bit-exact
    /// with [`CongestionReport::compute_flowset`] (property-pinned).
    #[doc(hidden)]
    pub fn compute_flowset_blocked(
        topo: &dyn TopologyView,
        flows: &crate::eval::FlowSet,
    ) -> CongestionReport {
        let mut acc = BitmapAccum::new(topo.num_ports(), topo.num_nodes());
        for ((src, dst), ports) in flows.iter() {
            acc.add(src, dst, ports.iter().copied());
        }
        acc.finish_blocked()
    }

    /// Ablation cross-check (§Perf iteration 1 → 2): scatter
    /// `(port, nid)` pairs, sort, dedup, count runs. Beats hash sets on
    /// small fabrics, loses past ~10⁶ hops; demoted from the public
    /// surface once `bench_perf` crowned the bitmap kernel — kept only
    /// to cross-check it in tests.
    #[cfg(test)]
    fn compute_sortdedup(topo: &Topology, routes: &[RoutePorts]) -> CongestionReport {
        let np = topo.num_ports();
        let mut per_port = vec![PortStats::default(); np];

        let hops: usize = routes.iter().map(|r| r.ports.len()).sum();
        let mut by_src: Vec<(u32, u32)> = Vec::with_capacity(hops);
        let mut by_dst: Vec<(u32, u32)> = Vec::with_capacity(hops);
        for r in routes {
            for &p in &r.ports {
                per_port[p].routes += 1;
                by_src.push((p as u32, r.src));
                by_dst.push((p as u32, r.dst));
            }
        }
        for (pairs, pick_src) in [(&mut by_src, true), (&mut by_dst, false)] {
            pairs.sort_unstable();
            pairs.dedup();
            for &(p, _) in pairs.iter() {
                let st = &mut per_port[p as usize];
                if pick_src {
                    st.srcs += 1;
                } else {
                    st.dsts += 1;
                }
            }
        }
        CongestionReport { per_port }
    }

    /// Ablation cross-check for §Perf: per-port `HashSet` accumulation
    /// (the obvious first implementation). Demoted from the public
    /// surface with [`CongestionReport::compute_sortdedup`]; the bitmap
    /// kernel is the one shipped path.
    #[cfg(test)]
    fn compute_hashset(topo: &Topology, routes: &[RoutePorts]) -> CongestionReport {
        use std::collections::HashSet;
        let np = topo.num_ports();
        let mut per_port = vec![PortStats::default(); np];
        let mut srcs: Vec<HashSet<u32>> = vec![HashSet::new(); np];
        let mut dsts: Vec<HashSet<u32>> = vec![HashSet::new(); np];
        for r in routes {
            for &p in &r.ports {
                per_port[p].routes += 1;
                srcs[p].insert(r.src);
                dsts[p].insert(r.dst);
            }
        }
        for p in 0..np {
            per_port[p].srcs = srcs[p].len() as u32;
            per_port[p].dsts = dsts[p].len() as u32;
        }
        CongestionReport { per_port }
    }

    /// Fused trace+metric hot path: routes are traced into a reusable
    /// arena (no per-route allocation) and the per-port statistics are
    /// accumulated directly — the path `random-dist`-style Monte-Carlo
    /// sweeps use. Equivalent to `trace_flows` + `compute` (asserted in
    /// tests).
    pub fn compute_flows(
        topo: &dyn TopologyView,
        router: &dyn crate::routing::Router,
        flows: &[(u32, u32)],
    ) -> CongestionReport {
        let mut acc = BitmapAccum::new(topo.num_ports(), topo.num_nodes());
        let mut ports: Vec<PortId> = Vec::with_capacity(2 * topo.spec().h);
        for &(src, dst) in flows {
            ports.clear();
            crate::routing::trace::trace_route_into(topo, router, src, dst, &mut ports);
            acc.add(src, dst, ports.iter().map(|&p| p as u32));
        }
        acc.finish()
    }

    /// `C_p` for one port.
    #[inline]
    pub fn c_port(&self, p: PortId) -> u32 {
        self.per_port[p].c()
    }

    /// `C_topo(R) = max_p C_p(R)`.
    pub fn c_topo(&self) -> u32 {
        self.per_port.iter().map(|s| s.c()).max().unwrap_or(0)
    }

    /// Ports with `C_p > 1` — "potentially avoidable network congestion".
    pub fn hot_ports(&self) -> Vec<PortId> {
        self.per_port
            .iter()
            .enumerate()
            .filter(|(_, s)| s.c() > 1)
            .map(|(p, _)| p)
            .collect()
    }

    /// Hot ports restricted to switch level `l` (up or down direction).
    pub fn hot_ports_at(&self, topo: &Topology, level: usize, up: bool) -> Vec<PortId> {
        self.hot_ports()
            .into_iter()
            .filter(|&p| topo.port_level(p) == level && topo.ports[p].up == up)
            .collect()
    }

    /// Max `C_p` over ports of a given level/direction.
    pub fn c_max_at(&self, topo: &Topology, level: usize, up: bool) -> u32 {
        topo.ports
            .iter()
            .filter(|port| topo.port_level(port.id) == level && port.up == up)
            .map(|port| self.c_port(port.id))
            .max()
            .unwrap_or(0)
    }

    /// Number of *used* ports at a level/direction (routes > 0).
    pub fn used_ports_at(&self, topo: &Topology, level: usize, up: bool) -> usize {
        topo.ports
            .iter()
            .filter(|port| {
                topo.port_level(port.id) == level
                    && port.up == up
                    && self.per_port[port.id].routes > 0
            })
            .count()
    }

    /// Histogram of `C_p` values over all ports (index = C value).
    pub fn histogram(&self) -> Vec<usize> {
        let max = self.c_topo() as usize;
        let mut h = vec![0usize; max + 1];
        for s in &self.per_port {
            h[s.c() as usize] += 1;
        }
        h
    }

    /// The input-side variant the paper mentions ("the same analysis can
    /// be made with ports as input"): every hop's input port is the far
    /// end of the link it arrived on; for symmetric patterns
    /// `C_topo` matches the output-side value.
    pub fn compute_input_side(topo: &Topology, routes: &[RoutePorts]) -> CongestionReport {
        // Map each output port to the receiving element's port on the same
        // link (the opposite directed port), and rerun the analysis.
        let mapped: Vec<RoutePorts> = routes
            .iter()
            .map(|r| RoutePorts {
                src: r.src,
                dst: r.dst,
                ports: r
                    .ports
                    .iter()
                    .map(|&p| {
                        let link = &topo.links[topo.ports[p].link];
                        if link.up_port == p {
                            link.down_port
                        } else {
                            link.up_port
                        }
                    })
                    .collect(),
            })
            .collect();
        CongestionReport::compute(topo, &mapped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::patterns::Pattern;
    use crate::routing::trace::trace_flows;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    /// Fig. 2: a port with a single destination (or single source) has
    /// C_p = 1 no matter how many routes share it.
    #[test]
    fn single_flow_port_is_one() {
        let topo = build_pgft(&PgftSpec::case_study());
        let r = AlgorithmKind::Dmodk.build(&topo, None, 0);
        // Gather: every node sends to node 7 → every used port has dst
        // count 1 → C_p = 1 everywhere.
        let types = crate::nodes::NodeTypeMap::uniform(64, crate::nodes::NodeType::Compute);
        let flows = Pattern::Gather { root: 7 }.flows(&topo, &types).unwrap();
        let routes = trace_flows(&topo, &*r, &flows);
        let rep = CongestionReport::compute(&topo, &routes);
        assert_eq!(rep.c_topo(), 1);
        assert!(rep.hot_ports().is_empty());
        // And scatter likewise (src count 1 everywhere).
        let flows = Pattern::Scatter { root: 0 }.flows(&topo, &types).unwrap();
        let routes = trace_flows(&topo, &*r, &flows);
        assert_eq!(CongestionReport::compute(&topo, &routes).c_topo(), 1);
    }

    /// Fig. 3: two sources to two destinations through one port → C_p = 2.
    #[test]
    fn crossing_flows_port_is_two() {
        let topo = build_pgft(&PgftSpec::case_study());
        let r = AlgorithmKind::Dmodk.build(&topo, None, 0);
        // Pick two flows that share exactly one port (the leaf up-port):
        // sources on leaf 0 to odd-parity destinations on *different*
        // destination leaves: 0→17 (leaf 2) and 1→27 (leaf 3).
        let routes = trace_flows(&topo, &*r, &[(0, 17), (1, 27)]);
        let rep = CongestionReport::compute(&topo, &routes);
        // The shared leaf up-port has 2 srcs and 2 dsts.
        assert_eq!(rep.c_topo(), 2);
        assert_eq!(rep.hot_ports().len(), 1);
        let hp = rep.hot_ports()[0];
        assert_eq!(rep.per_port[hp].srcs, 2);
        assert_eq!(rep.per_port[hp].dsts, 2);
    }

    #[test]
    fn histogram_sums_to_port_count() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let r = AlgorithmKind::Dmodk.build(&topo, Some(&types), 0);
        let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
        let routes = trace_flows(&topo, &*r, &flows);
        let rep = CongestionReport::compute(&topo, &routes);
        assert_eq!(rep.histogram().iter().sum::<usize>(), topo.num_ports());
    }

    #[test]
    fn input_side_matches_for_symmetric_pattern() {
        // §III.A: "This does not cause C_topo(R) to vary when the pattern
        // has symmetrical communications between sources and destinations."
        let topo = build_pgft(&PgftSpec::case_study());
        let types = crate::nodes::NodeTypeMap::uniform(64, crate::nodes::NodeType::Compute);
        let r = AlgorithmKind::Dmodk.build(&topo, None, 0);
        let flows = Pattern::AllToAll.flows(&topo, &types).unwrap();
        let routes = trace_flows(&topo, &*r, &flows);
        let out = CongestionReport::compute(&topo, &routes);
        let inp = CongestionReport::compute_input_side(&topo, &routes);
        assert_eq!(out.c_topo(), inp.c_topo());
    }

    #[test]
    fn ablation_and_fused_paths_agree() {
        // The demoted kernels (`compute_hashset`, `compute_sortdedup`)
        // live on exactly here: as cross-checks of the one canonical
        // bitmap kernel, alongside its fused and FlowSet entry points.
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gsmodk, AlgorithmKind::Random] {
            let r = kind.build(&topo, Some(&types), 5);
            let flows = Pattern::C2ioAll.flows(&topo, &types).unwrap();
            let routes = trace_flows(&topo, &*r, &flows);
            let a = CongestionReport::compute(&topo, &routes);
            let b = CongestionReport::compute_hashset(&topo, &routes);
            let s = CongestionReport::compute_sortdedup(&topo, &routes);
            let c = CongestionReport::compute_flows(&topo, &*r, &flows);
            let set = crate::eval::FlowSet::trace(&topo, &*r, &flows);
            let d = CongestionReport::compute_flowset(&topo, &set);
            for p in 0..topo.num_ports() {
                assert_eq!(a.per_port[p], b.per_port[p], "{kind} port {p} (hashset)");
                assert_eq!(a.per_port[p], s.per_port[p], "{kind} port {p} (sort-dedup)");
                assert_eq!(a.per_port[p], c.per_port[p], "{kind} port {p} (fused)");
                assert_eq!(a.per_port[p], d.per_port[p], "{kind} port {p} (flowset)");
            }
        }
    }

    #[test]
    fn prop_blocked_kernel_matches_hashset_on_large_degree_topologies() {
        use crate::util::rng::Xoshiro256;
        // High-arity shapes whose node counts straddle several 64-node
        // blocks — the blocked sweep's tile boundary — with random
        // (non-all-pairs) flows so block occupancy is ragged.
        let specs = [
            PgftSpec::new(vec![16, 8], vec![1, 8], vec![1, 2]).unwrap(),
            PgftSpec::new(vec![24, 6], vec![1, 5], vec![1, 3]).unwrap(),
            PgftSpec::new(vec![8, 4, 4], vec![1, 4, 2], vec![1, 2, 2]).unwrap(),
        ];
        for (si, spec) in specs.iter().enumerate() {
            let topo = build_pgft(spec);
            let n = topo.num_nodes() as u64;
            let mut rng = Xoshiro256::new(0xB10C ^ si as u64);
            let flows: Vec<(u32, u32)> = (0..4 * n)
                .map(|_| (rng.next_below(n) as u32, rng.next_below(n) as u32))
                .collect();
            for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Random] {
                let r = kind.build(&topo, None, si as u64 + 1);
                let routes = trace_flows(&topo, &*r, &flows);
                let blocked = CongestionReport::compute(&topo, &routes);
                let oracle = CongestionReport::compute_hashset(&topo, &routes);
                for p in 0..topo.num_ports() {
                    assert_eq!(
                        blocked.per_port[p], oracle.per_port[p],
                        "spec {si} {kind} port {p}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_striped_kernel_is_bit_exact_on_ragged_boundaries() {
        use crate::util::prop::Prop;
        use std::collections::HashSet;
        // Satellite pin for the striped kernel: random node counts that
        // are NOT multiples of 64 or of the stripe span (STRIPE×64) and
        // random port counts, so the last block of every pass is ragged.
        // Three-way agreement per synthetic flow set: striped vs the
        // retained single-word kernel vs a HashSet oracle, per port,
        // bit-exact.
        Prop::new("striped-kernel-ragged").cases(40).run(|g| {
            let num_nodes = g.usize_in(1, 3 * STRIPE * 64 + 17);
            let num_ports = g.usize_in(1, 257);
            let nflows = g.usize_in(0, 160);
            let mut striped = BitmapAccum::new(num_ports, num_nodes);
            let mut blocked = BitmapAccum::new(num_ports, num_nodes);
            let mut srcs: Vec<HashSet<u32>> = vec![HashSet::new(); num_ports];
            let mut dsts: Vec<HashSet<u32>> = vec![HashSet::new(); num_ports];
            let mut routes = vec![0u32; num_ports];
            for _ in 0..nflows {
                let src = g.usize_in(0, num_nodes - 1) as u32;
                let dst = g.usize_in(0, num_nodes - 1) as u32;
                let hops: Vec<u32> = (0..g.usize_in(0, 7))
                    .map(|_| g.usize_in(0, num_ports - 1) as u32)
                    .collect();
                for &p in &hops {
                    routes[p as usize] += 1;
                    srcs[p as usize].insert(src);
                    dsts[p as usize].insert(dst);
                }
                striped.add(src, dst, hops.iter().copied());
                blocked.add(src, dst, hops.iter().copied());
            }
            let (s, stats) = striped.finish_striped();
            let b = blocked.finish_blocked();
            for p in 0..num_ports {
                let oracle = PortStats {
                    routes: routes[p],
                    srcs: srcs[p].len() as u32,
                    dsts: dsts[p].len() as u32,
                };
                assert_eq!(s.per_port[p], oracle, "striped, port {p}, n={num_nodes}");
                assert_eq!(b.per_port[p], oracle, "blocked, port {p}, n={num_nodes}");
            }
            assert_eq!(stats.merged_words, stats.touched_ports * STRIPE as u64);
        });
    }

    #[test]
    fn distinct_counting_not_route_counting() {
        let topo = build_pgft(&PgftSpec::case_study());
        let r = AlgorithmKind::Dmodk.build(&topo, None, 0);
        // Duplicate the same flow 5 times: distinct src/dst still 1.
        let routes = trace_flows(&topo, &*r, &[(0, 63); 5]);
        let rep = CongestionReport::compute(&topo, &routes);
        assert_eq!(rep.c_topo(), 1);
        let first = routes[0].ports[0];
        assert_eq!(rep.per_port[first].routes, 5);
        assert_eq!(rep.per_port[first].srcs, 1);
        assert_eq!(rep.per_port[first].dsts, 1);
    }
}
