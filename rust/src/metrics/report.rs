//! Tabular congestion summaries — the rows the paper's analysis states
//! (and the benches print).

use super::CongestionReport;
use crate::nodes::NodeTypeMap;
use crate::patterns::Pattern;
use crate::routing::AlgorithmKind;
use crate::topology::Topology;
use anyhow::Result;

/// One row: an algorithm's congestion profile for a pattern.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AlgoSummary {
    /// Algorithm name (`AlgorithmKind::as_str`).
    pub algorithm: String,
    /// Pattern name (`Pattern::name`).
    pub pattern: String,
    /// Number of flows the pattern generated.
    pub flows: usize,
    /// The paper's static metric: `max_p min(src(p), dst(p))`.
    pub c_topo: u32,
    /// Hot ports (C > 1) in total.
    pub hot_total: usize,
    /// Hot ports per level (index 0 = node injection level, 1..=h
    /// switch levels).
    pub hot_per_level: Vec<usize>,
    /// Max `C_p` per level (same indexing), up-ports.
    pub c_max_up: Vec<u32>,
    /// Max `C_p` per level (same indexing), down-ports.
    pub c_max_down: Vec<u32>,
    /// Used top-level down-ports (the resource §III tracks).
    pub used_top_ports: usize,
    /// Total top-level down-ports.
    pub total_top_ports: usize,
}

impl AlgoSummary {
    /// Route `pattern` with `kind` and summarize the congestion metrics
    /// (the fused trace+metric path — no per-route allocation).
    pub fn compute(
        topo: &Topology,
        types: &NodeTypeMap,
        kind: AlgorithmKind,
        pattern: &Pattern,
        seed: u64,
    ) -> Result<AlgoSummary> {
        let router = kind.build(topo, Some(types), seed);
        let flows = pattern.flows(topo, types)?;
        // Fused trace+metric path (no per-route allocation) — §Perf it. 4.
        let rep = CongestionReport::compute_flows(topo, &*router, &flows);
        Ok(Self::from_report(topo, &rep, kind.as_str(), &pattern.name(), flows.len()))
    }

    /// Summarize an already-computed [`CongestionReport`].
    pub fn from_report(
        topo: &Topology,
        rep: &CongestionReport,
        algorithm: &str,
        pattern: &str,
        flows: usize,
    ) -> AlgoSummary {
        let h = topo.spec.h;
        let mut hot_per_level = vec![0usize; h + 1];
        for p in rep.hot_ports() {
            hot_per_level[topo.port_level(p)] += 1;
        }
        let c_max_up: Vec<u32> = (0..=h).map(|l| rep.c_max_at(topo, l, true)).collect();
        let c_max_down: Vec<u32> = (0..=h).map(|l| rep.c_max_at(topo, l, false)).collect();
        AlgoSummary {
            algorithm: algorithm.to_string(),
            pattern: pattern.to_string(),
            flows,
            c_topo: rep.c_topo(),
            hot_total: rep.hot_ports().len(),
            hot_per_level,
            c_max_up,
            c_max_down,
            used_top_ports: rep.used_ports_at(topo, h, false),
            total_top_ports: topo.level_ports(h, false).len(),
        }
    }
}

/// Render a fixed-width comparison table for several algorithm rows.
pub fn render_algorithm_table(rows: &[AlgoSummary]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:<10} {:>6} {:>7} {:>9} {:>12} {:>14} {:>12}\n",
        "algo", "pattern", "flows", "C_topo", "hot-ports", "hot-top-lvl", "used-top-ports", "Cmax-by-lvl"
    ));
    out.push_str(&"-".repeat(96));
    out.push('\n');
    for r in rows {
        let h = r.hot_per_level.len() - 1;
        let cmax: Vec<String> = (0..=h)
            .map(|l| format!("{}/{}", r.c_max_up[l], r.c_max_down[l]))
            .collect();
        out.push_str(&format!(
            "{:<10} {:<10} {:>6} {:>7} {:>9} {:>12} {:>11}/{:<3} {:>12}\n",
            r.algorithm,
            r.pattern,
            r.flows,
            r.c_topo,
            r.hot_total,
            r.hot_per_level[h],
            r.used_top_ports,
            r.total_top_ports,
            cmax.join(" "),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::topology::{build_pgft, PgftSpec};

    #[test]
    fn summary_for_dmodk_case_study() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let s = AlgoSummary::compute(&topo, &types, AlgorithmKind::Dmodk, &Pattern::C2ioSym, 0)
            .unwrap();
        assert_eq!(s.c_topo, 4, "paper §III.B");
        assert_eq!(s.flows, 56);
        // Exactly two hot top-level ports.
        assert_eq!(s.hot_per_level[3], 2);
    }

    #[test]
    fn table_renders_all_rows() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let rows: Vec<AlgoSummary> = AlgorithmKind::ALL
            .iter()
            .map(|&k| {
                AlgoSummary::compute(&topo, &types, k, &Pattern::C2ioSym, 1).unwrap()
            })
            .collect();
        let t = render_algorithm_table(&rows);
        for k in AlgorithmKind::ALL {
            assert!(t.contains(k.as_str()), "{t}");
        }
        assert_eq!(t.lines().count(), 2 + rows.len());
    }
}
