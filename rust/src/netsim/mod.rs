//! Event-driven flit-level network simulator — the "simulation-based
//! analysis" the paper's conclusions call for, at the fidelity standard
//! in the interconnect literature (latency-vs-offered-load curves,
//! FatPaths-style): input-buffered switches with virtual channels,
//! credit-based flow control, configurable link latency and packet
//! size, a calendar-queue event core, and pluggable seeded injection
//! processes.
//!
//! The simulator consumes *any* traced route set — every
//! [`crate::routing::AlgorithmKind`], and
//! [`crate::faults::DegradedRouter`] tables too, so fault scenarios are
//! simulatable end-to-end. It is fully deterministic in
//! `(routes, config, rate)`: the same seed reproduces every curve
//! byte-for-byte, which `tests/netsim_parity.rs` pins.
//!
//! Layering:
//!  * [`event`] — the calendar-queue event core (deterministic total
//!    order per cycle),
//!  * [`engine`] — VC/credit port model over precomputed routes,
//!  * [`inject`] — Bernoulli / burst packet-arrival processes,
//!  * [`curve`] — injection-rate sweeps, the latency-vs-load table and
//!    saturation-point detection,
//!  * [`phased`] — phase-sequenced replay of a workload's flow-table
//!    sequence (sources swap tables at phase boundaries; see
//!    [`crate::workload`]).
//!
//! Units: one cycle forwards one flit per port, i.e. links have
//! capacity 1 flit/cycle — the exact unit scale of
//! [`crate::sim::solve_fairrate_exact`], which remains the *low-load
//! oracle*: below saturation, netsim per-flow throughput must agree
//! with the fair-rate solution (pinned by the parity test).
//!
//! ```
//! use pgft::prelude::*;
//! use pgft::eval::FlowSet;
//! use pgft::netsim::{run_netsim, NetsimConfig};
//! let topo = build_pgft(&PgftSpec::case_study());
//! let types = Placement::paper_io().apply(&topo).unwrap();
//! let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
//! let router = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1);
//! let set = FlowSet::trace(&topo, &*router, &flows);
//! let cfg = NetsimConfig { warmup: 200, measure: 1000, drain: 200, ..Default::default() };
//! let rep = run_netsim(&topo, &set, &cfg, 0.05).unwrap();
//! assert!(!rep.saturated, "gdmodk is stable well below its 1/7 fair rate");
//! ```

pub mod curve;
pub mod engine;
pub mod event;
pub mod inject;
pub mod phased;

pub use curve::{
    curve_table, default_rates, load_curve, load_curve_recorded, load_curve_with,
    saturation_point, CurvePoint, Saturation,
};
pub use inject::Injection;
pub use phased::{run_netsim_phased, run_netsim_phased_recorded, PhaseNetsim, PhasedNetsimReport};

use crate::eval::FlowSet;
use crate::telemetry::{Recorder, RunInfo, Telemetry};
use crate::topology::Topology;
use anyhow::{ensure, Result};

/// A run counts as saturated when it accepts less than this fraction of
/// the aggregate offered load (the standard "accepted < offered" knee
/// test, with slack for open-loop sampling noise).
pub const SATURATION_FRACTION: f64 = 0.85;

/// Tunables of a flit-level simulation run (see the module docs for the
/// model; [`NetsimConfig::default`] matches the case-study scale).
#[derive(Clone, Debug, PartialEq)]
pub struct NetsimConfig {
    /// Flits per packet.
    pub packet_flits: u32,
    /// Virtual channels per port.
    pub vcs: u32,
    /// Buffer capacity of one (port, VC) pair, in flits.
    pub vc_capacity: u32,
    /// Link traversal latency in cycles (≥ 1).
    pub link_latency: u64,
    /// Cycles before measurement starts (reach steady state).
    pub warmup: u64,
    /// Measurement-window length in cycles.
    pub measure: u64,
    /// Extra cycles after the window so in-flight tagged packets can
    /// complete and report their latency.
    pub drain: u64,
    /// The packet-arrival process.
    pub injection: Injection,
    /// Seed of the per-flow injection streams.
    pub seed: u64,
}

impl Default for NetsimConfig {
    fn default() -> Self {
        NetsimConfig {
            packet_flits: 4,
            vcs: 2,
            vc_capacity: 8,
            link_latency: 1,
            warmup: 300,
            measure: 1500,
            drain: 300,
            injection: Injection::Bernoulli,
            seed: 1,
        }
    }
}

impl NetsimConfig {
    /// Reject degenerate parameter combinations with a clear message.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.packet_flits >= 1, "netsim: packet_flits must be >= 1");
        ensure!(self.vcs >= 1, "netsim: vcs must be >= 1");
        ensure!(self.vc_capacity >= 1, "netsim: vc_capacity must be >= 1");
        ensure!(self.link_latency >= 1, "netsim: link_latency must be >= 1");
        ensure!(self.measure >= 1, "netsim: measure window must be >= 1 cycle");
        Ok(())
    }
}

/// Result of one flit-level run at a single offered load.
#[derive(Clone, Debug, PartialEq)]
pub struct NetsimReport {
    /// Offered load per flow, flits/cycle (the swept knob).
    pub offered: f64,
    /// Offered load × active flows (aggregate flits/cycle).
    pub offered_aggregate: f64,
    /// Accepted throughput: flits delivered per cycle inside the
    /// measurement window, aggregated over all flows.
    pub accepted: f64,
    /// Per-flow accepted throughput (flits/cycle, measurement window).
    pub flow_accepted: Vec<f64>,
    /// Mean packet latency in cycles over packets *injected* in the
    /// window and delivered by the end of the run (0 when none).
    pub mean_latency: f64,
    /// 99th-percentile packet latency (same sample; 0 when none).
    pub p99_latency: f64,
    /// Packets created by the injection processes over the whole run.
    pub injected_packets: u64,
    /// Packets fully delivered over the whole run.
    pub delivered_packets: u64,
    /// Latency sample size (tagged packets delivered in time).
    pub measured_packets: u64,
    /// Active (non-self) flows.
    pub flows: usize,
    /// Total events the calendar processed (cost/debug figure).
    pub events: u64,
    /// Whether accepted fell below
    /// [`SATURATION_FRACTION`] × `offered_aggregate`.
    pub saturated: bool,
}

/// Run one flit-level simulation of a traced route store on `topo` at
/// offered load `rate` (flits per cycle per flow, in `(0, 1]`).
/// Deterministic in `(flows, cfg, rate)`. The store is borrowed — the
/// same [`FlowSet`] a sweep cell's other evaluators read.
pub fn run_netsim(
    topo: &Topology,
    flows: &FlowSet,
    cfg: &NetsimConfig,
    rate: f64,
) -> Result<NetsimReport> {
    run_netsim_with(topo, flows, cfg, rate, &Telemetry::disabled())
}

/// [`run_netsim`] with an instrumentation handle. A disabled handle is
/// exactly `run_netsim` (nothing allocates); a live one additionally
/// merges the run's counters into the handle's registry — per-port
/// forwarded flits and credit stalls, per-VC occupancy high-water
/// marks, the queue-depth histogram, per-flow injected/delivered
/// counts, the flit-conservation ledger, and one `netsim.run`
/// wall-clock span. The report itself is byte-identical either way
/// (pinned by `tests/telemetry.rs`).
pub fn run_netsim_with(
    topo: &Topology,
    flows: &FlowSet,
    cfg: &NetsimConfig,
    rate: f64,
    telem: &Telemetry,
) -> Result<NetsimReport> {
    run_netsim_recorded(topo, flows, cfg, rate, telem, &Recorder::disabled(), RunInfo::default())
}

/// [`run_netsim_with`] with a flight-recorder handle. A disabled
/// handle is exactly `run_netsim_with`; a live one additionally
/// samples the run into a windowed time-series [`Recording`]
/// (collected from the handle via [`Recorder::take`]) labelled by
/// `info`. The report stays byte-identical either way — the recorder
/// only observes simulated quantities (pinned by `tests/recorder.rs`).
pub fn run_netsim_recorded(
    topo: &Topology,
    flows: &FlowSet,
    cfg: &NetsimConfig,
    rate: f64,
    telem: &Telemetry,
    rec: &Recorder,
    info: RunInfo,
) -> Result<NetsimReport> {
    cfg.validate()?;
    rec.config().validate()?;
    ensure!(
        rate > 0.0 && rate <= 1.0,
        "netsim: offered load {rate} outside (0, 1] flits/cycle/flow"
    );
    ensure!(flows.num_active() > 0, "netsim: no active flows to simulate");
    let engine = engine::Engine::new(topo.num_ports(), flows, cfg, rate, None)
        .instrument(telem)
        .record(rec, cfg, info, Vec::new());
    Ok(telem.time("netsim.run", || engine.run()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::patterns::Pattern;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    fn routes(kind: AlgorithmKind) -> (Topology, FlowSet) {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
        let router = kind.build(&topo, Some(&types), 1);
        let set = FlowSet::trace(&topo, &*router, &flows);
        (topo, set)
    }

    fn small_cfg() -> NetsimConfig {
        NetsimConfig { warmup: 200, measure: 800, drain: 200, ..Default::default() }
    }

    #[test]
    fn low_load_is_stable_and_accepts_offered() {
        let (topo, routes) = routes(AlgorithmKind::Gdmodk);
        let rep = run_netsim(&topo, &routes, &small_cfg(), 0.05).unwrap();
        assert_eq!(rep.flows, 56);
        assert!(!rep.saturated, "{rep:?}");
        // Open-loop low load: accepted tracks offered (sampling slack).
        assert!(rep.accepted > 0.6 * rep.offered_aggregate, "{rep:?}");
        assert!(rep.accepted < 1.4 * rep.offered_aggregate, "{rep:?}");
        assert!(rep.measured_packets > 0);
        assert!(rep.mean_latency >= 6.0, "at least one cycle per hop: {rep:?}");
        assert!(rep.p99_latency >= rep.mean_latency);
    }

    #[test]
    fn overload_saturates_at_the_bottleneck_capacity() {
        // Dmodk funnels all 56 C2IO flows through 2 top down-ports, so
        // accepted throughput caps near 2 flits/cycle however hard the
        // sources push.
        let (topo, routes) = routes(AlgorithmKind::Dmodk);
        let rep = run_netsim(&topo, &routes, &small_cfg(), 0.8).unwrap();
        assert!(rep.saturated, "{rep:?}");
        assert!(rep.accepted <= 2.2, "top bundle capacity is 2 flits/cycle: {rep:?}");
        assert!(rep.accepted > 1.0, "the bottleneck stays busy: {rep:?}");
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let (topo, routes) = routes(AlgorithmKind::Smodk);
        let a = run_netsim(&topo, &routes, &small_cfg(), 0.3).unwrap();
        let b = run_netsim(&topo, &routes, &small_cfg(), 0.3).unwrap();
        assert_eq!(a, b, "identical seeds must reproduce bit-identical reports");
        let mut cfg = small_cfg();
        cfg.seed = 2;
        let c = run_netsim(&topo, &routes, &cfg, 0.3).unwrap();
        assert_ne!(a.injected_packets, 0);
        assert_ne!(a, c, "a different seed draws different arrivals");
    }

    #[test]
    fn burst_injection_raises_latency_at_equal_load() {
        let (topo, routes) = routes(AlgorithmKind::Gdmodk);
        let smooth = run_netsim(&topo, &routes, &small_cfg(), 0.1).unwrap();
        let mut cfg = small_cfg();
        cfg.injection = Injection::Burst { length: 4 };
        let bursty = run_netsim(&topo, &routes, &cfg, 0.1).unwrap();
        // Equal mean load within sampling noise...
        assert!(!bursty.saturated, "{bursty:?}");
        // ...but bursts queue behind each other at the source.
        assert!(
            bursty.mean_latency > smooth.mean_latency,
            "burst {bursty:?} vs smooth {smooth:?}"
        );
    }

    #[test]
    fn bad_inputs_are_rejected() {
        let (topo, routes) = routes(AlgorithmKind::Dmodk);
        assert!(run_netsim(&topo, &routes, &small_cfg(), 0.0).is_err());
        assert!(run_netsim(&topo, &routes, &small_cfg(), 1.5).is_err());
        let mut cfg = small_cfg();
        cfg.vcs = 0;
        assert!(run_netsim(&topo, &routes, &cfg, 0.5).is_err());
        let mut cfg = small_cfg();
        cfg.link_latency = 0;
        assert!(run_netsim(&topo, &routes, &cfg, 0.5).is_err());
        // All-self-flow route sets cannot be simulated.
        let router = AlgorithmKind::Dmodk.build(&topo, None, 0);
        let self_routes = FlowSet::trace(&topo, &*router, &[(0, 0)]);
        assert!(run_netsim(&topo, &self_routes, &small_cfg(), 0.5).is_err());
    }
}
