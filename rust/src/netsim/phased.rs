//! Phase-sequenced flit-level simulation: sources swap flow tables at
//! phase boundaries.
//!
//! A workload's fluid evaluation ([`crate::workload::evaluate_makespan`])
//! produces a sequence of global phases, each with its own flow union.
//! This runner replays that sequence in **one continuous** flit-level
//! simulation: the per-phase route stores are concatenated into a single
//! arena ([`FlowSet::concat`]) and every flow gets a disjoint injection
//! window — phase `k`'s sources start injecting exactly when phase
//! `k−1`'s window closes, while `k−1`'s in-flight packets are still
//! draining through the same fabric. Cross-phase interference (a
//! checkpoint burst landing on a fabric still congested by the previous
//! allreduce step) is therefore modelled, which per-phase independent
//! runs would miss.
//!
//! Timeline: `cfg.warmup` cycles of phase-0 traffic to reach steady
//! state, then `cfg.measure` measured cycles **per phase**, then
//! `cfg.drain` cycles for stragglers. Per-phase throughput counts only
//! flits delivered while the phase's own window was live (so a
//! saturated phase's draining backlog congests its successors — which
//! is the point — but cannot inflate its own figure); latency samples
//! attribute to the injecting phase however late the packet lands.
//! Sources are open-loop within a window: a phase pushed past
//! saturation keeps draining its backlog after its window closes, like
//! an application that over-ran its phase budget.
//!
//! Determinism matches the rest of `netsim`: the same
//! `(phases, cfg, rate)` reproduce the report byte-for-byte.

use super::engine::{summarize_latencies, Engine};
use super::{NetsimConfig, SATURATION_FRACTION};
use crate::eval::FlowSet;
use crate::telemetry::{Recorder, RunInfo};
use crate::topology::Topology;
use anyhow::{ensure, Result};

/// Flit-level figures of one phase of a phase-sequenced run.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseNetsim {
    /// Phase index (aligned with the workload's phase sequence).
    pub phase: usize,
    /// Active (non-self) flows injecting during the phase.
    pub flows: usize,
    /// Offered aggregate of the phase (rate × active flows).
    pub offered_aggregate: f64,
    /// Accepted aggregate throughput of the phase's flows
    /// (flits/cycle, normalized by the per-phase window).
    pub accepted: f64,
    /// Mean packet latency of the phase's flows (cycles; 0 when no
    /// packet was measured).
    pub mean_latency: f64,
    /// 99th-percentile packet latency of the phase's flows.
    pub p99_latency: f64,
    /// Whether the phase accepted less than
    /// [`SATURATION_FRACTION`] × its offered aggregate.
    pub saturated: bool,
}

/// Result of one phase-sequenced run.
#[derive(Clone, Debug, PartialEq)]
pub struct PhasedNetsimReport {
    /// Per-phase figures, in phase order (idle-only phases report zero
    /// flows and are never saturated).
    pub phases: Vec<PhaseNetsim>,
    /// Total events the calendar processed.
    pub events: u64,
    /// Packets created over the whole run.
    pub injected_packets: u64,
    /// Packets fully delivered over the whole run.
    pub delivered_packets: u64,
}

/// Run the phase sequence `phase_sets` (one traced [`FlowSet`] per
/// phase, e.g. from [`crate::workload::phase_flowsets`]) at offered
/// load `rate` per flow. At least one phase must carry an active flow;
/// individual idle phases are allowed and simply hold their window
/// open with nothing injecting.
pub fn run_netsim_phased(
    topo: &Topology,
    phase_sets: &[FlowSet],
    cfg: &NetsimConfig,
    rate: f64,
) -> Result<PhasedNetsimReport> {
    let rec = Recorder::disabled();
    run_netsim_phased_recorded(topo, phase_sets, cfg, rate, &rec, RunInfo::default())
}

/// [`run_netsim_phased`] with a flight-recorder handle. The phase-end
/// cycles are passed to the recorder as forced window-rollover marks,
/// so every recorded window lies entirely inside one phase and the
/// series can be segmented at phase boundaries exactly (pinned by
/// `tests/recorder.rs`). The report is byte-identical either way.
pub fn run_netsim_phased_recorded(
    topo: &Topology,
    phase_sets: &[FlowSet],
    cfg: &NetsimConfig,
    rate: f64,
    rec: &Recorder,
    info: RunInfo,
) -> Result<PhasedNetsimReport> {
    cfg.validate()?;
    rec.config().validate()?;
    ensure!(
        rate > 0.0 && rate <= 1.0,
        "netsim: offered load {rate} outside (0, 1] flits/cycle/flow"
    );
    ensure!(!phase_sets.is_empty(), "netsim: empty phase sequence");
    ensure!(
        phase_sets.iter().any(|s| s.num_active() > 0),
        "netsim: no phase carries an active flow"
    );
    let refs: Vec<&FlowSet> = phase_sets.iter().collect();
    let union = FlowSet::concat(&refs);
    let n_phases = phase_sets.len();
    let m = cfg.measure;

    // Injection windows: phase 0 additionally owns the warmup so the
    // fabric is in steady state when its measured window opens.
    let mut windows = Vec::with_capacity(union.len());
    let mut ranges = Vec::with_capacity(n_phases); // flow-index range per phase
    let mut base = 0usize;
    for (k, set) in phase_sets.iter().enumerate() {
        let start = if k == 0 { 0 } else { cfg.warmup + k as u64 * m };
        let end = cfg.warmup + (k as u64 + 1) * m;
        windows.extend(std::iter::repeat((start, end)).take(set.len()));
        ranges.push(base..base + set.len());
        base += set.len();
    }

    // One continuous run: global measurement window spans every phase.
    let run_cfg = NetsimConfig { measure: n_phases as u64 * m, ..cfg.clone() };
    // Phase-end cycles force recorder window rollovers so no recorded
    // window straddles a table swap.
    let marks: Vec<u64> = (0..n_phases).map(|k| cfg.warmup + (k as u64 + 1) * m).collect();
    let detail = Engine::new(topo.num_ports(), &union, &run_cfg, rate, Some(windows))
        .record(rec, &run_cfg, info, marks)
        .run_detailed();
    let report = &detail.report;

    // Bucket the per-flow figures back into phases. `flow_accepted` is
    // normalized by the global window; rescale to the per-phase window.
    let phases = ranges
        .iter()
        .enumerate()
        .map(|(k, range)| {
            let active =
                range.clone().filter(|&f| !union.route(f).is_empty()).count();
            let accepted: f64 = range
                .clone()
                .map(|f| report.flow_accepted[f] * n_phases as f64)
                .sum();
            let mut lat: Vec<(u32, u64)> = detail
                .latencies
                .iter()
                .filter(|&&(f, _)| range.contains(&(f as usize)))
                .copied()
                .collect();
            lat.sort_unstable_by_key(|&(_, l)| l);
            let (mean_latency, p99_latency) = summarize_latencies(&lat);
            let offered_aggregate = rate * active as f64;
            PhaseNetsim {
                phase: k,
                flows: active,
                offered_aggregate,
                accepted,
                mean_latency,
                p99_latency,
                saturated: active > 0
                    && accepted < SATURATION_FRACTION * offered_aggregate,
            }
        })
        .collect();

    Ok(PhasedNetsimReport {
        phases,
        events: report.events,
        injected_packets: report.injected_packets,
        delivered_packets: report.delivered_packets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::patterns::Pattern;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    fn setup() -> (Topology, Vec<FlowSet>) {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let router = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1);
        let phases = [Pattern::C2ioSym, Pattern::Io2cSym, Pattern::Shift { k: 1 }]
            .iter()
            .map(|p| FlowSet::trace(&topo, &*router, &p.flows(&topo, &types).unwrap()))
            .collect();
        (topo, phases)
    }

    fn small_cfg() -> NetsimConfig {
        NetsimConfig { warmup: 200, measure: 600, drain: 200, ..Default::default() }
    }

    #[test]
    fn phases_report_independently_and_deterministically() {
        let (topo, phases) = setup();
        let a = run_netsim_phased(&topo, &phases, &small_cfg(), 0.05).unwrap();
        assert_eq!(a.phases.len(), 3);
        for (k, p) in a.phases.iter().enumerate() {
            assert_eq!(p.phase, k);
            assert!(p.flows > 0);
            assert!(p.accepted > 0.0, "phase {k}: {p:?}");
            assert!(!p.saturated, "gdmodk at 5% load is stable: {p:?}");
            assert!(p.mean_latency >= 6.0, "all phases cross >= 6 hops: {p:?}");
            assert!(p.p99_latency >= p.mean_latency);
        }
        let b = run_netsim_phased(&topo, &phases, &small_cfg(), 0.05).unwrap();
        assert_eq!(a, b, "same inputs, byte-identical report");
        let mut cfg = small_cfg();
        cfg.seed = 2;
        assert_ne!(a, run_netsim_phased(&topo, &phases, &cfg, 0.05).unwrap());
    }

    #[test]
    fn idle_phases_are_quiet_windows() {
        let (topo, mut phases) = setup();
        phases.insert(1, FlowSet::empty());
        let rep = run_netsim_phased(&topo, &phases, &small_cfg(), 0.05).unwrap();
        assert_eq!(rep.phases.len(), 4);
        let idle = &rep.phases[1];
        assert_eq!((idle.flows, idle.accepted), (0, 0.0), "{idle:?}");
        assert!(!idle.saturated);
        assert!(rep.phases[2].accepted > 0.0, "traffic resumes after the gap");
    }

    #[test]
    fn overloaded_phases_saturate_individually() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let dmodk = AlgorithmKind::Dmodk.build(&topo, Some(&types), 1);
        let gdmodk = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1);
        let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
        // Same pattern, one phase per router: dmodk's 2-port funnel
        // saturates at 0.6 flits/cycle/flow, gdmodk accepts far more.
        let phases =
            vec![FlowSet::trace(&topo, &*dmodk, &flows), FlowSet::trace(&topo, &*gdmodk, &flows)];
        let rep = run_netsim_phased(&topo, &phases, &small_cfg(), 0.6).unwrap();
        assert!(rep.phases[0].saturated, "{:?}", rep.phases[0]);
        assert!(
            rep.phases[1].accepted > 1.5 * rep.phases[0].accepted,
            "gdmodk {:?} vs dmodk {:?}",
            rep.phases[1],
            rep.phases[0]
        );
    }

    #[test]
    fn bad_inputs_are_rejected() {
        let (topo, phases) = setup();
        assert!(run_netsim_phased(&topo, &phases, &small_cfg(), 0.0).is_err());
        assert!(run_netsim_phased(&topo, &phases, &small_cfg(), 1.5).is_err());
        assert!(run_netsim_phased(&topo, &[], &small_cfg(), 0.5).is_err());
        assert!(
            run_netsim_phased(&topo, &[FlowSet::empty()], &small_cfg(), 0.5).is_err(),
            "all-idle phase sequences cannot be simulated"
        );
    }
}
