//! The simulation engine: input-buffered ports with virtual channels
//! and credit-based flow control over precomputed routes.
//!
//! Model (one cycle = the time a port needs to forward one flit; links
//! are normalized to capacity 1 flit/cycle, the fair-rate solver's unit
//! scale):
//!
//!  * Every directed output port owns `vcs` virtual-channel FIFOs of
//!    `vc_capacity` flits. A packet is assigned one VC at creation
//!    (round-robin per flow) and keeps it on every hop.
//!  * **Credits**: a flit may only be transmitted toward the next port
//!    of its route if that port's VC buffer has a free slot. The slot is
//!    reserved at transmit time and freed when the flit itself is
//!    transmitted onward — exact credit flow control with the credit
//!    loop collapsed to the link latency.
//!  * **Arbitration**: each cycle a port forwards at most one flit,
//!    picking the next serviceable VC round-robin from the last one
//!    served. A head flit whose downstream credit is exhausted blocks
//!    its VC (head-of-line blocking within a VC is modelled; other VCs
//!    overtake).
//!  * **Sources** are open-loop: the injection process appends packets
//!    to an unbounded per-flow backlog, and the source pushes at most
//!    one flit per cycle into the first route port's VC buffer, credit
//!    permitting. Offered load is therefore not throttled by the
//!    fabric — exactly what makes saturation visible.
//!
//! Because all routes are minimal up\*/down\* port sequences (any
//! [`crate::routing::Router`], including
//! [`crate::faults::DegradedRouter`]), the channel dependency graph is
//! acyclic and the credit loops cannot deadlock.

use super::event::{Calendar, Event};
use super::inject::draw_gap;
use super::{NetsimConfig, NetsimReport, SATURATION_FRACTION};
use crate::eval::FlowSet;
use crate::telemetry::recorder::EngineRec;
use crate::telemetry::{hist_bucket, Recorder, Registry, RunInfo, Telemetry, VecKind, HIST_BUCKETS};
use crate::util::rng::Xoshiro256;
use std::collections::VecDeque;

/// One buffered flit: which packet it belongs to and which hop (index
/// into the packet's route) the buffering port is.
#[derive(Clone, Copy, Debug)]
struct Flit {
    packet: u32,
    hop: u16,
}

/// An in-flight packet.
#[derive(Clone, Copy, Debug)]
struct Packet {
    flow: u32,
    arrival: u64,
    vc: u32,
    pushed: u32,
    delivered: u32,
}

/// Per-run instrumentation arrays. Allocated only when a live
/// [`Telemetry`] handle is attached ([`Engine::instrument`]); the hot
/// loop records into plain vectors (no lock, no map lookup) and
/// `finish` folds them into the handle's registry in one merge.
/// Everything here is keyed by simulated quantities — cycles, flits,
/// queue depths — never wall-clock, so an instrumented run stays
/// byte-identical to an uninstrumented one.
struct EngineTelem {
    handle: Telemetry,
    /// Flits transmitted per port (final-hop transmits included).
    port_forwarded: Vec<u64>,
    /// Service rounds in which a port held head flits but every one
    /// was blocked on downstream credit.
    port_credit_stalls: Vec<u64>,
    /// Occupancy high-water mark per (port, VC) buffer slot.
    vc_occupancy_hwm: Vec<u64>,
    /// Power-of-two queue-depth histogram, sampled at every push.
    queue_depth: Vec<u64>,
    /// Packets created per flow.
    flow_injected_packets: Vec<u64>,
    /// Flits delivered per flow.
    flow_delivered_flits: Vec<u64>,
}

impl EngineTelem {
    fn new(handle: Telemetry, num_ports: usize, vcs: usize, nf: usize) -> EngineTelem {
        EngineTelem {
            handle,
            port_forwarded: vec![0; num_ports],
            port_credit_stalls: vec![0; num_ports],
            vc_occupancy_hwm: vec![0; num_ports * vcs],
            queue_depth: vec![0; HIST_BUCKETS],
            flow_injected_packets: vec![0; nf],
            flow_delivered_flits: vec![0; nf],
        }
    }

    /// Record one buffer push: `qi` is the (port, VC) slot, `depth`
    /// the queue length after the push.
    fn push_sample(&mut self, qi: usize, depth: u64) {
        self.vc_occupancy_hwm[qi] = self.vc_occupancy_hwm[qi].max(depth);
        self.queue_depth[hist_bucket(depth)] += 1;
    }
}

/// Mutable simulation state over a borrowed route store.
pub(crate) struct Engine<'a> {
    flows: &'a FlowSet,
    rate: f64,
    // Config (copied out for borrow-friendly field access).
    packet_flits: u32,
    vcs: usize,
    link_latency: u64,
    warmup: u64,
    measure: u64,
    drain: u64,
    p_event: f64,
    burst: u32,
    // Per (port, vc): FIFO buffer and free-slot (credit) count.
    queues: Vec<VecDeque<Flit>>,
    credits: Vec<u32>,
    // Per port: single-outstanding-event flags and round-robin pointer.
    service_pending: Vec<bool>,
    last_vc: Vec<usize>,
    // Per flow: source state.
    source_pending: Vec<bool>,
    next_vc: Vec<u32>,
    backlog: Vec<VecDeque<u32>>,
    rngs: Vec<Xoshiro256>,
    packets: Vec<Packet>,
    cal: Calendar,
    // Per-flow injection window `[start, end)` in cycles. The default
    // (whole run) reproduces the classic single-table behavior
    // bit-for-bit; phase-sequenced runs give each phase's flows a
    // disjoint window so sources swap flow tables at phase boundaries
    // (see `netsim::phased`).
    windows: Vec<(u64, u64)>,
    // Statistics.
    injected_packets: u64,
    delivered_packets: u64,
    accepted_flits: u64,
    flow_flits: Vec<u64>,
    latencies: Vec<(u32, u64)>,
    // Flit-conservation accounting (always on — a handful of u64 bumps
    // per flit event, asserted at finish in debug builds) and the
    // optional instrumentation arrays.
    created_flits: u64,
    delivered_flits: u64,
    in_flight_flits: u64,
    telem: Option<Box<EngineTelem>>,
    // The optional flight-recorder accumulator (windowed time-series).
    // Like `telem`, a `None` costs one branch per record site, so a
    // recorded run stays byte-identical to an unrecorded one.
    rec: Option<Box<EngineRec>>,
}

/// A finished run plus the per-flow detail the phase-sequenced runner
/// needs (the public [`NetsimReport`] keeps only aggregates).
pub(crate) struct RunDetail {
    /// The aggregate report (identical to what [`Engine::run`] returns).
    pub report: NetsimReport,
    /// `(flow, latency)` of every packet injected inside the measurement
    /// window and delivered in time.
    pub latencies: Vec<(u32, u64)>,
}

impl<'a> Engine<'a> {
    /// Set up a run of the route store at offered load `rate` (flits
    /// per cycle per flow). The caller validated `cfg` and `rate`.
    /// `windows` optionally restricts each flow's injection to
    /// `[start, end)` cycles (one entry per flow); `None` keeps every
    /// source active for the whole run.
    pub(crate) fn new(
        num_ports: usize,
        flows: &'a FlowSet,
        cfg: &NetsimConfig,
        rate: f64,
        windows: Option<Vec<(u64, u64)>>,
    ) -> Engine<'a> {
        let vcs = cfg.vcs as usize;
        let nf = flows.len();
        let horizon = cfg.warmup + cfg.measure + cfg.drain;
        let rngs = (0..nf)
            .map(|f| {
                Xoshiro256::new(
                    cfg.seed.wrapping_add((f as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                )
            })
            .collect();
        Engine {
            flows,
            rate,
            packet_flits: cfg.packet_flits,
            vcs,
            link_latency: cfg.link_latency,
            warmup: cfg.warmup,
            measure: cfg.measure,
            drain: cfg.drain,
            p_event: cfg.injection.event_probability(rate, cfg.packet_flits),
            burst: cfg.injection.burst_len(),
            queues: vec![VecDeque::new(); num_ports * vcs],
            credits: vec![cfg.vc_capacity; num_ports * vcs],
            service_pending: vec![false; num_ports],
            last_vc: vec![0; num_ports],
            source_pending: vec![false; nf],
            next_vc: vec![0; nf],
            backlog: vec![VecDeque::new(); nf],
            rngs,
            packets: Vec::new(),
            cal: Calendar::new(horizon),
            windows: windows.unwrap_or_else(|| vec![(0, u64::MAX); nf]),
            injected_packets: 0,
            delivered_packets: 0,
            accepted_flits: 0,
            flow_flits: vec![0; nf],
            latencies: Vec::new(),
            created_flits: 0,
            delivered_flits: 0,
            in_flight_flits: 0,
            telem: None,
            rec: None,
        }
    }

    /// Attach a telemetry handle. A disabled handle changes nothing —
    /// no arrays are allocated and every record site stays a single
    /// branch on `None`; a live one allocates the per-port, per-VC and
    /// per-flow accumulators merged into its registry at finish.
    pub(crate) fn instrument(mut self, telem: &Telemetry) -> Engine<'a> {
        if telem.is_enabled() {
            let (np, vcs, nf) = (self.service_pending.len(), self.vcs, self.flows.len());
            self.telem = Some(Box::new(EngineTelem::new(telem.clone(), np, vcs, nf)));
        }
        self
    }

    /// Attach a flight-recorder handle. Disabled handles change
    /// nothing; a live one allocates the window accumulator and pushes
    /// one [`crate::telemetry::Recording`] into the sink at finish.
    /// `info` labels the recording; `phases` lists forced window
    /// rollover cycles (phase ends of a phased replay).
    pub(crate) fn record(
        mut self,
        rec: &Recorder,
        cfg: &NetsimConfig,
        info: RunInfo,
        phases: Vec<u64>,
    ) -> Engine<'a> {
        if rec.is_enabled() {
            let num_ports = self.service_pending.len();
            self.rec = Some(Box::new(EngineRec::new(
                rec,
                info,
                cfg,
                self.rate,
                num_ports,
                self.flows.len(),
                phases,
            )));
        }
        self
    }

    /// Run to the horizon and summarize.
    pub(crate) fn run(self) -> NetsimReport {
        self.run_detailed().report
    }

    /// Run to the horizon and return the report plus per-flow latency
    /// samples (the phase-sequenced runner buckets them per phase).
    pub(crate) fn run_detailed(mut self) -> RunDetail {
        let end = self.warmup + self.measure + self.drain;
        // Seed the first arrival of every active flow at the start of
        // its injection window (gap ≥ 1, so the calendar cursor
        // invariant holds from cycle 0).
        for f in 0..self.flows.len() {
            if self.flows.route(f).is_empty() {
                continue; // self-flow: nothing to simulate
            }
            let gap = draw_gap(&mut self.rngs[f], self.p_event);
            // saturating: a near-infinite gap simply lands past the horizon.
            self.cal
                .schedule(self.windows[f].0.saturating_add(gap), Event::NewPacket { flow: f as u32 });
        }
        for t in 1..=end {
            for (_seq, ev) in self.cal.take(t) {
                match ev {
                    Event::Service { port } => self.on_service(port as usize, t),
                    Event::NewPacket { flow } => self.on_new_packet(flow as usize, t),
                    Event::Source { flow } => self.on_source(flow as usize, t),
                    Event::Arrive { port, packet, hop } => {
                        self.on_arrive(port as usize, packet, hop, t)
                    }
                }
            }
            if let Some(r) = self.rec.as_deref_mut() {
                r.maybe_close(t);
            }
        }
        self.finish()
    }

    fn wake_service(&mut self, port: usize, t: u64) {
        if !self.service_pending[port] {
            self.service_pending[port] = true;
            self.cal.schedule(t, Event::Service { port: port as u32 });
        }
    }

    fn wake_source(&mut self, flow: usize, t: u64) {
        if !self.source_pending[flow] {
            self.source_pending[flow] = true;
            self.cal.schedule(t, Event::Source { flow: flow as u32 });
        }
    }

    /// The injection process fires: create `burst` packets (while the
    /// flow's injection window is open), wake the source, draw the next
    /// inter-arrival gap. Both the creation and the next draw are gated
    /// on the window still being open — a closed window stops the
    /// flow's RNG stream. Default-window runs (`end = u64::MAX`) never
    /// take the closed branch, which is what keeps classic whole-run
    /// netsim bit-identical to the pre-window engine.
    fn on_new_packet(&mut self, flow: usize, t: u64) {
        if t < self.windows[flow].1 {
            for _ in 0..self.burst {
                let vc = self.next_vc[flow] % self.vcs as u32;
                self.next_vc[flow] = self.next_vc[flow].wrapping_add(1);
                let pid = self.packets.len() as u32;
                let pkt = Packet { flow: flow as u32, arrival: t, vc, pushed: 0, delivered: 0 };
                self.packets.push(pkt);
                self.backlog[flow].push_back(pid);
                self.injected_packets += 1;
                if let Some(tm) = self.telem.as_deref_mut() {
                    tm.flow_injected_packets[flow] += 1;
                }
                if let Some(r) = self.rec.as_deref_mut() {
                    r.on_injected();
                }
            }
            self.wake_source(flow, t + 1);
            let gap = draw_gap(&mut self.rngs[flow], self.p_event);
            self.cal.schedule(t.saturating_add(gap), Event::NewPacket { flow: flow as u32 });
        }
        // A closed window stops rescheduling (and RNG draws): at most
        // one no-op event fires past `end` per flow, keeping
        // phase-sequenced runs cheap.
    }

    /// The source pushes at most one backlog flit into the first route
    /// port's VC buffer, credit permitting; polls again next cycle while
    /// backlog remains.
    fn on_source(&mut self, flow: usize, t: u64) {
        self.source_pending[flow] = false;
        let pid = match self.backlog[flow].front() {
            Some(&pid) => pid,
            None => return,
        };
        let vc = self.packets[pid as usize].vc as usize;
        let p0 = self.flows.route(flow)[0] as usize;
        let qi = p0 * self.vcs + vc;
        if self.credits[qi] > 0 {
            self.credits[qi] -= 1;
            self.queues[qi].push_back(Flit { packet: pid, hop: 0 });
            self.created_flits += 1;
            let depth = self.queues[qi].len() as u64;
            if let Some(tm) = self.telem.as_deref_mut() {
                tm.push_sample(qi, depth);
            }
            if let Some(r) = self.rec.as_deref_mut() {
                r.on_push(qi, depth);
            }
            self.packets[pid as usize].pushed += 1;
            if self.packets[pid as usize].pushed == self.packet_flits {
                self.backlog[flow].pop_front();
            }
            self.wake_service(p0, t + 1);
        }
        if !self.backlog[flow].is_empty() {
            self.wake_source(flow, t + 1);
        }
    }

    /// A flit lands in `port`'s VC buffer (its credit was reserved at
    /// transmit time).
    fn on_arrive(&mut self, port: usize, packet: u32, hop: u16, t: u64) {
        let vc = self.packets[packet as usize].vc as usize;
        let qi = port * self.vcs + vc;
        self.in_flight_flits -= 1;
        self.queues[qi].push_back(Flit { packet, hop });
        let depth = self.queues[qi].len() as u64;
        if let Some(tm) = self.telem.as_deref_mut() {
            tm.push_sample(qi, depth);
        }
        if let Some(r) = self.rec.as_deref_mut() {
            r.on_push(qi, depth);
        }
        self.wake_service(port, t + 1);
    }

    /// Port arbitration: transmit the head flit of the next serviceable
    /// VC (round-robin), if any.
    fn on_service(&mut self, port: usize, t: u64) {
        self.service_pending[port] = false;
        let vcs = self.vcs;
        let base = port * vcs;
        let mut chosen: Option<usize> = None;
        let mut saw_blocked = false;
        for i in 1..=vcs {
            let vc = (self.last_vc[port] + i) % vcs;
            let head = match self.queues[base + vc].front() {
                Some(&f) => f,
                None => continue,
            };
            let flow = self.packets[head.packet as usize].flow as usize;
            let route = self.flows.route(flow);
            let nh = head.hop as usize + 1;
            if nh < route.len() {
                let q = route[nh] as usize;
                if self.credits[q * vcs + vc] == 0 {
                    saw_blocked = true;
                    continue; // blocked on downstream credit
                }
            }
            chosen = Some(vc);
            break;
        }
        if let Some(vc) = chosen {
            self.last_vc[port] = vc;
            let flit = self.queues[base + vc].pop_front().expect("chosen VC has a head flit");
            self.credits[base + vc] += 1; // our slot frees as the flit leaves
            if let Some(tm) = self.telem.as_deref_mut() {
                tm.port_forwarded[port] += 1;
            }
            if let Some(r) = self.rec.as_deref_mut() {
                r.on_forwarded(port);
            }
            let flow = self.packets[flit.packet as usize].flow as usize;
            let route = self.flows.route(flow);
            let nh = flit.hop as usize + 1;
            if nh < route.len() {
                let q = route[nh] as usize;
                self.credits[q * vcs + vc] -= 1; // reserve downstream slot
                self.in_flight_flits += 1;
                self.cal.schedule(
                    t + self.link_latency,
                    Event::Arrive { port: q as u32, packet: flit.packet, hop: nh as u16 },
                );
            } else {
                self.deliver(flit.packet, t);
            }
        } else if saw_blocked {
            // Every head flit the port held was credit-blocked: one
            // wholly stalled service round.
            if let Some(tm) = self.telem.as_deref_mut() {
                tm.port_credit_stalls[port] += 1;
            }
            if let Some(r) = self.rec.as_deref_mut() {
                r.on_stall(port);
            }
        }
        // Poll again while any VC holds flits (transmitted or blocked).
        if (0..vcs).any(|v| !self.queues[base + v].is_empty()) {
            self.wake_service(port, t + 1);
        }
    }

    /// A flit reaches its destination node (infinite sink).
    fn deliver(&mut self, pid: u32, t: u64) {
        let in_window = t >= self.warmup && t < self.warmup + self.measure;
        let pkt = &mut self.packets[pid as usize];
        pkt.delivered += 1;
        let flow = pkt.flow as usize;
        let arrival = pkt.arrival;
        let done = pkt.delivered == self.packet_flits;
        self.delivered_flits += 1;
        if let Some(tm) = self.telem.as_deref_mut() {
            tm.flow_delivered_flits[flow] += 1;
        }
        if let Some(r) = self.rec.as_deref_mut() {
            r.on_delivered();
        }
        if in_window {
            self.accepted_flits += 1;
            // Per-flow throughput is measured inside the flow's own
            // injection window (clamped to the global one) — with the
            // default whole-run window this is exactly `in_window`;
            // phase-sequenced runs attribute each phase only the flits
            // delivered while its table was live, so a saturated
            // phase's draining backlog cannot inflate its figure.
            let (ws, we) = self.windows[flow];
            if t >= ws.max(self.warmup) && t < we.min(self.warmup + self.measure) {
                self.flow_flits[flow] += 1;
            }
        }
        if done {
            self.delivered_packets += 1;
            if arrival >= self.warmup && arrival < self.warmup + self.measure {
                self.latencies.push((flow as u32, t - arrival));
            }
        }
    }

    /// Summarize the run.
    fn finish(mut self) -> RunDetail {
        if let Some(r) = self.rec.take() {
            r.finish();
        }
        let active = self.flows.num_active();
        let offered_aggregate = self.rate * active as f64;
        let measure = self.measure as f64;
        let accepted = self.accepted_flits as f64 / measure;
        let flow_accepted: Vec<f64> =
            self.flow_flits.iter().map(|&f| f as f64 / measure).collect();
        let mut lat = self.latencies;
        lat.sort_unstable_by_key(|&(_, l)| l);
        let (mean_latency, p99_latency) = summarize_latencies(&lat);
        let report = NetsimReport {
            offered: self.rate,
            offered_aggregate,
            accepted,
            flow_accepted,
            mean_latency,
            p99_latency,
            injected_packets: self.injected_packets,
            delivered_packets: self.delivered_packets,
            measured_packets: lat.len() as u64,
            flows: active,
            events: self.cal.scheduled(),
            saturated: accepted < SATURATION_FRACTION * offered_aggregate,
        };
        // Flit conservation: every injected flit is delivered, on a
        // link (an Arrive scheduled — possibly past the horizon, where
        // the calendar drops it), parked in a VC buffer, or still in
        // the source backlog. The accepted/offered stats cannot see a
        // silently dropped flit; this equality can.
        let buffered: u64 = self.queues.iter().map(|q| q.len() as u64).sum();
        let backlogged: u64 = self
            .backlog
            .iter()
            .flat_map(|b| b.iter())
            .map(|&pid| (self.packet_flits - self.packets[pid as usize].pushed) as u64)
            .sum();
        let injected_flits = self.injected_packets * self.packet_flits as u64;
        debug_assert_eq!(
            injected_flits,
            self.delivered_flits + self.in_flight_flits + buffered + backlogged,
            "flit conservation: injected == delivered + in-flight + buffered + backlogged"
        );
        debug_assert_eq!(
            self.created_flits,
            injected_flits - backlogged,
            "created flits are exactly the injected minus the never-pushed backlog"
        );
        if let Some(tm) = self.telem {
            let mut reg = Registry::default();
            reg.add("netsim.cycles", self.warmup + self.measure + self.drain);
            reg.add("netsim.events", report.events);
            reg.add("netsim.packets.injected", self.injected_packets);
            reg.add("netsim.packets.delivered", self.delivered_packets);
            reg.add("netsim.packets.measured", report.measured_packets);
            reg.add("netsim.flits.injected", injected_flits);
            reg.add("netsim.flits.created", self.created_flits);
            reg.add("netsim.flits.delivered", self.delivered_flits);
            reg.add("netsim.flits.accepted", self.accepted_flits);
            reg.add("netsim.flits.in_flight_end", self.in_flight_flits);
            reg.add("netsim.flits.buffered_end", buffered);
            reg.add("netsim.flits.backlogged_end", backlogged);
            reg.vec_bulk("netsim.port.forwarded_flits", VecKind::Sum, &tm.port_forwarded);
            reg.vec_bulk("netsim.port.credit_stalls", VecKind::Sum, &tm.port_credit_stalls);
            reg.vec_bulk("netsim.vc.occupancy_hwm", VecKind::Max, &tm.vc_occupancy_hwm);
            reg.vec_bulk(
                "netsim.flow.injected_packets",
                VecKind::Sum,
                &tm.flow_injected_packets,
            );
            reg.vec_bulk("netsim.flow.delivered_flits", VecKind::Sum, &tm.flow_delivered_flits);
            reg.hist_bulk("netsim.queue_depth", &tm.queue_depth);
            tm.handle.merge_registry(&reg);
        }
        RunDetail { report, latencies: lat }
    }
}

/// `(mean, p99)` of latency-sorted `(flow, latency)` samples — the one
/// summary formula both the whole-run report and the per-phase stats
/// use, so they cannot drift apart.
pub(crate) fn summarize_latencies(sorted: &[(u32, u64)]) -> (f64, f64) {
    if sorted.is_empty() {
        return (0.0, 0.0);
    }
    debug_assert!(sorted.windows(2).all(|w| w[0].1 <= w[1].1), "samples must be sorted");
    let mean = sorted.iter().map(|&(_, l)| l).sum::<u64>() as f64 / sorted.len() as f64;
    let idx = ((sorted.len() - 1) as f64 * 0.99).round() as usize;
    (mean, sorted[idx.min(sorted.len() - 1)].1 as f64)
}
