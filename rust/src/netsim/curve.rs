//! Injection-rate sweeps: the latency-vs-offered-load curve and its
//! saturation point — the standard presentation of the interconnect
//! literature, and the `pgft netsim` CLI's output shape.

use super::{run_netsim_recorded, run_netsim_with, NetsimConfig, NetsimReport};
use crate::eval::FlowSet;
use crate::report::Table;
use crate::telemetry::{Recorder, RunInfo, Telemetry};
use crate::topology::Topology;
use anyhow::{ensure, Result};

/// One labelled point of a latency-vs-load curve.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    /// Algorithm label of the routed table this point simulated.
    pub algorithm: String,
    /// Pattern label.
    pub pattern: String,
    /// The simulation figures at this offered load.
    pub report: NetsimReport,
}

/// Run the whole injection-rate grid over one traced route store. The
/// offered loads must be ascending (the curve reads left to right);
/// every run re-seeds identically, so the curve is deterministic
/// point-wise.
pub fn load_curve(
    topo: &Topology,
    flows: &FlowSet,
    cfg: &NetsimConfig,
    rates: &[f64],
) -> Result<Vec<NetsimReport>> {
    load_curve_with(topo, flows, cfg, rates, &Telemetry::disabled())
}

/// [`load_curve`] with an instrumentation handle: every point of the
/// curve records into the same registry (the CLI scopes one handle per
/// `(algo, pattern)` so per-port counters aggregate over the rate grid
/// of one configuration only).
pub fn load_curve_with(
    topo: &Topology,
    flows: &FlowSet,
    cfg: &NetsimConfig,
    rates: &[f64],
    telem: &Telemetry,
) -> Result<Vec<NetsimReport>> {
    ensure!(!rates.is_empty(), "netsim: no injection rates to sweep");
    ensure!(
        rates.windows(2).all(|w| w[0] < w[1]),
        "netsim: injection rates must be strictly ascending: {rates:?}"
    );
    rates.iter().map(|&r| run_netsim_with(topo, flows, cfg, r, telem)).collect()
}

/// [`load_curve_with`] with a flight-recorder handle: every rate point
/// produces one [`crate::telemetry::Recording`] labelled `info` plus a
/// `rate` key, so a recorded curve is a family of per-rate window
/// series. Disabled handles make this exactly `load_curve_with`.
pub fn load_curve_recorded(
    topo: &Topology,
    flows: &FlowSet,
    cfg: &NetsimConfig,
    rates: &[f64],
    telem: &Telemetry,
    rec: &Recorder,
    info: &RunInfo,
) -> Result<Vec<NetsimReport>> {
    ensure!(!rates.is_empty(), "netsim: no injection rates to sweep");
    ensure!(
        rates.windows(2).all(|w| w[0] < w[1]),
        "netsim: injection rates must be strictly ascending: {rates:?}"
    );
    rates
        .iter()
        .map(|&r| {
            let mut point_info = info.clone();
            point_info.label.insert("rate".to_string(), r.to_string());
            run_netsim_recorded(topo, flows, cfg, r, telem, rec, point_info)
        })
        .collect()
}

/// The default injection-rate grid: 0.05 to 1.0 in 0.05 steps.
pub fn default_rates() -> Vec<f64> {
    (1..=20).map(|i| i as f64 / 20.0).collect()
}

/// Where a curve stops scaling (see [`saturation_point`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Saturation {
    /// Peak accepted throughput over the curve (aggregate flits/cycle)
    /// — "the saturation throughput".
    pub peak_accepted: f64,
    /// Smallest offered load (per flow) whose accepted throughput
    /// reaches 95% of the peak — the knee of the curve.
    pub knee_offered: f64,
    /// Smallest offered load flagged saturated
    /// (accepted < [`super::SATURATION_FRACTION`] × offered), if any.
    pub first_saturated: Option<f64>,
}

/// Read the saturation point off a curve produced by [`load_curve`].
pub fn saturation_point(curve: &[NetsimReport]) -> Option<Saturation> {
    if curve.is_empty() {
        return None;
    }
    let peak_accepted = curve.iter().map(|r| r.accepted).fold(0.0f64, f64::max);
    let knee_offered = curve
        .iter()
        .find(|r| r.accepted >= 0.95 * peak_accepted)
        .map(|r| r.offered)
        .unwrap_or(curve[curve.len() - 1].offered);
    let first_saturated = curve.iter().find(|r| r.saturated).map(|r| r.offered);
    Some(Saturation { peak_accepted, knee_offered, first_saturated })
}

/// Collect labelled curve points into a [`Table`] (text/CSV/JSON).
/// Floats use Rust's shortest-round-trip `Display`, so the CSV is both
/// lossless and byte-deterministic per seed.
pub fn curve_table(points: &[CurvePoint]) -> Table {
    let mut t = Table::new(
        "netsim: latency vs offered load (flit-level, VC/credit flow control)",
        &[
            "algo", "pattern", "offered", "agg_offered", "accepted", "mean_lat", "p99_lat",
            "delivered", "injected", "saturated",
        ],
    );
    for p in points {
        let r = &p.report;
        t.row(&[
            p.algorithm.clone(),
            p.pattern.clone(),
            r.offered.to_string(),
            r.offered_aggregate.to_string(),
            r.accepted.to_string(),
            r.mean_latency.to_string(),
            r.p99_latency.to_string(),
            r.delivered_packets.to_string(),
            r.injected_packets.to_string(),
            if r.saturated { "1".to_string() } else { "0".to_string() },
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::patterns::Pattern;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    fn setup(kind: AlgorithmKind) -> (Topology, FlowSet) {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
        let router = kind.build(&topo, Some(&types), 1);
        let set = FlowSet::trace(&topo, &*router, &flows);
        (topo, set)
    }

    fn cfg() -> NetsimConfig {
        NetsimConfig { warmup: 200, measure: 1600, drain: 200, ..Default::default() }
    }

    #[test]
    fn curve_is_monotone_in_offered_and_detects_saturation() {
        let (topo, routes) = setup(AlgorithmKind::Dmodk);
        // Dmodk's fair-rate floor on C2IO is 1/28 ≈ 0.036: the first
        // point sits below it, the other two far above.
        let rates = [0.02, 0.2, 0.8];
        let curve = load_curve(&topo, &routes, &cfg(), &rates).unwrap();
        assert_eq!(curve.len(), 3);
        // Accepted throughput grows toward the bottleneck cap, then stops.
        assert!(curve[1].accepted > curve[0].accepted);
        assert!(!curve[0].saturated, "{:?}", curve[0]);
        assert!(curve[1].saturated && curve[2].saturated, "{curve:?}");
        let sat = saturation_point(&curve).unwrap();
        assert!(sat.peak_accepted <= 2.2, "dmodk top-bundle cap: {sat:?}");
        assert_eq!(sat.first_saturated, Some(0.2));
        // Latency climbs sharply past the knee.
        assert!(curve[2].mean_latency > curve[0].mean_latency);
    }

    #[test]
    fn rates_must_ascend_and_be_nonempty() {
        let (topo, routes) = setup(AlgorithmKind::Dmodk);
        assert!(load_curve(&topo, &routes, &cfg(), &[]).is_err());
        assert!(load_curve(&topo, &routes, &cfg(), &[0.5, 0.2]).is_err());
        assert!(saturation_point(&[]).is_none());
    }

    #[test]
    fn table_renders_and_labels() {
        let (topo, routes) = setup(AlgorithmKind::Gdmodk);
        let curve = load_curve(&topo, &routes, &cfg(), &[0.1]).unwrap();
        let points: Vec<CurvePoint> = curve
            .into_iter()
            .map(|report| CurvePoint {
                algorithm: "gdmodk".into(),
                pattern: "c2io-sym".into(),
                report,
            })
            .collect();
        let t = curve_table(&points);
        let text = t.to_text();
        assert!(text.contains("gdmodk"), "{text}");
        assert!(text.contains("0.1"), "{text}");
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn default_rates_span_the_unit_interval() {
        let r = default_rates();
        assert_eq!(r.len(), 20);
        assert!((r[0] - 0.05).abs() < 1e-12);
        assert!((r[19] - 1.0).abs() < 1e-12);
        assert!(r.windows(2).all(|w| w[0] < w[1]));
    }
}
