//! Calendar-queue event core of the flit-level simulator.
//!
//! Events are scheduled at integer cycle times on a bounded horizon, so
//! the calendar degenerates gracefully: one bucket per cycle, drained in
//! time order. Within a bucket, events are processed in a **total,
//! scheduling-independent order** — sorted by `(class, key, seq)`:
//!
//!  * `class` — [`Service`](Event::Service) transmissions first, then
//!    [`NewPacket`](Event::NewPacket) arrivals, then
//!    [`Source`](Event::Source) injections, then
//!    [`Arrive`](Event::Arrive) deliveries into downstream buffers.
//!    Running every transmission of cycle `t` *before* any flit lands at
//!    `t` enforces the one-cycle minimum dwell per hop without per-flit
//!    timestamps.
//!  * `key` — the entity id (port or flow), so same-class events run in
//!    a fixed fabric order regardless of how they were scheduled.
//!  * `seq` — a monotone tie-breaker for the rare same-class same-key
//!    duplicates, making the order fully deterministic.
//!
//! The engine only ever schedules strictly into the future
//! (`t_event > now`), which the cursor assert pins: a same-cycle
//! schedule after the bucket drained would be silently lost otherwise.

/// One simulator event (see the module docs for the processing order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// Port arbitration: `port` tries to transmit one flit this cycle.
    Service {
        /// The transmitting output port.
        port: u32,
    },
    /// The injection process delivers new packet(s) into `flow`'s
    /// source backlog.
    NewPacket {
        /// The flow whose source receives the packet(s).
        flow: u32,
    },
    /// `flow`'s source tries to push one backlog flit into the buffer
    /// of the first port of its route.
    Source {
        /// The injecting flow.
        flow: u32,
    },
    /// A flit finishes traversing a link and lands in the VC buffer of
    /// `port` (the next output port on its route).
    Arrive {
        /// The receiving output port.
        port: u32,
        /// Index of the in-flight packet in the engine's packet arena.
        packet: u32,
        /// Hop index of `port` within the packet's route.
        hop: u16,
    },
}

impl Event {
    /// Processing class within a cycle (lower runs first).
    #[inline]
    fn class(&self) -> u8 {
        match self {
            Event::Service { .. } => 0,
            Event::NewPacket { .. } => 1,
            Event::Source { .. } => 2,
            Event::Arrive { .. } => 3,
        }
    }

    /// Entity id ordering same-class events of one cycle.
    #[inline]
    fn key(&self) -> u32 {
        match self {
            Event::Service { port } => *port,
            Event::NewPacket { flow } => *flow,
            Event::Source { flow } => *flow,
            Event::Arrive { port, .. } => *port,
        }
    }
}

/// Bounded-horizon calendar queue: `buckets[t]` holds cycle `t`'s events.
pub struct Calendar {
    buckets: Vec<Vec<(u64, Event)>>,
    seq: u64,
    cursor: u64,
}

impl Calendar {
    /// A calendar covering cycles `0..=horizon`. Events scheduled past
    /// the horizon are dropped (the run is over before they would fire).
    pub fn new(horizon: u64) -> Calendar {
        Calendar {
            buckets: vec![Vec::new(); horizon as usize + 1],
            seq: 0,
            cursor: 0,
        }
    }

    /// Schedule `ev` at cycle `t`. Must be strictly after the bucket
    /// currently being drained (the engine never schedules same-cycle).
    pub fn schedule(&mut self, t: u64, ev: Event) {
        debug_assert!(t > self.cursor, "same-or-past-cycle schedule at t={t}");
        if let Some(bucket) = self.buckets.get_mut(t as usize) {
            self.seq += 1;
            bucket.push((self.seq, ev));
        }
    }

    /// Drain cycle `t`'s bucket in the canonical `(class, key, seq)`
    /// order.
    pub fn take(&mut self, t: u64) -> Vec<(u64, Event)> {
        self.cursor = t;
        let mut evs = std::mem::take(&mut self.buckets[t as usize]);
        evs.sort_unstable_by_key(|&(seq, ev)| (ev.class(), ev.key(), seq));
        evs
    }

    /// Total number of events ever scheduled (for reporting/debugging).
    pub fn scheduled(&self) -> u64 {
        self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_orders_by_class_then_key_then_seq() {
        let mut cal = Calendar::new(10);
        cal.schedule(5, Event::Arrive { port: 1, packet: 0, hop: 2 });
        cal.schedule(5, Event::Service { port: 9 });
        cal.schedule(5, Event::Source { flow: 0 });
        cal.schedule(5, Event::Service { port: 2 });
        cal.schedule(5, Event::NewPacket { flow: 4 });
        cal.schedule(5, Event::Arrive { port: 1, packet: 7, hop: 3 });
        let evs: Vec<Event> = cal.take(5).into_iter().map(|(_, e)| e).collect();
        assert_eq!(
            evs,
            vec![
                Event::Service { port: 2 },
                Event::Service { port: 9 },
                Event::NewPacket { flow: 4 },
                Event::Source { flow: 0 },
                Event::Arrive { port: 1, packet: 0, hop: 2 },
                Event::Arrive { port: 1, packet: 7, hop: 3 },
            ]
        );
        assert_eq!(cal.scheduled(), 6);
    }

    #[test]
    fn past_horizon_schedules_are_dropped() {
        let mut cal = Calendar::new(3);
        cal.schedule(3, Event::Service { port: 0 });
        cal.schedule(4, Event::Service { port: 1 }); // dropped
        assert_eq!(cal.take(3).len(), 1);
        assert_eq!(cal.take(2).len(), 0);
    }

    #[test]
    fn buckets_drain_once() {
        let mut cal = Calendar::new(4);
        cal.schedule(2, Event::Source { flow: 3 });
        assert_eq!(cal.take(2).len(), 1);
        assert_eq!(cal.take(2).len(), 0, "a drained bucket stays empty");
    }
}
