//! Seeded injection processes: how packets arrive at the sources.
//!
//! Offered load is expressed per flow in **flits per cycle** on the same
//! unit scale as the fair-rate solver (a link moves one flit per cycle,
//! i.e. has capacity 1.0), so a netsim sweep point at offered load `r`
//! is directly comparable to a [`crate::sim::fairrate`] rate `r`.
//!
//! * [`Injection::Bernoulli`] — every cycle each flow independently
//!   starts a new packet with probability `r / packet_flits`, the
//!   memoryless open-loop process of the latency-vs-load literature.
//!   Inter-arrival gaps are drawn in closed form (geometric), so idle
//!   sources cost no events.
//! * [`Injection::Burst`] — same mean load, but packets arrive in
//!   back-to-back groups of `length` (probability divided accordingly),
//!   stressing buffer depth at equal offered load.

use crate::util::rng::Xoshiro256;

/// The packet-arrival process of every source (see module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Injection {
    /// Memoryless per-cycle packet arrivals.
    Bernoulli,
    /// Bursty arrivals: groups of `length` back-to-back packets.
    Burst {
        /// Packets per burst (≥ 1; `1` degenerates to Bernoulli).
        length: u32,
    },
}

impl Injection {
    /// Packets created per arrival event.
    pub fn burst_len(&self) -> u32 {
        match self {
            Injection::Bernoulli => 1,
            Injection::Burst { length } => (*length).max(1),
        }
    }

    /// Per-cycle arrival-event probability for offered load `rate`
    /// (flits/cycle/flow) and `packet_flits` flits per packet.
    pub fn event_probability(&self, rate: f64, packet_flits: u32) -> f64 {
        rate / (packet_flits as f64 * self.burst_len() as f64)
    }

    /// Parse `bernoulli` or `burst:K`.
    pub fn parse(s: &str) -> anyhow::Result<Injection> {
        if s == "bernoulli" {
            return Ok(Injection::Bernoulli);
        }
        if let Some(k) = s.strip_prefix("burst:") {
            let length: u32 = k
                .parse()
                .map_err(|e| anyhow::anyhow!("injection {s:?}: {e}"))?;
            anyhow::ensure!(length >= 1, "injection {s:?}: burst length must be >= 1");
            return Ok(Injection::Burst { length });
        }
        anyhow::bail!("unknown injection process {s:?} (bernoulli|burst:K)")
    }

    /// Canonical spec string (inverse of [`Injection::parse`]).
    pub fn name(&self) -> String {
        match self {
            Injection::Bernoulli => "bernoulli".into(),
            Injection::Burst { length } => format!("burst:{length}"),
        }
    }
}

/// Next inter-arrival gap (in cycles, ≥ 1) of a Bernoulli(`p`) process,
/// drawn in closed form: `1 + Geometric(p)` failures-before-success.
/// `p ≥ 1` degenerates to back-to-back arrivals.
pub fn draw_gap(rng: &mut Xoshiro256, p: f64) -> u64 {
    if p >= 1.0 {
        return 1;
    }
    debug_assert!(p > 0.0, "draw_gap needs p in (0, 1]");
    let u = rng.next_f64(); // in [0, 1)
    // (1 - u) in (0, 1]: ln ≤ 0. The denominator is ln(1 - p) computed
    // as ln_1p(-p) so it stays strictly negative even when p is tiny
    // enough that `1.0 - p == 1.0` (a plain ln would return -0.0 there
    // and collapse every gap to 1, inverting a near-zero offered load
    // into full overload). The ratio is ≥ 0 and saturates to u64::MAX
    // on the (astronomically rare) u → 1 tail, which simply lands past
    // the horizon.
    let g = ((1.0 - u).ln() / (-p).ln_1p()).floor();
    1 + g as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["bernoulli", "burst:4"] {
            let i = Injection::parse(s).unwrap();
            assert_eq!(i.name(), s);
        }
        assert!(Injection::parse("poisson").is_err());
        assert!(Injection::parse("burst:0").is_err());
        assert_eq!(Injection::Burst { length: 4 }.burst_len(), 4);
    }

    #[test]
    fn event_probability_scales_with_packet_and_burst() {
        let b = Injection::Bernoulli;
        assert!((b.event_probability(0.4, 4) - 0.1).abs() < 1e-12);
        let burst = Injection::Burst { length: 2 };
        assert!((burst.event_probability(0.4, 4) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn gaps_have_the_right_mean() {
        let mut rng = Xoshiro256::new(7);
        let p = 0.125f64;
        let n = 20_000u64;
        let total: u64 = (0..n).map(|_| draw_gap(&mut rng, p)).sum();
        let mean = total as f64 / n as f64;
        // Geometric mean gap = 1/p = 8; allow 5% sampling slack.
        assert!((mean - 8.0).abs() < 0.4, "mean gap {mean}");
    }

    #[test]
    fn gap_is_always_at_least_one() {
        let mut rng = Xoshiro256::new(3);
        for _ in 0..1000 {
            assert!(draw_gap(&mut rng, 0.9) >= 1);
        }
        assert_eq!(draw_gap(&mut rng, 1.0), 1);
    }

    #[test]
    fn tiny_probabilities_yield_huge_gaps_not_back_to_back() {
        // Regression: with p below f64's 1-ulp (~1.1e-16), a plain
        // `(1.0 - p).ln()` is -0.0 and every gap collapses to 1 —
        // ln_1p keeps the mean at ~1/p instead.
        let mut rng = Xoshiro256::new(5);
        for _ in 0..50 {
            assert!(draw_gap(&mut rng, 1e-18) > 1_000, "gap must be astronomically long");
        }
    }

    #[test]
    fn gaps_are_deterministic_per_seed() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(draw_gap(&mut a, 0.3), draw_gap(&mut b, 0.3));
        }
    }
}
