//! Online fabric-manager service, in the style of the BXI routing
//! architecture (Vigneras & Quintin [8]): a single leader thread owns
//! the fabric state and repairs it; readers are fully decoupled through
//! versioned immutable snapshots.
//!
//! Three design rules shape the service:
//!
//!  * **Single writer, batched events.** Link up/down events arrive on
//!    an mpsc channel (the offline vendor set has no tokio; a fabric
//!    manager arguably prefers a plain thread anyway — strictly ordered
//!    events, no executor). The leader drains whatever has accumulated
//!    and coalesces consecutive event commands into **one** repair and
//!    one table push: a 10-link burst costs one retrace, one diff, one
//!    version bump. [`Coordinator::inject_burst`] submits an atomic
//!    batch; [`crate::faults::FaultScenario::as_events`] /
//!    [`drill_events`](crate::faults::FaultScenario::drill_events)
//!    turn seeded cascade scenarios into replayable event streams.
//!  * **Incremental repair.** The route store is an
//!    [`crate::eval::FlowSet`] over all node pairs, repaired with
//!    [`retrace_incremental`](crate::eval::FlowSet::retrace_incremental)
//!    + [`crate::faults::DegradedRouter`] — only flows crossing a dead
//!    link are re-traced; there is no full re-trace on the fault path
//!    (see `leader.rs` for the monotonicity argument and the
//!    pristine-store fallback on revives).
//!  * **Lock-free reads.** Every repair publishes one immutable
//!    [`FabricSnapshot`] (tables + route store + stats) into a
//!    [`SnapshotCell`]; `analyze`/`trace`/`stats` load the current
//!    `Arc` and never touch the leader. A slow analysis cannot delay a
//!    repair, and a repair can never tear a query. Writes are
//!    asynchronous — [`Coordinator::sync`] barriers on the leader
//!    having processed everything submitted before it.
//!
//! `pgft fabric` drives a seeded event schedule through the service and
//! reports per-event reroute latency, diff sizes, and read throughput;
//! `benches/bench_fabric.rs` records the same under a million-query
//! concurrent load.

mod leader;
mod snapshot;

pub use snapshot::{FabricSnapshot, FabricStats, SnapshotCell};

use crate::faults::LinkEvent;
use crate::metrics::AlgoSummary;
use crate::nodes::NodeTypeMap;
use crate::patterns::Pattern;
use crate::routing::trace::RoutePorts;
use crate::routing::AlgorithmKind;
use crate::telemetry::Telemetry;
use crate::topology::{LinkId, Nid, Topology};
use anyhow::{anyhow, Result};
use leader::Leader;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

enum Command {
    /// A batch of link transitions, applied as one repair.
    Events(Vec<LinkEvent>),
    SetAlgorithm(AlgorithmKind),
    /// Barrier: replied to once every earlier command is processed.
    Sync(Sender<()>),
    Shutdown,
}

/// Handle to a running coordinator: commands go to the leader thread,
/// queries are served from the latest published snapshot.
pub struct Coordinator {
    tx: Sender<Command>,
    cell: Arc<SnapshotCell>,
    join: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Compute the initial tables and route store, publish snapshot
    /// version 1, and spawn the leader thread.
    pub fn start(
        topo: Arc<Topology>,
        types: NodeTypeMap,
        kind: AlgorithmKind,
        seed: u64,
    ) -> Result<Coordinator> {
        Coordinator::start_instrumented(topo, types, kind, seed, Telemetry::disabled())
    }

    /// [`Coordinator::start`] with an instrumentation handle: the
    /// leader routes repairs through the telemetry-aware retrace, so
    /// `eval.retrace.*` and `eval.reach.*` counters (dirty-flow counts,
    /// reach-arena residency peaks) accumulate in the handle's registry
    /// across the service's lifetime. The handle is cloned into the
    /// leader thread; snapshot it any time — it is lock-protected and
    /// merge rules are commutative. Disabled handles make this exactly
    /// [`Coordinator::start`].
    pub fn start_instrumented(
        topo: Arc<Topology>,
        types: NodeTypeMap,
        kind: AlgorithmKind,
        seed: u64,
        telem: Telemetry,
    ) -> Result<Coordinator> {
        let (mut leader, cell) = Leader::new(topo, Arc::new(types), kind, seed, telem)?;
        let (tx, rx) = channel::<Command>();
        let join = std::thread::Builder::new()
            .name("pgft-fabric-leader".into())
            .spawn(move || {
                'service: while let Ok(first) = rx.recv() {
                    // Drain everything that accumulated while we were
                    // busy, then coalesce runs of event commands so a
                    // burst becomes one repair + one table push.
                    let mut queue = VecDeque::new();
                    queue.push_back(first);
                    while let Ok(cmd) = rx.try_recv() {
                        queue.push_back(cmd);
                    }
                    while let Some(cmd) = queue.pop_front() {
                        match cmd {
                            Command::Events(mut batch) => {
                                while matches!(queue.front(), Some(Command::Events(_))) {
                                    if let Some(Command::Events(more)) = queue.pop_front() {
                                        batch.extend(more);
                                    }
                                }
                                leader.apply_batch(&batch);
                            }
                            Command::SetAlgorithm(k) => leader.set_algorithm(k),
                            Command::Sync(reply) => {
                                let _ = reply.send(());
                            }
                            Command::Shutdown => break 'service,
                        }
                    }
                }
            })?;
        Ok(Coordinator { tx, cell, join: Some(join) })
    }

    /// Report a link failure (one-event batch).
    pub fn link_down(&self, l: LinkId) {
        let _ = self.tx.send(Command::Events(vec![LinkEvent::Down(l)]));
    }

    /// Report a link recovery (one-event batch).
    pub fn link_up(&self, l: LinkId) {
        let _ = self.tx.send(Command::Events(vec![LinkEvent::Up(l)]));
    }

    /// Submit a burst of link events as one atomic batch: exactly one
    /// repair and one table push, however many events it carries.
    /// (Singles submitted back-to-back coalesce opportunistically too —
    /// whatever piles up while the leader is busy becomes one batch —
    /// but only a burst is *guaranteed* to.)
    pub fn inject_burst(&self, events: Vec<LinkEvent>) {
        let _ = self.tx.send(Command::Events(events));
    }

    /// Switch the routing algorithm live (full rebuild, then repair if
    /// faults are active).
    pub fn set_algorithm(&self, k: AlgorithmKind) {
        let _ = self.tx.send(Command::SetAlgorithm(k));
    }

    /// Barrier: returns once the leader has processed every command
    /// submitted before this call (so the snapshot reflects them).
    pub fn sync(&self) -> Result<()> {
        let (tx, rx) = channel();
        self.tx.send(Command::Sync(tx)).map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator stopped"))
    }

    /// The latest published fabric snapshot — an immutable, internally
    /// consistent view served without contacting the leader. Hold it
    /// as long as you like; repairs publish new snapshots alongside.
    pub fn snapshot(&self) -> Arc<FabricSnapshot> {
        self.cell.load()
    }

    /// A shareable handle to the publication point: reader threads load
    /// the latest snapshot straight from the cell, with no reference to
    /// (or synchronization with) the coordinator handle itself.
    pub fn snapshots(&self) -> Arc<SnapshotCell> {
        self.cell.clone()
    }

    /// Monitoring counters from the latest snapshot (lock-free).
    pub fn stats(&self) -> FabricStats {
        self.snapshot().stats.clone()
    }

    /// Run the §III congestion analysis against the latest snapshot
    /// (lock-free; never blocks on the leader).
    pub fn analyze(&self, pattern: Pattern) -> Result<AlgoSummary> {
        self.snapshot().analyze(pattern)
    }

    /// Trace flows against the latest snapshot's route store.
    pub fn trace(&self, flows: &[(Nid, Nid)]) -> Vec<RoutePorts> {
        self.snapshot().trace(flows)
    }

    /// Stop the leader thread and join it.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::topology::{build_pgft, PgftSpec};

    fn start(kind: AlgorithmKind) -> (Arc<Topology>, Coordinator) {
        let topo = Arc::new(build_pgft(&PgftSpec::case_study()));
        let types = Placement::paper_io().apply(&topo).unwrap();
        let c = Coordinator::start(topo.clone(), types, kind, 1).unwrap();
        (topo, c)
    }

    #[test]
    fn startup_and_stats() {
        let (_t, c) = start(AlgorithmKind::Gdmodk);
        let s = c.stats();
        assert_eq!(s.algorithm, AlgorithmKind::Gdmodk);
        assert_eq!(s.table_version, 1);
        assert_eq!(s.rebuilds, 1);
        assert_eq!(s.reroutes, 0, "startup is a rebuild, not a reroute");
        assert_eq!(s.dead_links, 0);
        assert!(s.table_entries > 0);
        let snap = c.snapshot();
        assert_eq!(snap.table_version, 1);
        assert_eq!(snap.tables.version, 1);
        c.shutdown();
    }

    #[test]
    fn analyze_matches_direct_metric() {
        let (_t, c) = start(AlgorithmKind::Dmodk);
        let s = c.analyze(Pattern::C2ioSym).unwrap();
        assert_eq!(s.c_topo, 4, "§III.B through the coordinator");
        c.shutdown();
    }

    #[test]
    fn link_failure_triggers_degraded_reroute() {
        let (topo, c) = start(AlgorithmKind::Gdmodk);
        let victim = topo.links.iter().find(|l| l.stage == 3).unwrap().id;
        c.link_down(victim);
        c.sync().unwrap();
        let s = c.stats();
        assert!(s.degraded);
        assert_eq!(s.dead_links, 1);
        assert_eq!(s.table_version, 2);
        assert_eq!(s.reroutes, 1);
        assert_eq!(s.rebuilds, 1);
        assert!(s.last_diff_entries > 0, "incremental diff recorded");
        assert!(s.last_routes_changed > 0);
        // Routes avoid the dead link.
        let routes = c.trace(&[(0, 63), (63, 0), (8, 47)]);
        for r in &routes {
            for &p in &r.ports {
                assert_ne!(topo.ports[p].link, victim);
            }
        }
        // Revive: back to healthy routing.
        c.link_up(victim);
        c.sync().unwrap();
        let s = c.stats();
        assert!(!s.degraded);
        assert_eq!(s.table_version, 3);
        c.shutdown();
    }

    #[test]
    fn algorithm_switch_changes_analysis() {
        let (_t, c) = start(AlgorithmKind::Dmodk);
        assert_eq!(c.analyze(Pattern::C2ioSym).unwrap().c_topo, 4);
        c.set_algorithm(AlgorithmKind::Gdmodk);
        c.sync().unwrap();
        assert_eq!(c.analyze(Pattern::C2ioSym).unwrap().c_topo, 1);
        let s = c.stats();
        assert_eq!(s.algorithm, AlgorithmKind::Gdmodk);
        assert_eq!(s.rebuilds, 2, "algorithm switch is a rebuild");
        assert_eq!(s.reroutes, 0);
        c.shutdown();
    }

    #[test]
    fn source_based_algorithms_also_run() {
        let (_t, c) = start(AlgorithmKind::Gsmodk);
        let s = c.analyze(Pattern::C2ioSym).unwrap();
        assert_eq!(s.c_topo, 4, "§IV.B.2");
        c.shutdown();
    }

    #[test]
    fn old_snapshots_stay_valid_after_repairs() {
        let (topo, c) = start(AlgorithmKind::Dmodk);
        let before = c.snapshot();
        let victim = topo.links.iter().find(|l| l.stage == 2).unwrap().id;
        c.link_down(victim);
        c.sync().unwrap();
        let after = c.snapshot();
        assert_eq!(before.table_version, 1);
        assert_eq!(after.table_version, 2);
        // The old snapshot still answers, unchanged, from its own state.
        assert_eq!(before.analyze(Pattern::C2ioSym).unwrap().c_topo, 4);
        assert!(!before.stats.degraded && after.stats.degraded);
        c.shutdown();
    }

    #[test]
    fn instrumented_repairs_surface_reach_and_window_stats() {
        let topo = Arc::new(build_pgft(&PgftSpec::case_study()));
        let types = Placement::paper_io().apply(&topo).unwrap();
        let telem = Telemetry::enabled();
        let c = Coordinator::start_instrumented(
            topo.clone(),
            types,
            AlgorithmKind::Gdmodk,
            1,
            telem.clone(),
        )
        .unwrap();
        let s = c.stats();
        assert!(s.reroute_micros_window.is_empty(), "startup is not journalled");
        assert_eq!((s.journal_shed, s.reach_peak_bytes), (0, 0));
        let victim = topo.links.iter().find(|l| l.stage == 3).unwrap().id;
        c.link_down(victim);
        c.sync().unwrap();
        let s = c.stats();
        assert!(s.reach_peak_bytes > 0, "lazy reach arena accounted: {s:?}");
        assert_eq!(s.reroute_micros_window.len(), 1);
        assert_eq!(s.reroute_micros_window[0], s.last_reroute_micros);
        let reg = telem.snapshot();
        assert!(reg.counter("eval.retrace.calls") >= 1, "repair went through telem retrace");
        assert!(reg.counter("eval.reach.computed") > 0, "reach misses harvested");
        assert!(
            reg.maxima().get("eval.reach.peak_bytes").copied().unwrap_or(0) > 0,
            "reach peak exported"
        );
        // Revive: the restore is journalled (window grows) but builds no
        // reach structure (peak resets).
        c.link_up(victim);
        c.sync().unwrap();
        let s = c.stats();
        assert_eq!(s.reroute_micros_window.len(), 2);
        assert_eq!(s.reach_peak_bytes, 0, "restore builds no reach structure");
        c.shutdown();
    }

    #[test]
    fn duplicate_events_are_absorbed() {
        let (topo, c) = start(AlgorithmKind::Dmodk);
        let victim = topo.links.iter().find(|l| l.stage == 3).unwrap().id;
        c.link_down(victim);
        c.sync().unwrap();
        let v = c.stats().table_version;
        c.link_down(victim); // already dead: net no-op, no publish
        c.sync().unwrap();
        assert_eq!(c.stats().table_version, v);
        assert_eq!(c.stats().reroutes, 1);
        c.shutdown();
    }
}
