//! Fabric-manager coordinator, in the style of the BXI routing
//! architecture (Vigneras & Quintin [8]): a leader thread owns the
//! fabric state — topology, node types, routing algorithm, fault set,
//! versioned forwarding tables — and processes events (link up/down,
//! algorithm change, analysis queries) from a command channel. Route
//! recomputation after faults uses the procedural degraded router seeded
//! with the Gxmodk type re-index, and the coordinator reports incremental
//! table-diff sizes (what would be pushed to switches) and reroute
//! latency.
//!
//! The offline vendor set has no tokio; the event loop is a plain thread
//! over `std::sync::mpsc`, which a fabric manager would arguably prefer
//! anyway (single writer, strictly ordered events).

use crate::metrics::AlgoSummary;
use crate::nodes::{NodeTypeMap, TypeReindex};
use crate::patterns::Pattern;
use crate::routing::degraded::{route_degraded, FaultSet};
use crate::routing::table::ForwardingTables;
use crate::routing::trace::{trace_flows, RoutePorts};
use crate::routing::AlgorithmKind;
use crate::topology::{LinkId, Nid, Topology};
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Snapshot of coordinator state for monitoring.
#[derive(Clone, Debug)]
pub struct FabricStats {
    /// Active routing algorithm.
    pub algorithm: AlgorithmKind,
    /// Current forwarding-table generation.
    pub table_version: u64,
    /// Total reroutes performed since startup.
    pub reroutes: u64,
    /// Currently dead links.
    pub dead_links: usize,
    /// Total (switch, destination) table entries.
    pub table_entries: usize,
    /// Wall-clock cost of the last reroute.
    pub last_reroute_micros: u64,
    /// Entries the last reroute changed (incremental push size).
    pub last_diff_entries: usize,
    /// Whether the fabric is running on degraded (fault-avoiding) tables.
    pub degraded: bool,
}

enum Command {
    LinkDown(LinkId),
    LinkUp(LinkId),
    SetAlgorithm(AlgorithmKind),
    Analyze { pattern: Pattern, reply: Sender<Result<AlgoSummary>> },
    TraceFlows { flows: Vec<(Nid, Nid)>, reply: Sender<Vec<RoutePorts>> },
    Stats(Sender<FabricStats>),
    Shutdown,
}

/// Handle to a running coordinator thread.
pub struct Coordinator {
    tx: Sender<Command>,
    join: Option<JoinHandle<()>>,
}

struct State {
    topo: Arc<Topology>,
    types: NodeTypeMap,
    reindex: TypeReindex,
    kind: AlgorithmKind,
    seed: u64,
    faults: FaultSet,
    /// Current tables: router-derived when healthy & dest-based,
    /// degraded-procedural otherwise.
    tables: Option<ForwardingTables>,
    version: u64,
    reroutes: u64,
    last_reroute_micros: u64,
    last_diff_entries: usize,
}

impl State {
    fn rebuild_tables(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let new = if self.faults.num_dead() == 0 {
            let router = self.kind.build(&self.topo, Some(&self.types), self.seed);
            if router.dest_based() {
                ForwardingTables::build(&self.topo, &*router)?
            } else {
                // Source-based healthy fabric: per-ingress tables are
                // implicit in the router; the distributable dest-based
                // form falls back to the procedural balancer with the
                // same re-index.
                route_degraded(&self.topo, &self.faults, self.grouped_reindex())?
            }
        } else {
            route_degraded(&self.topo, &self.faults, self.grouped_reindex())?
        };
        let diff = match &self.tables {
            Some(old) => old.diff_entries(&new),
            None => new.num_entries(),
        };
        self.last_diff_entries = diff;
        self.last_reroute_micros = t0.elapsed().as_micros() as u64;
        self.version += 1;
        self.reroutes += 1;
        let mut new = new;
        new.version = self.version;
        self.tables = Some(new);
        Ok(())
    }

    fn grouped_reindex(&self) -> Option<&TypeReindex> {
        if self.kind.is_grouped() {
            Some(&self.reindex)
        } else {
            None
        }
    }

    /// Trace flows with the *current* state: healthy fabric uses the
    /// algorithm's router directly; degraded fabric walks the tables.
    fn trace(&self, flows: &[(Nid, Nid)]) -> Vec<RoutePorts> {
        if self.faults.num_dead() == 0 {
            let router = self.kind.build(&self.topo, Some(&self.types), self.seed);
            trace_flows(&self.topo, &*router, flows)
        } else {
            let t = self.tables.as_ref().expect("tables exist after rebuild");
            flows.iter().map(|&(s, d)| t.trace(&self.topo, s, d)).collect()
        }
    }
}

impl Coordinator {
    /// Spawn the leader thread, compute initial tables, and return the
    /// command handle.
    pub fn start(
        topo: Arc<Topology>,
        types: NodeTypeMap,
        kind: AlgorithmKind,
        seed: u64,
    ) -> Result<Coordinator> {
        let reindex = TypeReindex::new(&types);
        let faults = FaultSet::none(&topo);
        let mut state = State {
            topo,
            types,
            reindex,
            kind,
            seed,
            faults,
            tables: None,
            version: 0,
            reroutes: 0,
            last_reroute_micros: 0,
            last_diff_entries: 0,
        };
        state.rebuild_tables()?;
        let (tx, rx) = channel::<Command>();
        let join = std::thread::Builder::new()
            .name("pgft-fabric-leader".into())
            .spawn(move || {
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Command::LinkDown(l) => {
                            state.faults.kill(l);
                            if let Err(e) = state.rebuild_tables() {
                                eprintln!("reroute after link {l} down failed: {e:#}");
                            }
                        }
                        Command::LinkUp(l) => {
                            state.faults.revive(l);
                            if let Err(e) = state.rebuild_tables() {
                                eprintln!("reroute after link {l} up failed: {e:#}");
                            }
                        }
                        Command::SetAlgorithm(k) => {
                            state.kind = k;
                            if let Err(e) = state.rebuild_tables() {
                                eprintln!("algorithm switch failed: {e:#}");
                            }
                        }
                        Command::Analyze { pattern, reply } => {
                            let res = (|| {
                                let flows = pattern.flows(&state.topo, &state.types)?;
                                let routes = state.trace(&flows);
                                let rep =
                                    crate::metrics::CongestionReport::compute(&state.topo, &routes);
                                Ok(AlgoSummary::from_report(
                                    &state.topo,
                                    &rep,
                                    state.kind.as_str(),
                                    &pattern.name(),
                                    flows.len(),
                                ))
                            })();
                            let _ = reply.send(res);
                        }
                        Command::TraceFlows { flows, reply } => {
                            let _ = reply.send(state.trace(&flows));
                        }
                        Command::Stats(reply) => {
                            let _ = reply.send(FabricStats {
                                algorithm: state.kind,
                                table_version: state.version,
                                reroutes: state.reroutes,
                                dead_links: state.faults.num_dead(),
                                table_entries: state
                                    .tables
                                    .as_ref()
                                    .map(|t| t.num_entries())
                                    .unwrap_or(0),
                                last_reroute_micros: state.last_reroute_micros,
                                last_diff_entries: state.last_diff_entries,
                                degraded: state.faults.num_dead() > 0,
                            });
                        }
                        Command::Shutdown => break,
                    }
                }
            })?;
        Ok(Coordinator { tx, join: Some(join) })
    }

    /// Report a link failure; the leader reroutes incrementally.
    pub fn link_down(&self, l: LinkId) {
        let _ = self.tx.send(Command::LinkDown(l));
    }

    /// Report a link recovery; the leader reroutes incrementally.
    pub fn link_up(&self, l: LinkId) {
        let _ = self.tx.send(Command::LinkUp(l));
    }

    /// Switch the routing algorithm live (tables are rebuilt).
    pub fn set_algorithm(&self, k: AlgorithmKind) {
        let _ = self.tx.send(Command::SetAlgorithm(k));
    }

    /// Fetch a monitoring snapshot from the leader.
    pub fn stats(&self) -> Result<FabricStats> {
        let (tx, rx) = channel();
        self.tx.send(Command::Stats(tx)).map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator stopped"))
    }

    /// Run the §III congestion analysis on the *current* fabric state
    /// (healthy router or degraded tables).
    pub fn analyze(&self, pattern: Pattern) -> Result<AlgoSummary> {
        let (tx, rx) = channel();
        self.tx
            .send(Command::Analyze { pattern, reply: tx })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator stopped"))?
    }

    /// Trace flows through the current fabric state.
    pub fn trace(&self, flows: Vec<(Nid, Nid)>) -> Result<Vec<RoutePorts>> {
        let (tx, rx) = channel();
        self.tx
            .send(Command::TraceFlows { flows, reply: tx })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        rx.recv().map_err(|_| anyhow!("coordinator stopped"))
    }

    /// Stop the leader thread and join it.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::topology::{build_pgft, PgftSpec};

    fn start(kind: AlgorithmKind) -> (Arc<Topology>, Coordinator) {
        let topo = Arc::new(build_pgft(&PgftSpec::case_study()));
        let types = Placement::paper_io().apply(&topo).unwrap();
        let c = Coordinator::start(topo.clone(), types, kind, 1).unwrap();
        (topo, c)
    }

    #[test]
    fn startup_and_stats() {
        let (_t, c) = start(AlgorithmKind::Gdmodk);
        let s = c.stats().unwrap();
        assert_eq!(s.algorithm, AlgorithmKind::Gdmodk);
        assert_eq!(s.table_version, 1);
        assert_eq!(s.dead_links, 0);
        assert!(s.table_entries > 0);
        c.shutdown();
    }

    #[test]
    fn analyze_matches_direct_metric() {
        let (_t, c) = start(AlgorithmKind::Dmodk);
        let s = c.analyze(Pattern::C2ioSym).unwrap();
        assert_eq!(s.c_topo, 4, "§III.B through the coordinator");
        c.shutdown();
    }

    #[test]
    fn link_failure_triggers_degraded_reroute() {
        let (topo, c) = start(AlgorithmKind::Gdmodk);
        let victim = topo.links.iter().find(|l| l.stage == 3).unwrap().id;
        c.link_down(victim);
        let s = c.stats().unwrap();
        assert!(s.degraded);
        assert_eq!(s.dead_links, 1);
        assert_eq!(s.table_version, 2);
        assert!(s.last_diff_entries > 0, "incremental diff recorded");
        // Routes avoid the dead link.
        let routes = c.trace(vec![(0, 63), (63, 0), (8, 47)]).unwrap();
        for r in &routes {
            for &p in &r.ports {
                assert_ne!(topo.ports[p].link, victim);
            }
        }
        // Revive: back to healthy routing.
        c.link_up(victim);
        let s = c.stats().unwrap();
        assert!(!s.degraded);
        assert_eq!(s.table_version, 3);
        c.shutdown();
    }

    #[test]
    fn algorithm_switch_changes_analysis() {
        let (_t, c) = start(AlgorithmKind::Dmodk);
        assert_eq!(c.analyze(Pattern::C2ioSym).unwrap().c_topo, 4);
        c.set_algorithm(AlgorithmKind::Gdmodk);
        assert_eq!(c.analyze(Pattern::C2ioSym).unwrap().c_topo, 1);
        let s = c.stats().unwrap();
        assert_eq!(s.algorithm, AlgorithmKind::Gdmodk);
        c.shutdown();
    }

    #[test]
    fn source_based_algorithms_also_run() {
        let (_t, c) = start(AlgorithmKind::Gsmodk);
        let s = c.analyze(Pattern::C2ioSym).unwrap();
        assert_eq!(s.c_topo, 4, "§IV.B.2");
        c.shutdown();
    }
}
