//! Versioned, immutable fabric snapshots and the cell that publishes
//! them.
//!
//! The leader thread ([`crate::coordinator::Coordinator`]) never mutates
//! published state: every repair builds a fresh [`FabricSnapshot`]
//! (tables + route store + stats, all internally consistent) and swaps
//! it into the [`SnapshotCell`] with one pointer store. Readers clone
//! the current `Arc` and then work entirely on their private snapshot —
//! queries never observe a half-repaired fabric and never block the
//! writer beyond the pointer swap itself.

use crate::eval::FlowSet;
use crate::faults::FaultSet;
use crate::metrics::{AlgoSummary, CongestionReport};
use crate::nodes::NodeTypeMap;
use crate::patterns::Pattern;
use crate::routing::trace::RoutePorts;
use crate::routing::{AlgorithmKind, ForwardingTables};
use crate::telemetry::BatchRecord;
use crate::topology::{Nid, Topology};
use anyhow::Result;
use std::sync::{Arc, RwLock};

/// Monitoring counters, embedded in every snapshot.
#[derive(Clone, Debug)]
pub struct FabricStats {
    /// Active routing algorithm.
    pub algorithm: AlgorithmKind,
    /// Current forwarding-table generation (equals
    /// [`FabricSnapshot::table_version`] and `tables.version`).
    pub table_version: u64,
    /// Full table computations (startup + algorithm switches) — never
    /// fault-driven, and never incremental.
    pub rebuilds: u64,
    /// Fault-driven incremental repairs since startup (one per coalesced
    /// event batch, however many events it absorbed).
    pub reroutes: u64,
    /// Repair attempts that failed (fabric partitioned): the snapshot
    /// keeps serving the last good tables and flags the gap here.
    pub failed_repairs: u64,
    /// Currently dead links.
    pub dead_links: usize,
    /// Total (switch, destination) table entries.
    pub table_entries: usize,
    /// Wall-clock cost of the last repair or rebuild.
    pub last_reroute_micros: u64,
    /// Entries the last repair changed (incremental push size).
    pub last_diff_entries: usize,
    /// Events absorbed by the last coalesced batch.
    pub last_batch_events: usize,
    /// All-pairs routes the last repair moved.
    pub last_routes_changed: usize,
    /// Whether the fabric is running on degraded (fault-avoiding) tables.
    pub degraded: bool,
    /// Journal records dropped by the bounded ring since startup
    /// (exported as `coordinator.journal.shed`): non-zero means
    /// [`FabricSnapshot::journal`] is a suffix of the mutation history,
    /// not all of it.
    pub journal_shed: u64,
    /// Peak resident bytes of the lazy reachability arena during the
    /// most recent fault repair (0 at startup and after restores, which
    /// build no reach structure).
    pub reach_peak_bytes: u64,
    /// Sliding window of per-mutation reroute costs in microseconds,
    /// oldest first, bounded (the flight-recorder series the trace
    /// exporter renders as a repair-latency track). Wall-clock —
    /// diagnostic only, like the journal's phase timings.
    pub reroute_micros_window: Vec<u64>,
}

/// One immutable, internally consistent view of the fabric: the tables
/// a manager would upload, the all-pairs route store they were derived
/// with, the fault set they route around, and the stats describing how
/// they got there. Every query (`analyze`, `trace`, `stats`) reads one
/// snapshot end to end, so concurrent repairs can never tear a result.
#[derive(Clone, Debug)]
pub struct FabricSnapshot {
    /// The (immutable) fabric graph.
    pub topo: Arc<Topology>,
    /// Node-type assignment (drives grouped algorithms and patterns).
    pub types: Arc<NodeTypeMap>,
    /// Algorithm the tables were computed with.
    pub algorithm: AlgorithmKind,
    /// Seed the algorithm was instantiated with.
    pub seed: u64,
    /// Table generation; bumped on every successful repair/rebuild.
    pub table_version: u64,
    /// Dead links these tables route around. After a *failed* repair
    /// (partitioned fabric) this is ahead of `tables` — `stats.failed_repairs`
    /// counts those gaps.
    pub faults: FaultSet,
    /// Distributable forwarding tables (`tables.version == table_version`).
    pub tables: Arc<ForwardingTables>,
    /// All-pairs route store the evaluators consume; repaired
    /// incrementally on fault events.
    pub flows: Arc<FlowSet>,
    /// Monitoring counters at publication time.
    pub stats: FabricStats,
    /// The leader's event journal at publication time: one
    /// [`BatchRecord`] per applied mutation (repairs, rebuilds,
    /// restores) with its per-phase wall-clock breakdown, oldest first,
    /// bounded at [`crate::telemetry::JOURNAL_CAP`] records. Purely
    /// diagnostic — nothing deterministic reads it.
    pub journal: Vec<BatchRecord>,
}

/// All-pairs flow index of `(src, dst)`: the store is traced over
/// [`crate::routing::verify::all_pairs`] (src-major, diagonal skipped).
#[inline]
fn flow_index(n: usize, src: Nid, dst: Nid) -> usize {
    let (s, d) = (src as usize, dst as usize);
    s * (n - 1) + d - usize::from(d > s)
}

impl FabricSnapshot {
    /// Trace flows against this snapshot's route store (self-flows trace
    /// empty). Pure read — no channel, no lock, no re-trace.
    pub fn trace(&self, flows: &[(Nid, Nid)]) -> Vec<RoutePorts> {
        let n = self.topo.num_nodes();
        flows
            .iter()
            .map(|&(src, dst)| {
                if src == dst {
                    return RoutePorts { src, dst, ports: Vec::new() };
                }
                let f = flow_index(n, src, dst);
                debug_assert_eq!(self.flows.pair(f), (src, dst));
                let ports = self.flows.route(f).iter().map(|&p| p as usize).collect();
                RoutePorts { src, dst, ports }
            })
            .collect()
    }

    /// Run the §III congestion analysis for a pattern against this
    /// snapshot's routes.
    pub fn analyze(&self, pattern: Pattern) -> Result<AlgoSummary> {
        let flows = pattern.flows(&self.topo, &self.types)?;
        let routes = self.trace(&flows);
        let rep = CongestionReport::compute(&self.topo, &routes);
        Ok(AlgoSummary::from_report(
            &self.topo,
            &rep,
            self.algorithm.as_str(),
            &pattern.name(),
            flows.len(),
        ))
    }
}

/// The arc-swap-style publication point: a single `Arc` slot the leader
/// stores into and any number of readers load from. The critical
/// section on both sides is one pointer clone/store — readers hold no
/// lock while they use a snapshot, so a slow query never delays a
/// repair and a repair never tears a query.
///
/// (The offline vendor set has no `arc-swap` crate; an `RwLock` around
/// the `Arc` gives the same shape. Lock poisoning is ignored — the
/// stored value is always a fully constructed snapshot.)
pub struct SnapshotCell {
    slot: RwLock<Arc<FabricSnapshot>>,
}

impl SnapshotCell {
    /// Create a cell holding an initial snapshot.
    pub fn new(snap: Arc<FabricSnapshot>) -> SnapshotCell {
        SnapshotCell { slot: RwLock::new(snap) }
    }

    /// Load the current snapshot (one Arc clone under a read guard).
    pub fn load(&self) -> Arc<FabricSnapshot> {
        self.slot.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Publish a new snapshot (one pointer store under a write guard).
    pub fn store(&self, snap: Arc<FabricSnapshot>) {
        *self.slot.write().unwrap_or_else(|e| e.into_inner()) = snap;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flow_index_matches_all_pairs_order() {
        let n = 64usize;
        let pairs = crate::routing::verify::all_pairs(n as Nid);
        for (i, &(s, d)) in pairs.iter().enumerate() {
            assert_eq!(flow_index(n, s, d), i);
        }
    }
}
