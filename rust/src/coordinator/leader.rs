//! The leader's write path: full rebuilds and incremental fault
//! repairs, each ending in exactly one snapshot publication.
//!
//! # Incremental-repair invariant
//!
//! Fault batches are repaired with [`FlowSet::retrace_incremental`]
//! (only the flows crossing a dead link are re-traced), never a full
//! re-trace. Correctness rests on a monotonicity argument: under
//! [`crate::faults::DegradedRouter`], up\*/down\* reachability only
//! *shrinks* as the fault set grows, and the router keeps the base
//! algorithm's choice wherever its link survives. So for `F_new ⊇
//! F_old`, a store that is correct for `F_old` repaired incrementally
//! against `F_new` is byte-identical to a from-scratch trace under
//! `F_new` — pure link-*down* batches therefore compose from the
//! *current* store. A revive breaks the superset relation, so any batch
//! containing a link-up repairs from the cached *pristine* store
//! instead (and a batch that empties the fault set just restores the
//! pristine store and tables outright). `tests/fabric_service.rs` pins
//! this equality after every event of a random cascade grid.

use super::snapshot::{FabricSnapshot, FabricStats, SnapshotCell};
use crate::eval::FlowSet;
use crate::faults::{DegradedRouter, FaultSet, LinkEvent, ReachStats, DEFAULT_REACH_BUDGET};
use crate::nodes::{NodeTypeMap, TypeReindex};
use crate::routing::degraded::route_degraded;
use crate::routing::verify::all_pairs;
use crate::routing::{AlgorithmKind, ForwardingTables, Router};
use crate::telemetry::{BatchKind, BatchRecord, Journal, Telemetry, JOURNAL_CAP};
use crate::topology::{Nid, Topology};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Mutations retained in [`FabricStats::reroute_micros_window`]: enough
/// to smooth a latency estimate over a cascade, small enough that every
/// snapshot clone stays cheap.
const REROUTE_WINDOW_CAP: usize = 64;

/// Everything a full (from-scratch) build produces.
struct FullBuild {
    pristine_flows: Arc<FlowSet>,
    pristine_tables: Arc<ForwardingTables>,
    flows: Arc<FlowSet>,
    tables: ForwardingTables,
}

/// The single-writer fabric state. Owned by the leader thread; every
/// mutation publishes one fresh [`FabricSnapshot`] into the cell.
pub(super) struct Leader {
    topo: Arc<Topology>,
    types: Arc<NodeTypeMap>,
    reindex: TypeReindex,
    kind: AlgorithmKind,
    seed: u64,
    faults: FaultSet,
    /// Healthy-fabric route store / tables for the current algorithm —
    /// the repair base whenever a batch revives a link, and the restore
    /// target when the last fault clears.
    pristine_flows: Arc<FlowSet>,
    pristine_tables: Arc<ForwardingTables>,
    /// Published state (what the current snapshot serves).
    flows: Arc<FlowSet>,
    tables: Arc<ForwardingTables>,
    version: u64,
    rebuilds: u64,
    reroutes: u64,
    failed_repairs: u64,
    last_reroute_micros: u64,
    last_diff_entries: usize,
    last_batch_events: usize,
    last_routes_changed: usize,
    /// Bounded ring of per-batch phase breakdowns, cloned into every
    /// published snapshot (see [`crate::telemetry::journal`]).
    journal: Journal,
    /// Sliding window of per-mutation reroute costs (micros), oldest
    /// first, capped at [`REROUTE_WINDOW_CAP`].
    reroute_window: VecDeque<u64>,
    /// Reach-arena high-water of the most recent fault repair.
    reach_peak_bytes: u64,
    /// Instrumentation handle: repairs route through the
    /// telemetry-aware retrace and harvest `eval.reach.*` counters, so
    /// `pgft fabric --telemetry` sees the leader's work. Disabled
    /// handles cost one branch per call.
    telem: Telemetry,
    cell: Arc<SnapshotCell>,
}

impl Leader {
    /// Build the initial state (pristine fabric, version 1) and the cell
    /// readers will load from.
    pub(super) fn new(
        topo: Arc<Topology>,
        types: Arc<NodeTypeMap>,
        kind: AlgorithmKind,
        seed: u64,
        telem: Telemetry,
    ) -> Result<(Leader, Arc<SnapshotCell>)> {
        let t0 = Instant::now();
        let reindex = TypeReindex::new(&types);
        let faults = FaultSet::none(&topo);
        let built = compute_full(&topo, &types, &reindex, kind, seed, &faults)?;
        let mut tables = built.tables;
        tables.version = 1;
        let tables = Arc::new(tables);
        let stats = FabricStats {
            algorithm: kind,
            table_version: 1,
            rebuilds: 1,
            reroutes: 0,
            failed_repairs: 0,
            dead_links: 0,
            table_entries: tables.num_entries(),
            last_reroute_micros: t0.elapsed().as_micros() as u64,
            last_diff_entries: tables.num_entries(), // initial full push
            last_batch_events: 0,
            last_routes_changed: 0,
            degraded: false,
            journal_shed: 0,
            reach_peak_bytes: 0,
            reroute_micros_window: Vec::new(),
        };
        let cell = Arc::new(SnapshotCell::new(Arc::new(FabricSnapshot {
            topo: topo.clone(),
            types: types.clone(),
            algorithm: kind,
            seed,
            table_version: 1,
            faults: faults.clone(),
            tables: tables.clone(),
            flows: built.flows.clone(),
            stats: stats.clone(),
            journal: Vec::new(),
        })));
        let leader = Leader {
            topo,
            types,
            reindex,
            kind,
            seed,
            faults,
            pristine_flows: built.pristine_flows,
            pristine_tables: built.pristine_tables,
            flows: built.flows,
            tables,
            version: 1,
            rebuilds: 1,
            reroutes: 0,
            failed_repairs: 0,
            last_reroute_micros: stats.last_reroute_micros,
            last_diff_entries: stats.last_diff_entries,
            last_batch_events: 0,
            last_routes_changed: 0,
            journal: Journal::new(JOURNAL_CAP),
            reroute_window: VecDeque::new(),
            reach_peak_bytes: 0,
            telem,
            cell: cell.clone(),
        };
        Ok((leader, cell))
    }

    fn grouped_reindex(&self) -> Option<&TypeReindex> {
        if self.kind.is_grouped() {
            Some(&self.reindex)
        } else {
            None
        }
    }

    /// Apply one coalesced event batch: fold every event into the fault
    /// set, repair once, publish once. A batch whose net effect is
    /// empty (e.g. a down for an already-dead link) publishes nothing.
    pub(super) fn apply_batch(&mut self, events: &[LinkEvent]) {
        let t0 = Instant::now();
        let mut faults = self.faults.clone();
        for e in events {
            match *e {
                LinkEvent::Down(l) => faults.kill(l),
                LinkEvent::Up(l) => faults.revive(l),
            }
        }
        let coalesce_ns = t0.elapsed().as_nanos() as u64;
        if faults == self.faults {
            return;
        }
        // Did the batch revive anything that was dead before it? If so
        // the new fault set is not a superset of the old one and the
        // current store is no repair base — fall back to the pristine
        // store (see module docs).
        let any_revive = self.faults.dead_links().into_iter().any(|l| !faults.is_dead(l));
        let mut record = BatchRecord {
            kind: if faults.num_dead() == 0 { BatchKind::Restore } else { BatchKind::Repair },
            events: events.len(),
            dead_links: faults.num_dead(),
            dirty_flows: 0,
            routes_changed: 0,
            diff_entries: 0,
            coalesce_ns,
            dirty_scan_ns: 0,
            retrace_ns: 0,
            tables_ns: 0,
            diff_ns: 0,
            publish_ns: 0,
        };
        let mut reach = ReachStats::default();
        let repaired: Result<(Arc<FlowSet>, ForwardingTables)> = (|| {
            if faults.num_dead() == 0 {
                return Ok((self.pristine_flows.clone(), (*self.pristine_tables).clone()));
            }
            // Lazy-checked degraded router: eager partition validation
            // (same answers as the eager builder, so repair failures
            // surface identically) but a budgeted lazy reach arena, so
            // the repair's memory high-water is observable and bounded.
            let base_router = self.kind.build(&self.topo, Some(&self.types), self.seed);
            let router = DegradedRouter::new_lazy_checked(
                &self.topo,
                &faults,
                base_router,
                DEFAULT_REACH_BUDGET,
            )?;
            let base = if any_revive { &self.pristine_flows } else { &self.flows };
            // Large fabrics repair in parallel; the ordered splice keeps
            // the published store byte-identical to a serial repair.
            let threads = crate::eval::repair_threads(base.len());
            let (flows, changed, timing) = base.retrace_incremental_timed_telem(
                &self.topo,
                &faults,
                &router,
                threads,
                &self.telem,
            );
            record.dirty_flows = changed;
            record.dirty_scan_ns = timing.dirty_scan_ns;
            record.retrace_ns = timing.trace_ns + timing.splice_ns;
            let tt = Instant::now();
            let tables = if router.dest_based() {
                ForwardingTables::build(&self.topo, &router)?
            } else {
                // Source-based algorithms have no plain LFT form; the
                // distributable fallback is the procedural balancer
                // with the same type re-index.
                route_degraded(&self.topo, &faults, self.grouped_reindex())?
            };
            record.tables_ns = tt.elapsed().as_nanos() as u64;
            reach = router.reach_stats();
            Ok((Arc::new(flows), tables))
        })();
        self.last_batch_events = events.len();
        match repaired {
            Ok((flows, mut tables)) => {
                self.version += 1;
                tables.version = self.version;
                let td = Instant::now();
                self.last_routes_changed = self.flows.diff_count(&flows);
                self.last_diff_entries = self.tables.diff_entries(&tables);
                record.diff_ns = td.elapsed().as_nanos() as u64;
                record.routes_changed = self.last_routes_changed;
                record.diff_entries = self.last_diff_entries;
                self.flows = flows;
                self.tables = Arc::new(tables);
                self.reroutes += 1;
                self.faults = faults;
                self.last_reroute_micros = t0.elapsed().as_micros() as u64;
                self.reach_peak_bytes = reach.peak_bytes;
                self.telem.add("eval.reach.computed", reach.computed);
                self.telem.add("eval.reach.hits", reach.hits);
                self.telem.add("eval.reach.evictions", reach.evictions);
                self.telem.record_max("eval.reach.peak_bytes", reach.peak_bytes);
                self.note_reroute(self.last_reroute_micros);
                self.publish_journalled(record);
            }
            Err(e) => {
                // Partitioned: keep serving the last good tables, but
                // tell readers the truth about the fault set. Failed
                // repairs are counted, not journalled — the journal
                // records completed mutations only.
                self.failed_repairs += 1;
                eprintln!("fabric repair failed ({} events): {e:#}", events.len());
                self.faults = faults;
                self.last_reroute_micros = t0.elapsed().as_micros() as u64;
                self.publish();
            }
        }
    }

    /// Switch the routing algorithm live: full rebuild (pristine store
    /// and tables for the new algorithm), then a repair against the
    /// current fault set if one is active. Counted under `rebuilds`,
    /// not `reroutes`.
    pub(super) fn set_algorithm(&mut self, kind: AlgorithmKind) {
        if kind == self.kind {
            return;
        }
        let t0 = Instant::now();
        let old_kind = self.kind;
        self.kind = kind;
        let built =
            compute_full(&self.topo, &self.types, &self.reindex, kind, self.seed, &self.faults);
        // The whole from-scratch build (all-pairs trace + tables, plus
        // the degraded derivation under active faults) lands under the
        // journal record's `retrace_ns` — a rebuild has no incremental
        // phases to split it into.
        let build_ns = t0.elapsed().as_nanos() as u64;
        self.last_batch_events = 0;
        match built {
            Ok(built) => {
                let mut tables = built.tables;
                self.version += 1;
                tables.version = self.version;
                let td = Instant::now();
                self.last_routes_changed = self.flows.diff_count(&built.flows);
                self.last_diff_entries = self.tables.diff_entries(&tables);
                let diff_ns = td.elapsed().as_nanos() as u64;
                self.pristine_flows = built.pristine_flows;
                self.pristine_tables = built.pristine_tables;
                self.flows = built.flows;
                self.tables = Arc::new(tables);
                self.rebuilds += 1;
                self.last_reroute_micros = t0.elapsed().as_micros() as u64;
                self.note_reroute(self.last_reroute_micros);
                self.publish_journalled(BatchRecord {
                    kind: BatchKind::Rebuild,
                    events: 0,
                    dead_links: self.faults.num_dead(),
                    dirty_flows: 0,
                    routes_changed: self.last_routes_changed,
                    diff_entries: self.last_diff_entries,
                    coalesce_ns: 0,
                    dirty_scan_ns: 0,
                    retrace_ns: build_ns,
                    tables_ns: 0,
                    diff_ns,
                    publish_ns: 0,
                });
            }
            Err(e) => {
                self.kind = old_kind;
                self.failed_repairs += 1;
                eprintln!("algorithm switch to {kind} failed: {e:#}");
                self.last_reroute_micros = t0.elapsed().as_micros() as u64;
                self.publish();
            }
        }
    }

    /// Append one completed mutation's cost to the sliding window
    /// (journalled mutations only, like the journal itself).
    fn note_reroute(&mut self, micros: u64) {
        if self.reroute_window.len() == REROUTE_WINDOW_CAP {
            self.reroute_window.pop_front();
        }
        self.reroute_window.push_back(micros);
    }

    fn stats(&self) -> FabricStats {
        FabricStats {
            algorithm: self.kind,
            table_version: self.version,
            rebuilds: self.rebuilds,
            reroutes: self.reroutes,
            failed_repairs: self.failed_repairs,
            dead_links: self.faults.num_dead(),
            table_entries: self.tables.num_entries(),
            last_reroute_micros: self.last_reroute_micros,
            last_diff_entries: self.last_diff_entries,
            last_batch_events: self.last_batch_events,
            last_routes_changed: self.last_routes_changed,
            degraded: self.faults.num_dead() > 0,
            journal_shed: self.journal.shed(),
            reach_peak_bytes: self.reach_peak_bytes,
            reroute_micros_window: self.reroute_window.iter().copied().collect(),
        }
    }

    fn snapshot(&self) -> FabricSnapshot {
        FabricSnapshot {
            topo: self.topo.clone(),
            types: self.types.clone(),
            algorithm: self.kind,
            seed: self.seed,
            table_version: self.version,
            faults: self.faults.clone(),
            tables: self.tables.clone(),
            flows: self.flows.clone(),
            stats: self.stats(),
            journal: self.journal.records(),
        }
    }

    fn publish(&self) {
        self.cell.store(Arc::new(self.snapshot()));
    }

    /// Complete a journal record with the measured publish cost, append
    /// it, and publish. The snapshot is built *before* the record is
    /// appended (that build is what `publish_ns` measures — the cell
    /// store itself is one pointer swap), then its journal view is
    /// refreshed so the published snapshot already carries this batch's
    /// full phase breakdown.
    fn publish_journalled(&mut self, mut record: BatchRecord) {
        let tp = Instant::now();
        let mut snap = self.snapshot();
        record.publish_ns = tp.elapsed().as_nanos() as u64;
        self.journal.push(record);
        snap.journal = self.journal.records();
        self.cell.store(Arc::new(snap));
    }
}

/// Full (non-incremental) build for one algorithm: the pristine
/// all-pairs store + tables, and — when `faults` is non-empty — their
/// degraded counterparts derived from that pristine base.
fn compute_full(
    topo: &Arc<Topology>,
    types: &Arc<NodeTypeMap>,
    reindex: &TypeReindex,
    kind: AlgorithmKind,
    seed: u64,
    faults: &FaultSet,
) -> Result<FullBuild> {
    let grouped = if kind.is_grouped() { Some(reindex) } else { None };
    let router = kind.build(topo, Some(types), seed);
    let pairs = all_pairs(topo.num_nodes() as Nid);
    let pristine_flows = Arc::new(FlowSet::trace(topo, &*router, &pairs));
    let none = FaultSet::none(topo);
    let pristine_tables = Arc::new(if router.dest_based() {
        ForwardingTables::build(topo, &*router)?
    } else {
        route_degraded(topo, &none, grouped)?
    });
    let (flows, tables) = if faults.num_dead() == 0 {
        (pristine_flows.clone(), (*pristine_tables).clone())
    } else {
        let degraded = kind.build_degraded(topo, Some(types), seed, faults)?;
        let threads = crate::eval::repair_threads(pristine_flows.len());
        let (flows, _) =
            pristine_flows.retrace_incremental_par(topo, faults, &*degraded, threads);
        let tables = if degraded.dest_based() {
            ForwardingTables::build(topo, &*degraded)?
        } else {
            route_degraded(topo, faults, grouped)?
        };
        (Arc::new(flows), tables)
    };
    Ok(FullBuild { pristine_flows, pristine_tables, flows, tables })
}
