//! Lowering and evaluation: from a [`WorkloadSpec`] to per-phase
//! [`FlowSet`]s and a fair-rate-derived makespan.
//!
//! Two stages, deliberately separated:
//!
//!  1. [`lower`] — **router-independent**: resolve every job's group on
//!     the concrete fabric and expand its phases into [`Segment`]s
//!     (collective steps become one flow segment each, pattern bursts
//!     one segment, idles stay idle segments). A lowered workload can be
//!     evaluated against any router, degraded routers included.
//!  2. [`evaluate_makespan`] — the **fluid phase simulation**: jobs
//!     advance concurrently through their segments; between *global
//!     phase boundaries* (the moments some job finishes a segment) the
//!     active flow union is fixed, traced **once** into an arena-backed
//!     [`FlowSet`], and every flow progresses at its exact max-min fair
//!     rate ([`crate::sim::fair_rates`], links = capacity 1). The phase
//!     ends when the earliest job completes its segment; remaining
//!     volumes carry over and the next phase re-traces the new union.
//!
//! The model is bulk-synchronous *per segment*: a segment completes when
//! its slowest flow does, and rates are held constant within a phase
//! (flows that finish their own bytes early keep their allocation until
//! the boundary). That makes the metric deterministic, cheap — the
//! number of global phases is bounded by the total segment count — and
//! conservative; it is the same fluid approximation flow-level fat-tree
//! studies use between reconfiguration events. The flit-level
//! cross-check is [`crate::netsim::run_netsim_phased`], which replays
//! the same phase sequence with VC/credit flow control.
//!
//! A single-phase workload degenerates to exactly one phase whose
//! [`FlowSet`] equals the static pattern's, so its makespan is
//! `bytes / min_rate` — bit-exact with the corresponding static-pattern
//! sweep cell (`tests/workload_model.rs` pins this).

use super::job::{Phase, WorkloadSpec};
use crate::eval::FlowSet;
use crate::nodes::NodeTypeMap;
use crate::routing::Router;
use crate::sim::fair_rates;
use crate::topology::{Nid, Topology};
use anyhow::{ensure, Context, Result};

/// One lowered unit of job progress: a bulk-synchronous flow step or an
/// idle gap.
#[derive(Clone, Debug, PartialEq)]
pub enum Segment {
    /// Concurrent flows, each moving `bytes_per_flow`.
    Flows {
        /// Human-readable provenance (`"ring-allreduce step 3/30"`).
        label: String,
        /// The `(src, dst)` flows of the step.
        flows: Vec<(Nid, Nid)>,
        /// Bytes every flow moves.
        bytes_per_flow: f64,
    },
    /// No traffic for `time` units.
    Idle {
        /// Idle duration (bytes at unit link capacity).
        time: f64,
    },
}

/// One job, lowered onto a concrete fabric.
#[derive(Clone, Debug, PartialEq)]
pub struct LoweredJob {
    /// Job name (from the spec).
    pub name: String,
    /// Resolved group member NIDs, ascending.
    pub group: Vec<Nid>,
    /// The job's segment sequence.
    pub segments: Vec<Segment>,
}

/// A workload lowered onto a concrete fabric, ready for evaluation
/// against any router.
#[derive(Clone, Debug, PartialEq)]
pub struct LoweredWorkload {
    /// Workload name (from the spec).
    pub name: String,
    /// The concurrent lowered jobs.
    pub jobs: Vec<LoweredJob>,
}

impl LoweredWorkload {
    /// Total segments over all jobs — the upper bound on global phases.
    pub fn num_segments(&self) -> usize {
        self.jobs.iter().map(|j| j.segments.len()).sum()
    }
}

/// Resolve groups and expand phases (see the module docs). Pattern
/// phases keep the pattern's own flow order, restricted to sources
/// inside the job's group — so a whole-fabric single-phase workload
/// reproduces the static pattern's flow list verbatim.
pub fn lower(
    spec: &WorkloadSpec,
    topo: &Topology,
    types: &NodeTypeMap,
) -> Result<LoweredWorkload> {
    spec.validate()?;
    let mut jobs = Vec::with_capacity(spec.jobs.len());
    for job in &spec.jobs {
        let group = job
            .group
            .resolve(topo, types)
            .with_context(|| format!("workload {:?}: job {:?}", spec.name, job.name))?;
        let in_group = |n: Nid| group.binary_search(&n).is_ok();
        let mut segments = Vec::new();
        for phase in &job.phases {
            match phase {
                Phase::Collective { op, bytes } => {
                    let steps = op
                        .schedule(&group, *bytes)
                        .with_context(|| format!("job {:?}: phase {}", job.name, phase.name()))?;
                    let total = steps.len();
                    for (i, step) in steps.into_iter().enumerate() {
                        segments.push(Segment::Flows {
                            label: format!("{} step {}/{}", op.name(), i + 1, total),
                            flows: step.flows,
                            bytes_per_flow: step.bytes_per_flow,
                        });
                    }
                }
                Phase::Traffic { pattern, bytes } => {
                    let flows: Vec<(Nid, Nid)> = pattern
                        .flows(topo, types)
                        .with_context(|| format!("job {:?}: phase {}", job.name, phase.name()))?
                        .into_iter()
                        .filter(|&(s, d)| s != d && in_group(s))
                        .collect();
                    ensure!(
                        !flows.is_empty(),
                        "job {:?}: pattern {} has no sources inside group {}",
                        job.name,
                        pattern.name(),
                        job.group.name()
                    );
                    segments.push(Segment::Flows {
                        label: pattern.name(),
                        flows,
                        bytes_per_flow: *bytes as f64,
                    });
                }
                Phase::Idle { time } => segments.push(Segment::Idle { time: *time }),
            }
        }
        jobs.push(LoweredJob { name: job.name.clone(), group, segments });
    }
    Ok(LoweredWorkload { name: spec.name.clone(), jobs })
}

/// One global phase of the fluid simulation: a fixed flow union between
/// two consecutive job-segment boundaries.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseRecord {
    /// Phase index (0-based).
    pub index: usize,
    /// Start time of the phase.
    pub t_start: f64,
    /// Phase duration (until the earliest job finishes its segment).
    pub duration: f64,
    /// Names of the jobs active during the phase.
    pub active_jobs: Vec<String>,
    /// The phase's flow union, in (job, segment) order — the list
    /// [`crate::netsim::run_netsim_phased`] replays.
    pub flow_pairs: Vec<(Nid, Nid)>,
    /// Sum of the max-min fair rates over the phase's flows.
    pub aggregate_rate: f64,
    /// Worst flow rate of the phase (0 for idle-only phases).
    pub min_rate: f64,
}

/// Result of evaluating one lowered workload against one router.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadEval {
    /// Workload name.
    pub workload: String,
    /// Total time until every job completed its last segment.
    pub makespan: f64,
    /// The global phase sequence.
    pub phases: Vec<PhaseRecord>,
    /// Per-job completion time, in job order.
    pub job_times: Vec<(String, f64)>,
}

/// Compact per-cell summary for sweep rows and CSV columns.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadStats {
    /// Workload name.
    pub name: String,
    /// Number of global phases the fluid simulation produced.
    pub phases: usize,
    /// The makespan figure.
    pub makespan: f64,
    /// Per-job completion times, in job order.
    pub job_times: Vec<f64>,
}

impl WorkloadStats {
    /// Summarize an evaluation.
    pub fn from_eval(eval: &WorkloadEval) -> WorkloadStats {
        WorkloadStats {
            name: eval.workload.clone(),
            phases: eval.phases.len(),
            makespan: eval.makespan,
            job_times: eval.job_times.iter().map(|(_, t)| *t).collect(),
        }
    }
}

/// Per-job progress through its segment list.
enum JobState {
    Flows { remaining: Vec<f64> },
    Idle { remaining: f64 },
    Done,
}

fn enter_segment(job: &LoweredJob, seg: usize) -> JobState {
    match job.segments.get(seg) {
        Some(Segment::Flows { flows, bytes_per_flow, .. }) => {
            JobState::Flows { remaining: vec![*bytes_per_flow; flows.len()] }
        }
        Some(Segment::Idle { time }) => JobState::Idle { remaining: *time },
        None => JobState::Done,
    }
}

/// Run the fluid phase simulation (see the module docs) of a lowered
/// workload under `router` and return the makespan, the per-job
/// completion times and the full phase sequence.
pub fn evaluate_makespan(
    topo: &Topology,
    router: &dyn Router,
    lw: &LoweredWorkload,
) -> Result<WorkloadEval> {
    evaluate_inner(topo, router, lw, false).map(|(eval, _)| eval)
}

/// Like [`evaluate_makespan`], additionally returning the per-phase
/// [`FlowSet`]s the fluid loop traced (one per phase, empty stores for
/// idle-only phases) — the input of
/// [`crate::netsim::run_netsim_phased`], without re-tracing anything.
/// Use the plain variant when the sets are not needed (e.g. sweep
/// cells): the traced arenas are dropped per phase there instead of
/// accumulating.
pub fn evaluate_makespan_traced(
    topo: &Topology,
    router: &dyn Router,
    lw: &LoweredWorkload,
) -> Result<(WorkloadEval, Vec<FlowSet>)> {
    evaluate_inner(topo, router, lw, true)
}

fn evaluate_inner(
    topo: &Topology,
    router: &dyn Router,
    lw: &LoweredWorkload,
    keep_sets: bool,
) -> Result<(WorkloadEval, Vec<FlowSet>)> {
    ensure!(!lw.jobs.is_empty(), "workload {:?} has no jobs", lw.name);
    let n_jobs = lw.jobs.len();
    let mut seg_idx = vec![0usize; n_jobs];
    let mut states: Vec<JobState> =
        lw.jobs.iter().map(|j| enter_segment(j, 0)).collect();
    let mut job_times: Vec<f64> = vec![0.0; n_jobs];
    let mut phases: Vec<PhaseRecord> = Vec::new();
    let mut sets: Vec<FlowSet> = Vec::new();
    let mut t = 0.0f64;

    // Every iteration retires at least one segment, so the loop is
    // bounded by the total segment count (guarded below).
    for index in 0..=lw.num_segments() {
        // Gather the active flow union, tagged with its owning job.
        let mut pairs: Vec<(Nid, Nid)> = Vec::new();
        let mut owners: Vec<(usize, usize)> = Vec::new(); // (job, local flow)
        let mut active_jobs: Vec<String> = Vec::new();
        let mut any_active = false;
        for (j, state) in states.iter().enumerate() {
            match state {
                JobState::Flows { remaining } => {
                    any_active = true;
                    active_jobs.push(lw.jobs[j].name.clone());
                    let Segment::Flows { flows, .. } = &lw.jobs[j].segments[seg_idx[j]] else {
                        unreachable!("Flows state always points at a Flows segment")
                    };
                    for (i, &(s, d)) in flows.iter().enumerate() {
                        debug_assert_eq!(remaining.len(), flows.len());
                        pairs.push((s, d));
                        owners.push((j, i));
                    }
                }
                JobState::Idle { .. } => {
                    any_active = true;
                    active_jobs.push(lw.jobs[j].name.clone());
                }
                JobState::Done => {}
            }
        }
        if !any_active {
            let eval = WorkloadEval {
                workload: lw.name.clone(),
                makespan: t,
                phases,
                job_times: lw
                    .jobs
                    .iter()
                    .zip(&job_times)
                    .map(|(j, &ct)| (j.name.clone(), ct))
                    .collect(),
            };
            return Ok((eval, sets));
        }
        ensure!(
            index < lw.num_segments(),
            "workload {:?}: fluid simulation failed to retire a segment per phase",
            lw.name
        );

        // Trace the union once into the arena store and solve the exact
        // max-min rates (empty unions are idle-only phases). With
        // `keep_sets` the traced store is retained for the flit-level
        // replay instead of being re-traced later.
        let rates: Vec<f64> = if pairs.is_empty() {
            if keep_sets {
                sets.push(FlowSet::empty());
            }
            Vec::new()
        } else {
            let set = FlowSet::trace(topo, router, &pairs);
            let rates = fair_rates(topo, &set);
            if keep_sets {
                sets.push(set);
            }
            rates
        };

        // Per-job segment completion horizon at the current rates.
        let mut completions: Vec<Option<f64>> = vec![None; n_jobs];
        for (g, &(j, i)) in owners.iter().enumerate() {
            let JobState::Flows { remaining } = &states[j] else { unreachable!() };
            let (s, d) = pairs[g];
            ensure!(
                rates[g] > 1e-15,
                "workload {:?}: flow {s}->{d} received zero fair rate \
                 (is the fabric partitioned?)",
                lw.name
            );
            let need = remaining[i] / rates[g];
            let slot = completions[j].get_or_insert(0.0);
            if need > *slot {
                *slot = need;
            }
        }
        for (j, state) in states.iter().enumerate() {
            if let JobState::Idle { remaining } = state {
                completions[j] = Some(*remaining);
            }
        }
        let dt = completions
            .iter()
            .flatten()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        debug_assert!(dt.is_finite() && dt >= 0.0, "phase duration must be finite");

        // Advance every active job by dt; jobs whose horizon equals the
        // minimum finish their segment and load the next one.
        let mut agg = 0.0f64;
        let mut min_rate = f64::INFINITY;
        for (g, &(j, i)) in owners.iter().enumerate() {
            let JobState::Flows { remaining } = &mut states[j] else { unreachable!() };
            remaining[i] = (remaining[i] - rates[g] * dt).max(0.0);
            agg += rates[g];
            if rates[g] < min_rate {
                min_rate = rates[g];
            }
        }
        for j in 0..n_jobs {
            match &mut states[j] {
                JobState::Idle { remaining } => *remaining -= dt,
                JobState::Flows { .. } | JobState::Done => {}
            }
            if completions[j].is_some_and(|c| c <= dt) {
                seg_idx[j] += 1;
                states[j] = enter_segment(&lw.jobs[j], seg_idx[j]);
                if matches!(states[j], JobState::Done) {
                    job_times[j] = t + dt;
                }
            }
        }
        phases.push(PhaseRecord {
            index,
            t_start: t,
            duration: dt,
            active_jobs,
            flow_pairs: pairs,
            aggregate_rate: agg,
            min_rate: if min_rate.is_finite() { min_rate } else { 0.0 },
        });
        t += dt;
    }
    unreachable!("the segment-count bound always exits through the all-done branch")
}

/// Trace every phase of an evaluation into its own [`FlowSet`] — the
/// input shape of [`crate::netsim::run_netsim_phased`]. Idle-only
/// phases (no flows) are kept as empty stores so phase indices line up
/// with [`WorkloadEval::phases`]. When the evaluation itself is still
/// to be run, prefer [`evaluate_makespan_traced`], which returns the
/// same sets without tracing the phase sequence a second time.
pub fn phase_flowsets(
    topo: &Topology,
    router: &dyn Router,
    eval: &WorkloadEval,
) -> Vec<FlowSet> {
    eval.phases
        .iter()
        .map(|p| FlowSet::trace(topo, router, &p.flow_pairs))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::patterns::Pattern;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};
    use crate::workload::{Collective, GroupSpec, Job, WorkloadSpec};

    fn fabric() -> (Topology, NodeTypeMap) {
        let topo = build_pgft(&PgftSpec::case_study());
        let types =
            Placement::parse("io:last:1,gpgpu:first:2").unwrap().apply(&topo).unwrap();
        (topo, types)
    }

    #[test]
    fn lowering_expands_collectives_and_filters_patterns() {
        let (topo, types) = fabric();
        let lw = lower(&WorkloadSpec::mix(), &topo, &types).unwrap();
        assert_eq!(lw.name, "mix");
        assert_eq!(lw.jobs.len(), 2);
        let ckpt = &lw.jobs[0];
        assert_eq!(ckpt.name, "ckpt");
        assert_eq!(ckpt.segments.len(), 2, "idle + one pattern burst");
        let train = &lw.jobs[1];
        assert_eq!(train.group.len(), 16, "gpgpu:first:2 on 8 leaves");
        // 2 ring allreduces of 2(16-1) steps each, plus the idle gap.
        assert_eq!(train.segments.len(), 2 * 30 + 1);
        assert_eq!(lw.num_segments(), 63);
        // The checkpoint pattern flows come from compute sources only.
        let Segment::Flows { flows, bytes_per_flow, .. } = &ckpt.segments[1] else {
            panic!("second ckpt segment is the burst")
        };
        assert_eq!(*bytes_per_flow, 1024.0);
        for &(s, _) in flows {
            assert!(ckpt.group.binary_search(&s).is_ok());
        }
    }

    #[test]
    fn single_phase_workload_is_one_phase_with_the_pattern_flows() {
        let (topo, types) = fabric();
        let spec = WorkloadSpec::parse("single:c2io-sym:1024").unwrap();
        let lw = lower(&spec, &topo, &types).unwrap();
        let router = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1);
        let eval = evaluate_makespan(&topo, &*router, &lw).unwrap();
        assert_eq!(eval.phases.len(), 1);
        assert_eq!(
            eval.phases[0].flow_pairs,
            Pattern::C2ioSym.flows(&topo, &types).unwrap(),
            "whole-fabric single-phase workloads keep the pattern's flow list verbatim"
        );
        // makespan = bytes / min_rate, exactly (division is monotone).
        let set = FlowSet::trace(&topo, &*router, &eval.phases[0].flow_pairs);
        let min = fair_rates(&topo, &set).into_iter().fold(f64::INFINITY, f64::min);
        assert_eq!(eval.makespan, 1024.0 / min);
        assert_eq!(eval.job_times, vec![("main".to_string(), eval.makespan)]);
    }

    #[test]
    fn idle_only_workloads_cost_their_idle_time() {
        let (topo, types) = fabric();
        let spec = WorkloadSpec {
            name: "naps".into(),
            jobs: vec![
                Job {
                    name: "a".into(),
                    group: GroupSpec::All,
                    phases: vec![
                        crate::workload::Phase::Idle { time: 5.0 },
                        crate::workload::Phase::Idle { time: 2.0 },
                    ],
                },
                Job {
                    name: "b".into(),
                    group: GroupSpec::All,
                    phases: vec![crate::workload::Phase::Idle { time: 6.0 }],
                },
            ],
        };
        let lw = lower(&spec, &topo, &types).unwrap();
        let router = AlgorithmKind::Dmodk.build(&topo, Some(&types), 1);
        let eval = evaluate_makespan(&topo, &*router, &lw).unwrap();
        assert_eq!(eval.makespan, 7.0);
        assert_eq!(eval.phases.len(), 3, "boundaries at t=5, 6, 7");
        assert_eq!(eval.phases[0].flow_pairs.len(), 0);
        assert_eq!(eval.job_times, vec![("a".to_string(), 7.0), ("b".to_string(), 6.0)]);
    }

    #[test]
    fn makespan_is_deterministic_and_phase_bounded() {
        let (topo, types) = fabric();
        let lw = lower(&WorkloadSpec::mix(), &topo, &types).unwrap();
        for kind in [AlgorithmKind::Dmodk, AlgorithmKind::Gdmodk] {
            let router = kind.build(&topo, Some(&types), 1);
            let a = evaluate_makespan(&topo, &*router, &lw).unwrap();
            let b = evaluate_makespan(&topo, &*router, &lw).unwrap();
            assert_eq!(a, b, "{kind}: bit-identical re-evaluation");
            assert!(a.phases.len() <= lw.num_segments());
            assert!(a.makespan > 0.0);
            let durations: f64 = a.phases.iter().map(|p| p.duration).sum();
            assert!((durations - a.makespan).abs() < 1e-9 * a.makespan.max(1.0));
            for (name, time) in &a.job_times {
                assert!(*time > 0.0, "{kind}: job {name} must finish");
                assert!(*time <= a.makespan + 1e-9);
            }
        }
    }

    #[test]
    fn gpu_allreduce_and_checkpoint_mix_prefers_gdmodk() {
        // The acceptance pin at module level (the tests/ suite repeats it
        // end-to-end through the CLI): on the overlapping mix, grouped
        // routing's makespan is no worse than dmodk's — the node-type
        // balancing claim, restated at workload level.
        let (topo, types) = fabric();
        let lw = lower(&WorkloadSpec::mix(), &topo, &types).unwrap();
        let d = evaluate_makespan(
            &topo,
            &*AlgorithmKind::Dmodk.build(&topo, Some(&types), 1),
            &lw,
        )
        .unwrap();
        let g = evaluate_makespan(
            &topo,
            &*AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1),
            &lw,
        )
        .unwrap();
        assert!(
            g.makespan * 2.0 < d.makespan,
            "gdmodk {} vs dmodk {}: grouped routing must win the mix decisively \
             (python/tools/check_workload_fluid.py measures ~2.9x)",
            g.makespan,
            d.makespan
        );
    }

    #[test]
    fn collective_schedules_run_end_to_end() {
        let (topo, types) = fabric();
        for op in [
            Collective::RingAllreduce,
            Collective::RecursiveDoublingAllreduce,
            Collective::BinomialBroadcast,
            Collective::PairwiseAllToAll,
            Collective::GatherToRoot,
        ] {
            let spec = WorkloadSpec {
                name: format!("solo-{op}"),
                jobs: vec![Job {
                    name: "j".into(),
                    group: GroupSpec::Type { ty: crate::nodes::NodeType::Gpgpu },
                    phases: vec![crate::workload::Phase::Collective { op, bytes: 256 }],
                }],
            };
            let lw = lower(&spec, &topo, &types).unwrap();
            let router = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1);
            let eval = evaluate_makespan(&topo, &*router, &lw).unwrap();
            assert!(eval.makespan > 0.0, "{op}");
            assert_eq!(eval.phases.len(), lw.num_segments(), "{op}: one phase per step");
            let sets = phase_flowsets(&topo, &*router, &eval);
            assert_eq!(sets.len(), eval.phases.len());
            assert!(sets.iter().all(|s| s.num_active() == s.len()), "{op}: no self-flows");
            // The traced variant returns the same evaluation AND the
            // same stores without the second trace pass.
            let (eval2, sets2) = evaluate_makespan_traced(&topo, &*router, &lw).unwrap();
            assert_eq!(eval2, eval, "{op}");
            assert_eq!(sets2, sets, "{op}");
        }
    }
}
