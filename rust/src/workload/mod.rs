//! Application workloads: multi-phase job mixes and collective
//! schedules over typed node groups.
//!
//! The paper's premise is that *"application communication patterns are
//! rarely available beforehand"*, so node types stand in for node usage.
//! This module supplies the missing other half of that argument: actual
//! group-specific application workloads to stress the node-type
//! balancing claim against — several concurrent [`Job`]s (a GPGPU
//! training job running [`Collective`] allreduces, a compute partition
//! bursting a checkpoint at the IO nodes, …), each a phase sequence over
//! a node group selected by [`crate::nodes::NodeType`] and placement.
//!
//! Layering:
//!  * [`collective`] — MPI-style collectives (ring / recursive-doubling
//!    allreduce, binomial broadcast, pairwise all-to-all, gather)
//!    compiled into per-step flow lists over an arbitrary group;
//!  * [`job`] — [`GroupSpec`] / [`Phase`] / [`Job`] / [`WorkloadSpec`]:
//!    the TOML-parseable description of a concurrent job mix, plus
//!    named built-ins (`mix`, `allreduce`, `checkpoint`,
//!    `single:<pattern>:BYTES`);
//!  * [`compile`] — [`lower`] onto a concrete fabric and
//!    [`evaluate_makespan`]: the fluid phase simulation that traces one
//!    arena-backed [`crate::eval::FlowSet`] per global phase boundary
//!    and derives a max-min fair-rate makespan; [`phase_flowsets`]
//!    hands the same phase sequence to
//!    [`crate::netsim::run_netsim_phased`] for flit-level replay.
//!
//! Surfaces: the `pgft workload` subcommand, the `workload = [...]`
//! sweep axis (`wl_*` CSV columns), and
//! `examples/heterogeneous_cluster.rs`.
//!
//! ```
//! use pgft::prelude::*;
//! use pgft::workload::{evaluate_makespan, lower, WorkloadSpec};
//! let topo = build_pgft(&PgftSpec::case_study());
//! let types = Placement::parse("io:last:1,gpgpu:first:2").unwrap().apply(&topo).unwrap();
//! let lw = lower(&WorkloadSpec::mix(), &topo, &types).unwrap();
//! let dmodk = evaluate_makespan(&topo, &*AlgorithmKind::Dmodk.build(&topo, Some(&types), 1), &lw).unwrap();
//! let gdmodk = evaluate_makespan(&topo, &*AlgorithmKind::Gdmodk.build(&topo, Some(&types), 1), &lw).unwrap();
//! // The paper's claim, restated at workload level:
//! assert!(gdmodk.makespan < dmodk.makespan);
//! ```

pub mod collective;
pub mod compile;
pub mod job;

pub use collective::{Collective, CollectiveStep, COLLECTIVE_VOCAB};
pub use compile::{
    evaluate_makespan, evaluate_makespan_traced, lower, phase_flowsets, LoweredJob,
    LoweredWorkload, PhaseRecord, Segment, WorkloadEval, WorkloadStats,
};
pub use job::{
    GroupSpec, Job, Phase, WorkloadSpec, GROUP_VOCAB, PHASE_VOCAB, WORKLOAD_VOCAB,
};
