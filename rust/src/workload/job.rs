//! Jobs, node groups, phases and the [`WorkloadSpec`]: *what* runs on
//! the fabric.
//!
//! A [`Job`] is a node group — selected by [`crate::nodes::NodeType`]
//! and/or NID range, i.e. by the same placement vocabulary the paper
//! builds its premise on — plus an ordered sequence of [`Phase`]s:
//! collectives ([`Collective`]), pattern traffic bursts
//! ([`crate::patterns::Pattern`]) or idle gaps. A [`WorkloadSpec`] is
//! several jobs running **concurrently** (each advancing through its own
//! phases), which is what finally stresses the node-type-balancing claim
//! on realistic overlapping application mixes instead of one static
//! pattern at a time.
//!
//! Specs come from three places, uniformly through
//! [`WorkloadSpec::parse`]: named built-ins (`mix`, `allreduce`,
//! `checkpoint`), a `single:<pattern>:<bytes>` one-phase form (the
//! bridge to static-pattern sweep cells, pinned bit-exact by
//! `tests/workload_model.rs`), or a TOML file:
//!
//! ```toml
//! [workload]
//! name = "train-and-checkpoint"
//!
//! [job.train]
//! group  = "type:gpgpu"
//! phases = ["ring-allreduce:4096", "idle:64", "ring-allreduce:4096"]
//!
//! [job.ckpt]
//! group  = "type:compute"
//! phases = ["idle:32", "pattern:c2io-sym:1024"]
//! ```
//!
//! (Job sections are read in name order — the order is cosmetic, since
//! jobs run concurrently; only row/flow ordering follows it.)

use super::collective::{Collective, COLLECTIVE_VOCAB};
use crate::config::Doc;
use crate::nodes::{NodeType, NodeTypeMap, TYPE_VOCAB};
use crate::patterns::{Pattern, PATTERN_VOCAB};
use crate::topology::{Nid, Topology};
use anyhow::{ensure, Context, Result};

/// The accepted group-selector forms (the vocabulary parse errors cite).
pub const GROUP_VOCAB: &str = "all|type:TY|type:TY:N|nids:A-B";

/// The accepted phase forms (the vocabulary parse errors cite).
pub const PHASE_VOCAB: &str = "<collective>:BYTES|pattern:<pattern>:BYTES|idle:TIME";

/// The accepted workload-spec forms (the vocabulary parse errors cite).
pub const WORKLOAD_VOCAB: &str = "mix|allreduce|checkpoint|single:<pattern>:BYTES|FILE.toml";

/// Selects a job's node group from the fabric's type map.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GroupSpec {
    /// Every node of the fabric.
    All,
    /// Every node of one type.
    Type {
        /// The selecting node type.
        ty: NodeType,
    },
    /// The first `count` nodes of one type, in NID order.
    TypeFirst {
        /// The selecting node type.
        ty: NodeType,
        /// How many nodes to take.
        count: usize,
    },
    /// An inclusive NID range.
    Range {
        /// First NID of the range.
        start: Nid,
        /// Last NID of the range (inclusive).
        end: Nid,
    },
}

impl GroupSpec {
    /// Parse a group selector (see [`GROUP_VOCAB`]).
    pub fn parse(s: &str) -> Result<GroupSpec> {
        let bad = |why: &str| {
            anyhow::anyhow!("group {s:?}: {why} (expected one of {GROUP_VOCAB}; types: {TYPE_VOCAB})")
        };
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "all" => Ok(GroupSpec::All),
            "type" => {
                let ty = NodeType::parse(parts.get(1).copied().unwrap_or(""))
                    .ok_or_else(|| bad("bad node type"))?;
                match parts.get(2) {
                    None => Ok(GroupSpec::Type { ty }),
                    Some(c) => {
                        let count: usize = c.parse().map_err(|_| bad("bad count"))?;
                        ensure!(count > 0, bad("count must be > 0"));
                        Ok(GroupSpec::TypeFirst { ty, count })
                    }
                }
            }
            "nids" => {
                let (a, b) = parts
                    .get(1)
                    .and_then(|r| r.split_once('-'))
                    .ok_or_else(|| bad("want nids:A-B"))?;
                let start: Nid = a.parse().map_err(|_| bad("bad range start"))?;
                let end: Nid = b.parse().map_err(|_| bad("bad range end"))?;
                ensure!(start <= end, bad("range start exceeds end"));
                Ok(GroupSpec::Range { start, end })
            }
            _ => Err(bad("unknown selector")),
        }
    }

    /// Canonical spec string (inverse of [`GroupSpec::parse`]).
    pub fn name(&self) -> String {
        match self {
            GroupSpec::All => "all".into(),
            GroupSpec::Type { ty } => format!("type:{ty}"),
            GroupSpec::TypeFirst { ty, count } => format!("type:{ty}:{count}"),
            GroupSpec::Range { start, end } => format!("nids:{start}-{end}"),
        }
    }

    /// Resolve to the concrete member NIDs (ascending, distinct). Errors
    /// when the selection is empty on this fabric — a job over zero
    /// nodes is always a spec/placement mismatch, not a degenerate run.
    pub fn resolve(&self, topo: &Topology, types: &NodeTypeMap) -> Result<Vec<Nid>> {
        let nids = match self {
            GroupSpec::All => (0..topo.num_nodes() as Nid).collect(),
            GroupSpec::Type { ty } => types.nids_of(*ty),
            GroupSpec::TypeFirst { ty, count } => {
                let all = types.nids_of(*ty);
                ensure!(
                    all.len() >= *count,
                    "group {}: only {} {ty} nodes on this fabric",
                    self.name(),
                    all.len()
                );
                all.into_iter().take(*count).collect()
            }
            GroupSpec::Range { start, end } => {
                ensure!(
                    (*end as usize) < topo.num_nodes(),
                    "group {}: NID {end} outside the fabric (0..{})",
                    self.name(),
                    topo.num_nodes()
                );
                (*start..=*end).collect()
            }
        };
        ensure!(
            !nids.is_empty(),
            "group {} selects no nodes on this fabric (placement census: {})",
            self.name(),
            types.census()
        );
        Ok(nids)
    }
}

/// One phase of a job's lifetime.
#[derive(Clone, Debug, PartialEq)]
pub enum Phase {
    /// Run a collective over the job's group with a per-member payload.
    Collective {
        /// The collective operation.
        op: Collective,
        /// Per-member payload in bytes.
        bytes: u64,
    },
    /// A traffic burst: the pattern's flows restricted to sources inside
    /// the job's group, each flow moving `bytes`.
    Traffic {
        /// The traffic pattern.
        pattern: Pattern,
        /// Per-flow volume in bytes.
        bytes: u64,
    },
    /// Compute/sleep: the job injects nothing for `time` units (time is
    /// measured in bytes-at-unit-link-capacity, the fair-rate scale).
    Idle {
        /// Idle duration.
        time: f64,
    },
}

impl Phase {
    /// Parse a phase spec (see [`PHASE_VOCAB`]).
    pub fn parse(s: &str) -> Result<Phase> {
        let vocab = || {
            format!(
                "(expected one of {PHASE_VOCAB}; collectives: {COLLECTIVE_VOCAB}; \
                 patterns: {PATTERN_VOCAB})"
            )
        };
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "idle" => {
                let time: f64 = parts
                    .get(1)
                    .with_context(|| format!("phase {s:?}: missing idle time {}", vocab()))?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("phase {s:?}: bad idle time ({e})"))?;
                ensure!(time > 0.0 && time.is_finite(), "phase {s:?}: idle time must be > 0");
                Ok(Phase::Idle { time })
            }
            "pattern" => {
                ensure!(parts.len() >= 3, "phase {s:?}: want pattern:<pattern>:BYTES {}", vocab());
                let bytes = parse_bytes(s, parts[parts.len() - 1])?;
                let pattern = Pattern::parse(&parts[1..parts.len() - 1].join(":"))?;
                Ok(Phase::Traffic { pattern, bytes })
            }
            _ => {
                let op = Collective::parse(parts[0])
                    .map_err(|_| anyhow::anyhow!("unknown phase {s:?} {}", vocab()))?;
                let bytes = parse_bytes(
                    s,
                    parts.get(1).copied().with_context(|| {
                        format!("phase {s:?}: missing collective payload bytes {}", vocab())
                    })?,
                )?;
                Ok(Phase::Collective { op, bytes })
            }
        }
    }

    /// Canonical spec string (inverse of [`Phase::parse`]).
    pub fn name(&self) -> String {
        match self {
            Phase::Collective { op, bytes } => format!("{}:{bytes}", op.name()),
            Phase::Traffic { pattern, bytes } => format!("pattern:{}:{bytes}", pattern.name()),
            Phase::Idle { time } => format!("idle:{time}"),
        }
    }
}

fn parse_bytes(spec: &str, s: &str) -> Result<u64> {
    let bytes: u64 =
        s.parse().map_err(|e| anyhow::anyhow!("phase {spec:?}: bad byte volume {s:?} ({e})"))?;
    ensure!(bytes >= 1, "phase {spec:?}: byte volume must be >= 1");
    Ok(bytes)
}

/// One application job: a node group advancing through its phases.
#[derive(Clone, Debug, PartialEq)]
pub struct Job {
    /// Job name (rows and phase records cite it).
    pub name: String,
    /// The node group the job runs on.
    pub group: GroupSpec,
    /// The job's phase sequence, executed in order.
    pub phases: Vec<Phase>,
}

/// A multi-job application workload: every job starts at time zero and
/// runs concurrently with the others.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    /// Workload name (result rows cite it).
    pub name: String,
    /// The concurrent jobs.
    pub jobs: Vec<Job>,
}

impl WorkloadSpec {
    /// Reject structurally empty specs with a clear message.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.jobs.is_empty(), "workload {:?} has no jobs", self.name);
        for job in &self.jobs {
            ensure!(
                !job.phases.is_empty(),
                "workload {:?}: job {:?} has no phases",
                self.name,
                job.name
            );
        }
        Ok(())
    }

    /// Parse a workload selector (see [`WORKLOAD_VOCAB`]): a named
    /// built-in, the `single:<pattern>:BYTES` one-phase form, or a
    /// `.toml` file path ([`WorkloadSpec::from_file`]).
    pub fn parse(s: &str) -> Result<WorkloadSpec> {
        let spec = match s {
            "mix" => WorkloadSpec::mix(),
            "allreduce" => WorkloadSpec::allreduce(),
            "checkpoint" => WorkloadSpec::checkpoint(),
            _ => {
                if let Some(rest) = s.strip_prefix("single:") {
                    let parts: Vec<&str> = rest.split(':').collect();
                    ensure!(
                        parts.len() >= 2,
                        "workload {s:?}: want single:<pattern>:BYTES \
                         (patterns: {PATTERN_VOCAB})"
                    );
                    let bytes = parse_bytes(s, parts[parts.len() - 1])?;
                    let pattern = Pattern::parse(&parts[..parts.len() - 1].join(":"))?;
                    WorkloadSpec {
                        // The volume is part of the name: axis entries
                        // differing only in bytes must stay
                        // distinguishable in the `workload` CSV column.
                        name: format!("single-{}-{bytes}", pattern.name()),
                        jobs: vec![Job {
                            name: "main".into(),
                            group: GroupSpec::All,
                            phases: vec![Phase::Traffic { pattern, bytes }],
                        }],
                    }
                } else if s.ends_with(".toml") {
                    WorkloadSpec::from_file(s)?
                } else {
                    anyhow::bail!(
                        "unknown workload {s:?} (expected one of {WORKLOAD_VOCAB})"
                    );
                }
            }
        };
        spec.validate()?;
        Ok(spec)
    }

    /// The overlapping {GPGPU allreduce + compute→IO checkpoint} job mix
    /// — the workload-level restatement of the paper's premise (node
    /// types predict traffic), and the acceptance scenario of
    /// `tests/workload_model.rs`. Needs a placement with `gpgpu`, `io`
    /// and `compute` nodes (e.g. `io:last:1,gpgpu:first:2`).
    ///
    /// The volumes are chosen so the type-crossing checkpoint dominates
    /// the mix — the regime the paper's claim is about. (The intra-group
    /// allreduce ring is a group-local permutation both routers serve at
    /// full rate in isolation; grouped routing pays off on the
    /// compute→IO collection, where dmodk funnels everything through
    /// `W_h` top ports.)
    pub fn mix() -> WorkloadSpec {
        WorkloadSpec {
            name: "mix".into(),
            jobs: vec![
                Job {
                    name: "ckpt".into(),
                    group: GroupSpec::Type { ty: NodeType::Compute },
                    phases: vec![
                        Phase::Idle { time: 32.0 },
                        Phase::Traffic { pattern: Pattern::C2ioSym, bytes: 4096 },
                    ],
                },
                Job {
                    name: "train".into(),
                    group: GroupSpec::Type { ty: NodeType::Gpgpu },
                    phases: vec![
                        Phase::Collective { op: Collective::RingAllreduce, bytes: 2048 },
                        Phase::Idle { time: 64.0 },
                        Phase::Collective { op: Collective::RingAllreduce, bytes: 2048 },
                    ],
                },
            ],
        }
    }

    /// A lone GPGPU training job: two ring-allreduce iterations split by
    /// a compute gap.
    pub fn allreduce() -> WorkloadSpec {
        WorkloadSpec { name: "allreduce".into(), jobs: vec![WorkloadSpec::mix().jobs.remove(1)] }
    }

    /// A lone compute→IO checkpoint burst after a compute gap.
    pub fn checkpoint() -> WorkloadSpec {
        WorkloadSpec { name: "checkpoint".into(), jobs: vec![WorkloadSpec::mix().jobs.remove(0)] }
    }

    /// Parse from a config [`Doc`]: an optional `[workload]` section
    /// (`name = "..."`) plus one `[job.NAME]` section per job with
    /// `group` and `phases` keys (see the module docs for an example).
    /// Jobs are read in section-name order.
    pub fn from_doc(doc: &Doc) -> Result<WorkloadSpec> {
        let name = doc.get_str("workload", "name", "workload")?;
        let mut jobs = Vec::new();
        for (section, keys) in &doc.sections {
            if section == "workload" {
                for key in keys.keys() {
                    ensure!(key == "name", "unknown [workload] key {key:?} (known: [\"name\"])");
                }
                continue;
            }
            let job_name = section.strip_prefix("job.").with_context(|| {
                format!(
                    "unexpected section [{section}] in a workload config \
                     (want [workload] and [job.NAME] sections)"
                )
            })?;
            ensure!(!job_name.is_empty(), "empty job name in section [{section}]");
            for key in keys.keys() {
                ensure!(
                    key == "group" || key == "phases",
                    "unknown [job.{job_name}] key {key:?} (known: [\"group\", \"phases\"])"
                );
            }
            let group = GroupSpec::parse(&doc.get_str(section, "group", "")?)
                .with_context(|| format!("[job.{job_name}] group"))?;
            let phases = doc
                .get(section, "phases")
                .with_context(|| format!("[job.{job_name}] is missing phases = [...]"))?
                .as_str_array()?
                .iter()
                .map(|p| Phase::parse(p))
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("[job.{job_name}] phases"))?;
            jobs.push(Job { name: job_name.to_string(), group, phases });
        }
        let spec = WorkloadSpec { name, jobs };
        spec.validate()?;
        Ok(spec)
    }

    /// Read and parse a workload config file (see [`WorkloadSpec::from_doc`]).
    pub fn from_file(path: &str) -> Result<WorkloadSpec> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        Self::from_doc(&Doc::parse(&text)?).with_context(|| format!("workload config {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::topology::{build_pgft, PgftSpec};

    fn fabric() -> (Topology, NodeTypeMap) {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::parse("io:last:1,gpgpu:first:2").unwrap().apply(&topo).unwrap();
        (topo, types)
    }

    #[test]
    fn group_parse_resolve_roundtrip() {
        let (topo, types) = fabric();
        for (spec, len) in [("all", 64), ("type:gpgpu", 16), ("type:compute:8", 8), ("nids:0-7", 8)]
        {
            let g = GroupSpec::parse(spec).unwrap();
            assert_eq!(g.name(), spec);
            assert_eq!(g.resolve(&topo, &types).unwrap().len(), len, "{spec}");
        }
        // Errors enumerate the vocabulary.
        let err = GroupSpec::parse("leaf:3").unwrap_err().to_string();
        assert!(err.contains("type:TY") && err.contains("gpgpu"), "{err}");
        assert!(GroupSpec::parse("nids:9-3").is_err());
        assert!(GroupSpec::parse("type:warp").is_err());
        // Empty selections are spec errors, not degenerate runs.
        assert!(GroupSpec::Type { ty: NodeType::Fpga }.resolve(&topo, &types).is_err());
        assert!(GroupSpec::parse("nids:0-64").unwrap().resolve(&topo, &types).is_err());
        assert!(GroupSpec::parse("type:gpgpu:99").unwrap().resolve(&topo, &types).is_err());
    }

    #[test]
    fn phase_parse_roundtrip_and_vocab() {
        for spec in ["ring-allreduce:4096", "pattern:c2io-sym:1024", "pattern:shift:3:64", "idle:12.5"]
        {
            let p = Phase::parse(spec).unwrap();
            assert_eq!(Phase::parse(&p.name()).unwrap(), p, "{spec}");
        }
        assert_eq!(
            Phase::parse("pattern:shift:3:64").unwrap(),
            Phase::Traffic { pattern: Pattern::Shift { k: 3 }, bytes: 64 }
        );
        let err = Phase::parse("allgatherv:64").unwrap_err().to_string();
        assert!(
            err.contains("idle:TIME") && err.contains("rd-allreduce") && err.contains("shift:K"),
            "full vocabulary must be enumerated: {err}"
        );
        assert!(Phase::parse("idle:0").is_err());
        assert!(Phase::parse("idle:nan").is_err());
        assert!(Phase::parse("ring-allreduce:0").is_err());
        assert!(Phase::parse("pattern:c2io-sym").is_err());
    }

    #[test]
    fn builtins_validate_and_resolve() {
        let (topo, types) = fabric();
        for name in ["mix", "allreduce", "checkpoint"] {
            let w = WorkloadSpec::parse(name).unwrap();
            assert_eq!(w.name, name);
            for job in &w.jobs {
                assert!(!job.group.resolve(&topo, &types).unwrap().is_empty());
            }
        }
        assert_eq!(WorkloadSpec::mix().jobs.len(), 2);
        let single = WorkloadSpec::parse("single:c2io-sym:1024").unwrap();
        assert_eq!(single.jobs.len(), 1);
        assert_eq!(
            single.jobs[0].phases,
            vec![Phase::Traffic { pattern: Pattern::C2ioSym, bytes: 1024 }]
        );
        let err = WorkloadSpec::parse("frobnicate").unwrap_err().to_string();
        assert!(err.contains("mix") && err.contains("single:"), "{err}");
    }

    #[test]
    fn toml_roundtrip_and_unknown_keys() {
        let doc = Doc::parse(
            r#"
[workload]
name = "demo"
[job.b-train]
group  = "type:gpgpu"
phases = ["rd-allreduce:256", "idle:8"]
[job.a-ckpt]
group  = "type:compute"
phases = ["pattern:c2io-sym:64"]
"#,
        )
        .unwrap();
        let w = WorkloadSpec::from_doc(&doc).unwrap();
        assert_eq!(w.name, "demo");
        // Section-name order (BTreeMap): a-ckpt before b-train.
        assert_eq!(w.jobs[0].name, "a-ckpt");
        assert_eq!(w.jobs[1].name, "b-train");
        assert_eq!(w.jobs[1].phases.len(), 2);

        assert!(WorkloadSpec::from_doc(&Doc::parse("[job.x]\ngroup = \"all\"\n").unwrap())
            .is_err(), "missing phases");
        assert!(WorkloadSpec::from_doc(
            &Doc::parse("[job.x]\ngroup = \"all\"\nphases = [\"idle:1\"]\nfoo = 1\n").unwrap()
        )
        .is_err(), "unknown job key");
        assert!(WorkloadSpec::from_doc(&Doc::parse("[sweep]\nseeds = [1]\n").unwrap()).is_err());
        assert!(WorkloadSpec::from_doc(&Doc::parse("").unwrap()).is_err(), "no jobs");
    }
}
