//! MPI-style collective operations compiled into per-step flow lists.
//!
//! A collective is a *schedule*: an ordered sequence of bulk-synchronous
//! steps, each a list of `(src, dst)` flows over an arbitrary node group
//! with a uniform per-flow byte volume. The compiled form is exactly
//! what the workload lowering ([`crate::workload::compile`]) consumes —
//! one [`crate::eval::FlowSet`] per step — so collective traffic flows
//! through the same evaluator stack as any static pattern.
//!
//! Shipped algorithms (the textbook forms; `n` = group size, `bytes` =
//! per-member payload):
//!
//! | collective         | steps          | per-flow bytes | total volume        |
//! |--------------------|----------------|----------------|---------------------|
//! | `ring-allreduce`   | `2(n−1)`       | `bytes/n`      | `2(n−1)·bytes`      |
//! | `rd-allreduce`     | `log₂ n`       | `bytes`        | `n·log₂ n·bytes`    |
//! | `binomial-bcast`   | `⌈log₂ n⌉`     | `bytes`        | `(n−1)·bytes`       |
//! | `pairwise-a2a`     | `n−1`          | `bytes/n`      | `(n−1)·bytes`       |
//! | `gather`           | `1`            | `bytes`        | `(n−1)·bytes`       |
//!
//! Invariants pinned by `tests/workload_model.rs`: schedules conserve
//! the closed-form total volume, every group member participates, each
//! ring step is the intra-group shift-by-one permutation, and
//! recursive doubling runs exactly `log₂ n` perfect-matching steps on
//! power-of-two groups.

use crate::topology::Nid;
use anyhow::{ensure, Result};

/// The accepted collective names (the vocabulary parse errors cite).
pub const COLLECTIVE_VOCAB: &str =
    "ring-allreduce|rd-allreduce|binomial-bcast|pairwise-a2a|gather";

/// One MPI-style collective operation over a node group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Collective {
    /// Ring allreduce: reduce-scatter then allgather around the group
    /// ring — `2(n−1)` shift-by-one steps of `bytes/n` chunks (the
    /// bandwidth-optimal large-message algorithm).
    RingAllreduce,
    /// Recursive-doubling allreduce: `log₂ n` butterfly exchange steps,
    /// full payload per step (latency-optimal; power-of-two groups only).
    RecursiveDoublingAllreduce,
    /// Binomial-tree broadcast from the group's first member: the set of
    /// informed members doubles each step.
    BinomialBroadcast,
    /// Pairwise-exchange all-to-all: step `s` sends each member's chunk
    /// to the peer `s` positions around the group ring.
    PairwiseAllToAll,
    /// Single-step gather: every member sends its payload to the group's
    /// first member (incast).
    GatherToRoot,
}

/// One bulk-synchronous step of a compiled collective: concurrent flows,
/// all carrying the same byte volume.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectiveStep {
    /// Concurrent `(src, dst)` flows of this step (no self-flows).
    pub flows: Vec<(Nid, Nid)>,
    /// Bytes each flow moves in this step.
    pub bytes_per_flow: f64,
}

impl Collective {
    /// Parse a collective name (see [`COLLECTIVE_VOCAB`]).
    pub fn parse(s: &str) -> Result<Collective> {
        Ok(match s {
            "ring-allreduce" => Collective::RingAllreduce,
            "rd-allreduce" => Collective::RecursiveDoublingAllreduce,
            "binomial-bcast" => Collective::BinomialBroadcast,
            "pairwise-a2a" => Collective::PairwiseAllToAll,
            "gather" => Collective::GatherToRoot,
            other => anyhow::bail!(
                "unknown collective {other:?} (expected one of {COLLECTIVE_VOCAB})"
            ),
        })
    }

    /// Canonical name (inverse of [`Collective::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Collective::RingAllreduce => "ring-allreduce",
            Collective::RecursiveDoublingAllreduce => "rd-allreduce",
            Collective::BinomialBroadcast => "binomial-bcast",
            Collective::PairwiseAllToAll => "pairwise-a2a",
            Collective::GatherToRoot => "gather",
        }
    }

    /// Closed-form total byte volume the schedule moves (the figure the
    /// volume-conservation property test checks the compiled steps
    /// against).
    pub fn total_bytes(&self, n: usize, bytes: u64) -> f64 {
        let (n, b) = (n as f64, bytes as f64);
        match self {
            Collective::RingAllreduce => 2.0 * (n - 1.0) * n * (b / n),
            Collective::RecursiveDoublingAllreduce => (n.log2().round()) * n * b,
            Collective::BinomialBroadcast => (n - 1.0) * b,
            Collective::PairwiseAllToAll => (n - 1.0) * n * (b / n),
            Collective::GatherToRoot => (n - 1.0) * b,
        }
    }

    /// Compile the collective over `group` (distinct NIDs, ≥ 2 members)
    /// with a per-member payload of `bytes` into its step schedule.
    /// Member *indices* drive the algorithms, so the same schedule shape
    /// lands on whatever NIDs the group resolution selected.
    pub fn schedule(&self, group: &[Nid], bytes: u64) -> Result<Vec<CollectiveStep>> {
        let n = group.len();
        ensure!(n >= 2, "collective {} needs a group of >= 2 nodes, got {n}", self.name());
        ensure!(bytes >= 1, "collective {}: payload must be >= 1 byte", self.name());
        {
            let mut sorted = group.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            ensure!(sorted.len() == n, "collective {}: group has duplicate NIDs", self.name());
        }
        let chunk = bytes as f64 / n as f64;
        let full = bytes as f64;
        let steps = match self {
            Collective::RingAllreduce => {
                // Reduce-scatter + allgather: 2(n−1) identical ring
                // shifts of one chunk (which chunk rotates is a payload
                // detail; the flow shape is the shift-by-one pattern).
                let shift: Vec<(Nid, Nid)> =
                    (0..n).map(|i| (group[i], group[(i + 1) % n])).collect();
                (0..2 * (n - 1))
                    .map(|_| CollectiveStep { flows: shift.clone(), bytes_per_flow: chunk })
                    .collect()
            }
            Collective::RecursiveDoublingAllreduce => {
                ensure!(
                    n.is_power_of_two(),
                    "rd-allreduce needs a power-of-two group, got {n} members \
                     (use ring-allreduce for arbitrary group sizes)"
                );
                (0..n.trailing_zeros())
                    .map(|s| CollectiveStep {
                        flows: (0..n).map(|i| (group[i], group[i ^ (1 << s)])).collect(),
                        bytes_per_flow: full,
                    })
                    .collect()
            }
            Collective::BinomialBroadcast => {
                let mut steps = Vec::new();
                let mut informed = 1usize;
                while informed < n {
                    let flows: Vec<(Nid, Nid)> = (0..informed)
                        .filter(|i| i + informed < n)
                        .map(|i| (group[i], group[i + informed]))
                        .collect();
                    steps.push(CollectiveStep { flows, bytes_per_flow: full });
                    informed *= 2;
                }
                steps
            }
            Collective::PairwiseAllToAll => (1..n)
                .map(|s| CollectiveStep {
                    flows: (0..n).map(|i| (group[i], group[(i + s) % n])).collect(),
                    bytes_per_flow: chunk,
                })
                .collect(),
            Collective::GatherToRoot => vec![CollectiveStep {
                flows: (1..n).map(|i| (group[i], group[0])).collect(),
                bytes_per_flow: full,
            }],
        };
        debug_assert!(
            steps.iter().all(|st| st.flows.iter().all(|&(s, d)| s != d)),
            "collective schedules never emit self-flows"
        );
        Ok(steps)
    }
}

impl std::fmt::Display for Collective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [Collective; 5] = [
        Collective::RingAllreduce,
        Collective::RecursiveDoublingAllreduce,
        Collective::BinomialBroadcast,
        Collective::PairwiseAllToAll,
        Collective::GatherToRoot,
    ];

    #[test]
    fn parse_roundtrip_and_vocab_in_errors() {
        for c in ALL {
            assert_eq!(Collective::parse(c.name()).unwrap(), c);
        }
        let err = Collective::parse("allgatherv").unwrap_err().to_string();
        assert!(err.contains("ring-allreduce") && err.contains("gather"), "{err}");
    }

    #[test]
    fn ring_steps_are_shift_by_one() {
        let group = [3u32, 7, 11, 20];
        let steps = Collective::RingAllreduce.schedule(&group, 400).unwrap();
        assert_eq!(steps.len(), 2 * 3);
        for st in &steps {
            assert_eq!(st.flows, vec![(3, 7), (7, 11), (11, 20), (20, 3)]);
            assert!((st.bytes_per_flow - 100.0).abs() < 1e-12);
        }
    }

    #[test]
    fn recursive_doubling_is_log2_perfect_matchings() {
        let group: Vec<u32> = (0..8).map(|i| i * 5).collect();
        let steps = Collective::RecursiveDoublingAllreduce.schedule(&group, 64).unwrap();
        assert_eq!(steps.len(), 3);
        for st in &steps {
            assert_eq!(st.flows.len(), 8);
            let mut srcs: Vec<u32> = st.flows.iter().map(|f| f.0).collect();
            let mut dsts: Vec<u32> = st.flows.iter().map(|f| f.1).collect();
            srcs.sort_unstable();
            dsts.sort_unstable();
            assert_eq!(srcs, group, "every member sends each step");
            assert_eq!(dsts, group, "every member receives each step");
        }
        // Non-power-of-two groups are rejected with a pointer to ring.
        let err = Collective::RecursiveDoublingAllreduce
            .schedule(&[1, 2, 3], 64)
            .unwrap_err()
            .to_string();
        assert!(err.contains("power-of-two") && err.contains("ring-allreduce"), "{err}");
    }

    #[test]
    fn volume_conservation_closed_forms() {
        let group: Vec<u32> = (0..16).collect();
        for c in ALL {
            let steps = c.schedule(&group, 1 << 20).unwrap();
            let moved: f64 =
                steps.iter().map(|s| s.flows.len() as f64 * s.bytes_per_flow).sum();
            let want = c.total_bytes(group.len(), 1 << 20);
            assert!(
                (moved - want).abs() < 1e-6 * want,
                "{c}: moved {moved}, closed form {want}"
            );
        }
    }

    #[test]
    fn broadcast_informs_everyone_once() {
        let group: Vec<u32> = (0..11).collect();
        let steps = Collective::BinomialBroadcast.schedule(&group, 9).unwrap();
        assert_eq!(steps.len(), 4, "ceil(log2 11)");
        let mut dsts: Vec<u32> = steps.iter().flat_map(|s| s.flows.iter().map(|f| f.1)).collect();
        dsts.sort_unstable();
        assert_eq!(dsts, (1..11).collect::<Vec<u32>>(), "each non-root informed exactly once");
    }

    #[test]
    fn degenerate_groups_are_rejected() {
        for c in ALL {
            assert!(c.schedule(&[5], 64).is_err(), "{c}: singleton group");
            assert!(c.schedule(&[1, 2, 2, 4], 64).is_err(), "{c}: duplicate NIDs");
            assert!(c.schedule(&[1, 2], 0).is_err(), "{c}: zero payload");
        }
    }
}
