//! Generic result tables with text / CSV / JSON emitters — every bench
//! and CLI command reports through this so EXPERIMENTS.md can quote
//! machine-readable output.

use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// A rectangular result table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display<T: std::fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v)
    }

    /// Aligned fixed-width text.
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "# {}", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// JSON: array of objects keyed by header.
    pub fn to_json(&self) -> String {
        let esc = |s: &str| {
            let mut o = String::new();
            for c in s.chars() {
                match c {
                    '"' => o.push_str("\\\""),
                    '\\' => o.push_str("\\\\"),
                    '\n' => o.push_str("\\n"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(o, "\\u{:04x}", c as u32);
                    }
                    c => o.push(c),
                }
            }
            o
        };
        // Numbers stay unquoted when they parse as f64 and aren't empty.
        let cell = |s: &str| {
            if !s.is_empty() && s.parse::<f64>().is_ok() {
                s.to_string()
            } else {
                format!("\"{}\"", esc(s))
            }
        };
        let mut out = String::from("[");
        for (ri, row) in self.rows.iter().enumerate() {
            if ri > 0 {
                out.push(',');
            }
            out.push_str("\n  {");
            for (i, h) in self.headers.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                let _ = write!(out, "\"{}\": {}", esc(h), cell(&row[i]));
            }
            out.push('}');
        }
        out.push_str("\n]\n");
        out
    }

    pub fn write(&self, path: impl AsRef<Path>, format: &str) -> Result<()> {
        let body = match format {
            "csv" => self.to_csv(),
            "json" => self.to_json(),
            _ => self.to_text(),
        };
        std::fs::write(path.as_ref(), body)
            .with_context(|| format!("write {}", path.as_ref().display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["algo", "C_topo", "note"]);
        t.row_display(&["dmodk", "4", "two hot ports"]);
        t.row_display(&["gdmodk", "1", "optimal, \"quoted\""]);
        t
    }

    #[test]
    fn text_aligns() {
        let s = sample().to_text();
        assert!(s.contains("# demo"));
        assert!(s.contains("dmodk"));
        assert_eq!(s.lines().count(), 5);
    }

    #[test]
    fn csv_escapes() {
        let s = sample().to_csv();
        assert!(s.starts_with("algo,C_topo,note"));
        assert!(s.contains("\"optimal, \"\"quoted\"\"\""));
    }

    #[test]
    fn json_types() {
        let s = sample().to_json();
        assert!(s.contains("\"C_topo\": 4"), "{s}");
        assert!(s.contains("\"algo\": \"dmodk\""));
        assert!(s.trim_end().ends_with(']'));
    }

    #[test]
    fn write_roundtrip() {
        let t = sample();
        let dir = std::env::temp_dir().join("pgft_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        for fmt in ["text", "csv", "json"] {
            let p = dir.join(format!("t.{fmt}"));
            t.write(&p, fmt).unwrap();
            assert!(std::fs::read_to_string(&p).unwrap().contains("dmodk"));
        }
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        Table::new("x", &["a", "b"]).row(&["only-one".into()]);
    }
}
