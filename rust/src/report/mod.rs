//! Generic result tables with text / CSV / JSON emitters — every bench
//! and CLI command reports through this so EXPERIMENTS.md can quote
//! machine-readable output. The CSV and JSON forms also parse back
//! ([`Table::from_csv`] / [`Table::from_json`]), which is what lets
//! sweep results round-trip through files.

use anyhow::{ensure, Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// A rectangular result table.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Table {
    /// Heading printed above the text rendering (not part of CSV/JSON).
    pub title: String,
    /// Column names.
    pub headers: Vec<String>,
    /// Row-major cells; every row is as wide as `headers`.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table with the given title and column names.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a row of anything displayable.
    pub fn row_display<T: std::fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v)
    }

    /// Aligned fixed-width text.
    pub fn to_text(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "# {}", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// RFC-4180-style CSV: header line + rows; cells containing commas
    /// or quotes are quoted with doubled inner quotes.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// JSON: array of objects keyed by header.
    pub fn to_json(&self) -> String {
        let esc = |s: &str| {
            let mut o = String::new();
            for c in s.chars() {
                match c {
                    '"' => o.push_str("\\\""),
                    '\\' => o.push_str("\\\\"),
                    '\n' => o.push_str("\\n"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(o, "\\u{:04x}", c as u32);
                    }
                    c => o.push(c),
                }
            }
            o
        };
        // Numbers stay unquoted only when the cell is a token JSON's
        // number grammar accepts (Rust's f64 parser is laxer: "inf",
        // "NaN", "+4", ".5" and "1." all parse but are not JSON).
        let cell = |s: &str| {
            if is_json_number(s) {
                s.to_string()
            } else {
                format!("\"{}\"", esc(s))
            }
        };
        let mut out = String::from("[");
        for (ri, row) in self.rows.iter().enumerate() {
            if ri > 0 {
                out.push(',');
            }
            out.push_str("\n  {");
            for (i, h) in self.headers.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                let _ = write!(out, "\"{}\": {}", esc(h), cell(&row[i]));
            }
            out.push('}');
        }
        out.push_str("\n]\n");
        out
    }

    /// Write the table to a file in the given format (`csv`, `json`, or
    /// anything else for aligned text).
    pub fn write(&self, path: impl AsRef<Path>, format: &str) -> Result<()> {
        let body = match format {
            "csv" => self.to_csv(),
            "json" => self.to_json(),
            _ => self.to_text(),
        };
        std::fs::write(path.as_ref(), body)
            .with_context(|| format!("write {}", path.as_ref().display()))
    }

    /// Parse the CSV this module emits: a header line followed by data
    /// rows; quoted cells may contain commas and doubled quotes. Cells
    /// never span lines (the emitter never produces embedded newlines).
    /// The title is not representable in CSV and comes back empty.
    pub fn from_csv(text: &str) -> Result<Table> {
        let mut lines = text.lines();
        let header_line = lines.next().context("empty CSV input")?;
        let headers = parse_csv_record(header_line)?;
        let mut t = Table { title: String::new(), headers, rows: Vec::new() };
        for line in lines {
            // An empty line is noise for multi-column tables, but for a
            // single-column table it is a legitimate row holding one
            // empty cell (the round-trip of `[""]`).
            if line.is_empty() && t.headers.len() != 1 {
                continue;
            }
            let cells = parse_csv_record(line)?;
            ensure!(
                cells.len() == t.headers.len(),
                "CSV row has {} cells, header has {}: {line:?}",
                cells.len(),
                t.headers.len()
            );
            t.rows.push(cells);
        }
        Ok(t)
    }

    /// Parse the JSON array-of-flat-objects form [`Table::to_json`]
    /// emits. Headers are taken from the first object's keys (so at
    /// least one row is required), and unquoted number cells keep their
    /// literal text — `from_json(to_json(t))` reproduces the original
    /// cell strings byte-for-byte. The title comes back empty.
    pub fn from_json(text: &str) -> Result<Table> {
        let mut p = JsonParser { s: text.as_bytes(), i: 0 };
        let mut headers: Vec<String> = Vec::new();
        let mut rows: Vec<Vec<String>> = Vec::new();
        p.skip_ws();
        p.expect(b'[')?;
        p.skip_ws();
        if !p.eat(b']') {
            loop {
                p.skip_ws();
                p.expect(b'{')?;
                let mut keys = Vec::new();
                let mut cells = Vec::new();
                p.skip_ws();
                if !p.eat(b'}') {
                    loop {
                        p.skip_ws();
                        keys.push(p.string()?);
                        p.skip_ws();
                        p.expect(b':')?;
                        p.skip_ws();
                        cells.push(p.value()?);
                        p.skip_ws();
                        if p.eat(b',') {
                            continue;
                        }
                        p.expect(b'}')?;
                        break;
                    }
                }
                if headers.is_empty() {
                    headers = keys;
                } else {
                    ensure!(keys == headers, "object keys {keys:?} != headers {headers:?}");
                }
                rows.push(cells);
                p.skip_ws();
                if p.eat(b',') {
                    continue;
                }
                p.expect(b']')?;
                break;
            }
        }
        ensure!(!headers.is_empty(), "empty JSON table: headers live in the rows");
        Ok(Table { title: String::new(), headers, rows })
    }
}

/// Whether `s` matches JSON's number grammar exactly
/// (`-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?`).
fn is_json_number(s: &str) -> bool {
    let b = s.as_bytes();
    let mut i = 0;
    if b.first() == Some(&b'-') {
        i += 1;
    }
    match b.get(i) {
        Some(b'0') => i += 1,
        Some(c) if c.is_ascii_digit() => {
            while i < b.len() && b[i].is_ascii_digit() {
                i += 1;
            }
        }
        _ => return false,
    }
    if b.get(i) == Some(&b'.') {
        i += 1;
        let start = i;
        while i < b.len() && b[i].is_ascii_digit() {
            i += 1;
        }
        if i == start {
            return false;
        }
    }
    if matches!(b.get(i), Some(b'e') | Some(b'E')) {
        i += 1;
        if matches!(b.get(i), Some(b'+') | Some(b'-')) {
            i += 1;
        }
        let start = i;
        while i < b.len() && b[i].is_ascii_digit() {
            i += 1;
        }
        if i == start {
            return false;
        }
    }
    i == b.len()
}

/// Split one CSV line into unescaped cells.
fn parse_csv_record(line: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        cur.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => cur.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => out.push(std::mem::take(&mut cur)),
                c => cur.push(c),
            }
        }
    }
    ensure!(!in_quotes, "unterminated quoted CSV cell in {line:?}");
    out.push(cur);
    Ok(out)
}

/// Hand-rolled scanner for the JSON subset [`Table::to_json`] emits
/// (arrays of flat objects; string values with the emitter's escapes;
/// raw number tokens kept verbatim).
struct JsonParser<'a> {
    s: &'a [u8],
    i: usize,
}

impl JsonParser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn eat(&mut self, b: u8) -> bool {
        if self.i < self.s.len() && self.s[self.i] == b {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        ensure!(self.eat(b), "expected {:?} at byte {}", b as char, self.i);
        Ok(())
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            ensure!(self.i < self.s.len(), "unterminated JSON string");
            let c = self.s[self.i];
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    ensure!(self.i < self.s.len(), "dangling escape");
                    let e = self.s[self.i];
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'u' => {
                            ensure!(self.i + 4 <= self.s.len(), "short \\u escape");
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).context("bad \\u code point")?);
                            self.i += 4;
                        }
                        other => anyhow::bail!("unsupported escape \\{}", other as char),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Copy a full multi-byte UTF-8 sequence.
                    let start = self.i - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    ensure!(start + len <= self.s.len(), "truncated UTF-8 sequence");
                    out.push_str(std::str::from_utf8(&self.s[start..start + len])?);
                    self.i = start + len;
                }
            }
        }
    }

    /// A cell value: a string, or a raw (number-like) token kept
    /// verbatim so numeric cells round-trip exactly.
    fn value(&mut self) -> Result<String> {
        if self.i < self.s.len() && self.s[self.i] == b'"' {
            return self.string();
        }
        let start = self.i;
        while self.i < self.s.len()
            && !matches!(self.s[self.i], b',' | b'}' | b']')
            && !self.s[self.i].is_ascii_whitespace()
        {
            self.i += 1;
        }
        ensure!(self.i > start, "empty JSON value at byte {start}");
        Ok(std::str::from_utf8(&self.s[start..self.i])?.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["algo", "C_topo", "note"]);
        t.row_display(&["dmodk", "4", "two hot ports"]);
        t.row_display(&["gdmodk", "1", "optimal, \"quoted\""]);
        t
    }

    #[test]
    fn text_aligns() {
        let s = sample().to_text();
        assert!(s.contains("# demo"));
        assert!(s.contains("dmodk"));
        assert_eq!(s.lines().count(), 5);
    }

    #[test]
    fn csv_escapes() {
        let s = sample().to_csv();
        assert!(s.starts_with("algo,C_topo,note"));
        assert!(s.contains("\"optimal, \"\"quoted\"\"\""));
    }

    #[test]
    fn json_types() {
        let s = sample().to_json();
        assert!(s.contains("\"C_topo\": 4"), "{s}");
        assert!(s.contains("\"algo\": \"dmodk\""));
        assert!(s.trim_end().ends_with(']'));
    }

    #[test]
    fn write_roundtrip() {
        let t = sample();
        let dir = std::env::temp_dir().join("pgft_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        for fmt in ["text", "csv", "json"] {
            let p = dir.join(format!("t.{fmt}"));
            t.write(&p, fmt).unwrap();
            assert!(std::fs::read_to_string(&p).unwrap().contains("dmodk"));
        }
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        Table::new("x", &["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn csv_parses_back() {
        let t = sample();
        let p = Table::from_csv(&t.to_csv()).unwrap();
        assert_eq!(p.headers, t.headers);
        assert_eq!(p.rows, t.rows, "quoted commas and doubled quotes survive");
    }

    #[test]
    fn json_parses_back() {
        let t = sample();
        let p = Table::from_json(&t.to_json()).unwrap();
        assert_eq!(p.headers, t.headers);
        assert_eq!(p.rows, t.rows, "number cells keep their literal text");
        // And the re-emitted JSON is byte-identical.
        assert_eq!(p.to_json(), t.to_json());
    }

    #[test]
    fn empty_cells_roundtrip() {
        let mut t = Table::new("", &["a", "b", "c"]);
        t.row_display(&["", "0.5", "x,y"]);
        t.row_display(&["", "", ""]);
        assert_eq!(Table::from_csv(&t.to_csv()).unwrap().rows, t.rows);
        assert_eq!(Table::from_json(&t.to_json()).unwrap().rows, t.rows);
        // Single-column table with an empty cell: the row serializes to
        // an empty CSV line and must not be dropped.
        let mut one = Table::new("", &["only"]);
        one.row_display(&[""]);
        one.row_display(&["x"]);
        assert_eq!(Table::from_csv(&one.to_csv()).unwrap().rows, one.rows);
    }

    #[test]
    fn non_json_numbers_are_quoted() {
        // Rust's f64 parser accepts all of these, but JSON's number
        // grammar only accepts the last four: the rest must be quoted
        // for the emitted document to stay valid JSON — and all of them
        // must round-trip.
        let quoted = ["inf", "NaN", "-inf", "+4", ".5", "1.", "01", "1e"];
        let raw = ["1.5", "-2", "0", "6.02e23"];
        let mut t = Table::new("", &["v"]);
        for v in quoted.iter().chain(raw.iter()) {
            t.row_display(&[*v]);
        }
        let json = t.to_json();
        for v in quoted {
            assert!(json.contains(&format!("\"{v}\"")), "{v} should be quoted in {json}");
        }
        for v in raw {
            assert!(json.contains(&format!(": {v}")), "{v} should be raw in {json}");
        }
        assert_eq!(Table::from_json(&json).unwrap().rows, t.rows);
    }

    #[test]
    fn malformed_inputs_rejected() {
        assert!(Table::from_csv("").is_err());
        assert!(Table::from_csv("a,b\n\"unterminated").is_err());
        assert!(Table::from_csv("a,b\n1,2,3").is_err());
        assert!(Table::from_json("").is_err());
        assert!(Table::from_json("[\n]\n").is_err(), "headers live in the rows");
        assert!(Table::from_json("[{\"a\": 1}, {\"b\": 2}]").is_err(), "key mismatch");
        assert!(Table::from_json("[{\"a\": \"oops]").is_err());
    }
}
