//! Traffic-pattern library (§III).
//!
//! The paper's analysis pattern is **C2IO** — "data collection from all
//! compute nodes to IO nodes". Its §III prose pins a *bijective* reading
//! (each compute node sends to the IO node of its symmetrical leaf, "each
//! destination has exactly one corresponding source"), while the §IV
//! Gdmodk analysis ("all leaves' up-ports have seven sources and two
//! destinations") is only consistent with a *dense* reading (every
//! compute node sends to every IO node of the opposite subgroup). Both
//! are provided — [`Pattern::C2ioSym`] and [`Pattern::C2ioAll`] — and the
//! benches report both (see DESIGN.md §4).
//!
//! Classic worst-case patterns (all-to-all, shift, gather/scatter,
//! permutations, hot-spot) are included for baseline comparisons.

use crate::nodes::{NodeType, NodeTypeMap, TYPE_VOCAB};
use crate::topology::{Endpoint, Nid, Topology};
use crate::util::rng::Xoshiro256;
use anyhow::{ensure, Result};

/// The accepted pattern spellings (the vocabulary parse errors cite —
/// see [`Pattern::parse`] for the semantics of each form).
pub const PATTERN_VOCAB: &str = "c2io-sym|c2io-all|io2c-sym|io2c-all|all-to-all|shift:K|\
    gather:ROOT|scatter:ROOT|randperm:SEED|hotspot:D|biject:SRC:DST|dense:SRC:DST|\
    dense-any:SRC:DST|transpose:<inner>";

/// A communication pattern: a generator of (src, dst) flows.
#[derive(Clone, Debug, PartialEq)]
pub enum Pattern {
    /// Compute→IO, bijective symmetric-leaf reading (§III): the compute
    /// nodes of each leaf send to the IO node(s) of the leaf with the
    /// top-level digit mirrored (`a_h ↦ m_h-1-a_h`), round-robin when a
    /// leaf hosts several IO nodes.
    C2ioSym,
    /// Compute→IO, dense cross-subgroup reading (§IV): every compute node
    /// sends to every IO node whose top-level digit differs.
    C2ioAll,
    /// The symmetrical pattern Q of §IV.B's identities: IO→compute,
    /// bijective reading.
    Io2cSym,
    /// IO→compute, dense cross-subgroup reading.
    Io2cAll,
    /// Generalized bijective type pattern: sources of `src_ty` on each
    /// leaf send to `dst_ty` nodes of the mirrored leaf.
    TypeBiject {
        /// Source node type.
        src_ty: NodeType,
        /// Destination node type.
        dst_ty: NodeType,
    },
    /// Generalized dense type pattern; `cross_top_only` restricts to
    /// flows whose endpoints differ in the top-level digit.
    TypeDense {
        /// Source node type.
        src_ty: NodeType,
        /// Destination node type.
        dst_ty: NodeType,
        /// Keep only flows crossing the top level.
        cross_top_only: bool,
    },
    /// Every node to every other node.
    AllToAll,
    /// Shift permutation: node i → (i + k) mod N (Zahavi's nonblocking
    /// target for Dmodk on real-life fat-trees).
    Shift {
        /// The shift distance.
        k: u32,
    },
    /// All nodes send to `root` (incast).
    Gather {
        /// The collecting node.
        root: Nid,
    },
    /// `root` sends to all nodes (outcast).
    Scatter {
        /// The distributing node.
        root: Nid,
    },
    /// Random permutation (derangement not enforced; self-flows dropped).
    RandPerm {
        /// Shuffle seed.
        seed: u64,
    },
    /// Every node sends to one of `dsts` hot destinations (chosen
    /// round-robin by source).
    HotSpot {
        /// Number of hot destination nodes (NIDs `0..dsts`).
        dsts: u32,
    },
    /// Reverse every flow of the inner pattern (P ↦ its symmetrical Q).
    Transpose(Box<Pattern>),
}

impl Pattern {
    /// Generate the flow list. Patterns touching node types need a type
    /// map; others ignore it.
    pub fn flows(&self, topo: &Topology, types: &NodeTypeMap) -> Result<Vec<(Nid, Nid)>> {
        let n = topo.num_nodes() as Nid;
        let flows = match self {
            Pattern::C2ioSym => {
                Pattern::TypeBiject { src_ty: NodeType::Compute, dst_ty: NodeType::Io }
                    .flows(topo, types)?
            }
            Pattern::C2ioAll => Pattern::TypeDense {
                src_ty: NodeType::Compute,
                dst_ty: NodeType::Io,
                cross_top_only: true,
            }
            .flows(topo, types)?,
            Pattern::Io2cSym => Pattern::Transpose(Box::new(Pattern::C2ioSym)).flows(topo, types)?,
            Pattern::Io2cAll => Pattern::Transpose(Box::new(Pattern::C2ioAll)).flows(topo, types)?,
            Pattern::TypeBiject { src_ty, dst_ty } => {
                let mut out = Vec::new();
                for leaf in topo.level_switches(1) {
                    let srcs = leaf_nodes_of_type(topo, types, leaf, *src_ty);
                    if srcs.is_empty() {
                        continue;
                    }
                    let mirror = mirrored_leaf(topo, leaf);
                    let dsts = leaf_nodes_of_type(topo, types, mirror, *dst_ty);
                    if dsts.is_empty() {
                        continue;
                    }
                    for (i, &s) in srcs.iter().enumerate() {
                        out.push((s, dsts[i % dsts.len()]));
                    }
                }
                out
            }
            Pattern::TypeDense { src_ty, dst_ty, cross_top_only } => {
                let srcs = types.nids_of(*src_ty);
                let dsts = types.nids_of(*dst_ty);
                let mut out = Vec::new();
                for &s in &srcs {
                    let sd = topo.nid_digits(s);
                    for &d in &dsts {
                        if s == d {
                            continue;
                        }
                        if *cross_top_only {
                            let dd = topo.nid_digits(d);
                            if sd[topo.spec.h - 1] == dd[topo.spec.h - 1] {
                                continue;
                            }
                        }
                        out.push((s, d));
                    }
                }
                out
            }
            Pattern::AllToAll => {
                let mut out = Vec::with_capacity(n as usize * (n as usize - 1));
                for s in 0..n {
                    for d in 0..n {
                        if s != d {
                            out.push((s, d));
                        }
                    }
                }
                out
            }
            Pattern::Shift { k } => (0..n).map(|s| (s, (s + k) % n)).filter(|(s, d)| s != d).collect(),
            Pattern::Gather { root } => {
                ensure!(*root < n, "gather root {} out of range", root);
                (0..n).filter(|&s| s != *root).map(|s| (s, *root)).collect()
            }
            Pattern::Scatter { root } => {
                ensure!(*root < n, "scatter root {} out of range", root);
                (0..n).filter(|&d| d != *root).map(|d| (*root, d)).collect()
            }
            Pattern::RandPerm { seed } => {
                let mut perm: Vec<Nid> = (0..n).collect();
                Xoshiro256::new(*seed).shuffle(&mut perm);
                (0..n).map(|s| (s, perm[s as usize])).filter(|(s, d)| s != d).collect()
            }
            Pattern::HotSpot { dsts } => {
                ensure!(*dsts > 0 && *dsts <= n, "hotspot dsts out of range");
                (0..n)
                    .map(|s| (s, s % dsts))
                    .filter(|(s, d)| s != d)
                    .collect()
            }
            Pattern::Transpose(inner) => {
                inner.flows(topo, types)?.into_iter().map(|(s, d)| (d, s)).collect()
            }
        };
        ensure!(!flows.is_empty(), "pattern {} produced no flows", self.name());
        Ok(flows)
    }

    /// Canonical short display name. Parameterless patterns round-trip
    /// through [`Pattern::parse`] verbatim; parameterized ones display
    /// with `-` (`shift-1`) while `parse` takes `:` (`shift:1`).
    pub fn name(&self) -> String {
        match self {
            Pattern::C2ioSym => "c2io-sym".into(),
            Pattern::C2ioAll => "c2io-all".into(),
            Pattern::Io2cSym => "io2c-sym".into(),
            Pattern::Io2cAll => "io2c-all".into(),
            Pattern::TypeBiject { src_ty, dst_ty } => format!("biject-{src_ty}-{dst_ty}"),
            Pattern::TypeDense { src_ty, dst_ty, cross_top_only } => {
                format!("dense-{src_ty}-{dst_ty}{}", if *cross_top_only { "-cross" } else { "" })
            }
            Pattern::AllToAll => "all-to-all".into(),
            Pattern::Shift { k } => format!("shift-{k}"),
            Pattern::Gather { root } => format!("gather-{root}"),
            Pattern::Scatter { root } => format!("scatter-{root}"),
            Pattern::RandPerm { seed } => format!("randperm-{seed}"),
            Pattern::HotSpot { dsts } => format!("hotspot-{dsts}"),
            Pattern::Transpose(p) => format!("transpose({})", p.name()),
        }
    }

    /// Parse CLI forms: `c2io-sym`, `c2io-all`, `io2c-sym`, `io2c-all`,
    /// `all-to-all`, `shift:K`, `gather:ROOT`, `scatter:ROOT`,
    /// `randperm:SEED`, `hotspot:D`, `biject:SRC:DST`, `dense:SRC:DST`,
    /// `transpose:<inner>`.
    pub fn parse(s: &str) -> Result<Pattern> {
        let parts: Vec<&str> = s.split(':').collect();
        let arg = |i: usize| -> Result<u32> {
            parts
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("pattern {s:?}: missing arg {i}"))?
                .parse()
                .map_err(|e| anyhow::anyhow!("pattern {s:?}: {e}"))
        };
        let ty = |i: usize| -> Result<NodeType> {
            NodeType::parse(parts.get(i).copied().unwrap_or("")).ok_or_else(|| {
                anyhow::anyhow!("pattern {s:?}: bad node type at {i} (types: {TYPE_VOCAB})")
            })
        };
        Ok(match parts[0] {
            "c2io-sym" | "c2io" => Pattern::C2ioSym,
            "c2io-all" => Pattern::C2ioAll,
            "io2c-sym" | "io2c" => Pattern::Io2cSym,
            "io2c-all" => Pattern::Io2cAll,
            "all-to-all" | "a2a" => Pattern::AllToAll,
            "shift" => Pattern::Shift { k: arg(1)? },
            "gather" => Pattern::Gather { root: arg(1)? },
            "scatter" => Pattern::Scatter { root: arg(1)? },
            "randperm" => Pattern::RandPerm { seed: arg(1)? as u64 },
            "hotspot" => Pattern::HotSpot { dsts: arg(1)? },
            "biject" => Pattern::TypeBiject { src_ty: ty(1)?, dst_ty: ty(2)? },
            "dense" => Pattern::TypeDense { src_ty: ty(1)?, dst_ty: ty(2)?, cross_top_only: true },
            "dense-any" => {
                Pattern::TypeDense { src_ty: ty(1)?, dst_ty: ty(2)?, cross_top_only: false }
            }
            "transpose" => Pattern::Transpose(Box::new(Pattern::parse(&parts[1..].join(":"))?)),
            other => anyhow::bail!(
                "unknown pattern {other:?} (expected one of {PATTERN_VOCAB}; \
                 node types: {TYPE_VOCAB})"
            ),
        })
    }
}

/// Nodes of a given type on a leaf, ascending NID.
fn leaf_nodes_of_type(
    topo: &Topology,
    types: &NodeTypeMap,
    leaf: usize,
    ty: NodeType,
) -> Vec<Nid> {
    let mut nids: Vec<Nid> = topo.switches[leaf]
        .down_ports
        .iter()
        .filter_map(|&p| match topo.port_peer(p) {
            Endpoint::Node(n) if types.type_of(n) == ty => Some(n),
            _ => None,
        })
        .collect();
    nids.sort_unstable();
    nids.dedup();
    nids
}

/// The leaf with the top-level digit mirrored (`a_h ↦ m_h - 1 - a_h`).
fn mirrored_leaf(topo: &Topology, leaf: usize) -> usize {
    let sw = &topo.switches[leaf];
    debug_assert_eq!(sw.level, 1);
    let mut top = sw.top.clone();
    let h = topo.spec.h;
    if h >= 2 {
        let mh = topo.spec.m[h - 1];
        let last = top.len() - 1;
        top[last] = mh - 1 - top[last];
    }
    topo.switch_at(1, &top, &sw.bottom)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::topology::{build_pgft, PgftSpec};

    fn setup() -> (Topology, NodeTypeMap) {
        let t = build_pgft(&PgftSpec::case_study());
        let m = Placement::paper_io().apply(&t).unwrap();
        (t, m)
    }

    /// "(0,0,1) is symmetrical to (0,1,1), so NIDs 8 to 14 send to NID 47."
    #[test]
    fn c2io_sym_matches_paper_example() {
        let (t, m) = setup();
        let flows = Pattern::C2ioSym.flows(&t, &m).unwrap();
        assert_eq!(flows.len(), 56, "7 computes × 8 leaves");
        for s in 8..15u32 {
            assert!(flows.contains(&(s, 47)), "NID {s} should send to 47");
        }
        // And leaf 5's computes send to leaf 1's IO node (NID 15).
        for s in 40..47u32 {
            assert!(flows.contains(&(s, 15)));
        }
        // All flows cross the top (different subgroup digits).
        for &(s, d) in &flows {
            assert_ne!(t.nid_digits(s)[2], t.nid_digits(d)[2], "{s}->{d} must cross");
        }
        // Each destination has exactly 7 sources.
        for io in [7u32, 15, 23, 31, 39, 47, 55, 63] {
            assert_eq!(flows.iter().filter(|&&(_, d)| d == io).count(), 7);
        }
    }

    #[test]
    fn c2io_all_is_dense_cross_subgroup() {
        let (t, m) = setup();
        let flows = Pattern::C2ioAll.flows(&t, &m).unwrap();
        // 28 computes per subgroup × 4 opposite IO × 2 directions-of-subgroup.
        assert_eq!(flows.len(), 224);
        for &(s, d) in &flows {
            assert_eq!(m.type_of(s), NodeType::Compute);
            assert_eq!(m.type_of(d), NodeType::Io);
            assert_ne!(t.nid_digits(s)[2], t.nid_digits(d)[2]);
        }
    }

    #[test]
    fn transpose_reverses() {
        let (t, m) = setup();
        let p = Pattern::C2ioSym.flows(&t, &m).unwrap();
        let q = Pattern::Io2cSym.flows(&t, &m).unwrap();
        let mut p_rev: Vec<(Nid, Nid)> = p.iter().map(|&(s, d)| (d, s)).collect();
        let mut q2 = q.clone();
        p_rev.sort_unstable();
        q2.sort_unstable();
        assert_eq!(p_rev, q2);
    }

    #[test]
    fn classic_patterns_shapes() {
        let (t, m) = setup();
        assert_eq!(Pattern::AllToAll.flows(&t, &m).unwrap().len(), 64 * 63);
        assert_eq!(Pattern::Shift { k: 8 }.flows(&t, &m).unwrap().len(), 64);
        assert_eq!(Pattern::Gather { root: 7 }.flows(&t, &m).unwrap().len(), 63);
        assert_eq!(Pattern::Scatter { root: 0 }.flows(&t, &m).unwrap().len(), 63);
        let perm = Pattern::RandPerm { seed: 5 }.flows(&t, &m).unwrap();
        let mut dsts: Vec<Nid> = perm.iter().map(|&(_, d)| d).collect();
        dsts.sort_unstable();
        dsts.dedup();
        assert_eq!(dsts.len(), perm.len(), "permutation destinations distinct");
        let hot = Pattern::HotSpot { dsts: 2 }.flows(&t, &m).unwrap();
        assert!(hot.iter().all(|&(_, d)| d < 2));
    }

    #[test]
    fn parse_roundtrip() {
        for s in [
            "c2io-sym", "c2io-all", "io2c-sym", "io2c-all", "all-to-all", "shift:8",
            "gather:7", "scatter:0", "randperm:3", "hotspot:2", "biject:compute:io",
            "dense:compute:io", "transpose:shift:8",
        ] {
            let p = Pattern::parse(s).unwrap_or_else(|e| panic!("{s}: {e}"));
            let (t, m) = setup();
            assert!(!p.flows(&t, &m).unwrap().is_empty(), "{s}");
        }
        // Unknown patterns enumerate the full accepted vocabulary.
        let err = Pattern::parse("warp-drive").unwrap_err().to_string();
        for word in ["c2io-sym", "shift:K", "biject:SRC:DST", "transpose:", "gpgpu"] {
            assert!(err.contains(word), "vocabulary misses {word}: {err}");
        }
        assert!(Pattern::parse("shift").is_err());
        let err = Pattern::parse("biject:warp:io").unwrap_err().to_string();
        assert!(err.contains("compute|io|service"), "type vocabulary cited: {err}");
    }

    #[test]
    fn patterns_with_no_flows_error() {
        let t = build_pgft(&PgftSpec::case_study());
        let uniform = NodeTypeMap::uniform(64, NodeType::Compute);
        assert!(Pattern::C2ioSym.flows(&t, &uniform).is_err(), "no IO nodes → no flows");
    }
}
