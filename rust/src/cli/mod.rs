//! Command-line interface (zero-dep argument parser; `clap` is not in the
//! offline vendor set).
//!
//! ```text
//! pgft topo --topo case-study [--dot] [--leaves] [--placement io:last:1]
//! pgft sweep [--config FILE] [--topo ..] [--placements A;B] [--pattern ..]
//!            [--algo ..] [--faults none,rate:0.05] [--seeds 1,2] [--simulate]
//!            [--serial|--threads N] [--telemetry OUT.json]
//! pgft faults [--topo ..] [--algo ..] [--pattern ..] [--faults SPECS]
//!             [--seeds 1,2] [--simulate] [--format csv] [--out FILE]
//! pgft eval [--topo ..] [--algo ..] [--pattern ..] [--seed N]
//!           [--evaluators congestion,fairrate,netsim:0.3] [--faults SPEC]
//!           [--size 16k|64k|256k]      # large-fabric ladder presets
//! pgft workload [--workload mix,single:c2io-sym:1024|FILE.toml] [--topo ..]
//!               [--placement io:last:1,gpgpu:first:2] [--algo ..] [--seeds 1,2]
//!               [--faults SPEC] [--netsim RATE] [--no-phase-detail]
//! pgft analyze [--topo ..] [--placement ..] [--pattern c2io-sym,c2io-all]
//!              [--algo all|dmodk,...] [--seed N] [--format text|csv|json] [--out FILE]
//! pgft ports --algo dmodk --pattern c2io-sym [--level 3]      # per-port detail (Figs 4-7)
//! pgft random-dist [--trials 1000] [--pattern c2io-sym]       # §III.D histogram
//! pgft simulate [--xla|--no-xla] [--pattern ..] [--algo ..]   # flow-level rates
//! pgft netsim [--rates 0.05,0.1] [--algo ..] [--pattern ..]   # flit-level curves
//!             [--packet-flits 4] [--vcs 2] [--vc-capacity 8] [--link-latency 1]
//!             [--injection bernoulli|burst:K] [--faults SPEC] [--seed N]
//!             [--telemetry OUT.json]   # per-port/VC counters per (algo, pattern)
//! pgft packet-sim [--message 64] [--pattern ..] [--algo ..]   # slot-level sim
//! pgft run --config FILE                                      # full experiment
//! pgft fabric [--algo gdmodk] [--faults cascade:4] [--seed 2] # online service drill
//!             [--burst] [--readers 4] [--query-ms 200]        #  + read load
//!             [--telemetry OUT.json]   # event journal: per-phase repair timings
//! pgft fabric-demo [--algo gdmodk]                            # coordinator + fault drill
//! pgft artifacts                                              # runtime manifest
//! ```

use crate::config::ExperimentConfig;
use crate::coordinator::Coordinator;
use crate::eval::{evaluate_all, parse_evaluators, FlowSet};
use crate::faults::{DegradedRouter, FaultModel, FaultSet, DEFAULT_REACH_BUDGET};
use crate::metrics::{render_algorithm_table, CongestionReport};
use crate::netsim::{
    curve_table, default_rates, load_curve_recorded, saturation_point, CurvePoint, Injection,
    NetsimConfig,
};
use crate::nodes::{NodeTypeMap, Placement};
use crate::patterns::Pattern;
use crate::report::Table;
use crate::routing::trace::trace_flows;
use crate::routing::{AlgorithmKind, Router};
use crate::sim::{render_sim_table, simulate_flow_level, PacketSim, PacketSimConfig};
use crate::sweep::{
    fault_table, run_sweep, run_sweep_with, sweep_table, SweepOptions, SweepResult, SweepSpec,
};
use crate::telemetry::{
    attribute, diff_hotspots, parse_timeseries, summary_table as telemetry_summary_table,
    write_telemetry, write_timeseries, BatchRecord, Hotspot, Recorder, RecorderConfig, Registry,
    RunInfo, Telemetry, TelemetryRun, TraceBuilder, VecKind,
};
use crate::topology::{families, render, ImplicitTopology, Topology, TopologyView};
use crate::workload::{
    evaluate_makespan, evaluate_makespan_traced, lower, WorkloadEval, WorkloadSpec,
};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Flag spellings that mean the same thing. [`Args::get`] resolves a
/// lookup through its group, so every subcommand accepts both the
/// singular and plural spelling of each axis uniformly — `Args::parse`
/// has no unknown-flag rejection, so without this table a missed
/// spelling was silently ignored per subcommand (the old per-call
/// `get("faults").or_else(|| get("fault"))` hacks, each covering only
/// the spellings its author remembered).
const ALIAS_GROUPS: &[&[&str]] = &[
    &["algo", "algos"],
    &["pattern", "patterns"],
    &["placement", "placements"],
    &["fault", "faults"],
    &["seed", "seeds"],
    &["topo", "topology"],
    &["workload", "workloads"],
    &["rate", "rates"],
    &["evaluator", "evaluators"],
    &["thread", "threads"],
];

/// Parsed `--key value` / `--flag` arguments plus bare positional
/// operands (only `report` consumes positionals; [`run`] rejects stray
/// ones everywhere else so typos keep failing fast).
pub struct Args {
    /// The leading subcommand word (`help` when absent).
    pub cmd: String,
    /// Bare operands in argv order (`pgft report A.json B.json`). A
    /// bare token right after a valueless `--flag` is taken as that
    /// flag's value, so operands go first or after `--key value` pairs.
    pub positionals: Vec<String>,
    opts: BTreeMap<String, String>,
}

impl Args {
    /// Parse an argv tail (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".to_string());
        let mut opts = BTreeMap::new();
        let mut positionals = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                positionals.push(a.clone());
                i += 1;
                continue;
            };
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                opts.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                opts.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { cmd, positionals, opts })
    }

    /// Value of `--key`, if given — under its exact spelling first, then
    /// under any alias from [`ALIAS_GROUPS`] (group order).
    pub fn get(&self, key: &str) -> Option<&str> {
        if let Some(v) = self.opts.get(key) {
            return Some(v.as_str());
        }
        ALIAS_GROUPS
            .iter()
            .filter(|group| group.contains(&key))
            .flat_map(|group| group.iter())
            .filter(|alt| **alt != key)
            .find_map(|alt| self.opts.get(*alt).map(|s| s.as_str()))
    }

    /// Value of `--key`, or `default`.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Whether a boolean `--key` flag was given.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Numeric `--key` with a default; errors on non-numbers.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not a number")),
            None => Ok(default),
        }
    }
}

/// Expand an optional `--faults SPEC` argument into a fault set
/// (`None` when absent or `"none"`): parse the model, validate it
/// against the topology, expand it deterministically from `seed`.
/// Shared by the subcommands that simulate degraded fabrics
/// (`netsim`, `eval`) so fault-spec handling cannot diverge.
fn parse_fault_set(args: &Args, topo: &Topology, seed: u64) -> Result<Option<FaultSet>> {
    match args.get("faults") {
        Some(spec) if spec != "none" => {
            let model = FaultModel::parse(spec)?;
            model.validate_for(&topo.spec)?;
            Ok(Some(model.generate(topo, seed).fault_set(topo)))
        }
        _ => Ok(None),
    }
}

/// Expand the `--telemetry OUT.json` flag into a recording handle: live
/// when the flag is present, inert otherwise (an inert handle makes
/// every instrumented path compile down to an untaken branch, so
/// uninstrumented runs stay byte- and speed-identical).
fn telemetry_handle(args: &Args) -> Telemetry {
    if args.get("telemetry").is_some() {
        Telemetry::enabled()
    } else {
        Telemetry::disabled()
    }
}

/// Write the `pgft-telemetry/1` document named by `--telemetry` and
/// print the human summary to stderr (so `--out`/stdout CSV stays
/// machine-clean). A no-op when the flag was not given.
fn emit_telemetry(
    args: &Args,
    command: &str,
    runs: &[TelemetryRun],
    journal: &[BatchRecord],
) -> Result<()> {
    let Some(path) = args.get("telemetry") else {
        return Ok(());
    };
    write_telemetry(path, command, runs, journal)?;
    eprint!("{}", telemetry_summary_table(runs, journal).to_text());
    eprintln!("wrote telemetry {path}");
    Ok(())
}

/// Expand `--record OUT.json` (plus `--window`/`--top-k`/
/// `--max-windows`) into a flight-recorder handle. `--trace` also
/// enables it on the netsim-backed subcommands, whose Perfetto export
/// is rendered from the recordings. Inert otherwise, so unrecorded runs
/// stay byte- and speed-identical (pinned by the CLI tests).
fn recorder_handle(args: &Args) -> Result<Recorder> {
    if args.get("record").is_none() && args.get("trace").is_none() {
        return Ok(Recorder::disabled());
    }
    let d = RecorderConfig::default();
    let cfg = RecorderConfig {
        window: args.u64_or("window", d.window)?,
        top_k: args.u64_or("top-k", d.top_k as u64)? as usize,
        max_windows: args.u64_or("max-windows", d.max_windows as u64)? as usize,
    };
    cfg.validate()?;
    Ok(Recorder::enabled(cfg))
}

/// Drain a flight recorder and write what `--record` / `--trace` asked
/// for: the `pgft-timeseries/1` document and/or a Chrome-trace JSON
/// rendered from the same recordings (counter tracks per run, phase
/// slices for phased replays). Notices go to stderr so `--out`/stdout
/// stays machine-clean. A no-op for a disabled handle.
fn emit_recorded(args: &Args, command: &str, rec: &Recorder) -> Result<()> {
    if !rec.is_enabled() {
        return Ok(());
    }
    let recs = rec.take();
    if let Some(path) = args.get("record") {
        write_timeseries(path, command, &rec.config(), &recs)?;
        eprintln!("wrote time-series {path} ({} runs)", recs.len());
    }
    if let Some(path) = args.get("trace") {
        let mut tb = TraceBuilder::new();
        for r in &recs {
            tb.add_recording(r);
        }
        tb.write(path)?;
        eprintln!("wrote trace {path} ({} events)", tb.len());
    }
    Ok(())
}

fn load_topo(args: &Args) -> Result<(Topology, NodeTypeMap)> {
    let topo = families::named(&args.get_or("topo", "case-study"))?;
    crate::topology::validate::validate(&topo)?;
    let placement = Placement::parse(&args.get_or("placement", "io:last:1"))?;
    let types = placement.apply(&topo)?;
    Ok((topo, types))
}

fn parse_algos(args: &Args) -> Result<Vec<AlgorithmKind>> {
    let spec = args.get_or("algo", "all");
    if spec == "all" {
        return Ok(AlgorithmKind::ALL.to_vec());
    }
    spec.split(',').map(AlgorithmKind::parse).collect()
}

fn parse_patterns(args: &Args, default: &str) -> Result<Vec<Pattern>> {
    args.get_or("pattern", default)
        .split(',')
        .map(Pattern::parse)
        .collect()
}

fn emit(table: &Table, args: &Args) -> Result<()> {
    let format = args.get_or("format", "text");
    if let Some(path) = args.get("out") {
        table.write(path, &format)?;
        eprintln!("wrote {path}");
    } else {
        let body = match format.as_str() {
            "csv" => table.to_csv(),
            "json" => table.to_json(),
            _ => table.to_text(),
        };
        print!("{body}");
    }
    Ok(())
}

/// Entry point used by `main.rs`; returns the process exit code.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    // Only `report` takes operands; everywhere else a bare token is a
    // typo (a flag missing its `--`), so keep rejecting it loudly.
    if args.cmd != "report" && !args.positionals.is_empty() {
        bail!("expected --option, got {:?}", args.positionals[0]);
    }
    match args.cmd.as_str() {
        "topo" => cmd_topo(&args),
        "sweep" => cmd_sweep(&args),
        "faults" => cmd_faults(&args),
        "eval" => cmd_eval(&args),
        "workload" => cmd_workload(&args),
        "analyze" => cmd_analyze(&args),
        "ports" => cmd_ports(&args),
        "random-dist" => cmd_random_dist(&args),
        "simulate" => cmd_simulate(&args),
        "netsim" => cmd_netsim(&args),
        "packet-sim" => cmd_packet_sim(&args),
        "run" => cmd_run(&args),
        "fabric" => cmd_fabric(&args),
        "fabric-demo" => cmd_fabric_demo(&args),
        "report" => cmd_report(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `pgft help`"),
    }
}

const HELP: &str = r#"pgft — node-type-based load-balancing routing for PGFTs

commands:
  topo         show a topology (--topo case-study|medium-512|PGFT(...); --dot; --leaves)
  sweep        parallel experiment grid: algorithms × patterns × placements × seeds
               (--config FILE, or --topo/--placements A;B/--pattern/--algo/--seeds 1,2;
                --simulate adds flow-level throughput; --workload W,.. adds the
                wl_* makespan columns; --serial / --threads N)
  faults       fault-injection grid: algorithms × fault scenarios on one topology
               (--faults none,rate:0.05,links:4,switches:1,stage:3:2,cascade:4;
                reports rerouting cost and, with --simulate, throughput retention)
  eval         the unified evaluator surface: one shared FlowSet trace per
               (algorithm, pattern) cell, scored by any evaluator stack
               (--evaluators congestion,fairrate,netsim:0.3; --faults SPEC
                repairs the store via incremental re-trace first;
                --serial / --threads N caps the repair fan-out — stores
                below ~32k flows fall back to serial regardless, the
                width policy that keeps small repairs spawn-free;
                --size 16k|64k|256k|1m walks a large-fabric ladder rung
                with sampled pairs, reporting trace/repair rates instead
                of pattern rows; --implicit routes a rung through the
                arithmetic topology view — no port tables — and asserts
                byte-identity against the materialized trace; the 1m
                rung is implicit-only)
  workload     application workloads: concurrent multi-phase job mixes over
               typed node groups (--workload mix|allreduce|checkpoint|
               single:<pattern>:BYTES|FILE.toml; collectives: ring/rd
               allreduce, binomial bcast, pairwise a2a, gather); fluid
               makespan per algorithm, per-phase breakdown on stderr,
               --netsim RATE adds the phase-sequenced flit-level replay
  analyze      congestion table per algorithm × pattern (the paper's analysis)
  ports        per-port detail for one algorithm/pattern (Figs 4-7)
  random-dist  C_topo histogram over random-routing seeds (§III.D)
  simulate     flow-level max-min throughput (XLA/PJRT or rust solver)
  netsim       flit-level latency-vs-offered-load curves (VC/credit flow
               control; --rates 0.05,0.1,..; --packet-flits/--vcs/--vc-capacity/
               --link-latency/--warmup/--measure/--drain; --injection
               bernoulli|burst:K; --faults SPEC simulates degraded tables;
               deterministic per --seed)
  packet-sim   slot-level packet simulation (completion time; superseded by
               netsim for latency/throughput studies)
  run          full experiment from a TOML config (--config FILE)
  fabric       online fabric-manager drill: replay a seeded fault scenario
               through the coordinator (per-event reroute latency, table
               diffs, p50/p99), then measure snapshot-read queries/s under
               repair churn (--faults cascade:4 --seed 2; --burst submits
               each drill half as one coalesced batch; --readers N
               --query-ms MS size the read-load phase)
  fabric-demo  coordinator lifecycle: route, fail links, reroute, report
  report       hotspot attribution over recorded time-series: pgft report
               A.json [B.json] rebuilds each run's fabric from its recorded
               provenance, prints the hottest links (stage, element, node-type
               group, saturation onset, persistence; --top N rows per run)
               and diffs matched runs — across the two files, or within one
               file between runs differing only in their algo label
               (absent/cooler/similar/hotter verdicts, A is the baseline)
  artifacts    list AOT artifacts the runtime can execute
common options:
  --topo NAME --placement SPEC --algo LIST|all --pattern LIST --seed N
  --format text|csv|json --out FILE
  --telemetry OUT.json   (sweep/eval/netsim/fabric) write a pgft-telemetry/1
               document — counters, per-port vectors, histograms, span
               timings, and (fabric) the leader's per-batch event journal —
               plus a summary table on stderr; never changes stdout/--out
               bytes
  --record OUT.json      (netsim, workload with --netsim RATE) flight-record
               the flit replay into a pgft-timeseries/1 document: per-link
               forwarded flits, per-(port,VC) occupancy high-water, credit
               stalls and accepted/injected per fixed simulated-cycle window
               (--window CYCLES, default 64), top-K links per window
               (--top-k K, default 16), bounded ring of --max-windows
               (default 4096; oldest windows shed, totals conserved);
               never changes stdout/--out bytes
  --trace OUT.json       (netsim, workload, fabric) export a Chrome-trace/
               Perfetto JSON timeline: windowed counter tracks and phase
               spans from the recorder, plus (fabric) the coordinator's
               journalled repair batches with per-phase slices
"#;

fn cmd_topo(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    print!("{}", render::render_summary(&topo, Some(&types)));
    if args.flag("leaves") {
        print!("{}", render::render_leaves(&topo, &types));
    }
    if args.flag("dot") {
        print!("{}", render::render_dot(&topo, Some(&types)));
    }
    Ok(())
}

fn summary_table(rows: &[SweepResult]) -> Table {
    let mut t = Table::new(
        "congestion analysis (static metric, §III.A)",
        &["algo", "pattern", "flows", "C_topo", "hot_ports", "hot_top", "used_top", "total_top"],
    );
    for r in rows {
        let s = &r.summary;
        let h = s.hot_per_level.len() - 1;
        t.row(&[
            s.algorithm.clone(),
            s.pattern.clone(),
            s.flows.to_string(),
            s.c_topo.to_string(),
            s.hot_total.to_string(),
            s.hot_per_level[h].to_string(),
            s.used_top_ports.to_string(),
            s.total_top_ports.to_string(),
        ]);
    }
    t
}

/// Parse a comma-separated offered-load list (`0.05,0.1,0.2`).
fn parse_rates(spec: &str) -> Result<Vec<f64>> {
    spec.split(',')
        .map(|x| x.parse::<f64>().map_err(|e| anyhow::anyhow!("offered load {x:?}: {e}")))
        .collect()
}

/// Parse a comma-separated seed list (`1,2,3`).
fn parse_seeds(spec: &str) -> Result<Vec<u64>> {
    spec.split(',')
        .map(|s| s.parse::<u64>().map_err(|e| anyhow::anyhow!("--seeds {s:?}: {e}")))
        .collect()
}

/// Worker-thread count from `--serial` / `--threads N`.
fn parse_threads(args: &Args) -> Result<usize> {
    if args.flag("serial") {
        return Ok(1);
    }
    Ok(args.u64_or("threads", crate::util::par::max_threads() as u64)?.max(1) as usize)
}

fn cmd_sweep(args: &Args) -> Result<()> {
    // Base grid from the config file (or the paper defaults), then CLI
    // flags override axis by axis — `--config grid.toml --simulate`
    // means "that grid, with throughput attached".
    let mut spec = match args.get("config") {
        Some(path) => {
            let mut s = SweepSpec::from_file(path)?;
            if let Some(t) = args.get("topo") {
                s.topologies = vec![t.to_string()];
            }
            s
        }
        None => SweepSpec::paper_grid(&args.get_or("topo", "case-study")),
    };
    // Every axis accepts both the singular and plural spelling through
    // the uniform ALIAS_GROUPS table (Args::get resolves them).
    if let Some(p) = args.get("placements") {
        // ';'-separated so individual specs keep their ','-stacks.
        spec.placements = p.split(';').map(str::to_string).collect();
    }
    if let Some(p) = args.get("pattern") {
        spec.patterns = p.split(',').map(Pattern::parse).collect::<Result<Vec<_>>>()?;
    }
    if let Some(a) = args.get("algo") {
        spec.algorithms = if a == "all" {
            AlgorithmKind::ALL.to_vec()
        } else {
            a.split(',').map(AlgorithmKind::parse).collect::<Result<Vec<_>>>()?
        };
    }
    if let Some(f) = args.get("faults") {
        spec.faults = f.split(',').map(str::to_string).collect();
    }
    if let Some(seeds) = args.get("seeds") {
        spec.seeds = parse_seeds(seeds)?;
    }
    if args.flag("simulate") {
        spec.simulate = true;
    }
    if let Some(n) = args.get("netsim") {
        spec.netsim = parse_rates(n)?;
    }
    if let Some(w) = args.get("workload") {
        spec.workloads = w.split(',').map(str::to_string).collect();
    }
    spec.validate()?;
    let threads = parse_threads(args)?;
    let telem = telemetry_handle(args);
    let t0 = Instant::now();
    let rows = run_sweep_with(&spec, &SweepOptions { threads }, &telem)?;
    let elapsed = t0.elapsed();
    emit(&sweep_table(&rows), args)?;
    emit_telemetry(args, "sweep", &[TelemetryRun::unlabelled(telem.snapshot())], &[])?;
    eprintln!(
        "{} cells in {:.3}s on {} thread{}",
        rows.len(),
        elapsed.as_secs_f64(),
        threads,
        if threads == 1 { "" } else { "s" }
    );
    Ok(())
}

/// `pgft faults` — the paper-style comparison grid with fault scenarios
/// as the second axis: every algorithm × every fault spec on one
/// topology/pattern, reporting rerouting cost (routes changed vs.
/// pristine) and, with `--simulate`, fair-rate throughput retention.
/// Fully deterministic: the same `--seeds` produce byte-identical CSV.
fn cmd_faults(args: &Args) -> Result<()> {
    let spec = SweepSpec {
        topologies: vec![args.get_or("topo", "case-study")],
        placements: vec![args.get_or("placement", "io:last:1")],
        patterns: parse_patterns(args, "c2io-sym")?,
        algorithms: parse_algos(args)?,
        faults: args
            .get_or("faults", "none,rate:0.05,links:2,stage:2:1")
            .split(',')
            .map(str::to_string)
            .collect(),
        seeds: parse_seeds(&args.get_or("seeds", "1"))?,
        simulate: args.flag("simulate"),
        netsim: match args.get("netsim") {
            Some(n) => parse_rates(n)?,
            None => Vec::new(),
        },
        workloads: Vec::new(),
    };
    spec.validate()?;
    let rows = run_sweep(&spec, &SweepOptions { threads: parse_threads(args)? })?;
    emit(&sweep_table(&rows), args)?;
    // The focused resiliency view goes to stderr so `--out`/stdout CSV
    // stays machine-clean.
    eprint!("{}", fault_table(&rows).to_text());
    Ok(())
}

/// `pgft eval` — the uniform evaluator surface: trace one arena-backed
/// [`FlowSet`] per (algorithm, pattern) cell and score it with any
/// stack of [`crate::eval::Evaluator`]s
/// (`--evaluators congestion,fairrate,netsim:RATE`). With
/// `--faults SPEC` the store is first repaired through
/// [`FlowSet::retrace_incremental`] against the scenario expanded from
/// `--seed`, and the `changed` column reports how many routes moved.
///
/// `--serial` / `--threads N` cap the repair fan-out; the
/// [`crate::eval::repair_threads`] width policy still gates small
/// stores to serial (the spawn cost swamps the win below ~32k flows),
/// so the flag is a *cap*, not a force.
fn cmd_eval(args: &Args) -> Result<()> {
    if let Some(size) = args.get("size") {
        return cmd_eval_size(args, size);
    }
    let (topo, types) = load_topo(args)?;
    let max_threads = parse_threads(args)?;
    let seed = args.u64_or("seed", 1)?;
    let evaluators = parse_evaluators(&args.get_or("evaluators", "congestion,fairrate"))?;
    let faults = parse_fault_set(args, &topo, seed)?;
    let telem = telemetry_handle(args);
    let mut t = Table::new(
        "unified eval: evaluator stack over one shared route store per cell",
        &[
            "algo", "pattern", "flows", "hops", "changed", "C_topo", "hot_ports", "agg_thru",
            "min_rate", "ns_accepted", "ns_mean_lat", "ns_saturated",
        ],
    );
    for pattern in parse_patterns(args, "c2io-sym")? {
        let flows = pattern.flows(&topo, &types)?;
        for kind in parse_algos(args)? {
            let router = kind.build(&topo, Some(&types), seed);
            let pristine = FlowSet::trace(&topo, &*router, &flows);
            let (set, changed) = match &faults {
                Some(f) => {
                    let degraded = kind.build_degraded(&topo, Some(&types), seed, f)?;
                    let threads = max_threads.min(crate::eval::repair_threads(pristine.len()));
                    pristine.retrace_incremental_telem(&topo, f, &*degraded, threads, &telem)
                }
                None => (pristine, 0),
            };
            let cells = evaluate_all(&evaluators, &topo, &set, seed);
            let (c_topo, hot) = match &cells.congestion {
                Some(rep) => (rep.c_topo().to_string(), rep.hot_ports().len().to_string()),
                None => Default::default(),
            };
            let (agg, min) = match &cells.fairrate {
                Some(s) => (
                    format!("{:.4}", s.aggregate_throughput),
                    format!("{:.4}", s.min_rate),
                ),
                None => Default::default(),
            };
            let (ns_acc, ns_lat, ns_sat) = match &cells.netsim {
                Some(n) => (
                    format!("{:.4}", n.accepted),
                    format!("{:.2}", n.mean_latency),
                    if n.saturated { "1".to_string() } else { "0".to_string() },
                ),
                None => Default::default(),
            };
            t.row(&[
                kind.as_str().to_string(),
                pattern.name(),
                flows.len().to_string(),
                set.total_hops().to_string(),
                changed.to_string(),
                c_topo,
                hot,
                agg,
                min,
                ns_acc,
                ns_lat,
                ns_sat,
            ]);
        }
    }
    emit(&t, args)?;
    emit_telemetry(args, "eval", &[TelemetryRun::unlabelled(telem.snapshot())], &[])
}

/// `pgft eval --size` — one rung of the large-fabric size ladder
/// ([`crate::eval::LADDER`]): resolve the rung's 3-level PGFT, generate
/// its sampled flow pairs, trace the arena-backed store, repair it
/// against the rung's preset fault scenario (overridable with
/// `--faults`) through the parallel incremental re-trace, and report
/// rates (flows/s, bytes/flow, repair ms) instead of pattern rows.
/// Defaults to `--algo dmodk` and `--evaluators congestion` — the
/// fair-rate and flit-level engines do not scale to these stores.
///
/// `--implicit` routes the rung through the arithmetic
/// [`ImplicitTopology`] view instead of materialized port tables and
/// asserts the resulting trace is byte-identical to the tables where
/// they exist (every rung below 1M). The 1M rung is implicit-only —
/// its port tables would cost tens of GiB — and its fault repair runs
/// the lazily-built per-destination reachability under
/// [`DEFAULT_REACH_BUDGET`] (DESIGN.md §12); the `reach_mb` column
/// reports the peak reach-table footprint actually paid.
fn cmd_eval_size(args: &Args, size: &str) -> Result<()> {
    let rung = crate::eval::ladder::rung(size).with_context(|| {
        let names: Vec<&str> =
            crate::eval::LADDER.iter().map(|r| r.name).collect();
        format!("--size {size:?} is not a ladder rung (try one of {names:?})")
    })?;
    let spec = families::named_spec(rung.topology)?;
    let use_implicit = rung.name == "1m" || args.flag("implicit");
    let implicit = ImplicitTopology::new(&spec);
    let tables: Option<Topology> = if rung.name == "1m" {
        None
    } else {
        let topo = families::named(rung.topology)?;
        crate::topology::validate::validate(&topo)?;
        Some(topo)
    };
    let view: &dyn TopologyView = if use_implicit {
        &implicit
    } else {
        tables.as_ref().expect("every rung below 1m materializes tables")
    };
    // Node types need materialized tables today (placement walks the
    // graph); the 1m rung runs untyped, which keeps dmodk/smodk exact
    // and only loses the IO-aware tie-break.
    let types = match &tables {
        Some(topo) => {
            Some(Placement::parse(&args.get_or("placement", "io:last:1"))?.apply(topo)?)
        }
        None => None,
    };
    let seed = args.u64_or("seed", 1)?;
    let eval_spec = args.get_or("evaluators", "congestion");
    let evaluators = parse_evaluators(&eval_spec)?;
    if use_implicit {
        ensure!(
            eval_spec == "congestion",
            "--implicit scores through the table-free congestion kernel only \
             (got --evaluators {eval_spec:?}); the fair-rate and flit engines \
             need materialized tables"
        );
    }
    let flows = crate::eval::sample_pairs(view.num_nodes(), rung.dsts_per_node, seed);
    // The rung's preset fault scenario, unless the user asked for one.
    let fault_spec = match args.get("faults") {
        Some(s) => s.to_string(),
        None if rung.fault_links > 0 => format!("links:{}", rung.fault_links),
        None => "none".to_string(),
    };
    let faults = if fault_spec == "none" {
        None
    } else {
        let model = FaultModel::parse(&fault_spec)?;
        model.validate_for(&spec)?;
        let scenario = match &tables {
            Some(topo) => model.generate(topo, seed),
            None => model.generate_view(view, seed)?,
        };
        Some(scenario.fault_set_sized(view.num_links()))
    };
    let algos = match args.get_or("algo", "dmodk").as_str() {
        "all" => AlgorithmKind::ALL.to_vec(),
        spec => spec.split(',').map(AlgorithmKind::parse).collect::<Result<Vec<_>>>()?,
    };
    let threads = parse_threads(args)?;
    let telem = telemetry_handle(args);
    let mut t = Table::new(
        "large-fabric ladder rung: sampled pairs, parallel incremental repair",
        &[
            "size", "algo", "mode", "flows", "hops", "bytes_per_flow", "trace_ms",
            "flows_per_sec", "dead_links", "changed", "retrace_ms", "threads",
            "reach_mb", "C_topo", "hot_ports",
        ],
    );
    let mode = if use_implicit { "implicit" } else { "tables" };
    for kind in algos {
        let router = if use_implicit {
            kind.build_view(view, types.as_ref(), seed)?
        } else {
            kind.build(tables.as_ref().unwrap(), types.as_ref(), seed)
        };
        let t0 = Instant::now();
        let pristine = FlowSet::trace(view, &*router, &flows);
        let trace_s = t0.elapsed().as_secs_f64();
        if use_implicit {
            if let Some(topo) = &tables {
                // The contract the implicit view lives by: same router,
                // same flows, byte-identical store either way.
                let reference = FlowSet::trace(topo, &*router, &flows);
                ensure!(
                    pristine == reference,
                    "implicit trace diverged from materialized tables on rung {}",
                    rung.name
                );
            }
        }
        let bytes_per_flow = pristine.arena_bytes() as f64 / pristine.len().max(1) as f64;
        telem.add("eval.store.arena_bytes", pristine.arena_bytes() as u64);
        let (set, changed, retrace_ms, used_threads, reach) = match &faults {
            Some(f) => {
                let used = threads.min(crate::eval::repair_threads(pristine.len()));
                if use_implicit {
                    let base = kind.build_view(view, types.as_ref(), seed)?;
                    let degraded = crate::faults::DegradedRouter::new_lazy(
                        view,
                        f,
                        base,
                        DEFAULT_REACH_BUDGET,
                    );
                    let t1 = Instant::now();
                    let (set, changed) =
                        pristine.retrace_incremental_par(view, f, &degraded, used);
                    let ms = t1.elapsed().as_secs_f64() * 1e3;
                    (set, changed, ms, used, Some(degraded.reach_stats()))
                } else {
                    let topo = tables.as_ref().unwrap();
                    let degraded = kind.build_degraded(topo, types.as_ref(), seed, f)?;
                    let t1 = Instant::now();
                    let (set, changed) =
                        pristine.retrace_incremental_par(view, f, &*degraded, used);
                    (set, changed, t1.elapsed().as_secs_f64() * 1e3, used, None)
                }
            }
            None => (pristine, 0, 0.0, 1, None),
        };
        if let Some(r) = &reach {
            telem.add("eval.reach.computed", r.computed);
            telem.add("eval.reach.hits", r.hits);
            telem.add("eval.reach.evictions", r.evictions);
            telem.add("eval.reach.peak_bytes", r.peak_bytes);
        }
        let (c_topo, hot) = if use_implicit {
            let (rep, ks) = CongestionReport::compute_flowset_stats(view, &set);
            telem.add("eval.kernel.blocks", ks.blocks);
            telem.add("eval.kernel.touched_ports", ks.touched_ports);
            telem.add("eval.kernel.merged_words", ks.merged_words);
            (rep.c_topo().to_string(), rep.hot_ports().len().to_string())
        } else {
            let cells = evaluate_all(&evaluators, tables.as_ref().unwrap(), &set, seed);
            match &cells.congestion {
                Some(rep) => (rep.c_topo().to_string(), rep.hot_ports().len().to_string()),
                None => Default::default(),
            }
        };
        t.row(&[
            rung.name.to_string(),
            kind.as_str().to_string(),
            mode.to_string(),
            set.len().to_string(),
            set.total_hops().to_string(),
            format!("{bytes_per_flow:.1}"),
            format!("{:.1}", trace_s * 1e3),
            format!("{:.0}", set.len() as f64 / trace_s.max(1e-9)),
            faults.as_ref().map_or(0, |f| f.num_dead()).to_string(),
            changed.to_string(),
            format!("{retrace_ms:.1}"),
            used_threads.to_string(),
            reach.map_or_else(
                || "0.0".to_string(),
                |r| format!("{:.1}", r.peak_bytes as f64 / 1e6),
            ),
            c_topo,
            hot,
        ]);
    }
    emit(&t, args)?;
    emit_telemetry(args, "eval", &[TelemetryRun::unlabelled(telem.snapshot())], &[])
}

/// `pgft workload` — evaluate application workloads (concurrent
/// multi-phase job mixes, [`crate::workload`]) per algorithm and seed:
/// lower each workload onto the fabric once, run the fluid phase
/// simulation with every selected router (degraded via `--faults SPEC`),
/// and emit one row per (workload, algorithm, seed) with the makespan,
/// phase count and per-job completion times. A per-phase breakdown goes
/// to stderr (so `--out`/stdout CSV stays machine-clean); with
/// `--netsim RATE` the breakdown additionally carries flit-level
/// per-phase figures from the phase-sequenced replay
/// ([`crate::netsim::run_netsim_phased`]). Deterministic: the same
/// `--seeds` produce byte-identical CSV.
fn cmd_workload(args: &Args) -> Result<()> {
    let topo = families::named(&args.get_or("topo", "case-study"))?;
    crate::topology::validate::validate(&topo)?;
    // The default placement carries GPGPU nodes so the built-in job
    // mixes resolve out of the box.
    let placement = Placement::parse(&args.get_or("placement", "io:last:1,gpgpu:first:2"))?;
    let types = placement.apply(&topo)?;
    let seeds = parse_seeds(&args.get_or("seeds", "1"))?;
    let netsim_rate: Option<f64> = args
        .get("netsim")
        .map(|r| r.parse().map_err(|e| anyhow::anyhow!("--netsim {r:?}: {e}")))
        .transpose()?;
    let mut t = Table::new(
        "application workloads: fluid makespan per (workload, algorithm, seed)",
        &["workload", "algo", "seed", "jobs", "phases", "makespan", "job_times"],
    );
    let mut detail = Table::new(
        "per-phase breakdown (fluid rates; ns_* columns from the phase-sequenced \
         flit-level replay when --netsim RATE is given)",
        &[
            "workload", "algo", "seed", "phase", "t_start", "duration", "flows",
            "agg_rate", "min_rate", "ns_accepted", "ns_mean_lat", "ns_saturated",
        ],
    );
    // With the breakdown suppressed there is nothing to show per-phase
    // figures in, so the (expensive) flit-level replay would be wasted
    // work — reject the conflicting request instead of silently
    // dropping either flag.
    let want_detail = !args.flag("no-phase-detail");
    if !want_detail && netsim_rate.is_some() {
        bail!(
            "--netsim RATE fills the per-phase breakdown that --no-phase-detail \
             suppresses; drop one of the two flags"
        );
    }
    // The flight recorder samples the phase-sequenced flit-level replay,
    // so it needs one to sample.
    let rec = recorder_handle(args)?;
    if rec.is_enabled() && netsim_rate.is_none() {
        bail!("--record/--trace sample the flit-level replay; add --netsim RATE");
    }
    let fault_given = matches!(args.get("faults"), Some(s) if s != "none");
    for wname in args.get_or("workload", "mix").split(',') {
        let spec = WorkloadSpec::parse(wname)?;
        let lowered = lower(&spec, &topo, &types)?;
        for kind in parse_algos(args)? {
            // The fluid makespan is deterministic: only random
            // algorithms and generated fault scenarios make it
            // seed-sensitive, so other algo/seed combinations build the
            // router and evaluate once, then reuse (mirroring the sweep
            // runner's dedup). With `--netsim` the phase stores the
            // evaluation traced are kept and replayed — the flit-level
            // run itself re-seeds per row.
            let seeded = fault_given
                || matches!(kind, AlgorithmKind::Random | AlgorithmKind::RandomPair);
            let mut cached: Option<(WorkloadEval, Vec<FlowSet>)> = None;
            for &seed in &seeds {
                if seeded || cached.is_none() {
                    let router: Box<dyn Router> = match parse_fault_set(args, &topo, seed)? {
                        Some(f) => kind.build_degraded(&topo, Some(&types), seed, &f)?,
                        None => kind.build(&topo, Some(&types), seed),
                    };
                    cached = Some(if netsim_rate.is_some() {
                        evaluate_makespan_traced(&topo, &*router, &lowered)?
                    } else {
                        (evaluate_makespan(&topo, &*router, &lowered)?, Vec::new())
                    });
                }
                let (eval, sets) = cached.as_ref().expect("evaluated above");
                t.row(&[
                    spec.name.clone(),
                    kind.as_str().to_string(),
                    seed.to_string(),
                    eval.job_times.len().to_string(),
                    eval.phases.len().to_string(),
                    eval.makespan.to_string(),
                    eval.job_times
                        .iter()
                        .map(|(name, time)| format!("{name}={time}"))
                        .collect::<Vec<_>>()
                        .join("|"),
                ]);
                if !want_detail {
                    continue;
                }
                let ns = match netsim_rate {
                    Some(rate) => {
                        let cfg = NetsimConfig {
                            seed,
                            warmup: args.u64_or("warmup", 300)?,
                            measure: args.u64_or("measure", 500)?,
                            drain: args.u64_or("drain", 300)?,
                            ..Default::default()
                        };
                        let mut info = RunInfo {
                            label: BTreeMap::new(),
                            topo: args.get_or("topo", "case-study"),
                            placement: args
                                .get_or("placement", "io:last:1,gpgpu:first:2"),
                        };
                        info.label.insert("workload".to_string(), spec.name.clone());
                        info.label
                            .insert("algo".to_string(), kind.as_str().to_string());
                        info.label.insert("seed".to_string(), seed.to_string());
                        Some(crate::netsim::run_netsim_phased_recorded(
                            &topo, sets, &cfg, rate, &rec, info,
                        )?)
                    }
                    None => None,
                };
                for phase in &eval.phases {
                    let (ns_acc, ns_lat, ns_sat) = match ns.as_ref() {
                        Some(rep) => {
                            let p = &rep.phases[phase.index];
                            (
                                format!("{:.4}", p.accepted),
                                format!("{:.2}", p.mean_latency),
                                if p.saturated { "1".into() } else { "0".into() },
                            )
                        }
                        None => Default::default(),
                    };
                    detail.row(&[
                        spec.name.clone(),
                        kind.as_str().to_string(),
                        seed.to_string(),
                        phase.index.to_string(),
                        format!("{:.3}", phase.t_start),
                        format!("{:.3}", phase.duration),
                        phase.flow_pairs.len().to_string(),
                        format!("{:.4}", phase.aggregate_rate),
                        format!("{:.6}", phase.min_rate),
                        ns_acc,
                        ns_lat,
                        ns_sat,
                    ]);
                }
            }
        }
    }
    emit(&t, args)?;
    // The phase breakdown goes to stderr unless suppressed.
    if want_detail {
        eprint!("{}", detail.to_text());
    }
    emit_recorded(args, "workload", &rec)?;
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let spec = SweepSpec {
        topologies: vec![args.get_or("topo", "case-study")],
        placements: vec![args.get_or("placement", "io:last:1")],
        patterns: parse_patterns(args, "c2io-sym,c2io-all")?,
        algorithms: parse_algos(args)?,
        faults: vec!["none".into()],
        seeds: vec![args.u64_or("seed", 1)?],
        simulate: false,
        netsim: Vec::new(),
        workloads: Vec::new(),
    };
    let rows = run_sweep(&spec, &SweepOptions { threads: parse_threads(args)? })?;
    emit(&summary_table(&rows), args)?;
    eprintln!();
    eprint!("{}", render_algorithm_table(&crate::sweep::summaries(&rows)));
    Ok(())
}

fn cmd_ports(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let kind = AlgorithmKind::parse(&args.get_or("algo", "dmodk"))?;
    let pattern = Pattern::parse(&args.get_or("pattern", "c2io-sym"))?;
    let router = kind.build(&topo, Some(&types), args.u64_or("seed", 1)?);
    let flows = pattern.flows(&topo, &types)?;
    let routes = trace_flows(&topo, &*router, &flows);
    let rep = CongestionReport::compute(&topo, &routes);
    let level: Option<usize> = args.get("level").map(|v| v.parse()).transpose()?;
    let mut t = Table::new(
        format!("per-port flows: {} on {}", kind, pattern.name()),
        &["port", "dir", "level", "routes", "srcs", "dsts", "C_p"],
    );
    for port in &topo.ports {
        let st = rep.per_port[port.id];
        if st.routes == 0 {
            continue;
        }
        let lvl = topo.port_level(port.id);
        if let Some(l) = level {
            if lvl != l {
                continue;
            }
        }
        t.row(&[
            topo.port_label(port.id),
            if port.up { "up".into() } else { "down".into() },
            lvl.to_string(),
            st.routes.to_string(),
            st.srcs.to_string(),
            st.dsts.to_string(),
            st.c().to_string(),
        ]);
    }
    emit(&t, args)?;
    eprintln!("C_topo = {}", rep.c_topo());
    Ok(())
}

fn cmd_random_dist(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let pattern = Pattern::parse(&args.get_or("pattern", "c2io-sym"))?;
    let trials = args.u64_or("trials", 1000)?;
    let flows = pattern.flows(&topo, &types)?;
    let mut hist: BTreeMap<u32, u64> = BTreeMap::new();
    for seed in 0..trials {
        let router = AlgorithmKind::Random.build(&topo, Some(&types), seed);
        *hist
            .entry(CongestionReport::compute_flows(&topo, &*router, &flows).c_topo())
            .or_default() += 1;
    }
    let mut t = Table::new(
        format!("C_topo distribution over {trials} random routings ({})", pattern.name()),
        &["C_topo", "count", "fraction"],
    );
    for (c, n) in &hist {
        t.row(&[c.to_string(), n.to_string(), format!("{:.4}", *n as f64 / trials as f64)]);
    }
    emit(&t, args)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let seed = args.u64_or("seed", 1)?;
    let runtime = if args.flag("no-xla") {
        None
    } else {
        match crate::runtime::Runtime::open_default() {
            Ok(rt) => {
                eprintln!("PJRT platform: {}", rt.platform());
                Some(rt)
            }
            Err(e) => {
                eprintln!("XLA runtime unavailable ({e:#}); using rust solver");
                None
            }
        }
    };
    let mut rows = Vec::new();
    for pattern in parse_patterns(args, "c2io-sym")? {
        for kind in parse_algos(args)? {
            rows.push(simulate_flow_level(&topo, &types, kind, &pattern, seed, runtime.as_ref())?);
        }
    }
    let mut t = Table::new(
        "flow-level max-min simulation",
        &["algo", "pattern", "flows", "agg_thru", "min_rate", "completion", "C_topo", "solver"],
    );
    for r in &rows {
        t.row(&[
            r.algorithm.clone(),
            r.pattern.clone(),
            r.flows.to_string(),
            format!("{:.3}", r.aggregate_throughput),
            format!("{:.4}", r.min_rate),
            format!("{:.2}", r.completion_time),
            r.c_topo.to_string(),
            r.solver.clone(),
        ]);
    }
    emit(&t, args)?;
    eprint!("{}", render_sim_table(&rows));
    Ok(())
}

/// `pgft netsim` — flit-level latency-vs-offered-load curves: one curve
/// per (algorithm, pattern) over a grid of injection rates, simulated
/// with the VC/credit event-driven engine ([`crate::netsim`]). With
/// `--faults SPEC` the *degraded* tables are simulated end-to-end
/// (scenario expanded from `--seed`, routed via
/// [`crate::faults::DegradedRouter`]). Deterministic: the same `--seed`
/// produces byte-identical CSV.
fn cmd_netsim(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let seed = args.u64_or("seed", 1)?;
    let rates = match args.get("rates") {
        Some(spec) => parse_rates(spec)?,
        None => default_rates(),
    };
    let cfg = NetsimConfig {
        packet_flits: args.u64_or("packet-flits", 4)? as u32,
        vcs: args.u64_or("vcs", 2)? as u32,
        vc_capacity: args.u64_or("vc-capacity", 8)? as u32,
        link_latency: args.u64_or("link-latency", 1)?,
        warmup: args.u64_or("warmup", 300)?,
        measure: args.u64_or("measure", 1500)?,
        drain: args.u64_or("drain", 300)?,
        injection: Injection::parse(&args.get_or("injection", "bernoulli"))?,
        seed,
    };
    // Optional fault scenario: simulate rerouted (degraded) tables.
    let faults = parse_fault_set(args, &topo, seed)?;
    // Optional flight recorder: every rate point of every curve lands
    // as one labelled windowed time-series run in `--record OUT.json`.
    let rec = recorder_handle(args)?;
    // One telemetry run per (algo, pattern): every rate of that curve
    // merges into the same registry, so per-port counters aggregate
    // over one configuration's rate grid only (the rate list rides in
    // the run label).
    let telemetry_on = args.get("telemetry").is_some();
    let mut truns: Vec<TelemetryRun> = Vec::new();
    let mut points: Vec<CurvePoint> = Vec::new();
    let mut sat = Table::new(
        "saturation points (peak accepted flits/cycle, knee offered load)",
        &["algo", "pattern", "peak_accepted", "knee_offered", "first_saturated"],
    );
    for pattern in parse_patterns(args, "c2io-sym")? {
        let flows = pattern.flows(&topo, &types)?;
        for kind in parse_algos(args)? {
            let router: Box<dyn Router> = match &faults {
                Some(f) => kind.build_degraded(&topo, Some(&types), seed, f)?,
                None => kind.build(&topo, Some(&types), seed),
            };
            let set = FlowSet::trace(&topo, &*router, &flows);
            let telem =
                if telemetry_on { Telemetry::enabled() } else { Telemetry::disabled() };
            // Recording provenance: the run label names the curve, the
            // topo/placement strings let `pgft report` rebuild the
            // fabric for hotspot attribution.
            let mut info = RunInfo {
                label: BTreeMap::new(),
                topo: args.get_or("topo", "case-study"),
                placement: args.get_or("placement", "io:last:1"),
            };
            info.label.insert("algo".to_string(), kind.as_str().to_string());
            info.label.insert("pattern".to_string(), pattern.name());
            let curve = load_curve_recorded(&topo, &set, &cfg, &rates, &telem, &rec, &info)?;
            if telemetry_on {
                let mut label = BTreeMap::new();
                label.insert("algo".to_string(), kind.as_str().to_string());
                label.insert("pattern".to_string(), pattern.name());
                label.insert(
                    "rates".to_string(),
                    rates.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(","),
                );
                truns.push(TelemetryRun { label, registry: telem.snapshot() });
            }
            if let Some(s) = saturation_point(&curve) {
                sat.row(&[
                    kind.as_str().to_string(),
                    pattern.name(),
                    format!("{:.3}", s.peak_accepted),
                    format!("{:.3}", s.knee_offered),
                    s.first_saturated.map(|x| format!("{x:.3}")).unwrap_or_default(),
                ]);
            }
            points.extend(curve.into_iter().map(|report| CurvePoint {
                algorithm: kind.as_str().to_string(),
                pattern: pattern.name(),
                report,
            }));
        }
    }
    emit(&curve_table(&points), args)?;
    // The saturation summary goes to stderr so `--out`/stdout CSV stays
    // machine-clean.
    eprint!("{}", sat.to_text());
    emit_telemetry(args, "netsim", &truns, &[])?;
    emit_recorded(args, "netsim", &rec)?;
    Ok(())
}

fn cmd_packet_sim(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let seed = args.u64_or("seed", 1)?;
    let cfg = PacketSimConfig {
        message_packets: args.u64_or("message", 64)? as u32,
        queue_capacity: args.u64_or("queue", 8)? as usize,
        max_slots: args.u64_or("max-slots", 1_000_000)?,
    };
    let mut t = Table::new(
        "packet-level simulation",
        &["algo", "pattern", "flows", "completion_slots", "throughput", "max_queue"],
    );
    for pattern in parse_patterns(args, "c2io-sym")? {
        let flows = pattern.flows(&topo, &types)?;
        for kind in parse_algos(args)? {
            let router = kind.build(&topo, Some(&types), seed);
            let routes = trace_flows(&topo, &*router, &flows);
            let res = PacketSim::new(&topo, &routes, cfg.clone()).run()?;
            t.row(&[
                kind.as_str().to_string(),
                pattern.name(),
                flows.len().to_string(),
                res.completion_slots.to_string(),
                format!("{:.3}", res.throughput),
                res.max_queue_depth.to_string(),
            ]);
        }
    }
    emit(&t, args)
}

fn cmd_run(args: &Args) -> Result<()> {
    let path = args.get("config").context("--config FILE required")?;
    let cfg = ExperimentConfig::from_file(path)?;
    // Built once here for the summary banner; run_sweep re-resolves the
    // topology from its spec string (keeps SweepSpec self-contained; the
    // rebuild is milliseconds even at 4096 nodes).
    let topo = crate::topology::build_pgft(&cfg.topology);
    crate::topology::validate::validate(&topo)?;
    let types = cfg.placement.apply(&topo)?;
    println!("{}", render::render_summary(&topo, Some(&types)));

    // The whole experiment is one sweep: static congestion analysis plus
    // flow-level throughput (deterministic rust solver) for every
    // (algorithm, pattern) cell, fanned out in parallel.
    let spec = SweepSpec {
        topologies: vec![cfg.topology_name.clone()],
        placements: vec![cfg.placement_spec.clone()],
        patterns: cfg.patterns.clone(),
        algorithms: cfg.algorithms.clone(),
        faults: vec!["none".into()],
        seeds: vec![cfg.seed],
        simulate: true,
        netsim: Vec::new(),
        workloads: Vec::new(),
    };
    let rows = run_sweep(&spec, &SweepOptions { threads: parse_threads(args)? })?;
    print!("{}", render_algorithm_table(&crate::sweep::summaries(&rows)));
    print!("{}", sweep_table(&rows).to_text());

    // `use_xla = true`: additionally run the flow-level solves through
    // the AOT artifacts for cross-checking (the sweep's rust-solver
    // figures above stay the deterministic reference).
    if cfg.use_xla {
        match crate::runtime::Runtime::open_default() {
            Ok(rt) => {
                eprintln!("PJRT platform: {}", rt.platform());
                let mut sims = Vec::new();
                for pattern in &cfg.patterns {
                    for &kind in &cfg.algorithms {
                        sims.push(simulate_flow_level(
                            &topo,
                            &types,
                            kind,
                            pattern,
                            cfg.seed,
                            Some(&rt),
                        )?);
                    }
                }
                print!("{}", render_sim_table(&sims));
            }
            Err(e) => eprintln!(
                "XLA runtime unavailable ({e:#}); the sweep's rust-solver rates above stand"
            ),
        }
    }
    Ok(())
}

fn cmd_fabric_demo(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let kind = AlgorithmKind::parse(&args.get_or("algo", "gdmodk"))?;
    let topo = Arc::new(topo);
    let coord = Coordinator::start(topo.clone(), types, kind, args.u64_or("seed", 1)?)?;
    println!("fabric up: {:?}", coord.stats());
    println!("C2IO analysis: {:?}", coord.analyze(Pattern::C2ioSym)?.c_topo);
    // Fault drill: kill two top-stage links, reroute, verify, revive.
    let victims: Vec<_> = topo.links.iter().filter(|l| l.stage == topo.spec.h).take(2).collect();
    for v in &victims {
        coord.link_down(v.id);
        coord.sync()?;
        let s = coord.stats();
        println!(
            "link {} down → v{} reroute {} µs, diff {} entries",
            v.id, s.table_version, s.last_reroute_micros, s.last_diff_entries
        );
    }
    println!("degraded C2IO C_topo: {}", coord.analyze(Pattern::C2ioSym)?.c_topo);
    for v in &victims {
        coord.link_up(v.id);
    }
    coord.sync()?;
    println!("healed: {:?}", coord.stats());
    coord.shutdown();
    Ok(())
}

/// Percentile over an ascending-sorted latency sample (nearest-rank).
fn percentile(sorted: &[u64], p: usize) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    sorted[(sorted.len() - 1) * p / 100]
}

fn cmd_fabric(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let kind = AlgorithmKind::parse(&args.get_or("algo", "gdmodk"))?;
    let seed = args.u64_or("seed", 2)?;
    let model = FaultModel::parse(&args.get_or("faults", "cascade:4"))?;
    model.validate_for(&topo.spec)?;
    let scenario = model.generate(&topo, seed);
    anyhow::ensure!(
        !scenario.events.is_empty(),
        "fault model {model} generated no events; nothing to drill"
    );
    let topo = Arc::new(topo);
    // `--telemetry`/`--trace` instrument the leader itself: repairs run
    // through the telemetry-aware retrace, so `eval.retrace.*` and
    // `eval.reach.*` counters (and the lazy reach arena's residency
    // peaks) land in the handle's registry.
    let wants_trace = args.get("trace").is_some();
    let telem = if args.get("telemetry").is_some() || wants_trace {
        Telemetry::enabled()
    } else {
        Telemetry::disabled()
    };
    let coord = Coordinator::start_instrumented(topo.clone(), types, kind, seed, telem.clone())?;

    // Phase 1 — the seeded drill (every death, then every repair), one
    // table row per processed batch. --burst submits each half of the
    // drill as ONE atomic batch instead of per-event singles.
    let drill = scenario.drill_events();
    let batches: Vec<Vec<crate::faults::LinkEvent>> = if args.flag("burst") {
        let n = scenario.events.len();
        vec![drill[..n].to_vec(), drill[n..].to_vec()]
    } else {
        drill.iter().map(|&e| vec![e]).collect()
    };
    let mut t = Table::new(
        &format!("pgft fabric: {} drill, algo={kind}", scenario.label()),
        &["event", "dead_links", "version", "reroute_us", "diff_entries", "routes_moved", "batch"],
    );
    let mut lat: Vec<u64> = Vec::new();
    for batch in batches {
        let label = if batch.len() == 1 {
            batch[0].to_string()
        } else {
            format!("burst×{}", batch.len())
        };
        coord.inject_burst(batch);
        coord.sync()?;
        let s = coord.stats();
        lat.push(s.last_reroute_micros);
        t.row(&[
            label,
            s.dead_links.to_string(),
            s.table_version.to_string(),
            s.last_reroute_micros.to_string(),
            s.last_diff_entries.to_string(),
            s.last_routes_changed.to_string(),
            s.last_batch_events.to_string(),
        ]);
    }
    emit(&t, args)?;

    // Phase 2 — read throughput under repair churn: N reader threads
    // hammer snapshot queries while this thread keeps the leader
    // repairing (the drill on loop). Readers share only the snapshot
    // cell — no channel, no lock held across a query.
    let readers = args.u64_or("readers", 4)? as usize;
    let query_ms = args.u64_or("query-ms", 200)?;
    let cell = coord.snapshots();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let handles: Vec<_> = (0..readers)
        .map(|i| {
            let cell = cell.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut queries = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let snap = cell.load();
                    match i % 3 {
                        0 => drop(snap.analyze(Pattern::C2ioSym)),
                        1 => drop(snap.trace(&[(0, 63), (63, 0), (1, 62)])),
                        _ => assert_eq!(snap.stats.table_version, snap.tables.version),
                    }
                    queries += 1;
                }
                queries
            })
        })
        .collect();
    let t0 = Instant::now();
    let mut repairs = 0u64;
    while t0.elapsed().as_millis() < u128::from(query_ms) {
        for &e in &drill {
            coord.inject_burst(vec![e]);
            coord.sync()?;
            lat.push(coord.stats().last_reroute_micros);
            repairs += 1;
        }
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let secs = t0.elapsed().as_secs_f64();
    let queries: u64 = handles.into_iter().map(|h| h.join().expect("reader panicked")).sum();
    lat.sort_unstable();
    eprintln!(
        "reroute latency over {} repairs: p50 {} µs, p99 {} µs",
        lat.len(),
        percentile(&lat, 50),
        percentile(&lat, 99),
    );
    eprintln!(
        "read load: {queries} queries from {readers} readers in {secs:.2}s \
         → {:.0} queries/s while the writer applied {repairs} repairs",
        queries as f64 / secs.max(1e-9),
    );
    // --telemetry: the leader's event journal (per-phase repair
    // timings, straight off the final snapshot), the leader-side
    // retrace/reach counters, and the headline service counters as one
    // unlabelled run. --trace: the same journal and registry rendered
    // as a Chrome-trace/Perfetto timeline.
    if args.get("telemetry").is_some() || wants_trace {
        let snap = coord.snapshot();
        let s = &snap.stats;
        // The leader's own counters (eval.retrace.*, eval.reach.*)
        // seed the registry; the service stats ride alongside.
        let mut reg = telem.snapshot();
        reg.add("fabric.table_version", s.table_version);
        reg.add("fabric.rebuilds", s.rebuilds);
        reg.add("fabric.reroutes", s.reroutes);
        reg.add("fabric.failed_repairs", s.failed_repairs);
        reg.add("fabric.dead_links", s.dead_links as u64);
        reg.add("fabric.table_entries", s.table_entries as u64);
        reg.add("coordinator.journal.shed", s.journal_shed);
        reg.record_max("fabric.reach_peak_bytes", s.reach_peak_bytes);
        reg.vec_bulk(
            "fabric.reroute_micros_window",
            VecKind::Max,
            &s.reroute_micros_window,
        );
        reg.span_ns("fabric.last_reroute", s.last_reroute_micros * 1_000);
        emit_telemetry(
            args,
            "fabric",
            &[TelemetryRun::unlabelled(reg.clone())],
            &snap.journal,
        )?;
        if let Some(path) = args.get("trace") {
            let mut tb = TraceBuilder::new();
            tb.add_journal(&snap.journal);
            tb.add_telemetry_run(&TelemetryRun::unlabelled(reg));
            tb.write(path)?;
            eprintln!("wrote trace {path} ({} events)", tb.len());
        }
    }
    coord.shutdown();
    Ok(())
}

/// Display name of a recorded run: its label `k=v` pairs, or `run`.
fn run_name(info: &RunInfo) -> String {
    if info.label.is_empty() {
        return "run".to_string();
    }
    info.label.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(",")
}

/// Pairing key for the hotspot diff: every label except `algo`, plus
/// the fabric provenance — runs that differ only in their routing
/// algorithm compare like for like (same pattern, rate, workload,
/// topology and placement).
fn match_key(info: &RunInfo) -> String {
    let labels: Vec<String> = info
        .label
        .iter()
        .filter(|(k, _)| k.as_str() != "algo")
        .map(|(k, v)| format!("{k}={v}"))
        .collect();
    format!("{}|{}|{}", info.topo, info.placement, labels.join(","))
}

/// `pgft report`'s fabric cache, keyed by recorded `(topo, placement)`
/// provenance so every distinct fabric is rebuilt once per invocation.
type FabricCache = BTreeMap<(String, String), (Topology, Option<NodeTypeMap>)>;

/// Rebuild (once per distinct provenance) the fabric a recording was
/// sampled on, from the `topo`/`placement` strings the recorder stored.
fn fabric_for<'a>(
    fabrics: &'a mut FabricCache,
    info: &RunInfo,
) -> Result<&'a (Topology, Option<NodeTypeMap>)> {
    let key = (info.topo.clone(), info.placement.clone());
    if !fabrics.contains_key(&key) {
        ensure!(
            !info.topo.is_empty(),
            "recording carries no topology provenance; re-record with a current pgft"
        );
        let topo = families::named(&info.topo)?;
        crate::topology::validate::validate(&topo)?;
        let types = if info.placement.is_empty() {
            None
        } else {
            Some(Placement::parse(&info.placement)?.apply(&topo)?)
        };
        fabrics.insert(key.clone(), (topo, types));
    }
    Ok(&fabrics[&key])
}

/// `pgft report` — hotspot attribution over `pgft-timeseries/1`
/// documents, and hotspot diffing between recordings.
///
/// `pgft report A.json` rebuilds each run's fabric from its recorded
/// provenance and prints the hottest links per run: the link label, its
/// stage, the element below it, the node-type group it feeds, the
/// saturation-onset window and whether the hotspot persisted to the end
/// of the run. `pgft report A.json B.json` additionally matches runs
/// across the two documents (identical labels apart from `algo`) and
/// prints the verdict table — which of A's hotspots are `absent`,
/// `cooler`, `similar` or `hotter` under B; that table becomes stdout
/// and the attribution moves to stderr. A single document whose runs
/// differ only in their `algo` label is diffed the same way (first
/// algorithm seen is the baseline), so one recorded
/// `pgft netsim --algos dmodk,gdmodk --record` sweep carries the
/// paper's dmodk-vs-gdmodk hotspot comparison on its own. `--top N`
/// bounds the rows per run (default 5).
fn cmd_report(args: &Args) -> Result<()> {
    let files = &args.positionals;
    ensure!(
        !files.is_empty() && files.len() <= 2,
        "usage: pgft report A.json [B.json] (A is the diff baseline)"
    );
    let docs: Vec<crate::telemetry::TimeSeriesDoc> = files
        .iter()
        .map(|p| {
            let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
            parse_timeseries(&text).with_context(|| format!("parsing {p}"))
        })
        .collect::<Result<Vec<_>>>()?;
    let top = args.u64_or("top", 5)? as usize;
    let mut fabrics = BTreeMap::new();
    let mut t = Table::new(
        "flight-recorder hotspot attribution (per run, hottest first)",
        &[
            "file", "run", "link", "stage", "below", "group", "onset", "persist", "peak_fwd",
            "total_fwd", "util",
        ],
    );
    // Per document: (display name, full hotspot list) per run.
    let mut per_doc: Vec<Vec<(String, Vec<Hotspot>)>> = Vec::new();
    for (fi, doc) in docs.iter().enumerate() {
        let mut runs = Vec::new();
        for run in &doc.runs {
            let (topo, types) = fabric_for(&mut fabrics, &run.info)?;
            let hs = attribute(run, topo, types.as_ref())?;
            let name = run_name(&run.info);
            for h in hs.iter().take(top) {
                t.row(&[
                    files[fi].clone(),
                    name.clone(),
                    h.label.clone(),
                    h.stage.to_string(),
                    h.switch.clone(),
                    h.group.clone(),
                    h.onset.map(|o| o.to_string()).unwrap_or_default(),
                    String::from(if h.persistent { "1" } else { "0" }),
                    h.peak_forwarded.to_string(),
                    h.total_forwarded.to_string(),
                    format!("{:.3}", h.utilization),
                ]);
            }
            runs.push((name, hs));
        }
        per_doc.push(runs);
    }
    // Matched run pairs to diff: across the two documents, or within
    // the single document for runs differing only in `algo`.
    let mut pairs: Vec<((usize, usize), (usize, usize))> = Vec::new();
    if docs.len() == 2 {
        for i in 0..docs[0].runs.len() {
            let key = match_key(&docs[0].runs[i].info);
            if let Some(j) = docs[1].runs.iter().position(|r| match_key(&r.info) == key) {
                pairs.push(((0, i), (1, j)));
            }
        }
    } else {
        let keys: Vec<String> = docs[0].runs.iter().map(|r| match_key(&r.info)).collect();
        for i in 0..keys.len() {
            for j in (i + 1)..keys.len() {
                let differs = docs[0].runs[i].info.label.get("algo")
                    != docs[0].runs[j].info.label.get("algo");
                if keys[i] == keys[j] && differs && !pairs.iter().any(|&(_, b)| b == (0, j)) {
                    pairs.push(((0, i), (0, j)));
                }
            }
        }
    }
    let mut d = Table::new(
        "hotspot diff: baseline (a) vs candidate (b) per matched run pair",
        &[
            "run_a", "run_b", "link", "stage", "group", "a_total", "b_total", "a_onset",
            "b_onset", "a_persist", "verdict",
        ],
    );
    for &((da, ia), (db, ib)) in &pairs {
        let (na, ha) = &per_doc[da][ia];
        let (nb, hb) = &per_doc[db][ib];
        for x in diff_hotspots(ha, hb).into_iter().take(top) {
            d.row(&[
                na.clone(),
                nb.clone(),
                x.label.clone(),
                x.stage.to_string(),
                x.group.clone(),
                x.a_total.to_string(),
                x.b_total.to_string(),
                x.a_onset.map(|o| o.to_string()).unwrap_or_default(),
                x.b_onset.map(|o| o.to_string()).unwrap_or_default(),
                String::from(if x.a_persistent { "1" } else { "0" }),
                x.verdict.to_string(),
            ]);
        }
    }
    if docs.len() == 2 {
        emit(&d, args)?;
        eprint!("{}", t.to_text());
    } else {
        emit(&t, args)?;
        if !pairs.is_empty() {
            eprint!("{}", d.to_text());
        }
    }
    Ok(())
}

fn cmd_artifacts(_args: &Args) -> Result<()> {
    let rt = crate::runtime::Runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());
    let mut t = Table::new("AOT artifacts", &["name", "kind", "flows", "ports", "iters"]);
    for a in rt.manifest() {
        t.row(&[
            a.name.clone(),
            a.kind.clone(),
            a.flows.to_string(),
            a.ports.to_string(),
            a.iters.to_string(),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn args_parse_forms() {
        let a =
            Args::parse(&argv(&["analyze", "--algo", "dmodk", "--dot", "--seed", "3"])).unwrap();
        assert_eq!(a.cmd, "analyze");
        assert_eq!(a.get("algo"), Some("dmodk"));
        assert!(a.flag("dot"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 3);
        assert_eq!(a.get_or("missing", "x"), "x");
        // Bare operands parse into `positionals` (for `pgft report`)…
        let p = Args::parse(&argv(&["report", "a.json", "b.json", "--top", "3"])).unwrap();
        assert_eq!(p.positionals, ["a.json", "b.json"]);
        assert_eq!(p.u64_or("top", 5).unwrap(), 3);
        // …but every other command still rejects them loudly in run().
        let err = run(&argv(&["analyze", "oops"])).unwrap_err().to_string();
        assert!(err.contains("oops"), "{err}");
    }

    #[test]
    fn alias_table_resolves_spellings_uniformly() {
        // Singular and plural spellings resolve through one table in
        // both directions; exact spellings win over aliases.
        let a = Args::parse(&argv(&[
            "x", "--fault", "links:2", "--seeds", "1,2", "--patterns", "c2io-sym",
            "--topology", "case-study",
        ]))
        .unwrap();
        assert_eq!(a.get("faults"), Some("links:2"));
        assert_eq!(a.get("fault"), Some("links:2"));
        assert_eq!(a.get("seed"), Some("1,2"));
        assert_eq!(a.get("pattern"), Some("c2io-sym"));
        assert_eq!(a.get("topo"), Some("case-study"));
        assert_eq!(a.get("workload"), None, "unrelated keys stay unset");
        let b = Args::parse(&argv(&["x", "--algo", "dmodk", "--algos", "gdmodk"])).unwrap();
        assert_eq!(b.get("algo"), Some("dmodk"), "exact spelling wins");
        assert_eq!(b.get("algos"), Some("gdmodk"));
        // Every alias group is self-consistent (no key in two groups).
        let mut seen = std::collections::BTreeSet::new();
        for group in ALIAS_GROUPS {
            assert!(group.len() >= 2, "{group:?}");
            for key in *group {
                assert!(seen.insert(*key), "key {key} appears in two alias groups");
            }
        }
    }

    #[test]
    fn analyze_command_runs() {
        run(&argv(&["analyze", "--algo", "dmodk,gdmodk", "--pattern", "c2io-sym"])).unwrap();
    }

    #[test]
    fn topo_command_runs() {
        run(&argv(&["topo", "--leaves"])).unwrap();
        run(&argv(&["topo", "--topo", "4-ary-2-tree"])).unwrap();
    }

    #[test]
    fn fabric_command_runs() {
        run(&argv(&[
            "fabric", "--faults", "cascade:2", "--seed", "2", "--readers", "2", "--query-ms",
            "30",
        ]))
        .unwrap();
        run(&argv(&[
            "fabric", "--burst", "--algo", "dmodk", "--faults", "cascade:4", "--seed", "2",
            "--readers", "1", "--query-ms", "20",
        ]))
        .unwrap();
        // A zero-event scenario is a user error, not a silent no-op.
        assert!(run(&argv(&["fabric", "--faults", "none"])).is_err());
    }

    #[test]
    fn unknown_command_fails() {
        assert!(run(&argv(&["frobnicate"])).is_err());
    }

    #[test]
    fn random_dist_small() {
        run(&argv(&["random-dist", "--trials", "5"])).unwrap();
    }

    #[test]
    fn sweep_command_runs_serial_and_parallel() {
        let base = [
            "sweep", "--topo", "case-study", "--pattern", "c2io-sym",
            "--algo", "dmodk,gdmodk", "--seeds", "1,2",
        ];
        let mut serial: Vec<String> = argv(&base);
        serial.push("--serial".into());
        run(&serial).unwrap();
        let mut threaded: Vec<String> = argv(&base);
        threaded.extend(argv(&["--threads", "3"]));
        run(&threaded).unwrap();
    }

    #[test]
    fn sweep_rejects_bad_seeds() {
        assert!(run(&argv(&["sweep", "--seeds", "one,two"])).is_err());
    }

    #[test]
    fn faults_command_runs_and_is_deterministic() {
        let dir = std::env::temp_dir().join("pgft_faults_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out_a = dir.join("a.csv");
        let out_b = dir.join("b.csv");
        let base = [
            "faults", "--topo", "case-study", "--algo", "dmodk,gdmodk",
            "--pattern", "c2io-sym", "--faults", "none,links:2", "--seeds", "1",
            "--serial", "--format", "csv",
        ];
        let mut a: Vec<String> = argv(&base);
        a.extend(argv(&["--out", out_a.to_str().unwrap()]));
        run(&a).unwrap();
        let mut b: Vec<String> = argv(&base);
        b.extend(argv(&["--out", out_b.to_str().unwrap()]));
        run(&b).unwrap();
        let (ca, cb) = (
            std::fs::read_to_string(&out_a).unwrap(),
            std::fs::read_to_string(&out_b).unwrap(),
        );
        assert_eq!(ca, cb, "same seed must produce byte-identical CSV");
        assert!(ca.lines().next().unwrap().contains("fault"));
        assert_eq!(ca.lines().count(), 1 + 4, "header + 2 algos × 2 faults");
    }

    #[test]
    fn telemetry_flag_writes_schema_and_leaves_output_bytes_alone() {
        let dir = std::env::temp_dir().join("pgft_telemetry_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let plain_csv = dir.join("plain.csv");
        let telem_csv = dir.join("telem.csv");
        let telem_json = dir.join("netsim.json");
        let base = [
            "netsim", "--algo", "dmodk", "--pattern", "c2io-sym", "--rates", "0.1,0.3",
            "--warmup", "50", "--measure", "200", "--drain", "50", "--format", "csv",
        ];
        let mut plain: Vec<String> = argv(&base);
        plain.extend(argv(&["--out", plain_csv.to_str().unwrap()]));
        run(&plain).unwrap();
        let mut instrumented: Vec<String> = argv(&base);
        instrumented.extend(argv(&[
            "--out",
            telem_csv.to_str().unwrap(),
            "--telemetry",
            telem_json.to_str().unwrap(),
        ]));
        run(&instrumented).unwrap();
        assert_eq!(
            std::fs::read_to_string(&plain_csv).unwrap(),
            std::fs::read_to_string(&telem_csv).unwrap(),
            "--telemetry must not perturb a single output byte"
        );
        let doc = std::fs::read_to_string(&telem_json).unwrap();
        assert!(doc.contains("\"schema\": \"pgft-telemetry/1\""), "{doc}");
        assert!(doc.contains("\"command\": \"netsim\""));
        assert!(doc.contains("\"algo\": \"dmodk\""));
        assert!(doc.contains("\"rates\": \"0.1,0.3\""));
        assert!(doc.contains("netsim.port.forwarded_flits"));
        assert!(doc.contains("netsim.vc.occupancy_hwm"));
        assert!(doc.contains("netsim.port.credit_stalls"));
        assert!(doc.contains("netsim.queue_depth"));
        assert!(!doc.contains("null"), "no-null discipline: {doc}");
    }

    #[test]
    fn sweep_and_fabric_emit_telemetry_documents() {
        let dir = std::env::temp_dir().join("pgft_telemetry_cli_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let sweep_json = dir.join("sweep.json");
        run(&argv(&[
            "sweep", "--topo", "case-study", "--pattern", "c2io-sym", "--algo",
            "dmodk,gdmodk", "--faults", "none,links:2", "--serial", "--telemetry",
            sweep_json.to_str().unwrap(),
        ]))
        .unwrap();
        let doc = std::fs::read_to_string(&sweep_json).unwrap();
        assert!(doc.contains("\"sweep.cells\": 4"), "{doc}");
        assert!(doc.contains("sweep.cell.trace"));
        assert!(doc.contains("sweep.cell.retrace"));
        assert!(!doc.contains("null"));
        let fabric_json = dir.join("fabric.json");
        run(&argv(&[
            "fabric", "--burst", "--faults", "cascade:4", "--seed", "2", "--readers", "1",
            "--query-ms", "20", "--telemetry", fabric_json.to_str().unwrap(),
        ]))
        .unwrap();
        let doc = std::fs::read_to_string(&fabric_json).unwrap();
        assert!(doc.contains("\"command\": \"fabric\""));
        assert!(doc.contains("\"kind\": \"repair\""), "journal carries repairs: {doc}");
        assert!(doc.contains("\"kind\": \"restore\""), "drill ends healed: {doc}");
        assert!(doc.contains("fabric.reroutes"));
        assert!(!doc.contains("null"));
    }

    #[test]
    fn eval_emits_retrace_telemetry() {
        let dir = std::env::temp_dir().join("pgft_telemetry_cli_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let eval_json = dir.join("eval.json");
        run(&argv(&[
            "eval", "--algo", "gdmodk", "--faults", "stage:3:2", "--evaluators",
            "congestion", "--telemetry", eval_json.to_str().unwrap(),
        ]))
        .unwrap();
        let doc = std::fs::read_to_string(&eval_json).unwrap();
        assert!(doc.contains("\"eval.retrace.calls\": 1"), "{doc}");
        assert!(doc.contains("eval.retrace.dirty_flows"));
        assert!(doc.contains("eval.retrace.chunk"));
        assert!(!doc.contains("null"));
    }

    #[test]
    fn record_flag_writes_timeseries_and_report_attributes_it() {
        let dir = std::env::temp_dir().join("pgft_recorder_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let plain_csv = dir.join("plain.csv");
        let rec_csv = dir.join("rec.csv");
        let ts_json = dir.join("ts.json");
        let base = [
            "netsim", "--algo", "dmodk,gdmodk", "--pattern", "c2io-sym", "--rates", "0.8",
            "--warmup", "50", "--measure", "200", "--drain", "50", "--format", "csv",
        ];
        let mut plain: Vec<String> = argv(&base);
        plain.extend(argv(&["--out", plain_csv.to_str().unwrap()]));
        run(&plain).unwrap();
        let mut recorded: Vec<String> = argv(&base);
        recorded.extend(argv(&[
            "--out",
            rec_csv.to_str().unwrap(),
            "--record",
            ts_json.to_str().unwrap(),
            "--window",
            "64",
        ]));
        run(&recorded).unwrap();
        assert_eq!(
            std::fs::read_to_string(&plain_csv).unwrap(),
            std::fs::read_to_string(&rec_csv).unwrap(),
            "--record must not perturb a single output byte"
        );
        let doc = std::fs::read_to_string(&ts_json).unwrap();
        assert!(doc.contains("\"schema\": \"pgft-timeseries/1\""), "{doc}");
        assert!(doc.contains("\"command\": \"netsim\""));
        assert!(doc.contains("\"window\": 64"));
        assert!(doc.contains("\"algo\": \"dmodk\""));
        assert!(doc.contains("\"algo\": \"gdmodk\""));
        assert!(doc.contains("\"rate\": \"0.8\""));
        assert!(doc.contains("\"forwarded\""));
        assert!(!doc.contains("null"), "no-null discipline: {doc}");
        // The report command rebuilds the fabric from the recorded
        // provenance and attributes hotspots; the two runs differ only
        // in `algo`, so the within-file diff pairs them.
        let report_csv = dir.join("report.csv");
        run(&argv(&[
            "report",
            ts_json.to_str().unwrap(),
            "--format",
            "csv",
            "--out",
            report_csv.to_str().unwrap(),
        ]))
        .unwrap();
        let rep = std::fs::read_to_string(&report_csv).unwrap();
        assert!(rep.contains("algo=dmodk"), "{rep}");
        assert!(rep.contains("algo=gdmodk"), "{rep}");
    }

    #[test]
    fn workload_record_and_trace_capture_the_phased_replay() {
        let dir = std::env::temp_dir().join("pgft_recorder_cli_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let ts_json = dir.join("wl.json");
        let tr_json = dir.join("wl_trace.json");
        run(&argv(&[
            "workload", "--workload", "checkpoint", "--algo", "gdmodk", "--netsim", "0.3",
            "--record", ts_json.to_str().unwrap(), "--trace", tr_json.to_str().unwrap(),
        ]))
        .unwrap();
        let doc = std::fs::read_to_string(&ts_json).unwrap();
        assert!(doc.contains("\"command\": \"workload\""), "{doc}");
        assert!(doc.contains("\"workload\": \"checkpoint\""));
        assert!(doc.contains("\"phases\": ["));
        assert!(!doc.contains("null"));
        let trace = std::fs::read_to_string(&tr_json).unwrap();
        assert!(trace.contains("\"traceEvents\""), "{trace}");
        assert!(trace.contains("\"ph\": \"C\""), "counter tracks: {trace}");
        assert!(trace.contains("phase"), "phase spans: {trace}");
        // Recording samples the flit replay, so it needs one.
        assert!(run(&argv(&[
            "workload", "--workload", "checkpoint", "--record",
            dir.join("nope.json").to_str().unwrap(),
        ]))
        .is_err());
    }

    #[test]
    fn fabric_trace_and_telemetry_export_journal_and_reach_series() {
        let dir = std::env::temp_dir().join("pgft_recorder_cli_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let telem_json = dir.join("fabric.json");
        let tr_json = dir.join("fabric_trace.json");
        run(&argv(&[
            "fabric", "--burst", "--faults", "cascade:4", "--seed", "2", "--readers", "1",
            "--query-ms", "20", "--telemetry", telem_json.to_str().unwrap(), "--trace",
            tr_json.to_str().unwrap(),
        ]))
        .unwrap();
        let doc = std::fs::read_to_string(&telem_json).unwrap();
        assert!(doc.contains("coordinator.journal.shed"), "{doc}");
        assert!(doc.contains("fabric.reroute_micros_window"));
        assert!(doc.contains("eval.reach.computed"), "repairs route through the lazy arena");
        assert!(doc.contains("eval.retrace.calls"));
        assert!(!doc.contains("null"));
        let trace = std::fs::read_to_string(&tr_json).unwrap();
        assert!(trace.contains("\"traceEvents\""), "{trace}");
        assert!(trace.contains("repair"), "journalled batches become spans: {trace}");
    }

    #[test]
    fn report_command_rejects_bad_usage() {
        assert!(run(&argv(&["report"])).is_err());
        assert!(run(&argv(&["report", "a.json", "b.json", "c.json"])).is_err());
        assert!(run(&argv(&["report", "/definitely/not/there.json"])).is_err());
    }

    #[test]
    fn faults_command_rejects_bad_specs() {
        assert!(run(&argv(&["faults", "--faults", "meteor:3"])).is_err());
    }

    #[test]
    fn eval_command_runs_stacks_and_rejects_bad_evaluators() {
        run(&argv(&[
            "eval", "--algo", "dmodk,gdmodk", "--pattern", "c2io-sym",
            "--evaluators", "congestion,fairrate",
        ]))
        .unwrap();
        // A fault scenario routes through the incremental repair path.
        run(&argv(&[
            "eval", "--algo", "gdmodk", "--faults", "stage:3:2", "--evaluators", "congestion",
        ]))
        .unwrap();
        assert!(run(&argv(&["eval", "--evaluators", "bogus"])).is_err());
        assert!(run(&argv(&["eval", "--evaluators", "netsim:7"])).is_err());
        assert!(run(&argv(&["eval", "--faults", "meteor:3"])).is_err());
    }

    #[test]
    fn eval_size_walks_a_ladder_rung_and_rejects_unknown_ones() {
        // The smallest rung, fault leg off: builds the 16k-endpoint
        // fabric, samples its pairs and scores the store. (The preset
        // links:320 repair leg is exercised by the bench and the
        // retrace property tests — too slow for a debug unit test.)
        run(&argv(&["eval", "--size", "16k", "--faults", "none", "--serial"])).unwrap();
        // Same rung through the arithmetic view: cmd_eval_size asserts
        // the implicit trace is byte-identical to the tables in-line,
        // so a clean exit IS the identity check.
        run(&argv(&[
            "eval", "--size", "16k", "--implicit", "--faults", "none", "--serial",
        ]))
        .unwrap();
        assert!(run(&argv(&["eval", "--size", "2m"])).is_err());
        // Implicit mode refuses evaluator stacks that need port tables.
        assert!(run(&argv(&[
            "eval", "--size", "16k", "--implicit", "--evaluators", "fairrate",
        ]))
        .is_err());
    }

    #[test]
    fn sweep_accepts_faults_axis() {
        run(&argv(&[
            "sweep", "--topo", "case-study", "--pattern", "c2io-sym",
            "--algo", "gdmodk", "--faults", "none,stage:3:2", "--serial",
        ]))
        .unwrap();
    }

    #[test]
    fn netsim_command_runs_and_rejects_bad_args() {
        run(&argv(&[
            "netsim", "--algo", "dmodk", "--pattern", "c2io-sym", "--rates", "0.1",
            "--warmup", "50", "--measure", "200", "--drain", "50",
        ]))
        .unwrap();
        // Unordered rate grids and unknown injection processes fail fast.
        assert!(run(&argv(&["netsim", "--rates", "0.5,0.1"])).is_err());
        assert!(run(&argv(&["netsim", "--injection", "poisson"])).is_err());
        assert!(run(&argv(&["netsim", "--faults", "meteor:3"])).is_err());
    }

    #[test]
    fn workload_command_runs_and_rejects_bad_specs() {
        run(&argv(&["workload", "--workload", "mix", "--algo", "dmodk,gdmodk"])).unwrap();
        // The singular/plural alias and fault scenarios compose; the
        // phase detail can be suppressed.
        run(&argv(&[
            "workload", "--workloads", "checkpoint", "--algo", "gdmodk",
            "--faults", "stage:3:2", "--no-phase-detail",
        ]))
        .unwrap();
        assert!(run(&argv(&["workload", "--workload", "frobnicate"])).is_err());
        assert!(run(&argv(&["workload", "--workload", "single:warp:64"])).is_err());
        assert!(run(&argv(&["workload", "--faults", "meteor:3"])).is_err());
        // --netsim fills the detail table --no-phase-detail suppresses:
        // the conflicting request is rejected, not silently resolved.
        assert!(run(&argv(&[
            "workload", "--workload", "checkpoint", "--algo", "gdmodk",
            "--netsim", "0.2", "--no-phase-detail",
        ]))
        .is_err());
        // A placement without GPGPU nodes cannot host the mix.
        assert!(run(&argv(&["workload", "--placement", "io:last:1"])).is_err());
    }

    #[test]
    fn sweep_accepts_workload_axis() {
        run(&argv(&[
            "sweep", "--topo", "case-study", "--placements", "io:last:1,gpgpu:first:2",
            "--pattern", "c2io-sym", "--algo", "gdmodk",
            "--workload", "single:c2io-sym:1024", "--serial",
        ]))
        .unwrap();
        assert!(run(&argv(&["sweep", "--workload", "frobnicate"])).is_err());
    }

    #[test]
    fn sweep_accepts_netsim_axis() {
        run(&argv(&[
            "sweep", "--topo", "case-study", "--pattern", "c2io-sym",
            "--algo", "gdmodk", "--netsim", "0.1", "--serial",
        ]))
        .unwrap();
        assert!(run(&argv(&["sweep", "--netsim", "2.0"])).is_err(), "rates must be in (0,1]");
    }

    #[test]
    fn sweep_cli_flags_override_config() {
        let dir = std::env::temp_dir().join("pgft_sweep_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("grid.toml");
        std::fs::write(
            &path,
            "[sweep]\npatterns = [\"c2io-sym\"]\nalgorithms = [\"dmodk\"]\nplacements = [\"io:last:1\"]\n",
        )
        .unwrap();
        let p = path.to_str().unwrap();
        // Config alone works, and --algo/--serial compose on top of it
        // instead of being silently dropped.
        run(&argv(&["sweep", "--config", p, "--serial"])).unwrap();
        run(&argv(&["sweep", "--config", p, "--serial", "--algo", "gdmodk"])).unwrap();
        // A `pgft run`-shaped config is rejected, not defaulted.
        let wrong = dir.join("exp.toml");
        std::fs::write(&wrong, "[topology]\nspec = \"case-study\"\n").unwrap();
        assert!(run(&argv(&["sweep", "--config", wrong.to_str().unwrap()])).is_err());
    }
}
