//! Command-line interface (zero-dep argument parser; `clap` is not in the
//! offline vendor set).
//!
//! ```text
//! pgft topo --topo case-study [--dot] [--leaves] [--placement io:last:1]
//! pgft analyze [--topo ..] [--placement ..] [--pattern c2io-sym,c2io-all]
//!              [--algo all|dmodk,...] [--seed N] [--format text|csv|json] [--out FILE]
//! pgft ports --algo dmodk --pattern c2io-sym [--level 3]      # per-port detail (Figs 4-7)
//! pgft random-dist [--trials 1000] [--pattern c2io-sym]       # §III.D histogram
//! pgft simulate [--xla|--no-xla] [--pattern ..] [--algo ..]   # flow-level rates
//! pgft packet-sim [--message 64] [--pattern ..] [--algo ..]   # slot-level sim
//! pgft run --config FILE                                      # full experiment
//! pgft fabric-demo [--algo gdmodk]                            # coordinator + fault drill
//! pgft artifacts                                              # runtime manifest
//! ```

use crate::config::ExperimentConfig;
use crate::coordinator::Coordinator;
use crate::metrics::{render_algorithm_table, AlgoSummary, CongestionReport};
use crate::nodes::{NodeTypeMap, Placement};
use crate::patterns::Pattern;
use crate::report::Table;
use crate::routing::trace::trace_flows;
use crate::routing::AlgorithmKind;
use crate::sim::{render_sim_table, simulate_flow_level, PacketSim, PacketSimConfig};
use crate::topology::{families, render, Topology};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Parsed `--key value` / `--flag` arguments.
pub struct Args {
    pub cmd: String,
    opts: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".to_string());
        let mut opts = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .with_context(|| format!("expected --option, got {a:?}"))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                opts.insert(key.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                opts.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { cmd, opts })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not a number")),
            None => Ok(default),
        }
    }
}

fn load_topo(args: &Args) -> Result<(Topology, NodeTypeMap)> {
    let topo = families::named(&args.get_or("topo", "case-study"))?;
    crate::topology::validate::validate(&topo)?;
    let placement = Placement::parse(&args.get_or("placement", "io:last:1"))?;
    let types = placement.apply(&topo)?;
    Ok((topo, types))
}

fn parse_algos(args: &Args) -> Result<Vec<AlgorithmKind>> {
    let spec = args.get_or("algo", "all");
    if spec == "all" {
        return Ok(AlgorithmKind::ALL.to_vec());
    }
    spec.split(',').map(AlgorithmKind::parse).collect()
}

fn parse_patterns(args: &Args, default: &str) -> Result<Vec<Pattern>> {
    args.get_or("pattern", default)
        .split(',')
        .map(Pattern::parse)
        .collect()
}

fn emit(table: &Table, args: &Args) -> Result<()> {
    let format = args.get_or("format", "text");
    if let Some(path) = args.get("out") {
        table.write(path, &format)?;
        eprintln!("wrote {path}");
    } else {
        let body = match format.as_str() {
            "csv" => table.to_csv(),
            "json" => table.to_json(),
            _ => table.to_text(),
        };
        print!("{body}");
    }
    Ok(())
}

/// Entry point used by `main.rs`; returns the process exit code.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.cmd.as_str() {
        "topo" => cmd_topo(&args),
        "analyze" => cmd_analyze(&args),
        "ports" => cmd_ports(&args),
        "random-dist" => cmd_random_dist(&args),
        "simulate" => cmd_simulate(&args),
        "packet-sim" => cmd_packet_sim(&args),
        "run" => cmd_run(&args),
        "fabric-demo" => cmd_fabric_demo(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `pgft help`"),
    }
}

const HELP: &str = r#"pgft — node-type-based load-balancing routing for PGFTs

commands:
  topo         show a topology (--topo case-study|medium-512|PGFT(...); --dot; --leaves)
  analyze      congestion table per algorithm × pattern (the paper's analysis)
  ports        per-port detail for one algorithm/pattern (Figs 4-7)
  random-dist  C_topo histogram over random-routing seeds (§III.D)
  simulate     flow-level max-min throughput (XLA/PJRT or rust solver)
  packet-sim   slot-level packet simulation (completion time)
  run          full experiment from a TOML config (--config FILE)
  fabric-demo  coordinator lifecycle: route, fail links, reroute, report
  artifacts    list AOT artifacts the runtime can execute
common options:
  --topo NAME --placement SPEC --algo LIST|all --pattern LIST --seed N
  --format text|csv|json --out FILE
"#;

fn cmd_topo(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    print!("{}", render::render_summary(&topo, Some(&types)));
    if args.flag("leaves") {
        print!("{}", render::render_leaves(&topo, &types));
    }
    if args.flag("dot") {
        print!("{}", render::render_dot(&topo, Some(&types)));
    }
    Ok(())
}

fn summary_table(rows: &[AlgoSummary]) -> Table {
    let mut t = Table::new(
        "congestion analysis (static metric, §III.A)",
        &["algo", "pattern", "flows", "C_topo", "hot_ports", "hot_top", "used_top", "total_top"],
    );
    for r in rows {
        let h = r.hot_per_level.len() - 1;
        t.row(&[
            r.algorithm.clone(),
            r.pattern.clone(),
            r.flows.to_string(),
            r.c_topo.to_string(),
            r.hot_total.to_string(),
            r.hot_per_level[h].to_string(),
            r.used_top_ports.to_string(),
            r.total_top_ports.to_string(),
        ]);
    }
    t
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let seed = args.u64_or("seed", 1)?;
    let mut rows = Vec::new();
    for pattern in parse_patterns(args, "c2io-sym,c2io-all")? {
        for kind in parse_algos(args)? {
            rows.push(AlgoSummary::compute(&topo, &types, kind, &pattern, seed)?);
        }
    }
    emit(&summary_table(&rows), args)?;
    eprintln!();
    eprint!("{}", render_algorithm_table(&rows));
    Ok(())
}

fn cmd_ports(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let kind = AlgorithmKind::parse(&args.get_or("algo", "dmodk"))?;
    let pattern = Pattern::parse(&args.get_or("pattern", "c2io-sym"))?;
    let router = kind.build(&topo, Some(&types), args.u64_or("seed", 1)?);
    let flows = pattern.flows(&topo, &types)?;
    let routes = trace_flows(&topo, &*router, &flows);
    let rep = CongestionReport::compute(&topo, &routes);
    let level: Option<usize> = args.get("level").map(|v| v.parse()).transpose()?;
    let mut t = Table::new(
        format!("per-port flows: {} on {}", kind, pattern.name()),
        &["port", "dir", "level", "routes", "srcs", "dsts", "C_p"],
    );
    for port in &topo.ports {
        let st = rep.per_port[port.id];
        if st.routes == 0 {
            continue;
        }
        let lvl = topo.port_level(port.id);
        if let Some(l) = level {
            if lvl != l {
                continue;
            }
        }
        t.row(&[
            topo.port_label(port.id),
            if port.up { "up".into() } else { "down".into() },
            lvl.to_string(),
            st.routes.to_string(),
            st.srcs.to_string(),
            st.dsts.to_string(),
            st.c().to_string(),
        ]);
    }
    emit(&t, args)?;
    eprintln!("C_topo = {}", rep.c_topo());
    Ok(())
}

fn cmd_random_dist(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let pattern = Pattern::parse(&args.get_or("pattern", "c2io-sym"))?;
    let trials = args.u64_or("trials", 1000)?;
    let flows = pattern.flows(&topo, &types)?;
    let mut hist: BTreeMap<u32, u64> = BTreeMap::new();
    for seed in 0..trials {
        let router = AlgorithmKind::Random.build(&topo, Some(&types), seed);
        *hist
            .entry(CongestionReport::compute_flows(&topo, &*router, &flows).c_topo())
            .or_default() += 1;
    }
    let mut t = Table::new(
        format!("C_topo distribution over {trials} random routings ({})", pattern.name()),
        &["C_topo", "count", "fraction"],
    );
    for (c, n) in &hist {
        t.row(&[c.to_string(), n.to_string(), format!("{:.4}", *n as f64 / trials as f64)]);
    }
    emit(&t, args)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let seed = args.u64_or("seed", 1)?;
    let runtime = if args.flag("no-xla") {
        None
    } else {
        match crate::runtime::Runtime::open_default() {
            Ok(rt) => {
                eprintln!("PJRT platform: {}", rt.platform());
                Some(rt)
            }
            Err(e) => {
                eprintln!("XLA runtime unavailable ({e:#}); using rust solver");
                None
            }
        }
    };
    let mut rows = Vec::new();
    for pattern in parse_patterns(args, "c2io-sym")? {
        for kind in parse_algos(args)? {
            rows.push(simulate_flow_level(&topo, &types, kind, &pattern, seed, runtime.as_ref())?);
        }
    }
    let mut t = Table::new(
        "flow-level max-min simulation",
        &["algo", "pattern", "flows", "agg_thru", "min_rate", "completion", "C_topo", "solver"],
    );
    for r in &rows {
        t.row(&[
            r.algorithm.clone(),
            r.pattern.clone(),
            r.flows.to_string(),
            format!("{:.3}", r.aggregate_throughput),
            format!("{:.4}", r.min_rate),
            format!("{:.2}", r.completion_time),
            r.c_topo.to_string(),
            r.solver.clone(),
        ]);
    }
    emit(&t, args)?;
    eprint!("{}", render_sim_table(&rows));
    Ok(())
}

fn cmd_packet_sim(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let seed = args.u64_or("seed", 1)?;
    let cfg = PacketSimConfig {
        message_packets: args.u64_or("message", 64)? as u32,
        queue_capacity: args.u64_or("queue", 8)? as usize,
        max_slots: args.u64_or("max-slots", 1_000_000)?,
    };
    let mut t = Table::new(
        "packet-level simulation",
        &["algo", "pattern", "flows", "completion_slots", "throughput", "max_queue"],
    );
    for pattern in parse_patterns(args, "c2io-sym")? {
        let flows = pattern.flows(&topo, &types)?;
        for kind in parse_algos(args)? {
            let router = kind.build(&topo, Some(&types), seed);
            let routes = trace_flows(&topo, &*router, &flows);
            let res = PacketSim::new(&topo, &routes, cfg.clone()).run();
            t.row(&[
                kind.as_str().to_string(),
                pattern.name(),
                flows.len().to_string(),
                res.completion_slots.to_string(),
                format!("{:.3}", res.throughput),
                res.max_queue_depth.to_string(),
            ]);
        }
    }
    emit(&t, args)
}

fn cmd_run(args: &Args) -> Result<()> {
    let path = args.get("config").context("--config FILE required")?;
    let cfg = ExperimentConfig::from_file(path)?;
    let topo = crate::topology::build_pgft(&cfg.topology);
    crate::topology::validate::validate(&topo)?;
    let types = cfg.placement.apply(&topo)?;
    println!("{}", render::render_summary(&topo, Some(&types)));

    // Static analysis.
    let mut rows = Vec::new();
    for pattern in &cfg.patterns {
        for &kind in &cfg.algorithms {
            rows.push(AlgoSummary::compute(&topo, &types, kind, pattern, cfg.seed)?);
        }
    }
    print!("{}", render_algorithm_table(&rows));

    // Flow-level simulation.
    let runtime = if cfg.use_xla { crate::runtime::Runtime::open_default().ok() } else { None };
    let mut sims = Vec::new();
    for pattern in &cfg.patterns {
        for &kind in &cfg.algorithms {
            sims.push(simulate_flow_level(&topo, &types, kind, pattern, cfg.seed, runtime.as_ref())?);
        }
    }
    print!("{}", render_sim_table(&sims));
    Ok(())
}

fn cmd_fabric_demo(args: &Args) -> Result<()> {
    let (topo, types) = load_topo(args)?;
    let kind = AlgorithmKind::parse(&args.get_or("algo", "gdmodk"))?;
    let topo = Arc::new(topo);
    let coord = Coordinator::start(topo.clone(), types, kind, args.u64_or("seed", 1)?)?;
    println!("fabric up: {:?}", coord.stats()?);
    println!("C2IO analysis: {:?}", coord.analyze(Pattern::C2ioSym)?.c_topo);
    // Fault drill: kill two top-stage links, reroute, verify, revive.
    let victims: Vec<_> = topo.links.iter().filter(|l| l.stage == topo.spec.h).take(2).collect();
    for v in &victims {
        coord.link_down(v.id);
        let s = coord.stats()?;
        println!(
            "link {} down → v{} reroute {} µs, diff {} entries",
            v.id, s.table_version, s.last_reroute_micros, s.last_diff_entries
        );
    }
    println!("degraded C2IO C_topo: {}", coord.analyze(Pattern::C2ioSym)?.c_topo);
    for v in &victims {
        coord.link_up(v.id);
    }
    println!("healed: {:?}", coord.stats()?);
    coord.shutdown();
    Ok(())
}

fn cmd_artifacts(_args: &Args) -> Result<()> {
    let rt = crate::runtime::Runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());
    let mut t = Table::new("AOT artifacts", &["name", "kind", "flows", "ports", "iters"]);
    for a in rt.manifest() {
        t.row(&[
            a.name.clone(),
            a.kind.clone(),
            a.flows.to_string(),
            a.ports.to_string(),
            a.iters.to_string(),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn args_parse_forms() {
        let a = Args::parse(&argv(&["analyze", "--algo", "dmodk", "--dot", "--seed", "3"])).unwrap();
        assert_eq!(a.cmd, "analyze");
        assert_eq!(a.get("algo"), Some("dmodk"));
        assert!(a.flag("dot"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 3);
        assert_eq!(a.get_or("missing", "x"), "x");
        assert!(Args::parse(&argv(&["c", "oops"])).is_err());
    }

    #[test]
    fn analyze_command_runs() {
        run(&argv(&["analyze", "--algo", "dmodk,gdmodk", "--pattern", "c2io-sym"])).unwrap();
    }

    #[test]
    fn topo_command_runs() {
        run(&argv(&["topo", "--leaves"])).unwrap();
        run(&argv(&["topo", "--topo", "4-ary-2-tree"])).unwrap();
    }

    #[test]
    fn unknown_command_fails() {
        assert!(run(&argv(&["frobnicate"])).is_err());
    }

    #[test]
    fn random_dist_small() {
        run(&argv(&["random-dist", "--trials", "5"])).unwrap();
    }
}
