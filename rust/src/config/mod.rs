//! Experiment configuration: a zero-dependency TOML-subset parser plus
//! the typed experiment config the launcher consumes.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with
//! string / integer / float / boolean / flat array values, `#` comments.
//! That covers every config this repo ships; nested tables and dates are
//! intentionally out of scope (the offline vendor set has no `toml`
//! crate — see DESIGN.md substitutions).

use crate::nodes::Placement;
use crate::patterns::Pattern;
use crate::routing::AlgorithmKind;
use crate::topology::PgftSpec;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A floating-point literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat `[a, b, c]` array.
    Array(Vec<Value>),
}

impl Value {
    /// The string payload, or an error for any other value kind.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    /// The integer payload, or an error for any other value kind.
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    /// The value as a float (integers widen), or an error.
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }

    /// The boolean payload, or an error for any other value kind.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    /// String array (a lone string counts as a one-element array).
    pub fn as_str_array(&self) -> Result<Vec<String>> {
        match self {
            Value::Array(xs) => xs.iter().map(|v| v.as_str().map(str::to_string)).collect(),
            Value::Str(s) => Ok(vec![s.clone()]),
            other => bail!("expected array of strings, got {other:?}"),
        }
    }

    /// Integer array (a lone integer counts as a one-element array).
    pub fn as_int_array(&self) -> Result<Vec<i64>> {
        match self {
            Value::Array(xs) => xs.iter().map(|v| v.as_int()).collect(),
            Value::Int(i) => Ok(vec![*i]),
            other => bail!("expected array of integers, got {other:?}"),
        }
    }

    /// Float array; integers widen and a lone number counts as a
    /// one-element array (the `[sweep] netsim = [0.1, 0.2]` axis).
    pub fn as_float_array(&self) -> Result<Vec<f64>> {
        match self {
            Value::Array(xs) => xs.iter().map(|v| v.as_float()).collect(),
            Value::Float(f) => Ok(vec![*f]),
            Value::Int(i) => Ok(vec![*i as f64]),
            other => bail!("expected array of numbers, got {other:?}"),
        }
    }
}

/// Parsed document: section → key → value. Top-level keys live in `""`.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    /// Section name → (key → value); top-level keys under `""`.
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Parse the TOML subset (see module docs) into a [`Doc`].
    pub fn parse(text: &str) -> Result<Doc> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value: {raw:?}", lineno + 1))?;
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value {v:?}", lineno + 1))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    /// String lookup with a default for missing keys.
    pub fn get_str(&self, section: &str, key: &str, default: &str) -> Result<String> {
        match self.get(section, key) {
            Some(v) => Ok(v.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    /// Integer lookup with a default for missing keys.
    pub fn get_int(&self, section: &str, key: &str, default: i64) -> Result<i64> {
        match self.get(section, key) {
            Some(v) => v.as_int(),
            None => Ok(default),
        }
    }

    /// Boolean lookup with a default for missing keys.
    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            Some(v) => v.as_bool(),
            None => Ok(default),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    ensure!(!s.is_empty(), "empty value");
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?} (quote strings)")
}

/// Split on top-level commas (strings may contain commas).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

/// The typed experiment configuration used by `pgft run --config`.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// The topology spec string as written in the config (named family
    /// or `PGFT(...)` form) — kept so the sweep engine can re-resolve it.
    pub topology_name: String,
    /// Resolved topology parameters.
    pub topology: PgftSpec,
    /// The placement spec string as written in the config.
    pub placement_spec: String,
    /// Resolved placement strategy.
    pub placement: Placement,
    /// Algorithms to compare.
    pub algorithms: Vec<AlgorithmKind>,
    /// Patterns to route.
    pub patterns: Vec<Pattern>,
    /// Seed for the seed-sensitive (random) algorithms.
    pub seed: u64,
    /// Message size for the packet-level simulator.
    pub sim_message_packets: u32,
    /// Prefer the XLA/PJRT solver when artifacts are available.
    pub use_xla: bool,
}

impl ExperimentConfig {
    /// Build a typed config from a parsed [`Doc`], filling defaults.
    pub fn from_doc(doc: &Doc) -> Result<ExperimentConfig> {
        let topo_name = doc.get_str("topology", "spec", "case-study")?;
        let topology = crate::topology::families::named_spec(&topo_name)?;
        let placement_spec = doc.get_str("topology", "placement", "io:last:1")?;
        let placement = Placement::parse(&placement_spec)?;
        let algos = match doc.get("run", "algorithms") {
            Some(v) => v.as_str_array()?,
            None => AlgorithmKind::ALL.iter().map(|k| k.as_str().to_string()).collect(),
        };
        let algorithms = algos
            .iter()
            .map(|a| AlgorithmKind::parse(a))
            .collect::<Result<Vec<_>>>()?;
        let pats = match doc.get("run", "patterns") {
            Some(v) => v.as_str_array()?,
            None => vec!["c2io-sym".to_string(), "c2io-all".to_string()],
        };
        let patterns = pats.iter().map(|p| Pattern::parse(p)).collect::<Result<Vec<_>>>()?;
        Ok(ExperimentConfig {
            topology_name: topo_name,
            topology,
            placement_spec,
            placement,
            algorithms,
            patterns,
            seed: doc.get_int("run", "seed", 1)? as u64,
            sim_message_packets: doc.get_int("sim", "message_packets", 64)? as u32,
            use_xla: doc
                .get("sim", "use_xla")
                .map(|v| v.as_bool())
                .transpose()?
                .unwrap_or(true),
        })
    }

    /// Read and parse an experiment config file.
    pub fn from_file(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        Self::from_doc(&Doc::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# demo config
[topology]
spec = "case-study"          # the paper's PGFT
placement = "io:last:1"

[run]
algorithms = ["dmodk", "gdmodk"]
patterns = ["c2io-sym"]
seed = 7

[sim]
message_packets = 32
use_xla = false
"#;

    #[test]
    fn parse_sample() {
        let doc = Doc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get_str("topology", "spec", "").unwrap(), "case-study");
        assert_eq!(doc.get_int("run", "seed", 0).unwrap(), 7);
        assert_eq!(doc.get("sim", "use_xla").unwrap().as_bool().unwrap(), false);
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.topology, PgftSpec::case_study());
        assert_eq!(cfg.algorithms, vec![AlgorithmKind::Dmodk, AlgorithmKind::Gdmodk]);
        assert_eq!(cfg.patterns.len(), 1);
        assert_eq!(cfg.sim_message_packets, 32);
        assert!(!cfg.use_xla);
    }

    #[test]
    fn value_forms() {
        let doc = Doc::parse(
            "a = 1\nb = 2.5\nc = \"x # y\"\nd = [1, 2, 3]\ne = true\n[s]\nf = [\"p,q\", \"r\"]\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("", "b").unwrap().as_float().unwrap(), 2.5);
        assert_eq!(doc.get("", "c").unwrap().as_str().unwrap(), "x # y");
        assert_eq!(
            doc.get("", "d").unwrap(),
            &Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert!(doc.get("", "e").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("s", "f").unwrap().as_str_array().unwrap(), vec!["p,q", "r"]);
        assert_eq!(doc.get("", "d").unwrap().as_int_array().unwrap(), vec![1, 2, 3]);
        assert_eq!(doc.get("", "a").unwrap().as_int_array().unwrap(), vec![1]);
        assert!(doc.get("", "c").unwrap().as_int_array().is_err());
        assert_eq!(doc.get("", "d").unwrap().as_float_array().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(doc.get("", "b").unwrap().as_float_array().unwrap(), vec![2.5]);
        assert!(doc.get("", "c").unwrap().as_float_array().is_err());
        assert!(doc.get_bool("", "e", false).unwrap());
        assert!(doc.get_bool("", "missing", true).unwrap());
    }

    #[test]
    fn errors_are_reported() {
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("x = ").is_err());
        assert!(Doc::parse("x = \"unterminated").is_err());
        assert!(Doc::parse("x = [1, 2").is_err());
        assert!(Doc::parse("x = what").is_err());
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = ExperimentConfig::from_doc(&Doc::parse("").unwrap()).unwrap();
        assert_eq!(cfg.algorithms.len(), 6);
        assert_eq!(cfg.patterns.len(), 2);
        assert!(cfg.use_xla);
    }
}
