//! Zero-dependency substrates: PRNG, property-testing, bench harness.

pub mod bench;
pub mod prop;
pub mod rng;
