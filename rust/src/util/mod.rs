//! Zero-dependency substrates: PRNG, property-testing, bench harness,
//! and the rayon-style parallel map the sweep engine runs on.

pub mod bench;
pub mod par;
pub mod prop;
pub mod rng;
