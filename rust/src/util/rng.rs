//! Deterministic pseudo-random number generators.
//!
//! The offline build environment vendors only the `xla` crate closure, so
//! the `rand` crate is unavailable; routing algorithms and property tests
//! use these small, well-known generators instead. Both are fully
//! deterministic from their seed, which is what a fabric manager wants
//! anyway: "random" routing must be reproducible across leader restarts.

/// SplitMix64 — used to seed xoshiro and for cheap hashing-style streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the generator.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniform bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main PRNG for route randomization, workload
/// generation and property tests. Passes BigCrush; period 2^256 - 1.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors (avoids
    /// the all-zero state and correlated low-entropy seeds).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 uniform bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (from the public-domain
        // reference implementation).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256::new(43);
        let same = (0..100).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 3, "different seeds should diverge");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Xoshiro256::new(7);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = rng.next_below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn next_below_one_is_zero() {
        let mut rng = Xoshiro256::new(9);
        for _ in 0..10 {
            assert_eq!(rng.next_below(1), 0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Xoshiro256::new(11);
        for _ in 0..50 {
            let s = rng.sample_indices(20, 10);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 10, "indices must be distinct: {s:?}");
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Xoshiro256::new(3);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
