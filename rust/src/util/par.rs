//! Minimal data-parallel substrate for the sweep engine.
//!
//! `rayon` is not in the offline vendor set (which holds only the `xla`
//! crate closure), so this module hand-rolls the rayon-style slice the
//! repo needs — an indexed parallel map over a slice with
//!
//!  * **work stealing** via a shared atomic cursor (cells vary wildly in
//!    cost: an all-to-all trace on `medium-512` is ~1000× a case-study
//!    cell, so static chunking would idle most workers), and
//!  * **deterministic, input-ordered results**: every item writes to its
//!    own slot, so the output is independent of scheduling. This is what
//!    lets `pgft sweep` guarantee byte-identical output with and without
//!    `--serial`.
//!
//! Workers are scoped threads ([`std::thread::scope`]) — no pool object
//! to manage, no `'static` bounds, and a panicking cell propagates to the
//! caller exactly as it would serially.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default: the hardware parallelism
/// reported by the OS, or 1 when unknown.
pub fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` on up to `threads` scoped worker threads and
/// return the results in input order.
///
/// `f` receives `(index, &item)`. With `threads <= 1` (or one item) the
/// map degenerates to a plain serial loop on the calling thread — the
/// `--serial` reference path. Results are identical either way.
pub fn par_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads == 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<R>>> =
        Mutex::new(std::iter::repeat_with(|| None).take(items.len()).collect());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                // Compute outside the lock; the lock only guards the
                // O(1) slot store, so contention is negligible for the
                // coarse-grained cells the sweep engine schedules.
                let r = f(i, &items[i]);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|slot| slot.expect("every index was claimed by a worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_input_order() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map(4, &items, |i, &x| {
            assert_eq!(i as u64, x);
            x * x
        });
        let expect: Vec<u64> = items.iter().map(|&x| x * x).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn parallel_equals_serial() {
        let items: Vec<u32> = (0..100).rev().collect();
        let serial = par_map(1, &items, |i, &x| (i, x.wrapping_mul(2654435761)));
        for threads in [2, 3, 8, 64] {
            assert_eq!(par_map(threads, &items, |i, &x| (i, x.wrapping_mul(2654435761))), serial);
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<i32> = Vec::new();
        assert!(par_map(8, &none, |_, &x| x).is_empty());
        assert_eq!(par_map(8, &[41], |_, &x| x + 1), vec![42]);
    }

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }
}
