//! Minimal data-parallel substrate for the sweep engine.
//!
//! `rayon` is not in the offline vendor set (which holds only the `xla`
//! crate closure), so this module hand-rolls the rayon-style slice the
//! repo needs — an indexed parallel map over a slice with
//!
//!  * **work stealing** via a shared atomic cursor (cells vary wildly in
//!    cost: an all-to-all trace on `medium-512` is ~1000× a case-study
//!    cell, so static chunking would idle most workers),
//!  * **deterministic, input-ordered results**: every item writes to its
//!    own slot, so the output is independent of scheduling. This is what
//!    lets `pgft sweep` guarantee byte-identical output with and without
//!    `--serial`, and
//!  * **fail-fast panic propagation**: a panicking closure is caught on
//!    the worker, every other worker stops claiming new items, and the
//!    original payload is resumed on the *caller* thread once the scope
//!    joins — instead of the remaining workers draining the whole queue
//!    (minutes of doomed cells on a large sweep) before the panic
//!    surfaces.
//!
//! Workers are scoped threads ([`std::thread::scope`]) — no pool object
//! to manage and no `'static` bounds.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default: the hardware parallelism
/// reported by the OS, or 1 when unknown.
pub fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` on up to `threads` scoped worker threads and
/// return the results in input order.
///
/// `f` receives `(index, &item)`. With `threads <= 1` (or one item) the
/// map degenerates to a plain serial loop on the calling thread — the
/// `--serial` reference path. Results are identical either way.
///
/// If `f` panics on any item, the first panic payload is re-raised on
/// the calling thread (like the serial loop would) and the remaining
/// workers abandon the queue as soon as they observe the abort flag —
/// they never hang parked on unclaimed items.
pub fn par_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads == 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let aborted = AtomicBool::new(false);
    // First panic payload wins; later ones (already-running items) are
    // dropped, matching what a serial loop would have surfaced.
    let panic_payload: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);
    let slots: Mutex<Vec<Option<R>>> =
        Mutex::new(std::iter::repeat_with(|| None).take(items.len()).collect());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                if aborted.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                // Compute outside the locks; they only guard O(1)
                // stores, so contention is negligible for the
                // coarse-grained cells the sweep engine schedules.
                // `AssertUnwindSafe` is sound here: on panic the whole
                // map is abandoned and only the payload escapes, so no
                // closure state is observed in a broken state.
                match catch_unwind(AssertUnwindSafe(|| f(i, &items[i]))) {
                    Ok(r) => slots.lock().unwrap()[i] = Some(r),
                    Err(payload) => {
                        aborted.store(true, Ordering::Relaxed);
                        panic_payload.lock().unwrap().get_or_insert(payload);
                        break;
                    }
                }
            });
        }
    });
    if let Some(payload) = panic_payload.into_inner().unwrap() {
        resume_unwind(payload);
    }
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|slot| slot.expect("every index was claimed by a worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_input_order() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map(4, &items, |i, &x| {
            assert_eq!(i as u64, x);
            x * x
        });
        let expect: Vec<u64> = items.iter().map(|&x| x * x).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn parallel_equals_serial() {
        let items: Vec<u32> = (0..100).rev().collect();
        let serial = par_map(1, &items, |i, &x| (i, x.wrapping_mul(2654435761)));
        for threads in [2, 3, 8, 64] {
            assert_eq!(par_map(threads, &items, |i, &x| (i, x.wrapping_mul(2654435761))), serial);
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<i32> = Vec::new();
        assert!(par_map(8, &none, |_, &x| x).is_empty());
        assert_eq!(par_map(8, &[41], |_, &x| x + 1), vec![42]);
    }

    #[test]
    fn worker_panic_fails_fast_and_propagates() {
        let items: Vec<usize> = (0..2000).collect();
        let completed = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            par_map(8, &items, |i, _| {
                if i == 0 {
                    panic!("boom at {i}");
                }
                // Each surviving item sleeps ~1 ms, so draining the full
                // queue would take ~250 ms across 7 workers: if the
                // abort flag did not stop them, `completed` would reach
                // the item count and the assertion below would fail.
                std::thread::sleep(std::time::Duration::from_millis(1));
                completed.fetch_add(1, Ordering::Relaxed);
            })
        }));
        let payload = result.expect_err("worker panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string payload>".to_string());
        assert!(msg.contains("boom"), "original payload must survive: {msg:?}");
        assert!(
            completed.load(Ordering::Relaxed) < items.len() - 1,
            "workers kept draining the queue after the panic"
        );
    }

    #[test]
    fn serial_path_panic_still_propagates() {
        let items = vec![1u32];
        let result = catch_unwind(AssertUnwindSafe(|| {
            par_map(1, &items, |_, _| -> u32 { panic!("serial boom") })
        }));
        assert!(result.is_err());
    }

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }
}
