//! Zero-dependency micro/macro benchmark harness.
//!
//! `criterion` is unavailable offline; this module provides the part the
//! benches need: warmup, timed iterations, robust statistics
//! (median / p95 / mean / stddev), throughput reporting and a stable
//! text output format that `cargo bench` prints and EXPERIMENTS.md quotes.
//!
//! **Smoke mode:** setting `PGFT_BENCH_SMOKE=1` clamps every [`Bench`]
//! to zero warmup and a single timed sample, regardless of builder
//! configuration. CI runs benches this way — the numbers are
//! meaningless, but the bench *code* executes end to end on every push,
//! so benches cannot silently rot.

use std::time::{Duration, Instant};

/// Whether `PGFT_BENCH_SMOKE` requests 1-iteration smoke runs.
fn smoke_mode() -> bool {
    matches!(std::env::var("PGFT_BENCH_SMOKE"), Ok(v) if !v.is_empty() && v != "0")
}

/// Robust summary statistics over per-iteration wall-clock samples.
#[derive(Clone, Debug)]
pub struct Stats {
    /// Number of timed iterations.
    pub samples: usize,
    /// Mean per-iteration time in nanoseconds.
    pub mean_ns: f64,
    /// Standard deviation in nanoseconds.
    pub stddev_ns: f64,
    /// Fastest iteration in nanoseconds.
    pub min_ns: f64,
    /// Median iteration in nanoseconds.
    pub median_ns: f64,
    /// 95th-percentile iteration in nanoseconds.
    pub p95_ns: f64,
    /// Slowest iteration in nanoseconds.
    pub max_ns: f64,
}

impl Stats {
    /// Summarize raw per-iteration samples (nanoseconds).
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |q: f64| -> f64 {
            let idx = (q * (n - 1) as f64).round() as usize;
            ns[idx.min(n - 1)]
        };
        Stats {
            samples: n,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            min_ns: ns[0],
            median_ns: pct(0.5),
            p95_ns: pct(0.95),
            max_ns: ns[n - 1],
        }
    }
}

fn human_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// A single benchmark definition. Build with [`Bench::new`], configure,
/// then call [`Bench::run`] with the closure to measure.
pub struct Bench {
    name: String,
    warmup: Duration,
    min_samples: usize,
    max_samples: usize,
    target_time: Duration,
    /// Elements processed per iteration, for throughput lines.
    throughput: Option<u64>,
}

impl Bench {
    /// A benchmark with default warmup/sample/time budgets.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup: Duration::from_millis(200),
            min_samples: 10,
            max_samples: 200,
            target_time: Duration::from_secs(1),
            throughput: None,
        }
    }

    /// Set the warmup duration.
    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    /// Bound the number of timed samples.
    pub fn samples(mut self, min: usize, max: usize) -> Self {
        self.min_samples = min;
        self.max_samples = max.max(min);
        self
    }

    /// Set the target total sampling time (sample count adapts to it).
    pub fn target_time(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Report throughput as `elems/s` assuming `elems` per iteration.
    pub fn throughput_elems(mut self, elems: u64) -> Self {
        self.throughput = Some(elems);
        self
    }

    /// Measure `f`, print a criterion-like line, return the stats.
    /// `f` receives the iteration index; use `std::hint::black_box` inside.
    pub fn run<F: FnMut(usize)>(mut self, mut f: F) -> Stats {
        // CI smoke mode overrides every budget (see module docs): the
        // clamp lives here, after the builders, so call sites cannot
        // accidentally undo it.
        if smoke_mode() {
            self.warmup = Duration::ZERO;
            self.min_samples = 1;
            self.max_samples = 1;
            self.target_time = Duration::ZERO;
        }
        // Warmup.
        let w0 = Instant::now();
        let mut i = 0usize;
        while w0.elapsed() < self.warmup {
            f(i);
            i += 1;
        }
        // Sampling: adapt count to target_time using a pilot iteration.
        let pilot = {
            let t = Instant::now();
            f(i);
            i += 1;
            t.elapsed().as_secs_f64().max(1e-9)
        };
        let want = (self.target_time.as_secs_f64() / pilot) as usize;
        let count = want.clamp(self.min_samples, self.max_samples);
        let mut samples = Vec::with_capacity(count);
        for _ in 0..count {
            let t = Instant::now();
            f(i);
            i += 1;
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let st = Stats::from_samples(samples);
        let mut line = format!(
            "bench {:<44} median {:>10}  p95 {:>10}  mean {:>10} ± {:>9}  (n={})",
            self.name,
            human_ns(st.median_ns),
            human_ns(st.p95_ns),
            human_ns(st.mean_ns),
            human_ns(st.stddev_ns),
            st.samples
        );
        if let Some(e) = self.throughput {
            let eps = e as f64 / (st.median_ns / 1e9);
            line.push_str(&format!("  [{:.3} Melem/s]", eps / 1e6));
        }
        println!("{line}");
        st
    }
}

/// Measure a one-shot (non-repeatable or long) operation.
pub fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    let d = t.elapsed();
    println!("once  {:<44} {:>10}", name, human_ns(d.as_nanos() as f64));
    (out, d)
}

/// Human-readable duration in the same units the bench lines use.
pub fn human_duration(d: Duration) -> String {
    human_ns(d.as_nanos() as f64)
}

/// Print a serial-vs-parallel comparison line and return the speedup
/// factor (used by the sweep-engine benches).
pub fn speedup_line(name: &str, serial: Duration, parallel: Duration) -> f64 {
    let x = serial.as_secs_f64() / parallel.as_secs_f64().max(1e-12);
    println!(
        "speedup {:<42} serial {:>10}  parallel {:>10}  → {:.2}x",
        name,
        human_duration(serial),
        human_duration(parallel),
        x
    );
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let st = Stats::from_samples(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(st.min_ns, 1.0);
        assert_eq!(st.max_ns, 5.0);
        assert_eq!(st.median_ns, 3.0);
        assert!(st.p95_ns >= st.median_ns);
        assert!((st.mean_ns - 3.0).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut calls = 0usize;
        let st = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .samples(5, 5)
            .target_time(Duration::from_millis(1))
            .run(|_| {
                calls += 1;
                std::hint::black_box(calls);
            });
        assert_eq!(st.samples, 5);
        assert!(calls >= 6); // warmup + pilot + 5 samples
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn human_ns_units() {
        assert!(human_ns(12.0).ends_with("ns"));
        assert!(human_ns(12_000.0).ends_with("µs"));
        assert!(human_ns(12_000_000.0).ends_with("ms"));
        assert!(human_ns(2_000_000_000.0).ends_with('s'));
    }

    #[test]
    fn speedup_line_computes_ratio() {
        let x = speedup_line("demo", Duration::from_millis(100), Duration::from_millis(25));
        assert!((x - 4.0).abs() < 1e-9);
        assert!(human_duration(Duration::from_millis(3)).ends_with("ms"));
    }
}
