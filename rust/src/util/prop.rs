//! Minimal property-based testing harness.
//!
//! `proptest` is not available in the offline vendor set, so this module
//! provides the subset we need: run a closure over many pseudo-random
//! cases drawn from a seeded [`Xoshiro256`], and on failure retry with a
//! sequence of shrunken variants of the failing case (shrinking is
//! delegated to the case generator via integer size hints).
//!
//! Usage:
//! ```
//! use pgft::util::prop::Prop;
//! Prop::new("example").cases(64).run(|g| {
//!     let n = g.int_in(1, 100);
//!     assert!(n >= 1 && n <= 100);
//! });
//! ```

use crate::util::rng::Xoshiro256;

/// Case generator handed to property closures. Wraps the PRNG and records
/// the draws so a failing case can be reported.
pub struct Gen {
    rng: Xoshiro256,
    /// Trace of drawn values (for failure reports).
    pub trace: Vec<(String, i64)>,
    /// When `Some(k)`, integer draws are clamped toward their minimum to
    /// produce smaller counterexamples (shrink pass `k` of [`SHRINK_PASSES`]).
    shrink: Option<u32>,
}

const SHRINK_PASSES: u32 = 4;

impl Gen {
    fn new(seed: u64, shrink: Option<u32>) -> Self {
        Self { rng: Xoshiro256::new(seed), trace: Vec::new(), shrink }
    }

    /// Integer in `[lo, hi]` inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        let mut v = lo + self.rng.next_below(span) as i64;
        if let Some(pass) = self.shrink {
            // Bias toward lo: each pass halves the distance from lo.
            let dist = (v - lo) >> (pass + 1);
            v = lo + dist;
        }
        self.trace.push((format!("int_in({lo},{hi})"), v));
        v
    }

    /// usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.int_in(lo as i64, hi as i64) as usize
    }

    /// One element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize_in(0, xs.len() - 1);
        &xs[i]
    }

    /// A fair coin flip.
    pub fn bool(&mut self) -> bool {
        let b = self.rng.next_u64() & 1 == 1;
        self.trace.push(("bool".into(), b as i64));
        b
    }

    /// Raw access for non-shrinkable draws (permutations etc.).
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// A named property with a case budget.
pub struct Prop {
    name: &'static str,
    cases: u32,
    seed: u64,
}

impl Prop {
    /// A property named for failure reports (name also salts the seed).
    pub fn new(name: &'static str) -> Self {
        Self { name, cases: 128, seed: 0x5EED_0F00_D5EE_D0F7 ^ fnv(name) }
    }

    /// Set the number of cases to run.
    pub fn cases(mut self, n: u32) -> Self {
        self.cases = n;
        self
    }

    /// Override the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Run the property; panic with the smallest failing case found.
    pub fn run<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(self, f: F) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            if let Err(first) = try_case(&f, case_seed, None) {
                // Shrink: re-run with increasingly aggressive clamping;
                // keep the last failure (smallest draws).
                let mut best = first;
                for pass in 0..SHRINK_PASSES {
                    if let Err(t) = try_case(&f, case_seed, Some(pass)) {
                        best = t;
                    }
                }
                panic!(
                    "property '{}' failed (case {case}, seed {case_seed:#x})\n  draws: {:?}\n  error: {}",
                    self.name, best.trace, best.msg
                );
            }
        }
    }
}

struct Failure {
    trace: Vec<(String, i64)>,
    msg: String,
}

fn try_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    f: &F,
    seed: u64,
    shrink: Option<u32>,
) -> Result<(), Failure> {
    let mut g = Gen::new(seed, shrink);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
    match result {
        Ok(()) => Ok(()),
        Err(e) => {
            let msg = if let Some(s) = e.downcast_ref::<&str>() {
                s.to_string()
            } else if let Some(s) = e.downcast_ref::<String>() {
                s.clone()
            } else {
                "<non-string panic>".to_string()
            };
            Err(Failure { trace: g.trace, msg })
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

// Dummy const so the seed expression above compiles as a float literal
// trick would not; keep an explicit constant instead.
#[allow(non_upper_case_globals)]
const _: () = ();

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        Prop::new("tautology").cases(32).run(|g| {
            let n = g.int_in(0, 10);
            assert!((0..=10).contains(&n));
        });
    }

    #[test]
    fn failing_property_panics_with_trace() {
        let r = std::panic::catch_unwind(|| {
            Prop::new("always-false").cases(8).run(|g| {
                let n = g.int_in(5, 50);
                assert!(n < 5, "n={n} is not < 5");
            });
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("always-false"), "got: {msg}");
        assert!(msg.contains("draws"), "got: {msg}");
    }

    #[test]
    fn shrinking_biases_toward_minimum() {
        // A property failing for any n > 0 should report a small n after
        // shrink passes (clamped toward lo).
        let r = std::panic::catch_unwind(|| {
            Prop::new("shrinks").cases(4).run(|g| {
                let n = g.int_in(0, 1_000_000);
                assert!(n == 0, "fail {n}");
            });
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("should fail"),
        };
        // After SHRINK_PASSES with >>(pass+1), the reported value is at
        // most 1/32 of the original range.
        let val: i64 = msg
            .split("int_in(0,1000000)\", ")
            .nth(1)
            .and_then(|s| s.split(')').next())
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(i64::MAX);
        assert!(val <= 1_000_000 / 16, "shrunk value too large: {val} ({msg})");
    }
}
