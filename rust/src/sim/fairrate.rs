//! Exact max-min fair-rate solver (progressive filling) in pure rust.
//!
//! Mirrors `python/compile/kernels/ref.py::ref_fairrate_exact`; used as
//! the baseline solver, as the parity oracle for the XLA artifact path
//! (`tests/xla_parity.rs`), and wherever a workload exceeds the compiled
//! artifact shapes.

use super::flow::IncidenceMatrix;

/// Max-min fair rates for all flows, ports normalized by `cap`.
///
/// Water-filling: repeatedly find the bottleneck port (smallest residual
/// fair share among ports with active flows), freeze its flows at that
/// share, repeat. O(P · (F·P)) worst case; the per-iteration dual
/// contraction is the same computation the L1 Pallas kernel performs.
pub fn solve_fairrate_exact(inc: &IncidenceMatrix, cap: &[f64]) -> Vec<f64> {
    let nf = inc.num_flows();
    let np = inc.num_ports();
    assert_eq!(cap.len(), np);
    let mut rates = vec![0f64; nf];
    let mut frozen = vec![false; nf];
    // Flows with no ports (self-flows) stay at rate 0 but count as frozen.
    let flow_cols: Vec<Vec<usize>> = (0..nf).map(|f| inc.cols_of_flow(f)).collect();
    for (f, cols) in flow_cols.iter().enumerate() {
        if cols.is_empty() {
            frozen[f] = true;
        }
    }

    for _ in 0..np + 1 {
        // Dual contraction: committed load + active count per port.
        let mut load = vec![0f64; np];
        let mut cnt = vec![0u32; np];
        for f in 0..nf {
            for &c in &flow_cols[f] {
                if frozen[f] {
                    load[c] += rates[f];
                } else {
                    cnt[c] += 1;
                }
            }
        }
        // Bottleneck fair share.
        let mut theta = f64::INFINITY;
        for p in 0..np {
            if cnt[p] > 0 {
                let share = (cap[p] - load[p]).max(0.0) / cnt[p] as f64;
                if share < theta {
                    theta = share;
                }
            }
        }
        if !theta.is_finite() {
            break; // nothing active
        }
        // Freeze every active flow crossing a bottleneck port.
        let mut any = false;
        for f in 0..nf {
            if frozen[f] {
                continue;
            }
            let hit = flow_cols[f].iter().any(|&c| {
                cnt[c] > 0 && ((cap[c] - load[c]).max(0.0) / cnt[c] as f64) <= theta * (1.0 + 1e-12) + 1e-15
            });
            if hit {
                rates[f] = theta;
                frozen[f] = true;
                any = true;
            }
        }
        if !any {
            break;
        }
    }
    debug_assert!(frozen.iter().all(|&f| f), "solver must converge");
    rates
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::trace::RoutePorts;
    use crate::topology::{build_pgft, PgftSpec};

    /// Build an IncidenceMatrix from synthetic port lists.
    fn inc_from(port_lists: &[&[usize]]) -> IncidenceMatrix {
        let topo = build_pgft(&PgftSpec::case_study());
        let routes: Vec<RoutePorts> = port_lists
            .iter()
            .enumerate()
            .map(|(i, ports)| RoutePorts { src: i as u32, dst: 63, ports: ports.to_vec() })
            .collect();
        IncidenceMatrix::from_routes(&topo, &routes)
    }

    #[test]
    fn shared_port_splits_evenly() {
        let inc = inc_from(&[&[0], &[0], &[0], &[0]]);
        let rates = solve_fairrate_exact(&inc, &[1.0]);
        assert_eq!(rates, vec![0.25; 4]);
    }

    #[test]
    fn two_tier_case() {
        // flow0: {A,B}, flow1: {A}, flow2: {B}; cap A=1, B=2.
        let inc = inc_from(&[&[10, 20], &[10], &[20]]);
        let caps: Vec<f64> = (0..inc.num_ports())
            .map(|c| if inc.port_of_col(c) == 10 { 1.0 } else { 2.0 })
            .collect();
        let rates = solve_fairrate_exact(&inc, &caps);
        assert!((rates[0] - 0.5).abs() < 1e-12);
        assert!((rates[1] - 0.5).abs() < 1e-12);
        assert!((rates[2] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn capacity_respected_and_bottleneck_tight() {
        let mut lists: Vec<Vec<usize>> = Vec::new();
        let mut rng = crate::util::rng::Xoshiro256::new(42);
        for _ in 0..30 {
            let k = 1 + rng.index(4);
            let mut ports: Vec<usize> = (0..k).map(|_| rng.index(12)).collect();
            ports.sort_unstable();
            ports.dedup();
            lists.push(ports);
        }
        let refs: Vec<&[usize]> = lists.iter().map(|v| v.as_slice()).collect();
        let inc = inc_from(&refs);
        let cap = vec![1.0; inc.num_ports()];
        let rates = solve_fairrate_exact(&inc, &cap);
        // Check load ≤ cap and each flow hits a (nearly) full port.
        let np = inc.num_ports();
        let mut load = vec![0f64; np];
        for f in 0..inc.num_flows() {
            for c in inc.cols_of_flow(f) {
                load[c] += rates[f];
            }
        }
        for p in 0..np {
            assert!(load[p] <= 1.0 + 1e-9, "port {p} over capacity: {}", load[p]);
        }
        for f in 0..inc.num_flows() {
            let tight = inc.cols_of_flow(f).iter().any(|&c| load[c] >= 1.0 - 1e-6);
            assert!(tight, "flow {f} not bottlenecked");
        }
    }

    #[test]
    fn empty_flow_gets_zero() {
        let inc = inc_from(&[&[], &[0]]);
        let rates = solve_fairrate_exact(&inc, &[1.0]);
        assert_eq!(rates, vec![0.0, 1.0]);
    }
}
