//! Routed pattern → dense flow×port incidence matrix.
//!
//! Only *used* ports become columns (a pattern touches a small slice of
//! the fabric), which is what lets the fixed-shape XLA artifacts cover
//! real topologies: the case study's C2IO uses ≲ 120 ports of 192; a
//! 512-node sweep stays under the 1024-column artifact.

use crate::routing::trace::RoutePorts;
use crate::topology::{PortId, Topology};

/// Row element of a route: a port id in either of the repo's two
/// widths (`usize` on the legacy surface, `u32` in the route arena).
trait PortElem: Copy {
    /// The id as a table index.
    fn port(self) -> PortId;
}

impl PortElem for PortId {
    #[inline]
    fn port(self) -> PortId {
        self
    }
}

impl PortElem for u32 {
    #[inline]
    fn port(self) -> PortId {
        self as PortId
    }
}

/// Dense row-major (flows × used-ports) 0/1 matrix with the port-id
/// compression maps.
#[derive(Clone, Debug)]
pub struct IncidenceMatrix {
    dense: Vec<f32>,
    flows: usize,
    used_ports: Vec<PortId>,
    /// Reverse map: global PortId → column (usize::MAX = unused).
    col_of: Vec<usize>,
}

impl IncidenceMatrix {
    /// Build the dense matrix from traced routes, compressing columns to
    /// the ports the routes actually use.
    pub fn from_routes(topo: &Topology, routes: &[RoutePorts]) -> IncidenceMatrix {
        Self::from_port_rows(topo, routes.len(), |f| &routes[f].ports)
    }

    /// Build from an arena-backed [`crate::eval::FlowSet`] — the
    /// eval-layer entry point ([`crate::eval::FairRateEval`]); same
    /// matrix as [`IncidenceMatrix::from_routes`] on the equivalent
    /// route set, with no per-route allocation on the input side.
    pub fn from_flowset(topo: &Topology, flows: &crate::eval::FlowSet) -> IncidenceMatrix {
        Self::from_port_rows(topo, flows.len(), |f| flows.route(f))
    }

    /// Shared two-pass builder over any row accessor: map used ports to
    /// columns, then fill the dense 0/1 matrix. Generic over the row
    /// element ([`PortElem`]) because the legacy [`RoutePorts`] surface
    /// stores `usize` port ids while the arena-backed `FlowSet` stores
    /// `u32`.
    fn from_port_rows<'a, P: PortElem + 'a>(
        topo: &Topology,
        flows: usize,
        row: impl Fn(usize) -> &'a [P],
    ) -> IncidenceMatrix {
        let mut col_of = vec![usize::MAX; topo.num_ports()];
        let mut used_ports = Vec::new();
        for f in 0..flows {
            for &p in row(f) {
                let p = p.port();
                if col_of[p] == usize::MAX {
                    col_of[p] = used_ports.len();
                    used_ports.push(p);
                }
            }
        }
        let ports = used_ports.len();
        let mut dense = vec![0f32; flows * ports];
        for f in 0..flows {
            for &p in row(f) {
                dense[f * ports + col_of[p.port()]] = 1.0;
            }
        }
        IncidenceMatrix { dense, flows, used_ports, col_of }
    }

    /// Number of rows (flows).
    pub fn num_flows(&self) -> usize {
        self.flows
    }

    /// Number of columns (used ports).
    pub fn num_ports(&self) -> usize {
        self.used_ports.len()
    }

    /// Row-major dense 0/1 data, `num_flows() × num_ports()`.
    pub fn dense(&self) -> &[f32] {
        &self.dense
    }

    /// One matrix entry.
    #[inline]
    pub fn at(&self, flow: usize, col: usize) -> f32 {
        self.dense[flow * self.used_ports.len() + col]
    }

    /// Global PortId of a column.
    pub fn port_of_col(&self, col: usize) -> PortId {
        self.used_ports[col]
    }

    /// Column of a global PortId, if used.
    pub fn col_of_port(&self, p: PortId) -> Option<usize> {
        match self.col_of.get(p) {
            Some(&c) if c != usize::MAX => Some(c),
            _ => None,
        }
    }

    /// Ports crossed by one flow (column indices).
    pub fn cols_of_flow(&self, flow: usize) -> Vec<usize> {
        let np = self.used_ports.len();
        (0..np).filter(|&c| self.dense[flow * np + c] > 0.5).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::Pattern;
    use crate::routing::trace::trace_flows;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    #[test]
    fn incidence_matches_routes() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = crate::nodes::Placement::paper_io().apply(&topo).unwrap();
        let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
        let r = AlgorithmKind::Dmodk.build(&topo, Some(&types), 0);
        let routes = trace_flows(&topo, &*r, &flows);
        let inc = IncidenceMatrix::from_routes(&topo, &routes);
        assert_eq!(inc.num_flows(), 56);
        assert!(inc.num_ports() > 0 && inc.num_ports() <= topo.num_ports());
        // Every route's hop count equals its row sum.
        for (f, route) in routes.iter().enumerate() {
            assert_eq!(inc.cols_of_flow(f).len(), route.ports.len());
            for &p in &route.ports {
                let c = inc.col_of_port(p).expect("used port must have a column");
                assert_eq!(inc.at(f, c), 1.0);
                assert_eq!(inc.port_of_col(c), p);
            }
        }
        // Unused ports have no column.
        let used: std::collections::HashSet<_> =
            routes.iter().flat_map(|r| r.ports.iter().copied()).collect();
        for p in 0..topo.num_ports() {
            assert_eq!(inc.col_of_port(p).is_some(), used.contains(&p));
        }
    }

    #[test]
    fn flowset_and_routes_builders_agree() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = crate::nodes::Placement::paper_io().apply(&topo).unwrap();
        let flows = Pattern::C2ioAll.flows(&topo, &types).unwrap();
        let r = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 0);
        let routes = trace_flows(&topo, &*r, &flows);
        let set = crate::eval::FlowSet::trace(&topo, &*r, &flows);
        let a = IncidenceMatrix::from_routes(&topo, &routes);
        let b = IncidenceMatrix::from_flowset(&topo, &set);
        assert_eq!(a.num_flows(), b.num_flows());
        assert_eq!(a.num_ports(), b.num_ports());
        assert_eq!(a.dense(), b.dense(), "identical column order and entries");
    }

    #[test]
    fn case_study_c2io_fits_smallest_artifact() {
        // The (256, 256) artifact must cover the paper's workload.
        let topo = build_pgft(&PgftSpec::case_study());
        let types = crate::nodes::Placement::paper_io().apply(&topo).unwrap();
        for pat in [Pattern::C2ioSym, Pattern::C2ioAll] {
            let flows = pat.flows(&topo, &types).unwrap();
            let r = AlgorithmKind::Smodk.build(&topo, Some(&types), 0);
            let routes = trace_flows(&topo, &*r, &flows);
            let inc = IncidenceMatrix::from_routes(&topo, &routes);
            assert!(inc.num_flows() <= 256, "{}: {}", pat.name(), inc.num_flows());
            assert!(inc.num_ports() <= 256, "{}: {}", pat.name(), inc.num_ports());
        }
    }
}
