//! Simulation stack — the "simulation-based analysis" the paper's
//! conclusions call for.
//!
//! * [`flow`] — routed pattern → dense flow×port incidence matrix
//!   (columns compressed to used ports).
//! * [`fairrate`] — exact max-min fair-rate solver in rust (baseline and
//!   parity oracle for the XLA path).
//! * [`packet`] — discrete-time packet-level simulator (FIFO output
//!   queues) for completion-time results. *Superseded by
//!   [`crate::netsim`]* — the event-driven flit-level simulator with
//!   VC/credit flow control — for latency-vs-load and saturation
//!   studies; kept as the simple completion-time cross-check.
//! * [`SimReport`] — per-algorithm throughput/latency summary rows.
//!
//! [`fairrate`] doubles as the **low-load oracle** for `netsim`: below
//! saturation the flit-level per-flow throughput must agree with the
//! max-min fair rates (pinned by `tests/netsim_parity.rs`).

pub mod fairrate;
pub mod flow;
pub mod packet;

pub use fairrate::solve_fairrate_exact;
pub use flow::IncidenceMatrix;
pub use packet::{PacketSim, PacketSimConfig, PacketSimResult};

use crate::metrics::CongestionReport;
use crate::nodes::NodeTypeMap;
use crate::patterns::Pattern;
use crate::routing::AlgorithmKind;
use crate::topology::Topology;
use anyhow::Result;

/// Flow-level simulation summary for one (algorithm, pattern) cell.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Algorithm name.
    pub algorithm: String,
    /// Pattern name.
    pub pattern: String,
    /// Number of flows simulated.
    pub flows: usize,
    /// Sum of max-min fair rates (links normalized to capacity 1).
    pub aggregate_throughput: f64,
    /// Worst flow rate — the pattern's completion is bound by it.
    pub min_rate: f64,
    /// Mean flow rate.
    pub mean_rate: f64,
    /// Time to complete one unit of data per flow: 1 / min_rate.
    pub completion_time: f64,
    /// Static metric for cross-checking (C_topo of the same routes).
    pub c_topo: u32,
    /// Which solver produced the rates ("rust" or "xla:<artifact>").
    pub solver: String,
}

/// Max-min fair rates of a traced [`crate::eval::FlowSet`] on
/// unit-capacity links (the deterministic pure-rust solver). The shared
/// entry point for [`crate::eval::FairRateEval`], sweep cells and the
/// fault subsystem's throughput-retention figures: both the pristine
/// and the degraded route stores go through this one function, so
/// retention ratios compare like with like.
pub fn fair_rates(topo: &Topology, flows: &crate::eval::FlowSet) -> Vec<f64> {
    let inc = IncidenceMatrix::from_flowset(topo, flows);
    let cap = vec![1.0f64; inc.num_ports()];
    solve_fairrate_exact(&inc, &cap)
}

/// Run the flow-level simulation for one algorithm on one pattern.
/// `runtime`: use the XLA/PJRT artifact when `Some`, else the exact rust
/// solver.
pub fn simulate_flow_level(
    topo: &Topology,
    types: &NodeTypeMap,
    kind: AlgorithmKind,
    pattern: &Pattern,
    seed: u64,
    runtime: Option<&crate::runtime::Runtime>,
) -> Result<SimReport> {
    let router = kind.build(topo, Some(types), seed);
    let flows = pattern.flows(topo, types)?;
    // One arena-backed trace, shared by the solver and the metric.
    let set = crate::eval::FlowSet::trace(topo, &*router, &flows);
    let inc = IncidenceMatrix::from_flowset(topo, &set);
    let cap = vec![1.0f32; inc.num_ports()];

    // Use the XLA artifact when one fits the problem shape; otherwise
    // fall back to the exact rust solver (and say so in the report).
    let fits = runtime
        .map(|rt| rt.pick("fairrate", inc.num_flows(), inc.num_ports()).is_ok())
        .unwrap_or(false);
    let (rates, solver) = match runtime {
        Some(rt) if fits => {
            let valid = vec![1.0f32; inc.num_flows()];
            let r = rt.solve_fairrate(inc.dense(), inc.num_flows(), inc.num_ports(), &cap, &valid)?;
            (r.into_iter().map(|x| x as f64).collect::<Vec<f64>>(), "xla".to_string())
        }
        _ => {
            let cap64: Vec<f64> = cap.iter().map(|&c| c as f64).collect();
            let tag = if runtime.is_some() { "rust*" } else { "rust" };
            (solve_fairrate_exact(&inc, &cap64), tag.to_string())
        }
    };

    let rep = CongestionReport::compute_flowset(topo, &set);
    let sum: f64 = rates.iter().sum();
    let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    Ok(SimReport {
        algorithm: kind.as_str().to_string(),
        pattern: pattern.name(),
        flows: flows.len(),
        aggregate_throughput: sum,
        min_rate: min,
        mean_rate: sum / rates.len() as f64,
        completion_time: 1.0 / min,
        c_topo: rep.c_topo(),
        solver,
    })
}

/// Fixed-width table over several sim rows.
pub fn render_sim_table(rows: &[SimReport]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:<10} {:>6} {:>11} {:>9} {:>9} {:>11} {:>7} {:>6}\n",
        "algo", "pattern", "flows", "agg-thru", "min-rate", "mean-rate", "completion", "C_topo",
        "solver"
    ));
    out.push_str(&"-".repeat(90));
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{:<10} {:<10} {:>6} {:>11.3} {:>9.4} {:>9.4} {:>11.2} {:>7} {:>6}\n",
            r.algorithm,
            r.pattern,
            r.flows,
            r.aggregate_throughput,
            r.min_rate,
            r.mean_rate,
            r.completion_time,
            r.c_topo,
            r.solver,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::topology::{build_pgft, PgftSpec};

    #[test]
    fn flow_level_gdmodk_beats_dmodk_on_c2io() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let d = simulate_flow_level(&topo, &types, AlgorithmKind::Dmodk, &Pattern::C2ioSym, 0, None)
            .unwrap();
        let g =
            simulate_flow_level(&topo, &types, AlgorithmKind::Gdmodk, &Pattern::C2ioSym, 0, None)
                .unwrap();
        // Dmodk funnels all 56 flows through 2 top ports → min rate 1/28;
        // Gdmodk spreads → min rate 1/7 (leaf up-port bound).
        assert!(g.min_rate > d.min_rate * 3.0, "dmodk {d:?} vs gdmodk {g:?}");
        assert!(g.aggregate_throughput > d.aggregate_throughput * 2.0);
        assert!(g.completion_time < d.completion_time / 3.0);
    }

    #[test]
    fn table_renders() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let rows = vec![simulate_flow_level(
            &topo,
            &types,
            AlgorithmKind::Smodk,
            &Pattern::C2ioSym,
            0,
            None,
        )
        .unwrap()];
        let t = render_sim_table(&rows);
        assert!(t.contains("smodk"));
    }
}
