//! Discrete-time packet-level simulator (legacy *completion-time* tool).
//!
//! **Superseded by [`crate::netsim`]** for latency/throughput studies:
//! this module is a synchronous one-packet-per-slot FIFO model useful
//! for fixed-message completion times, while `netsim` is the
//! event-driven flit-level simulator (virtual channels, credit flow
//! control, injection-rate sweeps) that produces the
//! latency-vs-offered-load curves standard in the literature. New
//! scenarios should target `netsim`; this simulator is kept as the
//! simple completion-time cross-check.
//!
//! Model: every output port is a FIFO that forwards one packet per time
//! slot; each flow must deliver `message_packets` packets along its
//! precomputed route; a source injects its next packet when the first
//! queue has room. Head-of-line blocking and port contention emerge
//! naturally, so completion times order algorithms the way `C_topo`
//! does. A run that exhausts [`PacketSimConfig::max_slots`] before
//! delivering every message is an explicit error — a truncated
//! completion time would silently understate congestion.

use crate::routing::trace::RoutePorts;
use crate::topology::Topology;
use anyhow::{ensure, Result};
use std::collections::VecDeque;

/// Tunables of the discrete-time packet simulation.
#[derive(Clone, Debug)]
pub struct PacketSimConfig {
    /// Packets per flow message.
    pub message_packets: u32,
    /// Queue capacity per output port (packets).
    pub queue_capacity: usize,
    /// Safety cap on simulated slots.
    pub max_slots: u64,
}

impl Default for PacketSimConfig {
    fn default() -> Self {
        PacketSimConfig { message_packets: 64, queue_capacity: 8, max_slots: 1_000_000 }
    }
}

/// Outcome of one packet-level simulation run.
#[derive(Clone, Debug)]
pub struct PacketSimResult {
    /// Slot at which the last packet arrived.
    pub completion_slots: u64,
    /// Per-flow completion slot.
    pub flow_completion: Vec<u64>,
    /// Max queue depth observed per port (indexed by used-port order).
    pub max_queue_depth: usize,
    /// Total packets delivered.
    pub delivered: u64,
    /// Aggregate throughput in packets/slot.
    pub throughput: f64,
}

/// In-flight packet: which flow, which hop it sits *before*.
#[derive(Clone, Copy, Debug)]
struct Packet {
    flow: u32,
    #[allow(dead_code)] seq: u32, // kept for tracing/debug dumps
}

/// Discrete-time simulator over a fixed set of traced routes.
pub struct PacketSim<'a> {
    topo: &'a Topology,
    routes: &'a [RoutePorts],
    cfg: PacketSimConfig,
}

impl<'a> PacketSim<'a> {
    /// Set up a simulation of `routes` on `topo`.
    pub fn new(topo: &'a Topology, routes: &'a [RoutePorts], cfg: PacketSimConfig) -> Self {
        PacketSim { topo, routes, cfg }
    }

    /// Run until every message is delivered. Errors when
    /// [`PacketSimConfig::max_slots`] elapses with packets still queued
    /// (raise `max_slots`, or switch to [`crate::netsim`] for open-loop
    /// saturation studies where completion is not the question).
    pub fn run(&self) -> Result<PacketSimResult> {
        let nf = self.routes.len();
        let np = self.topo.num_ports();
        // Per-port FIFO of (packet, hop index of this port in its route).
        let mut queues: Vec<VecDeque<(Packet, u16)>> = vec![VecDeque::new(); np];
        let mut injected = vec![0u32; nf];
        let mut arrived = vec![0u32; nf];
        let mut flow_completion = vec![0u64; nf];
        let msg = self.cfg.message_packets;
        let mut remaining: u64 = self
            .routes
            .iter()
            .filter(|r| !r.ports.is_empty())
            .count() as u64
            * msg as u64;
        // Flows with empty routes (src == dst) complete instantly.
        for (f, r) in self.routes.iter().enumerate() {
            if r.ports.is_empty() {
                arrived[f] = msg;
            }
        }
        let mut max_depth = 0usize;
        let mut delivered = 0u64;
        let mut slot = 0u64;

        while remaining > 0 && slot < self.cfg.max_slots {
            slot += 1;
            // Phase 1: each port forwards its head packet (all ports step
            // simultaneously: collect moves first, apply after).
            let mut moves: Vec<(Packet, u16)> = Vec::new();
            for q in queues.iter_mut() {
                if let Some(head) = q.pop_front() {
                    moves.push(head);
                }
            }
            for (pkt, hop) in moves {
                let route = &self.routes[pkt.flow as usize];
                let next_hop = hop as usize + 1;
                if next_hop >= route.ports.len() {
                    // Arrived at destination node.
                    arrived[pkt.flow as usize] += 1;
                    delivered += 1;
                    remaining -= 1;
                    if arrived[pkt.flow as usize] == msg {
                        flow_completion[pkt.flow as usize] = slot;
                    }
                } else {
                    // Enqueue at the next output port (unbounded here;
                    // capacity is enforced at injection, which is where
                    // end-node congestion originates).
                    queues[route.ports[next_hop]].push_back((pkt, next_hop as u16));
                }
            }
            // Phase 2: injection — one packet per source per slot if the
            // first port's queue has room.
            for (f, route) in self.routes.iter().enumerate() {
                if route.ports.is_empty() || injected[f] >= msg {
                    continue;
                }
                let first = route.ports[0];
                if queues[first].len() < self.cfg.queue_capacity {
                    queues[first].push_back((Packet { flow: f as u32, seq: injected[f] }, 0));
                    injected[f] += 1;
                }
            }
            for q in &queues {
                max_depth = max_depth.max(q.len());
            }
        }
        ensure!(
            remaining == 0,
            "packet sim exhausted max_slots = {} with {} packet(s) undelivered \
             ({} delivered); raise max_slots or use `pgft netsim` for \
             open-loop saturation studies",
            self.cfg.max_slots,
            remaining,
            delivered
        );
        let _ = queues; // drained
        Ok(PacketSimResult {
            completion_slots: slot,
            flow_completion,
            max_queue_depth: max_depth,
            delivered,
            throughput: if slot > 0 { delivered as f64 / slot as f64 } else { 0.0 },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::patterns::Pattern;
    use crate::routing::trace::trace_flows;
    use crate::routing::AlgorithmKind;
    use crate::topology::{build_pgft, PgftSpec};

    fn run(kind: AlgorithmKind, pattern: &Pattern, msg: u32) -> PacketSimResult {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let flows = pattern.flows(&topo, &types).unwrap();
        let router = kind.build(&topo, Some(&types), 0);
        let routes = trace_flows(&topo, &*router, &flows);
        PacketSim::new(
            &topo,
            &routes,
            PacketSimConfig { message_packets: msg, ..Default::default() },
        )
        .run()
        .unwrap()
    }

    #[test]
    fn single_flow_latency_is_pipeline_depth() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let router = AlgorithmKind::Dmodk.build(&topo, Some(&types), 0);
        let routes = trace_flows(&topo, &*router, &[(0, 63)]);
        let res = PacketSim::new(
            &topo,
            &routes,
            PacketSimConfig { message_packets: 1, ..Default::default() },
        )
        .run()
        .unwrap();
        // One packet over 6 hops: phase-1 of slots 1..=6 moves it.
        assert_eq!(res.completion_slots, 7, "inject at slot1, deliver 6 slots later");
        assert_eq!(res.delivered, 1);
    }

    #[test]
    fn max_slots_exhaustion_is_an_explicit_error() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&topo).unwrap();
        let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
        let router = AlgorithmKind::Dmodk.build(&topo, Some(&types), 0);
        let routes = trace_flows(&topo, &*router, &flows);
        // 56 flows × 64 packets cannot possibly finish in 10 slots.
        let err = PacketSim::new(
            &topo,
            &routes,
            PacketSimConfig { message_packets: 64, max_slots: 10, ..Default::default() },
        )
        .run()
        .expect_err("truncation must not masquerade as completion");
        let msg = err.to_string();
        assert!(msg.contains("max_slots"), "{msg}");
        assert!(msg.contains("netsim"), "the error points at the successor: {msg}");
    }

    #[test]
    fn gdmodk_completes_c2io_faster_than_dmodk() {
        let d = run(AlgorithmKind::Dmodk, &Pattern::C2ioSym, 32);
        let g = run(AlgorithmKind::Gdmodk, &Pattern::C2ioSym, 32);
        assert_eq!(d.delivered, 56 * 32);
        assert_eq!(g.delivered, 56 * 32);
        assert!(
            (g.completion_slots as f64) < d.completion_slots as f64 * 0.5,
            "gdmodk {g:?} should be ≥2× faster than dmodk {d:?}"
        );
    }

    #[test]
    fn all_messages_delivered_for_all_algorithms() {
        for kind in AlgorithmKind::ALL {
            let r = run(kind, &Pattern::C2ioSym, 8);
            assert_eq!(r.delivered, 56 * 8, "{kind}");
            assert!(r.completion_slots < 100_000, "{kind} timed out");
            assert!(r.flow_completion.iter().all(|&c| c > 0), "{kind}");
        }
    }

    #[test]
    fn throughput_is_bounded_by_flows() {
        let r = run(AlgorithmKind::Gdmodk, &Pattern::C2ioSym, 64);
        assert!(r.throughput > 0.0 && r.throughput <= 56.0);
        assert!(r.max_queue_depth >= 1);
    }
}
