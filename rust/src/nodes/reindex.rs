//! Algorithm 1 of the paper: **Reindex NIDs by type**.
//!
//! Gxmodk preprocesses NIDs so that nodes of the same type occupy a
//! contiguous gNID range; within each type, gNIDs follow original NID
//! order ("re-indexing in the order of the original NIDs ensures that
//! consecutive reindexed NIDs are topologically close"). Xmodk is then
//! applied to the gNIDs.
//!
//! In the paper's worked example (64 nodes, IO on the last port of every
//! leaf): compute nodes get gNIDs 0..55, IO nodes 56..63.

use super::{NodeType, NodeTypeMap};
use crate::topology::Nid;

/// A bijection NID ↔ gNID induced by a type map.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TypeReindex {
    /// `gnid[nid]` — the reindexed id.
    gnid: Vec<Nid>,
    /// `nid[gnid]` — inverse.
    nid: Vec<Nid>,
    /// (type, first gNID, count) per group, in gNID order.
    groups: Vec<(NodeType, Nid, u32)>,
}

impl TypeReindex {
    /// Build the re-index from a type map. Types are processed in
    /// canonical rank order ([`NodeType::rank`]: compute first, then io,
    /// service, gpgpu, fpga, custom_k).
    pub fn new(types: &NodeTypeMap) -> TypeReindex {
        let n = types.len();
        let mut gnid = vec![0 as Nid; n];
        let mut nid = vec![0 as Nid; n];
        let mut groups = Vec::new();
        let mut next: Nid = 0;
        for ty in types.types_present() {
            let members = types.nids_of(ty); // ascending NID order
            groups.push((ty, next, members.len() as u32));
            for m in members {
                gnid[m as usize] = next;
                nid[next as usize] = m;
                next += 1;
            }
        }
        debug_assert_eq!(next as usize, n);
        TypeReindex { gnid, nid, groups }
    }

    /// Identity re-index (uniform fabric ⇒ Gxmodk degenerates to Xmodk).
    pub fn identity(n: u32) -> TypeReindex {
        TypeReindex {
            gnid: (0..n).collect(),
            nid: (0..n).collect(),
            groups: vec![(NodeType::Compute, 0, n)],
        }
    }

    /// gNID of a node.
    #[inline]
    pub fn gnid(&self, nid: Nid) -> Nid {
        self.gnid[nid as usize]
    }

    /// Inverse lookup: the NID holding a gNID.
    #[inline]
    pub fn nid(&self, gnid: Nid) -> Nid {
        self.nid[gnid as usize]
    }

    /// Number of nodes in the bijection.
    pub fn len(&self) -> usize {
        self.gnid.len()
    }

    /// Whether the re-index covers no nodes.
    pub fn is_empty(&self) -> bool {
        self.gnid.is_empty()
    }

    /// Groups as (type, first gNID, count).
    pub fn groups(&self) -> &[(NodeType, Nid, u32)] {
        &self.groups
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nodes::Placement;
    use crate::topology::{build_pgft, PgftSpec};
    use crate::util::prop::Prop;

    #[test]
    fn paper_worked_example() {
        // Compute nodes are reindexed first: gNIDs 0..55; IO 56..63.
        let t = build_pgft(&PgftSpec::case_study());
        let types = Placement::paper_io().apply(&t).unwrap();
        let r = TypeReindex::new(&types);
        // NID 7 (first IO) → gNID 56; NID 47 → gNID 61; NID 63 → 63.
        assert_eq!(r.gnid(7), 56);
        assert_eq!(r.gnid(15), 57);
        assert_eq!(r.gnid(23), 58);
        assert_eq!(r.gnid(31), 59);
        assert_eq!(r.gnid(39), 60);
        assert_eq!(r.gnid(47), 61);
        assert_eq!(r.gnid(55), 62);
        assert_eq!(r.gnid(63), 63);
        // Compute nodes keep order: NID 0 → 0, NID 8 → 7 (one IO skipped).
        assert_eq!(r.gnid(0), 0);
        assert_eq!(r.gnid(6), 6);
        assert_eq!(r.gnid(8), 7);
        assert_eq!(r.gnid(62), 55);
        assert_eq!(
            r.groups(),
            &[(NodeType::Compute, 0, 56), (NodeType::Io, 56, 8)]
        );
    }

    #[test]
    fn identity_reindex() {
        let r = TypeReindex::identity(16);
        for n in 0..16 {
            assert_eq!(r.gnid(n), n);
            assert_eq!(r.nid(n), n);
        }
    }

    #[test]
    fn prop_bijection_and_order_preserving() {
        Prop::new("reindex-bijection").cases(60).run(|g| {
            let n = g.usize_in(1, 200) as u32;
            let mut map = NodeTypeMap::uniform(n, NodeType::Compute);
            // Sprinkle random types.
            for _ in 0..g.usize_in(0, n as usize) {
                let nid = g.usize_in(0, n as usize - 1) as u32;
                let ty = *g.choose(&[
                    NodeType::Io,
                    NodeType::Service,
                    NodeType::Gpgpu,
                    NodeType::Custom(1),
                ]);
                map.set(nid, ty);
            }
            let r = TypeReindex::new(&map);
            // Bijection.
            let mut seen = vec![false; n as usize];
            for nid in 0..n {
                let gid = r.gnid(nid);
                assert!(!seen[gid as usize], "gnid reused");
                seen[gid as usize] = true;
                assert_eq!(r.nid(gid), nid);
            }
            // Within a type, NID order is preserved.
            for ty in map.types_present() {
                let members = map.nids_of(ty);
                let gids: Vec<u32> = members.iter().map(|&m| r.gnid(m)).collect();
                let mut sorted = gids.clone();
                sorted.sort_unstable();
                assert_eq!(gids, sorted, "order not preserved within {ty}");
                // And contiguous.
                if let Some(&first) = sorted.first() {
                    let expect: Vec<u32> = (first..first + sorted.len() as u32).collect();
                    assert_eq!(sorted, expect, "group not contiguous for {ty}");
                }
            }
            // Groups cover [0, n).
            let total: u32 = r.groups().iter().map(|&(_, _, c)| c).sum();
            assert_eq!(total, n);
        });
    }

    #[test]
    fn uniform_map_gives_identity() {
        let map = NodeTypeMap::uniform(32, NodeType::Compute);
        let r = TypeReindex::new(&map);
        assert_eq!(r, TypeReindex::identity(32));
    }
}
