//! Node-type heterogeneity (§II of the paper): node type taxonomy, the
//! NID→type map, placement strategies, and the Gxmodk type re-indexing
//! (Algorithm 1).

pub mod placement;
pub mod reindex;

pub use placement::Placement;
pub use reindex::TypeReindex;

use crate::topology::Nid;
use std::fmt;

/// The accepted node-type names (the vocabulary parse errors across the
/// crate cite; see [`NodeType::parse`] for the aliases).
pub const TYPE_VOCAB: &str = "compute|io|service|gpgpu|fpga|customN";

/// Node types observed on production clusters (§II). `Custom` leaves room
/// for site-specific classes (e.g. Lustre routers).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum NodeType {
    /// Ordinary compute node (the default type).
    Compute,
    /// I/O node (storage proxies, Lustre servers, …).
    Io,
    /// Service node (login, scheduler, metadata).
    Service,
    /// GPGPU accelerator node.
    Gpgpu,
    /// FPGA accelerator node.
    Fpga,
    /// Site-specific class `k`.
    Custom(u8),
}

impl NodeType {
    /// The "ordinary" type — unmarked in renderings.
    pub fn is_default(self) -> bool {
        self == NodeType::Compute
    }

    /// One-letter tag for diagrams.
    pub fn short(self) -> &'static str {
        match self {
            NodeType::Compute => "C",
            NodeType::Io => "I",
            NodeType::Service => "S",
            NodeType::Gpgpu => "G",
            NodeType::Fpga => "F",
            NodeType::Custom(_) => "X",
        }
    }

    /// Parse a CLI/config type name (`io`, `i`, `custom3`, …).
    pub fn parse(s: &str) -> Option<NodeType> {
        match s.to_ascii_lowercase().as_str() {
            "compute" | "c" => Some(NodeType::Compute),
            "io" | "i" => Some(NodeType::Io),
            "service" | "s" => Some(NodeType::Service),
            "gpgpu" | "gpu" | "g" => Some(NodeType::Gpgpu),
            "fpga" | "f" => Some(NodeType::Fpga),
            other => other
                .strip_prefix("custom")
                .and_then(|n| n.parse().ok())
                .map(NodeType::Custom),
        }
    }

    /// Canonical ordering rank used by the re-indexer (compute first, as
    /// in the paper's worked example: compute gNIDs 0..55, IO 56..63).
    pub fn rank(self) -> u32 {
        match self {
            NodeType::Compute => 0,
            NodeType::Io => 1,
            NodeType::Service => 2,
            NodeType::Gpgpu => 3,
            NodeType::Fpga => 4,
            NodeType::Custom(k) => 5 + k as u32,
        }
    }
}

impl fmt::Display for NodeType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NodeType::Compute => write!(f, "compute"),
            NodeType::Io => write!(f, "io"),
            NodeType::Service => write!(f, "service"),
            NodeType::Gpgpu => write!(f, "gpgpu"),
            NodeType::Fpga => write!(f, "fpga"),
            NodeType::Custom(k) => write!(f, "custom{k}"),
        }
    }
}

/// NID → type assignment for a whole fabric.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeTypeMap {
    types: Vec<NodeType>,
}

impl NodeTypeMap {
    /// All `n` nodes of one type.
    pub fn uniform(n: Nid, ty: NodeType) -> Self {
        Self { types: vec![ty; n as usize] }
    }

    /// Wrap an explicit NID-indexed type vector.
    pub fn from_vec(types: Vec<NodeType>) -> Self {
        Self { types }
    }

    /// Number of nodes covered.
    pub fn len(&self) -> usize {
        self.types.len()
    }

    /// Whether the map covers no nodes.
    pub fn is_empty(&self) -> bool {
        self.types.is_empty()
    }

    /// Type of one node.
    #[inline]
    pub fn type_of(&self, nid: Nid) -> NodeType {
        self.types[nid as usize]
    }

    /// Reassign one node's type.
    pub fn set(&mut self, nid: Nid, ty: NodeType) {
        self.types[nid as usize] = ty;
    }

    /// All NIDs of a given type, ascending.
    pub fn nids_of(&self, ty: NodeType) -> Vec<Nid> {
        self.types
            .iter()
            .enumerate()
            .filter(|(_, &t)| t == ty)
            .map(|(i, _)| i as Nid)
            .collect()
    }

    /// Distinct types present, in canonical rank order.
    pub fn types_present(&self) -> Vec<NodeType> {
        let mut tys: Vec<NodeType> = self.types.clone();
        tys.sort_by_key(|t| t.rank());
        tys.dedup();
        tys
    }

    /// Census string, e.g. `"compute:56 io:8"`.
    pub fn census(&self) -> String {
        self.types_present()
            .iter()
            .map(|&ty| format!("{ty}:{}", self.nids_of(ty).len()))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Iterate `(nid, type)` pairs in NID order.
    pub fn iter(&self) -> impl Iterator<Item = (Nid, NodeType)> + '_ {
        self.types.iter().enumerate().map(|(i, &t)| (i as Nid, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for ty in [
            NodeType::Compute,
            NodeType::Io,
            NodeType::Service,
            NodeType::Gpgpu,
            NodeType::Fpga,
            NodeType::Custom(3),
        ] {
            assert_eq!(NodeType::parse(&ty.to_string()), Some(ty));
        }
        assert_eq!(NodeType::parse("IO"), Some(NodeType::Io));
        assert_eq!(NodeType::parse("nonsense"), None);
    }

    #[test]
    fn ranks_put_compute_first() {
        assert!(NodeType::Compute.rank() < NodeType::Io.rank());
        assert!(NodeType::Io.rank() < NodeType::Custom(0).rank());
    }

    #[test]
    fn census_and_queries() {
        let mut m = NodeTypeMap::uniform(8, NodeType::Compute);
        m.set(7, NodeType::Io);
        m.set(3, NodeType::Io);
        assert_eq!(m.census(), "compute:6 io:2");
        assert_eq!(m.nids_of(NodeType::Io), vec![3, 7]);
        assert_eq!(m.types_present(), vec![NodeType::Compute, NodeType::Io]);
        assert_eq!(m.type_of(3), NodeType::Io);
    }
}
