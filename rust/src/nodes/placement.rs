//! Secondary-node placement strategies (§II): where IO / service / GPGPU
//! nodes sit in the fabric. The paper lists three realistic options;
//! we implement those plus scattered/random placements used by the
//! placement-sensitivity bench (E12).

use super::{NodeType, NodeTypeMap};
use crate::topology::{Endpoint, Topology};
use crate::util::rng::Xoshiro256;
use anyhow::{ensure, Result};

/// A placement strategy assigns types to the nodes of a topology.
/// Unassigned nodes default to [`NodeType::Compute`].
#[derive(Clone, Debug)]
pub enum Placement {
    /// "Placing a constant number of secondary nodes of each type at
    /// every leaf" — on the *last* ports, like BXI's reserved optical
    /// ports and the paper's case study (IO ≡ 7 mod 8).
    LastPortsPerLeaf {
        /// Secondary node type to place.
        ty: NodeType,
        /// Nodes per leaf.
        count: u32,
    },
    /// Same, but on the first ports of every leaf.
    FirstPortsPerLeaf {
        /// Secondary node type to place.
        ty: NodeType,
        /// Nodes per leaf.
        count: u32,
    },
    /// Every k-th NID fabric-wide (offset, stride).
    Strided {
        /// Secondary node type to place.
        ty: NodeType,
        /// First NID to mark.
        offset: u32,
        /// NID step between marks.
        stride: u32,
    },
    /// All nodes of the last `leaves` leaves — approximates the paper's
    /// "irregular subgroup with secondary nodes connected to the top
    /// switches" without breaking the fat-tree property.
    DedicatedLeaves {
        /// Secondary node type to place.
        ty: NodeType,
        /// How many trailing leaves to dedicate.
        leaves: u32,
    },
    /// `count` nodes of type `ty` placed uniformly at random (seeded) —
    /// the "unlucky repartition" scenario of the abstract.
    Random {
        /// Secondary node type to place.
        ty: NodeType,
        /// How many nodes to mark.
        count: u32,
        /// Sampling seed.
        seed: u64,
    },
    /// Apply several placements in order (later ones overwrite).
    Stack(Vec<Placement>),
}

impl Placement {
    /// The paper's case-study placement: one IO node on the last port of
    /// every leaf.
    pub fn paper_io() -> Placement {
        Placement::LastPortsPerLeaf { ty: NodeType::Io, count: 1 }
    }

    /// Apply this placement to a topology: unnamed nodes stay
    /// [`NodeType::Compute`].
    pub fn apply(&self, topo: &Topology) -> Result<NodeTypeMap> {
        let mut map = NodeTypeMap::uniform(topo.num_nodes() as u32, NodeType::Compute);
        self.apply_onto(topo, &mut map)?;
        Ok(map)
    }

    fn apply_onto(&self, topo: &Topology, map: &mut NodeTypeMap) -> Result<()> {
        match self {
            Placement::LastPortsPerLeaf { ty, count } | Placement::FirstPortsPerLeaf { ty, count } => {
                let m1 = topo.spec.m[0];
                ensure!(*count <= m1, "count {count} exceeds nodes-per-leaf {m1}");
                let from_end = matches!(self, Placement::LastPortsPerLeaf { .. });
                for leaf in topo.level_switches(1) {
                    let mut nids: Vec<u32> = topo.switches[leaf]
                        .down_ports
                        .iter()
                        .filter_map(|&p| match topo.port_peer(p) {
                            Endpoint::Node(n) => Some(n),
                            _ => None,
                        })
                        .collect();
                    nids.sort_unstable();
                    nids.dedup();
                    let take: Vec<u32> = if from_end {
                        nids.iter().rev().take(*count as usize).copied().collect()
                    } else {
                        nids.iter().take(*count as usize).copied().collect()
                    };
                    for n in take {
                        map.set(n, *ty);
                    }
                }
            }
            Placement::Strided { ty, offset, stride } => {
                ensure!(*stride > 0, "stride must be positive");
                let mut n = *offset;
                while (n as usize) < map.len() {
                    map.set(n, *ty);
                    n += stride;
                }
            }
            Placement::DedicatedLeaves { ty, leaves } => {
                let all: Vec<usize> = topo.level_switches(1).collect();
                ensure!((*leaves as usize) <= all.len(), "not enough leaves");
                for &leaf in all.iter().rev().take(*leaves as usize) {
                    for &p in &topo.switches[leaf].down_ports {
                        if let Endpoint::Node(n) = topo.port_peer(p) {
                            map.set(n, *ty);
                        }
                    }
                }
            }
            Placement::Random { ty, count, seed } => {
                ensure!((*count as usize) <= map.len(), "count exceeds node count");
                let mut rng = Xoshiro256::new(*seed);
                let picks = rng.sample_indices(map.len(), *count as usize);
                for i in picks {
                    map.set(i as u32, *ty);
                }
            }
            Placement::Stack(list) => {
                for p in list {
                    p.apply_onto(topo, map)?;
                }
            }
        }
        Ok(())
    }

    /// Parse a compact CLI form, e.g.:
    ///   `io:last:1` · `service:first:2` · `gpgpu:stride:3:8` ·
    ///   `io:leaves:2` · `io:random:8:42` · comma-separated stacks.
    pub fn parse(s: &str) -> Result<Placement> {
        let items: Vec<&str> = s.split(',').collect();
        let mut out = Vec::new();
        for item in items {
            let parts: Vec<&str> = item.split(':').collect();
            ensure!(parts.len() >= 2, "placement {item:?}: want type:kind[:args]");
            let ty = NodeType::parse(parts[0]).ok_or_else(|| {
                anyhow::anyhow!("unknown node type {:?} (types: {})", parts[0], crate::nodes::TYPE_VOCAB)
            })?;
            let arg = |i: usize| -> Result<u32> {
                parts
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("placement {item:?}: missing arg {i}"))?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("placement {item:?}: {e}"))
            };
            let p = match parts[1] {
                "last" => Placement::LastPortsPerLeaf { ty, count: arg(2)? },
                "first" => Placement::FirstPortsPerLeaf { ty, count: arg(2)? },
                "stride" => Placement::Strided { ty, offset: arg(2)?, stride: arg(3)? },
                "leaves" => Placement::DedicatedLeaves { ty, leaves: arg(2)? },
                "random" => Placement::Random { ty, count: arg(2)?, seed: arg(3)? as u64 },
                k => anyhow::bail!(
                    "unknown placement kind {k:?} (expected one of \
                     last:N|first:N|stride:OFF:STEP|leaves:N|random:N:SEED)"
                ),
            };
            out.push(p);
        }
        Ok(if out.len() == 1 { out.pop().unwrap() } else { Placement::Stack(out) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_pgft, PgftSpec};

    fn topo() -> Topology {
        build_pgft(&PgftSpec::case_study())
    }

    #[test]
    fn paper_io_placement_matches_mod8() {
        // "IO nodes ... have NIDs whose modulo by 8 is 7."
        let t = topo();
        let map = Placement::paper_io().apply(&t).unwrap();
        for nid in 0..64u32 {
            let expect = if nid % 8 == 7 { NodeType::Io } else { NodeType::Compute };
            assert_eq!(map.type_of(nid), expect, "nid {nid}");
        }
        assert_eq!(map.nids_of(NodeType::Io).len(), 8);
    }

    #[test]
    fn strided_equals_last_port_for_case_study() {
        let t = topo();
        let a = Placement::paper_io().apply(&t).unwrap();
        let b = Placement::Strided { ty: NodeType::Io, offset: 7, stride: 8 }
            .apply(&t)
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn dedicated_leaves_types_whole_leaf() {
        let t = topo();
        let map = Placement::DedicatedLeaves { ty: NodeType::Io, leaves: 2 }
            .apply(&t)
            .unwrap();
        let ios = map.nids_of(NodeType::Io);
        assert_eq!(ios.len(), 16);
        // Last two leaves hold nids 48..63.
        assert_eq!(ios, (48..64).collect::<Vec<u32>>());
    }

    #[test]
    fn random_placement_is_seeded_and_sized() {
        let t = topo();
        let a = Placement::Random { ty: NodeType::Io, count: 8, seed: 1 }.apply(&t).unwrap();
        let b = Placement::Random { ty: NodeType::Io, count: 8, seed: 1 }.apply(&t).unwrap();
        let c = Placement::Random { ty: NodeType::Io, count: 8, seed: 2 }.apply(&t).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.nids_of(NodeType::Io).len(), 8);
        assert_ne!(a, c, "different seed should (almost surely) differ");
    }

    #[test]
    fn stack_applies_in_order() {
        let t = topo();
        let p = Placement::Stack(vec![
            Placement::paper_io(),
            Placement::FirstPortsPerLeaf { ty: NodeType::Service, count: 1 },
        ]);
        let map = p.apply(&t).unwrap();
        assert_eq!(map.type_of(7), NodeType::Io);
        assert_eq!(map.type_of(0), NodeType::Service);
        assert_eq!(map.census(), "compute:48 io:8 service:8");
    }

    #[test]
    fn parse_forms() {
        let t = topo();
        let p = Placement::parse("io:last:1").unwrap();
        assert_eq!(p.apply(&t).unwrap(), Placement::paper_io().apply(&t).unwrap());
        let p2 = Placement::parse("io:last:1,service:first:1").unwrap();
        assert_eq!(p2.apply(&t).unwrap().census(), "compute:48 io:8 service:8");
        assert!(Placement::parse("io:bogus").is_err());
        assert!(Placement::parse("martian:last:1").is_err());
        let p3 = Placement::parse("io:random:4:99").unwrap();
        assert_eq!(p3.apply(&t).unwrap().nids_of(NodeType::Io).len(), 4);
    }

    #[test]
    fn overfull_counts_rejected() {
        let t = topo();
        assert!(Placement::LastPortsPerLeaf { ty: NodeType::Io, count: 9 }.apply(&t).is_err());
        assert!(Placement::DedicatedLeaves { ty: NodeType::Io, leaves: 99 }.apply(&t).is_err());
        assert!(Placement::Random { ty: NodeType::Io, count: 65, seed: 0 }.apply(&t).is_err());
    }
}
