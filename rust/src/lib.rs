//! # pgft — node-type-based load-balancing routing for PGFTs
//!
//! A production-shaped reproduction of *"Node-type-based load-balancing
//! routing for Parallel Generalized Fat-Trees"* (Gliksberg, Quintin,
//! García): PGFT topology substrate, the Dmodk/Smodk/Random baselines,
//! the paper's Gdmodk/Gsmodk contribution, the static congestion metric,
//! heterogeneous node-type modelling, flow-level and packet-level
//! simulators plus an event-driven flit-level simulator with VC/credit
//! flow control ([`netsim`]), a unified evaluation core ([`eval`]: the
//! arena-backed `FlowSet` route store with incremental fault re-trace,
//! and the `Evaluator` trait all three scoring engines sit behind), a
//! parallel experiment-sweep engine ([`sweep`]) that turns
//! the paper's algorithm × pattern × placement grids into one command,
//! a fault-injection & online-rerouting subsystem ([`faults`]) that adds
//! seeded failure scenarios as a first-class sweep axis, an
//! application-workload subsystem ([`workload`]: concurrent multi-phase
//! job mixes and MPI-style collective schedules over typed node groups,
//! scored by a fluid makespan metric and replayable flit-by-flit), and a
//! BXI-style online fabric-manager service ([`coordinator`]: a single
//! leader thread repairing tables incrementally through the `FlowSet`
//! store while queries read lock-free from versioned immutable
//! snapshots), and a deterministic telemetry layer ([`telemetry`]:
//! sharded counters/histograms/span timers plus the coordinator's
//! fabric event journal, surfaced as `--telemetry OUT.json` without
//! perturbing any output byte). With the `xla` cargo
//! feature, the simulation hot path runs AOT-compiled JAX/Pallas
//! programs through PJRT (see `rust/src/runtime`); without it the exact
//! pure-rust solvers are used.
//!
//! Quick taste (the paper's headline numbers):
//!
//! ```
//! use pgft::prelude::*;
//! let topo = build_pgft(&PgftSpec::case_study());
//! let types = Placement::paper_io().apply(&topo).unwrap();
//! let flows = Pattern::C2ioSym.flows(&topo, &types).unwrap();
//! let dmodk = AlgorithmKind::Dmodk.build(&topo, Some(&types), 0);
//! let routes = trace_flows(&topo, &*dmodk, &flows);
//! let rep = CongestionReport::compute(&topo, &routes);
//! assert_eq!(rep.c_topo(), 4); // §III.B
//! let gdmodk = AlgorithmKind::Gdmodk.build(&topo, Some(&types), 0);
//! let routes = trace_flows(&topo, &*gdmodk, &flows);
//! assert_eq!(CongestionReport::compute(&topo, &routes).c_topo(), 1); // §IV optimum
//! ```
//!
//! The same comparison as one declarative sweep over the whole grid:
//!
//! ```
//! use pgft::prelude::*;
//! let rows = run_sweep(&SweepSpec::paper_grid("case-study"), &SweepOptions::default()).unwrap();
//! assert!(rows.iter().any(|r| r.summary.algorithm == "gdmodk" && r.summary.c_topo == 1));
//! ```

#![warn(missing_docs)]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod faults;
pub mod metrics;
pub mod netsim;
pub mod nodes;
pub mod patterns;
pub mod report;
pub mod routing;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod telemetry;
pub mod topology;
pub mod util;
pub mod workload;

/// Convenience re-exports.
pub mod prelude {
    pub use crate::coordinator::{Coordinator, FabricSnapshot, FabricStats};
    pub use crate::eval::{
        CongestionEval, EvalCells, Evaluator, FairRateEval, FlowSet, NetsimEval,
    };
    pub use crate::faults::{
        DegradedRouter, DegradedTopology, FaultModel, FaultScenario, FaultSet, LinkEvent,
        ReachStats, DEFAULT_REACH_BUDGET,
    };
    pub use crate::metrics::{AlgoSummary, CongestionReport, KernelStats};
    pub use crate::netsim::{load_curve, run_netsim, Injection, NetsimConfig, NetsimReport};
    pub use crate::nodes::{NodeType, NodeTypeMap, Placement, TypeReindex};
    pub use crate::patterns::Pattern;
    pub use crate::routing::trace::{trace_flows, trace_route};
    pub use crate::routing::{AlgorithmKind, ForwardingTables, Router};
    pub use crate::sweep::{run_sweep, sweep_table, SweepOptions, SweepResult, SweepSpec};
    pub use crate::telemetry::{BatchRecord, Journal, Registry, Telemetry};
    pub use crate::topology::{
        build_pgft, families, ImplicitTopology, PgftSpec, Topology, TopologyView,
    };
    pub use crate::workload::{Collective, GroupSpec, Job, Phase, WorkloadSpec};
}
