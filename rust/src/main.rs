//! `pgft` binary — CLI front-end of the library. See `pgft help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = pgft::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
