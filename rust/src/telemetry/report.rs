//! The `pgft-telemetry/1` JSON emitter and the human stderr summary.
//!
//! Discipline matches `BENCH_eval.json` schema-v2: a `schema` tag, a
//! `host_cpus` provenance field, and **no null anywhere** — an absent
//! measurement is simply not a key, and empty collections are empty
//! objects/arrays. Everything is hand-formatted (the crate carries no
//! serde); all maps iterate in `BTreeMap` order so the document is
//! byte-deterministic for a given registry state (span durations are
//! wall-clock and vary run to run — the *shape* is what is stable).

use super::journal::BatchRecord;
use super::metrics::Registry;
use crate::report::Table;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// One labelled registry inside a telemetry document. A `pgft netsim`
/// emission carries one run per `(algo, pattern)` curve — the whole
/// rate grid merges into it, and the rate list rides in the label — so
/// per-port counters are never summed across unrelated configurations;
/// the other subcommands carry a single run with an empty label.
#[derive(Clone, Debug, Default)]
pub struct TelemetryRun {
    /// Label keys identifying the run (e.g. `algo`, `pattern`,
    /// `rate`), emitted in key order; empty for single-run commands.
    pub label: BTreeMap<String, String>,
    /// The merged metrics of the run.
    pub registry: Registry,
}

impl TelemetryRun {
    /// An unlabelled run around a registry snapshot.
    pub fn unlabelled(registry: Registry) -> TelemetryRun {
        TelemetryRun { label: BTreeMap::new(), registry }
    }

    /// A short human name for the run (`k=v` pairs, or `all`).
    pub fn name(&self) -> String {
        if self.label.is_empty() {
            "all".to_string()
        } else {
            self.label.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(" ")
        }
    }
}

pub(crate) fn esc(s: &str) -> String {
    let mut o = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => o.push_str("\\\""),
            '\\' => o.push_str("\\\\"),
            '\n' => o.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(o, "\\u{:04x}", c as u32);
            }
            c => o.push(c),
        }
    }
    o
}

pub(crate) fn map_json<V, F: Fn(&V) -> String>(
    map: &BTreeMap<String, V>,
    indent: &str,
    val: F,
) -> String {
    if map.is_empty() {
        return "{}".to_string();
    }
    let inner: Vec<String> =
        map.iter().map(|(k, v)| format!("{indent}  \"{}\": {}", esc(k), val(v))).collect();
    format!("{{\n{}\n{indent}}}", inner.join(",\n"))
}

pub(crate) fn u64s_json(values: &[u64]) -> String {
    let body: Vec<String> = values.iter().map(|v| v.to_string()).collect();
    format!("[{}]", body.join(", "))
}

fn run_json(run: &TelemetryRun) -> String {
    let r = &run.registry;
    let label = map_json(&run.label, "      ", |v: &String| format!("\"{}\"", esc(v)));
    let counters = map_json(r.counters(), "      ", |v: &u64| v.to_string());
    let maxima = map_json(r.maxima(), "      ", |v: &u64| v.to_string());
    let vectors = map_json(r.vectors(), "      ", |m: &super::VectorMetric| {
        format!("{{\"kind\": \"{}\", \"values\": {}}}", m.kind.label(), u64s_json(&m.values))
    });
    let histograms = map_json(r.histograms(), "      ", |h: &super::Histogram| {
        // Only populated buckets, as [bucket, count] pairs: fixed
        // 65-slot layouts are mostly zeros and zeros are noise.
        let pairs: Vec<String> = h
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(b, &c)| format!("[{b}, {c}]"))
            .collect();
        format!("{{\"count\": {}, \"buckets\": [{}]}}", h.count, pairs.join(", "))
    });
    let spans = map_json(r.spans(), "      ", |s: &super::SpanStat| {
        format!(
            "{{\"count\": {}, \"total_ns\": {}, \"max_ns\": {}}}",
            s.count, s.total_ns, s.max_ns
        )
    });
    format!(
        "    {{\n      \"label\": {label},\n      \"counters\": {counters},\n      \
         \"maxima\": {maxima},\n      \"vectors\": {vectors},\n      \
         \"histograms\": {histograms},\n      \"spans\": {spans}\n    }}"
    )
}

fn journal_json(records: &[BatchRecord]) -> String {
    if records.is_empty() {
        return "[]".to_string();
    }
    let lines: Vec<String> = records
        .iter()
        .map(|b| {
            format!(
                "    {{\"kind\": \"{}\", \"events\": {}, \"dead_links\": {}, \
                 \"dirty_flows\": {}, \"routes_changed\": {}, \"diff_entries\": {}, \
                 \"coalesce_ns\": {}, \"dirty_scan_ns\": {}, \"retrace_ns\": {}, \
                 \"tables_ns\": {}, \"diff_ns\": {}, \"publish_ns\": {}}}",
                b.kind,
                b.events,
                b.dead_links,
                b.dirty_flows,
                b.routes_changed,
                b.diff_entries,
                b.coalesce_ns,
                b.dirty_scan_ns,
                b.retrace_ns,
                b.tables_ns,
                b.diff_ns,
                b.publish_ns
            )
        })
        .collect();
    format!("[\n{}\n  ]", lines.join(",\n"))
}

/// Render a full `pgft-telemetry/1` document. `command` names the
/// emitting subcommand; `journal` is empty for everything but
/// `fabric`. No field is ever `null`.
pub fn telemetry_json(command: &str, runs: &[TelemetryRun], journal: &[BatchRecord]) -> String {
    let runs_body = if runs.is_empty() {
        "[]".to_string()
    } else {
        let items: Vec<String> = runs.iter().map(run_json).collect();
        format!("[\n{}\n  ]", items.join(",\n"))
    };
    format!(
        "{{\n  \"schema\": \"pgft-telemetry/1\",\n  \"command\": \"{}\",\n  \
         \"host_cpus\": {},\n  \"runs\": {},\n  \"journal\": {}\n}}\n",
        esc(command),
        crate::util::par::max_threads(),
        runs_body,
        journal_json(journal)
    )
}

/// Write a `pgft-telemetry/1` document to `path`.
pub fn write_telemetry(
    path: impl AsRef<Path>,
    command: &str,
    runs: &[TelemetryRun],
    journal: &[BatchRecord],
) -> Result<()> {
    let body = telemetry_json(command, runs, journal);
    std::fs::write(path.as_ref(), body)
        .with_context(|| format!("write telemetry {}", path.as_ref().display()))
}

/// The stderr summary: one row per metric per run (and one per journal
/// record), so a human can read the headline figures without opening
/// the JSON.
pub fn summary_table(runs: &[TelemetryRun], journal: &[BatchRecord]) -> Table {
    let mut t = Table::new("telemetry summary", &["run", "metric", "kind", "value"]);
    for run in runs {
        let name = run.name();
        let r = &run.registry;
        for (k, v) in r.counters() {
            t.row(&[name.clone(), k.clone(), "counter".into(), v.to_string()]);
        }
        for (k, v) in r.maxima() {
            t.row(&[name.clone(), k.clone(), "max".into(), v.to_string()]);
        }
        for (k, m) in r.vectors() {
            let sum: u64 = m.values.iter().sum();
            let peak = m.values.iter().copied().max().unwrap_or(0);
            t.row(&[
                name.clone(),
                k.clone(),
                format!("vec/{}", m.kind.label()),
                format!("len={} sum={sum} peak={peak}", m.values.len()),
            ]);
        }
        for (k, h) in r.histograms() {
            let top = h.buckets.iter().rposition(|&c| c > 0).unwrap_or(0);
            t.row(&[
                name.clone(),
                k.clone(),
                "hist".into(),
                format!("count={} top_bucket={top}", h.count),
            ]);
        }
        for (k, s) in r.spans() {
            t.row(&[
                name.clone(),
                k.clone(),
                "span".into(),
                format!(
                    "count={} total_us={} max_us={}",
                    s.count,
                    s.total_ns / 1_000,
                    s.max_ns / 1_000
                ),
            ]);
        }
    }
    for (i, b) in journal.iter().enumerate() {
        t.row(&[
            format!("journal[{i}]"),
            b.kind.to_string(),
            "batch".into(),
            format!(
                "events={} dirty={} changed={} retrace_us={} total_us={}",
                b.events,
                b.dirty_flows,
                b.routes_changed,
                b.retrace_ns / 1_000,
                b.total_ns() / 1_000
            ),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{BatchKind, VecKind};

    fn sample_run() -> TelemetryRun {
        let mut r = Registry::default();
        r.add("netsim.events", 42);
        r.record_max("netsim.peak", 9);
        r.vec_bulk("netsim.port.forwarded_flits", VecKind::Sum, &[3, 0, 5]);
        r.observe("netsim.queue_depth", 4);
        r.span_ns("netsim.run", 1_500);
        let mut label = BTreeMap::new();
        label.insert("algo".to_string(), "dmodk".to_string());
        TelemetryRun { label, registry: r }
    }

    fn sample_journal() -> Vec<BatchRecord> {
        vec![BatchRecord {
            kind: BatchKind::Repair,
            events: 4,
            dead_links: 4,
            dirty_flows: 10,
            routes_changed: 6,
            diff_entries: 3,
            coalesce_ns: 1,
            dirty_scan_ns: 2,
            retrace_ns: 3,
            tables_ns: 4,
            diff_ns: 5,
            publish_ns: 6,
        }]
    }

    #[test]
    fn document_shape_and_no_nulls() {
        let doc = telemetry_json("netsim", &[sample_run()], &sample_journal());
        assert!(doc.contains("\"schema\": \"pgft-telemetry/1\""), "{doc}");
        assert!(doc.contains("\"command\": \"netsim\""));
        assert!(doc.contains("\"host_cpus\": "));
        assert!(doc.contains("\"algo\": \"dmodk\""));
        assert!(doc.contains("\"netsim.events\": 42"));
        assert!(doc.contains("\"kind\": \"sum\", \"values\": [3, 0, 5]"));
        assert!(doc.contains("\"buckets\": [[3, 1]]"), "{doc}");
        assert!(doc.contains("\"kind\": \"repair\""));
        assert!(!doc.contains("null"), "no-null discipline: {doc}");
    }

    #[test]
    fn empty_document_is_valid_and_null_free() {
        let doc = telemetry_json("sweep", &[], &[]);
        assert!(doc.contains("\"runs\": []"));
        assert!(doc.contains("\"journal\": []"));
        assert!(!doc.contains("null"));
    }

    #[test]
    fn summary_rows_cover_every_family() {
        let t = summary_table(&[sample_run()], &sample_journal());
        let text = t.to_text();
        assert!(text.contains("netsim.events"), "{text}");
        assert!(text.contains("vec/sum"));
        assert!(text.contains("hist"));
        assert!(text.contains("span"));
        assert!(text.contains("journal[0]"));
        assert_eq!(t.rows.len(), 6);
    }

    #[test]
    fn write_roundtrips_to_disk() {
        let dir = std::env::temp_dir().join("pgft_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        write_telemetry(&p, "eval", &[sample_run()], &[]).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.contains("pgft-telemetry/1"));
    }
}
