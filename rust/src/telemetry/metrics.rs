//! The metric store: named counters, maxima, indexed vectors,
//! power-of-two histograms and wall-clock span statistics, with the
//! sharded recording surface that keeps `par_map` workers off any
//! shared lock.
//!
//! # Determinism rules
//!
//! Metrics that feed *outputs* (CSV cells, asserted counters, the
//! python cross-check) must be keyed by simulated quantities only —
//! simulated cycles, flit counts, queue depths. Wall-clock time is
//! quarantined in [`SpanStat`]s, which are reported but never compared
//! or folded into deterministic results. The merge operations below
//! (sum, max, element-wise sum/max) are all commutative and
//! associative over `u64`, so counter totals are identical whatever
//! thread count or merge order produced them — `tests/telemetry.rs`
//! pins sharded merge ≡ serial recording.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Number of power-of-two histogram buckets: bucket 0 holds the value
/// 0 and bucket `b ≥ 1` holds values in `[2^(b−1), 2^b)`, so bucket 64
/// tops out the `u64` range and no sample can overflow the fixed
/// layout.
pub const HIST_BUCKETS: usize = 65;

/// Bucket index of a histogram sample (see [`HIST_BUCKETS`]).
#[inline]
pub fn hist_bucket(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// How the elements of a [`VectorMetric`] combine across shards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum VecKind {
    /// Element-wise sum (e.g. per-port forwarded flits).
    #[default]
    Sum,
    /// Element-wise maximum (e.g. per-VC occupancy high-water marks).
    Max,
}

impl VecKind {
    /// The lower-case label the JSON report emits (`sum` / `max`).
    pub fn label(self) -> &'static str {
        match self {
            VecKind::Sum => "sum",
            VecKind::Max => "max",
        }
    }
}

/// A dense `u64` vector metric indexed by a small integer key (port,
/// VC slot, flow index). Shards resize lazily; merging aligns lengths.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct VectorMetric {
    /// Merge rule for the elements.
    pub kind: VecKind,
    /// The element values (index = the metric's integer key).
    pub values: Vec<u64>,
}

/// A fixed-layout power-of-two histogram (see [`hist_bucket`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    /// Total number of samples observed.
    pub count: u64,
    /// One slot per bucket, always [`HIST_BUCKETS`] long.
    pub buckets: Vec<u64>,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { count: 0, buckets: vec![0; HIST_BUCKETS] }
    }
}

/// Aggregated wall-clock figures of one named span. Wall-clock is
/// non-deterministic by nature; spans are reported for humans and
/// benches, never folded into deterministic outputs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanStat {
    /// Number of completed spans.
    pub count: u64,
    /// Summed duration in nanoseconds.
    pub total_ns: u64,
    /// Longest single span in nanoseconds.
    pub max_ns: u64,
}

/// The merged metric store: every family keyed by name in a `BTreeMap`
/// so iteration (and therefore every emitted report) is byte-ordered
/// and reproducible.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    maxima: BTreeMap<String, u64>,
    vectors: BTreeMap<String, VectorMetric>,
    histograms: BTreeMap<String, Histogram>,
    spans: BTreeMap<String, SpanStat>,
}

impl Registry {
    /// Add `v` to the named counter (created at 0).
    pub fn add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_default() += v;
    }

    /// Raise the named maximum to at least `v`.
    pub fn record_max(&mut self, name: &str, v: u64) {
        let slot = self.maxima.entry(name.to_string()).or_default();
        *slot = (*slot).max(v);
    }

    /// Observe one sample in the named power-of-two histogram.
    pub fn observe(&mut self, name: &str, v: u64) {
        let h = self.histograms.entry(name.to_string()).or_default();
        h.count += 1;
        h.buckets[hist_bucket(v)] += 1;
    }

    /// Add `v` to element `idx` of the named [`VecKind::Sum`] vector.
    pub fn vec_add(&mut self, name: &str, idx: usize, v: u64) {
        let m = self.vectors.entry(name.to_string()).or_default();
        m.kind = VecKind::Sum;
        if m.values.len() <= idx {
            m.values.resize(idx + 1, 0);
        }
        m.values[idx] += v;
    }

    /// Raise element `idx` of the named [`VecKind::Max`] vector to at
    /// least `v`.
    pub fn vec_max(&mut self, name: &str, idx: usize, v: u64) {
        let m = self.vectors.entry(name.to_string()).or_default();
        m.kind = VecKind::Max;
        if m.values.len() <= idx {
            m.values.resize(idx + 1, 0);
        }
        m.values[idx] = m.values[idx].max(v);
    }

    /// Record one completed span of `ns` nanoseconds under `name`.
    pub fn span_ns(&mut self, name: &str, ns: u64) {
        let s = self.spans.entry(name.to_string()).or_default();
        s.count += 1;
        s.total_ns += ns;
        s.max_ns = s.max_ns.max(ns);
    }

    /// Install a whole pre-built [`VecKind::Sum`] vector at once (the
    /// netsim engine accumulates into plain arrays in its hot loop and
    /// hands them over in one call at the end of the run).
    pub fn vec_bulk(&mut self, name: &str, kind: VecKind, values: &[u64]) {
        let other = VectorMetric { kind, values: values.to_vec() };
        merge_vector(self.vectors.entry(name.to_string()).or_default(), &other);
    }

    /// Install pre-accumulated histogram buckets at once (the buckets
    /// slice must use the [`HIST_BUCKETS`] layout). The sample count is
    /// recovered as the bucket sum.
    pub fn hist_bulk(&mut self, name: &str, buckets: &[u64]) {
        debug_assert_eq!(buckets.len(), HIST_BUCKETS, "fixed power-of-two layout");
        let h = self.histograms.entry(name.to_string()).or_default();
        for (m, o) in h.buckets.iter_mut().zip(buckets) {
            *m += o;
            h.count += o;
        }
    }

    /// Fold `other` into `self`: counters sum, maxima max, vectors
    /// merge element-wise by kind, histograms add bucket-wise, spans
    /// accumulate. All rules are commutative and associative, so merge
    /// order cannot influence totals.
    pub fn merge_from(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_default() += v;
        }
        for (k, v) in &other.maxima {
            let slot = self.maxima.entry(k.clone()).or_default();
            *slot = (*slot).max(*v);
        }
        for (k, v) in &other.vectors {
            merge_vector(self.vectors.entry(k.clone()).or_default(), v);
        }
        for (k, h) in &other.histograms {
            let mine = self.histograms.entry(k.clone()).or_default();
            mine.count += h.count;
            for (m, o) in mine.buckets.iter_mut().zip(&h.buckets) {
                *m += o;
            }
        }
        for (k, s) in &other.spans {
            let mine = self.spans.entry(k.clone()).or_default();
            mine.count += s.count;
            mine.total_ns += s.total_ns;
            mine.max_ns = mine.max_ns.max(s.max_ns);
        }
    }

    /// The named counter's value (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// All counters, name-ordered.
    pub fn counters(&self) -> &BTreeMap<String, u64> {
        &self.counters
    }

    /// All maxima, name-ordered.
    pub fn maxima(&self) -> &BTreeMap<String, u64> {
        &self.maxima
    }

    /// All vector metrics, name-ordered.
    pub fn vectors(&self) -> &BTreeMap<String, VectorMetric> {
        &self.vectors
    }

    /// All histograms, name-ordered.
    pub fn histograms(&self) -> &BTreeMap<String, Histogram> {
        &self.histograms
    }

    /// All span statistics, name-ordered.
    pub fn spans(&self) -> &BTreeMap<String, SpanStat> {
        &self.spans
    }

    /// Whether nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
            && self.maxima.is_empty()
            && self.vectors.is_empty()
            && self.histograms.is_empty()
            && self.spans.is_empty()
    }
}

fn merge_vector(mine: &mut VectorMetric, other: &VectorMetric) {
    debug_assert!(
        mine.values.is_empty() || mine.kind == other.kind,
        "vector metric merged under conflicting kinds"
    );
    mine.kind = other.kind;
    if mine.values.len() < other.values.len() {
        mine.values.resize(other.values.len(), 0);
    }
    for (i, v) in other.values.iter().enumerate() {
        match other.kind {
            VecKind::Sum => mine.values[i] += v,
            VecKind::Max => mine.values[i] = mine.values[i].max(*v),
        }
    }
}

/// A private per-worker recording surface: writes go into a local
/// [`Registry`] with no synchronization at all, and the whole shard is
/// folded into the shared handle **once** at scope exit via
/// [`Telemetry::merge`]. When the parent handle is disabled the shard
/// is dead (`live == false`) and every record call is a branch on a
/// bool — nothing allocates, nothing locks.
#[derive(Debug, Default)]
pub struct Shard {
    live: bool,
    reg: Registry,
}

impl Shard {
    pub(crate) fn new(live: bool) -> Shard {
        Shard { live, reg: Registry::default() }
    }

    /// Whether the parent handle was enabled when the shard was cut.
    pub fn is_live(&self) -> bool {
        self.live
    }

    /// Add `v` to the named counter.
    pub fn add(&mut self, name: &str, v: u64) {
        if self.live {
            self.reg.add(name, v);
        }
    }

    /// Raise the named maximum to at least `v`.
    pub fn record_max(&mut self, name: &str, v: u64) {
        if self.live {
            self.reg.record_max(name, v);
        }
    }

    /// Observe one histogram sample.
    pub fn observe(&mut self, name: &str, v: u64) {
        if self.live {
            self.reg.observe(name, v);
        }
    }

    /// Add `v` to element `idx` of the named sum-vector.
    pub fn vec_add(&mut self, name: &str, idx: usize, v: u64) {
        if self.live {
            self.reg.vec_add(name, idx, v);
        }
    }

    /// Raise element `idx` of the named max-vector to at least `v`.
    pub fn vec_max(&mut self, name: &str, idx: usize, v: u64) {
        if self.live {
            self.reg.vec_max(name, idx, v);
        }
    }

    /// Record one completed span of `ns` nanoseconds.
    pub fn span_ns(&mut self, name: &str, ns: u64) {
        if self.live {
            self.reg.span_ns(name, ns);
        }
    }

    /// Time `f` under the named span. Disabled shards run `f` without
    /// touching the clock.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        if !self.live {
            return f();
        }
        let t0 = Instant::now();
        let out = f();
        self.reg.span_ns(name, t0.elapsed().as_nanos() as u64);
        out
    }

    /// The shard's private registry (consumed by [`Telemetry::merge`]).
    pub(crate) fn into_registry(self) -> Registry {
        self.reg
    }
}

/// The cloneable instrumentation handle. A disabled handle (the
/// default, and what every un-instrumented caller passes) carries no
/// allocation at all — every operation is one `Option` check, so
/// instrumented hot paths cost nothing in normal runs. An enabled
/// handle shares one mutex-guarded [`Registry`]; hot loops should
/// record through a [`Shard`] (or private arrays) and merge once.
///
/// ```
/// use pgft::telemetry::Telemetry;
/// let t = Telemetry::enabled();
/// t.add("demo.count", 3);
/// let mut shard = t.shard();
/// shard.add("demo.count", 4);
/// t.merge(shard);
/// assert_eq!(t.snapshot().counter("demo.count"), 7);
/// assert_eq!(Telemetry::disabled().snapshot().counter("demo.count"), 0);
/// ```
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    inner: Option<Arc<Mutex<Registry>>>,
}

impl Telemetry {
    /// A live handle with a fresh empty registry.
    pub fn enabled() -> Telemetry {
        Telemetry { inner: Some(Arc::new(Mutex::new(Registry::default()))) }
    }

    /// The inert handle: every operation is a no-op after one cheap
    /// check (same as `Telemetry::default()`).
    pub fn disabled() -> Telemetry {
        Telemetry { inner: None }
    }

    /// Whether recording is live.
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    fn with<R>(&self, f: impl FnOnce(&mut Registry) -> R) -> Option<R> {
        self.inner.as_ref().map(|m| {
            // Same poisoning policy as `coordinator::SnapshotCell`: a
            // panicked recorder does not invalidate counters.
            let mut g = m.lock().unwrap_or_else(|e| e.into_inner());
            f(&mut g)
        })
    }

    /// Cut a recording shard (live iff this handle is enabled).
    pub fn shard(&self) -> Shard {
        Shard::new(self.is_enabled())
    }

    /// Fold a shard's records into the shared registry (one lock).
    pub fn merge(&self, shard: Shard) {
        if shard.is_live() {
            let reg = shard.into_registry();
            self.with(|r| r.merge_from(&reg));
        }
    }

    /// Fold a pre-built registry into the shared one (one lock).
    pub fn merge_registry(&self, reg: &Registry) {
        self.with(|r| r.merge_from(reg));
    }

    /// Add `v` to the named counter (locks; fine on cold paths).
    pub fn add(&self, name: &str, v: u64) {
        self.with(|r| r.add(name, v));
    }

    /// Raise the named maximum to at least `v`.
    pub fn record_max(&self, name: &str, v: u64) {
        self.with(|r| r.record_max(name, v));
    }

    /// Observe one histogram sample.
    pub fn observe(&self, name: &str, v: u64) {
        self.with(|r| r.observe(name, v));
    }

    /// Record one completed span of `ns` nanoseconds.
    pub fn span_ns(&self, name: &str, ns: u64) {
        self.with(|r| r.span_ns(name, ns));
    }

    /// Time `f` under the named span; disabled handles never touch the
    /// clock.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        if !self.is_enabled() {
            return f();
        }
        let t0 = Instant::now();
        let out = f();
        self.span_ns(name, t0.elapsed().as_nanos() as u64);
        out
    }

    /// A point-in-time copy of the merged registry (empty for disabled
    /// handles).
    pub fn snapshot(&self) -> Registry {
        self.with(|r| r.clone()).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(hist_bucket(0), 0);
        assert_eq!(hist_bucket(1), 1);
        assert_eq!(hist_bucket(2), 2);
        assert_eq!(hist_bucket(3), 2);
        assert_eq!(hist_bucket(4), 3);
        assert_eq!(hist_bucket(1023), 10);
        assert_eq!(hist_bucket(1024), 11);
        assert_eq!(hist_bucket(u64::MAX), 64);
    }

    #[test]
    fn merge_rules_per_family() {
        let mut a = Registry::default();
        a.add("c", 2);
        a.record_max("m", 5);
        a.vec_add("vs", 1, 3);
        a.vec_max("vm", 0, 9);
        a.observe("h", 4);
        a.span_ns("s", 100);
        let mut b = Registry::default();
        b.add("c", 3);
        b.record_max("m", 4);
        b.vec_add("vs", 3, 1);
        b.vec_max("vm", 0, 7);
        b.observe("h", 0);
        b.span_ns("s", 250);
        a.merge_from(&b);
        assert_eq!(a.counter("c"), 5);
        assert_eq!(a.maxima()["m"], 5);
        assert_eq!(a.vectors()["vs"].values, vec![0, 3, 0, 1]);
        assert_eq!(a.vectors()["vm"].values, vec![9]);
        let h = &a.histograms()["h"];
        assert_eq!((h.count, h.buckets[3], h.buckets[0]), (2, 1, 1));
        let s = a.spans()["s"];
        assert_eq!((s.count, s.total_ns, s.max_ns), (2, 350, 250));
    }

    #[test]
    fn disabled_handle_is_inert() {
        let t = Telemetry::disabled();
        assert!(!t.is_enabled());
        t.add("x", 1);
        t.observe("h", 2);
        let mut s = t.shard();
        assert!(!s.is_live());
        s.add("x", 5);
        assert_eq!(s.time("span", || 41 + 1), 42);
        t.merge(s);
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn sharded_merge_equals_direct_recording() {
        let direct = Telemetry::enabled();
        for i in 0..10u64 {
            direct.add("c", i);
            direct.observe("h", i);
        }
        let sharded = Telemetry::enabled();
        let mut s1 = sharded.shard();
        let mut s2 = sharded.shard();
        for i in 0..5u64 {
            s1.add("c", i);
            s1.observe("h", i);
        }
        for i in 5..10u64 {
            s2.add("c", i);
            s2.observe("h", i);
        }
        // Merge order must not matter.
        sharded.merge(s2);
        sharded.merge(s1);
        assert_eq!(direct.snapshot(), sharded.snapshot());
    }

    #[test]
    fn clones_share_one_registry() {
        let t = Telemetry::enabled();
        let u = t.clone();
        u.add("c", 7);
        assert_eq!(t.snapshot().counter("c"), 7);
    }

    #[test]
    fn bulk_vector_install_merges() {
        let mut r = Registry::default();
        r.vec_bulk("p", VecKind::Sum, &[1, 2]);
        r.vec_bulk("p", VecKind::Sum, &[0, 1, 4]);
        assert_eq!(r.vectors()["p"].values, vec![1, 3, 4]);
        r.vec_bulk("q", VecKind::Max, &[3, 1]);
        r.vec_bulk("q", VecKind::Max, &[2, 5]);
        assert_eq!(r.vectors()["q"].values, vec![3, 5]);
    }
}
