//! The fabric flight recorder: windowed time-series of netsim load.
//!
//! The telemetry [`Registry`](super::Registry) captures end-of-run
//! totals; the paper's argument needs load as a function of simulated
//! time — *when* a link saturates and *where*, not just how many flits
//! it moved overall. The recorder samples the engine on fixed
//! simulated-cycle **windows**: per-port forwarded flits, credit-stall
//! rounds and per-(port, VC) occupancy high-water marks, plus the
//! run-wide injected/delivered/forwarded flit deltas of the window.
//!
//! Three rules keep it scalable and deterministic:
//!
//!  * **Top-K selection.** A window sample keeps only the K ports with
//!    the most forwarded flits (deterministic tie-break on port id), so
//!    a sample is `O(K)` however many ports the fabric has — the
//!    xl-256k/1m rungs stay memory-bounded.
//!  * **Bounded ring.** At most `max_windows` samples are retained;
//!    older windows are shed into an aggregate ([`ShedTotals`]) that
//!    preserves the conservation identity
//!    `Σ retained + shed == totals` exactly.
//!  * **Simulated cycles only.** Every recorded quantity is keyed by
//!    cycles, flits or queue depths — never wall clock — so a recorded
//!    run is byte-identical to an unrecorded one and the series is
//!    reproducible run to run (pinned by `tests/recorder.rs`).
//!
//! On top of the series sits the **hotspot attribution pass**
//! ([`attribute`]): each hot port is mapped back to its link's stage,
//! owning switch and the node-type group under the link, with
//! saturation-onset localization (the first window the port exceeded
//! [`SATURATION_FRACTION`](crate::netsim::SATURATION_FRACTION) of the
//! window's cycle budget). [`diff_hotspots`] compares two recordings —
//! the dmodk-vs-gdmodk comparison `pgft report` prints is the
//! paper-facing payoff: gdmodk does not merely raise aggregate
//! throughput, it *removes* specific persistent hotspot links.
//!
//! Documents use schema `pgft-timeseries/1`: hand-formatted JSON,
//! labelled runs, window/top-K provenance at top level, and no `null`
//! anywhere (same discipline as `pgft-telemetry/1`).

use super::report::{esc, map_json, u64s_json};
use crate::netsim::{NetsimConfig, SATURATION_FRACTION};
use crate::nodes::NodeTypeMap;
use crate::topology::{Endpoint, Topology};
use anyhow::{bail, ensure, Context, Result};
use std::cmp::Reverse;
use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Sampling parameters of a recording session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecorderConfig {
    /// Window length in simulated cycles (≥ 1). Phase boundaries force
    /// an extra rollover, so phased replays always close a window
    /// exactly where a phase ends.
    pub window: u64,
    /// Ports kept per window sample (the K hottest by forwarded
    /// flits; ties break toward the lower port id).
    pub top_k: usize,
    /// Retained window samples per run; older windows are shed into
    /// the run's [`ShedTotals`] aggregate.
    pub max_windows: usize,
}

impl Default for RecorderConfig {
    fn default() -> Self {
        RecorderConfig { window: 64, top_k: 16, max_windows: 4096 }
    }
}

impl RecorderConfig {
    /// Reject degenerate parameters with a clear message.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.window >= 1, "recorder: window must be >= 1 cycle");
        ensure!(self.top_k >= 1, "recorder: top_k must be >= 1");
        ensure!(self.max_windows >= 1, "recorder: max_windows must be >= 1");
        Ok(())
    }
}

/// Aggregate of window samples shed from the bounded ring. The
/// conservation identity `Σ retained windows + shed == totals` holds
/// exactly at every moment.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShedTotals {
    /// Window samples dropped (oldest first).
    pub windows: u64,
    /// Flits injected during the shed windows.
    pub injected_flits: u64,
    /// Flits delivered during the shed windows.
    pub delivered_flits: u64,
    /// Flits forwarded (any port) during the shed windows.
    pub forwarded_flits: u64,
}

/// Whole-run flit totals, accumulated independently of the ring.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RunTotals {
    /// Flits injected over the run (packets × flits per packet).
    pub injected_flits: u64,
    /// Flits delivered over the run.
    pub delivered_flits: u64,
    /// Port transmissions over the run (final-hop included).
    pub forwarded_flits: u64,
}

/// One retained port inside a window sample (top-K selected).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PortWindow {
    /// Global directed-port id.
    pub port: u32,
    /// Flits the port transmitted inside the window.
    pub forwarded: u64,
    /// Service rounds inside the window in which every head flit the
    /// port held was blocked on downstream credit.
    pub stalls: u64,
    /// Occupancy high-water mark per VC inside the window.
    pub vc_hwm: Vec<u64>,
}

/// One closed window: the half-open cycle span `(start, end]` and the
/// flit deltas that fell inside it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WindowSample {
    /// Monotone window index from run start (shed windows keep their
    /// indices, so retained indices need not start at 0).
    pub index: u64,
    /// First cycle of the window is `start + 1`.
    pub start: u64,
    /// Last cycle of the window (inclusive).
    pub end: u64,
    /// Flits injected inside the window (bucketed by packet arrival
    /// cycle — exactly replayable from the injection process alone).
    pub injected_flits: u64,
    /// Flits delivered inside the window.
    pub delivered_flits: u64,
    /// Flits forwarded by any port inside the window.
    pub forwarded_flits: u64,
    /// The top-K hottest ports of the window, descending by
    /// `forwarded` (ties toward the lower port id).
    pub ports: Vec<PortWindow>,
}

impl WindowSample {
    /// Cycles the window spans.
    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    /// True for a zero-length (degenerate) window; never produced by
    /// the engine.
    pub fn is_empty(&self) -> bool {
        self.end == self.start
    }
}

/// Identifying metadata of a recorded run, supplied by the caller.
#[derive(Clone, Debug, Default)]
pub struct RunInfo {
    /// Label keys (e.g. `algo`, `pattern`, `rate`), emitted in key
    /// order like a [`TelemetryRun`](super::TelemetryRun) label.
    pub label: BTreeMap<String, String>,
    /// Topology spec string (e.g. `case-study`) so `pgft report` can
    /// rebuild the graph for attribution; empty when unknown.
    pub topo: String,
    /// Placement spec string (node-type groups); empty when unknown.
    pub placement: String,
}

/// One finished recording: provenance, totals, shed aggregate and the
/// retained window series.
#[derive(Clone, Debug)]
pub struct Recording {
    /// Caller-supplied identity (label, topology, placement).
    pub info: RunInfo,
    /// Window length the series was sampled on (cycles).
    pub window: u64,
    /// Ports retained per window sample.
    pub top_k: usize,
    /// Ring bound the series was recorded under.
    pub max_windows: usize,
    /// Directed ports of the simulated fabric.
    pub num_ports: usize,
    /// Virtual channels per port.
    pub vcs: usize,
    /// Flows in the simulated route store (self-flows included).
    pub flows: usize,
    /// Flits per packet.
    pub packet_flits: u32,
    /// Injection seed (the Python mirror replays arrivals from it).
    pub seed: u64,
    /// Offered load per flow (flits/cycle).
    pub rate: f64,
    /// Injection-process spec string (`bernoulli` / `burst:K`).
    pub injection: String,
    /// Total simulated cycles (warmup + measure + drain).
    pub horizon: u64,
    /// Forced rollover marks (phase-end cycles) of a phased replay;
    /// empty for plain runs.
    pub phases: Vec<u64>,
    /// Whole-run flit totals.
    pub totals: RunTotals,
    /// Aggregate of shed windows.
    pub shed: ShedTotals,
    /// Retained window samples, oldest first.
    pub windows: Vec<WindowSample>,
}

/// A cloneable recording sink. Disabled handles cost one branch at
/// every engine record site and allocate nothing; enabled handles
/// collect one [`Recording`] per engine run.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    sink: Option<Arc<Mutex<Vec<Recording>>>>,
    cfg: RecorderConfig,
}

impl Recorder {
    /// The no-op handle.
    pub fn disabled() -> Recorder {
        Recorder { sink: None, cfg: RecorderConfig::default() }
    }

    /// A live handle collecting recordings under `cfg`.
    pub fn enabled(cfg: RecorderConfig) -> Recorder {
        Recorder { sink: Some(Arc::new(Mutex::new(Vec::new()))), cfg }
    }

    /// Whether this handle collects anything.
    pub fn is_enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// The sampling parameters of this handle.
    pub fn config(&self) -> RecorderConfig {
        self.cfg
    }

    /// Append a finished recording (no-op when disabled).
    pub fn push(&self, rec: Recording) {
        if let Some(sink) = &self.sink {
            sink.lock().expect("recorder sink poisoned").push(rec);
        }
    }

    /// Drain the collected recordings, in completion order.
    pub fn take(&self) -> Vec<Recording> {
        match &self.sink {
            Some(sink) => std::mem::take(&mut *sink.lock().expect("recorder sink poisoned")),
            None => Vec::new(),
        }
    }
}

/// Per-run window accumulator the engine drives. All increments are
/// plain array bumps (no lock, no map); the window close is `O(touched
/// ports)`; the sink mutex is taken once, at [`EngineRec::finish`].
pub(crate) struct EngineRec {
    sink: Recorder,
    info: RunInfo,
    top_k: usize,
    max_windows: usize,
    num_ports: usize,
    vcs: usize,
    flows: usize,
    packet_flits: u32,
    seed: u64,
    rate: f64,
    injection: String,
    horizon: u64,
    win_len: u64,
    phases: Vec<u64>,
    /// Ascending window-end cycles; the last is the horizon.
    bounds: Vec<u64>,
    next: usize,
    index: u64,
    win_start: u64,
    // Window-local accumulators, reset in O(touched) at close.
    fwd: Vec<u64>,
    stalls: Vec<u64>,
    hwm: Vec<u64>,
    touched: Vec<u32>,
    touched_q: Vec<u32>,
    win_injected: u64,
    win_delivered: u64,
    win_forwarded: u64,
    totals: RunTotals,
    out: VecDeque<WindowSample>,
    shed: ShedTotals,
}

impl EngineRec {
    /// Set up the accumulator for one engine run. `phases` lists the
    /// phase-end cycles of a phased replay (forced rollovers); plain
    /// runs pass an empty slice.
    pub(crate) fn new(
        sink: &Recorder,
        info: RunInfo,
        cfg: &NetsimConfig,
        rate: f64,
        num_ports: usize,
        flows: usize,
        phases: Vec<u64>,
    ) -> EngineRec {
        let rc = sink.config();
        let horizon = cfg.warmup + cfg.measure + cfg.drain;
        let win_len = rc.window.max(1);
        let mut bounds: Vec<u64> = Vec::new();
        let mut b = win_len;
        while b < horizon {
            bounds.push(b);
            b = b.saturating_add(win_len);
        }
        bounds.extend(phases.iter().copied().filter(|&p| p > 0 && p < horizon));
        bounds.push(horizon.max(1));
        bounds.sort_unstable();
        bounds.dedup();
        let vcs = cfg.vcs as usize;
        EngineRec {
            sink: sink.clone(),
            info,
            top_k: rc.top_k.max(1),
            max_windows: rc.max_windows.max(1),
            num_ports,
            vcs,
            flows,
            packet_flits: cfg.packet_flits,
            seed: cfg.seed,
            rate,
            injection: cfg.injection.name(),
            horizon,
            win_len,
            phases,
            bounds,
            next: 0,
            index: 0,
            win_start: 0,
            fwd: vec![0; num_ports],
            stalls: vec![0; num_ports],
            hwm: vec![0; num_ports * vcs],
            touched: Vec::new(),
            touched_q: Vec::new(),
            win_injected: 0,
            win_delivered: 0,
            win_forwarded: 0,
            totals: RunTotals::default(),
            out: VecDeque::new(),
            shed: ShedTotals::default(),
        }
    }

    /// One packet created (bucketed by its arrival cycle).
    pub(crate) fn on_injected(&mut self) {
        let f = self.packet_flits as u64;
        self.win_injected += f;
        self.totals.injected_flits += f;
    }

    /// One flit transmitted by `port`.
    pub(crate) fn on_forwarded(&mut self, port: usize) {
        if self.fwd[port] == 0 && self.stalls[port] == 0 {
            self.touched.push(port as u32);
        }
        self.fwd[port] += 1;
        self.win_forwarded += 1;
        self.totals.forwarded_flits += 1;
    }

    /// One wholly credit-blocked service round at `port`.
    pub(crate) fn on_stall(&mut self, port: usize) {
        if self.fwd[port] == 0 && self.stalls[port] == 0 {
            self.touched.push(port as u32);
        }
        self.stalls[port] += 1;
    }

    /// One buffer push into (port, VC) slot `qi`, queue depth after.
    pub(crate) fn on_push(&mut self, qi: usize, depth: u64) {
        if self.hwm[qi] < depth {
            if self.hwm[qi] == 0 {
                self.touched_q.push(qi as u32);
            }
            self.hwm[qi] = depth;
        }
    }

    /// One flit delivered to its destination.
    pub(crate) fn on_delivered(&mut self) {
        self.win_delivered += 1;
        self.totals.delivered_flits += 1;
    }

    /// Called once per simulated cycle after the cycle's events: closes
    /// the current window when `t` is a boundary.
    pub(crate) fn maybe_close(&mut self, t: u64) {
        if self.next < self.bounds.len() && t == self.bounds[self.next] {
            self.close(t);
        }
    }

    fn close(&mut self, t: u64) {
        let mut sel = self.touched.clone();
        sel.sort_unstable_by_key(|&p| (Reverse(self.fwd[p as usize]), p));
        sel.truncate(self.top_k);
        let ports = sel
            .iter()
            .map(|&p| {
                let p = p as usize;
                PortWindow {
                    port: p as u32,
                    forwarded: self.fwd[p],
                    stalls: self.stalls[p],
                    vc_hwm: self.hwm[p * self.vcs..(p + 1) * self.vcs].to_vec(),
                }
            })
            .collect();
        let sample = WindowSample {
            index: self.index,
            start: self.win_start,
            end: t,
            injected_flits: self.win_injected,
            delivered_flits: self.win_delivered,
            forwarded_flits: self.win_forwarded,
            ports,
        };
        if self.out.len() == self.max_windows {
            let old = self.out.pop_front().expect("ring is non-empty at capacity");
            self.shed.windows += 1;
            self.shed.injected_flits += old.injected_flits;
            self.shed.delivered_flits += old.delivered_flits;
            self.shed.forwarded_flits += old.forwarded_flits;
        }
        self.out.push_back(sample);
        for &p in &self.touched {
            self.fwd[p as usize] = 0;
            self.stalls[p as usize] = 0;
        }
        self.touched.clear();
        for &q in &self.touched_q {
            self.hwm[q as usize] = 0;
        }
        self.touched_q.clear();
        self.win_injected = 0;
        self.win_delivered = 0;
        self.win_forwarded = 0;
        self.win_start = t;
        self.index += 1;
        self.next += 1;
    }

    /// Close any remaining window (the engine's main loop normally
    /// closes the last one at the horizon) and push the finished
    /// [`Recording`] into the sink.
    pub(crate) fn finish(mut self) {
        while self.next < self.bounds.len() {
            let b = self.bounds[self.next];
            self.close(b);
        }
        let rec = Recording {
            info: self.info,
            window: self.win_len,
            top_k: self.top_k,
            max_windows: self.max_windows,
            num_ports: self.num_ports,
            vcs: self.vcs,
            flows: self.flows,
            packet_flits: self.packet_flits,
            seed: self.seed,
            rate: self.rate,
            injection: self.injection,
            horizon: self.horizon,
            phases: self.phases,
            totals: self.totals,
            shed: self.shed,
            windows: self.out.into_iter().collect(),
        };
        self.sink.push(rec);
    }
}

// ---------------------------------------------------------------------------
// pgft-timeseries/1 document emission
// ---------------------------------------------------------------------------

fn window_json(w: &WindowSample) -> String {
    let ports: Vec<String> = w
        .ports
        .iter()
        .map(|p| {
            format!(
                "{{\"port\": {}, \"forwarded\": {}, \"stalls\": {}, \"vc_hwm\": {}}}",
                p.port,
                p.forwarded,
                p.stalls,
                u64s_json(&p.vc_hwm)
            )
        })
        .collect();
    format!(
        "        {{\"index\": {}, \"start\": {}, \"end\": {}, \"injected_flits\": {}, \
         \"delivered_flits\": {}, \"forwarded_flits\": {}, \"ports\": [{}]}}",
        w.index,
        w.start,
        w.end,
        w.injected_flits,
        w.delivered_flits,
        w.forwarded_flits,
        ports.join(", ")
    )
}

fn recording_json(rec: &Recording) -> String {
    let label = map_json(&rec.info.label, "      ", |v: &String| format!("\"{}\"", esc(v)));
    let windows = if rec.windows.is_empty() {
        "[]".to_string()
    } else {
        let items: Vec<String> = rec.windows.iter().map(window_json).collect();
        format!("[\n{}\n      ]", items.join(",\n"))
    };
    format!(
        "    {{\n      \"label\": {label},\n      \"topo\": \"{}\",\n      \
         \"placement\": \"{}\",\n      \"num_ports\": {},\n      \"vcs\": {},\n      \
         \"flows\": {},\n      \"packet_flits\": {},\n      \"seed\": {},\n      \
         \"rate\": {},\n      \"injection\": \"{}\",\n      \"horizon\": {},\n      \
         \"phases\": {},\n      \"totals\": {{\"injected_flits\": {}, \
         \"delivered_flits\": {}, \"forwarded_flits\": {}}},\n      \
         \"shed\": {{\"windows\": {}, \"injected_flits\": {}, \"delivered_flits\": {}, \
         \"forwarded_flits\": {}}},\n      \"windows\": {windows}\n    }}",
        esc(&rec.info.topo),
        esc(&rec.info.placement),
        rec.num_ports,
        rec.vcs,
        rec.flows,
        rec.packet_flits,
        rec.seed,
        rec.rate,
        esc(&rec.injection),
        rec.horizon,
        u64s_json(&rec.phases),
        rec.totals.injected_flits,
        rec.totals.delivered_flits,
        rec.totals.forwarded_flits,
        rec.shed.windows,
        rec.shed.injected_flits,
        rec.shed.delivered_flits,
        rec.shed.forwarded_flits,
    )
}

/// Render a full `pgft-timeseries/1` document. `command` names the
/// emitting subcommand; `cfg` is the shared sampling provenance of
/// every run in the document. No field is ever `null`.
pub fn timeseries_json(command: &str, cfg: &RecorderConfig, recs: &[Recording]) -> String {
    let runs = if recs.is_empty() {
        "[]".to_string()
    } else {
        let items: Vec<String> = recs.iter().map(recording_json).collect();
        format!("[\n{}\n  ]", items.join(",\n"))
    };
    format!(
        "{{\n  \"schema\": \"pgft-timeseries/1\",\n  \"command\": \"{}\",\n  \
         \"host_cpus\": {},\n  \"window\": {},\n  \"top_k\": {},\n  \
         \"max_windows\": {},\n  \"runs\": {}\n}}\n",
        esc(command),
        crate::util::par::max_threads(),
        cfg.window,
        cfg.top_k,
        cfg.max_windows,
        runs
    )
}

/// Write a `pgft-timeseries/1` document to `path`.
pub fn write_timeseries(
    path: impl AsRef<Path>,
    command: &str,
    cfg: &RecorderConfig,
    recs: &[Recording],
) -> Result<()> {
    let body = timeseries_json(command, cfg, recs);
    std::fs::write(path.as_ref(), body)
        .with_context(|| format!("write timeseries {}", path.as_ref().display()))
}

// ---------------------------------------------------------------------------
// pgft-timeseries/1 document parsing (for `pgft report`)
// ---------------------------------------------------------------------------

/// A parsed `pgft-timeseries/1` document.
#[derive(Clone, Debug)]
pub struct TimeSeriesDoc {
    /// The subcommand that emitted the document.
    pub command: String,
    /// `max_threads()` of the emitting host (provenance only).
    pub host_cpus: u64,
    /// The document-level sampling provenance.
    pub config: RecorderConfig,
    /// The labelled recordings.
    pub runs: Vec<Recording>,
}

fn req<'v>(v: &'v json::Value, key: &str) -> Result<&'v json::Value> {
    v.get(key).with_context(|| format!("pgft-timeseries: missing key {key:?}"))
}

fn req_u64(v: &json::Value, key: &str) -> Result<u64> {
    req(v, key)?.as_u64().with_context(|| format!("pgft-timeseries: {key:?} is not an integer"))
}

fn req_f64(v: &json::Value, key: &str) -> Result<f64> {
    req(v, key)?.as_f64().with_context(|| format!("pgft-timeseries: {key:?} is not a number"))
}

fn req_str<'v>(v: &'v json::Value, key: &str) -> Result<&'v str> {
    req(v, key)?.as_str().with_context(|| format!("pgft-timeseries: {key:?} is not a string"))
}

fn req_arr<'v>(v: &'v json::Value, key: &str) -> Result<&'v [json::Value]> {
    req(v, key)?.as_arr().with_context(|| format!("pgft-timeseries: {key:?} is not an array"))
}

fn u64_arr(v: &json::Value, key: &str) -> Result<Vec<u64>> {
    req_arr(v, key)?
        .iter()
        .map(|x| x.as_u64().with_context(|| format!("pgft-timeseries: {key:?} holds a non-integer")))
        .collect()
}

fn recording_from(v: &json::Value) -> Result<Recording> {
    let mut label = BTreeMap::new();
    if let json::Value::Obj(kv) = req(v, "label")? {
        for (k, val) in kv {
            let s = val.as_str().context("pgft-timeseries: label values must be strings")?;
            label.insert(k.clone(), s.to_string());
        }
    } else {
        bail!("pgft-timeseries: label is not an object");
    }
    let totals_v = req(v, "totals")?;
    let shed_v = req(v, "shed")?;
    let windows = req_arr(v, "windows")?
        .iter()
        .map(|w| {
            let ports = req_arr(w, "ports")?
                .iter()
                .map(|p| {
                    Ok(PortWindow {
                        port: req_u64(p, "port")? as u32,
                        forwarded: req_u64(p, "forwarded")?,
                        stalls: req_u64(p, "stalls")?,
                        vc_hwm: u64_arr(p, "vc_hwm")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            Ok(WindowSample {
                index: req_u64(w, "index")?,
                start: req_u64(w, "start")?,
                end: req_u64(w, "end")?,
                injected_flits: req_u64(w, "injected_flits")?,
                delivered_flits: req_u64(w, "delivered_flits")?,
                forwarded_flits: req_u64(w, "forwarded_flits")?,
                ports,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Recording {
        info: RunInfo {
            label,
            topo: req_str(v, "topo")?.to_string(),
            placement: req_str(v, "placement")?.to_string(),
        },
        window: 0, // filled from the document level by the caller
        top_k: 0,
        max_windows: 0,
        num_ports: req_u64(v, "num_ports")? as usize,
        vcs: req_u64(v, "vcs")? as usize,
        flows: req_u64(v, "flows")? as usize,
        packet_flits: req_u64(v, "packet_flits")? as u32,
        seed: req_u64(v, "seed")?,
        rate: req_f64(v, "rate")?,
        injection: req_str(v, "injection")?.to_string(),
        horizon: req_u64(v, "horizon")?,
        phases: u64_arr(v, "phases")?,
        totals: RunTotals {
            injected_flits: req_u64(totals_v, "injected_flits")?,
            delivered_flits: req_u64(totals_v, "delivered_flits")?,
            forwarded_flits: req_u64(totals_v, "forwarded_flits")?,
        },
        shed: ShedTotals {
            windows: req_u64(shed_v, "windows")?,
            injected_flits: req_u64(shed_v, "injected_flits")?,
            delivered_flits: req_u64(shed_v, "delivered_flits")?,
            forwarded_flits: req_u64(shed_v, "forwarded_flits")?,
        },
        windows,
    })
}

/// Parse a `pgft-timeseries/1` document (the inverse of
/// [`timeseries_json`], used by `pgft report`).
pub fn parse_timeseries(text: &str) -> Result<TimeSeriesDoc> {
    let v = json::parse(text)?;
    let schema = req_str(&v, "schema")?;
    ensure!(
        schema == "pgft-timeseries/1",
        "unsupported schema {schema:?} (expected pgft-timeseries/1)"
    );
    let config = RecorderConfig {
        window: req_u64(&v, "window")?,
        top_k: req_u64(&v, "top_k")? as usize,
        max_windows: req_u64(&v, "max_windows")? as usize,
    };
    let mut runs = Vec::new();
    for rv in req_arr(&v, "runs")? {
        let mut rec = recording_from(rv)?;
        rec.window = config.window;
        rec.top_k = config.top_k;
        rec.max_windows = config.max_windows;
        runs.push(rec);
    }
    Ok(TimeSeriesDoc {
        command: req_str(&v, "command")?.to_string(),
        host_cpus: req_u64(&v, "host_cpus")?,
        config,
        runs,
    })
}

pub(crate) mod json {
    //! A minimal recursive-descent JSON reader (the crate carries no
    //! serde). Numbers keep their raw token so integers round-trip
    //! exactly; only what `pgft-timeseries/1` emits is exercised, but
    //! the grammar is complete.

    use anyhow::{bail, ensure, Context, Result};

    /// A parsed JSON value.
    #[derive(Clone, Debug, PartialEq)]
    pub(crate) enum Value {
        /// `null` (never produced by pgft emitters; parsed for
        /// completeness).
        Null,
        /// `true` / `false`.
        Bool(bool),
        /// A number, kept as its raw token.
        Num(String),
        /// A string (escapes decoded).
        Str(String),
        /// An array.
        Arr(Vec<Value>),
        /// An object, in document order.
        Obj(Vec<(String, Value)>),
    }

    impl Value {
        pub(crate) fn get(&self, key: &str) -> Option<&Value> {
            match self {
                Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
                _ => None,
            }
        }

        pub(crate) fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }

        pub(crate) fn as_u64(&self) -> Option<u64> {
            match self {
                Value::Num(s) => s.parse().ok(),
                _ => None,
            }
        }

        pub(crate) fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(s) => s.parse().ok(),
                _ => None,
            }
        }

        pub(crate) fn as_arr(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(a) => Some(a),
                _ => None,
            }
        }
    }

    /// Parse one complete JSON document.
    pub(crate) fn parse(s: &str) -> Result<Value> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        ensure!(p.i == p.b.len(), "json: trailing bytes at offset {}", p.i);
        Ok(v)
    }

    struct Parser<'a> {
        b: &'a [u8],
        i: usize,
    }

    impl Parser<'_> {
        fn ws(&mut self) {
            while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
                self.i += 1;
            }
        }

        fn peek(&mut self) -> Result<u8> {
            self.ws();
            self.b.get(self.i).copied().context("json: unexpected end of input")
        }

        fn lit(&mut self, s: &str) -> Result<()> {
            ensure!(
                self.b[self.i..].starts_with(s.as_bytes()),
                "json: expected {s:?} at offset {}",
                self.i
            );
            self.i += s.len();
            Ok(())
        }

        fn value(&mut self) -> Result<Value> {
            match self.peek()? {
                b'{' => self.object(),
                b'[' => self.array(),
                b'"' => Ok(Value::Str(self.string()?)),
                b't' => self.lit("true").map(|_| Value::Bool(true)),
                b'f' => self.lit("false").map(|_| Value::Bool(false)),
                b'n' => self.lit("null").map(|_| Value::Null),
                _ => self.number(),
            }
        }

        fn object(&mut self) -> Result<Value> {
            self.lit("{")?;
            let mut kv = Vec::new();
            if self.peek()? == b'}' {
                self.i += 1;
                return Ok(Value::Obj(kv));
            }
            loop {
                ensure!(self.peek()? == b'"', "json: object key must be a string");
                let k = self.string()?;
                ensure!(self.peek()? == b':', "json: expected ':' after object key");
                self.i += 1;
                kv.push((k, self.value()?));
                match self.peek()? {
                    b',' => self.i += 1,
                    b'}' => {
                        self.i += 1;
                        return Ok(Value::Obj(kv));
                    }
                    c => bail!("json: expected ',' or '}}' in object, got {:?}", c as char),
                }
            }
        }

        fn array(&mut self) -> Result<Value> {
            self.lit("[")?;
            let mut out = Vec::new();
            if self.peek()? == b']' {
                self.i += 1;
                return Ok(Value::Arr(out));
            }
            loop {
                out.push(self.value()?);
                match self.peek()? {
                    b',' => self.i += 1,
                    b']' => {
                        self.i += 1;
                        return Ok(Value::Arr(out));
                    }
                    c => bail!("json: expected ',' or ']' in array, got {:?}", c as char),
                }
            }
        }

        fn string(&mut self) -> Result<String> {
            self.lit("\"")?;
            let mut out = String::new();
            loop {
                let c = *self.b.get(self.i).context("json: unterminated string")?;
                self.i += 1;
                match c {
                    b'"' => return Ok(out),
                    b'\\' => {
                        let e = *self.b.get(self.i).context("json: unterminated escape")?;
                        self.i += 1;
                        match e {
                            b'"' => out.push('"'),
                            b'\\' => out.push('\\'),
                            b'/' => out.push('/'),
                            b'n' => out.push('\n'),
                            b't' => out.push('\t'),
                            b'r' => out.push('\r'),
                            b'b' => out.push('\u{0008}'),
                            b'f' => out.push('\u{000c}'),
                            b'u' => {
                                let cp = self.hex4()?;
                                // Surrogate pairs are not produced by any
                                // pgft emitter; reject rather than decode
                                // them wrongly.
                                ensure!(
                                    !(0xD800..=0xDFFF).contains(&cp),
                                    "json: surrogate escapes are unsupported"
                                );
                                out.push(
                                    char::from_u32(cp).context("json: invalid \\u escape")?,
                                );
                            }
                            _ => bail!("json: bad escape \\{}", e as char),
                        }
                    }
                    _ => {
                        // Re-assemble multi-byte UTF-8 sequences: walk back
                        // one byte and take the full char from the source.
                        self.i -= 1;
                        let rest = std::str::from_utf8(&self.b[self.i..])
                            .context("json: invalid UTF-8")?;
                        let ch = rest.chars().next().context("json: unterminated string")?;
                        out.push(ch);
                        self.i += ch.len_utf8();
                    }
                }
            }
        }

        fn hex4(&mut self) -> Result<u32> {
            ensure!(self.i + 4 <= self.b.len(), "json: truncated \\u escape");
            let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
                .context("json: invalid \\u escape")?;
            let cp = u32::from_str_radix(s, 16).context("json: invalid \\u escape")?;
            self.i += 4;
            Ok(cp)
        }

        fn number(&mut self) -> Result<Value> {
            self.ws();
            let start = self.i;
            while matches!(
                self.b.get(self.i),
                Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
            ) {
                self.i += 1;
            }
            ensure!(self.i > start, "json: expected a value at offset {start}");
            let tok = std::str::from_utf8(&self.b[start..self.i]).expect("ascii token");
            tok.parse::<f64>().with_context(|| format!("json: bad number {tok:?}"))?;
            Ok(Value::Num(tok.to_string()))
        }
    }
}

// ---------------------------------------------------------------------------
// Hotspot attribution and recording diff
// ---------------------------------------------------------------------------

/// One attributed hot link: a port's windowed load mapped back to
/// (stage, switch, node-type group), with saturation-onset
/// localization. Figures are over the **retained** windows (the top-K
/// cut means totals are lower bounds for ports that sometimes fall out
/// of the selection; persistent hotspots never do).
#[derive(Clone, Debug)]
pub struct Hotspot {
    /// Global directed-port id.
    pub port: u32,
    /// Human port label (paper-style switch coordinates).
    pub label: String,
    /// Link stage (stage `l` joins levels `l-1` and `l`).
    pub stage: usize,
    /// Label of the owning element (switch coordinates or `nodeN`).
    pub switch: String,
    /// Node-type census of the nodes under the link's lower endpoint
    /// (e.g. `compute:7 io:1`), or the node's own type for stage-1
    /// injection links.
    pub group: String,
    /// Retained windows in which the port made the top-K selection.
    pub windows_seen: u64,
    /// First window index whose forwarded flits reached
    /// [`SATURATION_FRACTION`] of the window's cycle budget.
    pub onset: Option<u64>,
    /// Whether the port stayed saturated in at least half the retained
    /// windows from onset onward.
    pub persistent: bool,
    /// Largest per-window forwarded count.
    pub peak_forwarded: u64,
    /// Forwarded flits summed over the retained windows.
    pub total_forwarded: u64,
    /// `total_forwarded` over the retained cycle span (a port moves at
    /// most 1 flit/cycle, so 1.0 is a fully busy link).
    pub utilization: f64,
}

fn group_label(
    topo: &Topology,
    types: Option<&NodeTypeMap>,
    link: usize,
    cache: &mut BTreeMap<usize, String>,
) -> String {
    // The link's lower endpoint is the element that emits upward over
    // it; the group is whatever subtree hangs below that element.
    match topo.ports[topo.links[link].up_port].owner {
        Endpoint::Node(n) => match types {
            Some(t) => t.type_of(n).to_string(),
            None => "untyped".to_string(),
        },
        Endpoint::Switch(s) => {
            if let Some(g) = cache.get(&s) {
                return g.clone();
            }
            let mut counts: BTreeMap<String, usize> = BTreeMap::new();
            for node in &topo.nodes {
                if topo.is_ancestor(s, node.nid) {
                    let key = match types {
                        Some(t) => t.type_of(node.nid).to_string(),
                        None => "nodes".to_string(),
                    };
                    *counts.entry(key).or_insert(0) += 1;
                }
            }
            let label =
                counts.iter().map(|(k, v)| format!("{k}:{v}")).collect::<Vec<_>>().join(" ");
            cache.insert(s, label.clone());
            label
        }
    }
}

/// Attribute a recording's windowed load back to the topology: one
/// [`Hotspot`] per port that ever made a window's top-K selection,
/// descending by total forwarded flits (ties toward the lower port id).
pub fn attribute(
    rec: &Recording,
    topo: &Topology,
    types: Option<&NodeTypeMap>,
) -> Result<Vec<Hotspot>> {
    ensure!(
        topo.num_ports() == rec.num_ports,
        "recording is over {} ports but the topology has {} — wrong --topo?",
        rec.num_ports,
        topo.num_ports()
    );
    #[derive(Default)]
    struct Acc {
        total: u64,
        peak: u64,
        seen: u64,
        onset: Option<u64>,
        sat_windows: u64,
    }
    let mut acc: BTreeMap<u32, Acc> = BTreeMap::new();
    let covered: u64 = rec.windows.iter().map(|w| w.len()).sum();
    for w in &rec.windows {
        let budget = w.len() as f64;
        for p in &w.ports {
            let a = acc.entry(p.port).or_default();
            a.total += p.forwarded;
            a.peak = a.peak.max(p.forwarded);
            a.seen += 1;
            if p.forwarded as f64 >= SATURATION_FRACTION * budget {
                a.sat_windows += 1;
                if a.onset.is_none() {
                    a.onset = Some(w.index);
                }
            }
        }
    }
    let mut cache = BTreeMap::new();
    let mut out: Vec<Hotspot> = acc
        .into_iter()
        .map(|(port, a)| {
            let link = topo.ports[port as usize].link;
            let persistent = match a.onset {
                Some(first) => {
                    let after = rec.windows.iter().filter(|w| w.index >= first).count() as u64;
                    after > 0 && 2 * a.sat_windows >= after
                }
                None => false,
            };
            Hotspot {
                port,
                label: topo.port_label(port as usize),
                stage: topo.links[link].stage,
                switch: match topo.ports[port as usize].owner {
                    Endpoint::Switch(s) => topo.switch_label(s),
                    Endpoint::Node(n) => format!("node{n}"),
                },
                group: group_label(topo, types, link, &mut cache),
                windows_seen: a.seen,
                onset: a.onset,
                persistent,
                peak_forwarded: a.peak,
                total_forwarded: a.total,
                utilization: if covered > 0 { a.total as f64 / covered as f64 } else { 0.0 },
            }
        })
        .collect();
    out.sort_by_key(|h| (Reverse(h.total_forwarded), h.port));
    Ok(out)
}

/// How a hotspot of recording A fares in recording B.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiffVerdict {
    /// The port never made B's top-K at all.
    Absent,
    /// The port moved ≥ 10% fewer flits in B.
    Cooler,
    /// Within 10% either way.
    Similar,
    /// The port moved ≥ 10% more flits in B.
    Hotter,
}

impl fmt::Display for DiffVerdict {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DiffVerdict::Absent => "absent",
            DiffVerdict::Cooler => "cooler",
            DiffVerdict::Similar => "similar",
            DiffVerdict::Hotter => "hotter",
        })
    }
}

/// One row of a recording diff: an A-hotspot compared against B.
#[derive(Clone, Debug)]
pub struct HotspotDiff {
    /// Global directed-port id.
    pub port: u32,
    /// Human port label.
    pub label: String,
    /// Link stage.
    pub stage: usize,
    /// Node-type group under the link.
    pub group: String,
    /// Total forwarded flits in A.
    pub a_total: u64,
    /// Total forwarded flits in B (0 when absent).
    pub b_total: u64,
    /// Saturation onset in A.
    pub a_onset: Option<u64>,
    /// Saturation onset in B.
    pub b_onset: Option<u64>,
    /// Whether the port was a persistent hotspot in A.
    pub a_persistent: bool,
    /// The comparison verdict.
    pub verdict: DiffVerdict,
}

/// Diff two attributed hotspot lists: every A-hotspot is looked up in
/// B and classified ([`DiffVerdict`]). The paper-facing use is A =
/// dmodk, B = gdmodk over the same pattern and rate: gdmodk removes
/// (or strictly cools) dmodk's persistent top-stage funnel.
pub fn diff_hotspots(a: &[Hotspot], b: &[Hotspot]) -> Vec<HotspotDiff> {
    let bmap: BTreeMap<u32, &Hotspot> = b.iter().map(|h| (h.port, h)).collect();
    let mut out: Vec<HotspotDiff> = a
        .iter()
        .map(|ha| {
            let hb = bmap.get(&ha.port).copied();
            let b_total = hb.map(|h| h.total_forwarded).unwrap_or(0);
            let verdict = if b_total == 0 {
                DiffVerdict::Absent
            } else if 10 * b_total <= 9 * ha.total_forwarded {
                DiffVerdict::Cooler
            } else if 10 * ha.total_forwarded <= 9 * b_total {
                DiffVerdict::Hotter
            } else {
                DiffVerdict::Similar
            };
            HotspotDiff {
                port: ha.port,
                label: ha.label.clone(),
                stage: ha.stage,
                group: ha.group.clone(),
                a_total: ha.total_forwarded,
                b_total,
                a_onset: ha.onset,
                b_onset: hb.and_then(|h| h.onset),
                a_persistent: ha.persistent,
                verdict,
            }
        })
        .collect();
    out.sort_by_key(|d| (Reverse(d.a_total), d.port));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{build_pgft, PgftSpec};

    fn tiny_cfg(measure: u64) -> NetsimConfig {
        NetsimConfig { warmup: 0, measure, drain: 0, ..Default::default() }
    }

    fn rec_handle(window: u64, max_windows: usize) -> Recorder {
        Recorder::enabled(RecorderConfig { window, top_k: 2, max_windows })
    }

    #[test]
    fn disabled_handle_is_inert() {
        let r = Recorder::disabled();
        assert!(!r.is_enabled());
        assert!(r.take().is_empty());
        assert!(Recorder::enabled(RecorderConfig::default()).is_enabled());
        assert!(RecorderConfig::default().validate().is_ok());
        assert!(RecorderConfig { window: 0, ..Default::default() }.validate().is_err());
        assert!(RecorderConfig { top_k: 0, ..Default::default() }.validate().is_err());
        assert!(RecorderConfig { max_windows: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn windows_close_on_boundaries_and_conserve() {
        let sink = rec_handle(4, 64);
        let mut er =
            EngineRec::new(&sink, RunInfo::default(), &tiny_cfg(10), 0.5, 8, 3, Vec::new());
        for t in 1..=10u64 {
            if t == 1 {
                er.on_injected(); // 4 flits (packet_flits = 4)
                er.on_forwarded(2);
                er.on_push(5, 3);
            }
            if t == 6 {
                er.on_forwarded(2);
                er.on_forwarded(7);
                er.on_forwarded(7);
                er.on_stall(1);
                er.on_delivered();
            }
            er.maybe_close(t);
        }
        er.finish();
        let recs = sink.take();
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        let ends: Vec<u64> = r.windows.iter().map(|w| w.end).collect();
        assert_eq!(ends, vec![4, 8, 10]);
        assert_eq!(r.windows[0].injected_flits, 4);
        assert_eq!(r.windows[0].forwarded_flits, 1);
        assert_eq!(r.windows[1].forwarded_flits, 3);
        assert_eq!(r.windows[1].delivered_flits, 1);
        // Top-K ordering: port 7 (2 flits) before port 2 (1 flit);
        // stall-only port 1 is cut by top_k = 2... it ties port 2 at 0
        // forwarded? No: port 2 forwarded 1, port 7 forwarded 2, port 1
        // forwarded 0 — top_k keeps 7 then 2.
        let w1 = &r.windows[1];
        assert_eq!(w1.ports.len(), 2);
        assert_eq!((w1.ports[0].port, w1.ports[0].forwarded), (7, 2));
        assert_eq!((w1.ports[1].port, w1.ports[1].forwarded), (2, 1));
        // Window-local state reset: window 0's hwm does not leak.
        assert_eq!(r.windows[0].ports[0].port, 2);
        assert_eq!(r.windows[0].ports[0].vc_hwm, vec![0, 3]);
        assert!(w1.ports.iter().all(|p| p.vc_hwm == vec![0, 0]));
        // Conservation: Σ windows + shed == totals.
        let inj: u64 = r.windows.iter().map(|w| w.injected_flits).sum();
        assert_eq!(inj + r.shed.injected_flits, r.totals.injected_flits);
        assert_eq!(r.totals.injected_flits, 4);
        assert_eq!(r.totals.forwarded_flits, 4);
        assert_eq!(r.totals.delivered_flits, 1);
        assert_eq!(r.shed, ShedTotals::default());
    }

    #[test]
    fn ring_sheds_oldest_and_keeps_conservation() {
        let sink = rec_handle(2, 2);
        let mut er =
            EngineRec::new(&sink, RunInfo::default(), &tiny_cfg(10), 0.5, 4, 1, Vec::new());
        for t in 1..=10u64 {
            er.on_injected();
            er.on_forwarded(0);
            er.maybe_close(t);
        }
        er.finish();
        let r = &sink.take()[0];
        assert_eq!(r.windows.len(), 2, "ring bound holds");
        assert_eq!(r.shed.windows, 3, "5 windows total, 3 shed");
        assert_eq!(r.windows[0].index, 3, "oldest retained window keeps its index");
        let inj: u64 = r.windows.iter().map(|w| w.injected_flits).sum();
        let fwd: u64 = r.windows.iter().map(|w| w.forwarded_flits).sum();
        assert_eq!(inj + r.shed.injected_flits, r.totals.injected_flits);
        assert_eq!(fwd + r.shed.forwarded_flits, r.totals.forwarded_flits);
        assert_eq!(r.totals.injected_flits, 40);
        assert_eq!(r.totals.forwarded_flits, 10);
    }

    #[test]
    fn phase_marks_force_rollovers() {
        let sink = rec_handle(4, 64);
        let mut er =
            EngineRec::new(&sink, RunInfo::default(), &tiny_cfg(10), 0.5, 4, 1, vec![5, 10]);
        for t in 1..=10u64 {
            er.maybe_close(t);
        }
        er.finish();
        let r = &sink.take()[0];
        let ends: Vec<u64> = r.windows.iter().map(|w| w.end).collect();
        assert_eq!(ends, vec![4, 5, 8, 10], "phase ends split windows");
        assert_eq!(r.phases, vec![5, 10]);
        assert!(r.windows.iter().all(|w| w.start < w.end), "no degenerate windows");
    }

    #[test]
    fn document_roundtrips_and_is_null_free() {
        let sink = rec_handle(4, 64);
        let mut info = RunInfo {
            label: BTreeMap::new(),
            topo: "case-study".into(),
            placement: "paper-io".into(),
        };
        info.label.insert("algo".into(), "dmodk".into());
        let mut er = EngineRec::new(&sink, info, &tiny_cfg(8), 0.8, 8, 3, Vec::new());
        for t in 1..=8u64 {
            if t == 2 {
                er.on_injected();
                er.on_forwarded(3);
                er.on_push(6, 2);
                er.on_delivered();
            }
            er.maybe_close(t);
        }
        er.finish();
        let recs = sink.take();
        let doc = timeseries_json("netsim", &sink.config(), &recs);
        assert!(doc.contains("\"schema\": \"pgft-timeseries/1\""), "{doc}");
        assert!(doc.contains("\"window\": 4"));
        assert!(doc.contains("\"algo\": \"dmodk\""));
        assert!(!doc.contains("null"), "no-null discipline: {doc}");
        let parsed = parse_timeseries(&doc).unwrap();
        assert_eq!(parsed.command, "netsim");
        assert_eq!(parsed.config, RecorderConfig { window: 4, top_k: 2, max_windows: 64 });
        assert_eq!(parsed.runs.len(), 1);
        let (a, b) = (&parsed.runs[0], &recs[0]);
        assert_eq!(a.info.label, b.info.label);
        assert_eq!(a.info.topo, "case-study");
        assert_eq!((a.flows, a.num_ports, a.vcs), (b.flows, b.num_ports, b.vcs));
        assert_eq!(a.rate, 0.8);
        assert_eq!(a.totals, b.totals);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.windows, b.windows);
    }

    #[test]
    fn json_reader_handles_the_grammar() {
        let v = json::parse(r#"{"a": [1, 2.5, "x\n", true, false, null], "b": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2].as_str(), Some("x\n"));
        assert_eq!(arr[3], json::Value::Bool(true));
        assert_eq!(arr[5], json::Value::Null);
        assert!(json::parse("{").is_err());
        assert!(json::parse("[1,]").is_err());
        assert!(json::parse("12 34").is_err());
    }

    #[test]
    fn attribution_localizes_stage_and_group() {
        let topo = build_pgft(&PgftSpec::case_study());
        let types = crate::nodes::Placement::paper_io().apply(&topo).unwrap();
        // A synthetic recording: one top-stage down-port runs at ~0.94
        // utilization from window 0, a stage-1 port stays lukewarm.
        let top_port = topo.level_ports(topo.spec.h, false)[0] as u32;
        let leaf_port = topo.level_ports(1, false)[0] as u32;
        let window = |i: u64| WindowSample {
            index: i,
            start: i * 64,
            end: (i + 1) * 64,
            injected_flits: 100,
            delivered_flits: 80,
            forwarded_flits: 90,
            ports: vec![
                PortWindow { port: top_port, forwarded: 60, stalls: 0, vc_hwm: vec![4, 4] },
                PortWindow { port: leaf_port, forwarded: 10, stalls: 2, vc_hwm: vec![1, 0] },
            ],
        };
        let rec = Recording {
            info: RunInfo::default(),
            window: 64,
            top_k: 2,
            max_windows: 64,
            num_ports: topo.num_ports(),
            vcs: 2,
            flows: 56,
            packet_flits: 4,
            seed: 1,
            rate: 0.8,
            injection: "bernoulli".into(),
            horizon: 192,
            phases: Vec::new(),
            totals: RunTotals::default(),
            shed: ShedTotals::default(),
            windows: (0..3).map(window).collect(),
        };
        let hot = attribute(&rec, &topo, Some(&types)).unwrap();
        assert_eq!(hot.len(), 2);
        assert_eq!(hot[0].port, top_port, "hottest first");
        assert_eq!(hot[0].stage, topo.spec.h, "top-stage link");
        assert_eq!(hot[0].onset, Some(0));
        assert!(hot[0].persistent);
        assert!((hot[0].utilization - 60.0 / 64.0).abs() < 1e-9);
        assert!(hot[0].group.contains(':'), "census-style group: {}", hot[0].group);
        assert_eq!(hot[1].onset, None);
        assert!(!hot[1].persistent);
        // Wrong topology is rejected loudly.
        let rec2 = Recording { num_ports: 3, ..rec.clone() };
        assert!(attribute(&rec2, &topo, None).is_err());
    }

    #[test]
    fn diff_verdicts_cover_the_quadrants() {
        let h = |port: u32, total: u64, onset: Option<u64>| Hotspot {
            port,
            label: format!("p{port}"),
            stage: 1,
            switch: "s".into(),
            group: "g".into(),
            windows_seen: 1,
            onset,
            persistent: onset.is_some(),
            peak_forwarded: total,
            total_forwarded: total,
            utilization: 0.0,
        };
        let a = vec![h(1, 100, Some(0)), h(2, 100, None), h(3, 100, None), h(4, 100, None)];
        let b = vec![h(2, 50, None), h(3, 104, None), h(4, 200, Some(1))];
        let d = diff_hotspots(&a, &b);
        assert_eq!(d.len(), 4);
        let by_port: BTreeMap<u32, &HotspotDiff> = d.iter().map(|x| (x.port, x)).collect();
        assert_eq!(by_port[&1].verdict, DiffVerdict::Absent);
        assert!(by_port[&1].a_persistent);
        assert_eq!(by_port[&2].verdict, DiffVerdict::Cooler);
        assert_eq!(by_port[&3].verdict, DiffVerdict::Similar);
        assert_eq!(by_port[&4].verdict, DiffVerdict::Hotter);
        assert_eq!(by_port[&4].b_onset, Some(1));
    }
}

