//! The fabric event journal: a bounded ring of per-batch repair
//! records the coordinator leader appends to on every mutation and
//! exposes read-only through
//! [`FabricSnapshot`](crate::coordinator::FabricSnapshot).
//!
//! The journal is always on — the leader already pays an `Instant`
//! read per batch for `last_reroute_micros`, and a fixed-capacity ring
//! of plain-old-data records costs nothing detectable next to a
//! retrace — so a `cascade:4` drill can always be decomposed into its
//! per-phase timings after the fact, without re-running it
//! instrumented. The bound ([`JOURNAL_CAP`]) keeps a long-lived
//! coordinator's memory flat: the ring holds the most recent records
//! and silently sheds the oldest.

use std::collections::VecDeque;
use std::fmt;

/// Default journal capacity (records kept before the oldest is shed).
pub const JOURNAL_CAP: usize = 256;

/// What kind of mutation a journal record describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchKind {
    /// An incremental fault repair (link up/down batch).
    Repair,
    /// A full rebuild (algorithm switch).
    Rebuild,
    /// A batch that emptied the fault set: pristine state restored
    /// from cache, no retrace ran.
    Restore,
}

impl fmt::Display for BatchKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BatchKind::Repair => "repair",
            BatchKind::Rebuild => "rebuild",
            BatchKind::Restore => "restore",
        })
    }
}

/// One leader mutation, decomposed into its phases. Every duration is
/// wall-clock nanoseconds (the journal is diagnostic — nothing
/// deterministic reads it); every count is exact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchRecord {
    /// What the mutation was.
    pub kind: BatchKind,
    /// Link events coalesced into this batch.
    pub events: usize,
    /// Dead links after the batch was folded in.
    pub dead_links: usize,
    /// Flows the dirty scan marked for re-trace (0 for restores).
    pub dirty_flows: usize,
    /// Flows whose routes changed against the previously published
    /// store.
    pub routes_changed: usize,
    /// LFT entries that differ from the previously published tables.
    pub diff_entries: usize,
    /// Folding the event batch into the fault set.
    pub coalesce_ns: u64,
    /// Scanning the route store for flows crossing dead links.
    pub dirty_scan_ns: u64,
    /// Re-tracing the dirty flows (including the ordered splice).
    pub retrace_ns: u64,
    /// Rebuilding the forwarding tables.
    pub tables_ns: u64,
    /// Diffing the new store/tables against the published ones.
    pub diff_ns: u64,
    /// Publishing the new snapshot into the cell.
    pub publish_ns: u64,
}

impl BatchRecord {
    /// Total recorded time across every phase (nanoseconds).
    pub fn total_ns(&self) -> u64 {
        self.coalesce_ns
            + self.dirty_scan_ns
            + self.retrace_ns
            + self.tables_ns
            + self.diff_ns
            + self.publish_ns
    }
}

/// The bounded ring buffer of [`BatchRecord`]s.
#[derive(Clone, Debug, Default)]
pub struct Journal {
    cap: usize,
    buf: VecDeque<BatchRecord>,
    shed: u64,
}

impl Journal {
    /// An empty journal keeping at most `cap` records (`cap` is capped
    /// below by 1 — a zero-capacity journal would silently drop
    /// everything).
    pub fn new(cap: usize) -> Journal {
        Journal { cap: cap.max(1), buf: VecDeque::new(), shed: 0 }
    }

    /// Append a record, shedding the oldest when full.
    pub fn push(&mut self, rec: BatchRecord) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.shed += 1;
        }
        self.buf.push_back(rec);
    }

    /// Records dropped from the front of the ring since construction.
    /// `shed() + len()` is the total number of records ever pushed, so
    /// a consumer can tell a quiet fabric from a journal that wrapped.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// The retained records, oldest first.
    pub fn records(&self) -> Vec<BatchRecord> {
        self.buf.iter().cloned().collect()
    }

    /// Number of retained records.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been journalled (or everything was shed).
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The retention bound.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(events: usize) -> BatchRecord {
        BatchRecord {
            kind: BatchKind::Repair,
            events,
            dead_links: 1,
            dirty_flows: 2,
            routes_changed: 3,
            diff_entries: 4,
            coalesce_ns: 1,
            dirty_scan_ns: 2,
            retrace_ns: 3,
            tables_ns: 4,
            diff_ns: 5,
            publish_ns: 6,
        }
    }

    #[test]
    fn ring_keeps_newest_in_order() {
        let mut j = Journal::new(3);
        assert!(j.is_empty());
        for i in 0..5 {
            j.push(rec(i));
        }
        assert_eq!(j.len(), 3);
        assert_eq!(j.capacity(), 3);
        let evs: Vec<usize> = j.records().iter().map(|r| r.events).collect();
        assert_eq!(evs, vec![2, 3, 4], "oldest shed, order preserved");
    }

    #[test]
    fn shed_counts_dropped_records() {
        let mut j = Journal::new(3);
        assert_eq!(j.shed(), 0);
        for i in 0..5 {
            j.push(rec(i));
        }
        assert_eq!(j.shed(), 2, "5 pushes into cap 3 shed exactly 2");
        assert_eq!(j.shed() + j.len() as u64, 5, "shed + retained == pushed");
        j.push(rec(5));
        assert_eq!(j.shed(), 3);
    }

    #[test]
    fn total_sums_phases() {
        assert_eq!(rec(0).total_ns(), 21);
    }

    #[test]
    fn kind_labels() {
        assert_eq!(BatchKind::Repair.to_string(), "repair");
        assert_eq!(BatchKind::Rebuild.to_string(), "rebuild");
        assert_eq!(BatchKind::Restore.to_string(), "restore");
    }

    #[test]
    fn zero_capacity_clamped() {
        let mut j = Journal::new(0);
        j.push(rec(9));
        assert_eq!(j.len(), 1);
    }
}
