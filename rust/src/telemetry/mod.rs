//! Deterministic-safe instrumentation: counters, histograms, span
//! timers, and the coordinator's fabric event journal.
//!
//! The paper's argument is about *where* flits and cycles go, but
//! until this module the codebase could only report end results (C_p,
//! fair rates, saturation verdicts). This subsystem makes the four
//! load-bearing layers observable — the netsim engine (per-port
//! forwarded flits, per-VC occupancy high-water marks, credit-stall
//! counts, queue-depth histograms), the eval pipeline (retrace
//! dirty-flow counts and phase timings), the sweep runner (per-cell
//! trace/evaluate/retrace breakdown) and the coordinator leader (the
//! per-batch repair [`Journal`]) — without perturbing a single output
//! byte.
//!
//! Three rules keep it deterministic and free when unused:
//!
//!  * **Disabled means free.** [`Telemetry`] is a cloneable handle
//!    around `Option<Arc<Mutex<Registry>>>`; the disabled handle is
//!    `None` and every operation is one branch. Hot loops additionally
//!    record into plain local arrays or [`Shard`]s and merge once, so
//!    the instrumented netsim event loop costs nothing measurable with
//!    telemetry off (pinned by the bench smoke).
//!  * **Sharded recording, commutative merge.** `par_map` workers
//!    never share a lock: each records into a private [`Shard`] and
//!    the shard is folded in at scope exit. All merge rules (sum, max,
//!    element-wise sum/max, bucket-wise sum) are commutative and
//!    associative, so counter totals are thread-count-invariant.
//!  * **Simulated-cycle keys only in deterministic paths.** Anything
//!    that can feed an output or an assertion is keyed by simulated
//!    quantities (cycles, flits, queue depths). Wall-clock lives only
//!    in [`SpanStat`]s and the journal's phase timings, which are
//!    diagnostic.
//!
//! `--telemetry OUT.json` on the `sweep`, `netsim`, `eval` and
//! `fabric` subcommands emits the [`report`] module's
//! `pgft-telemetry/1` document (no-null discipline, `host_cpus`
//! provenance) plus a stderr summary table;
//! `python/tools/check_telemetry.py` cross-checks the netsim flit
//! counters against the golden Python pipeline.
//!
//! On top of the end-of-run registry sit two time-resolved layers: the
//! [`recorder`] module's flight recorder (windowed time-series of
//! per-port load, `--record OUT.json`, `pgft report` attribution/diff;
//! cross-checked by `python/tools/check_timeseries.py`) and the
//! [`trace`] module's Chrome-trace/Perfetto exporter (`--trace
//! OUT.json`).

pub mod journal;
pub mod metrics;
pub mod recorder;
pub mod report;
pub mod trace;

pub use journal::{BatchKind, BatchRecord, Journal, JOURNAL_CAP};
pub use metrics::{
    hist_bucket, Histogram, Registry, Shard, SpanStat, Telemetry, VecKind, VectorMetric,
    HIST_BUCKETS,
};
pub use recorder::{
    attribute, diff_hotspots, parse_timeseries, timeseries_json, write_timeseries, DiffVerdict,
    Hotspot, HotspotDiff, PortWindow, Recorder, RecorderConfig, Recording, RunInfo, RunTotals,
    ShedTotals, TimeSeriesDoc, WindowSample,
};
pub use report::{summary_table, telemetry_json, write_telemetry, TelemetryRun};
pub use trace::TraceBuilder;
